#!/usr/bin/env bash
# Run the hot_path bench suite and append its dated result rows to
# EXPERIMENTS.md — the one command that fills the measured-row stubs the
# toolchain-less PR containers keep leaving behind.
#
#   scripts/record_bench.sh            # full-size run (recommended)
#   BENCH_QUICK=1 scripts/record_bench.sh   # CI-sized run, labelled quick
#
# Appends a "### hot_path bench run — <date>" section containing the
# suite's markdown table verbatim, so the headline speedup rows
# ("delta speedup (target >= 4x)", "arena speedup", "shard speedup",
# "per-DC cost L=48/L=16", "serve: open-loop achieved (target >= 10k)",
# "dispatch: FCFS/LLF worst-slack ratio",
# "shift: forecaster warm-start (one-time)",
# "shift: planner step per epoch (forecast policy)",
# "oracle: per-epoch solve (L=16)",
# "oracle: per-epoch solve (L=48)",
# "signals: believed-panel resolve per epoch",
# "search: global walk (L=48)", "search: region-decomposed (L=48)",
# "search: region speedup L=48",
# "search: global walk (L=256)", "search: region-decomposed (L=256)",
# "search: region speedup L=256 (target >= 3x)",
# "search: global walk (L=512)", "search: region-decomposed (L=512)",
# "search: region speedup L=512 (target >= 3x)") are greppable
# straight from EXPERIMENTS.md.

set -euo pipefail
cd "$(dirname "$0")/.."

out="$(mktemp)"
section="$(mktemp)"
trap 'rm -f "$out" "$section"' EXIT

echo "running cargo bench --bench hot_path ..." >&2
cargo bench --bench hot_path 2>&1 | tee "$out"

# the benchkit markdown table (header + rows) printed by finish();
# extracted first so a malformed run cannot leave a dangling section
# header in EXPERIMENTS.md
rows="$(sed -n '/^## bench suite: hot_path$/,$p' "$out" | grep -E '^\|' || true)"
if [ -z "$rows" ]; then
    echo "error: no hot_path result table found in bench output" >&2
    exit 1
fi

label=""
if [ -n "${BENCH_QUICK:-}" ]; then
    label=" (BENCH_QUICK)"
fi
cores="$(nproc 2>/dev/null || sysctl -n hw.ncpu 2>/dev/null || echo '?')"

{
    echo ""
    echo "### hot_path bench run — $(date +%F)${label} ($(uname -ms), ${cores} cores)"
    echo ""
    printf '%s\n' "$rows"
} > "$section"
cat "$section" >> EXPERIMENTS.md

echo "appended dated hot_path rows to EXPERIMENTS.md" >&2
