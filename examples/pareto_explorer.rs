//! Pareto-front explorer: run the SLIT metaheuristic for a single epoch at
//! paper scale and inspect the solution set a datacenter manager would
//! choose from (§6: "allow a datacenter manager to weigh solutions ...
//! and systematically select the best solution").
//!
//!     cargo run --release --example pareto_explorer [-- --use-hlo]
//!
//! With --use-hlo the search runs on the AOT JAX/Pallas artifact via PJRT.

use slit::cluster::build_panels;
use slit::config::{SystemConfig, N_OBJ, OBJ_NAMES};
use slit::eval::{AnalyticEvaluator, EvalConsts};
use slit::opt::SlitOptimizer;
use slit::pareto::hypervolume;
use slit::power::GridSignals;
use slit::runtime::{artifacts_dir, artifacts_present, Engine, HloPlanEvaluator};
use slit::trace::Trace;

fn main() -> anyhow::Result<()> {
    let use_hlo = std::env::args().any(|a| a == "--use-hlo");
    let mut cfg = SystemConfig::paper_default();
    cfg.opt.budget_s = 10.0;
    cfg.opt.generations = 24;

    let epoch = 40; // mid-morning UTC: strong signal contrast across regions
    let trace = Trace::generate(&cfg, epoch + 1, cfg.seed);
    let signals = GridSignals::generate(&cfg, epoch + 1, cfg.seed);
    let (cp, dp) = build_panels(
        &cfg,
        &signals,
        epoch,
        &trace.epochs[epoch],
        cfg.physics.pr_off,
    );
    let ev =
        AnalyticEvaluator::new(cp, dp, EvalConsts::from_physics(&cfg.physics));

    let mut optimizer = SlitOptimizer::new(
        cfg.opt.clone(),
        cfg.num_classes(),
        cfg.datacenters.len(),
        cfg.seed,
    );
    let t = std::time::Instant::now();
    let outcome = if use_hlo {
        anyhow::ensure!(artifacts_present(), "run `make artifacts` first");
        let engine = Engine::load(&artifacts_dir())?;
        let hlo = HloPlanEvaluator::from_analytic(engine, &ev);
        optimizer.optimize(&hlo)
    } else {
        optimizer.optimize(&ev)
    };
    println!(
        "optimized epoch {epoch} in {:.2}s: {} evaluations, {} front \
         points, backend: {}\n",
        t.elapsed().as_secs_f64(),
        outcome.evaluations,
        outcome.archive.len(),
        if use_hlo { "pjrt-hlo" } else { "analytic" },
    );

    // showcased solutions
    println!(
        "| solution | {} |",
        OBJ_NAMES.to_vec().join(" | ")
    );
    println!("|---|---|---|---|---|");
    for (name, sol) in outcome.archive.showcase() {
        println!(
            "| {name} | {} |",
            sol.obj
                .iter()
                .map(|x| format!("{x:.3}"))
                .collect::<Vec<_>>()
                .join(" | ")
        );
    }

    // front diversity: objective ranges + hypervolume
    let (lo, hi) = outcome.archive.bounds();
    println!("\nfront ranges:");
    for i in 0..N_OBJ {
        println!(
            "  {:<10} [{:.3}, {:.3}]  spread {:.1}x",
            OBJ_NAMES[i],
            lo[i],
            hi[i],
            if lo[i] > 0.0 { hi[i] / lo[i] } else { f64::NAN }
        );
    }
    let mut reference = [0.0; N_OBJ];
    for i in 0..N_OBJ {
        reference[i] = hi[i] * 1.1;
    }
    println!(
        "hypervolume (vs 1.1x worst reference): {:.4}",
        hypervolume(&outcome.archive.solutions, &reference, 50_000, 1)
    );

    // where does the carbon-best plan park the load?
    if let Some(best) = outcome.archive.best_for(1) {
        println!("\nslit-carbon placement (fraction of class 0 per site):");
        for (l, d) in cfg.datacenters.iter().enumerate() {
            let f = best.plan.get(0, l);
            if f > 0.01 {
                println!(
                    "  {:<10} {:>5.1}%  (ci {:.3} kg/kWh)",
                    d.name,
                    100.0 * f,
                    ev.dp.ci[l]
                );
            }
        }
    }
    Ok(())
}
