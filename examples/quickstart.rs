//! Quickstart: simulate SLIT-Balance against the two paper baselines on a
//! small cluster and print the normalized comparison.
//!
//!     cargo run --release --example quickstart
//!
//! Takes ~10 s. For the full paper-scale reproduction see
//! examples/fig4_reproduction.rs.

use slit::config::SystemConfig;
use slit::power::GridSignals;
use slit::registry;
use slit::sim::{simulate, Scheduler, SimResult};
use slit::trace::Trace;

fn main() -> anyhow::Result<()> {
    // small_test(): 12 sites x 60 nodes, 8 epochs — laptop-friendly
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 8;
    cfg.opt.budget_s = 2.0;

    let trace = Trace::generate(&cfg, cfg.epochs, cfg.seed);
    let signals = GridSignals::generate(&cfg, cfg.epochs, cfg.seed);

    println!(
        "slit quickstart: {} datacenters, {} nodes/site, {} epochs, \
         ~{:.0} requests/epoch\n",
        cfg.datacenters.len(),
        cfg.datacenters[0].total_nodes(),
        cfg.epochs,
        trace.epochs.iter().map(|e| e.total_requests()).sum::<f64>()
            / cfg.epochs as f64,
    );

    let mut schedulers: Vec<Box<dyn Scheduler>> =
        ["helix", "splitwise", "slit-balance", "slit-carbon"]
            .into_iter()
            .map(|name| registry::build(name, &cfg, None))
            .collect::<anyhow::Result<_>>()?;

    let mut results: Vec<SimResult> = Vec::new();
    for s in &mut schedulers {
        let t = std::time::Instant::now();
        let r = simulate(&cfg, &trace, &signals, s.as_mut(), cfg.seed);
        println!(
            "  simulated {:<14} {:>6.1}s  ttft {:.3}s  carbon {:.1}kg  \
             water {:.0}L  cost ${:.2}",
            r.name,
            t.elapsed().as_secs_f64(),
            r.total.mean_ttft_s(),
            r.total.carbon_kg,
            r.total.water_l,
            r.total.cost_usd
        );
        results.push(r);
    }

    slit::cli::print_comparison(&results);
    println!(
        "\nNext steps:\n  slit simulate --framework all        # full CLI\n  \
         cargo run --release --example fig4_reproduction\n  \
         cargo run --release --example serve_realtime"
    );
    Ok(())
}
