//! END-TO-END driver: the full three-layer stack serving a live workload.
//!
//!   L1/L2  AOT JAX/Pallas plan-eval artifact (if built) executed via PJRT
//!   L3     rust coordinator: router -> batcher -> local WRR placement,
//!          epoch clock re-planning with the SLIT metaheuristic,
//!          JSON-lines TCP front
//!
//! Client threads replay a scaled BurstGPT-like trace against the TCP
//! endpoint in compressed real time; the run reports serving throughput,
//! TTFT percentiles, and the sustainability ledger. This is the record
//! kept in EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example serve_realtime [-- --analytic]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use slit::config::SystemConfig;
use slit::coordinator::{serve_forever, Coordinator, CoordinatorConfig};
use slit::opt::SlitVariant;
use slit::runtime::{artifacts_dir, artifacts_present, pjrt_enabled, Engine};
use slit::trace::Trace;
use slit::util::json::Json;
use slit::util::rng::Rng;
use slit::util::stats;

const CLIENTS: usize = 8;
const SIM_EPOCHS: usize = 6;
/// Real seconds per simulated 15-min epoch (time compression).
const EPOCH_WALL_S: f64 = 3.0;

fn main() -> anyhow::Result<()> {
    let force_analytic = std::env::args().any(|a| a == "--analytic");
    let mut cfg = SystemConfig::paper_default();
    cfg.opt.budget_s = 1.0;
    cfg.opt.generations = 6;

    let engine = if !force_analytic && pjrt_enabled() && artifacts_present() {
        println!("loading AOT artifacts (JAX/Pallas plan evaluator) ...");
        Some(Engine::load(&artifacts_dir())?)
    } else {
        println!("running with the native analytic evaluator");
        None
    };

    let ccfg = CoordinatorConfig {
        variant: SlitVariant::Balance,
        epoch_wall_s: EPOCH_WALL_S,
        plan_budget_s: 1.0,
        ..Default::default()
    };
    let coordinator = Coordinator::new(cfg.clone(), ccfg, engine);
    let clock = coordinator.spawn_epoch_clock();
    let handle = serve_forever(Arc::clone(&coordinator), 0)?;
    println!(
        "coordinator up on 127.0.0.1:{} (backend: {})\n",
        handle.port,
        coordinator.backend()
    );

    // --- load generation: replay the trace over TCP -----------------------
    let trace = Trace::generate(&cfg, SIM_EPOCHS, cfg.seed);
    let port = handle.port;
    let total_sent = Arc::new(AtomicU64::new(0));
    let t_start = std::time::Instant::now();
    let mut latencies_per_client: Vec<Vec<f64>> = Vec::new();

    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..CLIENTS {
            let trace = &trace;
            let cfg = &cfg;
            let total_sent = Arc::clone(&total_sent);
            joins.push(scope.spawn(move || -> Vec<f64> {
                let mut rng = Rng::new(1000 + c as u64);
                let mut lat = Vec::new();
                let Ok(stream) = TcpStream::connect(("127.0.0.1", port))
                else {
                    return lat;
                };
                stream.set_nodelay(true).ok(); // see §Perf: Nagle stalls
                let mut writer = stream.try_clone().expect("clone");
                let mut reader = BufReader::new(stream);
                // each client replays its share of each epoch, paced so one
                // epoch of requests spans EPOCH_WALL_S
                for epoch in 0..SIM_EPOCHS {
                    let reqs = trace.sample_requests(cfg, epoch, &mut rng);
                    let share: Vec<_> = reqs
                        .iter()
                        .skip(c)
                        .step_by(CLIENTS)
                        // cap per-client per-epoch sends: this is a latency
                        // demo, not a stress test
                        .take(400)
                        .collect();
                    let pace = EPOCH_WALL_S / share.len().max(1) as f64;
                    for r in share {
                        let msg = format!(
                            "{{\"region\": {}, \"model\": {}, \"tok_in\": {}, \"tok_out\": {}}}",
                            r.region(),
                            r.model(),
                            r.tok_in,
                            r.tok_out
                        );
                        let t0 = std::time::Instant::now();
                        if writeln!(writer, "{msg}").is_err() {
                            return lat;
                        }
                        let mut line = String::new();
                        if reader.read_line(&mut line).is_err() {
                            return lat;
                        }
                        total_sent.fetch_add(1, Ordering::Relaxed);
                        if let Ok(j) = Json::parse(line.trim()) {
                            if j.get("ok").and_then(Json::as_bool)
                                == Some(true)
                            {
                                // end-to-end = wire round-trip + simulated TTFT
                                let ttft_ms = j
                                    .get("ttft_ms")
                                    .and_then(Json::as_f64)
                                    .unwrap_or(0.0);
                                let wire_ms =
                                    t0.elapsed().as_secs_f64() * 1e3;
                                lat.push(ttft_ms + wire_ms);
                            }
                        }
                        std::thread::sleep(
                            std::time::Duration::from_secs_f64(pace * 0.8),
                        );
                    }
                }
                lat
            }));
        }
        for j in joins {
            latencies_per_client.push(j.join().expect("client"));
        }
    });

    let wall = t_start.elapsed().as_secs_f64();
    let sent = total_sent.load(Ordering::Relaxed);

    // --- shut down ----------------------------------------------------------
    {
        let mut s = TcpStream::connect(("127.0.0.1", port))?;
        writeln!(s, "{{\"op\": \"stats\"}}")?;
        let mut reader = BufReader::new(s.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let stats_json = Json::parse(line.trim())
            .map_err(|e| anyhow::anyhow!("stats parse: {e}"))?;
        writeln!(s, "{{\"op\": \"shutdown\"}}")?;
        line.clear();
        reader.read_line(&mut line).ok();

        let all: Vec<f64> = latencies_per_client.concat();
        println!("\n=== end-to-end serving report ===");
        println!("backend:              {}", coordinator.backend());
        println!("wall time:            {wall:.1} s ({SIM_EPOCHS} epochs compressed)");
        println!("requests sent:        {sent}");
        println!("throughput:           {:.1} req/s", sent as f64 / wall);
        println!(
            "served / rejected:    {} / {}",
            stats_json.f64_or("served", 0.0),
            stats_json.f64_or("rejected", 0.0)
        );
        println!(
            "plan refreshes:       {}",
            stats_json.f64_or("plan_refreshes", 0.0)
        );
        println!(
            "TTFT e2e p50/p95/p99: {:.1} / {:.1} / {:.1} ms",
            stats::percentile(&all, 50.0),
            stats::percentile(&all, 95.0),
            stats::percentile(&all, 99.0)
        );
        println!(
            "sustainability ledger: carbon {:.1} kg, water {:.0} L, cost ${:.2}",
            stats_json.f64_or("carbon_kg", 0.0),
            stats_json.f64_or("water_l", 0.0),
            stats_json.f64_or("cost_usd", 0.0)
        );
        anyhow::ensure!(sent > 0, "no requests completed");
        anyhow::ensure!(!all.is_empty(), "no latencies recorded");
    }

    handle.thread.join().ok();
    coordinator.stop();
    clock.join().ok();
    println!("\nserve_realtime OK");
    Ok(())
}
