//! Fig. 4 reproduction: normalized TTFT / carbon / energy-cost / water for
//! the five showcased SLIT solutions vs Helix vs Splitwise, at the paper's
//! experimental scale (12 DCs x 1000 nodes, 24 h = 96 epochs of 15 min,
//! 0.5x request delay, 3x tokens, 10x requests).
//!
//!     cargo run --release --example fig4_reproduction [-- --quick]
//!
//! `--quick` shrinks to 24 epochs for a fast smoke run. Results land in
//! results/fig4.json + a markdown table on stdout (EXPERIMENTS.md records
//! the canonical run).

use slit::cli::{print_comparison, write_results_json};
use slit::config::{SystemConfig, N_OBJ, OBJ_NAMES};
use slit::power::GridSignals;
use slit::registry;
use slit::sim::{simulate, SimResult};
use slit::trace::Trace;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = SystemConfig::paper_default();
    cfg.epochs = if quick { 24 } else { 96 };
    // real-time budget per epoch decision; the paper caps at 15 min — we
    // compress to keep the whole reproduction run tractable
    cfg.opt.budget_s = if quick { 0.5 } else { 2.0 };
    // capacity scaled 1:10 (100 nodes/site) so the discrete simulation of
    // ~8M requests stays tractable while utilisation pressure — where the
    // schedulers actually differentiate — matches the paper's regime.
    for d in &mut cfg.datacenters {
        d.nodes_per_type = d.nodes_per_type.iter().map(|&n| n / 10).collect();
    }

    let trace = Trace::generate(&cfg, cfg.epochs, cfg.seed);
    let signals = GridSignals::generate(&cfg, cfg.epochs, cfg.seed);
    let total_reqs: f64 =
        trace.epochs.iter().map(|e| e.total_requests()).sum();
    println!(
        "fig4 reproduction: {} epochs, {:.2}M requests total\n",
        cfg.epochs,
        total_reqs / 1e6
    );

    let mut results: Vec<SimResult> = Vec::new();
    // the registry's paper set = the Fig. 4 comparison rows
    for spec in registry::all().iter().filter(|f| f.in_paper_set) {
        let mut sched = (spec.build)(&cfg);
        let t = std::time::Instant::now();
        let r = simulate(&cfg, &trace, &signals, sched.as_mut(), cfg.seed);
        println!(
            "  {:<14} done in {:>6.1}s (decision time avg \
             {:.3}s/epoch)",
            spec.name,
            t.elapsed().as_secs_f64(),
            r.per_epoch.iter().map(|e| e.decision_s).sum::<f64>()
                / r.per_epoch.len() as f64
        );
        results.push(r);
    }

    print_comparison(&results);

    // headline reductions vs the baselines (§6 prose)
    let get = |name: &str| {
        results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.objectives())
    };
    let helix = get("helix").unwrap();
    let splitwise = get("splitwise").unwrap();
    println!("\nheadline reductions (paper: carbon 98/99%, water 97/99%, ttft 81/73%, cost 96/99%):");
    let singles = [
        ("slit-ttft", 0usize),
        ("slit-carbon", 1),
        ("slit-water", 2),
        ("slit-cost", 3),
    ];
    for (name, obj) in singles {
        if let Some(o) = get(name) {
            println!(
                "  {name:<12} {}: -{:.1}% vs helix, -{:.1}% vs splitwise",
                OBJ_NAMES[obj],
                100.0 * (1.0 - o[obj] / helix[obj]),
                100.0 * (1.0 - o[obj] / splitwise[obj]),
            );
        }
    }
    if let Some(balance) = get("slit-balance") {
        let beats_helix = (0..N_OBJ).all(|i| balance[i] <= helix[i]);
        println!(
            "  slit-balance beats helix on all four objectives: {beats_helix}"
        );
    }

    std::fs::create_dir_all("results").ok();
    write_results_json(&results, "results/fig4.json")?;
    println!("\nwrote results/fig4.json");
    Ok(())
}
