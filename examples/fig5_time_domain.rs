//! Fig. 5 reproduction: per-epoch TTFT / carbon / cost / water time series
//! for Helix vs Splitwise vs SLIT-Balance over the 24 h window.
//!
//!     cargo run --release --example fig5_time_domain [-- --quick]
//!
//! Writes results/fig5.csv with one row per (framework, epoch) — ready for
//! any plotting tool — and prints a per-framework epoch summary.

use slit::config::SystemConfig;
use slit::registry;
use slit::power::GridSignals;
use slit::sim::{simulate, SimResult};
use slit::trace::Trace;
use slit::util::csv::CsvWriter;
use slit::util::stats;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = SystemConfig::paper_default();
    cfg.epochs = if quick { 24 } else { 96 };
    cfg.opt.budget_s = if quick { 0.5 } else { 2.0 };
    // capacity scaled 1:10 (100 nodes/site) so the discrete simulation of
    // ~8M requests stays tractable while utilisation pressure — where the
    // schedulers actually differentiate — matches the paper's regime.
    for d in &mut cfg.datacenters {
        d.nodes_per_type = d.nodes_per_type.iter().map(|&n| n / 10).collect();
    }

    let trace = Trace::generate(&cfg, cfg.epochs, cfg.seed);
    let signals = GridSignals::generate(&cfg, cfg.epochs, cfg.seed);

    let frameworks = ["helix", "splitwise", "slit-balance"];
    let mut results: Vec<SimResult> = Vec::new();
    for name in frameworks {
        let mut sched = registry::build(name, &cfg, None)?;
        let t = std::time::Instant::now();
        results.push(simulate(&cfg, &trace, &signals, sched.as_mut(), cfg.seed));
        eprintln!("  {name}: {:.1}s", t.elapsed().as_secs_f64());
    }

    std::fs::create_dir_all("results").ok();
    let mut w = CsvWriter::create(
        "results/fig5.csv",
        &[
            "framework",
            "epoch",
            "ttft_s",
            "carbon_kg",
            "water_l",
            "cost_usd",
            "requests",
        ],
    )?;
    for r in &results {
        for e in &r.per_epoch {
            w.row(&[
                r.name.clone(),
                e.epoch.to_string(),
                format!("{}", e.ledger.mean_ttft_s()),
                format!("{}", e.ledger.carbon_kg),
                format!("{}", e.ledger.water_l),
                format!("{}", e.ledger.cost_usd),
                format!("{}", e.ledger.requests),
            ])?;
        }
    }
    w.finish()?;
    println!("wrote results/fig5.csv\n");

    // textual rendering of the Fig. 5 story
    println!("| framework | ttft p50/p95 (s) | carbon/epoch p50 (kg) | water/epoch p50 (L) | cost/epoch p50 ($) |");
    println!("|---|---|---|---|---|");
    for r in &results {
        let ttfts: Vec<f64> =
            r.per_epoch.iter().map(|e| e.ledger.mean_ttft_s()).collect();
        let carbon: Vec<f64> =
            r.per_epoch.iter().map(|e| e.ledger.carbon_kg).collect();
        let water: Vec<f64> =
            r.per_epoch.iter().map(|e| e.ledger.water_l).collect();
        let cost: Vec<f64> =
            r.per_epoch.iter().map(|e| e.ledger.cost_usd).collect();
        println!(
            "| {} | {:.3}/{:.3} | {:.1} | {:.0} | {:.2} |",
            r.name,
            stats::percentile(&ttfts, 50.0),
            stats::percentile(&ttfts, 95.0),
            stats::percentile(&carbon, 50.0),
            stats::percentile(&water, 50.0),
            stats::percentile(&cost, 50.0),
        );
    }

    // the Fig. 5 claims: slit-balance ~ splitwise TTFT, far lower footprint;
    // helix worse than slit-balance across the board per epoch
    let find = |n: &str| results.iter().find(|r| r.name == n).unwrap();
    let sw = find("splitwise");
    let sb = find("slit-balance");
    let hx = find("helix");
    let med =
        |r: &SimResult, f: fn(&slit::models::EpochLedger) -> f64| -> f64 {
            let v: Vec<f64> = r.per_epoch.iter().map(|e| f(&e.ledger)).collect();
            stats::percentile(&v, 50.0)
        };
    println!(
        "\nslit-balance vs splitwise: ttft ratio {:.2}, carbon ratio {:.3}",
        med(sb, |l| l.mean_ttft_s()) / med(sw, |l| l.mean_ttft_s()),
        med(sb, |l| l.carbon_kg) / med(sw, |l| l.carbon_kg),
    );
    println!(
        "slit-balance vs helix:     ttft ratio {:.2}, carbon ratio {:.3}",
        med(sb, |l| l.mean_ttft_s()) / med(hx, |l| l.mean_ttft_s()),
        med(sb, |l| l.carbon_kg) / med(hx, |l| l.carbon_kg),
    );
    Ok(())
}
