//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io registry, so this vendored crate covers
//! exactly the API surface the workspace uses: [`Error`], [`Result`], and
//! the `anyhow!` / `bail!` / `ensure!` macros, plus `From<E: std::error::
//! Error>` so `?` converts std errors. The real crate additionally carries
//! source chains and backtraces; this one flattens everything to a message,
//! which is all the callers format (`{e}` / `{e:#}`).

use std::fmt;

/// A message-carrying error type. Deliberately does **not** implement
/// `std::error::Error`, so the blanket `From` impl below does not overlap
/// with the reflexive `impl From<T> for T` (same trick as the real crate).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    fn ensured(x: i32) -> Result<i32> {
        ensure!(x > 0, "x must be positive, got {x}");
        ensure!(x < 100);
        Ok(x)
    }

    fn bailing() -> Result<()> {
        bail!("bailed with {}", 42)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let v = 7;
        let e = anyhow!("value {v} and {}", 8);
        assert_eq!(e.to_string(), "value 7 and 8");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(ensured(5).unwrap(), 5);
        let e = ensured(-1).unwrap_err();
        assert_eq!(e.to_string(), "x must be positive, got -1");
        let e = ensured(200).unwrap_err();
        assert!(e.to_string().contains("condition failed"));
        let e = bailing().unwrap_err();
        assert_eq!(e.to_string(), "bailed with 42");
    }

    #[test]
    fn display_alternate_matches_plain() {
        let e = anyhow!("msg");
        assert_eq!(format!("{e}"), format!("{e:#}"));
        assert_eq!(format!("{e:?}"), "msg");
    }
}
