//! Determinism regression: the same seed must produce bit-identical
//! `SimResult` objectives regardless of how many threads the parallel
//! evaluation hot path uses. This pins down the tentpole guarantees:
//! order-preserving `par_map`, the pure plan-fingerprint memo cache, and
//! the optimizer's main-thread-only RNG.
//!
//! This lives in its own integration binary so the global thread override
//! cannot race with other tests.

use slit::config::SystemConfig;
use slit::opt::{SearchMode, SlitOptions, SlitScheduler, SlitVariant};
use slit::power::GridSignals;
use slit::scenario::Scenario;
use slit::sim::{simulate, SimResult};
use slit::trace::Trace;
use slit::util::threadpool;

/// Both tests flip the process-global thread override, so they must not
/// interleave (the test harness runs #[test] fns concurrently).
static OVERRIDE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn run_world(cfg: &SystemConfig, trace: &Trace, signals: &GridSignals) -> SimResult {
    let mut sched = SlitScheduler::new(cfg, SlitVariant::Balance);
    simulate(cfg, trace, signals, &mut sched, 9)
}

#[test]
fn same_seed_same_objectives_for_any_thread_count() {
    let _guard = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 3;
    // wall-clock must never truncate the search, or timing differences
    // between thread counts would leak into the result
    cfg.opt.budget_s = 1e9;
    let trace = Trace::generate(&cfg, cfg.epochs, 9);
    let signals = GridSignals::generate(&cfg, cfg.epochs, 9);

    threadpool::set_thread_override(1);
    let serial = run_world(&cfg, &trace, &signals);

    threadpool::set_thread_override(threadpool::hardware_threads().max(4));
    let parallel = run_world(&cfg, &trace, &signals);

    threadpool::set_thread_override(0);
    let default = run_world(&cfg, &trace, &signals);

    assert_eq!(serial.objectives(), parallel.objectives());
    assert_eq!(serial.objectives(), default.objectives());
    assert_eq!(serial.total.requests, parallel.total.requests);
    assert_eq!(serial.total.dropped, parallel.total.dropped);
    assert_eq!(serial.total.e_it_j, parallel.total.e_it_j);
    assert_eq!(serial.total.ttft_sum_s, parallel.total.ttft_sum_s);
    // per-epoch plans are bit-identical too
    for (a, b) in serial.per_epoch.iter().zip(&parallel.per_epoch) {
        assert_eq!(a.plan, b.plan, "epoch {} plan diverged", a.epoch);
    }
}

#[test]
fn region_decomposed_search_is_thread_count_invariant() {
    // the decomposed search fans region subsearches out over the pool;
    // per-region RNG streams + position-stable RegionSub state + the
    // main-thread merge must keep results bit-identical whether the
    // subsearches run serially (override 1), on many workers, or on the
    // hardware default — and across repeated runs
    let _guard = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 2;
    cfg.opt.budget_s = 1e9;
    let trace = Trace::generate(&cfg, cfg.epochs, 11);
    let signals = GridSignals::generate(&cfg, cfg.epochs, 11);
    let run = || {
        let mut sched = SlitScheduler::new(&cfg, SlitVariant::Balance)
            .with_options(SlitOptions {
                search_mode: Some(SearchMode::RegionDecomposed),
                ..SlitOptions::default()
            });
        simulate(&cfg, &trace, &signals, &mut sched, 11)
    };

    threadpool::set_thread_override(1);
    let serial = run();
    let serial_again = run();

    threadpool::set_thread_override(threadpool::hardware_threads().max(4));
    let parallel = run();

    threadpool::set_thread_override(0);
    let default = run();

    assert_eq!(serial.name, "slit-region");
    assert_eq!(serial.objectives(), serial_again.objectives());
    assert_eq!(serial.objectives(), parallel.objectives());
    assert_eq!(serial.objectives(), default.objectives());
    for (a, b) in serial.per_epoch.iter().zip(&parallel.per_epoch) {
        assert_eq!(a.plan, b.plan, "epoch {} plan diverged", a.epoch);
    }
    for (a, b) in serial.per_epoch.iter().zip(&default.per_epoch) {
        assert_eq!(a.plan, b.plan, "epoch {} plan diverged", a.epoch);
    }
}

#[test]
fn scenario_worlds_are_thread_count_invariant_too() {
    let _guard = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 2;
    cfg.opt.budget_s = 1e9;
    let world = Scenario::CarbonSpike.build(&cfg, cfg.epochs, 5);

    threadpool::set_thread_override(1);
    let serial = run_world(&world.cfg, &world.trace, &world.signals);
    threadpool::set_thread_override(8);
    let parallel = run_world(&world.cfg, &world.trace, &world.signals);
    threadpool::set_thread_override(0);

    assert_eq!(serial.objectives(), parallel.objectives());
}
