//! CI-sized versions of the hot_path bench rows, runnable inside the
//! blocking `BENCH_QUICK=1 cargo test --all-targets` job:
//!
//!   * delta-vs-full neighbour scoring (the PR 4 O(L) vs O(K*L) path),
//!   * arena-vs-clone candidate batch build,
//!   * sharded-vs-global memo cache under thread contention,
//!   * L=48 tiled-DC smoke: the spilled `DcVec` path at planet scale —
//!     delta-vs-full parity and the per-DC L=16 vs L=48 scaling row,
//!   * region-decomposed search: forced global walk vs the
//!     price-coordinated decomposition on the same L=128 panels —
//!     bit-determinism and canonical-rescore parity asserted, the
//!     speedup printed,
//!   * loadgen smoke: closed-loop traffic over a real socket against the
//!     sharded-worker TCP front — zero dropped replies, request mass
//!     conserved end to end, finite TTFT p99,
//!   * LLF-vs-FCFS dispatch: slack-normalized worst-class p99 under the
//!     same saturating batch stream for both policies,
//!   * temporal shifting: batch-overnight carbon with vs without the
//!     forecast-driven release policy, at (asserted) equal served mass
//!     and zero missed deadlines,
//!   * oracle gap smoke: per-epoch lower-bound solve timing at L=16 and
//!     L=48 plus a blocking soundness + ceiling check on a slit-carbon
//!     run's recorded gaps,
//!   * signal fallback overhead: the believed-panel resolve (feed observe
//!     + robust view) per epoch, with a blocking no-fault bit-parity
//!     check — both believed views must reproduce the truth exactly when
//!     the feeds are healthy.
//!
//! Each test asserts bit/tolerance *parity* between the fast and reference
//! paths (the correctness half of the bench) and prints the measured
//! speedup row with `--nocapture` for eyeballing; hard speedup thresholds
//! live only in `benches/hot_path.rs` output, not as assertions, so a
//! noisy shared CI runner cannot flake the blocking job.

use std::time::Instant;

use slit::cluster::build_panels;
use slit::config::{SystemConfig, N_OBJ};
use slit::eval::{AnalyticEvaluator, BatchEvaluator, EvalConsts, MemoizedEvaluator};
use slit::plan::{Plan, PlanBatch};
use slit::power::GridSignals;
use slit::trace::Trace;
use slit::util::benchkit;
use slit::util::rng::Rng;
use slit::util::threadpool;

fn make_eval() -> (SystemConfig, AnalyticEvaluator) {
    let cfg = SystemConfig::paper_default();
    let signals = GridSignals::generate(&cfg, 8, 3);
    let trace = Trace::generate(&cfg, 8, 3);
    let (cp, dp) = build_panels(&cfg, &signals, 4, &trace.epochs[4], 0.05);
    let consts = EvalConsts::from_physics(&cfg.physics);
    (cfg, AnalyticEvaluator::new(cp, dp, consts))
}

#[test]
fn row_delta_vs_full_neighbor_scoring() {
    let (cfg, ev) = make_eval();
    let k_n = cfg.num_classes();
    let mut rng = Rng::new(41);
    let base = Plan::random(k_n, ev.dcs(), 0.5, &mut rng);
    let agg = ev.aggregate(base.as_slice());
    // one-row neighbours, the shape the SLIT search scores all day
    let cands: Vec<(usize, Plan)> = (0..256)
        .map(|_| {
            let k = rng.below(k_n);
            let to = rng.below(ev.dcs());
            (k, base.shifted_toward(k, to, rng.range(0.2, 0.8)))
        })
        .collect();

    let reps = 50;
    let t = Instant::now();
    let mut full_sum = 0.0;
    for _ in 0..reps {
        for (_, c) in &cands {
            full_sum += core::hint::black_box(ev.evaluate(c))[0];
        }
    }
    let full_s = t.elapsed().as_secs_f64();

    let t = Instant::now();
    let mut delta_sum = 0.0;
    for _ in 0..reps {
        for (k, c) in &cands {
            delta_sum += core::hint::black_box(ev.evaluate_delta(
                &agg,
                *k,
                base.row(*k),
                c.row(*k),
            ))[0];
        }
    }
    let delta_s = t.elapsed().as_secs_f64();

    // parity: every candidate's delta score within 1e-9 relative
    for (k, c) in &cands {
        let fast = ev.evaluate_delta(&agg, *k, base.row(*k), c.row(*k));
        let full = ev.evaluate(c);
        for i in 0..N_OBJ {
            let err = (fast[i] - full[i]).abs() / full[i].abs().max(1e-12);
            assert!(err <= 1e-9, "obj {i}: {} vs {}", fast[i], full[i]);
        }
    }
    assert!(full_sum.is_finite() && delta_sum.is_finite());
    println!(
        "| neighbor scoring: delta vs full | {:.2}x | ({:.1} us vs {:.1} us per 256) |",
        full_s / delta_s.max(1e-12),
        delta_s / reps as f64 * 1e6,
        full_s / reps as f64 * 1e6,
    );
}

#[test]
fn row_arena_vs_clone_candidate_build() {
    let (cfg, ev) = make_eval();
    let k_n = cfg.num_classes();
    let l_n = ev.dcs();
    let mut seed_rng = Rng::new(43);
    let currents: Vec<Plan> = (0..24)
        .map(|_| Plan::random(k_n, l_n, 0.5, &mut seed_rng))
        .collect();
    let neighbors = 8;
    let step = 0.25;
    let reps = 50;

    // arena path: one contiguous buffer, no per-candidate Plan
    let mut arena = PlanBatch::new(k_n, l_n);
    arena.reserve(currents.len() * neighbors);
    let t = Instant::now();
    for r in 0..reps {
        let mut rng = Rng::new(1000 + r as u64);
        arena.clear();
        for cur in &currents {
            arena.push_neighbors_of(cur.as_slice(), neighbors, step, &mut rng);
        }
        core::hint::black_box(arena.len());
    }
    let arena_s = t.elapsed().as_secs_f64();

    // clone path: the historical per-candidate Plan generation (the
    // shared reference generator the arena is parity-pinned against)
    let t = Instant::now();
    let mut last = 0usize;
    for r in 0..reps {
        let mut rng = Rng::new(1000 + r as u64);
        let mut cands: Vec<Plan> = Vec::new();
        for cur in &currents {
            cands.extend(benchkit::clone_path_neighbors(
                cur, neighbors, step, &mut rng,
            ));
        }
        last = cands.len();
        core::hint::black_box(&cands);
        // parity on the final rep: arena contents == clone contents bitwise
        if r == reps - 1 {
            for (i, p) in cands.iter().enumerate() {
                assert_eq!(arena.candidate(i), p.as_slice(), "candidate {i}");
            }
        }
    }
    let clone_s = t.elapsed().as_secs_f64();
    assert_eq!(last, arena.len());
    println!(
        "| candidate build: arena vs clone | {:.2}x | ({:.1} us vs {:.1} us per step) |",
        clone_s / arena_s.max(1e-12),
        arena_s / reps as f64 * 1e6,
        clone_s / reps as f64 * 1e6,
    );
}

/// Evaluator over the first `dcs` sites of the planet-scale fleet.
fn make_fleet_eval(dcs: usize) -> (SystemConfig, AnalyticEvaluator) {
    let mut cfg = SystemConfig::paper_default();
    cfg.datacenters =
        slit::scenario::global_fleet_datacenters(6)[..dcs].to_vec();
    cfg.validate().expect("fleet slice must validate");
    let signals = GridSignals::generate(&cfg, 8, 3);
    let trace = Trace::generate(&cfg, 8, 3);
    let (cp, dp) = build_panels(&cfg, &signals, 4, &trace.epochs[4], 0.05);
    let consts = EvalConsts::from_physics(&cfg.physics);
    (cfg, AnalyticEvaluator::new(cp, dp, consts))
}

#[test]
fn row_l48_tiled_dc_smoke() {
    use slit::eval::PlanAgg;

    // per-candidate delta rescore (the SLIT search loop shape: scratch
    // copy_from + masked row deltas + finish) at both tile regimes
    let time_and_check = |dcs: usize| -> f64 {
        let (cfg, ev) = make_fleet_eval(dcs);
        let k_n = cfg.num_classes();
        let mut rng = Rng::new(53);
        let base = Plan::random(k_n, dcs, 0.5, &mut rng);
        let agg = ev.aggregate(base.as_slice());
        // parity first: finish(aggregate) == evaluate, and every one-row
        // delta within 1e-9 relative of the full evaluation
        assert_eq!(ev.finish(&agg), ev.evaluate(&base), "L={dcs}");
        let cands: Vec<(usize, Plan)> = (0..128)
            .map(|_| {
                let k = rng.below(k_n);
                let to = rng.below(dcs);
                (k, base.shifted_toward(k, to, rng.range(0.2, 0.8)))
            })
            .collect();
        let mut scratch = PlanAgg::zeros(dcs);
        for (k, c) in &cands {
            scratch.copy_from(&agg);
            ev.apply_row_delta(&mut scratch, *k, base.row(*k), c.row(*k));
            let fast = ev.finish(&scratch);
            let full = ev.evaluate(c);
            for i in 0..N_OBJ {
                let err = (fast[i] - full[i]).abs() / full[i].abs().max(1e-12);
                assert!(
                    err <= 1e-9,
                    "L={dcs} obj {i}: {} vs {}",
                    fast[i],
                    full[i]
                );
            }
        }
        let reps = 20;
        let t = Instant::now();
        for _ in 0..reps {
            for (k, c) in &cands {
                scratch.copy_from(&agg);
                ev.apply_row_delta(&mut scratch, *k, base.row(*k), c.row(*k));
                core::hint::black_box(ev.finish(&scratch));
            }
        }
        t.elapsed().as_secs_f64() / (reps * cands.len()) as f64
    };

    let t16 = time_and_check(16);
    let t48 = time_and_check(48);
    println!(
        "| delta rescore per-DC cost: L=48 vs L=16 | {:.2}x | ({:.0} ns vs {:.0} ns per candidate) |",
        (t48 / 48.0) / (t16 / 16.0).max(1e-12),
        t48 * 1e9,
        t16 * 1e9,
    );
}

/// CI twin of the hot_path region-decomposition rows (PR 10): one
/// optimizer run per search mode on identical L=128 epoch panels (past
/// the auto threshold, so this is the fleet scale the decomposition
/// exists for, shrunk to CI size by the tiny search knobs). The blocking
/// half asserts what must hold exactly: the decomposed run is
/// bit-deterministic across repeats, its merged archive is mutually
/// non-dominated, and every archived objective vector equals a fresh
/// canonical rescore of its plan bit-for-bit (the merge really did go
/// through `finish∘aggregate` on the whole fleet, not a per-region
/// approximation). The wall-clock ratio is printed, never asserted.
#[test]
fn row_region_decomposed_speedup() {
    use slit::opt::{
        SearchMode, SlitOptimizer, SlitOptions, SlitOutcome,
        REGION_DECOMPOSE_THRESHOLD,
    };

    let mut cfg = SystemConfig::paper_default();
    cfg.datacenters = slit::scenario::global_fleet_datacenters(16);
    cfg.validate().expect("fleet must validate");
    let dcs = cfg.datacenters.len();
    assert_eq!(dcs, 128);
    assert!(dcs >= REGION_DECOMPOSE_THRESHOLD);
    cfg.opt.population = 12;
    cfg.opt.generations = 2;
    cfg.opt.search_steps = 3;
    cfg.opt.neighbors = 4;
    cfg.opt.gbdt_trees = 10;
    cfg.opt.train_freq = 2; // walk trains its surrogate at gen 1
    cfg.opt.budget_s = 60.0;
    let signals = GridSignals::generate(&cfg, 8, 3);
    let trace = Trace::generate(&cfg, 8, 3);
    let (cp, dp) = build_panels(&cfg, &signals, 4, &trace.epochs[4], 0.05);
    let consts = EvalConsts::from_physics(&cfg.physics);
    let ev = AnalyticEvaluator::new(cp, dp, consts);
    let regions: Vec<usize> =
        cfg.datacenters.iter().map(|d| d.region).collect();
    let k_n = cfg.num_classes();

    let run = |mode: SearchMode| -> (f64, SlitOutcome) {
        let t = Instant::now();
        let mut o = SlitOptimizer::new(cfg.opt.clone(), k_n, dcs, 7)
            .with_options(SlitOptions {
                search_mode: Some(mode),
                ..SlitOptions::default()
            })
            .with_regions(regions.clone());
        let out = o.optimize(&ev);
        (t.elapsed().as_secs_f64(), out)
    };

    let (global_s, global) = run(SearchMode::Global);
    let (region_s, region) = run(SearchMode::RegionDecomposed);
    let (_, region_again) = run(SearchMode::RegionDecomposed);

    // the decomposed phase really ran (no silent fallback to the walk)
    assert_eq!(region.surrogate_trainings, 0, "fallback to global walk?");
    assert!(global.surrogate_trainings > 0);
    assert!(!region.archive.is_empty() && region.archive.is_consistent());
    assert!(!global.archive.is_empty() && global.archive.is_consistent());

    // bit-determinism across repeats on the same seed
    let objs = |o: &SlitOutcome| -> Vec<[f64; N_OBJ]> {
        o.archive.solutions.iter().map(|s| s.obj).collect()
    };
    assert_eq!(region.evaluations, region_again.evaluations);
    assert_eq!(region.delta_evals, region_again.delta_evals);
    assert_eq!(objs(&region), objs(&region_again));

    // canonical-rescore parity: archived objectives are the whole-fleet
    // evaluation of the merged plan, bit-for-bit
    for (i, s) in region.archive.solutions.iter().enumerate() {
        assert_eq!(ev.evaluate(&s.plan), s.obj, "solution {i} not canonical");
    }

    println!(
        "| search: global vs region-decomposed (L=128) | {:.2}x | ({:.1} ms vs {:.1} ms per epoch search) |",
        global_s / region_s.max(1e-12),
        region_s * 1e3,
        global_s * 1e3,
    );
}

/// A coordinator sized for CI serving rows: tiny optimizer budget, no
/// epoch thread.
fn boot_coordinator(
    policy: slit::coordinator::DispatchPolicy,
) -> std::sync::Arc<slit::coordinator::Coordinator> {
    use slit::coordinator::{Coordinator, CoordinatorConfig};
    let mut cfg = SystemConfig::small_test();
    cfg.opt.generations = 2;
    cfg.opt.population = 8;
    let mut ccfg = CoordinatorConfig {
        plan_budget_s: 0.2,
        ..Default::default()
    };
    ccfg.batcher.policy = policy;
    Coordinator::new(cfg, ccfg, None)
}

/// CI twin of the hot_path serve-loop row: a few hundred closed-loop
/// requests over a real socket. The correctness half is asserted (zero
/// dropped replies, zero structured errors, request mass conserved on both
/// sides of the wire, finite percentiles); the achieved req/s is printed
/// for eyeballing only.
#[test]
fn row_loadgen_closed_loop_smoke() {
    use slit::coordinator::{
        run_loadgen, serve_forever, ArrivalMode, DispatchPolicy,
        LoadgenConfig,
    };

    let c = boot_coordinator(DispatchPolicy::Llf);
    let handle =
        serve_forever(std::sync::Arc::clone(&c), 0).expect("bind ephemeral");
    let lcfg = LoadgenConfig {
        port: handle.port,
        mode: ArrivalMode::Closed,
        conns: 4,
        requests: 320,
        batch: 4,
        ..Default::default()
    };
    let r = run_loadgen(&lcfg).expect("loadgen");

    // the client-side accounting invariant, then agreement with the server
    assert_eq!(
        r.ok + r.saturated + r.errors + r.dropped_replies,
        r.sent,
        "request mass leaked client-side"
    );
    assert_eq!(r.sent, 320, "closed loop must send every request");
    assert_eq!(r.dropped_replies, 0, "replies dropped");
    assert_eq!(r.errors, 0, "structured errors under clean load");
    assert_eq!(r.overloaded_conns, 0, "shed below max_conns");
    assert!(r.ttft.p99().is_finite() && r.ttft.p99() > 0.0);
    assert!(r.rtt.p99().is_finite() && r.rtt.p99() > 0.0);
    let m = c.metrics_snapshot();
    assert_eq!(
        m.served + m.rejected,
        r.ok + r.saturated,
        "server-side accounting disagrees with the client's view"
    );
    println!(
        "| loadgen closed-loop smoke | {:.0} req/s | (320 reqs, 4 conns, batch 4, ttft p99 {:.2} ms, rtt p99 {:.2} ms) |",
        r.achieved_rps(),
        r.ttft.p99() * 1e3,
        r.rtt.p99() * 1e3,
    );
    c.stop();
    handle.thread.join().expect("server thread");
}

/// LLF-vs-FCFS dispatch under a saturating batch stream: identical
/// mixed-class waves into two coordinators differing only in policy. The
/// figure of merit is the worst class's slack-normalized p99 (TTFT p99
/// divided by that model's TTFT SLO) — LLF spends scarce site capacity on
/// tight-SLO groups first, so its worst-case slack should not degrade vs
/// FCFS. Mass conservation is asserted; the comparison itself is printed,
/// not asserted, per the noisy-runner policy above.
#[test]
fn row_llf_vs_fcfs_slack_normalized_p99() {
    use slit::config::{MODELS, REGIONS};
    use slit::coordinator::DispatchPolicy;

    let run = |policy: DispatchPolicy| -> (f64, u64, u64) {
        let c = boot_coordinator(policy);
        // enough mass to fill the small-test fleet's epoch capacity, so
        // dispatch order decides who commits the last slots
        for wave in 0..16usize {
            let reqs: Vec<(usize, usize, u32, u32)> = (0..64)
                .map(|i| ((i + wave) % REGIONS, i % MODELS, 128, 256))
                .collect();
            core::hint::black_box(c.handle_batch(&reqs));
        }
        let m = c.metrics_snapshot();
        let worst = m
            .class_ttft
            .iter()
            .enumerate()
            .filter(|(_, h)| h.count() > 0)
            .map(|(k, h)| h.p99() / c.cfg.models[k % MODELS].ttft_slo_s)
            .fold(0.0f64, f64::max);
        (worst, m.served, m.rejected)
    };

    let (llf, llf_served, llf_rejected) = run(DispatchPolicy::Llf);
    let (fcfs, fcfs_served, fcfs_rejected) = run(DispatchPolicy::Fcfs);
    // ordering redistributes capacity between classes; it must not change
    // how many requests the fleet absorbs in total
    assert_eq!(
        llf_served + llf_rejected,
        fcfs_served + fcfs_rejected,
        "policy changed total request mass"
    );
    assert!(llf.is_finite() && llf > 0.0);
    assert!(fcfs.is_finite() && fcfs > 0.0);
    println!(
        "| dispatch: LLF vs FCFS worst slack-normalized p99 | {:.2}x | (p99/SLO {:.3} vs {:.3}; served {} vs {}) |",
        fcfs / llf.max(1e-12),
        llf,
        fcfs,
        llf_served,
        fcfs_served,
    );
}

/// CI twin of the hot_path shift-overhead row: the batch-overnight regime
/// under the same spatial policy with and without forecast-driven temporal
/// shifting. Mass parity and zero missed deadlines are asserted (the
/// correctness half — the strict carbon win is pinned at full size in
/// scenario_matrix.rs); the carbon ratio and wall-clock overhead of the
/// shifting layer are printed for eyeballing.
#[test]
fn row_shift_carbon_vs_noshift() {
    use slit::baselines::RoundRobinScheduler;
    use slit::opt::ShiftScheduler;
    use slit::scenario::Scenario;
    use slit::sim::simulate;

    let mut base = SystemConfig::small_test();
    base.epochs = 30;
    let world = Scenario::BatchOvernight.build(&base, base.epochs, 9);

    let t = Instant::now();
    let mut bare = RoundRobinScheduler;
    let noshift =
        simulate(&world.cfg, &world.trace, &world.signals, &mut bare, 9);
    let noshift_s = t.elapsed().as_secs_f64();

    let t = Instant::now();
    let mut wrapped = ShiftScheduler::new(Box::new(RoundRobinScheduler));
    let shift =
        simulate(&world.cfg, &world.trace, &world.signals, &mut wrapped, 9);
    let shift_s = t.elapsed().as_secs_f64();

    // the correctness half: exact served-mass parity (integral lots) and
    // zero missed deadlines on both sides
    assert_eq!(
        shift.total.requests, noshift.total.requests,
        "release schedule changed the served mass"
    );
    assert!(shift.total.requests > 0.0);
    assert_eq!(shift.total.deferred_expired, 0.0, "missed deadlines");
    assert_eq!(noshift.total.deferred_expired, 0.0);
    assert_eq!(
        shift.total.deferred_offered,
        shift.total.deferred_released,
        "queue not drained"
    );
    println!(
        "| temporal shift: carbon vs no-shift | {:.3}x | ({:.2} kg vs {:.2} kg; {:.1} ms vs {:.1} ms wall for 30 epochs) |",
        shift.total.carbon_kg / noshift.total.carbon_kg.max(1e-12),
        shift.total.carbon_kg,
        noshift.total.carbon_kg,
        shift_s * 1e3,
        noshift_s * 1e3,
    );
}

/// CI twin of the hot_path oracle rows: time the per-epoch lower-bound
/// solve (all four objectives) at L=16 and L=48, then run a short
/// slit-carbon session and assert the recorded optimality gap on the
/// carbon objective stays inside the pinned ceiling every epoch — the
/// blocking half of the PR 8 calibrated-quality claim. Timing is printed
/// for eyeballing only, per the noisy-runner policy above.
#[test]
fn row_oracle_gap_smoke() {
    use slit::opt::epoch_lower_bound;

    // matches the scenario_matrix default ceiling; a ratchet, not a target
    const GAP_CEILING: f64 = 0.95;

    let time_solve = |ev: &AnalyticEvaluator| -> f64 {
        let reps = 10;
        let t = Instant::now();
        for _ in 0..reps {
            for obj in 0..N_OBJ {
                let b = core::hint::black_box(epoch_lower_bound(ev, obj));
                assert!(b.score().is_finite(), "obj {obj}");
                assert!(b.slack >= 0.0, "obj {obj}");
            }
        }
        t.elapsed().as_secs_f64() / reps as f64
    };
    let (_, ev16) = make_fleet_eval(16);
    let t16 = time_solve(&ev16);
    let (_, ev48) = make_fleet_eval(48);
    let t48 = time_solve(&ev48);

    // the blocking half: a real session's recorded gaps are sound and
    // bounded on the target objective
    use slit::config::OBJ_CARBON;
    use slit::sim::simulate;
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 4;
    cfg.opt.generations = 2;
    let trace = Trace::generate(&cfg, cfg.epochs, 11);
    let signals = GridSignals::generate(&cfg, cfg.epochs, 11);
    let mut sched = slit::registry::build("slit-carbon", &cfg, None)
        .expect("slit-carbon in registry");
    let res = simulate(&cfg, &trace, &signals, sched.as_mut(), 11);
    for rec in &res.per_epoch {
        let g = &rec.gaps[OBJ_CARBON];
        assert!(
            g.oracle_score.is_finite() && g.oracle_score <= g.achieved,
            "epoch {}: unsound gap {g:?}",
            rec.epoch
        );
        assert!(
            (0.0..=GAP_CEILING).contains(&g.gap_frac),
            "epoch {}: carbon gap {} outside [0, {GAP_CEILING}]",
            rec.epoch,
            g.gap_frac
        );
    }
    let run_gap = res.oracle_gap(OBJ_CARBON);
    assert!((0.0..=GAP_CEILING).contains(&run_gap));
    println!(
        "| oracle gap smoke: slit-carbon run gap {:.3} | L=48 vs L=16 solve {:.2}x | ({:.1} us vs {:.1} us per 4-objective epoch) |",
        run_gap,
        t48 / t16.max(1e-12),
        t48 * 1e6,
        t16 * 1e6,
    );
}

/// CI twin of the hot_path believed-panel row: the per-epoch cost of the
/// degraded-signal feed (delivery + plausibility gates + fleet median +
/// robust-view resolve). The correctness half is asserted — with zero
/// faults both believed views reproduce the ground truth bit-for-bit and
/// the whole fleet stays Fresh — so the resilience layer is provably free
/// when the feeds are healthy; the timing is printed for eyeballing. The
/// zero-heap-allocation pin for the warm resolve loop lives in
/// alloc_hotpath.rs (the one binary with the counting allocator).
#[test]
fn row_signal_fallback_overhead() {
    use slit::signals::{SignalFeed, SignalPolicy};

    let cfg = SystemConfig::paper_default();
    let epochs = 64;
    let signals = GridSignals::generate(&cfg, epochs, 3);
    // pre-resolve the truth rows so the timed loop measures the feed, not
    // the signal generator
    let truth: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)> =
        (0..epochs).map(|t| signals.at(t)).collect();

    let mut feed = SignalFeed::new(&cfg);
    for (t, (ci, wi, tou)) in truth.iter().enumerate() {
        feed.observe(t, ci, wi, tou);
        // no-fault parity: both policies must hand schedulers the truth,
        // bit-for-bit, at every site and epoch
        for policy in [SignalPolicy::Trusting, SignalPolicy::Robust] {
            let (bci, bwi, btou) = feed.view(policy);
            for l in 0..feed.sites() {
                for (b, t_) in [
                    (bci[l], ci[l]),
                    (bwi[l], wi[l]),
                    (btou[l], tou[l]),
                ] {
                    assert_eq!(
                        b.to_bits(),
                        t_.to_bits(),
                        "epoch {t} site {l}: healthy belief diverges"
                    );
                }
            }
        }
        assert_eq!(feed.health_counts(), (feed.sites(), 0, 0));
    }

    let reps = 50;
    let t = Instant::now();
    for _ in 0..reps {
        for (e, (ci, wi, tou)) in truth.iter().enumerate() {
            feed.observe(e, ci, wi, tou);
            core::hint::black_box(feed.view(SignalPolicy::Robust));
        }
    }
    let resolve_s = t.elapsed().as_secs_f64() / (reps * epochs) as f64;
    println!(
        "| signals: believed-panel resolve | {:.2} us/epoch | ({} sites, {} epochs x {} reps, zero faults, bit-parity asserted) |",
        resolve_s * 1e6,
        feed.sites(),
        epochs,
        reps,
    );
}

#[test]
fn row_sharded_vs_global_memo_under_contention() {
    let (cfg, ev) = make_eval();
    let k_n = cfg.num_classes();
    let mut rng = Rng::new(47);
    // enough concurrent eval streams that par_map actually fans out over
    // the pool (its serial fallback engages below 2 * MIN_CHUNK items),
    // each stream with its own plan working set
    let streams: Vec<Vec<Plan>> = (0..64)
        .map(|_| {
            (0..16)
                .map(|_| Plan::random(k_n, ev.dcs(), 0.5, &mut rng))
                .collect()
        })
        .collect();

    let run = |shards: usize| -> (f64, Vec<Vec<[f64; N_OBJ]>>) {
        let memo = MemoizedEvaluator::with_shards(&ev, shards);
        // warm: all plans cached, so the timed loop measures pure
        // lock+lookup contention across pool workers
        for s in &streams {
            memo.eval_batch(s);
        }
        let t = Instant::now();
        let mut out = Vec::new();
        for _ in 0..20 {
            out = threadpool::par_map(&streams, |s| memo.eval_batch(s));
        }
        (t.elapsed().as_secs_f64(), out)
    };

    let (global_s, global_out) = run(1);
    let (sharded_s, sharded_out) = run(16);
    assert_eq!(global_out, sharded_out, "shard count must not change bits");
    println!(
        "| memo cache: 16 shards vs global lock | {:.2}x | ({:.1} us vs {:.1} us per warm sweep) |",
        global_s / sharded_s.max(1e-12),
        sharded_s / 20.0 * 1e6,
        global_s / 20.0 * 1e6,
    );
}
