//! Zero-allocation pins for the evaluation hot path. This binary
//! registers `util::benchkit::CountingAlloc` as its global allocator (the
//! counter is thread-local, so the libtest harness running other `#[test]`
//! threads concurrently cannot pollute a measurement) and asserts that:
//!
//!   * `AnalyticEvaluator::evaluate` — the full O(K*L) scoring of one
//!     plan — performs zero heap operations on fleets that fit the inline
//!     `DcVec` tile (<= `DC_SLOTS` sites);
//!   * the delta core (`PlanAgg` clone + `apply_row_delta` + `finish` /
//!     `evaluate_delta`) performs zero heap operations on inline-tile
//!     fleets;
//!   * the per-step candidate build (`PlanBatch::push_neighbors_of` into
//!     a reserved arena) performs zero heap operations at any fleet size;
//!   * past the tile (L = 48), the search-loop delta rescore (scratch
//!     `copy_from` + row delta + `finish`) is heap-silent once the spill
//!     capacity is warm;
//!   * at the edge-fleet scale (L = 256), one warm region subsearch step
//!     — arena neighbour generation plus share-scaled delta rescoring on
//!     the region-restricted evaluator — is heap-silent per candidate;
//!   * the degraded-signal feed's per-epoch believed-panel resolve
//!     (`SignalFeed::observe` + `view` + `health_counts`) performs zero
//!     heap operations once the median scratch is warm.
//!
//! These are the invariants the SoA-arena + delta-scoring + tiled-DC
//! redesigns exist to provide; a regression here silently reintroduces
//! per-candidate allocation churn long before it is visible in a
//! benchmark.

use slit::cluster::build_panels;
use slit::config::SystemConfig;
use slit::eval::{AnalyticEvaluator, EvalConsts};
use slit::plan::{Plan, PlanBatch};
use slit::power::GridSignals;
use slit::trace::Trace;
use slit::util::benchkit::{count_allocs, CountingAlloc};
use slit::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn make_eval() -> (SystemConfig, AnalyticEvaluator) {
    let cfg = SystemConfig::paper_default();
    let signals = GridSignals::generate(&cfg, 8, 3);
    let trace = Trace::generate(&cfg, 8, 3);
    let (cp, dp) = build_panels(&cfg, &signals, 4, &trace.epochs[4], 0.05);
    let consts = EvalConsts::from_physics(&cfg.physics);
    (cfg, AnalyticEvaluator::new(cp, dp, consts))
}

#[test]
fn evaluate_performs_zero_heap_operations() {
    let (cfg, ev) = make_eval();
    let mut rng = Rng::new(1);
    let plan = Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng);
    // warm up (touches TLS, lazy statics, code paths)
    core::hint::black_box(ev.evaluate(&plan));
    let (ops, _) = count_allocs(|| {
        for _ in 0..64 {
            core::hint::black_box(ev.evaluate(&plan));
        }
    });
    assert_eq!(ops, 0, "evaluate() must not touch the heap");
}

#[test]
fn delta_scoring_performs_zero_heap_operations() {
    let (cfg, ev) = make_eval();
    let mut rng = Rng::new(2);
    let base = Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng);
    let cand = base.shifted_toward(2, 1, 0.5);
    let agg = ev.aggregate(base.as_slice());
    core::hint::black_box(ev.evaluate_delta(&agg, 2, base.row(2), cand.row(2)));
    let (ops, _) = count_allocs(|| {
        for _ in 0..64 {
            // the whole delta chain: clone the inline-tile aggregates
            // (an empty spill Vec clones without allocating), shift one
            // row's contribution, run the O(L) physics pass
            let mut moved = agg.clone();
            ev.apply_row_delta(&mut moved, 2, base.row(2), cand.row(2));
            core::hint::black_box(ev.finish(&moved));
            core::hint::black_box(ev.evaluate_delta(
                &agg,
                2,
                base.row(2),
                cand.row(2),
            ));
        }
    });
    assert_eq!(ops, 0, "delta scoring must not touch the heap");
}

#[test]
fn spilled_delta_scoring_is_alloc_free_once_warm() {
    // L = 48 (three tiles' worth of sites): the aggregates spill to the
    // heap, but the SLIT search-loop shape — scratch copy_from + masked
    // row delta + finish — must stay heap-silent after the scratch's
    // spill capacity is established
    let mut cfg = SystemConfig::paper_default();
    cfg.datacenters = slit::scenario::global_fleet_datacenters(6);
    cfg.validate().expect("48-site fleet validates");
    let dcs = cfg.datacenters.len();
    assert_eq!(dcs, 48);
    let signals = GridSignals::generate(&cfg, 8, 3);
    let trace = Trace::generate(&cfg, 8, 3);
    let (cp, dp) = build_panels(&cfg, &signals, 4, &trace.epochs[4], 0.05);
    let ev =
        AnalyticEvaluator::new(cp, dp, EvalConsts::from_physics(&cfg.physics));

    let mut rng = Rng::new(7);
    let base = Plan::random(cfg.num_classes(), dcs, 0.5, &mut rng);
    let cand = base.shifted_toward(3, 40, 0.5);
    let agg = ev.aggregate(base.as_slice());
    let mut scratch = slit::eval::PlanAgg::zeros(dcs);
    scratch.copy_from(&agg); // warm: spill capacity allocated once here
    core::hint::black_box(ev.finish(&scratch));
    let (ops, _) = count_allocs(|| {
        for _ in 0..64 {
            scratch.copy_from(&agg);
            ev.apply_row_delta(&mut scratch, 3, base.row(3), cand.row(3));
            core::hint::black_box(ev.finish(&scratch));
        }
    });
    assert_eq!(
        ops, 0,
        "spilled delta rescoring must reuse the scratch allocation"
    );
}

#[test]
fn warm_region_subsearch_step_is_alloc_free_at_l256() {
    // PR 10: inside one region subsearch at the edge-fleet scale (L=256,
    // 64 sites per routing region), the per-candidate work — arena
    // neighbour generation, share-scaling rows into preallocated
    // buffers, scratch copy_from + masked row delta + finish on the
    // region-restricted evaluator — must be heap-silent once every
    // capacity (arena, spill scratch, row buffers) is warm. This is the
    // invariant that keeps the decomposed search's inner loop at
    // O(L_region) arithmetic with zero allocator traffic, exactly like
    // the global walk's pin above.
    let mut cfg = SystemConfig::paper_default();
    cfg.datacenters = slit::scenario::global_fleet_datacenters(32);
    cfg.validate().expect("256-site fleet validates");
    assert_eq!(cfg.datacenters.len(), 256);
    let signals = GridSignals::generate(&cfg, 8, 3);
    let trace = Trace::generate(&cfg, 8, 3);
    let (cp, dp) = build_panels(&cfg, &signals, 4, &trace.epochs[4], 0.05);
    let ev =
        AnalyticEvaluator::new(cp, dp, EvalConsts::from_physics(&cfg.physics));

    // one-time restriction (allocates its own panels, outside the pin)
    let tags: Vec<usize> =
        cfg.datacenters.iter().map(|d| d.region).collect();
    let parts = slit::scenario::partition_sites_by_region(&tags);
    let sub = ev.restrict_to_sites(&parts[0].1);
    let l_r = sub.dcs();
    assert_eq!(l_r, 64);
    let classes = cfg.num_classes();

    let mut rng = Rng::new(9);
    let cur = Plan::random(classes, l_r, 0.5, &mut rng);
    let w = 0.37; // the price loop's demand share scales rows at scoring
    let mut scaled = vec![0.0; classes * l_r];
    for (s, v) in scaled.iter_mut().zip(cur.as_slice()) {
        *s = w * v;
    }
    let agg = sub.aggregate(&scaled);
    let mut scratch = slit::eval::PlanAgg::zeros(l_r);
    let mut old_scaled = vec![0.0; l_r];
    let mut new_scaled = vec![0.0; l_r];
    let neighbors = 8;
    let mut arena = PlanBatch::new(classes, l_r);
    arena.reserve(neighbors);

    let step = |rng: &mut Rng,
                    arena: &mut PlanBatch,
                    scratch: &mut slit::eval::PlanAgg,
                    old_scaled: &mut [f64],
                    new_scaled: &mut [f64]| {
        arena.clear();
        arena.push_neighbors_of(cur.as_slice(), neighbors, 0.25, rng);
        for i in 0..arena.len() {
            let k = i % classes;
            let cand = &arena.candidate(i)[k * l_r..(k + 1) * l_r];
            for j in 0..l_r {
                old_scaled[j] = w * cur.row(k)[j];
                new_scaled[j] = w * cand[j];
            }
            scratch.copy_from(&agg);
            sub.apply_row_delta(scratch, k, old_scaled, new_scaled);
            core::hint::black_box(sub.finish(scratch));
        }
    };

    // warm: arena fill + spill-scratch capacity established here
    step(
        &mut rng,
        &mut arena,
        &mut scratch,
        &mut old_scaled,
        &mut new_scaled,
    );
    let (ops, _) = count_allocs(|| {
        for _ in 0..16 {
            step(
                &mut rng,
                &mut arena,
                &mut scratch,
                &mut old_scaled,
                &mut new_scaled,
            );
        }
    });
    assert_eq!(
        ops, 0,
        "warm region subsearch step must not touch the heap"
    );
}

#[test]
fn warm_signal_feed_resolve_performs_zero_heap_operations() {
    use slit::signals::{SignalFeed, SignalPolicy};

    let cfg = SystemConfig::paper_default();
    let signals = GridSignals::generate(&cfg, 8, 3);
    let (ci, wi, tou) = signals.at(4);
    let mut feed = SignalFeed::new(&cfg);
    // warm: the fleet-median scratch establishes its capacity here
    feed.observe(0, &ci, &wi, &tou);
    core::hint::black_box(feed.view(SignalPolicy::Robust));
    let (ops, _) = count_allocs(|| {
        for t in 1..65 {
            feed.observe(t, &ci, &wi, &tou);
            core::hint::black_box(feed.view(SignalPolicy::Robust));
            core::hint::black_box(feed.health_counts());
        }
    });
    assert_eq!(
        ops, 0,
        "warm believed-panel resolve must not touch the heap"
    );
}

#[test]
fn candidate_build_performs_zero_heap_operations_after_reserve() {
    let (cfg, ev) = make_eval();
    let (classes, dcs) = (cfg.num_classes(), ev.dcs());
    let mut rng = Rng::new(3);
    let cur = Plan::random(classes, dcs, 0.5, &mut rng);
    let neighbors = 8;
    let slots = 24;
    let mut arena = PlanBatch::new(classes, dcs);
    arena.reserve(slots * neighbors);
    // warm once at full size: the reserve must already be sufficient
    for _ in 0..slots {
        arena.push_neighbors_of(cur.as_slice(), neighbors, 0.25, &mut rng);
    }
    arena.clear();
    let (ops, _) = count_allocs(|| {
        // one full optimizer step's worth of candidate generation
        for _ in 0..slots {
            arena.push_neighbors_of(cur.as_slice(), neighbors, 0.25, &mut rng);
        }
    });
    assert_eq!(
        ops, 0,
        "arena candidate build must not touch the heap once reserved"
    );
    assert_eq!(arena.len(), slots * neighbors);
}
