//! Integration: the AOT HLO artifacts executed via PJRT must agree with
//! the native rust evaluator (which itself is pytest-verified against the
//! Pallas kernel and the pure-jnp oracle). This closes the three-layer
//! parity loop: Pallas kernel == jnp oracle == rust eval == PJRT artifact.

use std::sync::Arc;

use slit::cluster::build_panels;
use slit::config::{SystemConfig, N_OBJ};
use slit::eval::{AnalyticEvaluator, BatchEvaluator, EvalConsts};
use slit::opt::{SlitOptimizer, SlitVariant};
use slit::plan::Plan;
use slit::power::GridSignals;
use slit::runtime::{
    artifacts_dir, artifacts_present, pjrt_enabled, Engine, HloPlanEvaluator,
    HloPredictor,
};
use slit::trace::Trace;
use slit::util::rng::Rng;

fn engine() -> Option<Arc<Engine>> {
    if !pjrt_enabled() {
        eprintln!("SKIP: built without the `pjrt` feature (stub engine)");
        return None;
    }
    if !artifacts_present() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(&artifacts_dir()).expect("engine load"))
}

fn make_eval(seed: u64) -> (SystemConfig, AnalyticEvaluator) {
    let cfg = SystemConfig::paper_default();
    let signals = GridSignals::generate(&cfg, 8, seed);
    let trace = Trace::generate(&cfg, 8, seed);
    let (cp, dp) = build_panels(&cfg, &signals, 3, &trace.epochs[3], 0.05);
    let ev =
        AnalyticEvaluator::new(cp, dp, EvalConsts::from_physics(&cfg.physics));
    (cfg, ev)
}

#[test]
fn hlo_plan_eval_matches_rust_evaluator() {
    let Some(engine) = engine() else { return };
    let (cfg, ev) = make_eval(42);
    let hlo = HloPlanEvaluator::from_analytic(engine, &ev);

    let mut rng = Rng::new(7);
    let mut plans: Vec<Plan> = vec![
        Plan::uniform(cfg.num_classes(), ev.dcs()),
        Plan::one_dc(cfg.num_classes(), ev.dcs(), 5),
    ];
    for _ in 0..130 {
        // > one tile: exercises padding + multi-dispatch
        plans.push(Plan::random(cfg.num_classes(), ev.dcs(), 0.4, &mut rng));
    }

    let native = ev.eval_batch(&plans);
    let aot = hlo.eval_batch(&plans);
    assert_eq!(native.len(), aot.len());
    for (i, (n, a)) in native.iter().zip(&aot).enumerate() {
        for j in 0..N_OBJ {
            let scale = n[j].abs().max(1e-9);
            let rel = (n[j] - a[j]).abs() / scale;
            assert!(
                rel < 2e-4,
                "plan {i} obj {j}: native {} vs aot {} (rel {rel})",
                n[j],
                a[j]
            );
        }
    }
}

#[test]
fn optimizer_runs_against_hlo_backend() {
    let Some(engine) = engine() else { return };
    let (cfg, ev) = make_eval(43);
    let hlo = HloPlanEvaluator::from_analytic(engine.clone(), &ev);

    let mut opt_cfg = cfg.opt.clone();
    opt_cfg.population = 12;
    opt_cfg.generations = 3;
    opt_cfg.search_steps = 2;
    opt_cfg.neighbors = 4;
    let mut o = SlitOptimizer::new(opt_cfg, cfg.num_classes(), ev.dcs(), 1);
    let out = o.optimize(&hlo);
    assert!(!out.archive.is_empty());
    assert!(out.archive.is_consistent());
    assert!(engine.dispatches() > 0, "no PJRT dispatches recorded");

    // the HLO-backed archive should contain solutions whose native scores
    // confirm specialisation (carbon best <= balance's carbon)
    let show = out.archive.showcase();
    assert_eq!(show.len(), 5);
    let carbon = &show[1].1;
    let native = ev.evaluate(&carbon.plan);
    let rel = (native[1] - carbon.obj[1]).abs() / native[1].max(1e-9);
    assert!(rel < 2e-4, "archive objective drifted from native: {rel}");
    let _ = SlitVariant::all();
}

#[test]
fn hlo_predictor_tracks_series() {
    let Some(engine) = engine() else { return };
    let p = HloPredictor::new(engine);
    let series: Vec<f64> = (0..250)
        .map(|t| {
            1000.0
                + 350.0
                    * (2.0 * std::f64::consts::PI * t as f64 / 96.0).sin()
        })
        .collect();
    let pred = p.predict_series(&series, 96).unwrap();
    let actual = 1000.0
        + 350.0 * (2.0 * std::f64::consts::PI * 250.0 / 96.0).sin();
    let rel = (pred - actual).abs() / actual.abs();
    assert!(rel < 0.15, "pred {pred} vs actual {actual}");
}

#[test]
fn engine_survives_many_sequential_dispatches() {
    let Some(engine) = engine() else { return };
    let (cfg, ev) = make_eval(44);
    let hlo = HloPlanEvaluator::from_analytic(engine, &ev);
    let mut rng = Rng::new(9);
    for _ in 0..5 {
        let plans: Vec<Plan> = (0..32)
            .map(|_| Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng))
            .collect();
        let objs = hlo.eval_batch(&plans);
        assert_eq!(objs.len(), 32);
        assert!(objs.iter().all(|o| o.iter().all(|x| x.is_finite())));
    }
}
