//! SimSession / legacy-simulate equivalence suite.
//!
//! The tentpole redesign keeps `sim::simulate()` as a thin wrapper over
//! `session::SimSession`; these tests pin the contract:
//!
//! * every framework in the registry produces bit-identical `SimResult`s
//!   through either entry point on the baseline scenario,
//! * the wrapper is bit-identical to direct session use on all the
//!   pre-existing (event-free) scenarios,
//! * total request mass (served + dropped = `ledger.requests`) is
//!   invariant under mid-run capacity changes.

use slit::cluster::ClusterAction;
use slit::config::SystemConfig;
use slit::registry;
use slit::scenario::Scenario;
use slit::session::{ScenarioEvent, SimSession};
use slit::sim::{simulate, SimResult};

/// Small, fast, and immune to wall-clock truncation: the optimizer budget
/// is effectively infinite so timing noise cannot leak into the numbers.
fn quick_config() -> SystemConfig {
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 3;
    cfg.opt.generations = 2;
    cfg.opt.population = 8;
    cfg.opt.budget_s = 1e9;
    cfg
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, label: &str) {
    assert_eq!(a.name, b.name, "{label}: name");
    assert_eq!(a.total.requests, b.total.requests, "{label}: requests");
    assert_eq!(a.total.dropped, b.total.dropped, "{label}: dropped");
    assert_eq!(a.total.ttft_sum_s, b.total.ttft_sum_s, "{label}: ttft");
    assert_eq!(a.total.carbon_kg, b.total.carbon_kg, "{label}: carbon");
    assert_eq!(a.total.water_l, b.total.water_l, "{label}: water");
    assert_eq!(a.total.cost_usd, b.total.cost_usd, "{label}: cost");
    assert_eq!(a.total.e_it_j, b.total.e_it_j, "{label}: e_it");
    assert_eq!(a.total.e_tot_j, b.total.e_tot_j, "{label}: e_tot");
    assert_eq!(a.per_epoch.len(), b.per_epoch.len(), "{label}: epochs");
    for (x, y) in a.per_epoch.iter().zip(&b.per_epoch) {
        assert_eq!(x.plan, y.plan, "{label}: epoch {} plan", x.epoch);
        assert_eq!(
            x.site_nodes, y.site_nodes,
            "{label}: epoch {} capacity",
            x.epoch
        );
        assert_eq!(
            x.ledger.ttft_sum_s, y.ledger.ttft_sum_s,
            "{label}: epoch {} ledger",
            x.epoch
        );
    }
}

#[test]
fn every_registered_framework_round_trips_through_the_session() {
    let cfg = quick_config();
    let world = Scenario::Baseline.build(&cfg, cfg.epochs, 9);
    for spec in registry::all() {
        let mut legacy_sched = (spec.build)(&world.cfg);
        let legacy = simulate(
            &world.cfg,
            &world.trace,
            &world.signals,
            legacy_sched.as_mut(),
            9,
        );
        let mut session_sched = (spec.build)(&world.cfg);
        let streamed = SimSession::new(
            &world.cfg,
            &world.trace,
            &world.signals,
            session_sched.as_mut(),
            9,
        )
        .run();
        assert_bit_identical(&legacy, &streamed, spec.name);
    }
}

#[test]
fn wrapper_is_bit_identical_on_every_preexisting_scenario() {
    // the five pre-session regimes plus the baseline schedule no events,
    // so the wrapper and a bare session must agree exactly
    let cfg = quick_config();
    for sc in [
        Scenario::Baseline,
        Scenario::Diurnal,
        Scenario::BurstyHeavyTail,
        Scenario::RegionalOutage,
        Scenario::CarbonSpike,
        Scenario::WaterStressedSummer,
    ] {
        let world = sc.build(&cfg, cfg.epochs, 17);
        assert!(world.events.is_empty(), "{} schedules events", sc.name());
        for name in ["splitwise", "slit-balance"] {
            let mut a = registry::build(name, &world.cfg, None).unwrap();
            let legacy = simulate(
                &world.cfg,
                &world.trace,
                &world.signals,
                a.as_mut(),
                17,
            );
            let mut b = registry::build(name, &world.cfg, None).unwrap();
            let streamed = world.run(b.as_mut(), 17);
            assert_bit_identical(
                &legacy,
                &streamed,
                &format!("{}/{}", sc.name(), name),
            );
        }
    }
}

#[test]
fn request_mass_is_conserved_across_mid_run_capacity_changes() {
    // every sampled request is accounted exactly once (served or dropped:
    // ledger.requests counts both), so the total request mass must not
    // depend on capacity events firing mid-run
    let cfg = quick_config();
    let world = Scenario::Baseline.build(&cfg, cfg.epochs, 23);
    let expected: f64 = world.trace.epochs[..world.cfg.epochs]
        .iter()
        .map(|e| e.classes.iter().map(|c| c.n_req.round()).sum::<f64>())
        .sum();

    let mut plain_sched = registry::build("splitwise", &world.cfg, None).unwrap();
    let plain = world.run(plain_sched.as_mut(), 23);

    let mut outage_sched = registry::build("splitwise", &world.cfg, None).unwrap();
    let outage = SimSession::new(
        &world.cfg,
        &world.trace,
        &world.signals,
        outage_sched.as_mut(),
        23,
    )
    .with_events(vec![
        ScenarioEvent::at(
            1,
            ClusterAction::ScaleRegion {
                region: 2,
                frac: 0.0,
            },
        ),
        ScenarioEvent::at(2, ClusterAction::RestoreRegion { region: 2 }),
    ])
    .run();

    assert_eq!(plain.total.requests, expected);
    assert_eq!(outage.total.requests, expected);
    // served + dropped partitions the mass in both runs
    assert!(plain.total.dropped <= plain.total.requests);
    assert!(outage.total.dropped <= outage.total.requests);
    // the outage really happened: epoch 1 ran with less capacity
    let nodes = |r: &SimResult, e: usize| -> usize {
        r.per_epoch[e].site_nodes.iter().sum()
    };
    assert!(nodes(&outage, 1) < nodes(&outage, 0));
    assert_eq!(nodes(&outage, 2), nodes(&outage, 0));
    assert_eq!(nodes(&plain, 1), nodes(&plain, 0));
}
