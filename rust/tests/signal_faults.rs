//! Degraded-signal resilience properties (PR 9), at the feed and session
//! level — `signals.rs` unit tests cover single transitions; these pin the
//! system-wide guarantees the scenario matrix leans on:
//!
//! * under *arbitrary* fault schedules (random mixes of freeze, dropout,
//!   spike — including NaN and sign-flip corruption — lag, and region
//!   blackouts) every robust believed value stays finite and inside the
//!   per-axis plausibility band, at every epoch, at every site;
//! * quarantine round-trips: a spiked site is quarantined while corrupt,
//!   then [`RECOVERY_STREAK`] plausible samples restore it to Fresh with
//!   the believed value bit-identical to the live feed again;
//! * with zero faults, `slit-robust` is bit-identical to its inner
//!   framework (`slit-carbon`) — same plans, same ledgers — at every
//!   thread count (1, 8, hardware default), so the resilience layer is
//!   provably free when the feeds are healthy;
//! * with zero faults, every registered framework's per-epoch ledger
//!   reports zero believed-vs-truth divergence, zero stale/quarantined
//!   site-epochs, and a full fresh count.

use slit::cluster::ClusterAction;
use slit::config::SystemConfig;
use slit::power::GridSignals;
use slit::registry;
use slit::session::{ScenarioEvent, SimSession};
use slit::signals::{
    FallbackSource, FeedState, SignalFault, SignalFeed, SignalPolicy, AXES,
    AXIS_CI, PLAUSIBLE_MAX, PLAUSIBLE_MIN, RECOVERY_STREAK,
};
use slit::sim::{simulate, SimResult};
use slit::trace::Trace;
use slit::util::propkit;
use slit::util::threadpool;

/// One randomly drawn fault: (start epoch, kind tag, site, span, spike
/// factor, lag). Sites may be out of range on purpose — the feed must
/// ignore those, not panic.
type DrawnFault = (usize, u8, usize, usize, f64, usize);

fn build_fault(kind: u8, site: usize, span: usize, factor: f64, lag: usize) -> SignalFault {
    match kind {
        0 => SignalFault::Freeze { site, epochs: span },
        1 => SignalFault::Dropout { site, epochs: span },
        2 => SignalFault::Spike {
            site,
            axis: site % AXES,
            factor,
            epochs: span,
        },
        3 => SignalFault::Lag {
            site,
            lag,
            epochs: span,
        },
        _ => SignalFault::RegionBlackout {
            region: site % 6,
            epochs: span,
        },
    }
}

#[test]
fn robust_believed_values_stay_finite_and_bounded_under_arbitrary_faults() {
    propkit::check(
        "robust_belief_bounded",
        0x5349_4746,
        24,
        |rng| {
            let epochs = 6 + rng.below(18);
            let n_faults = 1 + rng.below(12);
            let faults: Vec<DrawnFault> = (0..n_faults)
                .map(|_| {
                    (
                        rng.below(epochs),
                        rng.below(5) as u8,
                        rng.below(14), // 12 real sites + 2 out-of-range
                        1 + rng.below(epochs),
                        // corruption magnitudes the plausibility gates
                        // must survive: zero, negative, NaN, huge, tiny
                        match rng.below(6) {
                            0 => 0.0,
                            1 => -4.0,
                            2 => f64::NAN,
                            3 => 1e9,
                            4 => 1e-8,
                            _ => 25.0,
                        },
                        1 + rng.below(4),
                    )
                })
                .collect();
            (epochs, faults, rng.next_u64())
        },
        |&(epochs, ref faults, seed)| {
            let mut cfg = SystemConfig::small_test();
            cfg.epochs = epochs;
            let signals = GridSignals::generate(&cfg, epochs, seed);
            let mut feed = SignalFeed::new(&cfg);
            for &(at, kind, site, span, factor, lag) in faults {
                feed.inject(at, &build_fault(kind, site, span, factor, lag));
            }
            for t in 0..epochs {
                let (ci, wi, tou) = signals.at(t);
                feed.observe(t, &ci, &wi, &tou);
                let (bci, bwi, btou) = feed.view(SignalPolicy::Robust);
                for (a, axis) in [bci, bwi, btou].iter().enumerate() {
                    for (l, &v) in axis.iter().enumerate() {
                        if !v.is_finite()
                            || v < PLAUSIBLE_MIN[a]
                            || v > PLAUSIBLE_MAX[a]
                        {
                            return Err(format!(
                                "epoch {t} site {l} axis {a}: \
                                 robust believed {v} escaped the band"
                            ));
                        }
                    }
                }
                // health states always partition the fleet
                let (fresh, stale, quar) = feed.health_counts();
                propkit::mass_balance(
                    feed.sites() as f64,
                    &[fresh as f64, stale as f64, quar as f64],
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn quarantine_round_trip_restores_fresh_and_bitwise_live_belief() {
    const SITE: usize = 4; // melbourne, ci_base 0.60: x400 is wildly out of band
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 16;
    let signals = GridSignals::generate(&cfg, 16, 21);
    let mut feed = SignalFeed::new(&cfg);

    let drive = |feed: &mut SignalFeed, t: usize| {
        let (ci, wi, tou) = signals.at(t);
        feed.observe(t, &ci, &wi, &tou);
    };

    drive(&mut feed, 0);
    assert_eq!(feed.site_state(SITE), FeedState::Fresh);
    feed.inject(
        1,
        &SignalFault::Spike {
            site: SITE,
            axis: AXIS_CI,
            factor: 400.0,
            epochs: 3,
        },
    );

    // corrupt window [1, 4): the gate quarantines the site throughout,
    // and the ladder keeps its believed value inside the band
    for t in 1..4 {
        drive(&mut feed, t);
        assert_eq!(feed.site_state(SITE), FeedState::Quarantined, "epoch {t}");
        let (bci, _, _) = feed.view(SignalPolicy::Robust);
        assert!(
            bci[SITE].is_finite() && bci[SITE] <= PLAUSIBLE_MAX[AXIS_CI],
            "quarantined believed CI escaped the band: {}",
            bci[SITE]
        );
    }

    // recovery: RECOVERY_STREAK plausible samples are probation, the
    // streak completing restores Fresh
    let mut t = 4;
    for _ in 1..RECOVERY_STREAK {
        drive(&mut feed, t);
        assert_eq!(feed.site_state(SITE), FeedState::Quarantined, "epoch {t}");
        t += 1;
    }
    drive(&mut feed, t);
    assert_eq!(feed.site_state(SITE), FeedState::Fresh);
    assert_eq!(feed.site_age(SITE), 0);
    assert_eq!(feed.site_source(SITE), FallbackSource::Live);

    // once Fresh, robust belief collapses back to the live feed bit-for-bit
    let (tci, twi, ttou) = signals.at(t);
    let (bci, bwi, btou) = feed.view(SignalPolicy::Robust);
    for (believed, truth) in
        [(bci, &tci), (bwi, &twi), (btou, &ttou)]
    {
        assert_eq!(
            believed[SITE].to_bits(),
            truth[SITE].to_bits(),
            "recovered belief diverges from truth"
        );
    }
}

#[test]
fn no_fault_slit_robust_is_bit_identical_to_slit_carbon_at_any_thread_count() {
    // wall-clock must never truncate the search, or timing differences
    // between thread counts would leak into the comparison
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 3;
    cfg.opt.budget_s = 1e9;
    cfg.opt.generations = 3;
    let trace = Trace::generate(&cfg, cfg.epochs, 42);
    let signals = GridSignals::generate(&cfg, cfg.epochs, 42);

    let run = |name: &str| -> SimResult {
        let mut sched = registry::build(name, &cfg, None).expect("framework");
        simulate(&cfg, &trace, &signals, sched.as_mut(), 42)
    };

    let mut totals: Vec<(u64, u64, u64)> = Vec::new();
    for threads in [1usize, 8, 0] {
        threadpool::set_thread_override(threads);
        let inner = run("slit-carbon");
        let robust = run("slit-robust");
        assert_eq!(robust.name, "slit-robust");
        assert_eq!(robust.per_epoch.len(), inner.per_epoch.len());
        for (a, b) in inner.per_epoch.iter().zip(&robust.per_epoch) {
            assert_eq!(
                a.plan, b.plan,
                "plans diverge at epoch {} ({threads} threads)",
                a.epoch
            );
            for (x, y, what) in [
                (a.ledger.requests, b.ledger.requests, "requests"),
                (a.ledger.carbon_kg, b.ledger.carbon_kg, "carbon_kg"),
                (a.ledger.water_l, b.ledger.water_l, "water_l"),
                (a.ledger.cost_usd, b.ledger.cost_usd, "cost_usd"),
            ] {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what} diverges at epoch {} ({threads} threads)",
                    a.epoch
                );
            }
        }
        totals.push((
            robust.total.carbon_kg.to_bits(),
            robust.total.water_l.to_bits(),
            robust.total.cost_usd.to_bits(),
        ));
    }
    threadpool::set_thread_override(0);
    for w in totals.windows(2) {
        assert_eq!(w[0], w[1], "thread count changed slit-robust totals");
    }
}

#[test]
fn every_framework_reports_zero_divergence_without_faults() {
    assert!(
        registry::names().contains(&"slit-robust"),
        "slit-robust missing from the registry"
    );
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 3;
    cfg.opt.budget_s = 60.0;
    cfg.opt.generations = 2;
    let trace = Trace::generate(&cfg, cfg.epochs, 7);
    let signals = GridSignals::generate(&cfg, cfg.epochs, 7);
    let full_fleet = cfg.datacenters.len() as f64;

    for spec in registry::all() {
        let mut sched =
            registry::build(spec.name, &cfg, None).expect("framework");
        let res = simulate(&cfg, &trace, &signals, sched.as_mut(), 7);
        for r in &res.per_epoch {
            assert_eq!(
                r.ledger.signal_div,
                [0.0; 3],
                "{} epoch {}: believed diverged from truth with no faults",
                spec.name,
                r.epoch
            );
            assert_eq!(r.ledger.signal_stale, 0.0, "{}", spec.name);
            assert_eq!(r.ledger.signal_quarantined, 0.0, "{}", spec.name);
            assert_eq!(
                r.ledger.signal_fresh, full_fleet,
                "{} epoch {}: fresh count short of the fleet",
                spec.name, r.epoch
            );
        }
    }
}

#[test]
fn session_routes_signal_events_into_feed_and_ledger() {
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 6;
    cfg.opt.budget_s = 60.0;
    cfg.opt.generations = 2;
    let trace = Trace::generate(&cfg, cfg.epochs, 11);
    let signals = GridSignals::generate(&cfg, cfg.epochs, 11);
    let mut sched = registry::build("slit-robust", &cfg, None).unwrap();
    let events = vec![ScenarioEvent::at(
        2,
        ClusterAction::Signal(SignalFault::RegionBlackout {
            region: 3,
            epochs: 3,
        }),
    )];
    let res = SimSession::new(&cfg, &trace, &signals, sched.as_mut(), 11)
        .with_events(events)
        .run();

    // europe has 3 sites: the blackout window [2, 5) must surface as
    // stale site-epochs and nonzero believed-vs-truth divergence
    let darkened: usize = res
        .per_epoch
        .iter()
        .filter(|r| r.ledger.signal_stale >= 3.0)
        .count();
    assert_eq!(darkened, 3, "blackout window never registered in the ledger");
    let div: f64 =
        res.per_epoch.iter().map(|r| r.ledger.signal_div[0]).sum();
    assert!(
        div > 0.0,
        "believed CI never diverged from truth under blackout"
    );
    // epochs before the blackout are clean
    assert_eq!(res.per_epoch[0].ledger.signal_stale, 0.0);
    assert_eq!(res.per_epoch[0].ledger.signal_div, [0.0; 3]);
    // a telemetry fault degrades information, never the served mass
    assert!(res.total.requests > 0.0);
    assert_eq!(res.total.dropped, 0.0);
}
