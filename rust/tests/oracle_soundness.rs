//! PR 8 satellite: oracle soundness across the whole framework registry.
//!
//! The certified lower bound (`opt::oracle`) claims to sit at or below
//! the scalarized score of *every* valid plan. The strongest cheap
//! falsifier we have is the registry itself: every shipped framework —
//! baselines and all SLIT variants, warm and scale-to-zero power
//! policies, shifting and feedback layers — produces real plans under
//! real predicted panels every epoch. On randomized small worlds (seed,
//! load level, thread count all varied), the per-epoch `GapReport`s the
//! session records must show `oracle_score <= achieved` for each of the
//! four objectives, with no exceptions.

use slit::config::{SystemConfig, OBJ_NAMES};
use slit::power::GridSignals;
use slit::registry;
use slit::sim::simulate;
use slit::trace::Trace;
use slit::util::propkit;
use slit::util::threadpool;

#[test]
fn oracle_is_below_every_frameworks_achieved_score() {
    propkit::check(
        "oracle-soundness-registry",
        0x0AC1E5,
        4,
        |r| {
            (
                r.int(1, 1_000_000) as u64,
                // 0.4x..2.5x the small_test load: spans comfortably
                // unsaturated through queue-pressured regimes
                r.range(0.4, 2.5),
                // 0 = the harness default worker count
                [0usize, 1, 2][r.below(3)],
            )
        },
        |&(seed, load_mult, threads)| {
            threadpool::set_thread_override(threads);
            let mut cfg = SystemConfig::small_test();
            cfg.epochs = 2;
            cfg.opt.generations = 2;
            cfg.opt.budget_s = 30.0;
            cfg.workload.base_requests_per_epoch *= load_mult;
            let trace = Trace::generate(&cfg, cfg.epochs, seed);
            let signals = GridSignals::generate(&cfg, cfg.epochs, seed);
            let result = (|| {
                for name in registry::names() {
                    let mut sched = registry::build(name, &cfg, None)
                        .map_err(|e| e.to_string())?;
                    let res =
                        simulate(&cfg, &trace, &signals, sched.as_mut(), seed);
                    for rec in &res.per_epoch {
                        for (obj, g) in rec.gaps.iter().enumerate() {
                            if !g.oracle_score.is_finite()
                                || !g.achieved.is_finite()
                            {
                                return Err(format!(
                                    "{name} epoch {} {}: non-finite {g:?}",
                                    rec.epoch, OBJ_NAMES[obj]
                                ));
                            }
                            if g.oracle_score > g.achieved {
                                return Err(format!(
                                    "{name} epoch {} {}: oracle {} > \
                                     achieved {} (slack {})",
                                    rec.epoch,
                                    OBJ_NAMES[obj],
                                    g.oracle_score,
                                    g.achieved,
                                    g.quantization_slack
                                ));
                            }
                            if g.gap_frac < 0.0 || g.quantization_slack < 0.0 {
                                return Err(format!(
                                    "{name} epoch {} {}: negative gap \
                                     fields {g:?}",
                                    rec.epoch, OBJ_NAMES[obj]
                                ));
                            }
                        }
                    }
                }
                Ok(())
            })();
            threadpool::set_thread_override(0);
            result
        },
    );
    threadpool::set_thread_override(0);
}
