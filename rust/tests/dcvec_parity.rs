//! Golden parity for the tiled-DC (`DcVec`) refactor (DESIGN.md §14).
//!
//! The refactor swapped `eval::PlanAgg`'s fixed `[f64; DC_SLOTS]` stack
//! buffers for `DcVec` tiles so fleets can grow past 16 sites. Nothing
//! about the arithmetic was allowed to change:
//!
//!   * for every existing <= 16-DC scenario, the contraction aggregates
//!     (and therefore the objectives — `finish` is a pure function of
//!     them) are **bit-identical** to an inline stack-array oracle that
//!     reproduces the pre-refactor code path, over seeded random plans;
//!   * every framework in the registry still simulates bit-deterministic
//!     through the DcVec evaluator path;
//!   * past the tile, a propkit property pins delta-vs-full rescoring
//!     parity <= 1e-9 relative at L = 48 over random move sequences.

use slit::cluster::build_panels;
use slit::config::{SystemConfig, DC_SLOTS, N_OBJ};
use slit::eval::{AnalyticEvaluator, EvalConsts, PlanAgg};
use slit::plan::Plan;
use slit::power::GridSignals;
use slit::registry;
use slit::scenario::{global_fleet_datacenters, Scenario, ScenarioWorld};
use slit::trace::Trace;
use slit::util::propkit;
use slit::util::rng::Rng;

/// The pre-refactor aggregation path: contraction into fixed
/// `[f64; DC_SLOTS]` stack arrays, weights rebuilt with the exact
/// expression order `AnalyticEvaluator::new` uses — so bitwise equality
/// is the expectation, not a tolerance.
fn inline_array_oracle(
    ev: &AnalyticEvaluator,
    a: &[f64],
) -> ([f64; DC_SLOTS], [f64; DC_SLOTS], f64) {
    let k_n = ev.classes();
    let l_n = ev.dcs();
    assert!(l_n <= DC_SLOTS, "oracle is the inline path only");
    let c = &ev.consts;
    let mut node_s = [0.0f64; DC_SLOTS];
    let mut reqs_l = [0.0f64; DC_SLOTS];
    let mut t_base = 0.0f64;
    for k in 0..k_n {
        let n_req = ev.cp.n_req[k];
        let w = ev.cp.n_req[k] * ev.cp.tok_out[k];
        for l in 0..l_n {
            let i = k * l_n + l;
            let wns = w / ev.cp.thr[i];
            let base = c.cold_frac * ev.cp.mem[k] / ev.dp.bw[l]
                + 2.0 * ev.cp.hops[i] * c.k_media
                + ev.cp.proc[i];
            let wtt = ev.cp.n_req[k] * base;
            node_s[l] += a[i] * wns;
            reqs_l[l] += a[i] * n_req;
            t_base += a[i] * wtt;
        }
    }
    (node_s, reqs_l, t_base)
}

fn world_evaluator(world: &ScenarioWorld, epoch: usize) -> AnalyticEvaluator {
    let (cp, dp) = build_panels(
        &world.cfg,
        &world.signals,
        epoch,
        &world.trace.epochs[epoch],
        world.cfg.physics.pr_off,
    );
    AnalyticEvaluator::new(cp, dp, EvalConsts::from_physics(&world.cfg.physics))
}

#[test]
fn every_small_fleet_scenario_matches_the_inline_array_oracle_bitwise() {
    let base = SystemConfig::paper_default();
    for sc in Scenario::all() {
        let world = sc.build(&base, 6, 11);
        if world.cfg.datacenters.len() > DC_SLOTS {
            continue; // global-fleet: spilled path, covered below
        }
        let ev = world_evaluator(&world, 3);
        let l_n = ev.dcs();
        let mut rng = Rng::new(0xD0C5);
        for trial in 0..12 {
            let plan =
                Plan::random(world.cfg.num_classes(), l_n, 0.5, &mut rng);
            let agg = ev.aggregate(plan.as_slice());
            let (node_s, reqs_l, t_base) =
                inline_array_oracle(&ev, plan.as_slice());
            assert_eq!(
                agg.node_s.as_slice(),
                &node_s[..l_n],
                "{} trial {trial}: node_s bits moved",
                sc.name()
            );
            assert_eq!(
                agg.reqs_l.as_slice(),
                &reqs_l[..l_n],
                "{} trial {trial}: reqs_l bits moved",
                sc.name()
            );
            assert_eq!(
                agg.t_base.to_bits(),
                t_base.to_bits(),
                "{} trial {trial}: t_base bits moved",
                sc.name()
            );
            // finish is a pure function of the aggregates, so objective
            // bits follow; pin the composition anyway
            assert_eq!(ev.finish(&agg), ev.evaluate(&plan), "{}", sc.name());
        }
    }
}

#[test]
fn every_registry_framework_is_bit_deterministic_through_the_dcvec_path() {
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 2;
    cfg.opt.generations = 2;
    let world = Scenario::Baseline.build(&cfg, cfg.epochs, 21);
    for spec in registry::all() {
        let run = || {
            let mut sched = registry::build(spec.name, &world.cfg, None)
                .expect("framework builds");
            world.run(sched.as_mut(), 21)
        };
        let a = run();
        let b = run();
        assert_eq!(a.name, spec.name);
        assert!(a.total.requests > 0.0, "{}: no traffic", spec.name);
        // bitwise: totals and the full per-epoch objective series
        assert_eq!(a.total.requests, b.total.requests, "{}", spec.name);
        assert_eq!(a.total.carbon_kg, b.total.carbon_kg, "{}", spec.name);
        assert_eq!(a.total.water_l, b.total.water_l, "{}", spec.name);
        assert_eq!(a.total.cost_usd, b.total.cost_usd, "{}", spec.name);
        assert_eq!(a.total.ttft_sum_s, b.total.ttft_sum_s, "{}", spec.name);
    }
}

#[test]
fn delta_vs_full_parity_holds_at_l48_property() {
    // the satellite's propkit row: maintaining spilled DcVec aggregates
    // incrementally across whole move sequences stays within 1e-9
    // relative of a from-scratch evaluation at planet scale
    let mut cfg = SystemConfig::paper_default();
    cfg.datacenters = global_fleet_datacenters(6);
    cfg.validate().expect("48-site fleet validates");
    let dcs = cfg.datacenters.len();
    assert_eq!(dcs, 48);
    let signals = GridSignals::generate(&cfg, 6, 13);
    let trace = Trace::generate(&cfg, 6, 13);
    let (cp, dp) = build_panels(&cfg, &signals, 3, &trace.epochs[3], 0.05);
    let ev =
        AnalyticEvaluator::new(cp, dp, EvalConsts::from_physics(&cfg.physics));
    let k_n = cfg.num_classes();

    let rel_err = |a: &[f64; N_OBJ], b: &[f64; N_OBJ]| -> f64 {
        (0..N_OBJ)
            .map(|i| (a[i] - b[i]).abs() / b[i].abs().max(1e-12))
            .fold(0.0, f64::max)
    };

    propkit::check(
        "dcvec-l48-delta-parity",
        0x48DC,
        24,
        |r| (Plan::random(k_n, dcs, 0.5, r), r.fork(5)),
        |(start, rng)| {
            let mut rng = rng.clone();
            let mut plan = start.clone();
            let mut agg = ev.aggregate(plan.as_slice());
            let mut scratch = PlanAgg::zeros(dcs);
            for mv in 0..10 {
                let (next, mask) = match mv % 4 {
                    2 => {
                        let k = rng.below(k_n);
                        let to = rng.below(dcs);
                        let frac = rng.range(0.2, 0.8);
                        (plan.shifted_toward(k, to, frac), 1u64 << k)
                    }
                    3 => {
                        let k = rng.below(k_n);
                        (plan.shifted_toward(k, 0, 1.0), 1u64 << k)
                    }
                    _ => plan.perturbed_tracked(0.4, &mut rng),
                };
                // the search-loop shape: copy into the reused scratch,
                // apply the touched rows, finish
                scratch.copy_from(&agg);
                for k in 0..k_n {
                    if (mask >> k) & 1 == 1 {
                        ev.apply_row_delta(
                            &mut scratch,
                            k,
                            plan.row(k),
                            next.row(k),
                        );
                    }
                }
                let fast = ev.finish(&scratch);
                agg.copy_from(&scratch);
                plan = next;
                let full = ev.evaluate(&plan);
                let err = rel_err(&fast, &full);
                if err > 1e-9 {
                    return Err(format!(
                        "move {mv}: rel err {err:.3e} ({fast:?} vs {full:?})"
                    ));
                }
            }
            Ok(())
        },
    );
}
