//! Deadline/conservation properties for the temporal-shifting subsystem
//! (PR 7), at the *session* level — the shifter's own unit tests cover the
//! queue in isolation; these pin what reaches the ledgers end-to-end:
//!
//! * every epoch, cumulative offered == cumulative released + cumulative
//!   expired + mass still queued (exact — lots are integral);
//! * nothing expires: both shipped policies force-release at the deadline,
//!   so `deferred_expired` staying 0 certifies every deadline was met;
//! * the release *schedule* never changes the served mass — Immediate and
//!   Forecast serve bit-for-bit the same request count;
//! * at deferrable fraction 0 the `slit-shift` wrapper is bit-identical
//!   to its inner framework (`slit-carbon`): same plans, same ledgers.

use slit::baselines::RoundRobinScheduler;
use slit::config::SystemConfig;
use slit::opt::ShiftScheduler;
use slit::power::GridSignals;
use slit::registry;
use slit::sim::{simulate, SimResult};
use slit::trace::Trace;
use slit::util::propkit;

/// Hourly-epoch config with a randomised deferrable carve-out.
fn deferrable_cfg(frac: f64, slack: usize, epochs: usize) -> SystemConfig {
    let mut cfg = SystemConfig::small_test();
    cfg.physics.epoch_s = 3600.0;
    cfg.workload.deferrable_frac = frac;
    cfg.workload.defer_slack_epochs = slack;
    cfg.epochs = epochs;
    cfg
}

/// Run one world under the round-robin spatial policy, either bare
/// (Immediate release) or wrapped (Forecast release).
fn run_world(cfg: &SystemConfig, seed: u64, wrapped: bool) -> SimResult {
    let trace = Trace::generate(cfg, cfg.epochs, seed);
    let signals = GridSignals::generate(cfg, cfg.epochs, seed);
    if wrapped {
        let mut s = ShiftScheduler::new(Box::new(RoundRobinScheduler));
        simulate(cfg, &trace, &signals, &mut s, seed)
    } else {
        let mut s = RoundRobinScheduler;
        simulate(cfg, &trace, &signals, &mut s, seed)
    }
}

#[test]
fn session_ledgers_conserve_deferred_mass_under_both_policies() {
    propkit::check(
        "session_deferred_conservation",
        0x5348_4950,
        6,
        |rng| {
            let frac = 0.05 + 0.55 * rng.f64();
            let slack = 1 + rng.below(10);
            let epochs = 8 + rng.below(10);
            (frac, slack, epochs, rng.next_u64())
        },
        |&(frac, slack, epochs, seed)| {
            let cfg = deferrable_cfg(frac, slack, epochs);
            for wrapped in [false, true] {
                let res = run_world(&cfg, seed, wrapped);
                let (mut off, mut rel, mut exp) = (0.0, 0.0, 0.0);
                for r in &res.per_epoch {
                    off += r.ledger.deferred_offered;
                    rel += r.ledger.deferred_released;
                    exp += r.ledger.deferred_expired;
                    // the every-epoch invariant, exact
                    propkit::mass_balance(
                        off,
                        &[rel, exp, r.ledger.deferred_queued],
                    )?;
                }
                if off == 0.0 {
                    return Err(format!(
                        "frac {frac} generated no deferrable mass"
                    ));
                }
                if exp != 0.0 {
                    return Err(format!("missed deadlines: {exp}"));
                }
                let tail =
                    res.per_epoch.last().unwrap().ledger.deferred_queued;
                if tail != 0.0 {
                    return Err(format!("queue not drained: {tail}"));
                }
                // everything the trace offered (interactive rounds +
                // deferrable lots) was accounted as a request exactly
                // once, regardless of the release schedule: released lots
                // are integral, so round(interactive + released) ==
                // round(interactive) + released in every epoch
                let trace = Trace::generate(&cfg, cfg.epochs, seed);
                let interactive: f64 = trace.epochs[..cfg.epochs]
                    .iter()
                    .map(|e| {
                        e.classes
                            .iter()
                            .map(|c| c.n_req.round())
                            .sum::<f64>()
                    })
                    .sum();
                let deferred: f64 = trace.epochs[..cfg.epochs]
                    .iter()
                    .map(|e| e.total_deferrable())
                    .sum();
                propkit::mass_balance(
                    res.total.requests,
                    &[interactive, deferred],
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn release_schedule_never_changes_served_mass() {
    propkit::check(
        "served_mass_policy_invariance",
        0x4D41_5353,
        6,
        |rng| {
            let frac = 0.1 + 0.4 * rng.f64();
            let slack = 2 + rng.below(12);
            (frac, slack, rng.next_u64())
        },
        |&(frac, slack, seed)| {
            let cfg = deferrable_cfg(frac, slack, 20);
            let imm = run_world(&cfg, seed, false);
            let fcp = run_world(&cfg, seed, true);
            // integral lots: equality is exact across release schedules
            propkit::mass_balance(
                imm.total.requests,
                &[fcp.total.requests],
            )?;
            propkit::mass_balance(
                imm.total.deferred_released,
                &[fcp.total.deferred_released],
            )?;
            if fcp.total.deferred_expired != 0.0 {
                return Err("forecast policy missed a deadline".into());
            }
            Ok(())
        },
    );
}

#[test]
fn slit_shift_is_bit_identical_to_slit_carbon_at_fraction_zero() {
    // deferrable_frac stays at small_test's default 0: the shifter must be
    // structurally inert — no forecaster, no RNG draws, no float changes —
    // so the wrapper reproduces its inner framework bit-for-bit
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 4;
    cfg.opt.budget_s = 60.0;
    cfg.opt.generations = 4;
    assert_eq!(cfg.workload.deferrable_frac, 0.0);
    let trace = Trace::generate(&cfg, cfg.epochs, 42);
    let signals = GridSignals::generate(&cfg, cfg.epochs, 42);

    let run = |name: &str| -> SimResult {
        let mut sched = registry::build(name, &cfg, None).expect("framework");
        simulate(&cfg, &trace, &signals, sched.as_mut(), 42)
    };
    let inner = run("slit-carbon");
    let wrapped = run("slit-shift");

    assert_eq!(wrapped.name, "slit-shift");
    assert_eq!(wrapped.per_epoch.len(), inner.per_epoch.len());
    for (a, b) in inner.per_epoch.iter().zip(&wrapped.per_epoch) {
        assert_eq!(a.plan, b.plan, "plans diverge at epoch {}", a.epoch);
        let la = &a.ledger;
        let lb = &b.ledger;
        for (x, y, what) in [
            (la.requests, lb.requests, "requests"),
            (la.dropped, lb.dropped, "dropped"),
            (la.ttft_sum_s, lb.ttft_sum_s, "ttft_sum_s"),
            (la.e_it_j, lb.e_it_j, "e_it_j"),
            (la.carbon_kg, lb.carbon_kg, "carbon_kg"),
            (la.water_l, lb.water_l, "water_l"),
            (la.cost_usd, lb.cost_usd, "cost_usd"),
        ] {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what} diverges at epoch {}: {x} vs {y}",
                a.epoch
            );
        }
        // and the deferral accounting is all-zero on both sides
        for v in [
            lb.deferred_offered,
            lb.deferred_released,
            lb.deferred_queued,
            lb.deferred_expired,
        ] {
            assert_eq!(v, 0.0);
        }
    }
    assert_eq!(
        inner.total.carbon_kg.to_bits(),
        wrapped.total.carbon_kg.to_bits()
    );
}
