//! Serve-loop harness: boots a real coordinator on an ephemeral TCP port,
//! drives a scripted outage drill over the wire, and pins the serve-time
//! contract the offline tests cannot see:
//!
//!   * serve-time `cluster` ops dip the topology and `restore` recovers it
//!     exactly, visible through `snapshot` replies across forced `tick`s;
//!   * request mass is conserved across the drill (every request sent is
//!     accounted served or rejected — nothing vanishes in the outage);
//!   * malformed input never kills a connection (structured error replies);
//!   * on the drilled (outage-rolling) regime, per-class adaptive SLIT is
//!     non-dominated vs the level-only adaptive it replaced (plain-SLIT
//!     comparisons live in scenario_matrix.rs).
//!
//! Epochs are forced via `{"op": "tick"}` rather than the wall-clock epoch
//! thread, so the harness is deterministic and fast on any CI box.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use slit::config::SystemConfig;
use slit::coordinator::{
    run_drill, serve_forever, Coordinator, CoordinatorConfig, DrillClient,
    DrillConfig,
};
use slit::opt::{SlitScheduler, SlitVariant};
use slit::pareto::dominates;
use slit::scenario::Scenario;
use slit::util::json::Json;

/// A coordinator sized for CI: tiny optimizer budget, no epoch thread
/// (ticks are driven over TCP).
fn boot() -> (Arc<Coordinator>, u16) {
    let mut cfg = SystemConfig::small_test();
    cfg.opt.generations = 2;
    cfg.opt.population = 8;
    let ccfg = CoordinatorConfig {
        plan_budget_s: 0.2,
        ..Default::default()
    };
    let c = Coordinator::new(cfg, ccfg, None);
    let handle = serve_forever(Arc::clone(&c), 0).expect("bind ephemeral");
    (c, handle.port)
}

#[test]
fn tcp_drill_dips_recovers_and_conserves_request_mass() {
    let (c, port) = boot();
    let mut client =
        DrillClient::connect("127.0.0.1", port).expect("connect");
    let report = run_drill(
        &mut client,
        &DrillConfig {
            region: 2,
            frac: 0.0,
            requests_per_wave: 48,
        },
    )
    .expect("drill");

    // the three invariants, individually (not just report.verify()):
    assert!(
        report.dipped_nodes < report.baseline_nodes,
        "no dip: {} -> {}",
        report.baseline_nodes,
        report.dipped_nodes
    );
    assert_eq!(
        report.recovered_nodes, report.baseline_nodes,
        "restore did not return to baseline"
    );
    assert_eq!(
        report.served + report.rejected,
        report.sent,
        "request mass leaked: {} + {} != {}",
        report.served,
        report.rejected,
        report.sent
    );
    // two forced ticks accounted real energy on the live topology
    assert!(report.carbon_kg > 0.0);
    assert_eq!(report.epoch, 2.0);
    report.verify().expect("report verify");
    c.stop();
}

#[test]
fn tcp_drill_partial_brownout_keeps_serving() {
    let (c, port) = boot();
    let mut client =
        DrillClient::connect("127.0.0.1", port).expect("connect");
    // 50% brownout instead of a full outage
    let report = run_drill(
        &mut client,
        &DrillConfig {
            region: 2,
            frac: 0.5,
            requests_per_wave: 32,
        },
    )
    .expect("drill");
    report.verify().expect("report verify");
    assert!(report.dipped_nodes > 0.0, "brownout went fully dark");
    // the small-test fleet has ample headroom: a 50% regional brownout
    // must not reject everything
    assert!(report.served > 0, "nothing served through the brownout");
    c.stop();
}

#[test]
fn tcp_snapshots_show_per_site_dip_only_in_the_drilled_region() {
    let (c, port) = boot();
    let mut client =
        DrillClient::connect("127.0.0.1", port).expect("connect");
    let op = |name: &str| -> Json {
        let mut j = Json::obj();
        j.set("op", Json::Str(name.into()));
        j
    };
    let before = client.call_ok(&op("snapshot")).expect("snapshot");
    let mut darken = op("cluster");
    darken.set("action", Json::Str("scale-region".into()));
    darken.set("region", Json::Num(2.0));
    darken.set("frac", Json::Num(0.0));
    client.call_ok(&darken).expect("cluster op");
    client.call_ok(&op("tick")).expect("tick");
    let during = client.call_ok(&op("snapshot")).expect("snapshot");

    let sites = |j: &Json| -> Vec<(f64, f64)> {
        j.get("sites")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|s| {
                (
                    s.get("region").and_then(Json::as_f64).unwrap(),
                    s.get("total").and_then(Json::as_f64).unwrap(),
                )
            })
            .collect()
    };
    for ((region, full), (_, dipped)) in
        sites(&before).into_iter().zip(sites(&during))
    {
        if region == 2.0 {
            assert_eq!(dipped, 0.0, "drilled site not dark");
            assert!(full > 0.0);
        } else {
            assert_eq!(dipped, full, "healthy site lost nodes");
        }
    }
    c.stop();
}

#[test]
fn tcp_malformed_traffic_mid_drill_gets_structured_errors() {
    let (c, port) = boot();
    // raw socket (not DrillClient): send garbage interleaved with a drill
    let stream =
        TcpStream::connect(("127.0.0.1", port)).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    fn call(
        writer: &mut TcpStream,
        reader: &mut BufReader<TcpStream>,
        payload: &[u8],
    ) -> Json {
        writer.write_all(payload).expect("write");
        writer.write_all(b"\n").expect("write nl");
        let mut line = String::new();
        reader.read_line(&mut line).expect("read");
        assert!(!line.is_empty(), "connection dropped");
        Json::parse(line.trim()).expect("parse reply")
    }
    let ok = |j: &Json| j.get("ok").and_then(Json::as_bool);

    let r = call(
        &mut writer,
        &mut reader,
        br#"{"op": "cluster", "action": "scale-region", "region": 2, "frac": 0}"#,
    );
    assert_eq!(ok(&r), Some(true));
    // garbage between drill steps must not sever the session
    assert_eq!(
        ok(&call(&mut writer, &mut reader, b"%% not json %%")),
        Some(false)
    );
    assert_eq!(
        ok(&call(&mut writer, &mut reader, br#"{"op": []}"#)),
        Some(false)
    );
    assert_eq!(
        ok(&call(
            &mut writer,
            &mut reader,
            br#"{"op": "cluster", "action": "scale-region"}"#
        )),
        Some(false)
    );
    let r = call(&mut writer, &mut reader, br#"{"op": "tick"}"#);
    assert_eq!(ok(&r), Some(true));
    let r = call(&mut writer, &mut reader, br#"{"op": "snapshot"}"#);
    assert_eq!(ok(&r), Some(true));
    assert_eq!(r.get("baseline").and_then(Json::as_bool), Some(false));
    c.stop();
}

/// Per-class TTFT percentiles must be visible through a real socket: after
/// a mixed-class request stream, `{"op": "stats"}` reports overall
/// p50/p95/p99 plus a per-class breakdown whose counts partition the served
/// total, and every served reply carries the unified per-request schema
/// (`dc`/`dc_index`/`ttft_ms`/`epoch`) on the single-request path too.
#[test]
fn tcp_stats_expose_per_class_ttft_percentiles() {
    use slit::config::{MODELS, REGIONS};

    let (c, port) = boot();
    let mut client =
        DrillClient::connect("127.0.0.1", port).expect("connect");
    let mut served = 0u64;
    for i in 0..64usize {
        let mut q = Json::obj();
        q.set("region", Json::Num((i % REGIONS) as f64));
        q.set("model", Json::Num((i % MODELS) as f64));
        q.set("tok_in", Json::Num(64.0));
        q.set("tok_out", Json::Num(128.0));
        let r = client.call(&q).expect("reply");
        if r.get("ok").and_then(Json::as_bool) == Some(true) {
            served += 1;
            for key in ["dc", "dc_index", "ttft_ms", "epoch"] {
                assert!(r.get(key).is_some(), "reply missing '{key}'");
            }
        }
    }
    assert!(served > 0, "small-test fleet served nothing");

    let mut op = Json::obj();
    op.set("op", Json::Str("stats".into()));
    let stats = client.call_ok(&op).expect("stats");
    let f = |j: &Json, k: &str| {
        j.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN)
    };
    assert_eq!(f(&stats, "served") as u64, served);
    assert!(f(&stats, "ttft_p50_ms") > 0.0);
    assert!(f(&stats, "ttft_p50_ms") <= f(&stats, "ttft_p95_ms"));
    assert!(f(&stats, "ttft_p95_ms") <= f(&stats, "ttft_p99_ms"));
    let classes =
        stats.get("classes").and_then(Json::as_arr).expect("classes");
    assert!(!classes.is_empty(), "no per-class histograms");
    let mut count_sum = 0u64;
    for e in classes {
        count_sum += f(e, "count") as u64;
        assert!(f(e, "ttft_p50_ms") > 0.0);
        assert!(f(e, "ttft_p50_ms") <= f(e, "ttft_p99_ms"));
        let class = f(e, "class") as usize;
        assert_eq!(f(e, "region") as usize, class / MODELS);
        assert_eq!(f(e, "model") as usize, class % MODELS);
    }
    assert_eq!(
        count_sum, served,
        "class histograms must partition the served total"
    );
    c.stop();
}

/// The feedback-evaluation half of the harness: on the drilled regime
/// (the event-driven rolling outage), the per-class adaptive scheduler
/// must be non-dominated against the level-only correction it replaced —
/// upgrading from one global ratio to per-class ratios must not make
/// SLIT strictly worse on every axis at once. (The adaptive-vs-*plain*
/// comparison, on both bursty and outage-rolling, lives in
/// rust/tests/scenario_matrix.rs::adaptive_vs_plain_on_bursty_and_rolling_outage.)
#[test]
fn per_class_adaptive_is_nondominated_vs_level_only_on_the_drilled_regime() {
    let mut base = SystemConfig::small_test();
    base.epochs = 6;
    base.opt.budget_s = 60.0;
    base.opt.generations = 4;
    base.workload.base_requests_per_epoch = 1000.0;
    let world = Scenario::RollingOutage.build(&base, base.epochs, 42);

    let mut level = SlitScheduler::new(&world.cfg, SlitVariant::Balance)
        .with_level_feedback();
    let level_res = world.run(&mut level, 42);
    let mut per_class =
        SlitScheduler::new(&world.cfg, SlitVariant::Balance).with_feedback();
    let per_class_res = world.run(&mut per_class, 42);

    assert_eq!(level_res.name, "slit-adaptive-level");
    assert_eq!(per_class_res.name, "slit-adaptive");

    // same world, same sampled request mass for both schedulers
    assert_eq!(level_res.total.requests, per_class_res.total.requests);
    assert!(per_class_res.total.requests > 0.0);

    let lo = level_res.objectives();
    let ao = per_class_res.objectives();
    assert!(
        !dominates(&lo, &ao),
        "level-only adaptive dominates per-class ({lo:?} vs {ao:?})"
    );
}
