//! PR 8 satellite: quantization round-trip for the lower-bound oracle.
//!
//! The oracle solves a fixed-point min-cost-flow relaxation and then
//! *certifies* the result: `OracleBound::score()` is the raw flow value
//! minus the stated quantization slack (floored demand residue priced at
//! the most favourable arc, plus a small FP-association margin). The
//! contract under test: on every ≤16-site world we can build, that
//! certified value never exceeds the exact f64 evaluation of any plan —
//! i.e. the stated slack really does cover everything the integer
//! round-trip discarded. A companion test pins bit-determinism of the
//! bound across thread-pool sizes (the oracle must not perturb the
//! simulation's reproducibility guarantees).

use slit::cluster::build_panels;
use slit::config::{SystemConfig, N_OBJ};
use slit::eval::{AnalyticEvaluator, EvalConsts};
use slit::opt::epoch_lower_bound;
use slit::plan::Plan;
use slit::power::GridSignals;
use slit::trace::Trace;
use slit::util::propkit;
use slit::util::rng::Rng;
use slit::util::threadpool;

/// Paper fleet truncated to `sites` datacenters, demand scaled by
/// `load_mult` (0.2 = deep linear regime, 20 = heavily saturated).
fn make_eval(
    sites: usize,
    unused_pr: f64,
    load_mult: f64,
    seed: u64,
) -> (SystemConfig, AnalyticEvaluator) {
    let mut cfg = SystemConfig::paper_default();
    cfg.datacenters.truncate(sites);
    cfg.workload.base_requests_per_epoch *= load_mult;
    let signals = GridSignals::generate(&cfg, 8, seed);
    let trace = Trace::generate(&cfg, 8, seed);
    let (cp, dp) = build_panels(&cfg, &signals, 4, &trace.epochs[4], unused_pr);
    let consts = EvalConsts::from_physics(&cfg.physics);
    (cfg, AnalyticEvaluator::new(cp, dp, consts))
}

#[test]
fn certified_bound_never_exceeds_exact_evaluation() {
    propkit::check(
        "oracle-quantization-roundtrip",
        0x51_AC4,
        12,
        |r| {
            (
                // paper fleet is 12 sites; each prefix keeps whole-region
                // blocks out rather than resampling
                [4usize, 6, 9, 12][r.below(4)],
                r.range(0.02, 0.4),
                [0.2f64, 1.0, 20.0][r.below(3)],
                r.int(1, 1_000_000) as u64,
            )
        },
        |&(sites, unused_pr, load_mult, seed)| {
            let (cfg, ev) = make_eval(sites, unused_pr, load_mult, seed);
            let mut rng = Rng::new(seed ^ 0xDEAD);
            let mut plans: Vec<Plan> = (0..8)
                .map(|_| {
                    Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng)
                })
                .collect();
            plans.push(Plan::uniform(cfg.num_classes(), ev.dcs()));
            for l in 0..ev.dcs() {
                plans.push(Plan::one_dc(cfg.num_classes(), ev.dcs(), l));
            }
            plans.extend(ev.greedy_seed_plans());
            for obj in 0..N_OBJ {
                let bound = epoch_lower_bound(&ev, obj);
                if !bound.score().is_finite() || bound.slack < 0.0 {
                    return Err(format!(
                        "obj {obj}: bad bound raw={} slack={}",
                        bound.raw, bound.slack
                    ));
                }
                for (i, p) in plans.iter().enumerate() {
                    let exact = ev.evaluate(p)[obj];
                    if bound.score() > exact {
                        return Err(format!(
                            "sites={sites} load={load_mult} obj={obj} \
                             plan#{i}: certified {} > exact {} \
                             (raw {} slack {})",
                            bound.score(),
                            exact,
                            bound.raw,
                            bound.slack
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn bound_is_bit_identical_across_thread_counts() {
    let (_, ev) = make_eval(12, 0.05, 1.0, 7);
    let baseline: Vec<(f64, f64)> = (0..N_OBJ)
        .map(|obj| {
            let b = epoch_lower_bound(&ev, obj);
            (b.raw, b.slack)
        })
        .collect();
    for &threads in &[1usize, 2, 8] {
        threadpool::set_thread_override(threads);
        for obj in 0..N_OBJ {
            let b = epoch_lower_bound(&ev, obj);
            assert_eq!(
                (b.raw, b.slack),
                baseline[obj],
                "obj {obj}: bound drifted at {threads} threads"
            );
        }
    }
    threadpool::set_thread_override(0);
}
