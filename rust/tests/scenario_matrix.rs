//! Scenario-matrix integration: every named workload/grid regime ×
//! (SLIT target variant, Helix, Splitwise) on the discrete simulator —
//! including the event-driven `outage-rolling` regime, whose capacity
//! varies *mid-run* through the SimSession event schedule, and the
//! planet-scale `global-fleet` regime (48 sites, past the AOT tile),
//! which runs the whole matrix on the spilled `DcVec` evaluator path.
//!
//! The paper's qualitative claim, generalised across regimes: on the
//! objective a scenario stresses, the matching SLIT variant must stay
//! non-dominated against both baselines — and on the sustainability axes
//! its scale-to-zero + grid-aware routing must win by a wide margin.

use slit::cluster::ClusterAction;
use slit::config::{
    SystemConfig, OBJ_CARBON, OBJ_NAMES, OBJ_TTFT, OBJ_WATER, REGIONS,
};
use slit::opt::{
    SearchMode, SlitOptions, SlitScheduler, SlitVariant,
    REGION_DECOMPOSE_THRESHOLD,
};
use slit::pareto::dominates;
use slit::registry;
use slit::scenario::Scenario;
use slit::session::ScenarioEvent;
use slit::signals::SignalFault;
use slit::sim::SimResult;

/// Test-scale config with enough pressure that schedulers differ. The
/// generation count bounds the runtime; the wall-clock budget is kept far
/// above it so a slow CI box cannot truncate the search and flake the
/// quantitative margins below.
fn pressured_config() -> SystemConfig {
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 4;
    cfg.opt.budget_s = 60.0;
    cfg.opt.generations = 5;
    cfg.workload.base_requests_per_epoch = 1200.0;
    cfg
}

fn variant_for(obj: usize) -> SlitVariant {
    match obj {
        OBJ_TTFT => SlitVariant::Ttft,
        OBJ_CARBON => SlitVariant::Carbon,
        OBJ_WATER => SlitVariant::Water,
        _ => SlitVariant::Cost,
    }
}

#[test]
fn slit_stays_nondominated_on_target_objective_in_every_scenario() {
    let base = pressured_config();
    for sc in Scenario::named() {
        let world = sc.build(&base, base.epochs, 42);
        let target = sc.target_objective();
        // frameworks resolve through the registry; worlds run through the
        // session API so scheduled events (outage-rolling) fire
        let run = |name: &str| -> SimResult {
            let mut sched =
                registry::build(name, &world.cfg, None).expect("framework");
            world.run(sched.as_mut(), 42)
        };
        let helix = run("helix");
        let splitwise = run("splitwise");
        let slit = run(variant_for(target).name());

        let so = slit.objectives();
        let ho = helix.objectives();
        let po = splitwise.objectives();
        assert!(slit.total.requests > 0.0, "{}: no traffic", sc.name());

        // non-domination: no baseline beats SLIT on every axis at once
        assert!(
            !dominates(&ho, &so),
            "{}: helix dominates slit ({ho:?} vs {so:?})",
            sc.name()
        );
        assert!(
            !dominates(&po, &so),
            "{}: splitwise dominates slit ({po:?} vs {so:?})",
            sc.name()
        );

        // ...and on the regime's stressed (sustainability) objective the
        // win must be wide, as in Fig. 4
        assert!(
            so[target] < 0.75 * ho[target],
            "{} ({}): slit {} vs helix {}",
            sc.name(),
            OBJ_NAMES[target],
            so[target],
            ho[target]
        );
        assert!(
            so[target] < 0.75 * po[target],
            "{} ({}): slit {} vs splitwise {}",
            sc.name(),
            OBJ_NAMES[target],
            so[target],
            po[target]
        );
    }
}

/// Pinned optimality-gap ceiling per regime (PR 8). These are ratchets:
/// loose enough that legitimate relaxation weakness under the pressured
/// config (saturation flattens the oracle's chord bound) cannot flake
/// them, tight enough that a search-quality regression that pushes SLIT
/// an order of magnitude off the certified optimum fails CI. The
/// harder-to-certify regimes — `global-fleet` (48 sites dilute the
/// per-site bound) and `batch-overnight` (released deferrable mass rides
/// on top of the interactive prediction) — get the wider ceiling, as do
/// the telemetry-fault regimes (PR 9), whose fault-blind target variant
/// plans on corrupt signals while the oracle scores against the truth.
/// The edge fleets (PR 10) push the same per-site dilution that widens
/// `global-fleet` out to 256/512 sites, so they sit at the widest rung.
fn gap_ceiling(scenario: &str) -> f64 {
    match scenario {
        "global-fleet" | "batch-overnight" => 0.98,
        "feed-blackout" | "stale-creep" => 0.98,
        "edge-fleet-256" | "edge-fleet-512" => 0.99,
        _ => 0.95,
    }
}

/// The PR 8 tentpole claim at matrix level. (a) Soundness: the certified
/// per-epoch oracle never exceeds *any* framework's achieved scalarized
/// score, on any objective, in any epoch of any regime — this is the
/// blocking guard that keeps the bound honest. (b) Calibration: on every
/// regime's target objective — including `global-fleet` at L=48 and
/// `batch-overnight` — the matching SLIT variant's whole-run gap stays
/// under a finite pinned ceiling, turning "non-dominated" into a
/// quantified distance from optimal.
#[test]
fn oracle_gap_is_sound_and_bounded_in_every_scenario() {
    let base = pressured_config();
    for sc in Scenario::named() {
        let world = sc.build(&base, base.epochs, 42);
        let target = sc.target_objective();
        let run = |name: &str| -> SimResult {
            let mut sched =
                registry::build(name, &world.cfg, None).expect("framework");
            world.run(sched.as_mut(), 42)
        };
        for name in ["helix", "splitwise", variant_for(target).name()] {
            let res = run(name);
            for rec in &res.per_epoch {
                for (obj, g) in rec.gaps.iter().enumerate() {
                    assert!(
                        g.oracle_score.is_finite() && g.achieved.is_finite(),
                        "{}/{name} epoch {} obj {obj}: non-finite {g:?}",
                        sc.name(),
                        rec.epoch
                    );
                    assert!(
                        g.oracle_score <= g.achieved,
                        "{}/{name} epoch {} {}: oracle {} > achieved {} — \
                         the bound is not a lower bound",
                        sc.name(),
                        rec.epoch,
                        OBJ_NAMES[obj],
                        g.oracle_score,
                        g.achieved
                    );
                    assert!(g.gap_frac >= 0.0);
                    assert!(g.quantization_slack >= 0.0);
                }
            }
            if name == variant_for(target).name() {
                let gap = res.oracle_gap(target);
                let ceiling = gap_ceiling(sc.name());
                assert!(
                    gap >= 0.0 && gap <= ceiling,
                    "{} ({}): slit gap {gap:.4} breaches ceiling {ceiling}",
                    sc.name(),
                    OBJ_NAMES[target]
                );
                // the EXPERIMENTS.md gap-table row, printable from CI logs
                eprintln!(
                    "| {} | {} | gap {gap:.3} | ceiling {ceiling:.2} |",
                    sc.name(),
                    OBJ_NAMES[target]
                );
            }
        }
    }
}

#[test]
fn global_fleet_matrix_really_runs_at_l48() {
    // the non-domination sweep above covers global-fleet like any named
    // regime; this pins that the world it ran actually is the 48-site
    // spilled-tile fleet, not a silently truncated one
    let base = pressured_config();
    let world = Scenario::GlobalFleet.build(&base, base.epochs, 42);
    assert_eq!(world.cfg.datacenters.len(), 48);
    assert!(world.cfg.validate_aot().is_err(), "analytic-only fleet");
    let mut sched =
        registry::build("slit-carbon", &world.cfg, None).expect("framework");
    let res = world.run(sched.as_mut(), 42);
    assert_eq!(res.per_epoch[0].site_nodes.len(), 48);
    assert!(res.total.requests > 0.0);
}

#[test]
fn edge_fleet_matrix_really_runs_at_l256_and_l512() {
    // the matrix loops above cover edge-fleet-256/512 like any named
    // regime; this pins that those worlds actually are the 256/512-site
    // fleets (past the region-decomposition threshold, so the decomposed
    // search auto-selects) and that a run still serves traffic. Two
    // epochs keep this pin cheap — the full-length runs happen in the
    // named() sweeps.
    let mut base = pressured_config();
    base.epochs = 2;
    for (sc, sites) in
        [(Scenario::EdgeFleet256, 256), (Scenario::EdgeFleet512, 512)]
    {
        let world = sc.build(&base, base.epochs, 42);
        assert_eq!(world.cfg.datacenters.len(), sites, "{}", sc.name());
        assert!(world.cfg.validate_aot().is_err(), "analytic-only fleet");
        assert!(sites >= REGION_DECOMPOSE_THRESHOLD);
        let mut sched = registry::build("slit-carbon", &world.cfg, None)
            .expect("framework");
        let res = world.run(sched.as_mut(), 42);
        assert_eq!(res.per_epoch[0].site_nodes.len(), sites);
        assert!(res.total.requests > 0.0, "{}", sc.name());
    }
}

/// PR 10 parity pin: forcing the region-decomposed search on fleets far
/// below its auto threshold must not wreck plan quality. On every
/// small-fleet regime, the forced-decomposed variant matching the
/// regime's target objective stays non-dominated against the plain
/// global walk run on the identical world and seed.
#[test]
fn forced_region_search_stays_nondominated_vs_global_walk_on_small_fleets() {
    let base = pressured_config();
    for sc in Scenario::named() {
        let world = sc.build(&base, base.epochs, 42);
        if world.cfg.datacenters.len() >= REGION_DECOMPOSE_THRESHOLD {
            // at these sizes both schedulers resolve to the decomposed
            // search anyway — the comparison below would be vacuous
            continue;
        }
        let target = sc.target_objective();
        let variant = variant_for(target);

        let mut global_sched = SlitScheduler::new(&world.cfg, variant);
        let global = world.run(&mut global_sched, 42);

        let mut region_sched = SlitScheduler::new(&world.cfg, variant)
            .with_options(SlitOptions {
                search_mode: Some(SearchMode::RegionDecomposed),
                ..SlitOptions::default()
            });
        let region = world.run(&mut region_sched, 42);

        assert!(
            region.name.ends_with("-region") || region.name == "slit-region",
            "{}: forced mode not reflected in name {}",
            sc.name(),
            region.name
        );
        assert_eq!(
            global.total.requests,
            region.total.requests,
            "{}: request mass differs between search modes",
            sc.name()
        );
        let go = global.objectives();
        let ro = region.objectives();
        assert!(ro.iter().all(|v| v.is_finite()), "{}", sc.name());
        assert!(
            !dominates(&go, &ro),
            "{} ({}): global walk dominates decomposed search \
             ({go:?} vs {ro:?})",
            sc.name(),
            OBJ_NAMES[target]
        );
        eprintln!(
            "| {} | global {:.4} | region {:.4} | {} |",
            sc.name(),
            go[target],
            ro[target],
            OBJ_NAMES[target]
        );
    }
}

#[test]
fn rolling_outage_records_show_dip_and_recovery_for_every_framework() {
    let base = pressured_config();
    let world = Scenario::RollingOutage.build(&base, base.epochs, 42);
    // 4-epoch horizon -> dark at epoch 1, restored at epoch 2
    for name in ["helix", "splitwise", "slit-cost"] {
        let mut sched =
            registry::build(name, &world.cfg, None).expect("framework");
        let res = world.run(sched.as_mut(), 42);
        let nodes =
            |e: usize| -> usize { res.per_epoch[e].site_nodes.iter().sum() };
        assert!(
            nodes(1) < nodes(0),
            "{name}: no capacity dip ({} vs {})",
            nodes(1),
            nodes(0)
        );
        assert_eq!(nodes(2), nodes(0), "{name}: capacity not restored");
        assert_eq!(nodes(3), nodes(0));
    }
}

/// The ROADMAP feedback-evaluation item: adaptive-vs-plain on the two
/// prediction-hostile regimes. The per-class corrected scheduler must be
/// non-dominated against both the plain balanced variant and the
/// level-only correction it replaced, on `bursty` (heavy-tailed demand
/// misses) and `outage-rolling` (capacity vanishes under the forecast).
/// EXPERIMENTS.md records the measured objective rows; this test pins the
/// qualitative outcome on every run.
#[test]
fn adaptive_vs_plain_on_bursty_and_rolling_outage() {
    let base = pressured_config();
    for sc in [Scenario::BurstyHeavyTail, Scenario::RollingOutage] {
        let world = sc.build(&base, base.epochs, 42);
        let run = |name: &str| -> SimResult {
            let mut sched =
                registry::build(name, &world.cfg, None).expect("framework");
            world.run(sched.as_mut(), 42)
        };
        let plain = run("slit-balance");
        let level = run("slit-adaptive-level");
        let adaptive = run("slit-adaptive");
        assert_eq!(adaptive.name, "slit-adaptive", "{}", sc.name());
        assert_eq!(level.name, "slit-adaptive-level", "{}", sc.name());

        // one shared world: every variant sees the same request mass
        assert_eq!(
            plain.total.requests,
            adaptive.total.requests,
            "{}: request mass differs",
            sc.name()
        );
        assert_eq!(level.total.requests, adaptive.total.requests);
        assert!(adaptive.total.requests > 0.0);

        let po = plain.objectives();
        let lo = level.objectives();
        let ao = adaptive.objectives();
        assert!(
            !dominates(&po, &ao),
            "{}: plain dominates per-class adaptive ({po:?} vs {ao:?})",
            sc.name()
        );
        assert!(
            !dominates(&lo, &ao),
            "{}: level-only dominates per-class adaptive ({lo:?} vs {ao:?})",
            sc.name()
        );
        // the EXPERIMENTS.md row: print the measured objectives so a CI
        // log or local run can be pasted into the table verbatim
        eprintln!(
            "| {} | plain {po:?} | level {lo:?} | per-class {ao:?} |",
            sc.name()
        );
    }
}

/// The PR 7 pinned claim: forecast-driven temporal shifting strictly
/// improves cumulative carbon at equal served mass, with zero missed
/// deadlines, against the same spatial scheduler releasing deferrable
/// mass on arrival. The horizon spans 1.5 diurnal cycles so the forecast
/// policy has real clean-energy valleys to shift into; masses are
/// integral, so the served-mass equality is exact, not approximate.
#[test]
fn temporal_shifting_cuts_carbon_at_equal_served_mass() {
    let mut base = SystemConfig::small_test();
    base.epochs = 36;
    base.opt.budget_s = 60.0;
    base.opt.generations = 3;
    let world = Scenario::BatchOvernight.build(&base, base.epochs, 42);
    assert!(
        world
            .trace
            .epochs
            .iter()
            .any(|e| e.total_deferrable() > 0.0),
        "regime generated no deferrable mass"
    );

    let run = |name: &str| -> SimResult {
        let mut sched =
            registry::build(name, &world.cfg, None).expect("framework");
        world.run(sched.as_mut(), 42)
    };
    let noshift = run("slit-carbon");
    let shift = run("slit-shift");

    // equal served mass — exact, because lots are integral and atomic
    assert_eq!(
        shift.total.requests, noshift.total.requests,
        "release schedule changed the served mass"
    );
    assert!(shift.total.requests > 0.0);

    // zero missed deadlines on both sides; both queues fully drained
    assert_eq!(shift.total.deferred_expired, 0.0);
    assert_eq!(noshift.total.deferred_expired, 0.0);
    assert_eq!(shift.total.deferred_offered, shift.total.deferred_released);
    assert_eq!(
        noshift.total.deferred_offered,
        noshift.total.deferred_released
    );
    assert_eq!(shift.total.deferred_queued, 0.0, "queue not drained");

    // the shifter actually held mass back (otherwise the comparison is
    // vacuous), and the immediate policy never does
    assert!(
        shift
            .per_epoch
            .iter()
            .any(|r| r.ledger.deferred_queued > 0.0),
        "forecast policy never deferred anything"
    );
    assert!(noshift
        .per_epoch
        .iter()
        .all(|r| r.ledger.deferred_queued == 0.0));

    // the pinned claim: strictly lower cumulative carbon
    assert!(
        shift.total.carbon_kg < noshift.total.carbon_kg,
        "temporal shifting did not cut carbon: {} vs {}",
        shift.total.carbon_kg,
        noshift.total.carbon_kg
    );
    // the EXPERIMENTS.md row, printable from any CI log
    eprintln!(
        "| batch-overnight | slit-shift {:.3} kg | slit-carbon {:.3} kg | \
         ratio {:.3} |",
        shift.total.carbon_kg,
        noshift.total.carbon_kg,
        shift.total.carbon_kg / noshift.total.carbon_kg
    );
}

/// The PR 9 pinned claim, half one: under telemetry faults the
/// health-gated fallback ladder (`slit-robust`) strictly cuts *true*
/// cumulative carbon against the fault-blind variant planning on the
/// same corrupt feeds — at exactly-equal served mass, on both telemetry
/// regimes. The 16-epoch horizon gives the fault windows room: a
/// 4-epoch regional blackout (feed-blackout) and a creeping fleet-wide
/// freeze (stale-creep). Request sampling is plan-independent per seed
/// and capacity has headroom, so the served-mass equality is exact.
#[test]
fn robust_beats_fault_blind_slit_on_true_carbon_under_faults() {
    let mut base = SystemConfig::small_test();
    base.epochs = 16;
    base.opt.budget_s = 60.0;
    base.opt.generations = 5;
    base.workload.base_requests_per_epoch = 1200.0;
    for sc in [Scenario::FeedBlackout, Scenario::StaleCreep] {
        let world = sc.build(&base, base.epochs, 42);
        assert!(
            !world.events.is_empty(),
            "{}: regime scheduled no telemetry faults",
            sc.name()
        );
        let run = |name: &str| -> SimResult {
            let mut sched =
                registry::build(name, &world.cfg, None).expect("framework");
            world.run(sched.as_mut(), 42)
        };
        let blind = run("slit-carbon");
        let robust = run("slit-robust");
        assert_eq!(robust.name, "slit-robust", "{}", sc.name());

        // the faults really degraded the believed picture mid-run
        assert!(
            robust.per_epoch.iter().any(|r| r.ledger.signal_stale > 0.0
                || r.ledger.signal_quarantined > 0.0),
            "{}: no site-epoch ever went stale",
            sc.name()
        );

        // telemetry faults touch information, not capacity: both sides
        // serve the identical request mass, exactly
        assert_eq!(
            robust.total.requests,
            blind.total.requests,
            "{}: served mass differs",
            sc.name()
        );
        assert!(robust.total.requests > 0.0);
        assert_eq!(robust.total.dropped, 0.0, "{}", sc.name());
        assert_eq!(blind.total.dropped, 0.0, "{}", sc.name());

        // the pinned claim: strictly lower true carbon
        assert!(
            robust.total.carbon_kg < blind.total.carbon_kg,
            "{}: fallback ladder did not cut true carbon ({} vs {})",
            sc.name(),
            robust.total.carbon_kg,
            blind.total.carbon_kg
        );
        // the EXPERIMENTS.md row, printable from any CI log
        eprintln!(
            "| {} | slit-robust {:.3} kg | slit-carbon {:.3} kg | \
             ratio {:.3} |",
            sc.name(),
            robust.total.carbon_kg,
            blind.total.carbon_kg,
            robust.total.carbon_kg / blind.total.carbon_kg
        );
    }
}

/// The PR 9 pinned claim, half two: under a *total* telemetry blackout —
/// every region's feed dark from epoch 1 to the end of the horizon, so
/// the fleet median rung has no fresh donor and the ladder bottoms out
/// on decayed last-known-good blended into the static config priors —
/// `slit-robust` still lands non-dominated against both baselines on the
/// true objectives.
#[test]
fn robust_survives_total_feed_blackout_nondominated() {
    let mut base = SystemConfig::small_test();
    base.epochs = 8;
    base.opt.budget_s = 60.0;
    base.opt.generations = 5;
    base.workload.base_requests_per_epoch = 1200.0;
    let mut world = Scenario::Baseline.build(&base, base.epochs, 42);
    for region in 0..REGIONS {
        world.events.push(ScenarioEvent::at(
            1,
            ClusterAction::Signal(SignalFault::RegionBlackout {
                region,
                epochs: base.epochs,
            }),
        ));
    }
    let run = |name: &str| -> SimResult {
        let mut sched =
            registry::build(name, &world.cfg, None).expect("framework");
        world.run(sched.as_mut(), 42)
    };
    let helix = run("helix");
    let splitwise = run("splitwise");
    let robust = run("slit-robust");

    // from epoch 1 on the whole fleet really is flying blind
    let fleet = world.cfg.datacenters.len() as f64;
    assert!(
        robust.per_epoch[1..]
            .iter()
            .all(|r| r.ledger.signal_stale == fleet),
        "total blackout did not keep every site stale"
    );
    assert_eq!(robust.per_epoch[0].ledger.signal_fresh, fleet);

    let ro = robust.objectives();
    let ho = helix.objectives();
    let po = splitwise.objectives();
    assert!(ro.iter().all(|v| v.is_finite()));
    assert!(robust.total.requests > 0.0);
    assert!(
        !dominates(&ho, &ro),
        "total blackout: helix dominates slit-robust ({ho:?} vs {ro:?})"
    );
    assert!(
        !dominates(&po, &ro),
        "total blackout: splitwise dominates slit-robust ({po:?} vs {ro:?})"
    );
    eprintln!(
        "| total-blackout | slit-robust {ro:?} | helix {ho:?} | \
         splitwise {po:?} |"
    );
}

#[test]
fn named_scenarios_actually_change_the_world() {
    let base = pressured_config();
    let b = Scenario::Baseline.build(&base, base.epochs, 7);
    for sc in Scenario::named() {
        let w = sc.build(&base, base.epochs, 7);
        let changed = w.cfg != b.cfg
            || w.trace.epochs != b.trace.epochs
            || w.signals.ci != b.signals.ci
            || w.events != b.events;
        assert!(changed, "{} did not alter the world", sc.name());
    }
}

#[test]
fn scenario_worlds_account_all_frameworks_consistently() {
    // every framework must serve (or account as dropped) the same request
    // mass within one scenario world — even while capacity varies mid-run
    let base = pressured_config();
    for sc in [
        Scenario::RegionalOutage,
        Scenario::RollingOutage,
        Scenario::BurstyHeavyTail,
    ] {
        let world = sc.build(&base, base.epochs, 11);
        // the simulator samples round(n_req) requests per class
        let expected: f64 = world.trace.epochs[..world.cfg.epochs]
            .iter()
            .map(|e| {
                e.classes.iter().map(|c| c.n_req.round()).sum::<f64>()
            })
            .sum();
        for name in ["helix", "splitwise"] {
            let mut sched =
                registry::build(name, &world.cfg, None).expect("framework");
            let r = world.run(sched.as_mut(), 11);
            assert!(
                (r.total.requests - expected).abs() < 1e-6,
                "{}/{}: {} vs {}",
                sc.name(),
                r.name,
                r.total.requests,
                expected
            );
            assert!(r.total.e_tot_j >= r.total.e_it_j);
        }
    }
}
