//! Full-stack integration tests: simulator + schedulers + coordinator +
//! CLI wiring, at test scale. The AOT/PJRT layer has its own integration
//! suite in runtime_parity.rs.

use slit::baselines::{HelixScheduler, RoundRobinScheduler, SplitwiseScheduler};
use slit::config::{SystemConfig, N_OBJ, OBJ_CARBON, OBJ_COST, OBJ_TTFT, OBJ_WATER};
use slit::coordinator::{serve_forever, Coordinator, CoordinatorConfig};
use slit::opt::{SlitScheduler, SlitVariant};
use slit::power::GridSignals;
use slit::registry;
use slit::sim::{simulate, Scheduler, SimResult};
use slit::trace::Trace;
use slit::util::json::Json;

/// Test-scale config with enough load pressure that schedulers differ.
fn pressured_config() -> SystemConfig {
    let mut cfg = SystemConfig::small_test();
    cfg.epochs = 6;
    cfg.opt.budget_s = 1.0;
    cfg.opt.generations = 6;
    cfg.workload.base_requests_per_epoch = 1200.0;
    cfg
}

fn run(cfg: &SystemConfig, s: &mut dyn Scheduler, seed: u64) -> SimResult {
    let trace = Trace::generate(cfg, cfg.epochs, seed);
    let signals = GridSignals::generate(cfg, cfg.epochs, seed);
    simulate(cfg, &trace, &signals, s, seed)
}

#[test]
fn fig4_shape_holds_at_test_scale() {
    // the paper's qualitative claims, checked end-to-end on the discrete
    // simulator: every single-objective SLIT variant beats both baselines
    // on its own objective, by a wide margin for the sustainability axes
    let cfg = pressured_config();
    let helix = run(&cfg, &mut HelixScheduler, 42);
    let splitwise = run(&cfg, &mut SplitwiseScheduler, 42);

    let mut slit_objs: Vec<(usize, [f64; N_OBJ])> = Vec::new();
    for (variant, obj) in [
        (SlitVariant::Carbon, OBJ_CARBON),
        (SlitVariant::Water, OBJ_WATER),
        (SlitVariant::Cost, OBJ_COST),
        (SlitVariant::Ttft, OBJ_TTFT),
    ] {
        let r = run(&cfg, &mut SlitScheduler::new(&cfg, variant), 42);
        slit_objs.push((obj, r.objectives()));
    }
    let h = helix.objectives();
    let s = splitwise.objectives();
    for (obj, o) in &slit_objs {
        let (obj, o) = (*obj, *o);
        if obj == OBJ_TTFT {
            // TTFT: must at least be competitive (paper: strictly better;
            // at test scale we allow a small tolerance)
            assert!(
                o[obj] <= h[obj] * 1.05,
                "ttft vs helix: {o:?} vs {h:?}"
            );
            assert!(
                o[obj] <= s[obj] * 1.15,
                "ttft vs splitwise: {o:?} vs {s:?}"
            );
        } else {
            // sustainability axes: the scale-to-zero + grid-aware routing
            // wins must be large (paper: 95-99%)
            assert!(
                o[obj] < 0.5 * h[obj],
                "obj {obj} vs helix: {} vs {}",
                o[obj],
                h[obj]
            );
            assert!(
                o[obj] < 0.5 * s[obj],
                "obj {obj} vs splitwise: {} vs {}",
                o[obj],
                s[obj]
            );
        }
    }
}

#[test]
fn all_frameworks_serve_all_requests_or_account_drops() {
    let cfg = pressured_config();
    let total_expected: f64 = {
        let trace = Trace::generate(&cfg, cfg.epochs, 7);
        trace.epochs[..cfg.epochs]
            .iter()
            .map(|e| e.total_requests())
            .sum()
    };
    // every framework in the registry, not a hand-maintained list
    let mut frameworks: Vec<Box<dyn Scheduler>> = registry::all()
        .iter()
        .map(|spec| (spec.build)(&cfg))
        .collect();
    for f in &mut frameworks {
        let r = run(&cfg, f.as_mut(), 7);
        assert!(
            (r.total.requests - total_expected).abs() < 1e-6,
            "{}: {} requests vs expected {total_expected}",
            r.name,
            r.total.requests
        );
        assert!(r.total.dropped <= r.total.requests);
        // all ledgers physically sane
        assert!(r.total.e_tot_j >= r.total.e_it_j);
        assert!(r.total.carbon_kg > 0.0);
        assert!(r.total.water_l > 0.0);
        assert!(r.total.cost_usd > 0.0);
    }
}

#[test]
fn results_json_round_trips() {
    let cfg = pressured_config();
    let r = run(&cfg, &mut RoundRobinScheduler, 3);
    let tmp = std::env::temp_dir().join("slit_e2e_results.json");
    slit::cli::write_results_json(
        std::slice::from_ref(&r),
        tmp.to_str().unwrap(),
    )
    .unwrap();
    let j = Json::parse(&std::fs::read_to_string(&tmp).unwrap()).unwrap();
    let rr = j.get("round-robin").unwrap();
    let objectives = rr.f64_vec("objectives").unwrap();
    assert_eq!(objectives.len(), N_OBJ);
    assert!((objectives[1] - r.total.carbon_kg).abs() < 1e-9);
    let per_epoch = rr.get("per_epoch").and_then(Json::as_arr).unwrap();
    assert_eq!(per_epoch.len(), cfg.epochs);
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn coordinator_full_loop_with_tcp_clients() {
    use std::io::{BufRead, BufReader, Write};

    let mut cfg = SystemConfig::small_test();
    cfg.opt.generations = 2;
    cfg.opt.population = 8;
    let ccfg = CoordinatorConfig {
        plan_budget_s: 0.3,
        ..Default::default()
    };
    let coordinator = Coordinator::new(cfg, ccfg, None);
    let handle = serve_forever(std::sync::Arc::clone(&coordinator), 0).unwrap();

    // several concurrent clients
    std::thread::scope(|s| {
        for c in 0..4 {
            let port = handle.port;
            s.spawn(move || {
                let stream =
                    std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
                stream.set_nodelay(true).ok();
                let mut w = stream.try_clone().unwrap();
                let mut r = BufReader::new(stream);
                for i in 0..50 {
                    writeln!(
                        w,
                        "{{\"region\": {}, \"model\": {}, \"tok_in\": 64, \
                         \"tok_out\": 128}}",
                        (c + i) % 4,
                        i % 2
                    )
                    .unwrap();
                    let mut line = String::new();
                    r.read_line(&mut line).unwrap();
                    let j = Json::parse(line.trim()).unwrap();
                    assert_eq!(
                        j.get("ok").and_then(Json::as_bool),
                        Some(true)
                    );
                }
            });
        }
    });

    // epoch tick mid-flight, then check accounting
    coordinator.tick_epoch();
    let m = coordinator.metrics_snapshot();
    assert_eq!(m.served, 200);
    assert_eq!(m.plan_refreshes, 1);
    assert!(m.ledger.carbon_kg > 0.0);

    // clean shutdown over the wire
    let mut s =
        std::net::TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
    writeln!(s, "{{\"op\": \"shutdown\"}}").unwrap();
    let mut r = BufReader::new(s);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    handle.thread.join().unwrap();
    assert!(coordinator.stopped());
}

#[test]
fn failure_injection_saturated_cluster_degrades_gracefully() {
    // cluster far too small for the load: every framework must still
    // terminate, account all requests, and record drops rather than panic
    let mut cfg = pressured_config();
    for d in &mut cfg.datacenters {
        d.nodes_per_type = vec![1, 0, 0, 0, 0, 0];
    }
    // 12 single-node sites ~ 10.8k node-seconds/epoch of capacity; this
    // load needs ~10x that
    cfg.workload.base_requests_per_epoch = 200_000.0;
    let r = run(&cfg, &mut SplitwiseScheduler, 9);
    assert!(r.total.dropped > 0.0, "expected drops under saturation");
    assert!(r.total.requests > 0.0);
    assert!(r.total.mean_ttft_s() > 0.0);
}

#[test]
fn failure_injection_zero_workload_epochs() {
    let mut cfg = pressured_config();
    cfg.workload.base_requests_per_epoch = 0.0;
    let r =
        run(&cfg, &mut SlitScheduler::new(&cfg, SlitVariant::Balance), 5);
    assert_eq!(r.total.requests, 0.0);
    // idle floor still accounted (pr_off x fleet)
    assert!(r.total.e_tot_j >= 0.0);
    assert_eq!(r.per_epoch.len(), cfg.epochs);
}

#[test]
fn single_datacenter_config_works() {
    let mut cfg = pressured_config();
    cfg.datacenters.truncate(1);
    let r =
        run(&cfg, &mut SlitScheduler::new(&cfg, SlitVariant::Balance), 6);
    assert!(r.total.requests > 0.0);
    for e in &r.per_epoch {
        assert!(e.plan.is_valid());
        // everything must route to the only site
        for k in 0..e.plan.classes {
            assert!((e.plan.get(k, 0) - 1.0).abs() < 1e-9);
        }
    }
}

#[test]
fn deterministic_end_to_end() {
    let cfg = pressured_config();
    let a =
        run(&cfg, &mut SlitScheduler::new(&cfg, SlitVariant::Carbon), 11);
    let b =
        run(&cfg, &mut SlitScheduler::new(&cfg, SlitVariant::Carbon), 11);
    assert_eq!(a.total.carbon_kg, b.total.carbon_kg);
    assert_eq!(a.total.requests, b.total.requests);
    assert_eq!(a.total.ttft_sum_s, b.total.ttft_sum_s);
}
