//! Command-line launcher (hand-rolled parsing; the offline image has no
//! clap). Subcommands:
//!
//! ```text
//! slit simulate    run frameworks over a trace, print the Fig.4-style table
//! slit trace       generate the synthetic BurstGPT-like trace (Fig. 1 data)
//! slit frameworks  list the registered scheduling frameworks
//! slit scenarios   list the named workload/grid regimes
//! slit pareto      dump one epoch's Pareto front (front.json)
//! slit serve       start the online coordinator + TCP front
//! slit artifacts   check the AOT artifacts load and match the build
//! slit config      write the paper-default config as JSON
//! ```
//!
//! Framework names resolve through `crate::registry` (the single source
//! of truth); this module contains no framework string-matching.

use std::collections::BTreeMap;

use crate::config::{SystemConfig, N_OBJ, OBJ_NAMES};
use crate::coordinator::{
    format_report, run_drill, run_loadgen, serve_forever, ArrivalMode,
    Coordinator, CoordinatorConfig, DispatchPolicy, DrillClient, DrillConfig,
    LoadgenConfig,
};
use crate::opt::SlitVariant;
use crate::power::GridSignals;
use crate::registry;
use crate::runtime::{artifacts_dir, artifacts_present, Engine};
use crate::scenario::{partition_sites_by_region, Scenario, ScenarioWorld};
use crate::session::CsvEpochObserver;
use crate::sim::{Scheduler, SimResult};
use crate::trace::Trace;
use crate::util::json::Json;

/// Parsed `--flag value` / `--flag` arguments.
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> anyhow::Result<Args> {
        let command = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut flags = BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            anyhow::ensure!(
                a.starts_with("--"),
                "unexpected argument '{a}' (flags start with --)"
            );
            let key = a.trim_start_matches("--").to_string();
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(key, argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key, "true".into());
                i += 1;
            }
        }
        Ok(Args { command, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

/// Load the config per --config/--scale/--epochs/--seed flags.
pub fn load_config(args: &Args) -> anyhow::Result<SystemConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => SystemConfig::load(path)?,
        None => match args.get("scale") {
            Some("small") => SystemConfig::small_test(),
            _ => SystemConfig::paper_default(),
        },
    };
    if let Some(e) = args.get("epochs") {
        cfg.epochs = e.parse()?;
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = s.parse()?;
    }
    if let Some(b) = args.get("budget") {
        cfg.opt.budget_s = b.parse()?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// All framework names `simulate --framework` accepts (registry order).
pub fn framework_names() -> Vec<&'static str> {
    registry::names()
}

/// Instantiate a scheduler by name — a thin alias over the registry.
pub fn make_scheduler(
    name: &str,
    cfg: &SystemConfig,
    engine: Option<std::sync::Arc<Engine>>,
) -> anyhow::Result<Box<dyn Scheduler>> {
    registry::build(name, cfg, engine)
}

/// Resolve the `--scenario` flag (defaults to the untouched baseline).
pub fn load_scenario(args: &Args) -> anyhow::Result<Scenario> {
    match args.get("scenario") {
        None => Ok(Scenario::Baseline),
        Some(name) => Scenario::from_name(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown scenario '{name}' (try: {})",
                Scenario::all()
                    .iter()
                    .map(|s| s.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        }),
    }
}

/// Per-framework epoch-CSV path: `out.csv` -> `out.helix.csv` when more
/// than one framework runs (each session streams its own time series).
/// Only the file name is split, so dotted directory names stay intact.
fn epoch_csv_path(base: &str, framework: &str, multi: bool) -> String {
    if !multi {
        return base.to_string();
    }
    let (dir, file) = match base.rsplit_once('/') {
        Some((dir, file)) => (Some(dir), file),
        None => (None, base),
    };
    let suffixed = match file.rsplit_once('.') {
        Some((stem, ext)) => format!("{stem}.{framework}.{ext}"),
        None => format!("{file}.{framework}"),
    };
    match dir {
        Some(dir) => format!("{dir}/{suffixed}"),
        None => suffixed,
    }
}

/// Run every named framework over one shared scenario world, each
/// framework on its own OS thread — Fig. 4-style comparisons spend almost
/// all their wall time inside per-framework sessions that share nothing
/// but the read-only trace/signals, so they scale near-linearly with
/// cores. Each thread drives a `SimSession` with the world's scheduled
/// `ScenarioEvent`s attached (rolling outages etc. fire identically for
/// every framework). Results come back in input order, and per-framework
/// seeding matches the sequential path exactly. The one caveat: SLIT's
/// per-epoch wall-clock budget (`--budget`) is the sole time-dependent
/// input, so on a machine where concurrent frameworks contend for cores a
/// *tight* budget can truncate the search at different points than an
/// uncontended sequential run would — budget-independent schedulers are
/// bit-for-bit identical.
///
/// `epoch_csv` is `(base path, multi)`: when set, each session streams its
/// per-epoch time series to [`epoch_csv_path`]`(base, name, multi)`.
pub fn simulate_frameworks(
    world: &ScenarioWorld,
    names: &[String],
    engine: Option<std::sync::Arc<Engine>>,
    epoch_csv: Option<(&str, bool)>,
) -> anyhow::Result<Vec<SimResult>> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = names
            .iter()
            .map(|name| {
                let engine = engine.clone();
                scope.spawn(move || -> anyhow::Result<SimResult> {
                    let mut sched =
                        registry::build(name, &world.cfg, engine)?;
                    let mut session =
                        world.session(sched.as_mut(), world.cfg.seed);
                    if let Some((base, multi)) = epoch_csv {
                        let path = epoch_csv_path(base, name, multi);
                        session.add_observer(Box::new(
                            CsvEpochObserver::create(&path)?,
                        ));
                    }
                    let t = std::time::Instant::now();
                    let res = session.run();
                    eprintln!(
                        "  {name}: {:.1}s, {} requests",
                        t.elapsed().as_secs_f64(),
                        res.total.requests
                    );
                    Ok(res)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| -> anyhow::Result<SimResult> {
                h.join().map_err(|_| {
                    anyhow::anyhow!("framework simulation thread panicked")
                })?
            })
            .collect()
    })
}

/// `slit simulate` — the Fig. 4 / Fig. 5 driver. All requested frameworks
/// run concurrently over the same (optionally scenario-shaped) world.
pub fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let scenario = load_scenario(args)?;
    let engine = if args.bool("use-hlo") {
        Some(Engine::load(&artifacts_dir())?)
    } else {
        None
    };
    let which: Vec<String> = match args.get("framework") {
        None | Some("all") => {
            framework_names().iter().map(|s| s.to_string()).collect()
        }
        Some(one) => vec![one.to_string()],
    };

    let world = scenario.build(&cfg, cfg.epochs, cfg.seed);
    // the scenario transform can grow the fleet past the AOT artifact's
    // padded DC slots (global-fleet does): such worlds are analytic-only
    if engine.is_some() {
        world.cfg.validate_aot()?;
    }
    // --serial: run frameworks one at a time. With a *tight* --budget the
    // SLIT variants' wall-clock-bounded searches are sensitive to core
    // contention from concurrent runs; sequential execution reproduces the
    // uncontended paper-comparison numbers exactly.
    let serial = args.bool("serial");
    let epoch_csv = args.get("epoch-csv");
    let multi = which.len() > 1;
    eprintln!(
        "simulating {} framework(s) over {} epochs (scenario: {}{}) ...",
        which.len(),
        world.cfg.epochs,
        scenario.name(),
        if serial { ", serial" } else { "" }
    );
    // the per-framework CSV suffix decision is made once here (`multi`)
    // and applied inside simulate_frameworks, for both execution modes
    let csv = epoch_csv.map(|base| (base, multi));
    let results = if serial {
        let mut out = Vec::with_capacity(which.len());
        for name in &which {
            out.extend(simulate_frameworks(
                &world,
                std::slice::from_ref(name),
                engine.clone(),
                csv,
            )?);
        }
        out
    } else {
        simulate_frameworks(&world, &which, engine, csv)?
    };
    print_comparison(&results);

    if let Some(path) = args.get("out") {
        write_results_json(&results, path)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// `slit scenarios` — list the named workload/grid regimes, each with its
/// stressed objective, the fleet it runs on (site/region counts after the
/// regime's config transform), and its deferrable-workload shape, so rows
/// like `global-fleet` and `batch-overnight` are self-describing.
pub fn cmd_scenarios(args: &Args) -> anyhow::Result<()> {
    let base = load_config(args)?;
    // telemetry-fault schedules depend on the run length; list them for
    // the config's own horizon so the column matches what `simulate` runs
    let epochs = base.epochs;
    println!(
        "| scenario | stressed objective | sites | search | region sites | \
         deferrable | faults | description |"
    );
    println!("|---|---|---|---|---|---|---|---|");
    for s in Scenario::all() {
        let (sites, _regions) = s.fleet(&base);
        // per-region site counts + the SLIT search mode the fleet size
        // auto-selects (SlitOptions can still force either mode)
        let mut cfg = base.clone();
        s.apply_config(&mut cfg);
        let tags: Vec<usize> =
            cfg.datacenters.iter().map(|d| d.region).collect();
        let region_sites = partition_sites_by_region(&tags)
            .iter()
            .map(|(tag, members)| format!("r{}:{}", tag, members.len()))
            .collect::<Vec<_>>()
            .join(" ");
        let search = if sites >= crate::opt::REGION_DECOMPOSE_THRESHOLD {
            "region-decomposed"
        } else {
            "global"
        };
        let (frac, slack) = s.deferrable(&base);
        let deferrable = if frac > 0.0 {
            format!("{:.0}% / {} ep", 100.0 * frac, slack)
        } else {
            "-".to_string()
        };
        println!(
            "| {} | {} | {} | {} | {} | {} | {} | {} |",
            s.name(),
            OBJ_NAMES[s.target_objective()],
            sites,
            search,
            region_sites,
            deferrable,
            s.fault_summary(epochs),
            s.description()
        );
    }
    Ok(())
}

/// `slit frameworks` — list the registered scheduling frameworks.
pub fn cmd_frameworks(_args: &Args) -> anyhow::Result<()> {
    println!("| framework | aliases | paper set | description |");
    println!("|---|---|---|---|");
    for spec in registry::all() {
        println!(
            "| {} | {} | {} | {} |",
            spec.name,
            if spec.aliases.is_empty() {
                "-".to_string()
            } else {
                spec.aliases.join(", ")
            },
            if spec.in_paper_set { "yes" } else { "no" },
            spec.description
        );
    }
    Ok(())
}

/// Print the Fig. 4-style normalized comparison (norm = Splitwise when
/// present, else the first framework).
pub fn print_comparison(results: &[SimResult]) {
    if results.is_empty() {
        return;
    }
    let base_idx = results
        .iter()
        .position(|r| r.name == "splitwise")
        .unwrap_or(0);
    let base = results[base_idx].objectives();
    println!(
        "\n| framework | {} |",
        OBJ_NAMES
            .iter()
            .map(|n| format!("{n} (norm)"))
            .collect::<Vec<_>>()
            .join(" | ")
    );
    println!("|---|---|---|---|---|");
    for r in results {
        let o = r.objectives();
        let cells: Vec<String> = (0..N_OBJ)
            .map(|i| {
                let norm = if base[i] > 0.0 { o[i] / base[i] } else { 0.0 };
                format!("{:.4} ({:.3})", o[i], norm)
            })
            .collect();
        println!("| {} | {} |", r.name, cells.join(" | "));
    }
    println!("(normalized to `{}`)", results[base_idx].name);
}

/// Serialize per-framework totals + per-epoch series.
pub fn write_results_json(results: &[SimResult], path: &str) -> anyhow::Result<()> {
    let mut root = Json::obj();
    for r in results {
        let mut jr = Json::obj();
        let o = r.objectives();
        jr.set("objectives", Json::num_arr(&o));
        jr.set("requests", Json::Num(r.total.requests));
        jr.set("dropped", Json::Num(r.total.dropped));
        jr.set("energy_kwh", Json::Num(r.total.e_tot_j / 3.6e6));
        let mut series = Vec::new();
        for e in &r.per_epoch {
            series.push(Json::num_arr(&[
                e.epoch as f64,
                e.ledger.mean_ttft_s(),
                e.ledger.carbon_kg,
                e.ledger.water_l,
                e.ledger.cost_usd,
                e.decision_s,
            ]));
        }
        jr.set("per_epoch", Json::Arr(series));
        root.set(&r.name, jr);
    }
    std::fs::write(path, root.to_string_pretty())?;
    Ok(())
}

/// `slit trace` — Fig. 1 data (optionally shaped by `--scenario`).
pub fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let mut cfg = load_config(args)?;
    let scenario = load_scenario(args)?;
    // two weeks by default, like the BurstGPT window in Fig. 1
    let epochs = args.usize("epochs", 1344);
    cfg.epochs = epochs;
    let trace = scenario.build(&cfg, epochs, cfg.seed).trace;
    let out = args.get("out").unwrap_or("trace.csv");
    trace.write_csv(out)?;
    let toks = trace.tokens_per_epoch();
    let (lo, hi) = crate::util::stats::min_max(&toks);
    println!(
        "wrote {out}: {epochs} epochs, tokens/epoch min {lo:.0} max {hi:.0} \
         mean {:.0}",
        crate::util::stats::mean(&toks)
    );
    Ok(())
}

/// `slit pareto` — dump one epoch's front.
pub fn cmd_pareto(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let epoch = args.usize("epoch", 36); // mid-morning by default
    let trace = Trace::generate(&cfg, epoch + 1, cfg.seed);
    let signals = GridSignals::generate(&cfg, epoch + 1, cfg.seed);
    let (cp, dp) = crate::cluster::build_panels(
        &cfg,
        &signals,
        epoch,
        &trace.epochs[epoch],
        cfg.physics.pr_off,
    );
    let ev = crate::eval::AnalyticEvaluator::new(
        cp,
        dp,
        crate::eval::EvalConsts::from_physics(&cfg.physics),
    );
    let mut optimizer = crate::opt::SlitOptimizer::new(
        cfg.opt.clone(),
        cfg.num_classes(),
        cfg.datacenters.len(),
        cfg.seed,
    );
    let engine = if args.bool("use-hlo") {
        cfg.validate_aot()?; // oversized fleets are analytic-only
        Some(Engine::load(&artifacts_dir())?)
    } else {
        None
    };
    let outcome = match engine {
        Some(engine) => {
            let hlo =
                crate::runtime::HloPlanEvaluator::from_analytic(engine, &ev);
            optimizer.optimize(&hlo)
        }
        None => optimizer.optimize(&ev),
    };

    let mut front = Vec::new();
    for s in &outcome.archive.solutions {
        front.push(Json::num_arr(&s.obj));
    }
    let mut root = Json::obj();
    root.set("epoch", Json::Num(epoch as f64));
    root.set("objectives", Json::str_arr(&OBJ_NAMES));
    root.set("front", Json::Arr(front));
    let mut showcased = Json::obj();
    for (name, sol) in outcome.archive.showcase() {
        showcased.set(&name, Json::num_arr(&sol.obj));
    }
    root.set("showcase", showcased);
    root.set("evaluations", Json::Num(outcome.evaluations as f64));
    root.set("delta_evals", Json::Num(outcome.delta_evals as f64));
    // self-calibration: the certified per-objective lower bound for this
    // epoch's placement problem, plus how far the front's best point on
    // each axis sits from it (DESIGN.md §16)
    let mut oracle = Json::obj();
    for (obj, name) in OBJ_NAMES.iter().enumerate() {
        let bound = crate::opt::oracle::epoch_lower_bound(&ev, obj);
        let best = outcome
            .archive
            .solutions
            .iter()
            .map(|s| s.obj[obj])
            .fold(f64::INFINITY, f64::min);
        let mut o = Json::obj();
        o.set("lower_bound", Json::Num(bound.score()));
        o.set("quantization_slack", Json::Num(bound.slack));
        o.set("best_front_point", Json::Num(best));
        o.set(
            "gap_frac",
            Json::Num((best - bound.score()) / best.abs().max(1e-12)),
        );
        oracle.set(name, o);
    }
    root.set("oracle", oracle);
    // believed-signal panel for this epoch: ground truth pushed through a
    // fault-free SignalFeed. Bit-identical to truth here (no faults are
    // injected on this path) — the block documents exactly what a
    // robust-policy scheduler would have consumed (DESIGN.md §17).
    let mut feed = crate::signals::SignalFeed::new(&cfg);
    for t in 0..=epoch {
        let (ci, wi, tou) = signals.at(t);
        feed.observe(t, &ci, &wi, &tou);
    }
    let (bci, bwi, btou) =
        feed.view(crate::signals::SignalPolicy::Robust);
    let (fresh, stale, quarantined) = feed.health_counts();
    let mut sig = Json::obj();
    sig.set("policy", Json::Str("robust".into()));
    sig.set(
        "faults_injected",
        Json::Num(feed.faults_injected() as f64),
    );
    sig.set("fresh", Json::Num(fresh as f64));
    sig.set("stale", Json::Num(stale as f64));
    sig.set("quarantined", Json::Num(quarantined as f64));
    sig.set("ci", Json::num_arr(bci));
    sig.set("wue", Json::num_arr(bwi));
    sig.set("tou", Json::num_arr(btou));
    root.set("signals", sig);
    let out = args.get("out").unwrap_or("front.json");
    std::fs::write(out, root.to_string_pretty())?;
    println!(
        "wrote {out}: {} front points, {} evaluations ({} delta), {:.2}s",
        outcome.archive.len(),
        outcome.evaluations,
        outcome.delta_evals,
        outcome.wall_s
    );
    Ok(())
}

/// `slit serve` — online coordinator + TCP front.
pub fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let engine = if args.bool("use-hlo") {
        cfg.validate_aot()?; // oversized fleets are analytic-only
        Some(Engine::load(&artifacts_dir())?)
    } else {
        None
    };
    let variant_name = args.get("variant").unwrap_or("slit-balance");
    let variant = SlitVariant::all()
        .into_iter()
        .find(|v| v.name() == variant_name)
        .ok_or_else(|| anyhow::anyhow!("unknown variant '{variant_name}'"))?;
    let mut ccfg = CoordinatorConfig {
        variant,
        epoch_wall_s: args.f64("epoch-seconds", 15.0),
        plan_budget_s: args.f64("budget", 5.0),
        ..Default::default()
    };
    ccfg.batcher.policy = dispatch_policy(args)?;
    let coordinator = Coordinator::new(cfg, ccfg, engine);
    let clock = coordinator.spawn_epoch_clock();
    let handle = serve_forever(
        std::sync::Arc::clone(&coordinator),
        args.usize("port", 7070) as u16,
    )?;
    println!(
        "slit coordinator listening on 127.0.0.1:{} (backend: {}, \
         variant: {variant_name})",
        handle.port,
        coordinator.backend()
    );
    handle.thread.join().ok();
    coordinator.stop();
    clock.join().ok();
    Ok(())
}

/// `slit drill` — scripted outage drill against a running `slit serve`.
///
/// Connects to the coordinator's TCP front, darkens a region mid-serve
/// (`cluster` op), forces epoch boundaries (`tick` op), keeps traffic
/// flowing, restores, and verifies the three drill invariants: topology
/// dip, exact recovery, and request-mass conservation.
pub fn cmd_drill(args: &Args) -> anyhow::Result<()> {
    let host = args.get("host").unwrap_or("127.0.0.1").to_string();
    let port = args.usize("port", 7070) as u16;
    let dcfg = DrillConfig {
        region: args.usize("region", 2),
        frac: args.f64("frac", 0.0),
        requests_per_wave: args.usize("requests", 64),
    };
    let mut client = DrillClient::connect(&host, port)?;
    eprintln!(
        "drilling {host}:{port}: region {} scaled to {:.0}% mid-serve ...",
        dcfg.region,
        dcfg.frac * 100.0
    );
    let report = run_drill(&mut client, &dcfg)?;
    println!("| phase | live nodes |");
    println!("|---|---|");
    println!("| baseline | {:.0} |", report.baseline_nodes);
    println!("| outage | {:.0} |", report.dipped_nodes);
    println!("| restored | {:.0} |", report.recovered_nodes);
    println!(
        "traffic: sent {} served {} rejected {} | epoch {:.0} | \
         carbon {:.4} kg",
        report.sent,
        report.served,
        report.rejected,
        report.epoch,
        report.carbon_kg
    );
    report.verify()?;
    println!("drill OK: dip + recovery observed, request mass conserved");
    Ok(())
}

/// `--policy llf|fcfs` -> batch dispatch policy (LLF is the default).
fn dispatch_policy(args: &Args) -> anyhow::Result<DispatchPolicy> {
    match args.get("policy").unwrap_or("llf") {
        "llf" => Ok(DispatchPolicy::Llf),
        "fcfs" => Ok(DispatchPolicy::Fcfs),
        other => anyhow::bail!("unknown dispatch policy '{other}'"),
    }
}

/// `slit loadgen` — closed-/open-loop load against a coordinator's TCP
/// front; reports achieved req/s and RTT/TTFT percentiles. With `--serve`,
/// boots an in-process coordinator on an ephemeral port first (one
/// command = a full self-contained serve-path benchmark).
pub fn cmd_loadgen(args: &Args) -> anyhow::Result<()> {
    let mode = match args.get("mode").unwrap_or("closed") {
        "closed" => ArrivalMode::Closed,
        "open" => ArrivalMode::Open,
        other => anyhow::bail!("unknown arrival mode '{other}'"),
    };
    let mut lcfg = LoadgenConfig {
        host: args.get("host").unwrap_or("127.0.0.1").to_string(),
        port: args.usize("port", 7070) as u16,
        mode,
        conns: args.usize("conns", 8),
        requests: args.usize("requests", 2_000),
        rate_rps: args.f64("rate", 2_000.0),
        duration_s: args.f64("secs", 2.0),
        batch: args.usize("batch", 1),
        tok_in: args.usize("tok-in", 128) as u32,
        tok_out: args.usize("tok-out", 256) as u32,
        seed: args.usize("seed", 7) as u64,
    };
    let server = if args.bool("serve") {
        let mut cfg = load_config(args)?;
        cfg.opt.generations = cfg.opt.generations.min(4);
        let mut ccfg = CoordinatorConfig {
            plan_budget_s: args.f64("budget", 0.5),
            ..Default::default()
        };
        ccfg.batcher.policy = dispatch_policy(args)?;
        let c = Coordinator::new(cfg, ccfg, None);
        let handle = serve_forever(std::sync::Arc::clone(&c), 0)?;
        lcfg.host = "127.0.0.1".into();
        lcfg.port = handle.port;
        Some((c, handle))
    } else {
        None
    };
    let report = run_loadgen(&lcfg)?;
    print!("{}", format_report(&lcfg, &report));
    if let Some((c, handle)) = server {
        c.stop();
        handle.thread.join().ok();
    }
    // non-zero exit when the run violates the error budget: lost replies
    // are always fatal; non-ok replies must stay under --error-budget
    anyhow::ensure!(
        report.dropped_replies == 0,
        "{} replies never arrived",
        report.dropped_replies
    );
    let budget = args.f64("error-budget", 0.01);
    anyhow::ensure!(
        report.error_rate() <= budget,
        "error rate {:.4} exceeds budget {budget}",
        report.error_rate()
    );
    Ok(())
}

/// `slit artifacts` — verify the AOT artifacts.
pub fn cmd_artifacts(_args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(
        artifacts_present(),
        "artifacts missing at {} — run `make artifacts`",
        artifacts_dir().display()
    );
    let engine = Engine::load(&artifacts_dir())?;
    let m = &engine.manifest;
    println!(
        "artifacts OK: plan_eval P={} K={} L={}; predictor H={} F={} D={}",
        m.population, m.classes, m.dc_slots, m.window, m.features, m.lambdas
    );
    Ok(())
}

/// `slit config` — dump the default config.
pub fn cmd_config(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let out = args.get("out").unwrap_or("slit-config.json");
    cfg.save(out)?;
    println!("wrote {out}");
    Ok(())
}

pub const USAGE: &str = "\
slit — sustainable geo-distributed LLM scheduling (SLIT reproduction)

USAGE: slit <command> [flags]

COMMANDS:
  simulate    run frameworks concurrently over a trace (Fig. 4/5 driver)
              --framework all|NAME (see `slit frameworks` for the registry)
              --scenario NAME (see `slit scenarios`; e.g. outage-rolling
                               takes a region dark mid-run and restores it;
                               batch-overnight carries deferrable mass the
                               slit-shift framework time-shifts)
              --scale paper|small   --epochs N   --seed N   --out results.json
              --epoch-csv FILE (stream the per-epoch time series; one file
                                per framework when several run)
              --use-hlo (search on the AOT/PJRT artifact)   --budget S
              --serial (one framework at a time; exact timing reproducibility
                        when a tight --budget bounds the SLIT search)
  trace       write the Fig. 1 workload series  --epochs N --out trace.csv
              --scenario NAME
  frameworks  list the registered scheduling frameworks (names, aliases)
  scenarios   list the named workload/grid regimes (stressed objective,
              fleet shape, deferrable share)
  pareto      dump one epoch's Pareto front     --epoch N --out front.json
  serve       start the online coordinator      --port N --variant NAME
              --epoch-seconds F --use-hlo --policy llf|fcfs
  drill       scripted outage drill against a running `slit serve`:
              darken a region, tick, verify dip/recovery + conservation
              --host H --port N --region N --frac F --requests N
  loadgen     socket load against a coordinator  --host H --port N
              --mode closed|open --conns N --requests N (closed)
              --rate RPS --secs F (open) --batch N --policy llf|fcfs
              --serve (boot an in-process server on an ephemeral port)
              --error-budget F (non-ok share that still exits 0)
  artifacts   verify AOT artifacts load + shape-check
  config      write the resolved config         --out slit-config.json
";

/// Entry point used by main.rs.
pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "simulate" => cmd_simulate(&args),
        "trace" => cmd_trace(&args),
        "frameworks" => cmd_frameworks(&args),
        "scenarios" => cmd_scenarios(&args),
        "pareto" => cmd_pareto(&args),
        "serve" => cmd_serve(&args),
        "drill" => cmd_drill(&args),
        "loadgen" => cmd_loadgen(&args),
        "artifacts" => cmd_artifacts(&args),
        "config" => cmd_config(&args),
        "help" | "--help" | "-h" => {
            // ignore broken pipes (e.g. `slit help | head`)
            use std::io::Write;
            let _ = writeln!(std::io::stdout(), "{USAGE}");
            Ok(())
        }
        other => {
            use std::io::Write;
            let _ = writeln!(std::io::stdout(), "{USAGE}");
            anyhow::bail!("unknown command '{other}'")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_flags() {
        let a = Args::parse(&argv(
            "simulate --framework helix --epochs 4 --use-hlo",
        ))
        .unwrap();
        assert_eq!(a.command, "simulate");
        assert_eq!(a.get("framework"), Some("helix"));
        assert_eq!(a.usize("epochs", 0), 4);
        assert!(a.bool("use-hlo"));
        assert!(!a.bool("missing"));
    }

    #[test]
    fn rejects_positional_garbage() {
        assert!(Args::parse(&argv("simulate bogus")).is_err());
    }

    #[test]
    fn scheduler_factory_knows_all_names() {
        let cfg = SystemConfig::small_test();
        for name in framework_names() {
            let s = make_scheduler(name, &cfg, None).unwrap();
            assert_eq!(s.name(), name);
        }
        assert!(make_scheduler("nope", &cfg, None).is_err());
    }

    #[test]
    fn config_flags_override() {
        let a = Args::parse(&argv(
            "simulate --scale small --epochs 3 --seed 99",
        ))
        .unwrap();
        let cfg = load_config(&a).unwrap();
        assert_eq!(cfg.epochs, 3);
        assert_eq!(cfg.seed, 99);
    }

    #[test]
    fn trace_command_writes_csv() {
        let tmp = std::env::temp_dir().join("slit_cli_trace.csv");
        let a = Args::parse(&argv(&format!(
            "trace --scale small --epochs 8 --out {}",
            tmp.display()
        )))
        .unwrap();
        cmd_trace(&a).unwrap();
        assert!(tmp.exists());
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn simulate_small_single_framework() {
        let tmp = std::env::temp_dir().join("slit_cli_sim.json");
        let a = Args::parse(&argv(&format!(
            "simulate --scale small --epochs 2 --framework round-robin --out {}",
            tmp.display()
        )))
        .unwrap();
        cmd_simulate(&a).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        let j = Json::parse(&text).unwrap();
        assert!(j.get("round-robin").is_some());
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn scenario_flag_resolves_and_rejects_unknown() {
        let a = Args::parse(&argv("simulate --scenario bursty")).unwrap();
        assert_eq!(
            load_scenario(&a).unwrap(),
            Scenario::BurstyHeavyTail
        );
        let d = Args::parse(&argv("simulate")).unwrap();
        assert_eq!(load_scenario(&d).unwrap(), Scenario::Baseline);
        let bad = Args::parse(&argv("simulate --scenario nope")).unwrap();
        assert!(load_scenario(&bad).is_err());
    }

    #[test]
    fn simulate_with_scenario_runs() {
        let tmp = std::env::temp_dir().join("slit_cli_sim_scenario.json");
        let a = Args::parse(&argv(&format!(
            "simulate --scale small --epochs 2 --framework round-robin \
             --scenario outage --out {}",
            tmp.display()
        )))
        .unwrap();
        cmd_simulate(&a).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        assert!(Json::parse(&text).unwrap().get("round-robin").is_some());
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn simulate_global_fleet_runs_end_to_end_at_l48() {
        // the planet-scale scenario through the real CLI path: 48 sites,
        // spilled DcVec evaluator, SLIT searching the full fleet
        let tmp = std::env::temp_dir().join("slit_cli_global_fleet.json");
        let a = Args::parse(&argv(&format!(
            "simulate --scale small --epochs 2 --framework slit-carbon \
             --scenario global-fleet --out {}",
            tmp.display()
        )))
        .unwrap();
        cmd_simulate(&a).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        let j = Json::parse(&text).unwrap();
        let r = j.get("slit-carbon").expect("slit-carbon results");
        assert!(r.f64_or("requests", 0.0) > 0.0);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn simulate_batch_overnight_with_slit_shift() {
        // the temporal-shifting regime through the real CLI path: hourly
        // epochs, deferrable mass, the forecast-driven release policy
        let tmp = std::env::temp_dir().join("slit_cli_batch_overnight.json");
        let a = Args::parse(&argv(&format!(
            "simulate --scale small --epochs 3 --framework slit-shift \
             --scenario batch-overnight --out {}",
            tmp.display()
        )))
        .unwrap();
        cmd_simulate(&a).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        let j = Json::parse(&text).unwrap();
        let r = j.get("slit-shift").expect("slit-shift results");
        assert!(r.f64_or("requests", 0.0) > 0.0);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn parallel_framework_runs_match_sequential_results() {
        // the scoped-thread fan-out must be invisible in the numbers
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 2;
        let trace = Trace::generate(&cfg, cfg.epochs, cfg.seed);
        let signals = GridSignals::generate(&cfg, cfg.epochs, cfg.seed);
        let seed = cfg.seed;
        let world = ScenarioWorld {
            cfg,
            trace,
            signals,
            events: Vec::new(),
        };
        let names: Vec<String> = vec![
            "round-robin".into(),
            "helix".into(),
            "splitwise".into(),
        ];
        let par = simulate_frameworks(&world, &names, None, None).unwrap();
        assert_eq!(par.len(), 3);
        for (name, res) in names.iter().zip(&par) {
            let mut sched = make_scheduler(name, &world.cfg, None).unwrap();
            let seq = crate::sim::simulate(
                &world.cfg,
                &world.trace,
                &world.signals,
                sched.as_mut(),
                seed,
            );
            assert_eq!(res.name, seq.name);
            assert_eq!(res.total.requests, seq.total.requests);
            assert_eq!(res.total.carbon_kg, seq.total.carbon_kg);
            assert_eq!(res.total.ttft_sum_s, seq.total.ttft_sum_s);
        }
    }

    #[test]
    fn drill_command_runs_against_an_ephemeral_server() {
        let mut cfg = SystemConfig::small_test();
        cfg.opt.generations = 2;
        cfg.opt.population = 8;
        let ccfg = CoordinatorConfig {
            plan_budget_s: 0.2,
            ..Default::default()
        };
        let c = Coordinator::new(cfg, ccfg, None);
        let handle =
            serve_forever(std::sync::Arc::clone(&c), 0).unwrap();
        let a = Args::parse(&argv(&format!(
            "drill --port {} --requests 16",
            handle.port
        )))
        .unwrap();
        cmd_drill(&a).unwrap();
        c.stop();
    }

    #[test]
    fn scenarios_command_lists_all() {
        let a = Args::parse(&argv("scenarios")).unwrap();
        cmd_scenarios(&a).unwrap();
    }

    #[test]
    fn frameworks_command_lists_registry() {
        let a = Args::parse(&argv("frameworks")).unwrap();
        cmd_frameworks(&a).unwrap();
        // the CLI's framework vocabulary IS the registry's
        assert_eq!(framework_names(), crate::registry::names());
    }

    #[test]
    fn epoch_csv_paths_split_per_framework() {
        assert_eq!(epoch_csv_path("out.csv", "helix", false), "out.csv");
        assert_eq!(
            epoch_csv_path("out.csv", "helix", true),
            "out.helix.csv"
        );
        assert_eq!(
            epoch_csv_path("series", "slit-balance", true),
            "series.slit-balance"
        );
        // dotted directory names are left intact: only the file name splits
        assert_eq!(
            epoch_csv_path("results.v2/series", "helix", true),
            "results.v2/series.helix"
        );
        assert_eq!(
            epoch_csv_path("results.v2/series.csv", "helix", true),
            "results.v2/series.helix.csv"
        );
    }

    #[test]
    fn simulate_rolling_outage_end_to_end_with_epoch_csv() {
        let tmp = std::env::temp_dir().join("slit_cli_rolling.json");
        let csv = std::env::temp_dir().join("slit_cli_rolling.csv");
        let a = Args::parse(&argv(&format!(
            "simulate --scale small --epochs 4 --framework round-robin \
             --scenario outage-rolling --out {} --epoch-csv {}",
            tmp.display(),
            csv.display()
        )))
        .unwrap();
        cmd_simulate(&a).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        assert!(Json::parse(&text).unwrap().get("round-robin").is_some());
        // the streamed time series shows the capacity dip: epoch 1 (the
        // 4-epoch schedule darkens north-america at epochs/4 = 1) has
        // fewer live nodes than epoch 0
        let (header, rows) = crate::util::csv::read_file(&csv).unwrap();
        let col = header
            .iter()
            .position(|h| h == "nodes_total")
            .expect("nodes_total column");
        let nodes: Vec<f64> = rows
            .iter()
            .map(|r| r[col].parse::<f64>().unwrap())
            .collect();
        assert_eq!(nodes.len(), 4);
        assert!(nodes[1] < nodes[0], "no dip in csv: {nodes:?}");
        assert_eq!(nodes[2], nodes[0], "no recovery in csv: {nodes:?}");
        std::fs::remove_file(&tmp).ok();
        std::fs::remove_file(&csv).ok();
    }

    #[test]
    fn loadgen_serve_closed_loop_end_to_end() {
        // self-contained: boots an in-process coordinator on an ephemeral
        // port, drives it closed-loop, and enforces the error budget
        run(&argv(
            "loadgen --serve --scale small --mode closed --conns 2 \
             --requests 40 --batch 2 --budget 0.2",
        ))
        .unwrap();
    }

    #[test]
    fn loadgen_rejects_unknown_policy_and_mode() {
        assert!(run(&argv("loadgen --serve --scale small --policy bogus"))
            .is_err());
        assert!(run(&argv("loadgen --serve --scale small --mode sideways"))
            .is_err());
    }
}
