//! Analytic batched plan evaluator — the rust mirror of the L1/L2 AOT
//! kernel (python/compile/kernels/ref.py), arithmetic-identical.
//!
//! Used (a) as the fallback hot path when no PJRT artifacts are present,
//! (b) as the parity oracle for the HLO executable in
//! rust/tests/runtime_parity.rs, and (c) by unit tests everywhere.
//!
//! The chain is Eqs. 1-18 collapsed into closed form over an epoch: the
//! contraction `node_s[l] = sum_k a[k][l] * n_req[k] * tok[k] / thr[k][l]`
//! followed by elementwise energy -> cost/water/carbon and the TTFT
//! aggregation (see DESIGN.md §6).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::cluster::{ClassPanels, DcPanels};
use crate::config::N_OBJ;
use crate::models::{total_energy_factor, J_PER_KWH};
use crate::plan::Plan;
use crate::util::dcvec::DcVec;
use crate::util::threadpool;

/// Physics constants in the kernel's consts layout.
#[derive(Clone, Copy, Debug)]
pub struct EvalConsts {
    pub epoch_s: f64,
    pub pr_on: f64,
    pub h_water: f64,
    pub d_ratio: f64,
    pub ei_pot: f64,
    pub ei_waste: f64,
    pub k_media: f64,
    pub q_coef: f64,
    pub u_max: f64,
    pub cold_frac: f64,
}

impl EvalConsts {
    pub fn from_physics(p: &crate::config::PhysicsConfig) -> EvalConsts {
        EvalConsts {
            epoch_s: p.epoch_s,
            pr_on: p.pr_on,
            h_water: p.h_water,
            d_ratio: p.d_ratio,
            ei_pot: p.ei_pot,
            ei_waste: p.ei_waste,
            k_media: p.k_media,
            q_coef: p.q_coef,
            u_max: p.u_max,
            cold_frac: p.cold_frac,
        }
    }

    /// The AOT consts[12] vector (padded), matching shapes.CONSTS.
    pub fn to_f32_vec(&self) -> Vec<f32> {
        vec![
            self.epoch_s as f32,
            self.pr_on as f32,
            self.h_water as f32,
            self.d_ratio as f32,
            self.ei_pot as f32,
            self.ei_waste as f32,
            self.k_media as f32,
            self.q_coef as f32,
            self.u_max as f32,
            self.cold_frac as f32,
            0.0,
            0.0,
        ]
    }
}

/// Anything that can score a batch of plans against the four objectives.
/// Implemented by [`AnalyticEvaluator`] (native) and by
/// `runtime::PlanEvalEngine` (AOT HLO via PJRT).
pub trait BatchEvaluator: Sync {
    fn eval_batch(&self, plans: &[Plan]) -> Vec<[f64; N_OBJ]>;
    /// Evaluate plans given by reference — what [`MemoizedEvaluator`]
    /// forwards for its cache misses. The default clones into a contiguous
    /// owned batch for backends that need one; the analytic evaluator
    /// overrides it with a direct parallel map (zero clones).
    fn eval_refs(&self, plans: &[&Plan]) -> Vec<[f64; N_OBJ]> {
        let owned: Vec<Plan> = plans.iter().map(|&p| p.clone()).collect();
        self.eval_batch(&owned)
    }
    /// The incremental one-row rescoring interface, when this backend
    /// supports it (`None` = the SLIT neighbour search falls back to full
    /// batch evaluation through the memo cache).
    fn delta_scorer(&self) -> Option<&dyn DeltaScorer> {
        None
    }
    /// Human-readable backend name (for logs/benches).
    fn backend(&self) -> &'static str {
        "analytic"
    }
    /// Region decomposition support: a self-contained evaluator restricted
    /// to the given global site indices, whose objectives are exactly this
    /// evaluator's per-site contributions over those sites (they sum back
    /// to the global objective across a partition — see
    /// [`AnalyticEvaluator::restrict_to_sites`]). `None` means the backend
    /// cannot be sliced (AOT HLO executables have a baked fleet shape) and
    /// the decomposed SLIT search must fall back to the global walk.
    fn region_evaluator(&self, _sites: &[usize]) -> Option<AnalyticEvaluator> {
        None
    }
}

impl BatchEvaluator for AnalyticEvaluator {
    fn eval_batch(&self, plans: &[Plan]) -> Vec<[f64; N_OBJ]> {
        self.evaluate_batch(plans)
    }

    fn eval_refs(&self, plans: &[&Plan]) -> Vec<[f64; N_OBJ]> {
        threadpool::par_map(plans, |p| self.evaluate(p))
    }

    fn delta_scorer(&self) -> Option<&dyn DeltaScorer> {
        Some(self)
    }

    fn region_evaluator(&self, sites: &[usize]) -> Option<AnalyticEvaluator> {
        Some(self.restrict_to_sites(sites))
    }
}

/// Cached per-plan epoch aggregates: exactly the terms of the Eq. 1-18
/// chain that are **linear** contractions over class rows (see DESIGN.md
/// §13). A one-row move `a[k][*] -> a'[k][*]` shifts each of these by a
/// row-local amount, so a neighbour can be rescored in O(L) via
/// [`AnalyticEvaluator::evaluate_delta`] instead of the O(K*L) full
/// contraction; the nonlinear per-DC physics (energy mix, queueing) is
/// recomputed from the adjusted aggregates by `finish`.
///
/// Storage is [`DcVec`] tiles (DESIGN.md §14): fleets up to `DC_SLOTS`
/// sites keep the aggregates inline on the stack — constructing and
/// cloning them performs zero heap operations, pinned by
/// rust/tests/alloc_hotpath.rs — while larger fleets spill to heap
/// buffers sized once from the fleet and reused via
/// [`PlanAgg::copy_from`] in the search loop.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanAgg {
    /// Node-seconds demanded at each DC (Eq. 1/5 contraction).
    pub node_s: DcVec,
    /// Requests routed to each DC (drives the Eq. 4 queue term).
    pub reqs_l: DcVec,
    /// Request-weighted queue-free TTFT sum (Eqs. 2-3 + proc).
    pub t_base: f64,
}

impl PlanAgg {
    /// Zeroed aggregates for an `dcs`-site fleet (the scratch shape the
    /// SLIT search reuses per candidate via [`PlanAgg::copy_from`]).
    pub fn zeros(dcs: usize) -> PlanAgg {
        PlanAgg {
            node_s: DcVec::zeros(dcs),
            reqs_l: DcVec::zeros(dcs),
            t_base: 0.0,
        }
    }

    /// Sites this aggregate spans.
    pub fn dcs(&self) -> usize {
        self.node_s.len()
    }

    /// Overwrite with `other`'s contents, reusing any spill allocations —
    /// allocation-free for same-fleet shapes at any L (the per-candidate
    /// copy the delta rescoring loop performs).
    pub fn copy_from(&mut self, other: &PlanAgg) {
        self.node_s.copy_from(&other.node_s);
        self.reqs_l.copy_from(&other.reqs_l);
        self.t_base = other.t_base;
    }
}

/// Object-safe access to the delta-scoring core, threaded through
/// [`BatchEvaluator::delta_scorer`] so `opt::slit` can use it behind a
/// `&dyn BatchEvaluator` without knowing the backend type.
pub trait DeltaScorer: Sync {
    /// Full O(K*L) contraction of a flattened plan into its aggregates.
    fn aggregate(&self, flat: &[f64]) -> PlanAgg;
    /// Shift `agg` by the contribution change of row `k`: O(L).
    fn apply_row_delta(
        &self,
        agg: &mut PlanAgg,
        k: usize,
        old_row: &[f64],
        new_row: &[f64],
    );
    /// Per-DC physics + TTFT aggregation from the aggregates: O(L).
    fn finish(&self, agg: &PlanAgg) -> [f64; N_OBJ];
}

impl DeltaScorer for AnalyticEvaluator {
    fn aggregate(&self, flat: &[f64]) -> PlanAgg {
        AnalyticEvaluator::aggregate(self, flat)
    }

    fn apply_row_delta(
        &self,
        agg: &mut PlanAgg,
        k: usize,
        old_row: &[f64],
        new_row: &[f64],
    ) {
        AnalyticEvaluator::apply_row_delta(self, agg, k, old_row, new_row)
    }

    fn finish(&self, agg: &PlanAgg) -> [f64; N_OBJ] {
        AnalyticEvaluator::finish(self, agg)
    }
}

/// 128-bit fingerprint of a plan's exact bit pattern (two independent
/// 64-bit mixes over the f64 bits + the matrix shape). Used as the
/// memoization key: no allocation per lookup, and a collision needs both
/// halves to collide (~2^-128 per pair — negligible across the ~10^4 plans
/// one epoch's search ever touches).
pub fn plan_fingerprint(plan: &Plan) -> (u64, u64) {
    let mut h1: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis
    let mut h2: u64 = 0x9e37_79b9_7f4a_7c15;
    for &v in plan.as_slice() {
        let b = v.to_bits();
        h1 = (h1 ^ b).wrapping_mul(0x0000_0100_0000_01b3);
        h2 = (h2 ^ b.rotate_left(17)).wrapping_mul(0xff51_afd7_ed55_8ccd);
        h2 ^= h2 >> 33;
    }
    h1 ^= (plan.classes as u64) << 32 | plan.dcs as u64;
    (h1, h2)
}

/// Default shard count for [`MemoizedEvaluator`] (power of two; indexed by
/// the low bits of the fingerprint's second half).
const MEMO_SHARDS: usize = 16;

/// Memoizing wrapper around any [`BatchEvaluator`]: repeated plans (the
/// SLIT local search revisits neighbours constantly, and snap-to-vertex
/// moves regenerate identical one-hot plans) are answered from a
/// fingerprint cache instead of paying for a true evaluation. Misses are
/// forwarded to the inner evaluator **by reference** as one batch
/// ([`BatchEvaluator::eval_refs`] — no per-plan clone), so they still fan
/// out over the thread pool. The cache is fingerprint-sharded across
/// [`MEMO_SHARDS`] independent mutexes so concurrent callers (e.g.
/// `cli::simulate_frameworks` workers sharing an evaluator) don't
/// serialise on one lock. Order-preserving and — because the inner
/// evaluator is pure — bit-deterministic regardless of hit pattern,
/// shard count, or interleaving.
pub struct MemoizedEvaluator<'a> {
    inner: &'a dyn BatchEvaluator,
    shards: Vec<Mutex<HashMap<(u64, u64), [f64; N_OBJ]>>>,
    shard_mask: u64,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl<'a> MemoizedEvaluator<'a> {
    pub fn new(inner: &'a dyn BatchEvaluator) -> Self {
        Self::with_shards(inner, MEMO_SHARDS)
    }

    /// Build with an explicit shard count (rounded up to a power of two;
    /// `1` reproduces the old single-lock cache — the shard-invariant test
    /// pins that accounting is identical for any count).
    pub fn with_shards(inner: &'a dyn BatchEvaluator, shards: usize) -> Self {
        let n = shards.max(1).next_power_of_two();
        MemoizedEvaluator {
            inner,
            shards: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
            shard_mask: (n - 1) as u64,
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    #[inline]
    fn shard(&self, key: (u64, u64)) -> &Mutex<HashMap<(u64, u64), [f64; N_OBJ]>> {
        // the second fingerprint half gets the extra avalanche mix, so its
        // low bits are the best-distributed shard selector
        &self.shards[(key.1 & self.shard_mask) as usize]
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Cached answers served so far.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// True evaluations forwarded to the inner evaluator so far.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Distinct plans cached.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("memo shard").len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl BatchEvaluator for MemoizedEvaluator<'_> {
    fn backend(&self) -> &'static str {
        self.inner.backend()
    }

    fn delta_scorer(&self) -> Option<&dyn DeltaScorer> {
        // delta rescoring is cheaper than a fingerprint probe (O(L) vs the
        // O(K*L) hash of the whole matrix), so it bypasses the cache
        self.inner.delta_scorer()
    }

    fn eval_batch(&self, plans: &[Plan]) -> Vec<[f64; N_OBJ]> {
        let keys: Vec<(u64, u64)> =
            plans.iter().map(plan_fingerprint).collect();
        let mut out: Vec<Option<[f64; N_OBJ]>> = vec![None; plans.len()];
        let mut miss_idx: Vec<usize> = Vec::new();
        for (i, key) in keys.iter().enumerate() {
            let shard = self.shard(*key).lock().expect("memo shard");
            match shard.get(key) {
                Some(obj) => out[i] = Some(*obj),
                None => miss_idx.push(i),
            }
        }
        if !miss_idx.is_empty() {
            // duplicates of the same new plan within one batch evaluate
            // once: later copies resolve against the freshly filled cache
            let mut fresh: Vec<usize> = Vec::with_capacity(miss_idx.len());
            {
                let mut seen: HashSet<(u64, u64)> = HashSet::new();
                for &i in &miss_idx {
                    if seen.insert(keys[i]) {
                        fresh.push(i);
                    }
                }
            }
            let miss_refs: Vec<&Plan> =
                fresh.iter().map(|&i| &plans[i]).collect();
            let objs = self.inner.eval_refs(&miss_refs);
            for (&i, obj) in fresh.iter().zip(&objs) {
                self.shard(keys[i])
                    .lock()
                    .expect("memo shard")
                    .insert(keys[i], *obj);
                out[i] = Some(*obj);
            }
            // only in-batch duplicates of a fresh plan still need a lookup
            for &i in &miss_idx {
                if out[i].is_none() {
                    out[i] = Some(
                        *self
                            .shard(keys[i])
                            .lock()
                            .expect("memo shard")
                            .get(&keys[i])
                            .expect("missed plan just cached"),
                    );
                }
            }
            self.misses.fetch_add(fresh.len(), Ordering::Relaxed);
            self.hits
                .fetch_add(plans.len() - fresh.len(), Ordering::Relaxed);
        } else {
            self.hits.fetch_add(plans.len(), Ordering::Relaxed);
        }
        out.into_iter()
            .map(|o| o.expect("memo slot filled"))
            .collect()
    }
}

/// Epoch-bound evaluator: panels are fixed, plans vary.
#[derive(Clone, Debug)]
pub struct AnalyticEvaluator {
    pub cp: ClassPanels,
    pub dp: DcPanels,
    pub consts: EvalConsts,
    /// Precomputed per-(k,l) weights, hoisted out of the per-plan loop:
    /// wk[k*l+l'] = n_req[k] * tok[k] / thr[k][l'].
    wk_node_s: Vec<f64>,
    /// base TTFT term per (k,l) scaled by n_req[k].
    wk_ttft: Vec<f64>,
    total_req: f64,
}

impl AnalyticEvaluator {
    pub fn new(cp: ClassPanels, dp: DcPanels, consts: EvalConsts) -> Self {
        let k_n = cp.classes;
        let l_n = cp.dcs;
        let mut wk_node_s = vec![0.0; k_n * l_n];
        let mut wk_ttft = vec![0.0; k_n * l_n];
        for k in 0..k_n {
            let w = cp.n_req[k] * cp.tok_out[k];
            for l in 0..l_n {
                let i = k * l_n + l;
                wk_node_s[i] = w / cp.thr[i];
                let base = consts.cold_frac * cp.mem[k] / dp.bw[l]
                    + 2.0 * cp.hops[i] * consts.k_media
                    + cp.proc[i];
                wk_ttft[i] = cp.n_req[k] * base;
            }
        }
        let total_req = cp.n_req.iter().sum::<f64>().max(1.0);
        AnalyticEvaluator {
            cp,
            dp,
            consts,
            wk_node_s,
            wk_ttft,
            total_req,
        }
    }

    pub fn dcs(&self) -> usize {
        self.dp.dcs
    }

    pub fn classes(&self) -> usize {
        self.cp.classes
    }

    /// A self-contained evaluator over a subset of sites (the per-region
    /// subproblem of the decomposed SLIT search). Every objective is a sum
    /// of per-site terms and the TTFT denominator `total_req` depends only
    /// on the class panel (which is kept whole), so for any partition of
    /// the fleet the restricted evaluators' objectives **sum to the global
    /// objective** exactly, up to FP summation order — the property the
    /// price-coordination loop and the final canonical rescore rely on.
    pub fn restrict_to_sites(&self, sites: &[usize]) -> AnalyticEvaluator {
        let k_n = self.cp.classes;
        let l_n = self.cp.dcs;
        let l_r = sites.len();
        assert!(l_r > 0, "restrict_to_sites: empty site set");
        debug_assert!(sites.iter().all(|&s| s < l_n));
        let pick = |panel: &[f64]| -> Vec<f64> {
            sites.iter().map(|&s| panel[s]).collect()
        };
        let pick_kl = |panel: &[f64]| -> Vec<f64> {
            let mut out = Vec::with_capacity(k_n * l_r);
            for k in 0..k_n {
                let row = &panel[k * l_n..(k + 1) * l_n];
                out.extend(sites.iter().map(|&s| row[s]));
            }
            out
        };
        let cp = ClassPanels {
            classes: k_n,
            dcs: l_r,
            n_req: self.cp.n_req.clone(),
            tok_out: self.cp.tok_out.clone(),
            mem: self.cp.mem.clone(),
            thr: pick_kl(&self.cp.thr),
            proc: pick_kl(&self.cp.proc),
            hops: pick_kl(&self.cp.hops),
        };
        let dp = DcPanels {
            dcs: l_r,
            nodes: pick(&self.dp.nodes),
            tdp: pick(&self.dp.tdp),
            cop: pick(&self.dp.cop),
            tou: pick(&self.dp.tou),
            ci: pick(&self.dp.ci),
            wi: pick(&self.dp.wi),
            bw: pick(&self.dp.bw),
            unused_pr: pick(&self.dp.unused_pr),
        };
        AnalyticEvaluator::new(cp, dp, self.consts)
    }

    /// The TTFT denominator: `sum_k n_req[k]` clamped to >= 1 exactly as
    /// `finish` uses it. Public so the optimality-gap oracle
    /// (`opt::oracle`) normalises its flow-cost bound by the identical
    /// divisor — any other reconstruction would break the certified
    /// oracle <= achieved comparison at the last ulp.
    pub fn total_requests(&self) -> f64 {
        self.total_req
    }

    /// Evaluate one plan -> [ttft_s, carbon_kg, water_l, cost_usd].
    /// The O(K*L) [`AnalyticEvaluator::aggregate`] contraction followed by
    /// the O(L) [`AnalyticEvaluator::finish`] physics pass; allocation-free
    /// on fleets that fit the inline `DcVec` tile (pinned by
    /// rust/tests/alloc_hotpath.rs), two sized allocations past it.
    pub fn evaluate(&self, plan: &Plan) -> [f64; N_OBJ] {
        debug_assert_eq!(plan.classes, self.cp.classes);
        debug_assert_eq!(plan.dcs, self.dp.dcs);
        self.finish(&self.aggregate(plan.as_slice()))
    }

    /// The O(K*L) contraction over classes: fold every row's contribution
    /// into the row-separable epoch aggregates (see [`PlanAgg`]).
    pub fn aggregate(&self, a: &[f64]) -> PlanAgg {
        let k_n = self.cp.classes;
        let l_n = self.dp.dcs;
        debug_assert_eq!(a.len(), k_n * l_n);
        // the accumulators are DcVec tiles: fleets <= DC_SLOTS stay on the
        // stack (this is the hottest loop in the optimizer, and it used to
        // pay two heap allocations per plan), larger fleets spill once
        let mut agg = PlanAgg::zeros(l_n);
        let PlanAgg {
            node_s,
            reqs_l,
            t_base,
        } = &mut agg;
        let node_s = node_s.as_mut_slice();
        let reqs_l = reqs_l.as_mut_slice();
        for k in 0..k_n {
            let n_req = self.cp.n_req[k];
            let row = &a[k * l_n..(k + 1) * l_n];
            let wns = &self.wk_node_s[k * l_n..(k + 1) * l_n];
            let wtt = &self.wk_ttft[k * l_n..(k + 1) * l_n];
            for l in 0..l_n {
                node_s[l] += row[l] * wns[l];
                reqs_l[l] += row[l] * n_req;
                *t_base += row[l] * wtt[l];
            }
        }
        agg
    }

    /// Shift cached aggregates by the contribution change of row `k`
    /// (`old_row` -> `new_row`): O(L). The aggregates are linear in every
    /// row, so adding the signed difference is exact up to FP rounding —
    /// the delta-vs-full parity property test pins the drift at <= 1e-9
    /// relative over whole move sequences.
    pub fn apply_row_delta(
        &self,
        agg: &mut PlanAgg,
        k: usize,
        old_row: &[f64],
        new_row: &[f64],
    ) {
        let l_n = self.dp.dcs;
        debug_assert!(k < self.cp.classes);
        debug_assert_eq!(old_row.len(), l_n);
        debug_assert_eq!(new_row.len(), l_n);
        debug_assert_eq!(agg.dcs(), l_n);
        let n_req = self.cp.n_req[k];
        let wns = &self.wk_node_s[k * l_n..(k + 1) * l_n];
        let wtt = &self.wk_ttft[k * l_n..(k + 1) * l_n];
        let PlanAgg {
            node_s,
            reqs_l,
            t_base,
        } = agg;
        let node_s = node_s.as_mut_slice();
        let reqs_l = reqs_l.as_mut_slice();
        for l in 0..l_n {
            let d = new_row[l] - old_row[l];
            node_s[l] += d * wns[l];
            reqs_l[l] += d * n_req;
            *t_base += d * wtt[l];
        }
    }

    /// Per-DC physics + TTFT aggregation from precomputed aggregates:
    /// O(L), allocation-free. `evaluate` == `finish(aggregate(plan))`
    /// bit-for-bit.
    pub fn finish(&self, agg: &PlanAgg) -> [f64; N_OBJ] {
        let l_n = self.dp.dcs;
        debug_assert_eq!(agg.dcs(), l_n);
        let c = &self.consts;
        let node_s = agg.node_s.as_slice();
        let reqs_l = agg.reqs_l.as_slice();
        let mut cost = 0.0;
        let mut water = 0.0;
        let mut carbon = 0.0;
        let mut t_queue = 0.0;
        for l in 0..l_n {
            let nodes = self.dp.nodes[l];
            let on = (node_s[l] / c.epoch_s).min(nodes);
            let util = on / nodes.max(1.0);
            let e_it = (on * c.pr_on + (nodes - on) * self.dp.unused_pr[l])
                * self.dp.tdp[l]
                * c.epoch_s;
            let e_tot = e_it * total_energy_factor(self.dp.cop[l]);
            let e_tot_kwh = e_tot / J_PER_KWH;
            cost += e_tot_kwh * self.dp.tou[l];
            let w_e = e_it / c.h_water;
            let w_b = w_e / (1.0 - c.d_ratio);
            let w_grid = e_tot_kwh * self.dp.wi[l];
            water += w_e + w_b + w_grid;
            carbon += self.dp.ci[l] * e_tot_kwh
                + ((w_e + w_b) * c.ei_pot + w_grid * c.ei_waste)
                    * self.dp.ci[l];
            let queue = c.q_coef * util / (1.0 - util.min(c.u_max));
            t_queue += reqs_l[l] * queue;
        }
        let ttft = (agg.t_base + t_queue) / self.total_req;
        [ttft, carbon, water, cost]
    }

    /// Score a one-row move against cached base aggregates in O(L): copy
    /// the aggregates, apply the row delta, run the physics pass. The base
    /// plan's full contraction is paid once; every neighbour after that
    /// costs O(L) instead of O(K*L). The clone is allocation-free for
    /// fleets that fit the inline `DcVec` tile; hot loops over larger
    /// fleets should reuse a scratch [`PlanAgg::copy_from`] instead (as
    /// `opt::slit` does), which is heap-silent at any L.
    pub fn evaluate_delta(
        &self,
        agg: &PlanAgg,
        k: usize,
        old_row: &[f64],
        new_row: &[f64],
    ) -> [f64; N_OBJ] {
        let mut moved = agg.clone();
        self.apply_row_delta(&mut moved, k, old_row, new_row);
        self.finish(&moved)
    }

    /// Evaluate a batch of plans (parallel over plans).
    pub fn evaluate_batch(&self, plans: &[Plan]) -> Vec<[f64; N_OBJ]> {
        threadpool::par_map(plans, |p| self.evaluate(p))
    }

    /// Greedy one-hot seed plans, one per objective: route every class to
    /// the site with the lowest marginal per-token contribution to that
    /// objective. These seed the metaheuristic's initial population so the
    /// archive's extreme points start from strong vertices (memetic init
    /// on top of Algorithm 1's two extreme plans).
    pub fn greedy_seed_plans(&self) -> Vec<Plan> {
        let k_n = self.cp.classes;
        let l_n = self.dp.dcs;
        let c = &self.consts;
        let mut plans = Vec::with_capacity(N_OBJ);
        for obj in 0..N_OBJ {
            let mut plan = Plan::one_dc(k_n, l_n, 0);
            for k in 0..k_n {
                let mut best_l = 0;
                let mut best_cost = f64::INFINITY;
                for l in 0..l_n {
                    let i = k * l_n + l;
                    // per-token energy at site l for class k, J
                    let e_per_tok = self.dp.tdp[l] / self.cp.thr[i];
                    let e_tot_kwh =
                        e_per_tok * total_energy_factor(self.dp.cop[l]) / J_PER_KWH;
                    let cost = match obj {
                        crate::config::OBJ_TTFT => {
                            c.cold_frac * self.cp.mem[k] / self.dp.bw[l]
                                + 2.0 * self.cp.hops[i] * c.k_media
                                + self.cp.proc[i]
                        }
                        crate::config::OBJ_CARBON => {
                            self.dp.ci[l] * e_tot_kwh
                        }
                        crate::config::OBJ_WATER => {
                            e_per_tok / c.h_water * (1.0 + 1.0 / (1.0 - c.d_ratio))
                                + e_tot_kwh * self.dp.wi[l]
                        }
                        _ => self.dp.tou[l] * e_tot_kwh,
                    };
                    if cost < best_cost {
                        best_cost = cost;
                        best_l = l;
                    }
                }
                for l in 0..l_n {
                    plan.set(k, l, if l == best_l { 1.0 } else { 0.0 });
                }
            }
            plans.push(plan);
        }
        plans
    }

    /// Flattened f32 input panels in the AOT argument layout, padded to
    /// `slots` DC columns. Returns (cls[K*3], thr, proc, hops, dc[8*slots]).
    #[allow(clippy::type_complexity)]
    pub fn to_f32_panels(
        &self,
        slots: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let k_n = self.cp.classes;
        let l_n = self.dp.dcs;
        assert!(
            slots >= l_n,
            "fleet has {l_n} datacenters but the AOT artifact pads only \
             {slots} DC slots — AOT-gated callers must check \
             SystemConfig::validate_aot first (analytic backend is L-generic)"
        );
        let mut cls = Vec::with_capacity(k_n * 3);
        for k in 0..k_n {
            cls.push(self.cp.n_req[k] as f32);
            cls.push(self.cp.tok_out[k] as f32);
            cls.push(self.cp.mem[k] as f32);
        }
        let pad_kl = |src: &[f64], pad_value: f32| -> Vec<f32> {
            let mut out = Vec::with_capacity(k_n * slots);
            for k in 0..k_n {
                for l in 0..l_n {
                    out.push(src[k * l_n + l] as f32);
                }
                for _ in l_n..slots {
                    out.push(pad_value);
                }
            }
            out
        };
        let thr = pad_kl(&self.cp.thr, 1.0);
        let proc = pad_kl(&self.cp.proc, 0.0);
        let hops = pad_kl(&self.cp.hops, 0.0);

        let mut dc = Vec::with_capacity(8 * slots);
        let rows: [(&[f64], f32); 8] = [
            (&self.dp.nodes, 0.0),
            (&self.dp.tdp, 0.0),
            (&self.dp.cop, 1.0),
            (&self.dp.tou, 0.0),
            (&self.dp.ci, 0.0),
            (&self.dp.wi, 0.0),
            (&self.dp.bw, 1.0),
            (&self.dp.unused_pr, 0.0),
        ];
        for (row, pad) in rows {
            for l in 0..l_n {
                dc.push(row[l] as f32);
            }
            for _ in l_n..slots {
                dc.push(pad);
            }
        }
        (cls, thr, proc, hops, dc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::build_panels;
    use crate::config::{SystemConfig, OBJ_CARBON, OBJ_COST, OBJ_TTFT, OBJ_WATER};
    use crate::power::GridSignals;
    use crate::trace::Trace;
    use crate::util::propkit;
    use crate::util::rng::Rng;

    fn make_eval(unused_pr: f64) -> (SystemConfig, AnalyticEvaluator) {
        let cfg = SystemConfig::paper_default();
        let signals = GridSignals::generate(&cfg, 8, 3);
        let trace = Trace::generate(&cfg, 8, 3);
        let (cp, dp) = build_panels(&cfg, &signals, 4, &trace.epochs[4], unused_pr);
        let consts = EvalConsts::from_physics(&cfg.physics);
        let ev = AnalyticEvaluator::new(cp, dp, consts);
        (cfg, ev)
    }

    #[test]
    fn objectives_positive_and_finite() {
        let (cfg, ev) = make_eval(0.05);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let p = Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng);
            let o = ev.evaluate(&p);
            assert!(o.iter().all(|x| x.is_finite() && *x >= 0.0), "{o:?}");
            assert!(o[OBJ_TTFT] > 0.0);
            assert!(o[OBJ_CARBON] > 0.0);
        }
    }

    #[test]
    fn batch_matches_single() {
        let (cfg, ev) = make_eval(0.05);
        let mut rng = Rng::new(2);
        let plans: Vec<Plan> = (0..17)
            .map(|_| Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng))
            .collect();
        let batch = ev.evaluate_batch(&plans);
        for (p, b) in plans.iter().zip(&batch) {
            let s = ev.evaluate(p);
            for i in 0..N_OBJ {
                assert!((s[i] - b[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn greener_dc_reduces_carbon() {
        // routing everything to the lowest-CI DC must beat the highest-CI DC
        let (cfg, ev) = make_eval(0.05);
        let ci = &ev.dp.ci;
        let best = (0..ev.dcs())
            .min_by(|&a, &b| ci[a].partial_cmp(&ci[b]).unwrap())
            .unwrap();
        let worst = (0..ev.dcs())
            .max_by(|&a, &b| ci[a].partial_cmp(&ci[b]).unwrap())
            .unwrap();
        let p_best = Plan::one_dc(cfg.num_classes(), ev.dcs(), best);
        let p_worst = Plan::one_dc(cfg.num_classes(), ev.dcs(), worst);
        assert!(
            ev.evaluate(&p_best)[OBJ_CARBON]
                < ev.evaluate(&p_worst)[OBJ_CARBON]
        );
    }

    #[test]
    fn local_routing_beats_remote_ttft() {
        let (cfg, ev) = make_eval(0.3);
        // all load from region 0; route to a region-0 DC vs a region-3 DC
        let local = cfg.datacenters.iter().position(|d| d.region == 0).unwrap();
        let remote = cfg.datacenters.iter().position(|d| d.region == 3).unwrap();
        let mut cp = ev.cp.clone();
        for k in 0..cp.classes {
            if k / 2 != 0 {
                cp.n_req[k] = 0.0;
            }
        }
        let ev2 = AnalyticEvaluator::new(cp, ev.dp.clone(), ev.consts);
        let p_local = Plan::one_dc(cfg.num_classes(), ev2.dcs(), local);
        let p_remote = Plan::one_dc(cfg.num_classes(), ev2.dcs(), remote);
        assert!(
            ev2.evaluate(&p_local)[OBJ_TTFT]
                < ev2.evaluate(&p_remote)[OBJ_TTFT]
        );
    }

    #[test]
    fn idle_policy_dominates_off_policy_energy() {
        // always-warm (pr_idle) must cost/emit more than scale-to-zero
        let (cfg, ev_off) = make_eval(0.05);
        let (_, ev_idle) = make_eval(0.3);
        let p = Plan::uniform(cfg.num_classes(), ev_off.dcs());
        let off = ev_off.evaluate(&p);
        let idle = ev_idle.evaluate(&p);
        assert!(idle[OBJ_CARBON] > off[OBJ_CARBON]);
        assert!(idle[OBJ_WATER] > off[OBJ_WATER]);
        assert!(idle[OBJ_COST] > off[OBJ_COST]);
    }

    #[test]
    fn queueing_kicks_in_under_concentration() {
        // at high demand, concentrating everything on one site must raise
        // TTFT versus spreading (queue term), all else equal
        let (cfg, ev) = make_eval(0.05);
        let mut cp = ev.cp.clone();
        for k in 0..cp.classes {
            cp.n_req[k] *= 50.0; // force saturation
        }
        let ev2 = AnalyticEvaluator::new(cp, ev.dp.clone(), ev.consts);
        let spread = Plan::uniform(cfg.num_classes(), ev2.dcs());
        let single = Plan::one_dc(cfg.num_classes(), ev2.dcs(), 0);
        assert!(
            ev2.evaluate(&single)[OBJ_TTFT] > ev2.evaluate(&spread)[OBJ_TTFT]
        );
    }

    #[test]
    fn plan_mass_conservation_property() {
        // splitting a class between two DCs interpolates node-seconds:
        // objectives vary continuously, never exceed the one-DC extremes sum
        let (cfg, ev) = make_eval(0.05);
        propkit::check(
            "eval-mix-bounded",
            0xE7A1,
            64,
            |r| {
                let w = r.f64();
                (w, r.below(ev.dcs()), r.below(ev.dcs()))
            },
            |&(w, l1, l2)| {
                let k_n = cfg.num_classes();
                let mut mix = Plan::one_dc(k_n, ev.dcs(), l1);
                for k in 0..k_n {
                    mix.set(k, l1, w);
                    mix.set(k, l2, mix.get(k, l2) + (1.0 - w));
                }
                mix.normalize();
                let o = ev.evaluate(&mix);
                let o1 = ev.evaluate(&Plan::one_dc(k_n, ev.dcs(), l1));
                let o2 = ev.evaluate(&Plan::one_dc(k_n, ev.dcs(), l2));
                // energy-ish objectives are concave-bounded by extremes sum
                for i in 1..N_OBJ {
                    let hi = o1[i].max(o2[i]) + 1e-6;
                    let lo = 0.0;
                    if !(lo..=hi + o1[i].min(o2[i])).contains(&o[i]) {
                        return Err(format!("obj {i}: {} out of bounds", o[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn memoized_matches_direct_and_counts_hits() {
        let (cfg, ev) = make_eval(0.05);
        let mut rng = Rng::new(3);
        let plans: Vec<Plan> = (0..40)
            .map(|_| Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng))
            .collect();
        let memo = MemoizedEvaluator::new(&ev);
        let first = memo.eval_batch(&plans);
        let direct = ev.eval_batch(&plans);
        assert_eq!(first, direct);
        assert_eq!(memo.misses(), 40);
        assert_eq!(memo.hits(), 0);
        assert_eq!(memo.len(), 40);
        // the whole batch again: pure cache hits, identical bits
        let second = memo.eval_batch(&plans);
        assert_eq!(second, direct);
        assert_eq!(memo.misses(), 40);
        assert_eq!(memo.hits(), 40);
    }

    #[test]
    fn memoized_dedups_within_one_batch() {
        let (cfg, ev) = make_eval(0.05);
        let p = Plan::uniform(cfg.num_classes(), ev.dcs());
        let q = Plan::one_dc(cfg.num_classes(), ev.dcs(), 1);
        let batch = vec![p.clone(), q.clone(), p.clone(), q.clone(), p];
        let memo = MemoizedEvaluator::new(&ev);
        let out = memo.eval_batch(&batch);
        assert_eq!(out.len(), 5);
        assert_eq!(out[0], out[2]);
        assert_eq!(out[0], out[4]);
        assert_eq!(out[1], out[3]);
        assert_eq!(memo.misses(), 2, "duplicates must not pay twice");
        assert_eq!(memo.hits(), 3);
    }

    /// Relative error across all four objectives.
    fn rel_err(a: &[f64; N_OBJ], b: &[f64; N_OBJ]) -> f64 {
        (0..N_OBJ)
            .map(|i| (a[i] - b[i]).abs() / b[i].abs().max(1e-12))
            .fold(0.0, f64::max)
    }

    #[test]
    fn delta_matches_full_eval_for_single_row_moves() {
        let (cfg, ev) = make_eval(0.05);
        let mut rng = Rng::new(17);
        for _ in 0..50 {
            let base = Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng);
            let agg = ev.aggregate(base.as_slice());
            // finish(aggregate) must be bit-identical to evaluate
            assert_eq!(ev.finish(&agg), ev.evaluate(&base));
            let k = rng.below(cfg.num_classes());
            let to = rng.below(ev.dcs());
            let cand = base.shifted_toward(k, to, rng.range(0.1, 1.0));
            let fast =
                ev.evaluate_delta(&agg, k, base.row(k), cand.row(k));
            let full = ev.evaluate(&cand);
            assert!(
                rel_err(&fast, &full) <= 1e-9,
                "delta {fast:?} vs full {full:?}"
            );
        }
    }

    #[test]
    fn delta_parity_over_random_move_sequences_property() {
        // the tentpole invariant: maintaining aggregates incrementally
        // across whole move sequences (all four neighbour kinds) stays
        // within 1e-9 relative of a from-scratch evaluation, on every
        // objective, at every step
        let (cfg, ev) = make_eval(0.05);
        let k_n = cfg.num_classes();
        propkit::check(
            "delta-vs-full-parity",
            0xDE17A,
            40,
            |r| (Plan::random(k_n, ev.dcs(), 0.5, r), r.fork(3)),
            |(start, rng)| {
                let mut rng = rng.clone();
                let mut plan = start.clone();
                let mut agg = ev.aggregate(plan.as_slice());
                for mv in 0..12 {
                    let (next, mask) = match mv % 4 {
                        2 => {
                            let k = rng.below(k_n);
                            let to = rng.below(ev.dcs());
                            let frac = rng.range(0.2, 0.8);
                            (plan.shifted_toward(k, to, frac), 1u64 << k)
                        }
                        3 => {
                            let k = rng.below(k_n);
                            (plan.shifted_toward(k, 0, 1.0), 1u64 << k)
                        }
                        _ => plan.perturbed_tracked(0.4, &mut rng),
                    };
                    for k in 0..k_n {
                        if (mask >> k) & 1 == 1 {
                            ev.apply_row_delta(
                                &mut agg,
                                k,
                                plan.row(k),
                                next.row(k),
                            );
                        }
                    }
                    plan = next;
                    let fast = ev.finish(&agg);
                    let full = ev.evaluate(&plan);
                    let err = rel_err(&fast, &full);
                    if err > 1e-9 {
                        return Err(format!(
                            "move {mv}: rel err {err:.3e} ({fast:?} vs {full:?})"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn sharded_memo_accounting_matches_single_lock_cache() {
        // hits+misses accounting and every returned objective must be
        // identical whether the cache is one lock or 16 shards
        let (cfg, ev) = make_eval(0.05);
        let mut rng = Rng::new(23);
        let fresh: Vec<Plan> = (0..60)
            .map(|_| Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng))
            .collect();
        // batches with in-batch duplicates and cross-batch repeats
        let batches: Vec<Vec<Plan>> = vec![
            fresh[..40].to_vec(),
            fresh[20..].iter().chain(&fresh[..10]).cloned().collect(),
            vec![fresh[0].clone(), fresh[0].clone(), fresh[59].clone()],
        ];
        let single = MemoizedEvaluator::with_shards(&ev, 1);
        let sharded = MemoizedEvaluator::with_shards(&ev, 16);
        assert_eq!(single.shard_count(), 1);
        assert_eq!(sharded.shard_count(), 16);
        for batch in &batches {
            let a = single.eval_batch(batch);
            let b = sharded.eval_batch(batch);
            assert_eq!(a, b);
            assert_eq!(single.hits(), sharded.hits());
            assert_eq!(single.misses(), sharded.misses());
            assert_eq!(single.len(), sharded.len());
        }
        assert_eq!(single.misses(), 60, "one true eval per distinct plan");
    }

    #[test]
    fn eval_refs_matches_eval_batch() {
        let (cfg, ev) = make_eval(0.05);
        let mut rng = Rng::new(29);
        let plans: Vec<Plan> = (0..24)
            .map(|_| Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng))
            .collect();
        let refs: Vec<&Plan> = plans.iter().collect();
        assert_eq!(ev.eval_refs(&refs), ev.eval_batch(&plans));
        // the memoized wrapper exposes the inner delta scorer
        let memo = MemoizedEvaluator::new(&ev);
        assert!(memo.delta_scorer().is_some());
    }

    #[test]
    fn fingerprint_distinguishes_plans_and_shapes() {
        let a = Plan::uniform(4, 6);
        let b = Plan::one_dc(4, 6, 2);
        assert_ne!(plan_fingerprint(&a), plan_fingerprint(&b));
        assert_eq!(plan_fingerprint(&a), plan_fingerprint(&a.clone()));
        // same cell values, different shape
        let c = Plan::uniform(6, 4);
        assert_ne!(plan_fingerprint(&Plan::uniform(4, 6)), plan_fingerprint(&c));
        // a tiny perturbation changes the exact bit pattern
        let mut d = a.clone();
        d.set(0, 0, d.get(0, 0) + 1e-13);
        assert_ne!(plan_fingerprint(&a), plan_fingerprint(&d));
    }

    #[test]
    fn f32_panels_layout() {
        let (_, ev) = make_eval(0.05);
        let (cls, thr, proc, hops, dc) = ev.to_f32_panels(16);
        assert_eq!(cls.len(), ev.classes() * 3);
        assert_eq!(thr.len(), ev.classes() * 16);
        assert_eq!(proc.len(), ev.classes() * 16);
        assert_eq!(hops.len(), ev.classes() * 16);
        assert_eq!(dc.len(), 8 * 16);
        // padded thr slots are 1.0 (safe divisor), padded nodes are 0
        assert_eq!(thr[ev.dcs()], 1.0);
        assert_eq!(dc[ev.dcs()], 0.0);
        // cop padding row
        assert_eq!(dc[2 * 16 + ev.dcs()], 1.0);
    }
}

#[cfg(test)]
mod ledger_parity_tests {
    use super::*;
    use crate::cluster::build_panels;
    use crate::config::SystemConfig;
    use crate::models::{self, EpochLedger};
    use crate::plan::Plan;
    use crate::power::GridSignals;
    use crate::trace::Trace;

    /// The analytic evaluator must agree with the scalar Eq. 5-18 chain in
    /// `models::EpochLedger` when fed the same single-site workload — this
    /// pins the vectorised math to the per-equation implementation (which
    /// is itself pinned to the paper's formulas by models::tests).
    #[test]
    fn analytic_matches_scalar_ledger_single_site() {
        let cfg = SystemConfig::paper_default();
        let signals = GridSignals::generate(&cfg, 4, 9);
        let trace = Trace::generate(&cfg, 4, 9);
        let unused_pr = 0.2;
        let (cp, dp) = build_panels(&cfg, &signals, 2, &trace.epochs[2], unused_pr);
        let consts = EvalConsts::from_physics(&cfg.physics);
        let ev = AnalyticEvaluator::new(cp, dp, consts);

        let target = 5usize;
        let plan = Plan::one_dc(cfg.num_classes(), ev.dcs(), target);
        let got = ev.evaluate(&plan);

        // scalar reconstruction: node-seconds -> ON nodes -> ledger
        let epoch_s = cfg.physics.epoch_s;
        let l_n = ev.dcs();
        let mut node_s = 0.0;
        for k in 0..ev.classes() {
            node_s += ev.cp.n_req[k] * ev.cp.tok_out[k]
                / ev.cp.thr[k * l_n + target];
        }
        let (ci, wi, tou) = signals.at(2);
        let mut ledger = EpochLedger::default();
        for (l, _) in cfg.datacenters.iter().enumerate() {
            let nodes = ev.dp.nodes[l];
            let on = if l == target {
                (node_s / epoch_s).min(nodes)
            } else {
                0.0
            };
            let e_it = (on * cfg.physics.pr_on + (nodes - on) * unused_pr)
                * ev.dp.tdp[l]
                * epoch_s;
            ledger.add_site(
                e_it,
                ev.dp.cop[l],
                tou[l],
                cfg.physics.h_water,
                cfg.physics.d_ratio,
                wi[l],
                cfg.physics.ei_pot,
                cfg.physics.ei_waste,
                ci[l],
            );
        }
        let scale = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-9);
        assert!(
            scale(got[crate::config::OBJ_CARBON], ledger.carbon_kg) < 1e-9,
            "carbon {} vs {}",
            got[1],
            ledger.carbon_kg
        );
        assert!(scale(got[crate::config::OBJ_WATER], ledger.water_l) < 1e-9);
        assert!(scale(got[crate::config::OBJ_COST], ledger.cost_usd) < 1e-9);

        // TTFT: reconstruct Eq. 1-4 + queue for the single site
        let util = (node_s / epoch_s).min(ev.dp.nodes[target])
            / ev.dp.nodes[target];
        let queue = cfg.physics.q_coef * util
            / (1.0 - util.min(cfg.physics.u_max));
        let mut t_sum = 0.0;
        let mut n_sum = 0.0;
        for k in 0..ev.classes() {
            let i = k * l_n + target;
            let load = cfg.physics.cold_frac * ev.cp.mem[k] / ev.dp.bw[target];
            let mig = models::migration_latency_s(
                ev.cp.hops[i],
                cfg.physics.k_media,
            );
            t_sum += ev.cp.n_req[k]
                * (load + 2.0 * mig + ev.cp.proc[i] + queue);
            n_sum += ev.cp.n_req[k];
        }
        let want_ttft = t_sum / n_sum.max(1.0);
        assert!(
            scale(got[crate::config::OBJ_TTFT], want_ttft) < 1e-9,
            "ttft {} vs {}",
            got[0],
            want_ttft
        );
    }
}
