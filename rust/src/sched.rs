//! Local datacenter scheduler: weighted round-robin (extended from [27],
//! §4 "fast and fair"). Once the geo-scheduler assigns a request to a
//! site, this module places it on a node type, commits capacity, and
//! produces the request's TTFT per Eqs. 1-4.
//!
//! Weights are node-type capacity shares, so placement is proportional-
//! fair across the heterogeneous pool; the smooth-WRR current-weight
//! update keeps the order deterministic and starvation-free.

use crate::cluster::{can_serve, DcCapacity};
use crate::config::{PhysicsConfig, SystemConfig};
use crate::models;
use crate::trace::Request;

/// Smooth weighted round-robin over node types of one site.
#[derive(Clone, Debug)]
pub struct LocalScheduler {
    dc: usize,
    /// static weights = node_count x throughput(model 0) (capacity share)
    weights: Vec<f64>,
    current: Vec<f64>,
    pub capacity: DcCapacity,
}

/// Outcome of placing one request locally.
#[derive(Clone, Copy, Debug)]
pub struct Placement {
    pub node_type: usize,
    /// TTFT including load/migration/processing/queueing, seconds.
    pub ttft_s: f64,
    /// Node-seconds committed.
    pub node_s: f64,
}

impl LocalScheduler {
    pub fn new(cfg: &SystemConfig, dc: usize) -> LocalScheduler {
        let spec = &cfg.datacenters[dc];
        let weights: Vec<f64> = cfg
            .node_types
            .iter()
            .enumerate()
            .map(|(ti, nt)| {
                spec.nodes_per_type[ti] as f64 * nt.thr_tokens_s[0]
            })
            .collect();
        LocalScheduler {
            dc,
            current: vec![0.0; weights.len()],
            weights,
            capacity: DcCapacity::new(spec, cfg.physics.epoch_s),
        }
    }

    /// Reset per-epoch capacity (keeps WRR state for fairness continuity).
    pub fn new_epoch(&mut self, cfg: &SystemConfig) {
        self.capacity =
            DcCapacity::new(&cfg.datacenters[self.dc], cfg.physics.epoch_s);
    }

    /// Reset per-epoch capacity against *live* node counts (the
    /// `SimSession` path). WRR weights are recomputed so topology changes
    /// — outages, brownouts, node additions — take effect immediately;
    /// the smooth-WRR current-weight state is preserved for fairness
    /// continuity, and with unchanged counts this is bit-identical to
    /// [`LocalScheduler::new_epoch`].
    pub fn new_epoch_with(
        &mut self,
        cfg: &SystemConfig,
        nodes_per_type: &[usize],
    ) {
        self.weights = cfg
            .node_types
            .iter()
            .enumerate()
            .map(|(ti, nt)| {
                nodes_per_type[ti] as f64 * nt.thr_tokens_s[0]
            })
            .collect();
        self.capacity =
            DcCapacity::from_nodes(nodes_per_type, cfg.physics.epoch_s);
    }

    /// Smooth-WRR pick over node types that can serve `model` and still
    /// have capacity for `node_s`; returns None when the site is full.
    fn pick_type(
        &mut self,
        cfg: &SystemConfig,
        model: usize,
        node_s_for_type: impl Fn(usize) -> f64,
    ) -> Option<usize> {
        let mem = cfg.models[model].param_mem_gb;
        // smooth WRR: add weights, pick max current among feasible
        let mut best: Option<usize> = None;
        for (ti, nt) in cfg.node_types.iter().enumerate() {
            self.current[ti] += self.weights[ti];
            let feasible = can_serve(nt, mem)
                && self.capacity.remaining_s(ti) >= node_s_for_type(ti);
            if feasible
                && best
                    .map(|b| self.current[ti] > self.current[b])
                    .unwrap_or(true)
            {
                best = Some(ti);
            }
        }
        if let Some(b) = best {
            let total: f64 = self.weights.iter().sum();
            self.current[b] -= total;
        }
        best
    }

    /// Place a request that has already been routed to this site.
    ///
    /// `hops` is the router hop count from the request's origin region
    /// (Eq. 3); `warm` marks whether the model is already resident
    /// (otherwise the Eq. 2 load overhead applies).
    pub fn place(
        &mut self,
        cfg: &SystemConfig,
        req: &Request,
        hops: f64,
        warm: bool,
    ) -> Option<Placement> {
        let model = req.model();
        let spec_model = &cfg.models[model];
        let phys = &cfg.physics;

        let node_s_for_type = |ti: usize| -> f64 {
            let nt = &cfg.node_types[ti];
            req.tok_out as f64 / nt.thr_tokens_s[model].max(1e-9)
        };
        let ti = self.pick_type(cfg, model, node_s_for_type)?;
        let nt = &cfg.node_types[ti];
        let node_s = node_s_for_type(ti);
        let util_before = self.capacity.utilization(ti);
        if !self.capacity.commit(ti, node_s) {
            return None;
        }

        // Eq. 1: if the KV footprint exceeds pooled memory the request
        // pays a reassignment/load penalty (extra load overhead).
        let footprint = models::memory_footprint_gb(
            req.tok_out as f64,
            spec_model.kv_gb_per_token,
            spec_model.param_mem_gb,
        );
        let pooled = crate::cluster::pooled_mem_gb(nt);
        let overflow_penalty = if footprint > pooled {
            models::load_latency_s(
                spec_model.param_mem_gb,
                cfg.datacenters[self.dc].bw_gbs,
            )
        } else {
            0.0
        };

        let load_s = if warm {
            0.0
        } else {
            models::load_latency_s(
                spec_model.param_mem_gb,
                cfg.datacenters[self.dc].bw_gbs,
            )
        } + overflow_penalty;
        let mig_s = models::migration_latency_s(hops, phys.k_media);
        // Eq. 4: first-token processing = T_exec / N = 1 / decode rate
        let t_exec_s = req.tok_out as f64 / nt.decode_tokens_s[model].max(1e-9);
        let base_ttft =
            models::ttft_s(load_s, mig_s, t_exec_s, req.tok_out as f64);
        // queueing on the chosen pool
        let queue_s = queue_delay_s(phys, util_before);
        Some(Placement {
            node_type: ti,
            ttft_s: base_ttft + queue_s,
            node_s,
        })
    }
}

/// Utilisation-driven queueing delay (same shape the analytic evaluator
/// and the AOT kernel use).
pub fn queue_delay_s(phys: &PhysicsConfig, util: f64) -> f64 {
    phys.q_coef * util / (1.0 - util.min(phys.u_max))
}

/// Predicted first-token service time for a (site, model) pair, seconds —
/// the service term of the coordinator's Least-Laxity-First laxity
/// (laxity = SLO - queued age - this). Mirrors the Eq. 4 terms [`place`]
/// realises per request: best-case decode (T_exec/N = 1/decode rate over
/// the node types the site actually has) plus the *expected* cold-start
/// share of the Eq. 2 load latency. An estimate, not a quote: WRR may
/// pick a slower type and queueing adds on top, but LLF only needs the
/// relative urgency ordering to be right.
pub fn predicted_first_token_s(
    cfg: &SystemConfig,
    dc: usize,
    model: usize,
) -> f64 {
    let spec = &cfg.datacenters[dc];
    let mem = cfg.models[model].param_mem_gb;
    let mut best_decode = 0.0f64;
    for (ti, nt) in cfg.node_types.iter().enumerate() {
        if spec.nodes_per_type[ti] > 0 && can_serve(nt, mem) {
            best_decode = best_decode.max(nt.decode_tokens_s[model]);
        }
    }
    // a site with no feasible type is maximally slow, never negative-laxity
    // "urgent" by accident
    let exec_s = if best_decode > 0.0 {
        1.0 / best_decode
    } else {
        cfg.physics.epoch_s
    };
    exec_s
        + cfg.physics.cold_frac
            * models::load_latency_s(mem, spec.bw_gbs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::trace::Request;

    fn req(class: usize, tok_out: u32) -> Request {
        Request {
            arrival_s: 0.0,
            class,
            tok_in: 128,
            tok_out,
        }
    }

    #[test]
    fn places_and_commits_capacity() {
        let cfg = SystemConfig::small_test();
        let mut ls = LocalScheduler::new(&cfg, 0);
        let p = ls.place(&cfg, &req(0, 200), 2.0, true).unwrap();
        assert!(p.ttft_s > 0.0);
        assert!(p.node_s > 0.0);
        assert!(ls.capacity.used_s[p.node_type] > 0.0);
    }

    #[test]
    fn cold_start_pays_load_latency() {
        let cfg = SystemConfig::small_test();
        let mut a = LocalScheduler::new(&cfg, 0);
        let mut b = LocalScheduler::new(&cfg, 0);
        let warm = a.place(&cfg, &req(0, 200), 2.0, true).unwrap();
        let cold = b.place(&cfg, &req(0, 200), 2.0, false).unwrap();
        let expect = crate::models::load_latency_s(
            cfg.models[0].param_mem_gb,
            cfg.datacenters[0].bw_gbs,
        );
        assert!((cold.ttft_s - warm.ttft_s - expect).abs() < 1e-9);
    }

    #[test]
    fn wrr_spreads_over_types_proportionally() {
        let cfg = SystemConfig::small_test();
        let mut ls = LocalScheduler::new(&cfg, 0);
        let mut counts = vec![0usize; cfg.node_types.len()];
        for _ in 0..600 {
            let p = ls.place(&cfg, &req(0, 10), 2.0, true).unwrap();
            counts[p.node_type] += 1;
        }
        // every type gets traffic; higher-throughput types get more
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        let a2 = counts[0]; // a100x2 (weight low)
        let h8 = counts[5]; // h100x8 (weight high)
        assert!(h8 > a2, "{counts:?}");
    }

    #[test]
    fn saturation_returns_none() {
        let mut cfg = SystemConfig::small_test();
        for d in &mut cfg.datacenters {
            d.nodes_per_type = vec![1, 0, 0, 0, 0, 0];
        }
        cfg.physics.epoch_s = 10.0; // 10 node-seconds budget total
        let mut ls = LocalScheduler::new(&cfg, 0);
        // each 7B request with ~2700 tok/s node: 10k tokens ~ 3.7 node-s
        let mut placed = 0;
        while ls.place(&cfg, &req(0, 10_000), 0.0, true).is_some() {
            placed += 1;
            assert!(placed < 100, "never saturates");
        }
        assert!(placed >= 1);
    }

    #[test]
    fn zero_node_epoch_places_nothing_and_recovers() {
        let cfg = SystemConfig::small_test();
        let mut ls = LocalScheduler::new(&cfg, 0);
        ls.new_epoch_with(&cfg, &[0, 0, 0, 0, 0, 0]);
        assert!(ls.place(&cfg, &req(0, 200), 2.0, true).is_none());
        // restoring the baseline counts brings the site back
        ls.new_epoch_with(&cfg, &cfg.datacenters[0].nodes_per_type);
        assert!(ls.place(&cfg, &req(0, 200), 2.0, true).is_some());
    }

    #[test]
    fn queue_delay_grows_with_utilization() {
        let cfg = SystemConfig::small_test();
        let q0 = queue_delay_s(&cfg.physics, 0.0);
        let q5 = queue_delay_s(&cfg.physics, 0.5);
        let q99 = queue_delay_s(&cfg.physics, 0.99);
        assert_eq!(q0, 0.0);
        assert!(q5 > 0.0);
        assert!(q99 > 10.0 * q5);
        // clip prevents infinity
        assert!(queue_delay_s(&cfg.physics, 1.0).is_finite());
    }

    #[test]
    fn predicted_first_token_orders_models_and_stays_finite() {
        let cfg = SystemConfig::small_test();
        for dc in 0..cfg.datacenters.len() {
            let small = predicted_first_token_s(&cfg, dc, 0);
            let large = predicted_first_token_s(&cfg, dc, 1);
            assert!(small.is_finite() && small > 0.0);
            assert!(
                large > small,
                "dc {dc}: large-model first token must predict slower \
                 ({large} vs {small})"
            );
        }
        // a site stripped of every node predicts epoch-scale service, so
        // LLF never ranks an unservable site as urgent
        let mut dark = cfg.clone();
        dark.datacenters[0].nodes_per_type = vec![0; 6];
        assert!(
            predicted_first_token_s(&dark, 0, 0) >= dark.physics.epoch_s
        );
    }

    #[test]
    fn more_remote_hops_mean_higher_ttft() {
        let cfg = SystemConfig::small_test();
        let mut a = LocalScheduler::new(&cfg, 0);
        let mut b = LocalScheduler::new(&cfg, 0);
        let near = a.place(&cfg, &req(0, 200), 2.0, true).unwrap();
        let far = b.place(&cfg, &req(0, 200), 11.0, true).unwrap();
        assert!(far.ttft_s > near.ttft_s);
    }
}
