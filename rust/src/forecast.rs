//! Grid-signal forecasting: per-site, per-epoch-ahead forecasts of carbon
//! intensity (CI), water intensity (WUE), and TOU price over a
//! configurable horizon, with backtest error tracking.
//!
//! Hosted next to `predictor.rs` and built from the same ridge machinery
//! (`fit_window` / `LAMBDAS` / `WINDOW`): each (site, signal) series gets
//! a predictor *set* with `best_fit` selection, exactly like the workload
//! predictor — but extended to multi-step horizons by *iterated*
//! prediction (each forecast value is appended as pseudo-history for the
//! next step) and with the diurnal phase feature computed from the
//! absolute epoch index, so the phase stays correct past the rolling
//! window.
//!
//! The temporal-shifting layer (`opt::shift`) consumes these forecasts to
//! pick low-carbon / low-water release windows for deferrable mass; the
//! backtest (rolling MAPE vs a persistence baseline) quantifies how much
//! the forecasts can be trusted (MetaTune-style forecast-driven
//! scheduling, SNIPPETS.md snippet 1).

use std::collections::VecDeque;

use crate::config::SystemConfig;
use crate::power::GridSignals;
use crate::predictor::{fit_window, FEATURES, LAMBDAS, WINDOW};

/// Seed tweak for the synthetic "historical grid data" used to warm-start
/// a forecaster (same generator, different noise realisation).
const HIST_SEED: u64 = 0x5748_4953_54; // "WHIST"

/// Epochs per day implied by the epoch length.
pub fn epochs_per_day(epoch_s: f64) -> usize {
    ((86_400.0 / epoch_s).round() as usize).max(1)
}

/// Diurnal persistence memory for one scalar series: the last observed
/// value at each phase-of-day slot. The signal plane's fallback ladder
/// (`signals.rs`) anchors stale feeds on "yesterday, same time" — the
/// strongest single predictor for diurnal grid signals — without paying
/// for a ridge fit per site × axis. Fixed-size after construction; the
/// observe/lookup path never allocates.
#[derive(Clone, Debug)]
pub struct DiurnalRing {
    slots: Vec<f64>,
    filled: Vec<bool>,
    per_day: usize,
}

impl DiurnalRing {
    pub fn new(epochs_per_day: usize) -> DiurnalRing {
        let per_day = epochs_per_day.max(1);
        DiurnalRing {
            slots: vec![0.0; per_day],
            filled: vec![false; per_day],
            per_day,
        }
    }

    /// Record the realised value at `epoch`'s phase slot.
    pub fn observe(&mut self, epoch: usize, value: f64) {
        let i = epoch % self.per_day;
        self.slots[i] = value;
        self.filled[i] = true;
    }

    /// The last value seen at `epoch`'s phase of day, if any day has
    /// covered that slot yet.
    pub fn at_phase(&self, epoch: usize) -> Option<f64> {
        let i = epoch % self.per_day;
        self.filled[i].then(|| self.slots[i])
    }
}

/// Feature vector for predicting the value at absolute epoch `abs_t`,
/// given `y` = the most recent history (oldest first, ending at
/// `abs_t - 1`). Same layout as `predictor::features`, but lags index
/// from the *end* of the window and the diurnal phase comes from the
/// absolute epoch, so iterated multi-step forecasts keep phase alignment
/// beyond the rolling window.
fn feat(y: &[f64], abs_t: usize, scale: f64, epd: usize) -> [f64; FEATURES] {
    let lag = |d: usize| -> f64 {
        if y.len() >= d {
            y[y.len() - d] / scale
        } else {
            1.0
        }
    };
    let phase =
        2.0 * std::f64::consts::PI * (abs_t % epd) as f64 / epd as f64;
    [
        1.0,
        lag(1),
        lag(2),
        lag(3),
        lag(4),
        phase.sin(),
        phase.cos(),
        lag(epd),
    ]
}

/// Ridge predictor set for one scalar grid-signal series, with iterated
/// multi-horizon forecasting.
#[derive(Clone, Debug)]
pub struct SeriesForecaster {
    history: VecDeque<f64>,
    /// Absolute index of the next (unobserved) epoch.
    epochs_seen: usize,
    epochs_per_day: usize,
    val_err: [f64; LAMBDAS.len()],
    betas: [Option<Vec<f64>>; LAMBDAS.len()],
    scale: f64,
}

impl SeriesForecaster {
    pub fn new(epochs_per_day: usize) -> Self {
        SeriesForecaster {
            history: VecDeque::with_capacity(WINDOW + 1),
            epochs_seen: 0,
            epochs_per_day,
            val_err: [0.0; LAMBDAS.len()],
            betas: [const { None }; LAMBDAS.len()],
            scale: 1.0,
        }
    }

    /// Record a realised value and refit the set (scores the one-step
    /// validation error of each member first, as the workload predictor
    /// does).
    pub fn observe(&mut self, value: f64) {
        let y: Vec<f64> = self.history.iter().copied().collect();
        for (i, beta) in self.betas.iter().enumerate() {
            if let Some(beta) = beta {
                let x =
                    feat(&y, self.epochs_seen, self.scale, self.epochs_per_day);
                let pred: f64 =
                    x.iter().zip(beta).map(|(a, b)| a * b).sum::<f64>()
                        * self.scale;
                self.val_err[i] =
                    0.8 * self.val_err[i] + 0.2 * (pred - value).abs();
            }
        }
        self.absorb(value);
        self.refit();
    }

    /// Push a value without refitting — bulk warm-up path; call
    /// [`SeriesForecaster::refit`] once afterwards.
    pub fn absorb(&mut self, value: f64) {
        self.history.push_back(value);
        if self.history.len() > WINDOW {
            self.history.pop_front();
        }
        self.epochs_seen += 1;
    }

    /// Refit all set members on the current window.
    pub fn refit(&mut self) {
        let y: Vec<f64> = self.history.iter().copied().collect();
        if y.len() < 8 {
            return;
        }
        self.scale = (y.iter().sum::<f64>() / y.len() as f64).max(1e-9);
        let base = self.epochs_seen - y.len(); // absolute epoch of y[0]
        let mut xs = Vec::with_capacity(y.len());
        let mut ys = Vec::with_capacity(y.len());
        for t in 5..y.len() {
            xs.push(feat(&y[..t], base + t, self.scale, self.epochs_per_day));
            ys.push(y[t] / self.scale);
        }
        for (i, &lam) in LAMBDAS.iter().enumerate() {
            let (beta, _) = fit_window(&xs, &ys, lam);
            self.betas[i] = Some(beta);
        }
    }

    fn best_fit(&self) -> usize {
        self.val_err
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Iterated forecast of the next `horizon` epochs (>= 0 each). Falls
    /// back to persistence until enough history exists for a fit.
    pub fn forecast(&self, horizon: usize) -> Vec<f64> {
        let beta = self.betas[self.best_fit()].clone();
        let mut y: Vec<f64> = self.history.iter().copied().collect();
        let mut out = Vec::with_capacity(horizon);
        for h in 0..horizon {
            let v = match &beta {
                Some(b) => {
                    let x = feat(
                        &y,
                        self.epochs_seen + h,
                        self.scale,
                        self.epochs_per_day,
                    );
                    (x.iter().zip(b).map(|(a, c)| a * c).sum::<f64>()
                        * self.scale)
                        .max(0.0)
                }
                None => y.last().copied().unwrap_or(0.0),
            };
            out.push(v);
            y.push(v);
        }
        out
    }

    /// Persistence (last-value) baseline over the same horizon.
    pub fn persistence(&self, horizon: usize) -> Vec<f64> {
        vec![self.history.back().copied().unwrap_or(0.0); horizon]
    }

    pub fn history_len(&self) -> usize {
        self.history.len()
    }
}

/// One fleet-wide forecast: `[site][h]` values for epochs
/// `now + 1 ..= now + horizon`.
#[derive(Clone, Debug, Default)]
pub struct GridForecast {
    pub ci: Vec<Vec<f64>>,
    pub wi: Vec<Vec<f64>>,
    pub tou: Vec<Vec<f64>>,
}

/// Rolling backtest of forecast quality vs the persistence baseline, as
/// MAPE over every (site, signal, horizon-step) cell scored so far.
#[derive(Clone, Copy, Debug, Default)]
pub struct ForecastBacktest {
    pub model_ape_sum: f64,
    pub persistence_ape_sum: f64,
    pub samples: usize,
}

impl ForecastBacktest {
    pub fn model_mape(&self) -> f64 {
        self.model_ape_sum / self.samples.max(1) as f64
    }

    pub fn persistence_mape(&self) -> f64 {
        self.persistence_ape_sum / self.samples.max(1) as f64
    }
}

/// A forecast snapshot retained for backtesting: made after observing
/// epoch `made_after`, covering epochs `made_after + 1 ..= + horizon`.
#[derive(Clone, Debug)]
struct Pending {
    made_after: usize,
    model: GridForecast,
    persist: GridForecast,
}

/// Per-site CI / WUE / TOU forecaster over a configurable horizon.
#[derive(Clone, Debug)]
pub struct GridForecaster {
    ci: Vec<SeriesForecaster>,
    wi: Vec<SeriesForecaster>,
    tou: Vec<SeriesForecaster>,
    horizon: usize,
    epochs_seen: usize,
    pending: VecDeque<Pending>,
    backtest: ForecastBacktest,
}

impl GridForecaster {
    pub fn new(cfg: &SystemConfig, horizon: usize) -> Self {
        let epd = epochs_per_day(cfg.physics.epoch_s);
        let sites = cfg.datacenters.len();
        let mk = || -> Vec<SeriesForecaster> {
            (0..sites).map(|_| SeriesForecaster::new(epd)).collect()
        };
        GridForecaster {
            ci: mk(),
            wi: mk(),
            tou: mk(),
            horizon: horizon.max(1),
            epochs_seen: 0,
            pending: VecDeque::new(),
            backtest: ForecastBacktest::default(),
        }
    }

    /// A forecaster pre-trained on `warmup_days` of synthetic historical
    /// grid data from the same generator (different noise realisation) —
    /// the stand-in for the grid-history archive a real deployment would
    /// bootstrap from. Deterministic per config seed.
    pub fn warmed(
        cfg: &SystemConfig,
        warmup_days: usize,
        horizon: usize,
    ) -> Self {
        let mut f = GridForecaster::new(cfg, horizon);
        let epd = epochs_per_day(cfg.physics.epoch_s);
        let epochs = warmup_days.max(1) * epd;
        let hist = GridSignals::generate(cfg, epochs, cfg.seed ^ HIST_SEED);
        // bulk-absorb with one final refit (cheap), then run the last few
        // epochs through the full observe path so val_err has real
        // one-step scores before best_fit selection goes live
        let live_tail = 8.min(epochs);
        for t in 0..epochs - live_tail {
            let (ci, wi, tou) = hist.at(t);
            f.absorb_epoch(&ci, &wi, &tou);
        }
        f.refit();
        for t in epochs - live_tail..epochs {
            let (ci, wi, tou) = hist.at(t);
            f.observe(&ci, &wi, &tou);
        }
        // warm-up history is not part of the live backtest
        f.pending.clear();
        f.backtest = ForecastBacktest::default();
        f
    }

    fn absorb_epoch(&mut self, ci: &[f64], wi: &[f64], tou: &[f64]) {
        for (l, f) in self.ci.iter_mut().enumerate() {
            f.absorb(ci[l]);
        }
        for (l, f) in self.wi.iter_mut().enumerate() {
            f.absorb(wi[l]);
        }
        for (l, f) in self.tou.iter_mut().enumerate() {
            f.absorb(tou[l]);
        }
        self.epochs_seen += 1;
    }

    fn refit(&mut self) {
        for f in self
            .ci
            .iter_mut()
            .chain(self.wi.iter_mut())
            .chain(self.tou.iter_mut())
        {
            f.refit();
        }
    }

    /// Record one epoch of realised signals: scores pending forecasts
    /// against the realisation (backtest), then updates every series and
    /// retains a fresh snapshot for future scoring.
    pub fn observe(&mut self, ci: &[f64], wi: &[f64], tou: &[f64]) {
        // score every live snapshot's cell for this epoch
        let now = self.epochs_seen;
        for p in &self.pending {
            let h = now - p.made_after - 1;
            if h >= self.horizon {
                continue;
            }
            let score = |fc: &[Vec<f64>], actual: &[f64], sum: &mut f64| {
                for (l, a) in actual.iter().enumerate() {
                    *sum += (fc[l][h] - a).abs() / a.abs().max(1e-9);
                }
            };
            score(&p.model.ci, ci, &mut self.backtest.model_ape_sum);
            score(&p.model.wi, wi, &mut self.backtest.model_ape_sum);
            score(&p.model.tou, tou, &mut self.backtest.model_ape_sum);
            score(&p.persist.ci, ci, &mut self.backtest.persistence_ape_sum);
            score(&p.persist.wi, wi, &mut self.backtest.persistence_ape_sum);
            score(&p.persist.tou, tou, &mut self.backtest.persistence_ape_sum);
            self.backtest.samples += 3 * ci.len();
        }
        while self
            .pending
            .front()
            .is_some_and(|p| now - p.made_after >= self.horizon)
        {
            self.pending.pop_front();
        }

        for (l, f) in self.ci.iter_mut().enumerate() {
            f.observe(ci[l]);
        }
        for (l, f) in self.wi.iter_mut().enumerate() {
            f.observe(wi[l]);
        }
        for (l, f) in self.tou.iter_mut().enumerate() {
            f.observe(tou[l]);
        }
        self.epochs_seen += 1;

        self.pending.push_back(Pending {
            made_after: self.epochs_seen - 1,
            model: self.forecast(),
            persist: GridForecast {
                ci: self.ci.iter().map(|f| f.persistence(self.horizon)).collect(),
                wi: self.wi.iter().map(|f| f.persistence(self.horizon)).collect(),
                tou: self
                    .tou
                    .iter()
                    .map(|f| f.persistence(self.horizon))
                    .collect(),
            },
        });
    }

    /// Forecast all three signals for every site over the configured
    /// horizon (epochs `now + 1 ..= now + horizon`).
    pub fn forecast(&self) -> GridForecast {
        GridForecast {
            ci: self.ci.iter().map(|f| f.forecast(self.horizon)).collect(),
            wi: self.wi.iter().map(|f| f.forecast(self.horizon)).collect(),
            tou: self.tou.iter().map(|f| f.forecast(self.horizon)).collect(),
        }
    }

    pub fn horizon(&self) -> usize {
        self.horizon
    }

    pub fn epochs_seen(&self) -> usize {
        self.epochs_seen
    }

    pub fn backtest(&self) -> ForecastBacktest {
        self.backtest
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool;

    fn hourly_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::small_test();
        cfg.physics.epoch_s = 3600.0; // 24 epochs/day
        cfg
    }

    #[test]
    fn synthetic_diurnal_series_beats_persistence_at_horizon() {
        // a clean diurnal curve: persistence at half-day horizons is
        // maximally wrong; the phase-featured ridge set must beat it
        let epd = 24usize;
        let curve = |t: usize| -> f64 {
            let ph = 2.0 * std::f64::consts::PI * (t % epd) as f64 / epd as f64;
            1.0 + 0.4 * ph.sin() + 0.15 * (2.0 * ph).cos()
        };
        let mut f = SeriesForecaster::new(epd);
        for t in 0..3 * epd {
            f.observe(curve(t));
        }
        let horizon = epd;
        let fc = f.forecast(horizon);
        let pers = f.persistence(horizon);
        let mape = |xs: &[f64]| -> f64 {
            xs.iter()
                .enumerate()
                .map(|(h, &v)| {
                    let a = curve(3 * epd + h);
                    (v - a).abs() / a
                })
                .sum::<f64>()
                / horizon as f64
        };
        let (m, p) = (mape(&fc), mape(&pers));
        assert!(m < p, "model mape {m} not better than persistence {p}");
        assert!(m < 0.10, "model mape too high on a clean curve: {m}");
    }

    #[test]
    fn grid_backtest_beats_persistence_on_generated_signals() {
        let cfg = hourly_cfg();
        let epd = epochs_per_day(cfg.physics.epoch_s);
        let signals = GridSignals::generate(&cfg, 4 * epd, 17);
        let mut f = GridForecaster::new(&cfg, epd);
        for t in 0..signals.epochs() {
            let (ci, wi, tou) = signals.at(t);
            f.observe(&ci, &wi, &tou);
        }
        let bt = f.backtest();
        assert!(bt.samples > 0);
        assert!(
            bt.model_mape() < bt.persistence_mape(),
            "model {} vs persistence {}",
            bt.model_mape(),
            bt.persistence_mape()
        );
    }

    #[test]
    fn forecasts_deterministic_across_thread_counts() {
        let cfg = hourly_cfg();
        let epd = epochs_per_day(cfg.physics.epoch_s);
        let signals = GridSignals::generate(&cfg, 2 * epd, 5);
        let run = || -> GridForecast {
            let mut f = GridForecaster::new(&cfg, epd);
            for t in 0..signals.epochs() {
                let (ci, wi, tou) = signals.at(t);
                f.observe(&ci, &wi, &tou);
            }
            f.forecast()
        };
        threadpool::set_thread_override(1);
        let a = run();
        threadpool::set_thread_override(8);
        let b = run();
        threadpool::set_thread_override(0);
        for (x, y) in a.ci.iter().flatten().zip(b.ci.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.tou.iter().flatten().zip(b.tou.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn diurnal_ring_remembers_yesterdays_phase() {
        let mut r = DiurnalRing::new(24);
        assert_eq!(r.at_phase(5), None);
        for t in 0..24 {
            r.observe(t, t as f64);
        }
        // next day, same phase: yesterday's value
        assert_eq!(r.at_phase(24 + 5), Some(5.0));
        r.observe(24 + 5, 99.0);
        assert_eq!(r.at_phase(48 + 5), Some(99.0));
        // unvisited phases of a partial day stay empty
        let mut p = DiurnalRing::new(24);
        p.observe(3, 1.0);
        assert_eq!(p.at_phase(27), Some(1.0));
        assert_eq!(p.at_phase(28), None);
    }

    #[test]
    fn warmed_forecaster_starts_trained_and_is_deterministic() {
        let cfg = hourly_cfg();
        let epd = epochs_per_day(cfg.physics.epoch_s);
        let a = GridForecaster::warmed(&cfg, 2, epd);
        let b = GridForecaster::warmed(&cfg, 2, epd);
        assert_eq!(a.epochs_seen(), 2 * epd);
        let (fa, fb) = (a.forecast(), b.forecast());
        assert_eq!(fa.ci.len(), cfg.datacenters.len());
        assert_eq!(fa.ci[0].len(), epd);
        for (x, y) in fa.ci.iter().flatten().zip(fb.ci.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // trained: forecast over a day is not the flat persistence line
        let spread = fa.ci[0]
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max)
            - fa.ci[0].iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread > 1e-6, "warmed forecast is flat");
    }
}
