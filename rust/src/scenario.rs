//! Named workload/grid regimes — the scenario suite behind the ROADMAP's
//! "as many scenarios as you can imagine" mandate.
//!
//! A [`Scenario`] is a deterministic transform over the experiment world:
//! it adjusts the [`SystemConfig`] before generation (workload knobs, site
//! capacity, water/cooling parameters) and reshapes the generated
//! [`Trace`] / [`GridSignals`] through the hooks `trace::Trace::
//! scale_epoch` and `power::GridSignals::scale_window`. Every regime is
//! seeded, so scenario runs are exactly reproducible and comparable across
//! frameworks.
//!
//! Regimes that need *time-varying capacity* additionally schedule
//! [`ScenarioEvent`]s, which a [`SimSession`] applies to its mutable
//! `ClusterState` mid-run — something the static config transform cannot
//! express.
//!
//! Regimes can also schedule *telemetry* faults
//! ([`crate::signals::SignalFault`] via `ClusterAction::Signal`): the
//! cluster ignores them, but the session's [`crate::signals::SignalFeed`]
//! distorts what schedulers believe about the grid while the ledger keeps
//! accounting against ground truth.
//!
//! The twelve named regimes (plus the untouched baseline):
//!   * `diurnal` — sharpened day/night demand swing, no bursts: the
//!     follow-the-sun routing case (cf. Fig. 1's diurnal trend).
//!   * `bursty` — heavy-tailed demand spikes on top of frequent bursts:
//!     the BurstGPT "intensity changes rapidly" trend, exaggerated.
//!   * `outage` — a whole region's datacenters lose 90% of their nodes
//!     while its users keep sending traffic: forced cross-region failover.
//!   * `outage-rolling` — the same region goes *fully* dark partway
//!     through the run and is restored N epochs later (event-driven).
//!   * `carbon-spike` — the cleanest grids suffer a mid-window carbon
//!     event (wind lull / coal backup): carbon-aware routing must re-plan
//!     away from its favourite sites.
//!   * `water-summer` — drought summer: grid water intensity triples and
//!     cooling COP degrades everywhere, stressing the water objective.
//!   * `global-fleet` — the planet-scale case past the old 16-site
//!     ceiling: 48 sites generated from 8 per-zone grid templates (two
//!     geographic zones per routing region), with diverse CI/WUE/TOU
//!     profiles. Exercises the L-generic `DcVec` evaluator path end to
//!     end (DESIGN.md §14); analytic-only — the fleet exceeds the AOT
//!     artifact's `DC_SLOTS` padding.
//!   * `batch-overnight` — hourly epochs and a 40% deferrable batch share
//!     with ~14h deadlines: the temporal-shifting regime the `slit-shift`
//!     framework (forecast-driven deferral, DESIGN.md §15) is built for.
//!   * `feed-blackout` — western-europe's grid telemetry goes dark for a
//!     quarter of the horizon while its true carbon intensity spikes:
//!     fault-blind routers keep chasing stockholm's stale clean readings.
//!   * `stale-creep` — feeds freeze one by one (cleanest magnets first)
//!     until only north-america reports fresh data, while the frozen
//!     clean sites' true CI climbs in the second half. The `slit-robust`
//!     fallback ladder (DESIGN.md §17) is built for these two.
//!   * `edge-fleet-256` / `edge-fleet-512` — the same 8 zone templates
//!     stamped at 32 and 64 sites per zone: the 256/512-site fleets the
//!     region-decomposed SLIT search (DESIGN.md §18) exists for. Past the
//!     auto-decomposition threshold, SLIT runs price-coordinated
//!     per-region subsearches instead of the global walk.

use crate::cluster::ClusterAction;
use crate::config::{
    DatacenterSpec, SystemConfig, OBJ_CARBON, OBJ_COST, OBJ_WATER,
};
use crate::power::GridSignals;
use crate::session::{ScenarioEvent, SimSession};
use crate::signals::SignalFault;
use crate::sim::{Scheduler, SimResult};
use crate::trace::Trace;
use crate::util::rng::Rng;

/// The region taken down by [`Scenario::RegionalOutage`] (north-america:
/// the largest origin share in the paper's region mix).
pub const OUTAGE_REGION: usize = 2;

/// Fraction of nodes that survive the outage at affected sites.
pub const OUTAGE_SURVIVING_FRAC: f64 = 0.1;

/// The region whose telemetry feed goes dark in [`Scenario::FeedBlackout`]
/// (western-europe: home of the fleet's cleanest site, stockholm — the
/// magnet a fault-blind carbon router keeps chasing on stale readings).
pub const FEED_BLACKOUT_REGION: usize = 3;

/// Truth carbon-intensity multiplier inside the blackout window: big
/// enough that the stale-believed clean sites are genuinely dirty
/// (stockholm 0.03 → 0.30, past oregon's 0.11) while the feed is dark.
pub const FEED_BLACKOUT_CI_MULT: f64 = 10.0;

/// The region whose feeds stay fresh under [`Scenario::StaleCreep`]
/// (north-america: oregon is the genuinely-clean refuge a robust router
/// can still verify while everything else freezes).
pub const STALE_FRESH_REGION: usize = 2;

/// Truth CI multiplier applied, over the second half of the horizon, to
/// the frozen clean magnets (`ci_base <` [`STALE_CLEAN_CI_CEILING`]
/// outside the fresh region): stockholm 0.03 → 0.18, auckland 0.09 →
/// 0.54 — both dirtier than fresh oregon's 0.11.
pub const STALE_CREEP_CI_MULT: f64 = 6.0;

/// `ci_base` ceiling below which a frozen site counts as a "clean magnet"
/// for [`Scenario::StaleCreep`]'s truth rotation.
pub const STALE_CLEAN_CI_CEILING: f64 = 0.15;

/// Paper-layout site indices outside [`STALE_FRESH_REGION`], cleanest
/// first, frozen in creeping order by [`Scenario::StaleCreep`]. The feed
/// ignores indices past a smaller custom fleet, so the fixed table
/// degrades gracefully.
pub const STALE_CREEP_SITES: [usize; 9] = [11, 5, 9, 10, 0, 1, 2, 3, 4];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// The untouched paper setup.
    Baseline,
    /// Sharpened diurnal demand, bursts disabled.
    Diurnal,
    /// Heavy-tailed burst spikes on top of a high burst rate.
    BurstyHeavyTail,
    /// One region's sites lose 90% of capacity; demand unchanged.
    RegionalOutage,
    /// One region goes fully dark mid-run and comes back N epochs later
    /// (time-varying capacity via `ScenarioEvent`s).
    RollingOutage,
    /// Mid-window carbon-intensity spike on the cleanest grids.
    CarbonSpike,
    /// Drought summer: high water intensity, degraded cooling COP.
    WaterStressedSummer,
    /// Planet-scale fleet: 48 sites from 8 per-zone grid templates — the
    /// regime that breaks the 16-datacenter ceiling.
    GlobalFleet,
    /// Hourly epochs with a large deferrable (batch/embedding/eval) share
    /// carrying overnight deadlines — the temporal-shifting regime
    /// (`slit-shift` is the framework built for it).
    BatchOvernight,
    /// Western-europe's telemetry feed goes dark for a quarter of the
    /// horizon while its true CI spikes (telemetry fault via
    /// `ClusterAction::Signal`; capacity untouched).
    FeedBlackout,
    /// Feeds freeze one by one — cleanest magnets first — until only
    /// north-america reports fresh data; the frozen clean sites' true CI
    /// climbs in the second half.
    StaleCreep,
    /// 256-site fleet (32 sites per zone template): past the
    /// region-decomposition threshold, so SLIT auto-selects the
    /// price-coordinated per-region search.
    EdgeFleet256,
    /// 512-site fleet (64 sites per zone template): the largest stamped
    /// regime, stressing region-decomposed search throughput.
    EdgeFleet512,
}

/// A generated experiment world: config + matching trace, grid signals,
/// and the mid-run cluster mutations the regime schedules.
pub struct ScenarioWorld {
    pub cfg: SystemConfig,
    pub trace: Trace,
    pub signals: GridSignals,
    pub events: Vec<ScenarioEvent>,
}

impl ScenarioWorld {
    /// Open a streaming session over this world for one framework —
    /// scheduled [`ScenarioEvent`]s attached, ready for observers.
    pub fn session<'a>(
        &'a self,
        scheduler: &'a mut dyn Scheduler,
        seed: u64,
    ) -> SimSession<'a> {
        SimSession::new(&self.cfg, &self.trace, &self.signals, scheduler, seed)
            .with_events(self.events.clone())
    }

    /// Run one framework over this world end-to-end (events included).
    pub fn run(&self, scheduler: &mut dyn Scheduler, seed: u64) -> SimResult {
        self.session(scheduler, seed).run()
    }
}

impl Scenario {
    /// Every scenario including the baseline.
    pub fn all() -> [Scenario; 13] {
        [
            Scenario::Baseline,
            Scenario::Diurnal,
            Scenario::BurstyHeavyTail,
            Scenario::RegionalOutage,
            Scenario::RollingOutage,
            Scenario::CarbonSpike,
            Scenario::WaterStressedSummer,
            Scenario::GlobalFleet,
            Scenario::BatchOvernight,
            Scenario::FeedBlackout,
            Scenario::StaleCreep,
            Scenario::EdgeFleet256,
            Scenario::EdgeFleet512,
        ]
    }

    /// The named non-baseline regimes (the scenario-matrix set).
    pub fn named() -> [Scenario; 12] {
        [
            Scenario::Diurnal,
            Scenario::BurstyHeavyTail,
            Scenario::RegionalOutage,
            Scenario::RollingOutage,
            Scenario::CarbonSpike,
            Scenario::WaterStressedSummer,
            Scenario::GlobalFleet,
            Scenario::BatchOvernight,
            Scenario::FeedBlackout,
            Scenario::StaleCreep,
            Scenario::EdgeFleet256,
            Scenario::EdgeFleet512,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Baseline => "baseline",
            Scenario::Diurnal => "diurnal",
            Scenario::BurstyHeavyTail => "bursty",
            Scenario::RegionalOutage => "outage",
            Scenario::RollingOutage => "outage-rolling",
            Scenario::CarbonSpike => "carbon-spike",
            Scenario::WaterStressedSummer => "water-summer",
            Scenario::GlobalFleet => "global-fleet",
            Scenario::BatchOvernight => "batch-overnight",
            Scenario::FeedBlackout => "feed-blackout",
            Scenario::StaleCreep => "stale-creep",
            Scenario::EdgeFleet256 => "edge-fleet-256",
            Scenario::EdgeFleet512 => "edge-fleet-512",
        }
    }

    pub fn description(&self) -> &'static str {
        match self {
            Scenario::Baseline => "paper-default workload and grid signals",
            Scenario::Diurnal => {
                "sharpened day/night demand swing, bursts disabled"
            }
            Scenario::BurstyHeavyTail => {
                "heavy-tailed demand spikes (BurstGPT trend 2, exaggerated)"
            }
            Scenario::RegionalOutage => {
                "north-america sites lose 90% of nodes; demand unchanged"
            }
            Scenario::RollingOutage => {
                "north-america goes dark mid-run, restored N epochs later"
            }
            Scenario::CarbonSpike => {
                "cleanest grids suffer a mid-window 4x carbon event"
            }
            Scenario::WaterStressedSummer => {
                "drought summer: 3x grid water intensity, degraded COP"
            }
            Scenario::GlobalFleet => {
                "planet-scale fleet: 48 sites from 8 per-zone grid \
                 templates (analytic-only; exceeds AOT DC slots)"
            }
            Scenario::BatchOvernight => {
                "hourly epochs; 40% deferrable batch mass with ~14h \
                 deadlines — the temporal-shifting regime"
            }
            Scenario::FeedBlackout => {
                "western-europe telemetry dark for a quarter of the run \
                 while its true CI spikes 10x"
            }
            Scenario::StaleCreep => {
                "feeds freeze one by one (cleanest first); frozen clean \
                 magnets' true CI climbs 6x in the second half"
            }
            Scenario::EdgeFleet256 => {
                "256-site fleet (32 per zone template); region-decomposed \
                 SLIT search auto-selected"
            }
            Scenario::EdgeFleet512 => {
                "512-site fleet (64 per zone template); region-decomposed \
                 SLIT search auto-selected"
            }
        }
    }

    pub fn from_name(name: &str) -> Option<Scenario> {
        Scenario::all().into_iter().find(|s| s.name() == name)
    }

    /// The objective axis this regime stresses — the scenario-matrix test
    /// requires SLIT's matching variant to stay non-dominated here.
    pub fn target_objective(&self) -> usize {
        match self {
            Scenario::Baseline => OBJ_COST,
            Scenario::Diurnal => OBJ_CARBON,
            Scenario::BurstyHeavyTail => OBJ_COST,
            Scenario::RegionalOutage => OBJ_COST,
            Scenario::RollingOutage => OBJ_COST,
            Scenario::CarbonSpike => OBJ_CARBON,
            Scenario::WaterStressedSummer => OBJ_WATER,
            // the fleet's CI spread (coal-heavy Asia vs Nordic wind) is
            // the signal a planet-scale scheduler must exploit
            Scenario::GlobalFleet => OBJ_CARBON,
            // shifting batch mass into clean windows is a carbon play
            Scenario::BatchOvernight => OBJ_CARBON,
            // both telemetry regimes corrupt the carbon picture: the cost
            // of believing bad signals lands on true carbon
            Scenario::FeedBlackout => OBJ_CARBON,
            Scenario::StaleCreep => OBJ_CARBON,
            // same CI-spread story as global-fleet, at 256/512 sites
            Scenario::EdgeFleet256 => OBJ_CARBON,
            Scenario::EdgeFleet512 => OBJ_CARBON,
        }
    }

    /// Fleet shape after this regime's config transform: (site count,
    /// distinct routing regions). What `slit scenarios` prints so every
    /// row is self-describing.
    pub fn fleet(&self, base: &SystemConfig) -> (usize, usize) {
        let mut cfg = base.clone();
        self.apply_config(&mut cfg);
        let mut regions: Vec<usize> =
            cfg.datacenters.iter().map(|d| d.region).collect();
        regions.sort_unstable();
        regions.dedup();
        (cfg.datacenters.len(), regions.len())
    }

    /// Deferrable-workload shape after this regime's config transform:
    /// (deferrable fraction, deadline slack in epochs). `(0.0, 0)` for
    /// regimes without deferrable mass; `slit scenarios` prints it.
    pub fn deferrable(&self, base: &SystemConfig) -> (f64, usize) {
        let mut cfg = base.clone();
        self.apply_config(&mut cfg);
        (cfg.workload.deferrable_frac, cfg.workload.defer_slack_epochs)
    }

    /// Mid-run cluster mutations this regime schedules (time-varying
    /// capacity — the static transforms above cannot express these).
    pub fn events(&self, epochs: usize) -> Vec<ScenarioEvent> {
        match self {
            Scenario::RollingOutage => {
                // dark for the second quarter of the horizon: healthy
                // epochs on both sides show the dip and the recovery.
                // Clamped so even tiny horizons keep epoch 0 healthy
                // (a 1-epoch run schedules nothing — there is no mid-run)
                if epochs < 2 {
                    return Vec::new();
                }
                let start = (epochs / 4).clamp(1, epochs - 1);
                let span = (epochs / 4).max(1);
                vec![
                    ScenarioEvent::at(
                        start,
                        ClusterAction::ScaleRegion {
                            region: OUTAGE_REGION,
                            frac: 0.0,
                        },
                    ),
                    ScenarioEvent::at(
                        start + span,
                        ClusterAction::RestoreRegion {
                            region: OUTAGE_REGION,
                        },
                    ),
                ]
            }
            Scenario::FeedBlackout => {
                // same window arithmetic as the rolling outage: dark for
                // the (second) quarter of the horizon, healthy epochs on
                // both sides; a 1-epoch run has no mid-run
                if epochs < 2 {
                    return Vec::new();
                }
                let start = (epochs / 4).clamp(1, epochs - 1);
                let span = (epochs / 4).max(1);
                vec![ScenarioEvent::at(
                    start,
                    ClusterAction::Signal(SignalFault::RegionBlackout {
                        region: FEED_BLACKOUT_REGION,
                        epochs: span,
                    }),
                )]
            }
            Scenario::StaleCreep => {
                // feeds freeze one by one, cleanest magnets first, each
                // staying frozen to the end of the horizon — fleet-wide
                // staleness that only grows
                if epochs < 2 {
                    return Vec::new();
                }
                let start = (epochs / 4).clamp(1, epochs - 1);
                STALE_CREEP_SITES
                    .iter()
                    .enumerate()
                    .filter_map(|(k, &site)| {
                        let at = start
                            + k * (epochs - start) / STALE_CREEP_SITES.len();
                        (at < epochs).then(|| {
                            ScenarioEvent::at(
                                at,
                                ClusterAction::Signal(SignalFault::Freeze {
                                    site,
                                    epochs,
                                }),
                            )
                        })
                    })
                    .collect()
            }
            _ => Vec::new(),
        }
    }

    /// Telemetry-fault summary for `slit scenarios` listings: scheduled
    /// [`SignalFault`] count plus distinct kind tags, `-` when the regime
    /// injects none.
    pub fn fault_summary(&self, epochs: usize) -> String {
        let mut kinds: Vec<&'static str> = Vec::new();
        let mut count = 0usize;
        for ev in self.events(epochs) {
            if let ClusterAction::Signal(f) = &ev.action {
                count += 1;
                let kind = f.kind();
                if !kinds.contains(&kind) {
                    kinds.push(kind);
                }
            }
        }
        if count == 0 {
            "-".into()
        } else {
            format!("{} {}", count, kinds.join("+"))
        }
    }

    /// Pre-generation config adjustments.
    pub fn apply_config(&self, cfg: &mut SystemConfig) {
        match self {
            Scenario::Baseline => {}
            Scenario::Diurnal => {
                cfg.workload.burst_prob = 0.0;
            }
            Scenario::BurstyHeavyTail => {
                cfg.workload.burst_prob = 0.18;
                cfg.workload.burst_mult = 6.0;
            }
            Scenario::RegionalOutage => {
                for d in &mut cfg.datacenters {
                    if d.region == OUTAGE_REGION {
                        d.nodes_per_type = d
                            .nodes_per_type
                            .iter()
                            .map(|&n| {
                                ((n as f64 * OUTAGE_SURVIVING_FRAC) as usize)
                                    .max(1)
                            })
                            .collect();
                    }
                }
            }
            // no static change: the outage arrives via ScenarioEvents
            Scenario::RollingOutage => {}
            Scenario::CarbonSpike => {}
            Scenario::WaterStressedSummer => {
                for d in &mut cfg.datacenters {
                    d.wi_base *= 3.0;
                    d.cop = (d.cop * 0.75).max(1.0);
                }
            }
            Scenario::GlobalFleet => {
                cfg.datacenters = global_fleet_datacenters(SITES_PER_ZONE);
            }
            Scenario::BatchOvernight => {
                // hourly epochs: a CI-sized run still spans whole diurnal
                // cycles, which is what the shift forecaster learns from
                cfg.physics.epoch_s = 3600.0;
                cfg.workload.deferrable_frac = 0.4;
                cfg.workload.defer_slack_epochs = 14;
                // batch arrivals are steady; bursts belong to interactive
                // regimes
                cfg.workload.burst_prob = 0.0;
            }
            // telemetry faults arrive via ScenarioEvents; the grid truth
            // rotation happens in shape_signals
            Scenario::FeedBlackout => {}
            Scenario::StaleCreep => {}
            Scenario::EdgeFleet256 => {
                cfg.datacenters = global_fleet_datacenters(32);
            }
            Scenario::EdgeFleet512 => {
                cfg.datacenters = global_fleet_datacenters(64);
            }
        }
    }

    /// Post-generation trace shaping (deterministic per seed).
    fn shape_trace(&self, cfg: &SystemConfig, trace: &mut Trace, seed: u64) {
        let epochs = trace.epochs.len();
        match self {
            Scenario::Diurnal => {
                // sharpen the global day/night contrast on top of the
                // generator's per-region diurnal base
                for t in 0..epochs {
                    let hour = (t as f64 * cfg.physics.epoch_s / 3600.0)
                        .rem_euclid(24.0);
                    let day = (std::f64::consts::PI * ((hour - 7.0) / 16.0))
                        .sin()
                        .max(0.0);
                    trace.scale_epoch(t, 0.45 + 1.4 * day);
                }
            }
            Scenario::BurstyHeavyTail => {
                // extra heavy-tail spikes: rare epochs multiply by
                // 1 + Gamma(0.7)-scaled surges (approximate Pareto tail)
                let mut rng = Rng::new(seed ^ 0x5C3A_4210);
                for t in 0..epochs {
                    if rng.chance(0.08) {
                        trace.scale_epoch(t, 1.0 + 4.0 * rng.gamma(0.7));
                    }
                }
            }
            _ => {}
        }
    }

    /// Post-generation grid-signal shaping.
    fn shape_signals(&self, cfg: &SystemConfig, signals: &mut GridSignals) {
        match self {
            Scenario::CarbonSpike => {
                // the cleanest quarter of sites (by CI base) spike 4x
                // during the middle third of the horizon — a wind lull
                // backed by coal
                let epochs = signals.epochs();
                let window = epochs / 3..(2 * epochs) / 3;
                let mut order: Vec<usize> =
                    (0..cfg.datacenters.len()).collect();
                order.sort_by(|&a, &b| {
                    cfg.datacenters[a]
                        .ci_base
                        .partial_cmp(&cfg.datacenters[b].ci_base)
                        .unwrap()
                });
                let afflicted = (cfg.datacenters.len() / 4).max(1);
                for &dc in order.iter().take(afflicted) {
                    signals.scale_window(dc, window.clone(), 4.0, 1.0, 1.0);
                }
            }
            Scenario::FeedBlackout => {
                // the dark region's true CI spikes over exactly the
                // blackout window (same arithmetic as events()): the
                // fault-blind believed picture and the truth diverge
                let epochs = signals.epochs();
                if epochs >= 2 {
                    let start = (epochs / 4).clamp(1, epochs - 1);
                    let span = (epochs / 4).max(1);
                    let window = start..start + span;
                    for (dc, d) in cfg.datacenters.iter().enumerate() {
                        if d.region == FEED_BLACKOUT_REGION {
                            signals.scale_window(
                                dc,
                                window.clone(),
                                FEED_BLACKOUT_CI_MULT,
                                1.0,
                                1.0,
                            );
                        }
                    }
                }
            }
            Scenario::StaleCreep => {
                // the frozen clean magnets get dirty in the second half
                // while their feeds keep replaying clean pre-freeze
                // values; the fresh region's truth is untouched
                let epochs = signals.epochs();
                let window = epochs / 2..epochs;
                for (dc, d) in cfg.datacenters.iter().enumerate() {
                    if d.region != STALE_FRESH_REGION
                        && d.ci_base < STALE_CLEAN_CI_CEILING
                    {
                        signals.scale_window(
                            dc,
                            window.clone(),
                            STALE_CREEP_CI_MULT,
                            1.0,
                            1.0,
                        );
                    }
                }
            }
            _ => {}
        }
    }

    /// Generate the full world for this regime: mutated config, then the
    /// trace/signal generators (trace.rs / power.rs), then the shaping
    /// passes, plus the regime's mid-run event schedule. Deterministic in
    /// (base config, epochs, seed).
    pub fn build(
        &self,
        base: &SystemConfig,
        epochs: usize,
        seed: u64,
    ) -> ScenarioWorld {
        let mut cfg = base.clone();
        self.apply_config(&mut cfg);
        cfg.epochs = epochs;
        let mut trace = Trace::generate(&cfg, epochs, seed);
        let mut signals = GridSignals::generate(&cfg, epochs, seed);
        self.shape_trace(&cfg, &mut trace, seed);
        self.shape_signals(&cfg, &mut signals);
        ScenarioWorld {
            events: self.events(epochs),
            cfg,
            trace,
            signals,
        }
    }
}

// --- the planet-scale fleet --------------------------------------------------

/// Sites per geographic zone in the `global-fleet` regime
/// (8 zones x 6 = 48 sites).
pub const SITES_PER_ZONE: usize = 6;

/// One geographic zone template: a grid/climate profile that stamps out
/// `sites_per_zone` sites with deterministic per-site variation. Two zones
/// per routing region — the paper's 4-region router (and the AOT class
/// layout pinned to it) is untouched; zones only diversify generation.
struct ZoneTemplate {
    name: &'static str,
    region: usize,
    tz_offset_h: f64,
    ci: (f64, f64),
    wi: (f64, f64),
    tou: (f64, f64),
    cop: f64,
    bw_gbs: f64,
}

/// Shorthand constructor keeping the zone table readable (and rustfmt-
/// stable) — field order mirrors [`ZoneTemplate`].
#[allow(clippy::too_many_arguments)]
const fn zone(
    name: &'static str,
    region: usize,
    tz_offset_h: f64,
    ci: (f64, f64),
    wi: (f64, f64),
    tou: (f64, f64),
    cop: f64,
    bw_gbs: f64,
) -> ZoneTemplate {
    ZoneTemplate {
        name,
        region,
        tz_offset_h,
        ci,
        wi,
        tou,
        cop,
        bw_gbs,
    }
}

/// The 8 zone templates: per routing region a carbon-heavy and a clean
/// (or hydro-heavy, water-expensive) zone, straddling the cited grid
/// extremes exactly as the 12-site paper testbed does.
const ZONES: [ZoneTemplate; 8] = [
    // east-asia: coal-heavy north vs tropical south (low COP, dear water)
    zone("ea-north", 0, 9.0, (0.46, 0.22), (1.7, 0.2), (0.18, 0.5), 4.2, 12.0),
    zone("ea-south", 0, 8.0, (0.52, 0.12), (2.4, 0.15), (0.16, 0.35), 3.1, 10.0),
    // oceania: solar-swing Australia vs hydro New Zealand
    zone("oc-au", 1, 10.0, (0.58, 0.45), (1.4, 0.25), (0.20, 0.5), 4.9, 9.0),
    zone("oc-nz", 1, 12.0, (0.10, 0.30), (22.0, 0.3), (0.15, 0.3), 5.4, 7.0),
    // north-america: mixed east vs hydro-heavy pacific northwest
    zone("na-east", 2, -5.0, (0.34, 0.30), (2.0, 0.2), (0.09, 0.55), 4.3, 18.0),
    zone("na-west", 2, -8.0, (0.10, 0.35), (28.0, 0.35), (0.07, 0.45), 6.0, 16.0),
    // western-europe: Nordic wind/hydro vs continental mixed grids
    zone("eu-north", 3, 1.0, (0.05, 0.30), (7.0, 0.3), (0.07, 0.35), 7.2, 11.0),
    zone("eu-west", 3, 0.0, (0.30, 0.45), (1.0, 0.3), (0.21, 0.5), 5.6, 15.0),
];

/// Generate the planet-scale fleet: `sites_per_zone` sites stamped from
/// each of the 8 [`ZONES`], with deterministic per-site spread (no RNG —
/// the fleet is a pure function of its arguments) and the paper's three
/// node-mix shapes rotated across sites. 48 sites at the default
/// [`SITES_PER_ZONE`], well past the AOT artifact's `DC_SLOTS` padding —
/// this is the workload the L-generic `DcVec` evaluator path exists for.
pub fn global_fleet_datacenters(sites_per_zone: usize) -> Vec<DatacenterSpec> {
    // A100-heavy / balanced / H100-heavy, ~360 nodes per site
    const MIXES: [[usize; 6]; 3] = [
        [90, 72, 54, 72, 54, 18],
        [60, 60, 60, 60, 60, 60],
        [18, 54, 72, 54, 72, 90],
    ];
    let mut fleet = Vec::with_capacity(ZONES.len() * sites_per_zone);
    for z in &ZONES {
        for i in 0..sites_per_zone {
            // symmetric spread in [-1, 1] across the zone's sites: real
            // zones are not uniform — neighbouring grids differ a little
            let spread = if sites_per_zone > 1 {
                2.0 * i as f64 / (sites_per_zone - 1) as f64 - 1.0
            } else {
                0.0
            };
            fleet.push(DatacenterSpec {
                name: format!("{}-{}", z.name, i + 1),
                region: z.region,
                nodes_per_type: MIXES[fleet.len() % MIXES.len()].to_vec(),
                cop: (z.cop + 0.3 * spread).max(1.0),
                bw_gbs: (z.bw_gbs + 2.0 * spread).max(1.0),
                tz_offset_h: z.tz_offset_h,
                ci_base: z.ci.0 * (1.0 + 0.10 * spread),
                ci_amp: z.ci.1,
                wi_base: z.wi.0 * (1.0 + 0.15 * spread),
                wi_amp: z.wi.1,
                tou_base: z.tou.0 * (1.0 + 0.08 * spread),
                tou_amp: z.tou.1,
            });
        }
    }
    fleet
}

/// Group site indices by region tag, ordered by ascending tag — the
/// partition the region-decomposed SLIT search fans out over (one
/// subproblem per routing region) and `slit scenarios` prints per row.
/// Pure and deterministic; index order within a region is ascending.
pub fn partition_sites_by_region(
    regions: &[usize],
) -> Vec<(usize, Vec<usize>)> {
    let mut tags: Vec<usize> = regions.to_vec();
    tags.sort_unstable();
    tags.dedup();
    tags.into_iter()
        .map(|t| {
            let sites: Vec<usize> = regions
                .iter()
                .enumerate()
                .filter(|&(_, &r)| r == t)
                .map(|(i, _)| i)
                .collect();
            (t, sites)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn base() -> SystemConfig {
        SystemConfig::paper_default()
    }

    #[test]
    fn names_round_trip_and_are_unique() {
        let mut seen = Vec::new();
        for s in Scenario::all() {
            assert_eq!(Scenario::from_name(s.name()), Some(s));
            assert!(!seen.contains(&s.name()), "duplicate {}", s.name());
            seen.push(s.name());
            assert!(!s.description().is_empty());
            assert!(s.target_objective() < crate::config::N_OBJ);
        }
        assert_eq!(Scenario::from_name("nope"), None);
        assert_eq!(Scenario::named().len(), 12);
    }

    #[test]
    fn builds_are_deterministic_and_valid() {
        for s in Scenario::all() {
            let a = s.build(&base(), 48, 7);
            let b = s.build(&base(), 48, 7);
            a.cfg.validate().unwrap();
            assert_eq!(a.trace.epochs, b.trace.epochs, "{}", s.name());
            assert_eq!(a.signals.ci, b.signals.ci, "{}", s.name());
            assert!(
                a.trace.epochs.iter().map(|e| e.total_requests()).sum::<f64>()
                    > 0.0,
                "{} generated no demand",
                s.name()
            );
        }
    }

    #[test]
    fn diurnal_disables_bursts_and_keeps_day_night_contrast() {
        let w = Scenario::Diurnal.build(&base(), 192, 3);
        assert_eq!(w.cfg.workload.burst_prob, 0.0);
        let toks = w.trace.tokens_per_epoch();
        let (lo, hi) = crate::util::stats::min_max(&toks);
        assert!(hi > 3.0 * lo.max(1.0), "no day/night contrast: {lo} {hi}");
    }

    #[test]
    fn bursty_exhibits_a_heavy_tail() {
        // enforce the regime's mechanism (3x the baseline burst rate, a
        // bigger multiplier) and the resulting shape: a clearly heavy
        // peak plus multiple spike epochs — absolute bounds, since a
        // cross-seed max/mean comparison against baseline would be too
        // noisy to pin down
        let s = Scenario::BurstyHeavyTail.build(&base(), 288, 5);
        assert!(s.cfg.workload.burst_prob >= 2.0 * base().workload.burst_prob);
        assert!(s.cfg.workload.burst_mult > base().workload.burst_mult);
        let toks = s.trace.tokens_per_epoch();
        let mean = crate::util::stats::mean(&toks).max(1.0);
        let max = toks.iter().cloned().fold(0.0f64, f64::max);
        assert!(max > 2.5 * mean, "bursty trace too flat: {}", max / mean);
        let spikes = toks.iter().filter(|&&t| t > 2.0 * mean).count();
        assert!(spikes >= 3, "too few spike epochs: {spikes}");
    }

    #[test]
    fn outage_shrinks_only_the_afflicted_region() {
        let b = base();
        let w = Scenario::RegionalOutage.build(&b, 24, 1);
        for (orig, out) in b.datacenters.iter().zip(&w.cfg.datacenters) {
            if orig.region == OUTAGE_REGION {
                assert!(
                    out.total_nodes() * 5 < orig.total_nodes(),
                    "{} not degraded",
                    out.name
                );
            } else {
                assert_eq!(out.total_nodes(), orig.total_nodes());
            }
        }
        // demand from the afflicted region is NOT shed
        let total: f64 =
            w.trace.epochs.iter().map(|e| e.total_requests()).sum();
        assert!(total > 0.0);
    }

    #[test]
    fn rolling_outage_schedules_dark_and_restore_events() {
        let w = Scenario::RollingOutage.build(&base(), 96, 1);
        // no static capacity change: the config keeps full node counts
        assert_eq!(w.cfg.datacenters, base().datacenters);
        assert_eq!(w.events.len(), 2);
        assert_eq!(w.events[0].epoch, 24);
        assert_eq!(w.events[1].epoch, 48);
        assert_eq!(
            w.events[0].action,
            crate::cluster::ClusterAction::ScaleRegion {
                region: OUTAGE_REGION,
                frac: 0.0
            }
        );
        assert_eq!(
            w.events[1].action,
            crate::cluster::ClusterAction::RestoreRegion {
                region: OUTAGE_REGION
            }
        );
        // every other regime schedules no *capacity* events — the two
        // telemetry regimes only inject topology-inert Signal faults
        for sc in Scenario::all() {
            if sc != Scenario::RollingOutage {
                let w = sc.build(&base(), 24, 1);
                assert!(
                    w.events.iter().all(|ev| matches!(
                        ev.action,
                        crate::cluster::ClusterAction::Signal(_)
                    )),
                    "{} schedules capacity events",
                    sc.name()
                );
            }
        }
        // short horizons keep epoch 0 healthy; a 1-epoch run has no
        // mid-run, so nothing is scheduled
        let tiny = Scenario::RollingOutage.events(3);
        assert_eq!(tiny.len(), 2);
        assert_eq!(tiny[0].epoch, 1);
        assert_eq!(tiny[1].epoch, 2);
        assert!(Scenario::RollingOutage.events(1).is_empty());
    }

    #[test]
    fn rolling_outage_world_dips_and_recovers_capacity() {
        use crate::sim::{EpochContext, Scheduler};

        struct Uniform;
        impl Scheduler for Uniform {
            fn name(&self) -> String {
                "uniform".into()
            }
            fn plan(&mut self, ctx: &EpochContext) -> crate::plan::Plan {
                crate::plan::Plan::uniform(
                    ctx.cfg.num_classes(),
                    ctx.cfg.datacenters.len(),
                )
            }
        }
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 8;
        let w = Scenario::RollingOutage.build(&cfg, cfg.epochs, 3);
        let res = w.run(&mut Uniform, 3);
        let nodes =
            |e: usize| -> usize { res.per_epoch[e].site_nodes.iter().sum() };
        // events at epochs 2 and 4 for an 8-epoch horizon
        assert_eq!(nodes(0), nodes(7));
        assert!(nodes(2) < nodes(0), "no dip: {} vs {}", nodes(2), nodes(0));
        assert!(nodes(3) < nodes(0));
        assert_eq!(nodes(4), nodes(0), "capacity not restored");
        // request mass conserved through the capacity change
        let expected: f64 = w.trace.epochs[..w.cfg.epochs]
            .iter()
            .map(|e| {
                e.classes.iter().map(|c| c.n_req.round()).sum::<f64>()
            })
            .sum();
        assert!((res.total.requests - expected).abs() < 1e-6);
    }

    #[test]
    fn carbon_spike_raises_clean_site_ci_in_window_only() {
        let b = Scenario::Baseline.build(&base(), 96, 9);
        let s = Scenario::CarbonSpike.build(&base(), 96, 9);
        let cfg = base();
        // cleanest site by base CI
        let clean = (0..cfg.datacenters.len())
            .min_by(|&a, &b| {
                cfg.datacenters[a]
                    .ci_base
                    .partial_cmp(&cfg.datacenters[b].ci_base)
                    .unwrap()
            })
            .unwrap();
        let window = 96 / 3..2 * 96 / 3;
        let inside_base = b.signals.mean_ci(clean, window.clone());
        let inside_spike = s.signals.mean_ci(clean, window);
        assert!(
            inside_spike > 3.0 * inside_base,
            "no spike: {inside_spike} vs {inside_base}"
        );
        // outside the window the signals are untouched
        let before_base = b.signals.mean_ci(clean, 0..96 / 3);
        let before_spike = s.signals.mean_ci(clean, 0..96 / 3);
        assert!((before_base - before_spike).abs() < 1e-12);
    }

    #[test]
    fn global_fleet_builds_48_diverse_sites_past_the_aot_ceiling() {
        let w = Scenario::GlobalFleet.build(&base(), 8, 3);
        w.cfg.validate().expect("planet-scale fleet must validate");
        assert_eq!(w.cfg.datacenters.len(), 48);
        assert!(
            w.cfg.datacenters.len() > crate::config::DC_SLOTS,
            "the regime exists to exceed the inline tile"
        );
        assert!(w.cfg.validate_aot().is_err(), "analytic-only fleet");
        assert!(w.events.is_empty());

        // every routing region is covered, 12 sites each (2 zones x 6)
        for r in 0..crate::config::REGIONS {
            let n = w.cfg.datacenters.iter().filter(|d| d.region == r).count();
            assert_eq!(n, 2 * SITES_PER_ZONE, "region {r}");
        }
        // names are unique
        let mut names: Vec<&str> =
            w.cfg.datacenters.iter().map(|d| d.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 48, "duplicate site names");

        // grid diversity straddles the cited extremes: coal-heavy vs
        // near-zero-carbon grids, wind-dry vs hydro-wet water intensity
        let ci: Vec<f64> = w.cfg.datacenters.iter().map(|d| d.ci_base).collect();
        let wi: Vec<f64> = w.cfg.datacenters.iter().map(|d| d.wi_base).collect();
        let (ci_lo, ci_hi) = crate::util::stats::min_max(&ci);
        let (wi_lo, wi_hi) = crate::util::stats::min_max(&wi);
        assert!(ci_lo < 0.1 && ci_hi > 0.5, "CI spread too flat: {ci_lo}..{ci_hi}");
        assert!(wi_lo < 1.5 && wi_hi > 20.0, "WI spread too flat: {wi_lo}..{wi_hi}");
        // deterministic per-site variation inside one zone
        assert_ne!(w.cfg.datacenters[0].ci_base, w.cfg.datacenters[1].ci_base);
        assert_eq!(
            global_fleet_datacenters(SITES_PER_ZONE),
            global_fleet_datacenters(SITES_PER_ZONE),
        );

        // the fleet summary `slit scenarios` prints
        assert_eq!(Scenario::GlobalFleet.fleet(&base()), (48, 4));
        assert_eq!(Scenario::Baseline.fleet(&base()), (12, 4));
    }

    #[test]
    fn global_fleet_simulates_end_to_end_on_the_session_path() {
        use crate::sim::{EpochContext, Scheduler};

        struct Uniform;
        impl Scheduler for Uniform {
            fn name(&self) -> String {
                "uniform".into()
            }
            fn plan(&mut self, ctx: &EpochContext) -> crate::plan::Plan {
                crate::plan::Plan::uniform(
                    ctx.cfg.num_classes(),
                    ctx.cfg.datacenters.len(),
                )
            }
        }
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 2;
        let w = Scenario::GlobalFleet.build(&cfg, cfg.epochs, 5);
        let res = w.run(&mut Uniform, 5);
        assert_eq!(res.per_epoch.len(), 2);
        assert_eq!(res.per_epoch[0].site_nodes.len(), 48);
        // request mass conserved across the 48-site fleet
        let expected: f64 = w.trace.epochs[..w.cfg.epochs]
            .iter()
            .map(|e| e.classes.iter().map(|c| c.n_req.round()).sum::<f64>())
            .sum();
        assert!((res.total.requests - expected).abs() < 1e-6);
        assert!(res.total.e_tot_j > 0.0);
    }

    #[test]
    fn edge_fleets_stamp_256_and_512_sites_across_all_regions() {
        for (sc, sites, per_zone) in [
            (Scenario::EdgeFleet256, 256usize, 32usize),
            (Scenario::EdgeFleet512, 512, 64),
        ] {
            let w = sc.build(&base(), 4, 3);
            w.cfg.validate().expect("edge fleet must validate");
            assert_eq!(w.cfg.datacenters.len(), sites, "{}", sc.name());
            assert!(w.cfg.validate_aot().is_err(), "analytic-only fleet");
            assert!(w.events.is_empty());
            assert_eq!(sc.fleet(&base()), (sites, 4));
            // every routing region holds its two zones' worth of sites,
            // so the region decomposition fans out over 4 balanced parts
            for r in 0..crate::config::REGIONS {
                let n =
                    w.cfg.datacenters.iter().filter(|d| d.region == r).count();
                assert_eq!(n, 2 * per_zone, "{} region {r}", sc.name());
            }
            // names stay unique at scale
            let mut names: Vec<&str> =
                w.cfg.datacenters.iter().map(|d| d.name.as_str()).collect();
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), sites, "duplicate site names");
            // past the auto-decomposition threshold: SLIT switches modes
            assert!(sites >= crate::opt::REGION_DECOMPOSE_THRESHOLD);
        }
        // the 48-site global fleet stays under the threshold, keeping
        // its global-walk results bit-identical to earlier releases
        assert!(48 < crate::opt::REGION_DECOMPOSE_THRESHOLD);
    }

    #[test]
    fn partition_groups_sites_by_ascending_region_tag() {
        let regions = [2usize, 0, 2, 1, 0, 2];
        let parts = partition_sites_by_region(&regions);
        assert_eq!(
            parts,
            vec![
                (0, vec![1, 4]),
                (1, vec![3]),
                (2, vec![0, 2, 5]),
            ]
        );
        // partition covers every site exactly once
        let mut all: Vec<usize> =
            parts.iter().flat_map(|(_, s)| s.iter().copied()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..regions.len()).collect::<Vec<_>>());
        assert!(partition_sites_by_region(&[]).is_empty());

        // the edge fleets split into the 4 routing regions, 64 sites each
        let fleet = global_fleet_datacenters(32);
        let tags: Vec<usize> = fleet.iter().map(|d| d.region).collect();
        let parts = partition_sites_by_region(&tags);
        assert_eq!(parts.len(), 4);
        for (_, sites) in &parts {
            assert_eq!(sites.len(), 64);
        }
    }

    #[test]
    fn batch_overnight_carries_deferrable_mass_with_deadlines() {
        let b = base();
        let w = Scenario::BatchOvernight.build(&b, 48, 7);
        assert_eq!(w.cfg.physics.epoch_s, 3600.0);
        assert_eq!(w.cfg.workload.deferrable_frac, 0.4);
        assert!(w.events.is_empty());
        assert_eq!(Scenario::BatchOvernight.deferrable(&b), (0.4, 14));
        assert_eq!(Scenario::Baseline.deferrable(&b), (0.0, 0));

        let deferred: f64 = w
            .trace
            .epochs
            .iter()
            .map(|e| e.total_deferrable())
            .sum();
        let interactive: f64 =
            w.trace.epochs.iter().map(|e| e.total_requests()).sum();
        assert!(deferred > 0.0, "no deferrable mass generated");
        // the carve-out is ~40% of the offered total
        let frac = deferred / (deferred + interactive);
        assert!((0.25..0.55).contains(&frac), "odd deferrable share {frac}");
        // deadlines are within the slack window and inside the horizon
        for (t, e) in w.trace.epochs.iter().enumerate() {
            for c in &e.classes {
                if c.defer_req > 0.0 {
                    assert!(c.defer_deadline >= t);
                    assert!(c.defer_deadline <= (t + 14).min(47));
                    // integral lots keep conservation checks exact
                    assert_eq!(c.defer_req, c.defer_req.round());
                }
            }
        }
    }

    #[test]
    fn feed_blackout_darkens_and_dirties_western_europe() {
        use crate::cluster::ClusterAction;
        use crate::signals::SignalFault;

        let b = Scenario::Baseline.build(&base(), 96, 9);
        let w = Scenario::FeedBlackout.build(&base(), 96, 9);
        // capacity untouched: the only event is the telemetry blackout
        assert_eq!(w.cfg.datacenters, base().datacenters);
        assert_eq!(w.events.len(), 1);
        assert_eq!(w.events[0].epoch, 24);
        assert_eq!(
            w.events[0].action,
            ClusterAction::Signal(SignalFault::RegionBlackout {
                region: FEED_BLACKOUT_REGION,
                epochs: 24,
            })
        );
        // the dark region's truth spikes inside the window only
        let window = 24..48;
        for (dc, d) in w.cfg.datacenters.iter().enumerate() {
            let inside_base = b.signals.mean_ci(dc, window.clone());
            let inside = w.signals.mean_ci(dc, window.clone());
            let before_base = b.signals.mean_ci(dc, 0..24);
            let before = w.signals.mean_ci(dc, 0..24);
            if d.region == FEED_BLACKOUT_REGION {
                assert!(
                    inside > 8.0 * inside_base,
                    "{} not spiked: {inside} vs {inside_base}",
                    d.name
                );
            } else {
                assert!((inside - inside_base).abs() < 1e-12, "{}", d.name);
            }
            assert!((before - before_base).abs() < 1e-12, "{}", d.name);
        }
        // a 1-epoch run has no mid-run to black out
        assert!(Scenario::FeedBlackout.events(1).is_empty());
    }

    #[test]
    fn stale_creep_freezes_cleanest_first_and_spares_the_fresh_region() {
        use crate::cluster::ClusterAction;
        use crate::signals::SignalFault;

        let cfg = base();
        let b = Scenario::Baseline.build(&cfg, 96, 9);
        let w = Scenario::StaleCreep.build(&cfg, 96, 9);
        assert_eq!(w.events.len(), STALE_CREEP_SITES.len());
        let mut prev_epoch = 0;
        for (k, ev) in w.events.iter().enumerate() {
            // freezes creep outward in time, cleanest magnets first
            assert!(ev.epoch >= prev_epoch, "events out of order");
            prev_epoch = ev.epoch;
            match &ev.action {
                ClusterAction::Signal(SignalFault::Freeze {
                    site,
                    epochs,
                }) => {
                    assert_eq!(*site, STALE_CREEP_SITES[k]);
                    assert_eq!(*epochs, 96, "frozen to end of horizon");
                    assert_ne!(
                        cfg.datacenters[*site].region,
                        STALE_FRESH_REGION,
                        "the fresh region must stay fresh"
                    );
                }
                other => panic!("unexpected action {other:?}"),
            }
        }
        // stockholm (the cleanest magnet) freezes first
        assert_eq!(w.events[0].epoch, 24);
        assert!(matches!(
            w.events[0].action,
            ClusterAction::Signal(SignalFault::Freeze { site: 11, .. })
        ));
        // second-half truth: frozen clean magnets get dirty, the fresh
        // refuge is untouched
        let second_half = 48..96;
        for (dc, d) in cfg.datacenters.iter().enumerate() {
            let base_ci = b.signals.mean_ci(dc, second_half.clone());
            let creep_ci = w.signals.mean_ci(dc, second_half.clone());
            if d.region != STALE_FRESH_REGION
                && d.ci_base < STALE_CLEAN_CI_CEILING
            {
                assert!(
                    creep_ci > 5.0 * base_ci,
                    "{} not dirtied: {creep_ci} vs {base_ci}",
                    d.name
                );
            } else {
                assert!((creep_ci - base_ci).abs() < 1e-12, "{}", d.name);
            }
        }
        assert!(Scenario::StaleCreep.events(1).is_empty());
    }

    #[test]
    fn fault_summaries_describe_signal_schedules() {
        assert_eq!(Scenario::Baseline.fault_summary(96), "-");
        assert_eq!(Scenario::RollingOutage.fault_summary(96), "-");
        assert_eq!(
            Scenario::FeedBlackout.fault_summary(96),
            "1 region-blackout"
        );
        assert_eq!(Scenario::StaleCreep.fault_summary(96), "9 freeze");
    }

    #[test]
    fn water_summer_raises_wi_and_degrades_cop() {
        let b = base();
        let w = Scenario::WaterStressedSummer.build(&b, 24, 1);
        for (orig, out) in b.datacenters.iter().zip(&w.cfg.datacenters) {
            assert!(out.wi_base > 2.9 * orig.wi_base, "{}", out.name);
            assert!(out.cop <= orig.cop);
            assert!(out.cop >= 1.0);
        }
    }
}
