//! API-compatible stand-in for the PJRT engine, compiled when the `pjrt`
//! cargo feature is off (the offline image ships no `xla` crate to link).
//!
//! [`Engine::load`] always fails with a clear message, so every caller that
//! gates on it (`--use-hlo`, runtime_parity tests, benches) degrades
//! gracefully; [`HloPlanEvaluator`] falls back to the analytic evaluator so
//! optimizer plumbing that is generic over [`BatchEvaluator`] typechecks
//! and still produces correct numbers if one is ever constructed by hand.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::N_OBJ;
use crate::eval::{AnalyticEvaluator, BatchEvaluator};
use crate::plan::Plan;

use super::Manifest;

/// Stub engine handle. Never constructed via [`Engine::load`]; exists so
/// `Arc<Engine>`-typed plumbing compiles without the XLA runtime.
pub struct Engine {
    pub manifest: Manifest,
    dispatches: AtomicU64,
}

impl Engine {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn load(dir: &std::path::Path) -> anyhow::Result<Arc<Engine>> {
        anyhow::bail!(
            "AOT/PJRT backend unavailable: built without the `pjrt` cargo \
             feature (no XLA runtime linked; artifacts dir was {})",
            dir.display()
        )
    }

    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }
}

/// Stub plan evaluator: carries the engine handle for API parity but
/// evaluates on the native analytic path.
pub struct HloPlanEvaluator {
    engine: Arc<Engine>,
    fallback: AnalyticEvaluator,
}

impl HloPlanEvaluator {
    pub fn from_analytic(engine: Arc<Engine>, ev: &AnalyticEvaluator) -> Self {
        HloPlanEvaluator {
            engine,
            fallback: ev.clone(),
        }
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }
}

impl BatchEvaluator for HloPlanEvaluator {
    fn backend(&self) -> &'static str {
        "analytic (pjrt stub)"
    }

    fn eval_batch(&self, plans: &[Plan]) -> Vec<[f64; N_OBJ]> {
        self.engine.dispatches.fetch_add(1, Ordering::Relaxed);
        self.fallback.eval_batch(plans)
    }
}

/// Stub predictor: reports the missing backend instead of predicting.
pub struct HloPredictor {
    _engine: Arc<Engine>,
}

impl HloPredictor {
    pub fn new(engine: Arc<Engine>) -> Self {
        HloPredictor { _engine: engine }
    }

    pub fn predict_series(
        &self,
        _series: &[f64],
        _epochs_per_day: usize,
    ) -> anyhow::Result<f64> {
        anyhow::bail!(
            "predictor artifact execution requires the `pjrt` cargo feature"
        )
    }
}
