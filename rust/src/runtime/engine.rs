//! The PJRT engine thread and its thread-safe handles.
//!
//! One OS thread owns the (non-`Send`) `xla::PjRtClient` plus the two
//! compiled executables; requests arrive over an mpsc channel and return
//! over per-call reply channels. Dispatch overhead is amortised by the
//! population-sized batches the optimizer sends (P = 128 plans/call).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::config::{CLASSES, DC_SLOTS, EVAL_POPULATION, N_OBJ};
use crate::eval::{AnalyticEvaluator, BatchEvaluator};
use crate::plan::Plan;

use super::Manifest;

enum Job {
    /// Upload an epoch's parameter panels once; later PlanEval jobs refer
    /// to them by token (saves 5 host->device transfers per dispatch).
    BindPanels {
        token: u64,
        cls: Vec<f32>,
        thr: Vec<f32>,
        proc: Vec<f32>,
        hops: Vec<f32>,
        dc: Vec<f32>,
        consts: Vec<f32>,
        reply: mpsc::Sender<anyhow::Result<()>>,
    },
    UnbindPanels {
        token: u64,
    },
    /// Evaluate one population tile against bound panels.
    PlanEvalBound {
        token: u64,
        a: Vec<f32>,
        reply: mpsc::Sender<anyhow::Result<Vec<f32>>>,
    },
    PlanEval {
        /// Flattened f32 inputs in the artifact's argument order.
        a: Vec<f32>,
        cls: Vec<f32>,
        thr: Vec<f32>,
        proc: Vec<f32>,
        hops: Vec<f32>,
        dc: Vec<f32>,
        consts: Vec<f32>,
        reply: mpsc::Sender<anyhow::Result<Vec<f32>>>,
    },
    Predict {
        x: Vec<f32>,
        y: Vec<f32>,
        xq: Vec<f32>,
        lambdas: Vec<f32>,
        reply: mpsc::Sender<anyhow::Result<(Vec<f32>, Vec<f32>)>>,
    },
    Shutdown,
}

/// Thread-safe handle to the PJRT engine thread.
pub struct Engine {
    tx: Mutex<mpsc::Sender<Job>>,
    pub manifest: Manifest,
    /// Executions served (coarse metric; includes both executables).
    dispatches: std::sync::atomic::AtomicU64,
    /// Panel-binding token source.
    next_token: std::sync::atomic::AtomicU64,
}

impl Engine {
    /// Load artifacts from `dir`, compile on a fresh engine thread, and
    /// block until the thread reports readiness (propagating any error).
    pub fn load(dir: &std::path::Path) -> anyhow::Result<Arc<Engine>> {
        let manifest = Manifest::load(dir)?;
        let plan_path = dir.join(&manifest.plan_eval_file);
        let pred_path = dir.join(&manifest.predictor_file);
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();

        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                engine_thread(plan_path, pred_path, rx, ready_tx);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during init"))??;
        Ok(Arc::new(Engine {
            tx: Mutex::new(tx),
            manifest,
            dispatches: std::sync::atomic::AtomicU64::new(0),
            next_token: std::sync::atomic::AtomicU64::new(1),
        }))
    }

    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn send(&self, job: Job) {
        self.tx
            .lock()
            .expect("engine tx poisoned")
            .send(job)
            .expect("engine thread gone");
    }

    /// Bind an epoch's panels on the engine thread; returns a token for
    /// [`Engine::plan_eval_bound`]. Panels stay device-resident until
    /// [`Engine::unbind_panels`].
    #[allow(clippy::too_many_arguments)]
    pub fn bind_panels(
        &self,
        cls: Vec<f32>,
        thr: Vec<f32>,
        proc: Vec<f32>,
        hops: Vec<f32>,
        dc: Vec<f32>,
        consts: Vec<f32>,
    ) -> anyhow::Result<u64> {
        let token = self
            .next_token
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        self.send(Job::BindPanels {
            token,
            cls,
            thr,
            proc,
            hops,
            dc,
            consts,
            reply,
        });
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine dropped bind reply"))??;
        Ok(token)
    }

    pub fn unbind_panels(&self, token: u64) {
        self.send(Job::UnbindPanels { token });
    }

    /// Evaluate one padded population tile against previously-bound panels.
    pub fn plan_eval_bound(
        &self,
        token: u64,
        a: Vec<f32>,
    ) -> anyhow::Result<Vec<f32>> {
        assert_eq!(a.len(), EVAL_POPULATION * CLASSES * DC_SLOTS);
        let (reply, rx) = mpsc::channel();
        self.send(Job::PlanEvalBound { token, a, reply });
        self.dispatches
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine dropped reply"))?
    }

    /// Execute the plan-eval artifact on one padded population tile.
    /// `a` must be P*K*L floats; returns P*N_OBJ objective floats.
    pub fn plan_eval_raw(
        &self,
        a: Vec<f32>,
        cls: Vec<f32>,
        thr: Vec<f32>,
        proc: Vec<f32>,
        hops: Vec<f32>,
        dc: Vec<f32>,
        consts: Vec<f32>,
    ) -> anyhow::Result<Vec<f32>> {
        assert_eq!(a.len(), EVAL_POPULATION * CLASSES * DC_SLOTS);
        let (reply, rx) = mpsc::channel();
        self.send(Job::PlanEval {
            a,
            cls,
            thr,
            proc,
            hops,
            dc,
            consts,
            reply,
        });
        self.dispatches
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine dropped reply"))?
    }

    /// Execute the predictor artifact: returns (preds[D], rmse[D]).
    pub fn predict_raw(
        &self,
        x: Vec<f32>,
        y: Vec<f32>,
        xq: Vec<f32>,
        lambdas: Vec<f32>,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        assert_eq!(x.len(), self.manifest.window * self.manifest.features);
        let (reply, rx) = mpsc::channel();
        self.send(Job::Predict {
            x,
            y,
            xq,
            lambdas,
            reply,
        });
        self.dispatches
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine dropped reply"))?
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Job::Shutdown);
        }
    }
}

fn engine_thread(
    plan_path: std::path::PathBuf,
    pred_path: std::path::PathBuf,
    rx: mpsc::Receiver<Job>,
    ready: mpsc::Sender<anyhow::Result<()>>,
) {
    let init = (|| -> anyhow::Result<_> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        let load = |p: &std::path::Path| -> anyhow::Result<_> {
            let proto = xla::HloModuleProto::from_text_file(p)
                .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", p.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", p.display()))
        };
        let plan_exe = load(&plan_path)?;
        let pred_exe = load(&pred_path)?;
        Ok((client, plan_exe, pred_exe))
    })();

    let (client, plan_exe, pred_exe) = match init {
        Ok(t) => {
            let _ = ready.send(Ok(()));
            t
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let lit = |data: &[f32], dims: &[i64]| -> anyhow::Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow::anyhow!("literal reshape: {e:?}"))
    };

    let buf = |data: &[f32], dims: &[usize]| -> anyhow::Result<xla::PjRtBuffer> {
        client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow::anyhow!("host->device: {e:?}"))
    };
    // device-resident panel sets keyed by binding token
    let mut bound: std::collections::HashMap<u64, Vec<xla::PjRtBuffer>> =
        std::collections::HashMap::new();
    let kk = CLASSES;
    let ll = DC_SLOTS;

    while let Ok(job) = rx.recv() {
        match job {
            Job::Shutdown => break,
            Job::BindPanels {
                token,
                cls,
                thr,
                proc,
                hops,
                dc,
                consts,
                reply,
            } => {
                let run = (|| -> anyhow::Result<Vec<xla::PjRtBuffer>> {
                    Ok(vec![
                        buf(&cls, &[kk, 3])?,
                        buf(&thr, &[kk, ll])?,
                        buf(&proc, &[kk, ll])?,
                        buf(&hops, &[kk, ll])?,
                        buf(&dc, &[8, ll])?,
                        buf(&consts, &[12])?,
                    ])
                })();
                let _ = match run {
                    Ok(bufs) => {
                        bound.insert(token, bufs);
                        reply.send(Ok(()))
                    }
                    Err(e) => reply.send(Err(e)),
                };
            }
            Job::UnbindPanels { token } => {
                bound.remove(&token);
            }
            Job::PlanEvalBound { token, a, reply } => {
                let run = (|| -> anyhow::Result<Vec<f32>> {
                    let panels = bound.get(&token).ok_or_else(|| {
                        anyhow::anyhow!("panels token {token} not bound")
                    })?;
                    let a_buf = buf(&a, &[EVAL_POPULATION, kk, ll])?;
                    let args: Vec<&xla::PjRtBuffer> =
                        std::iter::once(&a_buf).chain(panels.iter()).collect();
                    let result = plan_exe
                        .execute_b::<&xla::PjRtBuffer>(&args)
                        .map_err(|e| anyhow::anyhow!("execute_b: {e:?}"))?[0]
                        [0]
                        .to_literal_sync()
                        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
                    let out = result
                        .to_tuple1()
                        .map_err(|e| anyhow::anyhow!("tuple: {e:?}"))?;
                    out.to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
                })();
                let _ = reply.send(run);
            }
            Job::PlanEval {
                a,
                cls,
                thr,
                proc,
                hops,
                dc,
                consts,
                reply,
            } => {
                let run = (|| -> anyhow::Result<Vec<f32>> {
                    let p = EVAL_POPULATION as i64;
                    let k = CLASSES as i64;
                    let l = DC_SLOTS as i64;
                    let args = [
                        lit(&a, &[p, k, l])?,
                        lit(&cls, &[k, 3])?,
                        lit(&thr, &[k, l])?,
                        lit(&proc, &[k, l])?,
                        lit(&hops, &[k, l])?,
                        lit(&dc, &[8, l])?,
                        lit(&consts, &[12])?,
                    ];
                    let result = plan_exe
                        .execute::<xla::Literal>(&args)
                        .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
                    // aot.py lowers with return_tuple=True -> 1-tuple
                    let out = result
                        .to_tuple1()
                        .map_err(|e| anyhow::anyhow!("tuple: {e:?}"))?;
                    out.to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
                })();
                let _ = reply.send(run);
            }
            Job::Predict {
                x,
                y,
                xq,
                lambdas,
                reply,
            } => {
                let run = (|| -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
                    let h = x.len() as i64 / xq.len() as i64;
                    let f = xq.len() as i64;
                    let d = lambdas.len() as i64;
                    let args = [
                        lit(&x, &[h, f])?,
                        lit(&y, &[h])?,
                        lit(&xq, &[f])?,
                        lit(&lambdas, &[d])?,
                    ];
                    let result = pred_exe
                        .execute::<xla::Literal>(&args)
                        .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
                    let (preds, rmse) = result
                        .to_tuple2()
                        .map_err(|e| anyhow::anyhow!("tuple2: {e:?}"))?;
                    Ok((
                        preds
                            .to_vec::<f32>()
                            .map_err(|e| anyhow::anyhow!("{e:?}"))?,
                        rmse.to_vec::<f32>()
                            .map_err(|e| anyhow::anyhow!("{e:?}"))?,
                    ))
                })();
                let _ = reply.send(run);
            }
        }
    }
}

/// Epoch-bound plan evaluator running on the AOT artifact. Panels are
/// captured as f32 once; each `eval_batch` pads the population to tiles of
/// P and dispatches to the engine thread.
pub struct HloPlanEvaluator {
    engine: Arc<Engine>,
    /// Device-resident panel binding (uploaded once per epoch; see §Perf).
    token: u64,
    classes: usize,
    dcs: usize,
}

impl HloPlanEvaluator {
    /// Build from the same analytic evaluator the native path uses — the
    /// panels are shared, so parity failures point at the kernel, not the
    /// plumbing. Panels are uploaded to the device once, here.
    pub fn from_analytic(engine: Arc<Engine>, ev: &AnalyticEvaluator) -> Self {
        let (cls, thr, proc, hops, dc) = ev.to_f32_panels(DC_SLOTS);
        let token = engine
            .bind_panels(cls, thr, proc, hops, dc, ev.consts.to_f32_vec())
            .expect("panel binding failed");
        HloPlanEvaluator {
            engine,
            token,
            classes: ev.classes(),
            dcs: ev.dcs(),
        }
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }
}

impl Drop for HloPlanEvaluator {
    fn drop(&mut self) {
        self.engine.unbind_panels(self.token);
    }
}

impl BatchEvaluator for HloPlanEvaluator {
    fn backend(&self) -> &'static str {
        "pjrt-hlo"
    }

    fn eval_batch(&self, plans: &[Plan]) -> Vec<[f64; N_OBJ]> {
        let mut out = Vec::with_capacity(plans.len());
        for tile in plans.chunks(EVAL_POPULATION) {
            let mut a =
                Vec::with_capacity(EVAL_POPULATION * self.classes * DC_SLOTS);
            for p in tile {
                debug_assert_eq!(p.classes, self.classes);
                debug_assert_eq!(p.dcs, self.dcs);
                p.to_f32_padded(DC_SLOTS, &mut a);
            }
            // pad the tile with copies of the first plan
            let pad_plan = &tile[0];
            for _ in tile.len()..EVAL_POPULATION {
                pad_plan.to_f32_padded(DC_SLOTS, &mut a);
            }
            let objs = self
                .engine
                .plan_eval_bound(self.token, a)
                .expect("plan_eval artifact execution failed");
            for (i, _) in tile.iter().enumerate() {
                let mut o = [0.0f64; N_OBJ];
                for j in 0..N_OBJ {
                    o[j] = objs[i * N_OBJ + j] as f64;
                }
                out.push(o);
            }
        }
        out
    }
}

/// Workload predictor running on the AOT ridge-regression artifact.
pub struct HloPredictor {
    engine: Arc<Engine>,
}

impl HloPredictor {
    pub fn new(engine: Arc<Engine>) -> Self {
        HloPredictor { engine }
    }

    /// One-step-ahead prediction for a scalar series. Builds the same
    /// feature matrix as `crate::predictor` (window/lags/harmonics), runs
    /// the D-lambda ridge fit on the artifact, returns the best_fit
    /// prediction (min train RMSE member).
    pub fn predict_series(
        &self,
        series: &[f64],
        epochs_per_day: usize,
    ) -> anyhow::Result<f64> {
        let man = &self.engine.manifest;
        let h = man.window;
        let f = man.features;
        anyhow::ensure!(f == crate::predictor::FEATURES, "feature mismatch");
        if series.len() < 8 {
            return Ok(series.last().copied().unwrap_or(0.0));
        }
        let scale = (series.iter().sum::<f64>() / series.len() as f64).max(1.0);
        // last `h` targets (pad the front by repeating the first value)
        let mut x = Vec::with_capacity(h * f);
        let mut y = Vec::with_capacity(h);
        let start = series.len().saturating_sub(h);
        for t in start..series.len() {
            let feats =
                crate::predictor::features(series, t, scale, epochs_per_day);
            x.extend(feats.iter().map(|&v| v as f32));
            y.push((series[t] / scale) as f32);
        }
        while y.len() < h {
            // replicate the oldest row to fill the fixed window
            let row: Vec<f32> = x[..f].to_vec();
            x.splice(0..0, row);
            let v = y[0];
            y.insert(0, v);
        }
        let xq = crate::predictor::features(
            series,
            series.len(),
            scale,
            epochs_per_day,
        );
        let lambdas: Vec<f32> = crate::predictor::LAMBDAS
            .iter()
            .map(|&l| l as f32)
            .collect();
        let (preds, rmse) = self.engine.predict_raw(
            x,
            y,
            xq.iter().map(|&v| v as f32).collect(),
            lambdas,
        )?;
        let best = rmse
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        Ok((preds[best] as f64 * scale).max(0.0))
    }
}
