//! PJRT runtime: loads the AOT artifacts (HLO text lowered from the L2 JAX
//! graph + L1 Pallas kernel by `make artifacts`) and executes them on the
//! `xla` crate's CPU PJRT client from the rust hot path.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so a dedicated engine thread
//! owns the client and compiled executables; callers talk to it over
//! channels. [`Engine`] is the cloneable, thread-safe handle;
//! [`HloPlanEvaluator`] binds an epoch's parameter panels and implements
//! [`crate::eval::BatchEvaluator`] so the SLIT optimizer can search against
//! the AOT artifact transparently.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md for why serialized protos are rejected).
//!
//! The artifact is lowered for exactly `DC_SLOTS` padded DC columns, so
//! the AOT backend only serves fleets that fit the inline tile; larger
//! fleets are analytic-only and every AOT-selecting call site gates on
//! `SystemConfig::validate_aot` (DESIGN.md §14). [`Manifest::validate`]
//! keeps rejecting shape-mismatched artifacts regardless.

#[cfg(feature = "pjrt")]
mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
mod engine;

pub use engine::{Engine, HloPlanEvaluator, HloPredictor};

use crate::util::json::Json;

/// Parsed artifacts/manifest.json, checked against the crate's constants.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub plan_eval_file: String,
    pub predictor_file: String,
    /// Population tile P the plan_eval artifact was lowered for.
    pub population: usize,
    pub classes: usize,
    pub dc_slots: usize,
    pub n_obj: usize,
    pub window: usize,
    pub features: usize,
    pub lambdas: usize,
}

impl Manifest {
    pub fn load(dir: &std::path::Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let pe = j
            .get("plan_eval")
            .ok_or_else(|| anyhow::anyhow!("manifest missing plan_eval"))?;
        let pr = j
            .get("predictor")
            .ok_or_else(|| anyhow::anyhow!("manifest missing predictor"))?;
        let m = Manifest {
            plan_eval_file: pe
                .get("file")
                .and_then(Json::as_str)
                .unwrap_or("plan_eval.hlo.txt")
                .to_string(),
            predictor_file: pr
                .get("file")
                .and_then(Json::as_str)
                .unwrap_or("predictor.hlo.txt")
                .to_string(),
            population: pe.usize_or("population", 0),
            classes: pe.usize_or("classes", 0),
            dc_slots: pe.usize_or("dc_slots", 0),
            n_obj: pe.usize_or("n_obj", 4),
            window: pr.usize_or("window", 0),
            features: pr.usize_or("features", 0),
            lambdas: pr.usize_or("lambdas", 0),
        };
        m.validate()?;
        Ok(m)
    }

    /// Refuse to run against artifacts whose shapes disagree with the
    /// crate's compiled-in layout.
    pub fn validate(&self) -> anyhow::Result<()> {
        use crate::config::{CLASSES, DC_SLOTS, EVAL_POPULATION, N_OBJ};
        anyhow::ensure!(
            self.population == EVAL_POPULATION,
            "artifact population {} != crate {}",
            self.population,
            EVAL_POPULATION
        );
        anyhow::ensure!(
            self.classes == CLASSES,
            "artifact classes {} != crate {}",
            self.classes,
            CLASSES
        );
        anyhow::ensure!(
            self.dc_slots == DC_SLOTS,
            "artifact dc_slots {} != crate {}",
            self.dc_slots,
            DC_SLOTS
        );
        anyhow::ensure!(self.n_obj == N_OBJ, "objective count mismatch");
        anyhow::ensure!(
            self.window > 0 && self.features > 0 && self.lambdas > 0,
            "degenerate predictor shapes"
        );
        Ok(())
    }
}

/// Default artifacts directory: $SLIT_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("SLIT_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// True when the AOT artifacts exist (tests skip gracefully otherwise).
pub fn artifacts_present() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// True when the crate links the real PJRT engine (`pjrt` feature). Tests
/// and benches that would execute artifacts must gate on this **and**
/// [`artifacts_present`] — with the stub build, `Engine::load` always
/// fails even if artifacts exist on disk.
pub const fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_rejects_bad_shapes() {
        let m = Manifest {
            plan_eval_file: "x".into(),
            predictor_file: "y".into(),
            population: 64, // wrong
            classes: crate::config::CLASSES,
            dc_slots: crate::config::DC_SLOTS,
            n_obj: 4,
            window: 192,
            features: 8,
            lambdas: 4,
        };
        assert!(m.validate().is_err());
    }

    #[test]
    fn manifest_loads_real_artifacts_when_present() {
        if !artifacts_present() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert_eq!(m.population, crate::config::EVAL_POPULATION);
        assert_eq!(m.dc_slots, crate::config::DC_SLOTS);
    }
}
