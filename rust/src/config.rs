//! Configuration system: the full experiment setup of the paper as data.
//!
//! The paper's testbed (§6): 12 datacenters spread over four regions
//! (East Asia, Oceania, North America, Western Europe), 1000 heterogeneous
//! nodes per datacenter drawn from six node types (2-8 GPUs of A100 or
//! H100), two served models (Llama-7B / Llama-70B), 15-minute epochs, and a
//! 24-hour evaluation window at 0.5x request delay / 3x tokens / 10x
//! request count relative to the BurstGPT trace.
//!
//! Everything is plain data with JSON load/save (`util::json`), so every
//! experiment is reproducible from a config file + seed.

use crate::util::json::Json;

/// Geographic regions (request origins and datacenter sites).
pub const REGIONS: usize = 4;
pub const REGION_NAMES: [&str; REGIONS] =
    ["east-asia", "oceania", "north-america", "western-europe"];

/// Served model families.
pub const MODELS: usize = 2;
pub const MODEL_NAMES: [&str; MODELS] = ["llama-7b", "llama-70b"];

/// Request classes: (origin region, model) pairs; k = region * MODELS + model.
pub const CLASSES: usize = REGIONS * MODELS;

/// Datacenters in the paper's testbed.
pub const DATACENTERS: usize = 12;

/// Padded DC slots in the AOT plan-eval artifact (see python/compile/shapes.py).
pub const DC_SLOTS: usize = 16;

/// Population tile of the AOT plan evaluator.
pub const EVAL_POPULATION: usize = 128;

/// Epochs per day at 15-minute epochs.
pub const EPOCHS_PER_DAY: usize = 96;

/// Objective vector layout (all minimised).
pub const N_OBJ: usize = 4;
pub const OBJ_NAMES: [&str; N_OBJ] = ["ttft_s", "carbon_kg", "water_l", "cost_usd"];
pub const OBJ_TTFT: usize = 0;
pub const OBJ_CARBON: usize = 1;
pub const OBJ_WATER: usize = 2;
pub const OBJ_COST: usize = 3;

/// Inter-region router hop counts (Eq. 3); symmetric, diagonal = intra-region.
pub const REGION_HOPS: [[f64; REGIONS]; REGIONS] = [
    [2.0, 6.0, 9.0, 11.0],  // east-asia
    [6.0, 2.0, 10.0, 12.0], // oceania
    [9.0, 10.0, 2.0, 7.0],  // north-america
    [11.0, 12.0, 7.0, 2.0], // western-europe
];

/// A served LLM (Eq. 1 parameters).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    /// Parameter memory M_O, GB.
    pub param_mem_gb: f64,
    /// KV-cache growth per output token, GB (M_KV in Eq. 1).
    pub kv_gb_per_token: f64,
    /// Mean output tokens per request (scaled by workload token_scale).
    pub mean_out_tokens: f64,
    /// Mean input tokens per request.
    pub mean_in_tokens: f64,
    /// TTFT service-level objective, seconds — the deadline budget the
    /// serving coordinator's Least-Laxity-First dispatch orders against
    /// (laxity = SLO - queued age - predicted first-token service). Sized
    /// off the Eq. 4 TTFT scale: warm requests land well inside it; cold
    /// large-model loads may overshoot (negative laxity = most urgent).
    pub ttft_slo_s: f64,
}

/// One of the six heterogeneous node types (§6).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeType {
    pub name: String,
    pub gpus: usize,
    /// Per-GPU memory, GB (pooled across the node, §3.2).
    pub gpu_mem_gb: f64,
    /// Node thermal design power, W (Eq. 5).
    pub tdp_w: f64,
    /// Serving throughput per node, tokens/s, per model.
    pub thr_tokens_s: [f64; MODELS],
    /// Per-request decode rate, tokens/s, per model (Eq. 4 T_exec/N term).
    pub decode_tokens_s: [f64; MODELS],
}

/// Static description of one datacenter site.
#[derive(Clone, Debug, PartialEq)]
pub struct DatacenterSpec {
    pub name: String,
    pub region: usize,
    /// Nodes of each node type (sums to ~1000 in the paper setup).
    pub nodes_per_type: Vec<usize>,
    /// Cooling coefficient of performance (Eq. 7).
    pub cop: f64,
    /// Model-load bandwidth, GB/s (Eq. 2).
    pub bw_gbs: f64,
    /// Local solar-time offset, hours (drives diurnal signals).
    pub tz_offset_h: f64,
    /// Carbon-intensity profile: (base kg/kWh, diurnal amplitude frac).
    pub ci_base: f64,
    pub ci_amp: f64,
    /// Water intensity of the grid, L/kWh (Eq. 14), with diurnal amplitude.
    pub wi_base: f64,
    pub wi_amp: f64,
    /// Time-of-use price, $/kWh base + peak uplift fraction (Eq. 11).
    pub tou_base: f64,
    pub tou_amp: f64,
}

impl DatacenterSpec {
    pub fn total_nodes(&self) -> usize {
        self.nodes_per_type.iter().sum()
    }
}

/// Workload scaling knobs (§6: 0.5x delay, 3x tokens, 10x requests).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    /// Multiplier on request counts vs the base trace.
    pub request_scale: f64,
    /// Multiplier on token counts.
    pub token_scale: f64,
    /// Multiplier on inter-arrival delay (0.5 = twice the arrival rate).
    pub delay_scale: f64,
    /// Fraction of requests hitting the small model (trend 1 from Fig. 1).
    pub small_model_frac: f64,
    /// Base requests per epoch across all regions (pre-scaling).
    pub base_requests_per_epoch: f64,
    /// Burstiness: probability an epoch is a spike, and spike multiplier.
    pub burst_prob: f64,
    pub burst_mult: f64,
    /// Regional share of request origins (sums to 1).
    pub region_mix: [f64; REGIONS],
    /// Fraction of each class's request mass that is deferrable
    /// (batch/embedding jobs the temporal-shifting layer may move in time).
    /// 0 (the default) generates a purely interactive trace, bit-identical
    /// to pre-deferrable builds.
    pub deferrable_frac: f64,
    /// Deadline slack for deferrable mass: arrivals at epoch t must be
    /// served by epoch t + slack (clamped to the trace horizon).
    pub defer_slack_epochs: usize,
}

/// SLIT metaheuristic knobs (Algorithm 1).
#[derive(Clone, Debug, PartialEq)]
pub struct OptConfig {
    /// Population size X.
    pub population: usize,
    /// Outer iterations `gen`.
    pub generations: usize,
    /// Local-search steps per plan per generation.
    pub search_steps: usize,
    /// Neighbour candidates scored (by the surrogate) per step.
    pub neighbors: usize,
    /// Local-search step size (Dirichlet-ish perturbation scale).
    pub step: f64,
    /// Surrogate retrain frequency `freq` (generations).
    pub train_freq: usize,
    /// EA mutation probability per gene.
    pub mutation_rate: f64,
    /// GBDT: number of trees / depth / learning rate / min leaf.
    pub gbdt_trees: usize,
    pub gbdt_depth: usize,
    pub gbdt_lr: f64,
    pub gbdt_min_leaf: usize,
    /// Pareto archive capacity.
    pub archive_cap: usize,
    /// Wall-clock budget per epoch decision, seconds (paper: <= 15 min).
    pub budget_s: f64,
}

/// Physical constants shared with the AOT kernel (shapes.CONSTS layout).
#[derive(Clone, Debug, PartialEq)]
pub struct PhysicsConfig {
    /// Epoch length, seconds (paper: 15 minutes).
    pub epoch_s: f64,
    /// Power ratio of ON nodes (x TDP, Eq. 5).
    pub pr_on: f64,
    /// Power ratio of IDLE nodes.
    pub pr_idle: f64,
    /// Power ratio of OFF nodes (serverless scale-to-zero floor).
    pub pr_off: f64,
    /// Heat absorbed per liter of evaporated water, J/L (Eq. 12).
    pub h_water: f64,
    /// Blowdown solids ratio D (Eq. 13).
    pub d_ratio: f64,
    /// Potable / wastewater treatment energy intensity, kWh/L (Eq. 17).
    pub ei_pot: f64,
    pub ei_waste: f64,
    /// Inter-router latency per hop, s (Eq. 3).
    pub k_media: f64,
    /// Queueing-delay coefficient, s, and utilisation clip.
    pub q_coef: f64,
    pub u_max: f64,
    /// Fraction of requests paying the model-load latency (Eq. 2).
    pub cold_frac: f64,
    /// TTFT penalty charged when a request cannot be placed anywhere this
    /// epoch (re-queue latency), seconds.
    pub drop_penalty_s: f64,
}

/// Top-level experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    pub seed: u64,
    /// Number of epochs simulated (96 = the paper's 24 h window).
    pub epochs: usize,
    pub physics: PhysicsConfig,
    pub models: Vec<ModelSpec>,
    pub node_types: Vec<NodeType>,
    pub datacenters: Vec<DatacenterSpec>,
    pub workload: WorkloadConfig,
    pub opt: OptConfig,
}

impl SystemConfig {
    /// The paper's experimental setup (§6) with public-datasheet constants.
    pub fn paper_default() -> SystemConfig {
        let models = vec![
            ModelSpec {
                name: MODEL_NAMES[0].into(),
                param_mem_gb: 14.0,
                kv_gb_per_token: 0.0005,
                mean_out_tokens: 180.0,
                mean_in_tokens: 380.0,
                ttft_slo_s: 1.5,
            },
            ModelSpec {
                name: MODEL_NAMES[1].into(),
                param_mem_gb: 140.0,
                kv_gb_per_token: 0.0025,
                mean_out_tokens: 260.0,
                mean_in_tokens: 520.0,
                ttft_slo_s: 6.0,
            },
        ];

        // Six node types: {2,4,8} GPUs x {A100, H100}. TDP = GPUs x GPU TDP
        // + 350 W host. Throughputs from public serving benchmarks, scaled
        // sublinearly with GPU count (NVLink batching efficiency 0.9).
        let node_types = vec![
            node_type("a100x2", 2, 80.0, 400.0, 1.0),
            node_type("a100x4", 4, 80.0, 400.0, 1.0),
            node_type("a100x8", 8, 80.0, 400.0, 1.0),
            node_type("h100x2", 2, 80.0, 700.0, 2.0),
            node_type("h100x4", 4, 80.0, 700.0, 2.0),
            node_type("h100x8", 8, 80.0, 700.0, 2.0),
        ];

        // 12 datacenters, 3 per region, ~1000 nodes each (§6). Node-type
        // mixes are heterogeneous across sites (A100-heavy / balanced /
        // H100-heavy rotation) — §3.2's "different combinations and amounts
        // of processing capabilities". Grid parameters straddle the cited
        // extremes: wind-heavy grids at 0.2 L/kWh vs hydro-heavy at up to
        // 67 L/kWh [25]; CI from ~0.02 (hydro/nuclear) to ~0.8 kg/kWh
        // (coal).
        const MIXES: [[usize; 6]; 3] = [
            [250, 200, 150, 200, 150, 50],  // A100-heavy
            [167, 167, 167, 167, 166, 166], // balanced
            [50, 150, 200, 150, 200, 250],  // H100-heavy
        ];
        let mut dc_idx = 0usize;
        let mut dc = |name: &str,
                      region: usize,
                      tz: f64,
                      ci: (f64, f64),
                      wi: (f64, f64),
                      tou: (f64, f64),
                      cop: f64,
                      bw: f64| {
            let mix = MIXES[dc_idx % MIXES.len()];
            dc_idx += 1;
            DatacenterSpec {
                name: name.into(),
                region,
                nodes_per_type: mix.to_vec(),
                cop,
                bw_gbs: bw,
                tz_offset_h: tz,
                ci_base: ci.0,
                ci_amp: ci.1,
                wi_base: wi.0,
                wi_amp: wi.1,
                tou_base: tou.0,
                tou_amp: tou.1,
            }
        };
        let datacenters = vec![
            // East Asia: coal-heavy grids, high CI; moderate water.
            dc("tokyo", 0, 9.0, (0.48, 0.25), (1.9, 0.2), (0.19, 0.5), 4.5, 12.0),
            dc("seoul", 0, 9.0, (0.42, 0.2), (1.6, 0.2), (0.17, 0.5), 4.0, 10.0),
            dc("singapore", 0, 8.0, (0.41, 0.1), (2.3, 0.15), (0.16, 0.35), 3.2, 14.0),
            // Oceania: solar midday dip (big diurnal CI swing), hydro NZ.
            dc("sydney", 1, 10.0, (0.55, 0.45), (1.4, 0.25), (0.21, 0.55), 4.8, 9.0),
            dc("melbourne", 1, 10.0, (0.60, 0.4), (1.5, 0.25), (0.2, 0.5), 5.0, 9.0),
            dc("auckland", 1, 12.0, (0.09, 0.3), (24.0, 0.3), (0.15, 0.3), 5.5, 7.0),
            // North America: mixed; hydro-heavy Pacific NW (high WI, low CI).
            dc("virginia", 2, -5.0, (0.35, 0.3), (2.1, 0.2), (0.09, 0.6), 4.2, 18.0),
            dc("oregon", 2, -8.0, (0.11, 0.35), (31.0, 0.35), (0.07, 0.45), 6.0, 16.0),
            dc("iowa", 2, -6.0, (0.30, 0.5), (1.1, 0.3), (0.08, 0.5), 5.2, 14.0),
            // Western Europe: wind-heavy north (low CI, very low WI).
            dc("dublin", 3, 0.0, (0.28, 0.5), (0.7, 0.3), (0.18, 0.5), 6.5, 13.0),
            dc("frankfurt", 3, 1.0, (0.33, 0.4), (1.2, 0.25), (0.24, 0.55), 5.0, 15.0),
            dc("stockholm", 3, 1.0, (0.03, 0.3), (9.0, 0.3), (0.06, 0.35), 7.5, 11.0),
        ];

        SystemConfig {
            seed: 0xC0FFEE,
            epochs: EPOCHS_PER_DAY,
            physics: PhysicsConfig {
                epoch_s: 900.0,
                pr_on: 1.0,
                pr_idle: 0.3,
                // serverless scale-to-zero (§6: containers on a serverless
                // infrastructure): a site with no assigned load draws no
                // marginal IT power — the source of SLIT's Fig. 4 wins
                pr_off: 0.0,
                h_water: 2.45e6,
                d_ratio: 0.3,
                ei_pot: 0.003,
                ei_waste: 0.0015,
                k_media: 0.01,
                q_coef: 0.25,
                u_max: 0.995,
                cold_frac: 0.01,
                drop_penalty_s: 60.0,
            },
            models,
            node_types,
            datacenters,
            workload: WorkloadConfig {
                request_scale: 10.0,
                token_scale: 3.0,
                delay_scale: 0.5,
                small_model_frac: 0.8,
                base_requests_per_epoch: 6000.0,
                burst_prob: 0.06,
                burst_mult: 3.5,
                region_mix: [0.3, 0.1, 0.35, 0.25],
                deferrable_frac: 0.0,
                defer_slack_epochs: 0,
            },
            opt: OptConfig {
                population: 24,
                generations: 12,
                search_steps: 6,
                neighbors: 8,
                step: 0.25,
                train_freq: 3,
                mutation_rate: 0.08,
                gbdt_trees: 40,
                gbdt_depth: 3,
                gbdt_lr: 0.15,
                gbdt_min_leaf: 8,
                archive_cap: 128,
                budget_s: 900.0,
            },
        }
    }

    /// A scaled-down configuration for unit tests and quick benches.
    pub fn small_test() -> SystemConfig {
        let mut c = SystemConfig::paper_default();
        c.epochs = 8;
        for dc in &mut c.datacenters {
            dc.nodes_per_type = vec![10, 10, 10, 10, 10, 10];
        }
        c.workload.base_requests_per_epoch = 400.0;
        c.workload.request_scale = 1.0;
        c.opt.population = 12;
        c.opt.generations = 4;
        c.opt.search_steps = 3;
        c.opt.neighbors = 4;
        c.opt.gbdt_trees = 10;
        c
    }

    pub fn num_classes(&self) -> usize {
        REGIONS * self.models.len()
    }

    /// Hop count from an origin region to a datacenter (Eq. 3 R term).
    pub fn hops(&self, origin_region: usize, dc: usize) -> f64 {
        REGION_HOPS[origin_region][self.datacenters[dc].region]
    }

    // --- json round-trip ----------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("seed", Json::Num(self.seed as f64));
        j.set("epochs", Json::Num(self.epochs as f64));
        let p = &self.physics;
        j.set(
            "physics",
            Json::from_pairs(vec![
                ("epoch_s", Json::Num(p.epoch_s)),
                ("pr_on", Json::Num(p.pr_on)),
                ("pr_idle", Json::Num(p.pr_idle)),
                ("pr_off", Json::Num(p.pr_off)),
                ("h_water", Json::Num(p.h_water)),
                ("d_ratio", Json::Num(p.d_ratio)),
                ("ei_pot", Json::Num(p.ei_pot)),
                ("ei_waste", Json::Num(p.ei_waste)),
                ("k_media", Json::Num(p.k_media)),
                ("q_coef", Json::Num(p.q_coef)),
                ("u_max", Json::Num(p.u_max)),
                ("cold_frac", Json::Num(p.cold_frac)),
                ("drop_penalty_s", Json::Num(p.drop_penalty_s)),
            ]),
        );
        j.set(
            "models",
            Json::Arr(
                self.models
                    .iter()
                    .map(|m| {
                        Json::from_pairs(vec![
                            ("name", Json::Str(m.name.clone())),
                            ("param_mem_gb", Json::Num(m.param_mem_gb)),
                            ("kv_gb_per_token", Json::Num(m.kv_gb_per_token)),
                            ("mean_out_tokens", Json::Num(m.mean_out_tokens)),
                            ("mean_in_tokens", Json::Num(m.mean_in_tokens)),
                            ("ttft_slo_s", Json::Num(m.ttft_slo_s)),
                        ])
                    })
                    .collect(),
            ),
        );
        j.set(
            "node_types",
            Json::Arr(
                self.node_types
                    .iter()
                    .map(|n| {
                        Json::from_pairs(vec![
                            ("name", Json::Str(n.name.clone())),
                            ("gpus", Json::Num(n.gpus as f64)),
                            ("gpu_mem_gb", Json::Num(n.gpu_mem_gb)),
                            ("tdp_w", Json::Num(n.tdp_w)),
                            ("thr_tokens_s", Json::num_arr(&n.thr_tokens_s)),
                            (
                                "decode_tokens_s",
                                Json::num_arr(&n.decode_tokens_s),
                            ),
                        ])
                    })
                    .collect(),
            ),
        );
        j.set(
            "datacenters",
            Json::Arr(
                self.datacenters
                    .iter()
                    .map(|d| {
                        Json::from_pairs(vec![
                            ("name", Json::Str(d.name.clone())),
                            ("region", Json::Num(d.region as f64)),
                            (
                                "nodes_per_type",
                                Json::num_arr(
                                    &d.nodes_per_type
                                        .iter()
                                        .map(|&n| n as f64)
                                        .collect::<Vec<_>>(),
                                ),
                            ),
                            ("cop", Json::Num(d.cop)),
                            ("bw_gbs", Json::Num(d.bw_gbs)),
                            ("tz_offset_h", Json::Num(d.tz_offset_h)),
                            ("ci_base", Json::Num(d.ci_base)),
                            ("ci_amp", Json::Num(d.ci_amp)),
                            ("wi_base", Json::Num(d.wi_base)),
                            ("wi_amp", Json::Num(d.wi_amp)),
                            ("tou_base", Json::Num(d.tou_base)),
                            ("tou_amp", Json::Num(d.tou_amp)),
                        ])
                    })
                    .collect(),
            ),
        );
        let w = &self.workload;
        j.set(
            "workload",
            Json::from_pairs(vec![
                ("request_scale", Json::Num(w.request_scale)),
                ("token_scale", Json::Num(w.token_scale)),
                ("delay_scale", Json::Num(w.delay_scale)),
                ("small_model_frac", Json::Num(w.small_model_frac)),
                (
                    "base_requests_per_epoch",
                    Json::Num(w.base_requests_per_epoch),
                ),
                ("burst_prob", Json::Num(w.burst_prob)),
                ("burst_mult", Json::Num(w.burst_mult)),
                ("region_mix", Json::num_arr(&w.region_mix)),
                ("deferrable_frac", Json::Num(w.deferrable_frac)),
                (
                    "defer_slack_epochs",
                    Json::Num(w.defer_slack_epochs as f64),
                ),
            ]),
        );
        let o = &self.opt;
        j.set(
            "opt",
            Json::from_pairs(vec![
                ("population", Json::Num(o.population as f64)),
                ("generations", Json::Num(o.generations as f64)),
                ("search_steps", Json::Num(o.search_steps as f64)),
                ("neighbors", Json::Num(o.neighbors as f64)),
                ("step", Json::Num(o.step)),
                ("train_freq", Json::Num(o.train_freq as f64)),
                ("mutation_rate", Json::Num(o.mutation_rate)),
                ("gbdt_trees", Json::Num(o.gbdt_trees as f64)),
                ("gbdt_depth", Json::Num(o.gbdt_depth as f64)),
                ("gbdt_lr", Json::Num(o.gbdt_lr)),
                ("gbdt_min_leaf", Json::Num(o.gbdt_min_leaf as f64)),
                ("archive_cap", Json::Num(o.archive_cap as f64)),
                ("budget_s", Json::Num(o.budget_s)),
            ]),
        );
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SystemConfig> {
        let mut c = SystemConfig::paper_default();
        c.seed = j.f64_or("seed", c.seed as f64) as u64;
        c.epochs = j.usize_or("epochs", c.epochs);
        if let Some(p) = j.get("physics") {
            let d = &c.physics;
            c.physics = PhysicsConfig {
                epoch_s: p.f64_or("epoch_s", d.epoch_s),
                pr_on: p.f64_or("pr_on", d.pr_on),
                pr_idle: p.f64_or("pr_idle", d.pr_idle),
                pr_off: p.f64_or("pr_off", d.pr_off),
                h_water: p.f64_or("h_water", d.h_water),
                d_ratio: p.f64_or("d_ratio", d.d_ratio),
                ei_pot: p.f64_or("ei_pot", d.ei_pot),
                ei_waste: p.f64_or("ei_waste", d.ei_waste),
                k_media: p.f64_or("k_media", d.k_media),
                q_coef: p.f64_or("q_coef", d.q_coef),
                u_max: p.f64_or("u_max", d.u_max),
                cold_frac: p.f64_or("cold_frac", d.cold_frac),
                drop_penalty_s: p.f64_or("drop_penalty_s", d.drop_penalty_s),
            };
        }
        if let Some(ms) = j.get("models").and_then(Json::as_arr) {
            c.models = ms
                .iter()
                .map(|m| ModelSpec {
                    name: m
                        .get("name")
                        .and_then(Json::as_str)
                        .unwrap_or("model")
                        .into(),
                    param_mem_gb: m.f64_or("param_mem_gb", 14.0),
                    kv_gb_per_token: m.f64_or("kv_gb_per_token", 5e-4),
                    mean_out_tokens: m.f64_or("mean_out_tokens", 200.0),
                    mean_in_tokens: m.f64_or("mean_in_tokens", 400.0),
                    // pre-SLO config files get a mid-range deadline
                    ttft_slo_s: m.f64_or("ttft_slo_s", 3.0),
                })
                .collect();
        }
        if let Some(ns) = j.get("node_types").and_then(Json::as_arr) {
            c.node_types = ns
                .iter()
                .map(|n| {
                    let thr = n
                        .f64_vec("thr_tokens_s")
                        .unwrap_or_else(|| vec![1000.0, 100.0]);
                    let dec = n
                        .f64_vec("decode_tokens_s")
                        .unwrap_or_else(|| vec![50.0, 10.0]);
                    NodeType {
                        name: n
                            .get("name")
                            .and_then(Json::as_str)
                            .unwrap_or("node")
                            .into(),
                        gpus: n.usize_or("gpus", 2),
                        gpu_mem_gb: n.f64_or("gpu_mem_gb", 80.0),
                        tdp_w: n.f64_or("tdp_w", 1200.0),
                        thr_tokens_s: [thr[0], thr[1]],
                        decode_tokens_s: [dec[0], dec[1]],
                    }
                })
                .collect();
        }
        if let Some(ds) = j.get("datacenters").and_then(Json::as_arr) {
            c.datacenters = ds
                .iter()
                .map(|d| DatacenterSpec {
                    name: d
                        .get("name")
                        .and_then(Json::as_str)
                        .unwrap_or("dc")
                        .into(),
                    region: d.usize_or("region", 0).min(REGIONS - 1),
                    nodes_per_type: d
                        .f64_vec("nodes_per_type")
                        .unwrap_or_else(|| vec![167.0; 6])
                        .iter()
                        .map(|&x| x as usize)
                        .collect(),
                    cop: d.f64_or("cop", 4.0),
                    bw_gbs: d.f64_or("bw_gbs", 12.0),
                    tz_offset_h: d.f64_or("tz_offset_h", 0.0),
                    ci_base: d.f64_or("ci_base", 0.3),
                    ci_amp: d.f64_or("ci_amp", 0.3),
                    wi_base: d.f64_or("wi_base", 2.0),
                    wi_amp: d.f64_or("wi_amp", 0.2),
                    tou_base: d.f64_or("tou_base", 0.12),
                    tou_amp: d.f64_or("tou_amp", 0.5),
                })
                .collect();
        }
        if let Some(w) = j.get("workload") {
            let d = &c.workload;
            let mix = w
                .f64_vec("region_mix")
                .unwrap_or_else(|| d.region_mix.to_vec());
            c.workload = WorkloadConfig {
                request_scale: w.f64_or("request_scale", d.request_scale),
                token_scale: w.f64_or("token_scale", d.token_scale),
                delay_scale: w.f64_or("delay_scale", d.delay_scale),
                small_model_frac: w
                    .f64_or("small_model_frac", d.small_model_frac),
                base_requests_per_epoch: w
                    .f64_or("base_requests_per_epoch", d.base_requests_per_epoch),
                burst_prob: w.f64_or("burst_prob", d.burst_prob),
                burst_mult: w.f64_or("burst_mult", d.burst_mult),
                region_mix: [mix[0], mix[1], mix[2], mix[3]],
                deferrable_frac: w
                    .f64_or("deferrable_frac", d.deferrable_frac),
                defer_slack_epochs: w
                    .usize_or("defer_slack_epochs", d.defer_slack_epochs),
            };
        }
        if let Some(o) = j.get("opt") {
            let d = &c.opt;
            c.opt = OptConfig {
                population: o.usize_or("population", d.population),
                generations: o.usize_or("generations", d.generations),
                search_steps: o.usize_or("search_steps", d.search_steps),
                neighbors: o.usize_or("neighbors", d.neighbors),
                step: o.f64_or("step", d.step),
                train_freq: o.usize_or("train_freq", d.train_freq),
                mutation_rate: o.f64_or("mutation_rate", d.mutation_rate),
                gbdt_trees: o.usize_or("gbdt_trees", d.gbdt_trees),
                gbdt_depth: o.usize_or("gbdt_depth", d.gbdt_depth),
                gbdt_lr: o.f64_or("gbdt_lr", d.gbdt_lr),
                gbdt_min_leaf: o.usize_or("gbdt_min_leaf", d.gbdt_min_leaf),
                archive_cap: o.usize_or("archive_cap", d.archive_cap),
                budget_s: o.f64_or("budget_s", d.budget_s),
            };
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &str) -> anyhow::Result<SystemConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        SystemConfig::from_json(&j)
    }

    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Sanity-check invariants the rest of the system relies on.
    ///
    /// Deliberately **L-generic**: the analytic evaluator, the planner,
    /// and the simulator handle any fleet size (per-DC state lives in
    /// `util::dcvec::DcVec` tiles), so the old `datacenters.len() <=
    /// DC_SLOTS` hard cap no longer lives here. That bound is an
    /// AOT-artifact constraint only — callers selecting the AOT/PJRT
    /// backend must additionally pass [`SystemConfig::validate_aot`].
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.datacenters.is_empty(), "no datacenters");
        anyhow::ensure!(
            self.models.len() == MODELS,
            "exactly {MODELS} models expected (AOT class layout)"
        );
        anyhow::ensure!(self.epochs > 0, "epochs must be positive");
        anyhow::ensure!(self.physics.epoch_s > 0.0, "epoch_s must be positive");
        for d in &self.datacenters {
            anyhow::ensure!(
                d.nodes_per_type.len() == self.node_types.len(),
                "dc {} node_per_type len mismatch",
                d.name
            );
            anyhow::ensure!(d.cop > 0.0, "dc {} cop must be > 0", d.name);
            anyhow::ensure!(d.bw_gbs > 0.0, "dc {} bw must be > 0", d.name);
        }
        for n in &self.node_types {
            anyhow::ensure!(
                n.thr_tokens_s.iter().all(|&t| t > 0.0),
                "node {} throughput must be > 0",
                n.name
            );
        }
        for m in &self.models {
            anyhow::ensure!(
                m.ttft_slo_s.is_finite() && m.ttft_slo_s > 0.0,
                "model {} ttft_slo_s must be a positive finite deadline",
                m.name
            );
        }
        let mix_sum: f64 = self.workload.region_mix.iter().sum();
        anyhow::ensure!(
            (mix_sum - 1.0).abs() < 1e-6,
            "region_mix must sum to 1 (got {mix_sum})"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.workload.deferrable_frac),
            "deferrable_frac must be in [0, 1]"
        );
        anyhow::ensure!(self.opt.population >= 4, "population too small");
        Ok(())
    }

    /// The AOT/PJRT-backend-only constraint: the compiled plan-eval
    /// artifact is lowered for exactly [`DC_SLOTS`] padded DC columns
    /// (python/compile/shapes.py), so fleets past that must run on the
    /// L-generic analytic backend. Checked wherever the AOT backend is
    /// actually selected (`registry::build` with an engine, `--use-hlo`
    /// paths), never as a global invariant.
    pub fn validate_aot(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.datacenters.len() <= DC_SLOTS,
            "fleet has {} datacenters but the AOT plan-eval artifact is \
             compiled for {DC_SLOTS} padded DC slots — this fleet is \
             analytic-only (drop --use-hlo / the engine), or re-lower the \
             artifact with more slots",
            self.datacenters.len()
        );
        Ok(())
    }
}

/// Helper constructing one of the six paper node types.
fn node_type(
    name: &str,
    gpus: usize,
    gpu_mem: f64,
    gpu_tdp: f64,
    speed: f64,
) -> NodeType {
    let eff = 0.9f64.powi(gpus as i32 / 2); // multi-GPU batching efficiency
    NodeType {
        name: name.into(),
        gpus,
        gpu_mem_gb: gpu_mem,
        tdp_w: gpus as f64 * gpu_tdp + 350.0,
        thr_tokens_s: [
            1500.0 * speed * gpus as f64 * eff,
            150.0 * speed * gpus as f64 * eff,
        ],
        decode_tokens_s: [50.0 * speed, 10.0 * speed],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_is_valid() {
        let c = SystemConfig::paper_default();
        c.validate().unwrap();
        assert_eq!(c.datacenters.len(), DATACENTERS);
        assert_eq!(c.node_types.len(), 6);
        assert_eq!(c.models.len(), MODELS);
        assert_eq!(c.num_classes(), CLASSES);
        // ~1000 nodes per site as in §6
        for d in &c.datacenters {
            assert_eq!(d.total_nodes(), 1000, "{}", d.name);
        }
    }

    #[test]
    fn all_regions_have_sites() {
        let c = SystemConfig::paper_default();
        for r in 0..REGIONS {
            assert!(
                c.datacenters.iter().any(|d| d.region == r),
                "region {r} uncovered"
            );
        }
    }

    #[test]
    fn json_round_trip_preserves_config() {
        let c = SystemConfig::paper_default();
        let j = c.to_json();
        let c2 = SystemConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn json_round_trip_small() {
        let c = SystemConfig::small_test();
        let text = c.to_json().to_string_pretty();
        let c2 =
            SystemConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn hops_symmetric_and_intra_smallest() {
        let c = SystemConfig::paper_default();
        for a in 0..REGIONS {
            for b in 0..REGIONS {
                assert_eq!(REGION_HOPS[a][b], REGION_HOPS[b][a]);
                if a != b {
                    assert!(REGION_HOPS[a][b] > REGION_HOPS[a][a]);
                }
            }
        }
        // a DC in the origin region is fewer hops away
        let local = c
            .datacenters
            .iter()
            .position(|d| d.region == 0)
            .unwrap();
        let remote = c
            .datacenters
            .iter()
            .position(|d| d.region == 3)
            .unwrap();
        assert!(c.hops(0, local) < c.hops(0, remote));
    }

    #[test]
    fn validate_rejects_bad_mix() {
        let mut c = SystemConfig::paper_default();
        c.workload.region_mix = [0.5, 0.5, 0.5, 0.5];
        assert!(c.validate().is_err());
    }

    #[test]
    fn oversized_fleet_validates_but_fails_the_aot_gate() {
        // regression for the old hard cap: a fleet past DC_SLOTS is a
        // perfectly valid analytic-backend config now; only the AOT gate
        // rejects it, with a structured error naming the constraint
        let mut c = SystemConfig::paper_default();
        while c.datacenters.len() <= DC_SLOTS {
            let d = c.datacenters[0].clone();
            c.datacenters.push(d);
        }
        c.validate().expect("oversized fleets are analytic-valid");
        let err = c.validate_aot().unwrap_err().to_string();
        assert!(err.contains("analytic-only"), "unhelpful error: {err}");
        assert!(err.contains(&format!("{DC_SLOTS}")));
    }

    #[test]
    fn forty_eight_dc_config_validates_cleanly() {
        // the planet-scale regression from ISSUE 5: 48 sites must pass
        // validate() (and round-trip through JSON) without tripping any
        // AOT-slot assertion
        let mut c = SystemConfig::paper_default();
        let twelve = c.datacenters.clone();
        for rep in 0..3 {
            for d in &twelve {
                let mut d = d.clone();
                d.name = format!("{}-{rep}", d.name);
                c.datacenters.push(d);
            }
        }
        assert_eq!(c.datacenters.len(), 48);
        c.validate().expect("48-DC fleet must validate");
        let c2 = SystemConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
        assert!(c.validate_aot().is_err(), "48 > DC_SLOTS stays AOT-gated");
    }

    #[test]
    fn node_types_h100_faster_than_a100() {
        let c = SystemConfig::paper_default();
        let a = c.node_types.iter().find(|n| n.name == "a100x4").unwrap();
        let h = c.node_types.iter().find(|n| n.name == "h100x4").unwrap();
        assert!(h.thr_tokens_s[0] > a.thr_tokens_s[0]);
        assert!(h.tdp_w > a.tdp_w);
    }
}
