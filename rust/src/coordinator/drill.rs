//! Scripted sustainability drills against a *live* coordinator.
//!
//! A drill is the serving-side counterpart of the offline `outage-rolling`
//! scenario: instead of scheduling `ScenarioEvent`s inside a `SimSession`,
//! it speaks the coordinator's JSON-lines TCP protocol (DESIGN.md §12) to
//! darken a region mid-serve, watch the topology dip in `snapshot` replies,
//! keep traffic flowing through the degraded fleet, and verify the
//! restore — all against a running `slit serve` process.
//!
//! Script (one phase per epoch, epochs forced via `{"op": "tick"}` so the
//! drill is deterministic regardless of the server's wall-clock epoch
//! compression):
//!
//!   1. snapshot the healthy fleet, send one traffic wave
//!   2. `cluster scale-region frac` -> tick -> snapshot (the dip)
//!   3. send a second wave into the degraded fleet (failover exercises)
//!   4. `cluster restore-region` -> tick -> snapshot (the recovery)
//!   5. final ledger; request mass must be conserved (sent == served +
//!      rejected, counted from the drill's own per-request replies, so a
//!      drill against a coordinator with other live clients stays sound)
//!
//! `slit drill` wires this up as a CLI subcommand; the serve-loop test
//! harness (rust/tests/serve_drill.rs) drives the same code over an
//! ephemeral-port coordinator.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::config::{MODELS, REGIONS};
use crate::util::json::Json;

/// Socket read/write deadline. A wedged or half-dead server turns into a
/// structured timeout error instead of hanging the drill (and whatever CI
/// job is running it) forever.
const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Parameters of one scripted outage drill.
#[derive(Clone, Debug)]
pub struct DrillConfig {
    /// Region taken down mid-drill.
    pub region: usize,
    /// Fraction of baseline nodes the region keeps (0.0 = fully dark).
    pub frac: f64,
    /// Requests sent per traffic wave (healthy wave + degraded wave).
    pub requests_per_wave: usize,
}

impl Default for DrillConfig {
    fn default() -> Self {
        DrillConfig {
            region: 2, // north-america: the largest origin share
            frac: 0.0,
            requests_per_wave: 64,
        }
    }
}

/// What the drill observed; [`DrillReport::verify`] turns it into a
/// pass/fail judgement.
#[derive(Clone, Debug)]
pub struct DrillReport {
    pub baseline_nodes: f64,
    pub dipped_nodes: f64,
    pub recovered_nodes: f64,
    /// Requests this drill sent over the wire.
    pub sent: u64,
    /// Outcomes of the drill's own requests, counted from the per-request
    /// batch replies (independent of any concurrent client traffic).
    pub served: u64,
    pub rejected: u64,
    /// Epoch counter after the final tick.
    pub epoch: f64,
    /// Cumulative carbon (kg) after the drill's ticks.
    pub carbon_kg: f64,
}

impl DrillReport {
    /// The three drill invariants: the topology dipped, it recovered to
    /// baseline, and every request sent is accounted served or rejected.
    pub fn verify(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.dipped_nodes < self.baseline_nodes,
            "no topology dip: {} nodes before, {} during the outage",
            self.baseline_nodes,
            self.dipped_nodes
        );
        anyhow::ensure!(
            self.recovered_nodes == self.baseline_nodes,
            "topology not restored: {} nodes after restore vs {} baseline",
            self.recovered_nodes,
            self.baseline_nodes
        );
        anyhow::ensure!(
            self.served + self.rejected == self.sent,
            "request mass not conserved: sent {} but served {} + rejected {}",
            self.sent,
            self.served,
            self.rejected
        );
        Ok(())
    }
}

/// Blocking JSON-lines client over the coordinator's TCP front.
pub struct DrillClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl DrillClient {
    pub fn connect(host: &str, port: u16) -> anyhow::Result<DrillClient> {
        let stream = TcpStream::connect((host, port))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(IO_TIMEOUT))?;
        stream.set_write_timeout(Some(IO_TIMEOUT))?;
        Ok(DrillClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    /// Send one JSON line, read one JSON reply.
    pub fn call(&mut self, msg: &Json) -> anyhow::Result<Json> {
        writeln!(self.writer, "{msg}")?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }

    /// `call` + require `"ok": true` in the reply.
    pub fn call_ok(&mut self, msg: &Json) -> anyhow::Result<Json> {
        let reply = self.call(msg)?;
        if reply.get("ok").and_then(Json::as_bool) != Some(true) {
            let err = reply
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unknown server error");
            anyhow::bail!("server rejected {msg}: {err}");
        }
        Ok(reply)
    }
}

fn op(name: &str) -> Json {
    let mut j = Json::obj();
    j.set("op", Json::Str(name.into()));
    j
}

fn cluster_op(action: &str, key: &str, index: usize, frac: Option<f64>) -> Json {
    let mut j = op("cluster");
    j.set("action", Json::Str(action.into()));
    j.set(key, Json::Num(index as f64));
    if let Some(f) = frac {
        j.set("frac", Json::Num(f));
    }
    j
}

/// One traffic wave as a single `batch` op: requests cycle through every
/// (region, model) class so each wave exercises the whole plan. Returns
/// (served, rejected) counted from the wave's own per-request results —
/// robust against other clients talking to the same coordinator, unlike
/// global `stats` counter deltas.
fn wave(client: &mut DrillClient, n: usize) -> anyhow::Result<(u64, u64)> {
    let mut reqs = Vec::with_capacity(n);
    for i in 0..n {
        let mut q = Json::obj();
        q.set("region", Json::Num((i % REGIONS) as f64));
        q.set("model", Json::Num((i % MODELS) as f64));
        q.set("tok_in", Json::Num(64.0));
        q.set("tok_out", Json::Num(128.0));
        reqs.push(q);
    }
    let mut msg = op("batch");
    msg.set("requests", Json::Arr(reqs));
    let reply = client.call_ok(&msg)?;
    let results = reply
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("batch reply missing results"))?;
    anyhow::ensure!(
        results.len() == n,
        "batch returned {} results for {n} requests",
        results.len()
    );
    let served = results
        .iter()
        .filter(|r| r.get("ok").and_then(Json::as_bool) == Some(true))
        .count() as u64;
    Ok((served, n as u64 - served))
}

fn total_nodes(client: &mut DrillClient) -> anyhow::Result<f64> {
    let snap = client.call_ok(&op("snapshot"))?;
    snap.get("total_nodes")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("snapshot missing total_nodes"))
}

/// Run the scripted outage drill over an open client connection. Does not
/// shut the server down; the caller owns its lifecycle.
pub fn run_drill(
    client: &mut DrillClient,
    dcfg: &DrillConfig,
) -> anyhow::Result<DrillReport> {
    anyhow::ensure!(dcfg.region < REGIONS, "drill region out of range");

    // phase 1: healthy fleet, first traffic wave
    let baseline_nodes = total_nodes(client)?;
    let (served_a, rejected_a) = wave(client, dcfg.requests_per_wave)?;

    // phase 2: darken the region; the re-plan lands at the next tick
    client.call_ok(&cluster_op(
        "scale-region",
        "region",
        dcfg.region,
        Some(dcfg.frac),
    ))?;
    client.call_ok(&op("tick"))?;
    let dipped_nodes = total_nodes(client)?;

    // phase 3: traffic into the degraded fleet (failover exercises)
    let (served_b, rejected_b) = wave(client, dcfg.requests_per_wave)?;

    // phase 4: restore and re-plan
    client.call_ok(&cluster_op("restore-region", "region", dcfg.region, None))?;
    let tick_reply = client.call_ok(&op("tick"))?;
    let epoch =
        tick_reply.get("epoch").and_then(Json::as_f64).unwrap_or(-1.0);
    let recovered_nodes = total_nodes(client)?;

    // phase 5: the ledger after both ticks (cumulative sustainability)
    let carbon = client
        .call_ok(&op("ledger"))?
        .get("carbon_kg")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);

    Ok(DrillReport {
        baseline_nodes,
        dipped_nodes,
        recovered_nodes,
        sent: 2 * dcfg.requests_per_wave as u64,
        served: served_a + served_b,
        rejected: rejected_a + rejected_b,
        epoch,
        carbon_kg: carbon,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::coordinator::{
        serve_forever, Coordinator, CoordinatorConfig,
    };
    use std::sync::Arc;

    fn serving_coordinator() -> (Arc<Coordinator>, u16) {
        let mut cfg = SystemConfig::small_test();
        cfg.opt.generations = 2;
        cfg.opt.population = 8;
        let ccfg = CoordinatorConfig {
            plan_budget_s: 0.2,
            ..Default::default()
        };
        let c = Coordinator::new(cfg, ccfg, None);
        let handle = serve_forever(Arc::clone(&c), 0).unwrap();
        // dropping the JoinHandle detaches the acceptor; the tests stop
        // the coordinator at the end, which winds the acceptor down
        (c, handle.port)
    }

    #[test]
    fn drill_end_to_end_over_tcp() {
        let (c, port) = serving_coordinator();
        let mut client = DrillClient::connect("127.0.0.1", port).unwrap();
        let report = run_drill(
            &mut client,
            &DrillConfig {
                requests_per_wave: 32,
                ..Default::default()
            },
        )
        .unwrap();
        report.verify().unwrap();
        assert_eq!(report.sent, 64);
        assert!(report.carbon_kg > 0.0, "ticks accounted no energy");
        assert_eq!(report.epoch, 2.0);
        c.stop();
    }

    #[test]
    fn drill_rejects_out_of_range_region() {
        let (c, port) = serving_coordinator();
        let mut client = DrillClient::connect("127.0.0.1", port).unwrap();
        let err = run_drill(
            &mut client,
            &DrillConfig {
                region: REGIONS + 1,
                ..Default::default()
            },
        );
        assert!(err.is_err());
        c.stop();
    }

    #[test]
    fn report_verify_catches_broken_invariants() {
        let good = DrillReport {
            baseline_nodes: 100.0,
            dipped_nodes: 60.0,
            recovered_nodes: 100.0,
            sent: 10,
            served: 8,
            rejected: 2,
            epoch: 2.0,
            carbon_kg: 1.0,
        };
        good.verify().unwrap();
        let mut no_dip = good.clone();
        no_dip.dipped_nodes = 100.0;
        assert!(no_dip.verify().is_err());
        let mut no_recovery = good.clone();
        no_recovery.recovered_nodes = 60.0;
        assert!(no_recovery.verify().is_err());
        let mut leaked = good.clone();
        leaked.served = 7;
        assert!(leaked.verify().is_err());
    }
}
