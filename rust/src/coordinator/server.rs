//! JSON-lines TCP front for the coordinator.
//!
//! Protocol (one JSON object per line, both directions):
//!   -> {"region": 0-3, "model": 0-1, "tok_in": N, "tok_out": N}
//!   <- {"ok": true, "dc": "oregon", "dc_index": 7, "ttft_ms": 12.5,
//!       "epoch": 3}
//!   <- {"ok": false, "error": "..."}
//! Special ops:
//!   -> {"op": "stats"}   <- serving metrics snapshot
//!   -> {"op": "plan"}    <- current routing plan (per-class rows)
//!   -> {"op": "shutdown"}
//!
//! std::net + a thread per connection (bounded by the acceptor): the
//! offline image has no tokio, and the router critical section is
//! microseconds, so blocking IO threads are a faithful stand-in.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::util::json::Json;

use super::Coordinator;

/// Handle returned by [`serve_forever`]'s spawner.
pub struct ServeHandle {
    pub port: u16,
    pub thread: std::thread::JoinHandle<()>,
}

/// Bind `port` (0 = ephemeral) and serve until the coordinator is stopped.
/// Returns once the listener is ready; serving continues on a thread.
pub fn serve_forever(
    coordinator: Arc<Coordinator>,
    port: u16,
) -> anyhow::Result<ServeHandle> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let actual_port = listener.local_addr()?.port();
    listener.set_nonblocking(true)?;
    let thread = std::thread::Builder::new()
        .name("slit-acceptor".into())
        .spawn(move || {
            let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
            loop {
                if coordinator.stopped() {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let c = Arc::clone(&coordinator);
                        workers.push(
                            std::thread::Builder::new()
                                .name("slit-conn".into())
                                .spawn(move || handle_conn(c, stream))
                                .expect("spawn conn"),
                        );
                        workers.retain(|w| !w.is_finished());
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(
                            5,
                        ));
                    }
                    Err(_) => break,
                }
            }
            for w in workers {
                let _ = w.join();
            }
        })?;
    Ok(ServeHandle {
        port: actual_port,
        thread,
    })
}

fn handle_conn(c: Arc<Coordinator>, stream: TcpStream) {
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(30)))
        .ok();
    // request/reply lines are tiny: Nagle + delayed-ACK would add ~40 ms
    // per round trip (measured in §Perf; 86 -> >2000 req/s after)
    stream.set_nodelay(true).ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = respond(&c, &line);
        let stop = matches!(reply.get("stopping").and_then(Json::as_bool), Some(true));
        if writeln!(writer, "{reply}").is_err() {
            break;
        }
        if stop || c.stopped() {
            break;
        }
    }
}

/// Pure request -> reply mapping (unit-testable without sockets).
pub fn respond(c: &Coordinator, line: &str) -> Json {
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            let mut r = Json::obj();
            r.set("ok", Json::Bool(false));
            r.set("error", Json::Str(format!("bad json: {e}")));
            return r;
        }
    };

    match parsed.get("op").and_then(Json::as_str) {
        Some("stats") => {
            let m = c.metrics_snapshot();
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("served", Json::Num(m.served as f64));
            r.set("rejected", Json::Num(m.rejected as f64));
            r.set("plan_refreshes", Json::Num(m.plan_refreshes as f64));
            r.set("ttft_mean_ms", Json::Num(m.ttft.mean() * 1e3));
            r.set("ttft_max_ms", Json::Num(m.ttft.max() * 1e3));
            r.set("carbon_kg", Json::Num(m.ledger.carbon_kg));
            r.set("water_l", Json::Num(m.ledger.water_l));
            r.set("cost_usd", Json::Num(m.ledger.cost_usd));
            r.set("epoch", Json::Num(c.current_epoch() as f64));
            r.set("backend", Json::Str(c.backend().into()));
            return r;
        }
        Some("plan") => {
            let plan = c.current_plan();
            let mut rows = Vec::new();
            for k in 0..plan.classes {
                rows.push(Json::num_arr(plan.row(k)));
            }
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("plan", Json::Arr(rows));
            return r;
        }
        Some("batch") => {
            // {"op":"batch","requests":[{"region":..,"model":..,...},..]}
            let Some(reqs) = parsed.get("requests").and_then(Json::as_arr)
            else {
                let mut r = Json::obj();
                r.set("ok", Json::Bool(false));
                r.set("error", Json::Str("batch needs 'requests'".into()));
                return r;
            };
            let mut batch = Vec::with_capacity(reqs.len());
            for q in reqs {
                let region = q.usize_or("region", usize::MAX);
                let model = q.usize_or("model", usize::MAX);
                if region >= crate::config::REGIONS
                    || model >= crate::config::MODELS
                {
                    let mut r = Json::obj();
                    r.set("ok", Json::Bool(false));
                    r.set(
                        "error",
                        Json::Str("region/model out of range".into()),
                    );
                    return r;
                }
                batch.push((
                    region,
                    model,
                    q.f64_or("tok_in", 128.0).max(1.0) as u32,
                    q.f64_or("tok_out", 256.0).max(1.0) as u32,
                ));
            }
            let results = c.handle_batch(&batch);
            let mut arr = Vec::with_capacity(results.len());
            for res in results {
                let mut item = Json::obj();
                match res {
                    Some((dc, ttft_s)) => {
                        item.set("ok", Json::Bool(true));
                        item.set(
                            "dc",
                            Json::Str(c.cfg.datacenters[dc].name.clone()),
                        );
                        item.set("ttft_ms", Json::Num(ttft_s * 1e3));
                    }
                    None => {
                        item.set("ok", Json::Bool(false));
                    }
                }
                arr.push(item);
            }
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("results", Json::Arr(arr));
            return r;
        }
        Some("shutdown") => {
            c.stop();
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("stopping", Json::Bool(true));
            return r;
        }
        Some(other) => {
            let mut r = Json::obj();
            r.set("ok", Json::Bool(false));
            r.set("error", Json::Str(format!("unknown op '{other}'")));
            return r;
        }
        None => {}
    }

    let region = parsed.usize_or("region", usize::MAX);
    let model = parsed.usize_or("model", usize::MAX);
    if region >= crate::config::REGIONS || model >= crate::config::MODELS {
        let mut r = Json::obj();
        r.set("ok", Json::Bool(false));
        r.set("error", Json::Str("region/model out of range".into()));
        return r;
    }
    let tok_in = parsed.f64_or("tok_in", 128.0) as u32;
    let tok_out = parsed.f64_or("tok_out", 256.0) as u32;
    match c.handle(region, model, tok_in.max(1), tok_out.max(1)) {
        Some((dc, ttft_s)) => {
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set(
                "dc",
                Json::Str(c.cfg.datacenters[dc].name.clone()),
            );
            r.set("dc_index", Json::Num(dc as f64));
            r.set("ttft_ms", Json::Num(ttft_s * 1e3));
            r.set("epoch", Json::Num(c.current_epoch() as f64));
            r
        }
        None => {
            let mut r = Json::obj();
            r.set("ok", Json::Bool(false));
            r.set("error", Json::Str("all sites saturated".into()));
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::coordinator::CoordinatorConfig;

    fn coordinator() -> Arc<Coordinator> {
        let mut cfg = SystemConfig::small_test();
        cfg.opt.generations = 2;
        cfg.opt.population = 8;
        Coordinator::new(cfg, CoordinatorConfig::default(), None)
    }

    #[test]
    fn respond_serves_request() {
        let c = coordinator();
        let r = respond(
            &c,
            r#"{"region": 1, "model": 0, "tok_in": 100, "tok_out": 150}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        assert!(r.get("ttft_ms").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(r.get("dc").and_then(Json::as_str).is_some());
    }

    #[test]
    fn respond_rejects_bad_input() {
        let c = coordinator();
        assert_eq!(
            respond(&c, "not json").get("ok").and_then(Json::as_bool),
            Some(false)
        );
        assert_eq!(
            respond(&c, r#"{"region": 99, "model": 0}"#)
                .get("ok")
                .and_then(Json::as_bool),
            Some(false)
        );
        assert_eq!(
            respond(&c, r#"{"op": "nope"}"#)
                .get("ok")
                .and_then(Json::as_bool),
            Some(false)
        );
    }

    #[test]
    fn respond_stats_and_plan() {
        let c = coordinator();
        respond(&c, r#"{"region": 0, "model": 0}"#);
        let s = respond(&c, r#"{"op": "stats"}"#);
        assert_eq!(s.get("served").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            s.get("backend").and_then(Json::as_str),
            Some("analytic")
        );
        let p = respond(&c, r#"{"op": "plan"}"#);
        let rows = p.get("plan").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), c.cfg.num_classes());
    }

    #[test]
    fn respond_batch_op() {
        let c = coordinator();
        let r = respond(
            &c,
            r#"{"op":"batch","requests":[
                {"region":0,"model":0,"tok_in":64,"tok_out":128},
                {"region":3,"model":1,"tok_in":512,"tok_out":256}]}"#
                .replace('\n', " ")
                .as_str(),
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        let results = r.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 2);
        for item in results {
            assert_eq!(item.get("ok").and_then(Json::as_bool), Some(true));
            assert!(item.get("ttft_ms").and_then(Json::as_f64).unwrap() > 0.0);
        }
        let m = c.metrics_snapshot();
        assert_eq!(m.served, 2);
        assert!(m.batches >= 1);
    }

    #[test]
    fn respond_batch_rejects_bad_member() {
        let c = coordinator();
        let r = respond(
            &c,
            r#"{"op":"batch","requests":[{"region":9,"model":0}]}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
        let r2 = respond(&c, r#"{"op":"batch"}"#);
        assert_eq!(r2.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn tcp_round_trip() {
        use std::io::{BufRead, BufReader, Write};
        let c = coordinator();
        let handle = serve_forever(Arc::clone(&c), 0).unwrap();
        let mut stream =
            std::net::TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
        writeln!(stream, r#"{{"region": 0, "model": 1}}"#).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let r = Json::parse(line.trim()).unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        writeln!(stream, r#"{{"op": "shutdown"}}"#).unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        handle.thread.join().unwrap();
        assert!(c.stopped());
    }
}
