//! JSON-lines TCP front for the coordinator.
//!
//! Protocol (one JSON object per line, both directions; DESIGN.md §12 has
//! the full op table and epoch-boundary semantics):
//!   -> {"region": 0-3, "model": 0-1, "tok_in": N, "tok_out": N}
//!   <- {"ok": true, "dc": "oregon", "dc_index": 7, "ttft_ms": 12.5,
//!       "epoch": 3}
//!   <- {"ok": false, "error": "..."}
//! Special ops:
//!   -> {"op": "stats"}    <- serving metrics snapshot (incl. overall and
//!                            per-class TTFT p50/p95/p99)
//!   -> {"op": "plan"}     <- current routing plan (per-class rows)
//!   -> {"op": "batch"}    <- route/place a request group as one batch;
//!                            each item uses the same reply object as a
//!                            single request (dc, dc_index, ttft_ms, epoch)
//!   -> {"op": "snapshot"} <- live cluster topology (per-site node counts)
//!   -> {"op": "signals"}  <- believed grid-telemetry health (per-site
//!                            feed state, staleness age, fallback source,
//!                            believed CI/WUE/TOU)
//!   -> {"op": "ledger"}   <- cumulative sustainability ledger
//!   -> {"op": "cluster"}  <- apply a ClusterAction (outage drills);
//!                            takes effect at the next epoch tick
//!   -> {"op": "tick"}     <- force an epoch tick now (drill/test clock)
//!   -> {"op": "shutdown"}
//!
//! Every malformed input — bad JSON, a non-string/unknown `op`, even a
//! non-UTF-8 line — gets a structured {"ok": false, "error": ...} reply;
//! the connection is never silently dropped on client error.
//!
//! Architecture (std::net; the offline image has no tokio): one
//! nonblocking acceptor feeds a bounded admission queue drained by N
//! sharded worker threads, each multiplexing its adopted connections with
//! nonblocking reads/writes. Admission is explicit: past `max_conns` live
//! connections the acceptor answers
//! {"ok": false, "error": "overloaded", "retry_ms": ..} and closes,
//! instead of spawning an unbounded thread per connection — under a
//! connection flood the coordinator sheds load with a structured reply
//! rather than exhausting threads. Transient accept errors (aborted
//! handshakes, fd pressure) retry with capped backoff; only genuinely
//! fatal listener errors stop the acceptor.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::ClusterAction;
use crate::util::json::Json;

use super::Coordinator;

/// A client line longer than this is a protocol violation, answered with a
/// structured error before the connection closes.
const MAX_LINE_BYTES: usize = 1 << 20;
/// A reader this far behind on replies is dead weight; drop it.
const MAX_WBUF_BYTES: usize = 4 << 20;
const READ_CHUNK: usize = 16 * 1024;

/// TCP front tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Worker threads draining the admission queue. 0 = auto
    /// (available parallelism, clamped to 2..=8).
    pub workers: usize,
    /// Live-connection bound; connections past it get the `overloaded`
    /// reply instead of service.
    pub max_conns: usize,
    /// Client back-off hint carried in the `overloaded` reply, ms.
    pub retry_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 0,
            max_conns: 1024,
            retry_ms: 25,
        }
    }
}

impl ServerConfig {
    fn resolved_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(2, 8)
    }
}

/// Handle returned by [`serve_forever`]'s spawner.
pub struct ServeHandle {
    pub port: u16,
    pub thread: std::thread::JoinHandle<()>,
}

/// Accepted connections waiting for a worker, plus the live-connection
/// count that bounds admission.
struct Admission {
    queue: Mutex<VecDeque<TcpStream>>,
    cv: Condvar,
    live: AtomicUsize,
}

/// Bind `port` (0 = ephemeral) and serve until the coordinator is stopped,
/// with default tuning. Returns once the listener is ready; serving
/// continues on background threads (the returned handle joins them all).
pub fn serve_forever(
    coordinator: Arc<Coordinator>,
    port: u16,
) -> anyhow::Result<ServeHandle> {
    serve_with(coordinator, port, ServerConfig::default())
}

/// [`serve_forever`] with explicit [`ServerConfig`] tuning.
pub fn serve_with(
    coordinator: Arc<Coordinator>,
    port: u16,
    scfg: ServerConfig,
) -> anyhow::Result<ServeHandle> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let actual_port = listener.local_addr()?.port();
    listener.set_nonblocking(true)?;
    let adm = Arc::new(Admission {
        queue: Mutex::new(VecDeque::new()),
        cv: Condvar::new(),
        live: AtomicUsize::new(0),
    });
    let n_workers = scfg.resolved_workers();
    let thread = std::thread::Builder::new()
        .name("slit-acceptor".into())
        .spawn(move || {
            let workers: Vec<_> = (0..n_workers)
                .map(|i| {
                    let c = Arc::clone(&coordinator);
                    let a = Arc::clone(&adm);
                    std::thread::Builder::new()
                        .name(format!("slit-worker-{i}"))
                        .spawn(move || worker_loop(c, a))
                        .expect("spawn worker")
                })
                .collect();
            accept_loop(&coordinator, &listener, &adm, &scfg);
            // wake any worker parked on the empty queue so it observes stop
            adm.cv.notify_all();
            for w in workers {
                let _ = w.join();
            }
        })?;
    Ok(ServeHandle {
        port: actual_port,
        thread,
    })
}

/// Only listener-is-broken errors stop the acceptor; everything else is a
/// per-connection or resource-pressure condition that a later accept can
/// survive (the pre-rebuild acceptor broke on *any* non-WouldBlock error,
/// so one aborted handshake could kill the whole server).
fn accept_fatal(kind: std::io::ErrorKind) -> bool {
    use std::io::ErrorKind::*;
    matches!(kind, InvalidInput | Unsupported | AddrNotAvailable | NotConnected)
}

fn accept_loop(
    c: &Arc<Coordinator>,
    listener: &TcpListener,
    adm: &Arc<Admission>,
    scfg: &ServerConfig,
) {
    let mut backoff_ms = 1u64;
    loop {
        if c.stopped() {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                backoff_ms = 1;
                if adm.live.load(Ordering::SeqCst) >= scfg.max_conns {
                    shed_connection(c, stream, scfg.retry_ms);
                    continue;
                }
                adm.live.fetch_add(1, Ordering::SeqCst);
                // request/reply lines are tiny: Nagle + delayed-ACK would
                // add ~40 ms per round trip (measured in §Perf; 86 ->
                // >2000 req/s after)
                stream.set_nodelay(true).ok();
                if stream.set_nonblocking(true).is_err() {
                    adm.live.fetch_sub(1, Ordering::SeqCst);
                    continue;
                }
                adm.queue.lock().expect("admission").push_back(stream);
                adm.cv.notify_one();
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) if accept_fatal(e.kind()) => {
                eprintln!("slit-acceptor: fatal accept error: {e}");
                break;
            }
            Err(_) => {
                // transient (aborted handshake, fd exhaustion, ...):
                // capped exponential backoff, reset on the next success
                std::thread::sleep(Duration::from_millis(backoff_ms));
                backoff_ms = (backoff_ms * 2).min(100);
            }
        }
    }
}

/// Bounded-admission refusal: a structured reply with a retry hint, then
/// close. The accepted socket is still blocking here, so the one-line
/// write completes synchronously.
fn shed_connection(c: &Coordinator, mut stream: TcpStream, retry_ms: u64) {
    let mut r = Json::obj();
    r.set("ok", Json::Bool(false));
    r.set("error", Json::Str("overloaded".into()));
    r.set("retry_ms", Json::Num(retry_ms as f64));
    let _ = writeln!(stream, "{r}");
    c.metrics.lock().expect("metrics").overloaded += 1;
}

/// One multiplexed connection owned by a worker.
struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    /// Scan resume offset into `rbuf` (no rescans of a long partial line).
    scan_from: usize,
    wbuf: Vec<u8>,
    /// Flush what's pending, then close (EOF seen or protocol violation).
    closing: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            scan_from: 0,
            wbuf: Vec::new(),
            closing: false,
        }
    }
}

/// Push pending reply bytes out. Returns (made progress, still alive).
fn flush_wbuf(conn: &mut Conn) -> (bool, bool) {
    let mut progress = false;
    while !conn.wbuf.is_empty() {
        match conn.stream.write(&conn.wbuf) {
            Ok(0) => return (progress, false),
            Ok(n) => {
                conn.wbuf.drain(..n);
                progress = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return (progress, false),
        }
    }
    (progress, true)
}

/// Drive one connection: flush replies, read what's arrived, answer every
/// complete line. Returns (made progress, still alive).
fn pump(c: &Coordinator, conn: &mut Conn) -> (bool, bool) {
    let (mut progress, alive) = flush_wbuf(conn);
    if !alive {
        return (progress, false);
    }
    if conn.closing {
        // drain-only mode: done once the reply buffer empties
        return (progress, !conn.wbuf.is_empty());
    }

    // pull everything the socket has
    let mut chunk = [0u8; READ_CHUNK];
    loop {
        match conn.stream.read(&mut chunk) {
            Ok(0) => {
                conn.closing = true; // EOF: flush any pending reply, then go
                break;
            }
            Ok(n) => {
                conn.rbuf.extend_from_slice(&chunk[..n]);
                progress = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return (progress, false),
        }
    }

    // answer complete lines in one pass over the buffer
    let mut consumed = 0usize;
    while let Some(rel) = conn.rbuf[conn.scan_from..]
        .iter()
        .position(|&b| b == b'\n')
    {
        let end = conn.scan_from + rel;
        // raw bytes, not `lines()`: a non-UTF-8 line must produce a
        // structured parse-error reply, not a silent disconnect (the lossy
        // conversion feeds the JSON parser, which rejects the replacement
        // characters with a reportable error)
        let line = String::from_utf8_lossy(&conn.rbuf[consumed..end]);
        let line = line.trim();
        consumed = end + 1;
        conn.scan_from = consumed;
        if line.is_empty() {
            continue;
        }
        let reply = respond(c, line);
        let stop = matches!(
            reply.get("stopping").and_then(Json::as_bool),
            Some(true)
        );
        conn.wbuf.extend_from_slice(reply.to_string().as_bytes());
        conn.wbuf.push(b'\n');
        progress = true;
        if stop {
            conn.closing = true;
            break;
        }
    }
    conn.rbuf.drain(..consumed);
    conn.scan_from = conn.rbuf.len();

    if conn.rbuf.len() > MAX_LINE_BYTES && !conn.closing {
        let reply = error_reply("line exceeds 1 MiB");
        conn.wbuf.extend_from_slice(reply.to_string().as_bytes());
        conn.wbuf.push(b'\n');
        conn.closing = true;
    }
    if conn.wbuf.len() > MAX_WBUF_BYTES {
        return (progress, false); // reader too far behind
    }

    let (p2, alive) = flush_wbuf(conn);
    (progress || p2, alive && !(conn.closing && conn.wbuf.is_empty()))
}

fn worker_loop(c: Arc<Coordinator>, adm: Arc<Admission>) {
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        // adopt queued connections: drain freely when idle, trickle when
        // busy so a burst spreads across workers
        {
            let take = if conns.is_empty() { usize::MAX } else { 2 };
            let mut q = adm.queue.lock().expect("admission");
            if conns.is_empty() && q.is_empty() && !c.stopped() {
                let (guard, _) = adm
                    .cv
                    .wait_timeout(q, Duration::from_millis(10))
                    .expect("admission");
                q = guard;
            }
            for _ in 0..take {
                match q.pop_front() {
                    Some(s) => conns.push(Conn::new(s)),
                    None => break,
                }
            }
        }

        let mut progress = false;
        let mut i = 0;
        while i < conns.len() {
            let (p, alive) = pump(&c, &mut conns[i]);
            progress |= p;
            if alive {
                i += 1;
            } else {
                conns.swap_remove(i);
                adm.live.fetch_sub(1, Ordering::SeqCst);
            }
        }

        if c.stopped() {
            // bounded drain so in-flight replies (e.g. the shutdown ack
            // on a sibling connection) reach their clients
            let deadline = Instant::now() + Duration::from_millis(500);
            while Instant::now() < deadline
                && conns.iter().any(|cn| !cn.wbuf.is_empty())
            {
                for cn in &mut conns {
                    let _ = flush_wbuf(cn);
                }
                std::thread::sleep(Duration::from_micros(200));
            }
            break;
        }
        if !progress && !conns.is_empty() {
            std::thread::sleep(Duration::from_micros(300));
        }
    }
}

/// Structured error reply: `{"ok": false, "error": msg}`.
fn error_reply(msg: &str) -> Json {
    let mut r = Json::obj();
    r.set("ok", Json::Bool(false));
    r.set("error", Json::Str(msg.into()));
    r
}

/// Strict non-negative integer field. `Json::as_usize` is a saturating
/// float cast (-1 -> 0), which would silently redirect a malformed index
/// at site/region 0 — here anything missing, negative, or fractional is
/// `None` so the caller's range check rejects it.
fn index_field(msg: &Json, key: &str) -> Option<usize> {
    let v = msg.get(key)?.as_f64()?;
    if v.is_finite() && v >= 0.0 && v.fract() == 0.0 {
        Some(v as usize)
    } else {
        None
    }
}

/// Token-count field: absent -> `default`; present must be a finite
/// positive integer (≤ 1e6). Shared by the single-request and batch paths
/// — they used to disagree (`as u32` on one, `.max(1.0)` on the other),
/// so a NaN or negative count turned into garbage on exactly one of them.
fn token_field(msg: &Json, key: &str, default: u32) -> Result<u32, String> {
    let Some(v) = msg.get(key) else {
        return Ok(default);
    };
    let Some(x) = v.as_f64() else {
        return Err(format!("'{key}' must be a number"));
    };
    if !x.is_finite() || x < 1.0 || x.fract() != 0.0 {
        return Err(format!("'{key}' must be a positive integer"));
    }
    if x > 1e6 {
        return Err(format!("'{key}' exceeds 1e6 tokens"));
    }
    Ok(x as u32)
}

/// The one reply shape for a placed/rejected request, shared verbatim by
/// the single-request path and every batch item.
fn request_reply(c: &Coordinator, res: Option<(usize, f64)>) -> Json {
    match res {
        Some((dc, ttft_s)) => {
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("dc", Json::Str(c.cfg.datacenters[dc].name.clone()));
            r.set("dc_index", Json::Num(dc as f64));
            r.set("ttft_ms", Json::Num(ttft_s * 1e3));
            r.set("epoch", Json::Num(c.current_epoch() as f64));
            r
        }
        None => error_reply("all sites saturated"),
    }
}

/// Pure request -> reply mapping (unit-testable without sockets). Every
/// input, however malformed, maps to exactly one reply object.
pub fn respond(c: &Coordinator, line: &str) -> Json {
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return error_reply(&format!("bad json: {e}")),
    };
    match parsed.get("op") {
        // a present-but-non-string op must not fall through to the plain
        // request path (it would earn a misleading range error there)
        Some(op) => match op.as_str() {
            Some(op) => respond_op(c, op, &parsed),
            None => error_reply("'op' must be a string"),
        },
        None => respond_request(c, &parsed),
    }
}

/// Dispatch a special `{"op": ...}` message.
fn respond_op(c: &Coordinator, op: &str, parsed: &Json) -> Json {
    match op {
        "stats" => stats_reply(c),
        "plan" => {
            let plan = c.current_plan();
            let mut rows = Vec::new();
            for k in 0..plan.classes {
                rows.push(Json::num_arr(plan.row(k)));
            }
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("plan", Json::Arr(rows));
            r
        }
        "snapshot" => snapshot_reply(c),
        "signals" => signals_reply(c),
        "ledger" => ledger_reply(c),
        "tick" => {
            // force an epoch boundary now: drills and tests drive the
            // epoch clock deterministically instead of waiting wall time
            c.tick_epoch();
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("epoch", Json::Num(c.current_epoch() as f64));
            r
        }
        "cluster" => match parse_cluster_action(c, parsed) {
            Ok(action) => {
                c.apply_cluster_action(&action);
                let mut r = Json::obj();
                r.set("ok", Json::Bool(true));
                r.set(
                    "applied",
                    parsed
                        .get("action")
                        .and_then(Json::as_str)
                        .map(|a| Json::Str(a.into()))
                        .unwrap_or(Json::Null),
                );
                // actions land on the live state immediately but the
                // plan/capacity only rebuild at the next tick
                r.set(
                    "effective_epoch",
                    Json::Num((c.current_epoch() + 1) as f64),
                );
                r
            }
            Err(msg) => error_reply(&msg),
        },
        "batch" => {
            // {"op":"batch","requests":[{"region":..,"model":..,...},..]}
            let Some(reqs) = parsed.get("requests").and_then(Json::as_arr)
            else {
                return error_reply("batch needs 'requests'");
            };
            let mut batch = Vec::with_capacity(reqs.len());
            for (i, q) in reqs.iter().enumerate() {
                let region = index_field(q, "region").unwrap_or(usize::MAX);
                let model = index_field(q, "model").unwrap_or(usize::MAX);
                if region >= crate::config::REGIONS
                    || model >= crate::config::MODELS
                {
                    return error_reply(&format!(
                        "request {i}: region/model out of range"
                    ));
                }
                let tok_in = match token_field(q, "tok_in", 128) {
                    Ok(t) => t,
                    Err(e) => return error_reply(&format!("request {i}: {e}")),
                };
                let tok_out = match token_field(q, "tok_out", 256) {
                    Ok(t) => t,
                    Err(e) => return error_reply(&format!("request {i}: {e}")),
                };
                batch.push((region, model, tok_in, tok_out));
            }
            let results = c.handle_batch(&batch);
            let arr = results
                .into_iter()
                .map(|res| request_reply(c, res))
                .collect();
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("results", Json::Arr(arr));
            r
        }
        "shutdown" => {
            c.stop();
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("stopping", Json::Bool(true));
            r
        }
        other => error_reply(&format!("unknown op '{other}'")),
    }
}

/// `{"op": "stats"}` — serving metrics, now with overall and per-class
/// TTFT percentiles from the log-bucketed histograms.
fn stats_reply(c: &Coordinator) -> Json {
    let m = c.metrics_snapshot();
    let mut r = Json::obj();
    r.set("ok", Json::Bool(true));
    r.set("served", Json::Num(m.served as f64));
    r.set("rejected", Json::Num(m.rejected as f64));
    r.set("overloaded", Json::Num(m.overloaded as f64));
    r.set("plan_refreshes", Json::Num(m.plan_refreshes as f64));
    r.set("ttft_mean_ms", Json::Num(m.ttft.mean() * 1e3));
    r.set("ttft_max_ms", Json::Num(m.ttft.max() * 1e3));
    r.set("ttft_p50_ms", Json::Num(m.ttft_hist.p50() * 1e3));
    r.set("ttft_p95_ms", Json::Num(m.ttft_hist.p95() * 1e3));
    r.set("ttft_p99_ms", Json::Num(m.ttft_hist.p99() * 1e3));
    let classes = m
        .class_ttft
        .iter()
        .enumerate()
        .filter(|(_, h)| h.count() > 0)
        .map(|(k, h)| {
            let mut e = Json::obj();
            e.set("class", Json::Num(k as f64));
            e.set(
                "region",
                Json::Num((k / crate::config::MODELS) as f64),
            );
            e.set(
                "model",
                Json::Num((k % crate::config::MODELS) as f64),
            );
            e.set("count", Json::Num(h.count() as f64));
            e.set("ttft_p50_ms", Json::Num(h.p50() * 1e3));
            e.set("ttft_p95_ms", Json::Num(h.p95() * 1e3));
            e.set("ttft_p99_ms", Json::Num(h.p99() * 1e3));
            e
        })
        .collect();
    r.set("classes", Json::Arr(classes));
    r.set("carbon_kg", Json::Num(m.ledger.carbon_kg));
    r.set("water_l", Json::Num(m.ledger.water_l));
    r.set("cost_usd", Json::Num(m.ledger.cost_usd));
    r.set("epoch", Json::Num(c.current_epoch() as f64));
    r.set("backend", Json::Str(c.backend().into()));
    r
}

/// `{"op": "snapshot"}` — the live cluster topology, per site.
fn snapshot_reply(c: &Coordinator) -> Json {
    let snap = c.cluster_snapshot();
    let mut sites = Vec::with_capacity(c.cfg.datacenters.len());
    let mut total = 0usize;
    for (l, spec) in c.cfg.datacenters.iter().enumerate() {
        total += snap.total_nodes(l);
        let counts: Vec<f64> =
            snap.nodes(l).iter().map(|&n| n as f64).collect();
        let mut s = Json::obj();
        s.set("dc", Json::Num(l as f64));
        s.set("name", Json::Str(spec.name.clone()));
        s.set("region", Json::Num(spec.region as f64));
        s.set("nodes", Json::num_arr(&counts));
        s.set("total", Json::Num(snap.total_nodes(l) as f64));
        sites.push(s);
    }
    let mut r = Json::obj();
    r.set("ok", Json::Bool(true));
    r.set("epoch", Json::Num(c.current_epoch() as f64));
    r.set("baseline", Json::Bool(snap.is_baseline()));
    r.set("total_nodes", Json::Num(total as f64));
    r.set("sites", Json::Arr(sites));
    r
}

/// `{"op": "signals"}` — believed grid-telemetry health per site: feed
/// state, staleness age, fallback-ladder source, and the believed
/// CI/WUE/TOU panel the next re-plan will consume.
fn signals_reply(c: &Coordinator) -> Json {
    let (faults, rows) = c.signal_snapshot();
    let sites = rows
        .iter()
        .enumerate()
        .map(|(l, row)| {
            let mut s = Json::obj();
            s.set("dc", Json::Num(l as f64));
            s.set("name", Json::Str(row.name.clone()));
            s.set("region", Json::Num(row.region as f64));
            s.set("state", Json::Str(row.state.into()));
            s.set("age", Json::Num(row.age as f64));
            s.set("source", Json::Str(row.source.into()));
            s.set("ci", Json::Num(row.ci));
            s.set("wue", Json::Num(row.wue));
            s.set("tou", Json::Num(row.tou));
            s
        })
        .collect();
    let mut r = Json::obj();
    r.set("ok", Json::Bool(true));
    r.set("epoch", Json::Num(c.current_epoch() as f64));
    r.set(
        "policy",
        Json::Str(c.ccfg.signal_policy.as_str().into()),
    );
    r.set("faults_injected", Json::Num(faults as f64));
    r.set("sites", Json::Arr(sites));
    r
}

/// `{"op": "ledger"}` — the cumulative sustainability/performance ledger
/// (everything accounted since the coordinator started).
fn ledger_reply(c: &Coordinator) -> Json {
    let m = c.metrics_snapshot();
    let mut r = Json::obj();
    r.set("ok", Json::Bool(true));
    r.set("epoch", Json::Num(c.current_epoch() as f64));
    r.set("e_it_j", Json::Num(m.ledger.e_it_j));
    r.set("e_tot_j", Json::Num(m.ledger.e_tot_j));
    r.set("carbon_kg", Json::Num(m.ledger.carbon_kg));
    r.set("water_l", Json::Num(m.ledger.water_l));
    r.set("cost_usd", Json::Num(m.ledger.cost_usd));
    r.set("served", Json::Num(m.served as f64));
    r.set("rejected", Json::Num(m.rejected as f64));
    r.set("overloaded", Json::Num(m.overloaded as f64));
    r.set("batches", Json::Num(m.batches as f64));
    r.set("ttft_mean_ms", Json::Num(m.ttft.mean() * 1e3));
    r.set("ttft_p50_ms", Json::Num(m.ttft_hist.p50() * 1e3));
    r.set("ttft_p95_ms", Json::Num(m.ttft_hist.p95() * 1e3));
    r.set("ttft_p99_ms", Json::Num(m.ttft_hist.p99() * 1e3));
    // believed-vs-truth telemetry accounting (site-epoch counts + summed
    // |believed − truth| per axis; all zero when no faults were injected)
    r.set("signal_fresh", Json::Num(m.ledger.signal_fresh));
    r.set("signal_stale", Json::Num(m.ledger.signal_stale));
    r.set(
        "signal_quarantined",
        Json::Num(m.ledger.signal_quarantined),
    );
    r.set("signal_div_ci", Json::Num(m.ledger.signal_div[0]));
    r.set("signal_div_wue", Json::Num(m.ledger.signal_div[1]));
    r.set("signal_div_tou", Json::Num(m.ledger.signal_div[2]));
    r
}

/// Validate and decode a `{"op": "cluster", "action": ...}` message.
fn parse_cluster_action(
    c: &Coordinator,
    msg: &Json,
) -> Result<ClusterAction, String> {
    let Some(action) = msg.get("action").and_then(Json::as_str) else {
        return Err("cluster needs an 'action' string (one of: \
                    scale-region, restore-region, scale-site, \
                    restore-site, set-site)"
            .into());
    };
    let region = || -> Result<usize, String> {
        match index_field(msg, "region") {
            Some(r) if r < crate::config::REGIONS => Ok(r),
            _ => Err(format!(
                "'region' must be an integer in 0..{}",
                crate::config::REGIONS
            )),
        }
    };
    let dc = || -> Result<usize, String> {
        match index_field(msg, "dc") {
            Some(d) if d < c.cfg.datacenters.len() => Ok(d),
            _ => Err(format!(
                "'dc' must be an integer in 0..{}",
                c.cfg.datacenters.len()
            )),
        }
    };
    let frac = || -> Result<f64, String> {
        let f = msg.f64_or("frac", f64::NAN);
        if f.is_finite() && f >= 0.0 {
            Ok(f)
        } else {
            Err("'frac' must be a finite number >= 0".into())
        }
    };
    match action {
        "scale-region" => Ok(ClusterAction::ScaleRegion {
            region: region()?,
            frac: frac()?,
        }),
        "restore-region" => {
            Ok(ClusterAction::RestoreRegion { region: region()? })
        }
        "scale-site" => Ok(ClusterAction::ScaleSite {
            dc: dc()?,
            frac: frac()?,
        }),
        "restore-site" => Ok(ClusterAction::RestoreSite { dc: dc()? }),
        "set-site" => {
            let nodes = msg
                .f64_vec("nodes")
                .ok_or("set-site needs a 'nodes' array of numbers")?;
            if nodes.iter().any(|&n| !n.is_finite() || n < 0.0) {
                return Err("'nodes' entries must be finite and >= 0".into());
            }
            Ok(ClusterAction::SetSite {
                dc: dc()?,
                nodes_per_type: nodes.iter().map(|&n| n as usize).collect(),
            })
        }
        other => Err(format!("unknown cluster action '{other}'")),
    }
}

/// Handle a plain (op-less) single-request message.
fn respond_request(c: &Coordinator, parsed: &Json) -> Json {
    let region = index_field(parsed, "region").unwrap_or(usize::MAX);
    let model = index_field(parsed, "model").unwrap_or(usize::MAX);
    if region >= crate::config::REGIONS || model >= crate::config::MODELS {
        return error_reply("region/model out of range");
    }
    let tok_in = match token_field(parsed, "tok_in", 128) {
        Ok(t) => t,
        Err(e) => return error_reply(&e),
    };
    let tok_out = match token_field(parsed, "tok_out", 256) {
        Ok(t) => t,
        Err(e) => return error_reply(&e),
    };
    request_reply(c, c.handle(region, model, tok_in, tok_out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::coordinator::CoordinatorConfig;

    fn coordinator() -> Arc<Coordinator> {
        let mut cfg = SystemConfig::small_test();
        cfg.opt.generations = 2;
        cfg.opt.population = 8;
        Coordinator::new(cfg, CoordinatorConfig::default(), None)
    }

    #[test]
    fn respond_serves_request() {
        let c = coordinator();
        let r = respond(
            &c,
            r#"{"region": 1, "model": 0, "tok_in": 100, "tok_out": 150}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        assert!(r.get("ttft_ms").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(r.get("dc").and_then(Json::as_str).is_some());
    }

    #[test]
    fn respond_rejects_bad_input() {
        let c = coordinator();
        assert_eq!(
            respond(&c, "not json").get("ok").and_then(Json::as_bool),
            Some(false)
        );
        assert_eq!(
            respond(&c, r#"{"region": 99, "model": 0}"#)
                .get("ok")
                .and_then(Json::as_bool),
            Some(false)
        );
        // a negative region must not saturate to region 0 and serve
        assert_eq!(
            respond(&c, r#"{"region": -1, "model": 0}"#)
                .get("ok")
                .and_then(Json::as_bool),
            Some(false)
        );
        assert_eq!(
            respond(&c, r#"{"op": "nope"}"#)
                .get("ok")
                .and_then(Json::as_bool),
            Some(false)
        );
    }

    #[test]
    fn token_validation_is_symmetric_across_paths() {
        let c = coordinator();
        // the same malformed token count must be rejected with a
        // structured error on BOTH paths (the single path used to cast
        // NaN/negatives straight to u32 while batch clamped them)
        for bad in [
            r#""tok_in": -5"#,
            r#""tok_in": 1.5"#,
            r#""tok_in": "many""#,
            r#""tok_in": 0"#,
            r#""tok_in": 1e9"#,
            r#""tok_out": -1"#,
        ] {
            let single = respond(&c, &format!(r#"{{"region":0,"model":0,{bad}}}"#));
            assert_eq!(
                single.get("ok").and_then(Json::as_bool),
                Some(false),
                "single path accepted {bad}"
            );
            assert!(single.get("error").and_then(Json::as_str).is_some());
            let batch = respond(
                &c,
                &format!(
                    r#"{{"op":"batch","requests":[{{"region":0,"model":0,{bad}}}]}}"#
                ),
            );
            assert_eq!(
                batch.get("ok").and_then(Json::as_bool),
                Some(false),
                "batch path accepted {bad}"
            );
            assert!(
                batch
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap()
                    .starts_with("request 0:"),
                "batch error must name the offending request"
            );
        }
        // nothing slipped through to placement
        assert_eq!(c.metrics_snapshot().served, 0);
        // missing counts still default on both paths
        let s = respond(&c, r#"{"region":0,"model":0}"#);
        assert_eq!(s.get("ok").and_then(Json::as_bool), Some(true));
        let b = respond(
            &c,
            r#"{"op":"batch","requests":[{"region":0,"model":0}]}"#,
        );
        assert_eq!(b.get("ok").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn respond_stats_and_plan() {
        let c = coordinator();
        respond(&c, r#"{"region": 0, "model": 0}"#);
        let s = respond(&c, r#"{"op": "stats"}"#);
        assert_eq!(s.get("served").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            s.get("backend").and_then(Json::as_str),
            Some("analytic")
        );
        let p = respond(&c, r#"{"op": "plan"}"#);
        let rows = p.get("plan").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), c.cfg.num_classes());
    }

    #[test]
    fn stats_reports_overall_and_per_class_percentiles() {
        let c = coordinator();
        for i in 0..80 {
            respond(
                &c,
                &format!(r#"{{"region": {}, "model": {}}}"#, i % 4, i % 2),
            );
        }
        let s = respond(&c, r#"{"op": "stats"}"#);
        let f = |k: &str| s.get(k).and_then(Json::as_f64).unwrap();
        assert!(f("ttft_p50_ms") > 0.0);
        assert!(f("ttft_p50_ms") <= f("ttft_p95_ms"));
        assert!(f("ttft_p95_ms") <= f("ttft_p99_ms"));
        assert!(f("ttft_p99_ms") <= f("ttft_max_ms") + 1e-9);
        let classes = s.get("classes").and_then(Json::as_arr).unwrap();
        assert!(classes.len() > 1, "per-class table missing");
        let total: f64 = classes
            .iter()
            .map(|e| e.get("count").and_then(Json::as_f64).unwrap())
            .sum();
        assert_eq!(total, f("served"));
        for e in classes {
            let p50 = e.get("ttft_p50_ms").and_then(Json::as_f64).unwrap();
            let p99 = e.get("ttft_p99_ms").and_then(Json::as_f64).unwrap();
            assert!(p50 > 0.0 && p99 >= p50);
            assert!(e.get("region").and_then(Json::as_f64).is_some());
            assert!(e.get("model").and_then(Json::as_f64).is_some());
        }
        // the ledger reply carries the same overall percentiles
        let l = respond(&c, r#"{"op": "ledger"}"#);
        assert_eq!(
            l.get("ttft_p99_ms").and_then(Json::as_f64),
            s.get("ttft_p99_ms").and_then(Json::as_f64)
        );
    }

    #[test]
    fn respond_batch_op() {
        let c = coordinator();
        let r = respond(
            &c,
            r#"{"op":"batch","requests":[
                {"region":0,"model":0,"tok_in":64,"tok_out":128},
                {"region":3,"model":1,"tok_in":512,"tok_out":256}]}"#
                .replace('\n', " ")
                .as_str(),
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        let results = r.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 2);
        for item in results {
            assert_eq!(item.get("ok").and_then(Json::as_bool), Some(true));
            assert!(item.get("ttft_ms").and_then(Json::as_f64).unwrap() > 0.0);
        }
        let m = c.metrics_snapshot();
        assert_eq!(m.served, 2);
        assert!(m.batches >= 1);
    }

    #[test]
    fn batch_items_use_the_single_request_reply_schema() {
        let c = coordinator();
        let single = respond(&c, r#"{"region":0,"model":0}"#);
        let batch = respond(
            &c,
            r#"{"op":"batch","requests":[{"region":0,"model":0}]}"#,
        );
        let item = &batch.get("results").and_then(Json::as_arr).unwrap()[0];
        // batch items used to omit dc_index and epoch; now both paths emit
        // the identical field set
        for key in ["ok", "dc", "dc_index", "ttft_ms", "epoch"] {
            assert!(
                single.get(key).is_some(),
                "single reply missing '{key}'"
            );
            assert!(item.get(key).is_some(), "batch item missing '{key}'");
        }
        assert!(
            item.get("dc_index").and_then(Json::as_f64).unwrap() >= 0.0
        );
        assert_eq!(
            item.get("epoch").and_then(Json::as_f64),
            Some(c.current_epoch() as f64)
        );
    }

    #[test]
    fn respond_batch_rejects_bad_member() {
        let c = coordinator();
        let r = respond(
            &c,
            r#"{"op":"batch","requests":[{"region":9,"model":0}]}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
        let neg = respond(
            &c,
            r#"{"op":"batch","requests":[{"region":-1,"model":0}]}"#,
        );
        assert_eq!(neg.get("ok").and_then(Json::as_bool), Some(false));
        let r2 = respond(&c, r#"{"op":"batch"}"#);
        assert_eq!(r2.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn respond_rejects_non_string_op() {
        let c = coordinator();
        let r = respond(&c, r#"{"op": 5}"#);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
        assert!(r
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("'op' must be a string"));
    }

    #[test]
    fn accept_error_classification() {
        use std::io::ErrorKind::*;
        // listener-is-broken: stop accepting
        for k in [InvalidInput, Unsupported, AddrNotAvailable, NotConnected] {
            assert!(accept_fatal(k), "{k:?} should be fatal");
        }
        // per-connection / resource pressure: retry with backoff (the old
        // acceptor died on the first of any of these)
        for k in [
            ConnectionAborted,
            ConnectionReset,
            PermissionDenied,
            TimedOut,
            Other,
        ] {
            assert!(!accept_fatal(k), "{k:?} must not kill the acceptor");
        }
    }

    #[test]
    fn respond_snapshot_reports_live_topology() {
        let c = coordinator();
        let s = respond(&c, r#"{"op": "snapshot"}"#);
        assert_eq!(s.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(s.get("baseline").and_then(Json::as_bool), Some(true));
        let sites = s.get("sites").and_then(Json::as_arr).unwrap();
        assert_eq!(sites.len(), c.cfg.datacenters.len());
        let total: f64 = sites
            .iter()
            .map(|s| s.get("total").and_then(Json::as_f64).unwrap())
            .sum();
        assert_eq!(
            s.get("total_nodes").and_then(Json::as_f64),
            Some(total)
        );
        assert!(total > 0.0);
    }

    #[test]
    fn respond_cluster_op_dips_and_restores_topology() {
        let c = coordinator();
        let total = |j: &Json| -> f64 {
            j.get("total_nodes").and_then(Json::as_f64).unwrap()
        };
        let full = total(&respond(&c, r#"{"op": "snapshot"}"#));

        let r = respond(
            &c,
            r#"{"op": "cluster", "action": "scale-region", "region": 2, "frac": 0}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(
            r.get("applied").and_then(Json::as_str),
            Some("scale-region")
        );
        assert_eq!(r.get("effective_epoch").and_then(Json::as_f64), Some(1.0));
        // the live state mutates immediately; the snapshot shows the dip
        let dipped = respond(&c, r#"{"op": "snapshot"}"#);
        assert!(total(&dipped) < full);
        assert_eq!(
            dipped.get("baseline").and_then(Json::as_bool),
            Some(false)
        );
        // tick, then restore + tick: whole again
        let t = respond(&c, r#"{"op": "tick"}"#);
        assert_eq!(t.get("epoch").and_then(Json::as_f64), Some(1.0));
        let r = respond(
            &c,
            r#"{"op": "cluster", "action": "restore-region", "region": 2}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        respond(&c, r#"{"op": "tick"}"#);
        let restored = respond(&c, r#"{"op": "snapshot"}"#);
        assert_eq!(total(&restored), full);
        assert_eq!(
            restored.get("baseline").and_then(Json::as_bool),
            Some(true)
        );
    }

    #[test]
    fn respond_cluster_op_validates_input() {
        let c = coordinator();
        for bad in [
            r#"{"op": "cluster"}"#,
            r#"{"op": "cluster", "action": "warp-drive"}"#,
            r#"{"op": "cluster", "action": "scale-region", "region": 99, "frac": 0.5}"#,
            r#"{"op": "cluster", "action": "scale-region", "region": 1}"#,
            // negative/fractional indices must NOT saturate to site 0
            r#"{"op": "cluster", "action": "scale-region", "region": -1, "frac": 0}"#,
            r#"{"op": "cluster", "action": "scale-site", "dc": -2, "frac": 0.5}"#,
            r#"{"op": "cluster", "action": "scale-site", "dc": 1.5, "frac": 0.5}"#,
            r#"{"op": "cluster", "action": "scale-site", "dc": 9999, "frac": 0.5}"#,
            r#"{"op": "cluster", "action": "scale-site", "dc": 0, "frac": -1}"#,
            r#"{"op": "cluster", "action": "set-site", "dc": 0}"#,
            r#"{"op": "cluster", "action": "set-site", "dc": 0, "nodes": [-1]}"#,
        ] {
            let r = respond(&c, bad);
            assert_eq!(
                r.get("ok").and_then(Json::as_bool),
                Some(false),
                "accepted: {bad}"
            );
            assert!(r.get("error").and_then(Json::as_str).is_some());
        }
        // a rejected action must not have mutated the topology
        let s = respond(&c, r#"{"op": "snapshot"}"#);
        assert_eq!(s.get("baseline").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn respond_set_site_replaces_counts() {
        let c = coordinator();
        let r = respond(
            &c,
            r#"{"op": "cluster", "action": "set-site", "dc": 0, "nodes": [1, 1, 1, 1, 1, 1]}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        let s = respond(&c, r#"{"op": "snapshot"}"#);
        let site0 = s.get("sites").and_then(Json::as_arr).unwrap()[0]
            .get("total")
            .and_then(Json::as_f64);
        assert_eq!(site0, Some(6.0));
    }

    #[test]
    fn respond_signals_reports_feed_health() {
        let c = coordinator();
        let s = respond(&c, r#"{"op": "signals"}"#);
        assert_eq!(s.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(
            s.get("faults_injected").and_then(Json::as_f64),
            Some(0.0)
        );
        assert_eq!(
            s.get("policy").and_then(Json::as_str),
            Some("robust")
        );
        assert_eq!(
            s.get("sites").and_then(Json::as_arr).unwrap().len(),
            c.cfg.datacenters.len()
        );
        // darken one region's telemetry, tick: those feeds read non-fresh
        // with a fallback source while the rest stay live — and every
        // believed value remains finite and positive
        c.apply_cluster_action(&ClusterAction::Signal(
            crate::signals::SignalFault::RegionBlackout {
                region: 1,
                epochs: 8,
            },
        ));
        respond(&c, r#"{"op": "tick"}"#);
        let s = respond(&c, r#"{"op": "signals"}"#);
        assert_eq!(
            s.get("faults_injected").and_then(Json::as_f64),
            Some(1.0)
        );
        for site in s.get("sites").and_then(Json::as_arr).unwrap() {
            let region =
                site.get("region").and_then(Json::as_f64).unwrap() as usize;
            let state = site.get("state").and_then(Json::as_str).unwrap();
            let source = site.get("source").and_then(Json::as_str).unwrap();
            if region == 1 {
                assert_ne!(state, "fresh");
                assert_ne!(source, "live");
            } else {
                assert_eq!(state, "fresh");
                assert_eq!(source, "live");
            }
            for axis in ["ci", "wue", "tou"] {
                let v = site.get(axis).and_then(Json::as_f64).unwrap();
                assert!(v.is_finite() && v > 0.0, "{axis} = {v}");
            }
        }
        // the ledger reply carries the matching health counters
        let l = respond(&c, r#"{"op": "ledger"}"#);
        assert_eq!(l.get("signal_stale").and_then(Json::as_f64), Some(3.0));
        assert_eq!(l.get("signal_fresh").and_then(Json::as_f64), Some(9.0));
    }

    #[test]
    fn respond_ledger_accumulates_after_tick() {
        let c = coordinator();
        for i in 0..20 {
            respond(
                &c,
                &format!(r#"{{"region": {}, "model": 0}}"#, i % 4),
            );
        }
        let before = respond(&c, r#"{"op": "ledger"}"#);
        assert_eq!(before.get("served").and_then(Json::as_f64), Some(20.0));
        assert_eq!(before.get("carbon_kg").and_then(Json::as_f64), Some(0.0));
        respond(&c, r#"{"op": "tick"}"#);
        let after = respond(&c, r#"{"op": "ledger"}"#);
        assert!(after.get("carbon_kg").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(after.get("e_tot_j").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(after.get("epoch").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn tcp_round_trip() {
        use std::io::{BufRead, BufReader, Write};
        let c = coordinator();
        let handle = serve_forever(Arc::clone(&c), 0).unwrap();
        let mut stream =
            std::net::TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
        writeln!(stream, r#"{{"region": 0, "model": 1}}"#).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let r = Json::parse(line.trim()).unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        writeln!(stream, r#"{{"op": "shutdown"}}"#).unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        handle.thread.join().unwrap();
        assert!(c.stopped());
    }

    #[test]
    fn tcp_pipelined_lines_in_one_segment_all_get_replies() {
        use std::io::{BufRead, BufReader, Write};
        let c = coordinator();
        let handle = serve_forever(Arc::clone(&c), 0).unwrap();
        let mut stream =
            std::net::TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
        // many requests in one write: the worker must answer each line
        let mut payload = String::new();
        for i in 0..50 {
            payload.push_str(&format!(
                "{{\"region\": {}, \"model\": {}}}\n",
                i % 4,
                i % 2
            ));
        }
        stream.write_all(payload.as_bytes()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        for _ in 0..50 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let r = Json::parse(line.trim()).unwrap();
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        }
        writeln!(stream, r#"{{"op": "shutdown"}}"#).unwrap();
        let mut last = String::new();
        reader.read_line(&mut last).unwrap();
        handle.thread.join().unwrap();
    }

    #[test]
    fn tcp_connection_flood_gets_backpressure_not_collapse() {
        use std::io::{BufRead, BufReader, Write};
        let c = coordinator();
        let handle = serve_with(
            Arc::clone(&c),
            0,
            ServerConfig {
                workers: 1,
                max_conns: 2,
                retry_ms: 7,
            },
        )
        .unwrap();
        // saturate admission with connections proven live via a round
        // trip (so both are admitted before the flood starts)
        let mut held = Vec::new();
        for _ in 0..2 {
            let mut s =
                std::net::TcpStream::connect(("127.0.0.1", handle.port))
                    .unwrap();
            writeln!(s, r#"{{"region": 0, "model": 0}}"#).unwrap();
            let mut rd = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            rd.read_line(&mut line).unwrap();
            let r = Json::parse(line.trim()).unwrap();
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
            held.push((s, rd));
        }
        // the flood: every connection past the bound gets a structured
        // overloaded reply with the retry hint, then EOF
        for _ in 0..5 {
            let s = std::net::TcpStream::connect(("127.0.0.1", handle.port))
                .unwrap();
            let mut reader = BufReader::new(s);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let r = Json::parse(line.trim()).unwrap();
            assert_eq!(
                r.get("error").and_then(Json::as_str),
                Some("overloaded"),
                "flooded connection was not shed: {line}"
            );
            assert_eq!(r.get("retry_ms").and_then(Json::as_f64), Some(7.0));
            let mut eof = String::new();
            assert_eq!(reader.read_line(&mut eof).unwrap(), 0);
        }
        assert_eq!(c.metrics_snapshot().overloaded, 5);
        // held connections still get service through the flood
        {
            let (stream, rd) = &mut held[0];
            writeln!(stream, r#"{{"region": 1, "model": 1}}"#).unwrap();
            let mut line = String::new();
            rd.read_line(&mut line).unwrap();
            let r = Json::parse(line.trim()).unwrap();
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        }
        // ...and once the flood clears, new connections are admitted again
        drop(held);
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut fresh =
            std::net::TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
        writeln!(fresh, r#"{{"op": "shutdown"}}"#).unwrap();
        let mut reader = BufReader::new(fresh);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let r = Json::parse(line.trim()).unwrap();
        assert_eq!(r.get("stopping").and_then(Json::as_bool), Some(true));
        handle.thread.join().unwrap();
    }

    #[test]
    fn tcp_malformed_lines_get_structured_errors_and_keep_the_connection() {
        use std::io::{BufRead, BufReader, Write};
        let c = coordinator();
        let handle = serve_forever(Arc::clone(&c), 0).unwrap();
        let mut stream =
            std::net::TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut expect_error = |stream: &mut std::net::TcpStream,
                                payload: &[u8]| {
            stream.write_all(payload).unwrap();
            stream.write_all(b"\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(!line.is_empty(), "connection dropped on {payload:?}");
            let r = Json::parse(line.trim()).unwrap();
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
            assert!(r.get("error").and_then(Json::as_str).is_some());
        };
        // malformed JSON, unknown op, non-string op, and a non-UTF-8 line:
        // each earns a structured error on the SAME connection
        expect_error(&mut stream, b"this is not json");
        expect_error(&mut stream, br#"{"op": "frobnicate"}"#);
        expect_error(&mut stream, br#"{"op": 42}"#);
        expect_error(&mut stream, &[0xff, 0xfe, 0x80, b'{']);
        // ...which must still be alive and serving
        writeln!(stream, r#"{{"region": 0, "model": 0}}"#).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let r = Json::parse(line.trim()).unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        writeln!(stream, r#"{{"op": "shutdown"}}"#).unwrap();
        let mut last = String::new();
        reader.read_line(&mut last).unwrap();
        handle.thread.join().unwrap();
    }

    #[test]
    fn tcp_drill_ops_round_trip() {
        use std::io::{BufRead, BufReader, Write};
        let c = coordinator();
        let handle = serve_forever(Arc::clone(&c), 0).unwrap();
        let mut stream =
            std::net::TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut call = |stream: &mut std::net::TcpStream,
                        payload: &str|
         -> Json {
            writeln!(stream, "{payload}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(line.trim()).unwrap()
        };
        let snap = call(&mut stream, r#"{"op": "snapshot"}"#);
        let full = snap.get("total_nodes").and_then(Json::as_f64).unwrap();
        let r = call(
            &mut stream,
            r#"{"op": "cluster", "action": "scale-region", "region": 2, "frac": 0}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        let dipped = call(&mut stream, r#"{"op": "snapshot"}"#);
        assert!(
            dipped.get("total_nodes").and_then(Json::as_f64).unwrap() < full
        );
        let r = call(
            &mut stream,
            r#"{"op": "cluster", "action": "restore-region", "region": 2}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        let restored = call(&mut stream, r#"{"op": "snapshot"}"#);
        assert_eq!(
            restored.get("total_nodes").and_then(Json::as_f64),
            Some(full)
        );
        call(&mut stream, r#"{"op": "shutdown"}"#);
        handle.thread.join().unwrap();
    }
}
