//! JSON-lines TCP front for the coordinator.
//!
//! Protocol (one JSON object per line, both directions; DESIGN.md §12 has
//! the full op table and epoch-boundary semantics):
//!   -> {"region": 0-3, "model": 0-1, "tok_in": N, "tok_out": N}
//!   <- {"ok": true, "dc": "oregon", "dc_index": 7, "ttft_ms": 12.5,
//!       "epoch": 3}
//!   <- {"ok": false, "error": "..."}
//! Special ops:
//!   -> {"op": "stats"}    <- serving metrics snapshot
//!   -> {"op": "plan"}     <- current routing plan (per-class rows)
//!   -> {"op": "batch"}    <- route/place a request group as one batch
//!   -> {"op": "snapshot"} <- live cluster topology (per-site node counts)
//!   -> {"op": "ledger"}   <- cumulative sustainability ledger
//!   -> {"op": "cluster"}  <- apply a ClusterAction (outage drills);
//!                            takes effect at the next epoch tick
//!   -> {"op": "tick"}     <- force an epoch tick now (drill/test clock)
//!   -> {"op": "shutdown"}
//!
//! Every malformed input — bad JSON, a non-string/unknown `op`, even a
//! non-UTF-8 line — gets a structured {"ok": false, "error": ...} reply;
//! the connection is never silently dropped on client error.
//!
//! std::net + a thread per connection (bounded by the acceptor): the
//! offline image has no tokio, and the router critical section is
//! microseconds, so blocking IO threads are a faithful stand-in.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::cluster::ClusterAction;
use crate::util::json::Json;

use super::Coordinator;

/// Handle returned by [`serve_forever`]'s spawner.
pub struct ServeHandle {
    pub port: u16,
    pub thread: std::thread::JoinHandle<()>,
}

/// Bind `port` (0 = ephemeral) and serve until the coordinator is stopped.
/// Returns once the listener is ready; serving continues on a thread.
pub fn serve_forever(
    coordinator: Arc<Coordinator>,
    port: u16,
) -> anyhow::Result<ServeHandle> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let actual_port = listener.local_addr()?.port();
    listener.set_nonblocking(true)?;
    let thread = std::thread::Builder::new()
        .name("slit-acceptor".into())
        .spawn(move || {
            let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
            loop {
                if coordinator.stopped() {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let c = Arc::clone(&coordinator);
                        workers.push(
                            std::thread::Builder::new()
                                .name("slit-conn".into())
                                .spawn(move || handle_conn(c, stream))
                                .expect("spawn conn"),
                        );
                        workers.retain(|w| !w.is_finished());
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(
                            5,
                        ));
                    }
                    Err(_) => break,
                }
            }
            for w in workers {
                let _ = w.join();
            }
        })?;
    Ok(ServeHandle {
        port: actual_port,
        thread,
    })
}

fn handle_conn(c: Arc<Coordinator>, stream: TcpStream) {
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(30)))
        .ok();
    // request/reply lines are tiny: Nagle + delayed-ACK would add ~40 ms
    // per round trip (measured in §Perf; 86 -> >2000 req/s after)
    stream.set_nodelay(true).ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) | Err(_) => break, // EOF or socket error/timeout
            Ok(_) => {}
        }
        // raw bytes, not `lines()`: a non-UTF-8 line must produce a
        // structured parse-error reply, not a silent disconnect (the
        // lossy conversion feeds the JSON parser, which rejects the
        // replacement characters with a reportable error)
        let line = String::from_utf8_lossy(&buf);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let reply = respond(&c, line);
        let stop = matches!(reply.get("stopping").and_then(Json::as_bool), Some(true));
        if writeln!(writer, "{reply}").is_err() {
            break;
        }
        if stop || c.stopped() {
            break;
        }
    }
}

/// Structured error reply: `{"ok": false, "error": msg}`.
fn error_reply(msg: &str) -> Json {
    let mut r = Json::obj();
    r.set("ok", Json::Bool(false));
    r.set("error", Json::Str(msg.into()));
    r
}

/// Strict non-negative integer field. `Json::as_usize` is a saturating
/// float cast (-1 -> 0), which would silently redirect a malformed index
/// at site/region 0 — here anything missing, negative, or fractional is
/// `None` so the caller's range check rejects it.
fn index_field(msg: &Json, key: &str) -> Option<usize> {
    let v = msg.get(key)?.as_f64()?;
    if v.is_finite() && v >= 0.0 && v.fract() == 0.0 {
        Some(v as usize)
    } else {
        None
    }
}

/// Pure request -> reply mapping (unit-testable without sockets). Every
/// input, however malformed, maps to exactly one reply object.
pub fn respond(c: &Coordinator, line: &str) -> Json {
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return error_reply(&format!("bad json: {e}")),
    };
    match parsed.get("op") {
        // a present-but-non-string op must not fall through to the plain
        // request path (it would earn a misleading range error there)
        Some(op) => match op.as_str() {
            Some(op) => respond_op(c, op, &parsed),
            None => error_reply("'op' must be a string"),
        },
        None => respond_request(c, &parsed),
    }
}

/// Dispatch a special `{"op": ...}` message.
fn respond_op(c: &Coordinator, op: &str, parsed: &Json) -> Json {
    match op {
        "stats" => {
            let m = c.metrics_snapshot();
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("served", Json::Num(m.served as f64));
            r.set("rejected", Json::Num(m.rejected as f64));
            r.set("plan_refreshes", Json::Num(m.plan_refreshes as f64));
            r.set("ttft_mean_ms", Json::Num(m.ttft.mean() * 1e3));
            r.set("ttft_max_ms", Json::Num(m.ttft.max() * 1e3));
            r.set("carbon_kg", Json::Num(m.ledger.carbon_kg));
            r.set("water_l", Json::Num(m.ledger.water_l));
            r.set("cost_usd", Json::Num(m.ledger.cost_usd));
            r.set("epoch", Json::Num(c.current_epoch() as f64));
            r.set("backend", Json::Str(c.backend().into()));
            return r;
        }
        "plan" => {
            let plan = c.current_plan();
            let mut rows = Vec::new();
            for k in 0..plan.classes {
                rows.push(Json::num_arr(plan.row(k)));
            }
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("plan", Json::Arr(rows));
            return r;
        }
        "snapshot" => return snapshot_reply(c),
        "ledger" => return ledger_reply(c),
        "tick" => {
            // force an epoch boundary now: drills and tests drive the
            // epoch clock deterministically instead of waiting wall time
            c.tick_epoch();
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("epoch", Json::Num(c.current_epoch() as f64));
            return r;
        }
        "cluster" => {
            return match parse_cluster_action(c, parsed) {
                Ok(action) => {
                    c.apply_cluster_action(&action);
                    let mut r = Json::obj();
                    r.set("ok", Json::Bool(true));
                    r.set(
                        "applied",
                        parsed
                            .get("action")
                            .and_then(Json::as_str)
                            .map(|a| Json::Str(a.into()))
                            .unwrap_or(Json::Null),
                    );
                    // actions land on the live state immediately but the
                    // plan/capacity only rebuild at the next tick
                    r.set(
                        "effective_epoch",
                        Json::Num((c.current_epoch() + 1) as f64),
                    );
                    r
                }
                Err(msg) => error_reply(&msg),
            };
        }
        "batch" => {
            // {"op":"batch","requests":[{"region":..,"model":..,...},..]}
            let Some(reqs) = parsed.get("requests").and_then(Json::as_arr)
            else {
                return error_reply("batch needs 'requests'");
            };
            let mut batch = Vec::with_capacity(reqs.len());
            for q in reqs {
                let region = index_field(q, "region").unwrap_or(usize::MAX);
                let model = index_field(q, "model").unwrap_or(usize::MAX);
                if region >= crate::config::REGIONS
                    || model >= crate::config::MODELS
                {
                    return error_reply("region/model out of range");
                }
                batch.push((
                    region,
                    model,
                    q.f64_or("tok_in", 128.0).max(1.0) as u32,
                    q.f64_or("tok_out", 256.0).max(1.0) as u32,
                ));
            }
            let results = c.handle_batch(&batch);
            let mut arr = Vec::with_capacity(results.len());
            for res in results {
                let mut item = Json::obj();
                match res {
                    Some((dc, ttft_s)) => {
                        item.set("ok", Json::Bool(true));
                        item.set(
                            "dc",
                            Json::Str(c.cfg.datacenters[dc].name.clone()),
                        );
                        item.set("ttft_ms", Json::Num(ttft_s * 1e3));
                    }
                    None => {
                        item.set("ok", Json::Bool(false));
                    }
                }
                arr.push(item);
            }
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("results", Json::Arr(arr));
            return r;
        }
        "shutdown" => {
            c.stop();
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set("stopping", Json::Bool(true));
            return r;
        }
        other => error_reply(&format!("unknown op '{other}'")),
    }
}

/// `{"op": "snapshot"}` — the live cluster topology, per site.
fn snapshot_reply(c: &Coordinator) -> Json {
    let snap = c.cluster_snapshot();
    let mut sites = Vec::with_capacity(c.cfg.datacenters.len());
    let mut total = 0usize;
    for (l, spec) in c.cfg.datacenters.iter().enumerate() {
        total += snap.total_nodes(l);
        let counts: Vec<f64> =
            snap.nodes(l).iter().map(|&n| n as f64).collect();
        let mut s = Json::obj();
        s.set("dc", Json::Num(l as f64));
        s.set("name", Json::Str(spec.name.clone()));
        s.set("region", Json::Num(spec.region as f64));
        s.set("nodes", Json::num_arr(&counts));
        s.set("total", Json::Num(snap.total_nodes(l) as f64));
        sites.push(s);
    }
    let mut r = Json::obj();
    r.set("ok", Json::Bool(true));
    r.set("epoch", Json::Num(c.current_epoch() as f64));
    r.set("baseline", Json::Bool(snap.is_baseline()));
    r.set("total_nodes", Json::Num(total as f64));
    r.set("sites", Json::Arr(sites));
    r
}

/// `{"op": "ledger"}` — the cumulative sustainability/performance ledger
/// (everything accounted since the coordinator started).
fn ledger_reply(c: &Coordinator) -> Json {
    let m = c.metrics_snapshot();
    let mut r = Json::obj();
    r.set("ok", Json::Bool(true));
    r.set("epoch", Json::Num(c.current_epoch() as f64));
    r.set("e_it_j", Json::Num(m.ledger.e_it_j));
    r.set("e_tot_j", Json::Num(m.ledger.e_tot_j));
    r.set("carbon_kg", Json::Num(m.ledger.carbon_kg));
    r.set("water_l", Json::Num(m.ledger.water_l));
    r.set("cost_usd", Json::Num(m.ledger.cost_usd));
    r.set("served", Json::Num(m.served as f64));
    r.set("rejected", Json::Num(m.rejected as f64));
    r.set("batches", Json::Num(m.batches as f64));
    r.set("ttft_mean_ms", Json::Num(m.ttft.mean() * 1e3));
    r
}

/// Validate and decode a `{"op": "cluster", "action": ...}` message.
fn parse_cluster_action(
    c: &Coordinator,
    msg: &Json,
) -> Result<ClusterAction, String> {
    let Some(action) = msg.get("action").and_then(Json::as_str) else {
        return Err("cluster needs an 'action' string (one of: \
                    scale-region, restore-region, scale-site, \
                    restore-site, set-site)"
            .into());
    };
    let region = || -> Result<usize, String> {
        match index_field(msg, "region") {
            Some(r) if r < crate::config::REGIONS => Ok(r),
            _ => Err(format!(
                "'region' must be an integer in 0..{}",
                crate::config::REGIONS
            )),
        }
    };
    let dc = || -> Result<usize, String> {
        match index_field(msg, "dc") {
            Some(d) if d < c.cfg.datacenters.len() => Ok(d),
            _ => Err(format!(
                "'dc' must be an integer in 0..{}",
                c.cfg.datacenters.len()
            )),
        }
    };
    let frac = || -> Result<f64, String> {
        let f = msg.f64_or("frac", f64::NAN);
        if f.is_finite() && f >= 0.0 {
            Ok(f)
        } else {
            Err("'frac' must be a finite number >= 0".into())
        }
    };
    match action {
        "scale-region" => Ok(ClusterAction::ScaleRegion {
            region: region()?,
            frac: frac()?,
        }),
        "restore-region" => {
            Ok(ClusterAction::RestoreRegion { region: region()? })
        }
        "scale-site" => Ok(ClusterAction::ScaleSite {
            dc: dc()?,
            frac: frac()?,
        }),
        "restore-site" => Ok(ClusterAction::RestoreSite { dc: dc()? }),
        "set-site" => {
            let nodes = msg
                .f64_vec("nodes")
                .ok_or("set-site needs a 'nodes' array of numbers")?;
            if nodes.iter().any(|&n| !n.is_finite() || n < 0.0) {
                return Err("'nodes' entries must be finite and >= 0".into());
            }
            Ok(ClusterAction::SetSite {
                dc: dc()?,
                nodes_per_type: nodes.iter().map(|&n| n as usize).collect(),
            })
        }
        other => Err(format!("unknown cluster action '{other}'")),
    }
}

/// Handle a plain (op-less) single-request message.
fn respond_request(c: &Coordinator, parsed: &Json) -> Json {
    let region = index_field(parsed, "region").unwrap_or(usize::MAX);
    let model = index_field(parsed, "model").unwrap_or(usize::MAX);
    if region >= crate::config::REGIONS || model >= crate::config::MODELS {
        return error_reply("region/model out of range");
    }
    let tok_in = parsed.f64_or("tok_in", 128.0) as u32;
    let tok_out = parsed.f64_or("tok_out", 256.0) as u32;
    match c.handle(region, model, tok_in.max(1), tok_out.max(1)) {
        Some((dc, ttft_s)) => {
            let mut r = Json::obj();
            r.set("ok", Json::Bool(true));
            r.set(
                "dc",
                Json::Str(c.cfg.datacenters[dc].name.clone()),
            );
            r.set("dc_index", Json::Num(dc as f64));
            r.set("ttft_ms", Json::Num(ttft_s * 1e3));
            r.set("epoch", Json::Num(c.current_epoch() as f64));
            r
        }
        None => {
            let mut r = Json::obj();
            r.set("ok", Json::Bool(false));
            r.set("error", Json::Str("all sites saturated".into()));
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::coordinator::CoordinatorConfig;

    fn coordinator() -> Arc<Coordinator> {
        let mut cfg = SystemConfig::small_test();
        cfg.opt.generations = 2;
        cfg.opt.population = 8;
        Coordinator::new(cfg, CoordinatorConfig::default(), None)
    }

    #[test]
    fn respond_serves_request() {
        let c = coordinator();
        let r = respond(
            &c,
            r#"{"region": 1, "model": 0, "tok_in": 100, "tok_out": 150}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        assert!(r.get("ttft_ms").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(r.get("dc").and_then(Json::as_str).is_some());
    }

    #[test]
    fn respond_rejects_bad_input() {
        let c = coordinator();
        assert_eq!(
            respond(&c, "not json").get("ok").and_then(Json::as_bool),
            Some(false)
        );
        assert_eq!(
            respond(&c, r#"{"region": 99, "model": 0}"#)
                .get("ok")
                .and_then(Json::as_bool),
            Some(false)
        );
        // a negative region must not saturate to region 0 and serve
        assert_eq!(
            respond(&c, r#"{"region": -1, "model": 0}"#)
                .get("ok")
                .and_then(Json::as_bool),
            Some(false)
        );
        assert_eq!(
            respond(&c, r#"{"op": "nope"}"#)
                .get("ok")
                .and_then(Json::as_bool),
            Some(false)
        );
    }

    #[test]
    fn respond_stats_and_plan() {
        let c = coordinator();
        respond(&c, r#"{"region": 0, "model": 0}"#);
        let s = respond(&c, r#"{"op": "stats"}"#);
        assert_eq!(s.get("served").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            s.get("backend").and_then(Json::as_str),
            Some("analytic")
        );
        let p = respond(&c, r#"{"op": "plan"}"#);
        let rows = p.get("plan").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), c.cfg.num_classes());
    }

    #[test]
    fn respond_batch_op() {
        let c = coordinator();
        let r = respond(
            &c,
            r#"{"op":"batch","requests":[
                {"region":0,"model":0,"tok_in":64,"tok_out":128},
                {"region":3,"model":1,"tok_in":512,"tok_out":256}]}"#
                .replace('\n', " ")
                .as_str(),
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        let results = r.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 2);
        for item in results {
            assert_eq!(item.get("ok").and_then(Json::as_bool), Some(true));
            assert!(item.get("ttft_ms").and_then(Json::as_f64).unwrap() > 0.0);
        }
        let m = c.metrics_snapshot();
        assert_eq!(m.served, 2);
        assert!(m.batches >= 1);
    }

    #[test]
    fn respond_batch_rejects_bad_member() {
        let c = coordinator();
        let r = respond(
            &c,
            r#"{"op":"batch","requests":[{"region":9,"model":0}]}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
        let neg = respond(
            &c,
            r#"{"op":"batch","requests":[{"region":-1,"model":0}]}"#,
        );
        assert_eq!(neg.get("ok").and_then(Json::as_bool), Some(false));
        let r2 = respond(&c, r#"{"op":"batch"}"#);
        assert_eq!(r2.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn respond_rejects_non_string_op() {
        let c = coordinator();
        let r = respond(&c, r#"{"op": 5}"#);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
        assert!(r
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("'op' must be a string"));
    }

    #[test]
    fn respond_snapshot_reports_live_topology() {
        let c = coordinator();
        let s = respond(&c, r#"{"op": "snapshot"}"#);
        assert_eq!(s.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(s.get("baseline").and_then(Json::as_bool), Some(true));
        let sites = s.get("sites").and_then(Json::as_arr).unwrap();
        assert_eq!(sites.len(), c.cfg.datacenters.len());
        let total: f64 = sites
            .iter()
            .map(|s| s.get("total").and_then(Json::as_f64).unwrap())
            .sum();
        assert_eq!(
            s.get("total_nodes").and_then(Json::as_f64),
            Some(total)
        );
        assert!(total > 0.0);
    }

    #[test]
    fn respond_cluster_op_dips_and_restores_topology() {
        let c = coordinator();
        let total = |j: &Json| -> f64 {
            j.get("total_nodes").and_then(Json::as_f64).unwrap()
        };
        let full = total(&respond(&c, r#"{"op": "snapshot"}"#));

        let r = respond(
            &c,
            r#"{"op": "cluster", "action": "scale-region", "region": 2, "frac": 0}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(
            r.get("applied").and_then(Json::as_str),
            Some("scale-region")
        );
        assert_eq!(r.get("effective_epoch").and_then(Json::as_f64), Some(1.0));
        // the live state mutates immediately; the snapshot shows the dip
        let dipped = respond(&c, r#"{"op": "snapshot"}"#);
        assert!(total(&dipped) < full);
        assert_eq!(
            dipped.get("baseline").and_then(Json::as_bool),
            Some(false)
        );
        // tick, then restore + tick: whole again
        let t = respond(&c, r#"{"op": "tick"}"#);
        assert_eq!(t.get("epoch").and_then(Json::as_f64), Some(1.0));
        let r = respond(
            &c,
            r#"{"op": "cluster", "action": "restore-region", "region": 2}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        respond(&c, r#"{"op": "tick"}"#);
        let restored = respond(&c, r#"{"op": "snapshot"}"#);
        assert_eq!(total(&restored), full);
        assert_eq!(
            restored.get("baseline").and_then(Json::as_bool),
            Some(true)
        );
    }

    #[test]
    fn respond_cluster_op_validates_input() {
        let c = coordinator();
        for bad in [
            r#"{"op": "cluster"}"#,
            r#"{"op": "cluster", "action": "warp-drive"}"#,
            r#"{"op": "cluster", "action": "scale-region", "region": 99, "frac": 0.5}"#,
            r#"{"op": "cluster", "action": "scale-region", "region": 1}"#,
            // negative/fractional indices must NOT saturate to site 0
            r#"{"op": "cluster", "action": "scale-region", "region": -1, "frac": 0}"#,
            r#"{"op": "cluster", "action": "scale-site", "dc": -2, "frac": 0.5}"#,
            r#"{"op": "cluster", "action": "scale-site", "dc": 1.5, "frac": 0.5}"#,
            r#"{"op": "cluster", "action": "scale-site", "dc": 9999, "frac": 0.5}"#,
            r#"{"op": "cluster", "action": "scale-site", "dc": 0, "frac": -1}"#,
            r#"{"op": "cluster", "action": "set-site", "dc": 0}"#,
            r#"{"op": "cluster", "action": "set-site", "dc": 0, "nodes": [-1]}"#,
        ] {
            let r = respond(&c, bad);
            assert_eq!(
                r.get("ok").and_then(Json::as_bool),
                Some(false),
                "accepted: {bad}"
            );
            assert!(r.get("error").and_then(Json::as_str).is_some());
        }
        // a rejected action must not have mutated the topology
        let s = respond(&c, r#"{"op": "snapshot"}"#);
        assert_eq!(s.get("baseline").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn respond_set_site_replaces_counts() {
        let c = coordinator();
        let r = respond(
            &c,
            r#"{"op": "cluster", "action": "set-site", "dc": 0, "nodes": [1, 1, 1, 1, 1, 1]}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        let s = respond(&c, r#"{"op": "snapshot"}"#);
        let site0 = s.get("sites").and_then(Json::as_arr).unwrap()[0]
            .get("total")
            .and_then(Json::as_f64);
        assert_eq!(site0, Some(6.0));
    }

    #[test]
    fn respond_ledger_accumulates_after_tick() {
        let c = coordinator();
        for i in 0..20 {
            respond(
                &c,
                &format!(r#"{{"region": {}, "model": 0}}"#, i % 4),
            );
        }
        let before = respond(&c, r#"{"op": "ledger"}"#);
        assert_eq!(before.get("served").and_then(Json::as_f64), Some(20.0));
        assert_eq!(before.get("carbon_kg").and_then(Json::as_f64), Some(0.0));
        respond(&c, r#"{"op": "tick"}"#);
        let after = respond(&c, r#"{"op": "ledger"}"#);
        assert!(after.get("carbon_kg").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(after.get("e_tot_j").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(after.get("epoch").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn tcp_round_trip() {
        use std::io::{BufRead, BufReader, Write};
        let c = coordinator();
        let handle = serve_forever(Arc::clone(&c), 0).unwrap();
        let mut stream =
            std::net::TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
        writeln!(stream, r#"{{"region": 0, "model": 1}}"#).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let r = Json::parse(line.trim()).unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        writeln!(stream, r#"{{"op": "shutdown"}}"#).unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        handle.thread.join().unwrap();
        assert!(c.stopped());
    }

    #[test]
    fn tcp_malformed_lines_get_structured_errors_and_keep_the_connection() {
        use std::io::{BufRead, BufReader, Write};
        let c = coordinator();
        let handle = serve_forever(Arc::clone(&c), 0).unwrap();
        let mut stream =
            std::net::TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut expect_error = |stream: &mut std::net::TcpStream,
                                payload: &[u8]| {
            stream.write_all(payload).unwrap();
            stream.write_all(b"\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(!line.is_empty(), "connection dropped on {payload:?}");
            let r = Json::parse(line.trim()).unwrap();
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
            assert!(r.get("error").and_then(Json::as_str).is_some());
        };
        // malformed JSON, unknown op, non-string op, and a non-UTF-8 line:
        // each earns a structured error on the SAME connection
        expect_error(&mut stream, b"this is not json");
        expect_error(&mut stream, br#"{"op": "frobnicate"}"#);
        expect_error(&mut stream, br#"{"op": 42}"#);
        expect_error(&mut stream, &[0xff, 0xfe, 0x80, b'{']);
        // ...which must still be alive and serving
        writeln!(stream, r#"{{"region": 0, "model": 0}}"#).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let r = Json::parse(line.trim()).unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        writeln!(stream, r#"{{"op": "shutdown"}}"#).unwrap();
        let mut last = String::new();
        reader.read_line(&mut last).unwrap();
        handle.thread.join().unwrap();
    }

    #[test]
    fn tcp_drill_ops_round_trip() {
        use std::io::{BufRead, BufReader, Write};
        let c = coordinator();
        let handle = serve_forever(Arc::clone(&c), 0).unwrap();
        let mut stream =
            std::net::TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut call = |stream: &mut std::net::TcpStream,
                        payload: &str|
         -> Json {
            writeln!(stream, "{payload}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(line.trim()).unwrap()
        };
        let snap = call(&mut stream, r#"{"op": "snapshot"}"#);
        let full = snap.get("total_nodes").and_then(Json::as_f64).unwrap();
        let r = call(
            &mut stream,
            r#"{"op": "cluster", "action": "scale-region", "region": 2, "frac": 0}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        let dipped = call(&mut stream, r#"{"op": "snapshot"}"#);
        assert!(
            dipped.get("total_nodes").and_then(Json::as_f64).unwrap() < full
        );
        let r = call(
            &mut stream,
            r#"{"op": "cluster", "action": "restore-region", "region": 2}"#,
        );
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        let restored = call(&mut stream, r#"{"op": "snapshot"}"#);
        assert_eq!(
            restored.get("total_nodes").and_then(Json::as_f64),
            Some(full)
        );
        call(&mut stream, r#"{"op": "shutdown"}"#);
        handle.thread.join().unwrap();
    }
}
