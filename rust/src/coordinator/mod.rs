//! L3 online coordinator: the serving-side embodiment of SLIT.
//!
//! A leader process owns the epoch clock. Each (compressed) epoch it runs
//! the SLIT metaheuristic — against the AOT/PJRT plan evaluator when
//! artifacts are loaded, the native evaluator otherwise — and atomically
//! swaps the active routing plan. Request handling never touches python:
//!
//!   request -> router (plan-weighted site choice, saturation failover)
//!           -> per-(site, model) dynamic batcher
//!           -> local WRR placement (sched::LocalScheduler)
//!           -> TTFT reply + ledger accounting
//!
//! A JSON-lines TCP front (std::net; the offline image has no tokio — see
//! DESIGN.md substitutions) exposes the router; `examples/serve_realtime.rs`
//! drives it end-to-end and reports latency/throughput percentiles.

mod batcher;
mod drill;
mod loadgen;
mod router;
mod server;

pub use batcher::{
    dispatch_order, Batch, BatchItem, Batcher, BatcherConfig, DispatchPolicy,
    LaxityModel,
};
pub use drill::{run_drill, DrillClient, DrillConfig, DrillReport};
pub use loadgen::{
    format_report, run_loadgen, ArrivalMode, LoadgenConfig, LoadgenReport,
};
pub use router::{RouteOutcome, Router};
pub use server::{serve_forever, serve_with, ServeHandle, ServerConfig};

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::cluster::{build_panels_with, ClusterAction, ClusterState};
use crate::config::{SystemConfig, MODELS};
use crate::eval::{AnalyticEvaluator, EvalConsts};
use crate::models::EpochLedger;
use crate::opt::{SlitOptimizer, SlitVariant};
use crate::plan::Plan;
use crate::power::GridSignals;
use crate::predictor::WorkloadPredictor;
use crate::runtime::{Engine, HloPlanEvaluator};
use crate::sched::LocalScheduler;
use crate::signals::{SignalFeed, SignalPolicy};
use crate::trace::{ClassLoad, EpochLoad};
use crate::util::histogram::LatencyHistogram;
use crate::util::rng::Rng;
use crate::util::stats::Welford;

/// Coordinator deployment settings.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Which showcased SLIT solution the router deploys.
    pub variant: SlitVariant,
    /// Real seconds per simulated epoch (time compression for demos).
    pub epoch_wall_s: f64,
    /// Optimizer budget per plan refresh, seconds.
    pub plan_budget_s: f64,
    /// Which believed-telemetry view the re-plan consumes. Robust by
    /// default: with zero injected faults the robust view is bit-identical
    /// to ground truth, and under faults the fallback ladder keeps the
    /// planner on plausible signals instead of frozen/corrupt ones.
    pub signal_policy: SignalPolicy,
    pub batcher: BatcherConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            variant: SlitVariant::Balance,
            epoch_wall_s: 2.0,
            plan_budget_s: 1.0,
            signal_policy: SignalPolicy::Robust,
            batcher: BatcherConfig::default(),
        }
    }
}

/// Aggregated serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub ttft: Welford,
    /// TTFT distribution (p50/p95/p99 in `stats`/`ledger` replies).
    pub ttft_hist: LatencyHistogram,
    /// Per-class TTFT distributions, k = region * MODELS + model
    /// (grown on first record, so Default stays cheap).
    pub class_ttft: Vec<LatencyHistogram>,
    pub served: u64,
    pub rejected: u64,
    /// Connections turned away at the TCP front with a structured
    /// `overloaded` reply (bounded admission, not silent drop).
    pub overloaded: u64,
    pub batches: u64,
    pub batch_sizes: Welford,
    pub plan_refreshes: u64,
    pub ledger: EpochLedger,
}

impl Metrics {
    /// Record one served TTFT into every aggregate (mean, overall
    /// histogram, per-class histogram).
    pub fn record_ttft(&mut self, class: usize, ttft_s: f64) {
        self.ttft.push(ttft_s);
        self.ttft_hist.record(ttft_s);
        if class >= self.class_ttft.len() {
            self.class_ttft
                .resize_with(class + 1, LatencyHistogram::new);
        }
        self.class_ttft[class].record(ttft_s);
    }
}

/// One row of [`Coordinator::signal_snapshot`]: the believed-telemetry
/// health of a single site's grid feed (TCP `{"op": "signals"}` reply).
#[derive(Clone, Debug)]
pub struct SiteSignal {
    pub name: String,
    pub region: usize,
    /// Health classification: `fresh` / `stale` / `quarantined`.
    pub state: &'static str,
    /// Epochs since the last accepted measurement.
    pub age: usize,
    /// Fallback-ladder rung behind the current robust value.
    pub source: &'static str,
    /// Believed CI/WUE/TOU under the deployed [`SignalPolicy`] — what the
    /// next re-plan will consume, not necessarily the ground truth.
    pub ci: f64,
    pub wue: f64,
    pub tou: f64,
}

/// Shared state between the router, batcher flushers, and the epoch thread.
pub struct Coordinator {
    pub cfg: SystemConfig,
    pub ccfg: CoordinatorConfig,
    plan: RwLock<Plan>,
    locals: Vec<Mutex<LocalScheduler>>,
    epoch: AtomicUsize,
    signals: GridSignals,
    predictor: Mutex<WorkloadPredictor>,
    /// Arrivals observed during the current epoch (per class).
    observed: Mutex<Vec<f64>>,
    /// Live cluster topology the epoch clock plans and accounts against
    /// (mutable at serve time via [`Coordinator::apply_cluster_action`]).
    state: RwLock<ClusterState>,
    /// Per-origin-region site order, nearest-first by Eq. 3 hops —
    /// precomputed once so the per-request failover walk allocates nothing.
    failover_by_region: Vec<Vec<usize>>,
    /// Believed-telemetry layer for the re-plan: each tick the ground
    /// truth flows through the feed (where injected telemetry faults
    /// distort delivery) and the optimizer panels are built from the
    /// believed view, while ledger accounting stays on ground truth.
    feed: Mutex<SignalFeed>,
    pub metrics: Mutex<Metrics>,
    engine: Option<Arc<Engine>>,
    rng: Mutex<Rng>,
    /// Laxity inputs for LLF dispatch, precomputed once from the config.
    laxity: LaxityModel,
    /// Serializes whole epoch ticks. The epoch clock thread and the TCP
    /// `tick` op both call [`Coordinator::tick_epoch`]; without this, two
    /// interleaved ticks each read the same epoch-0 on-times before either
    /// reset capacity, double-accounting that epoch's energy.
    tick_lock: Mutex<()>,
    stop: AtomicBool,
}

impl Coordinator {
    pub fn new(
        cfg: SystemConfig,
        ccfg: CoordinatorConfig,
        engine: Option<Arc<Engine>>,
    ) -> Arc<Coordinator> {
        let horizon = cfg.epochs.max(2 * crate::config::EPOCHS_PER_DAY);
        let signals = GridSignals::generate(&cfg, horizon, cfg.seed);
        let locals = (0..cfg.datacenters.len())
            .map(|l| Mutex::new(LocalScheduler::new(&cfg, l)))
            .collect();
        let classes = cfg.num_classes();
        let dcs = cfg.datacenters.len();
        let failover_by_region = (0..crate::config::REGIONS)
            .map(|region| {
                let hops: Vec<f64> =
                    (0..dcs).map(|l| cfg.hops(region, l)).collect();
                Router::hop_order(&hops)
            })
            .collect();
        Arc::new(Coordinator {
            plan: RwLock::new(Plan::uniform(classes, dcs)),
            locals,
            failover_by_region,
            feed: Mutex::new(SignalFeed::new(&cfg)),
            epoch: AtomicUsize::new(0),
            signals,
            predictor: Mutex::new(WorkloadPredictor::new(&cfg)),
            observed: Mutex::new(vec![0.0; classes]),
            state: RwLock::new(ClusterState::from_config(&cfg)),
            metrics: Mutex::new(Metrics::default()),
            engine,
            rng: Mutex::new(Rng::new(cfg.seed ^ 0xC0)),
            laxity: LaxityModel::from_config(&cfg),
            tick_lock: Mutex::new(()),
            stop: AtomicBool::new(false),
            cfg,
            ccfg,
        })
    }

    /// Mutate the live cluster topology at serve time (outage drills, node
    /// additions). Takes effect at the next epoch tick: the re-plan and
    /// per-site capacity resets both derive from this state.
    pub fn apply_cluster_action(&self, action: &ClusterAction) {
        if let ClusterAction::Signal(fault) = action {
            // telemetry faults live on the signal feed, not the topology;
            // like capacity actions they take effect at the next tick
            // (whose feed observation is for current_epoch() + 1)
            let epoch = self.current_epoch() + 1;
            self.feed.lock().expect("signal feed").inject(epoch, fault);
            return;
        }
        self.state.write().expect("cluster state").apply(action);
    }

    /// Snapshot of the live cluster topology.
    pub fn cluster_snapshot(&self) -> ClusterState {
        self.state.read().expect("cluster state").clone()
    }

    /// Per-site believed-telemetry health, as served by the TCP
    /// `{"op": "signals"}` reply: total faults injected so far plus one
    /// [`SiteSignal`] row per site.
    pub fn signal_snapshot(&self) -> (usize, Vec<SiteSignal>) {
        let feed = self.feed.lock().expect("signal feed");
        let (ci, wi, tou) = feed.view(self.ccfg.signal_policy);
        let rows = self
            .cfg
            .datacenters
            .iter()
            .enumerate()
            .map(|(l, d)| SiteSignal {
                name: d.name.clone(),
                region: d.region,
                state: feed.site_state(l).as_str(),
                age: feed.site_age(l),
                source: feed.site_source(l).as_str(),
                ci: ci[l],
                wue: wi[l],
                tou: tou[l],
            })
            .collect();
        (feed.faults_injected(), rows)
    }

    pub fn current_epoch(&self) -> usize {
        self.epoch.load(Ordering::Relaxed)
    }

    pub fn current_plan(&self) -> Plan {
        self.plan.read().expect("plan lock").clone()
    }

    pub fn backend(&self) -> &'static str {
        if self.engine.is_some() {
            "pjrt-hlo"
        } else {
            "analytic"
        }
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Handle one request end-to-end (router -> placement -> accounting).
    /// Returns (site index, ttft seconds) or None when rejected everywhere.
    pub fn handle(
        &self,
        region: usize,
        model: usize,
        tok_in: u32,
        tok_out: u32,
    ) -> Option<(usize, f64)> {
        let class = region * MODELS + model;
        {
            let mut obs = self.observed.lock().expect("observed");
            if class < obs.len() {
                obs[class] += 1.0;
            }
        }
        let plan = self.plan.read().expect("plan lock");
        let row = plan.row(class);
        let req = crate::trace::Request {
            arrival_s: 0.0,
            class,
            tok_in,
            tok_out,
        };
        let first = self.rng.lock().expect("rng").weighted(row);
        // serverless container churn: a cold_frac share of requests pay the
        // Eq. 2 load latency (consistent with the analytic/AOT evaluator)
        let is_warm = {
            let mut rng = self.rng.lock().expect("rng");
            !rng.chance(self.cfg.physics.cold_frac)
        };
        // saturation failover walks the remaining sites nearest-first by
        // Eq. 3 hops from the origin region (precomputed, allocation-free)
        let order = &self.failover_by_region[region];
        for l in std::iter::once(first)
            .chain(order.iter().copied().filter(|&l| l != first))
        {
            let hops = self.cfg.hops(region, l);
            let placed = {
                let mut ls = self.locals[l].lock().expect("local");
                ls.place(&self.cfg, &req, hops, is_warm)
            };
            if let Some(p) = placed {
                let mut m = self.metrics.lock().expect("metrics");
                m.record_ttft(class, p.ttft_s);
                m.served += 1;
                return Some((l, p.ttft_s));
            }
        }
        let mut m = self.metrics.lock().expect("metrics");
        m.rejected += 1;
        None
    }

    /// Handle a group of requests as one dynamic batch: route each request,
    /// group per (site, model) via [`Batcher`], order the groups by the
    /// configured [`DispatchPolicy`] (LLF by default — most urgent group
    /// commits site capacity first), then place every group under a single
    /// local-scheduler critical section. This is the router-side batching
    /// that keeps lock contention flat at high request rates; the TCP front
    /// exposes it as `{"op": "batch", ...}`.
    ///
    /// Returns one `Option<(site, ttft_s)>` per request, in input order.
    pub fn handle_batch(
        &self,
        requests: &[(usize, usize, u32, u32)], // (region, model, in, out)
    ) -> Vec<Option<(usize, f64)>> {
        let plan = self.current_plan();
        // A fresh batcher per call means the age cap can never fire on this
        // path — every group drains through size caps + flush_all below.
        // The cap exists for long-lived streaming batchers; pinned by
        // batch_age_cap_is_inert_in_handle_batch.
        let mut batcher =
            Batcher::new(self.ccfg.batcher, self.laxity.clone());
        // route + accumulate; remember each request's batch destination
        let mut routed: Vec<(usize, crate::trace::Request)> =
            Vec::with_capacity(requests.len());
        {
            let mut rng = self.rng.lock().expect("rng");
            let mut obs = self.observed.lock().expect("observed");
            for &(region, model, tok_in, tok_out) in requests {
                let class = region * MODELS + model;
                if class < obs.len() {
                    obs[class] += 1.0;
                }
                let req = crate::trace::Request {
                    arrival_s: 0.0,
                    class,
                    tok_in,
                    tok_out,
                };
                let dc = rng.weighted(plan.row(class));
                routed.push((dc, req));
            }
        }
        let mut results: Vec<Option<(usize, f64)>> =
            vec![None; requests.len()];
        // push through the batcher tagged with the caller's index — each
        // item carries its own result slot, so dispatch may reorder groups
        // freely without any placed-to-submitted back-mapping
        let mut groups: Vec<Batch> = Vec::new();
        for (i, &(dc, req)) in routed.iter().enumerate() {
            if let Some(b) = batcher.push(dc, req, i) {
                groups.push(b);
            }
        }
        groups.extend(batcher.flush_all());
        dispatch_order(&mut groups, batcher.policy());

        let mut batch_count = 0u64;
        for group in &groups {
            batch_count += 1;
            // one critical section per group
            let mut ls = self.locals[group.dc].lock().expect("local");
            let mut rng = self.rng.lock().expect("rng");
            for item in &group.items {
                let hops = self.cfg.hops(item.req.region(), group.dc);
                let is_warm = !rng.chance(self.cfg.physics.cold_frac);
                if let Some(p) =
                    ls.place(&self.cfg, &item.req, hops, is_warm)
                {
                    // a failed placement leaves the slot None for the
                    // failover pass below
                    results[item.tag] = Some((group.dc, p.ttft_s));
                }
            }
        }
        // hop-aware failover for requests whose batch destination was full
        // or dark: retried one site at a time *after* every group critical
        // section has been released (single-lock discipline — two
        // concurrent handle_batch calls can never hold-and-wait on each
        // other's site locks)
        for i in 0..results.len() {
            if results[i].is_some() {
                continue;
            }
            let (routed_dc, req) = routed[i];
            let region = req.region();
            let is_warm = {
                let mut rng = self.rng.lock().expect("rng");
                !rng.chance(self.cfg.physics.cold_frac)
            };
            for &l in self.failover_by_region[region]
                .iter()
                .filter(|&&l| l != routed_dc)
            {
                let hops = self.cfg.hops(region, l);
                let placed = {
                    let mut ls = self.locals[l].lock().expect("local");
                    ls.place(&self.cfg, &req, hops, is_warm)
                };
                if let Some(p) = placed {
                    results[i] = Some((l, p.ttft_s));
                    break;
                }
            }
        }
        let served =
            results.iter().filter(|r| r.is_some()).count() as u64;
        let rejected = results.len() as u64 - served;
        {
            let mut m = self.metrics.lock().expect("metrics");
            m.batches += batch_count;
            for group in &groups {
                m.batch_sizes.push(group.items.len() as f64);
            }
            m.served += served;
            m.rejected += rejected;
            for (i, r) in results.iter().enumerate() {
                if let Some((_, ttft_s)) = r {
                    m.record_ttft(routed[i].1.class, *ttft_s);
                }
            }
        }
        results
    }

    /// Advance the epoch clock by one epoch: account energy for the epoch
    /// that just ended, feed the predictor, re-plan, reset capacity — all
    /// against the live [`ClusterState`] rather than the frozen config, so
    /// serve-time topology changes take effect at the next tick.
    pub fn tick_epoch(&self) {
        // Whole-tick serialization: the epoch clock thread and the TCP
        // `tick` op race here, and an interleaved pair used to read the
        // same on-times twice before either reset capacity — the ledger
        // double-counted that epoch's energy (pinned by
        // racing_ticks_account_energy_exactly_once).
        let _tick = self.tick_lock.lock().expect("tick lock");
        let epoch = self.epoch.fetch_add(1, Ordering::SeqCst);
        let state = self.cluster_snapshot();

        // --- account the epoch that just finished -------------------------
        // Gather per-site energy first, one site lock at a time; the
        // metrics lock is taken only afterwards. Request paths lock a site
        // then metrics, so holding metrics while acquiring sites (as this
        // loop previously did) inverts that order.
        let (ci, wi, tou) = self.signals.at(epoch);
        let site_e_it: Vec<f64> = (0..self.cfg.datacenters.len())
            .map(|l| {
                let ls = self.locals[l].lock().expect("local");
                let live = state.nodes(l);
                let mut e_it = 0.0;
                for (ti, nt) in self.cfg.node_types.iter().enumerate() {
                    let on =
                        ls.capacity.on_nodes(ti, self.cfg.physics.epoch_s);
                    let nodes = live[ti] as f64;
                    // an action may have shrunk the site mid-epoch; never
                    // account negative idle capacity
                    e_it += (on * self.cfg.physics.pr_on
                        + (nodes - on).max(0.0) * self.cfg.physics.pr_off)
                        * nt.tdp_w
                        * self.cfg.physics.epoch_s;
                }
                e_it
            })
            .collect();
        {
            let mut m = self.metrics.lock().expect("metrics");
            for (l, spec) in self.cfg.datacenters.iter().enumerate() {
                m.ledger.add_site(
                    site_e_it[l],
                    spec.cop,
                    tou[l],
                    self.cfg.physics.h_water,
                    self.cfg.physics.d_ratio,
                    wi[l],
                    self.cfg.physics.ei_pot,
                    self.cfg.physics.ei_waste,
                    ci[l],
                );
            }
        }

        // --- predictor update + next-epoch forecast ------------------------
        let observed: Vec<f64> = {
            let mut obs = self.observed.lock().expect("observed");
            let copy = obs.clone();
            obs.iter_mut().for_each(|v| *v = 0.0);
            copy
        };
        let predicted = {
            let mut p = self.predictor.lock().expect("predictor");
            let load = EpochLoad {
                classes: observed
                    .iter()
                    .enumerate()
                    .map(|(k, &n)| ClassLoad {
                        n_req: n,
                        tok_in: self.cfg.models[k % MODELS].mean_in_tokens
                            * self.cfg.workload.token_scale,
                        tok_out: self.cfg.models[k % MODELS].mean_out_tokens
                            * self.cfg.workload.token_scale,
                        ..ClassLoad::default()
                    })
                    .collect(),
            };
            p.observe(&load);
            p.predict_next()
        };

        // --- re-plan against the forecast + live topology ------------------
        // The planning panels resolve through the signal plane: ground
        // truth is observed into the feed (where any injected telemetry
        // faults distort delivery) and the optimizer sees the believed
        // view under the deployed policy. Accounting above stayed on
        // truth, so the ledger's signal_* fields measure exactly what
        // scheduling on degraded telemetry cost.
        let next_epoch = epoch + 1;
        let (tci, twi, ttou) = self
            .signals
            .at(next_epoch.min(self.signals.epochs() - 1));
        let (cp, dp) = {
            let mut feed = self.feed.lock().expect("signal feed");
            feed.observe(next_epoch, &tci, &twi, &ttou);
            let div = feed.divergence(
                self.ccfg.signal_policy,
                &tci,
                &twi,
                &ttou,
            );
            let (fresh, stale, quarantined) = feed.health_counts();
            {
                let mut m = self.metrics.lock().expect("metrics");
                m.ledger.signal_fresh += fresh as f64;
                m.ledger.signal_stale += stale as f64;
                m.ledger.signal_quarantined += quarantined as f64;
                for (a, d) in div.iter().enumerate() {
                    m.ledger.signal_div[a] += d;
                }
            }
            let (bci, bwi, btou) = feed.view(self.ccfg.signal_policy);
            build_panels_with(
                &self.cfg,
                &state,
                bci,
                bwi,
                btou,
                &predicted,
                self.cfg.physics.pr_off,
            )
        };
        let analytic = AnalyticEvaluator::new(
            cp,
            dp,
            EvalConsts::from_physics(&self.cfg.physics),
        );
        let mut opt_cfg = self.cfg.opt.clone();
        opt_cfg.budget_s = self.ccfg.plan_budget_s;
        let mut optimizer = SlitOptimizer::new(
            opt_cfg,
            self.cfg.num_classes(),
            self.cfg.datacenters.len(),
            self.cfg.seed ^ (next_epoch as u64),
        );
        let seeds = analytic.greedy_seed_plans();
        // fleets past the artifact's DC_SLOTS padding plan analytic-only
        // (cmd_serve rejects the combination at startup; this guard keeps
        // a hand-built coordinator from panicking in panel padding —
        // announced once, on the first epoch tick, so the degrade is
        // observable)
        if self.engine.is_some()
            && self.cfg.validate_aot().is_err()
            && next_epoch <= 1
        {
            eprintln!(
                "coordinator: fleet exceeds AOT DC slots — engine ignored, \
                 planning on the analytic backend"
            );
        }
        let outcome = match &self.engine {
            Some(engine) if self.cfg.validate_aot().is_ok() => {
                let hlo =
                    HloPlanEvaluator::from_analytic(engine.clone(), &analytic);
                optimizer.optimize_with_seeds(&hlo, &seeds)
            }
            _ => optimizer.optimize_with_seeds(&analytic, &seeds),
        };
        let new_plan = match self.ccfg.variant {
            SlitVariant::Balance => outcome.archive.balanced().cloned(),
            v => {
                let idx = match v {
                    SlitVariant::Ttft => crate::config::OBJ_TTFT,
                    SlitVariant::Carbon => crate::config::OBJ_CARBON,
                    SlitVariant::Water => crate::config::OBJ_WATER,
                    SlitVariant::Cost => crate::config::OBJ_COST,
                    SlitVariant::Balance => unreachable!(),
                };
                outcome.archive.best_for(idx).cloned()
            }
        };
        if let Some(sol) = new_plan {
            *self.plan.write().expect("plan lock") = sol.plan;
            let mut m = self.metrics.lock().expect("metrics");
            m.plan_refreshes += 1;
        }

        // --- new epoch: reset per-epoch capacity from the live state ------
        for l in 0..self.cfg.datacenters.len() {
            let mut ls = self.locals[l].lock().expect("local");
            ls.new_epoch_with(&self.cfg, state.nodes(l));
        }
    }

    /// Spawn the epoch clock thread (compressed time).
    pub fn spawn_epoch_clock(self: &Arc<Self>) -> std::thread::JoinHandle<()> {
        let me = Arc::clone(self);
        std::thread::Builder::new()
            .name("slit-epoch-clock".into())
            .spawn(move || {
                while !me.stopped() {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        me.ccfg.epoch_wall_s,
                    ));
                    if me.stopped() {
                        break;
                    }
                    me.tick_epoch();
                }
            })
            .expect("spawn epoch clock")
    }

    pub fn metrics_snapshot(&self) -> Metrics {
        self.metrics.lock().expect("metrics").clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coordinator() -> Arc<Coordinator> {
        let mut cfg = SystemConfig::small_test();
        cfg.opt.generations = 2;
        cfg.opt.population = 8;
        Coordinator::new(cfg, CoordinatorConfig::default(), None)
    }

    #[test]
    fn handles_requests_and_accounts() {
        let c = coordinator();
        let mut served = 0;
        for i in 0..200 {
            if c.handle(i % 4, i % 2, 128, 200).is_some() {
                served += 1;
            }
        }
        let m = c.metrics_snapshot();
        assert_eq!(m.served, served);
        assert!(m.ttft.count() == served as u64);
        assert!(m.ttft.mean() > 0.0);
        // the histogram sees the same stream as the Welford mean
        assert_eq!(m.ttft_hist.count(), served as u64);
        assert!((m.ttft_hist.mean() - m.ttft.mean()).abs() < 1e-12);
        assert!(m.ttft_hist.p50() <= m.ttft_hist.p99());
        // every exercised class has its own histogram, and they partition
        // the overall count
        let class_total: u64 =
            m.class_ttft.iter().map(|h| h.count()).sum();
        assert_eq!(class_total, served as u64);
        assert!(m.class_ttft.iter().filter(|h| h.count() > 0).count() > 1);
    }

    #[test]
    fn racing_ticks_account_energy_exactly_once() {
        // identical coordinators, identical load; `a` ticks twice
        // sequentially, `b`'s two ticks race from two threads. Accounting
        // is deterministic given the same served load (energy depends only
        // on epoch-0 on-times and live nodes; epoch-1 is idle), so the
        // ledgers must agree exactly. Before ticks were serialized, the
        // interleaving read the same on-times twice and double-counted.
        let a = coordinator();
        let b = coordinator();
        for i in 0..50 {
            a.handle(i % 4, 0, 64, 100);
            b.handle(i % 4, 0, 64, 100);
        }
        a.tick_epoch();
        a.tick_epoch();
        let t1 = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || b.tick_epoch())
        };
        let t2 = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || b.tick_epoch())
        };
        t1.join().unwrap();
        t2.join().unwrap();
        assert_eq!(b.current_epoch(), 2);
        let (ma, mb) = (a.metrics_snapshot(), b.metrics_snapshot());
        assert_eq!(
            ma.ledger.e_it_j, mb.ledger.e_it_j,
            "racing ticks double-counted IT energy"
        );
        assert_eq!(ma.ledger.e_tot_j, mb.ledger.e_tot_j);
        assert_eq!(ma.ledger.carbon_kg, mb.ledger.carbon_kg);
        assert_eq!(ma.ledger.water_l, mb.ledger.water_l);
    }

    #[test]
    fn epoch_tick_replans_and_accounts_energy() {
        let c = coordinator();
        for i in 0..50 {
            c.handle(i % 4, 0, 64, 100);
        }
        c.tick_epoch();
        let m = c.metrics_snapshot();
        assert!(m.ledger.carbon_kg > 0.0);
        assert!(m.ledger.e_tot_j > 0.0);
        assert_eq!(m.plan_refreshes, 1);
        assert_eq!(c.current_epoch(), 1);
        // plan is valid and differs from pure uniform in general
        assert!(c.current_plan().is_valid());
    }

    #[test]
    fn variant_controls_deployed_plan() {
        let mut cfg = SystemConfig::small_test();
        cfg.opt.generations = 2;
        cfg.opt.population = 8;
        let ccfg = CoordinatorConfig {
            variant: SlitVariant::Carbon,
            ..Default::default()
        };
        let c = Coordinator::new(cfg, ccfg, None);
        for i in 0..50 {
            c.handle(i % 4, 0, 64, 100);
        }
        c.tick_epoch();
        assert!(c.current_plan().is_valid());
    }

    #[test]
    fn signal_fault_degrades_feed_and_planning_continues() {
        let c = coordinator();
        // before any fault: snapshot exists and every believed value is a
        // positive finite number
        let (faults, rows) = c.signal_snapshot();
        assert_eq!(faults, 0);
        assert_eq!(rows.len(), c.cfg.datacenters.len());
        c.apply_cluster_action(&ClusterAction::Signal(
            crate::signals::SignalFault::RegionBlackout {
                region: 1,
                epochs: 8,
            },
        ));
        for i in 0..40 {
            c.handle(i % 4, 0, 64, 100);
        }
        c.tick_epoch();
        let (faults, rows) = c.signal_snapshot();
        assert_eq!(faults, 1);
        for row in &rows {
            assert!(
                row.ci.is_finite() && row.ci > 0.0,
                "{}: believed CI {}",
                row.name,
                row.ci
            );
            if row.region == 1 {
                assert_ne!(row.state, "fresh", "{} should be dark", row.name);
                assert_ne!(row.source, "live");
            } else {
                assert_eq!(row.state, "fresh", "{}", row.name);
                assert_eq!(row.source, "live");
            }
        }
        // planning survived the blackout and the health counters landed in
        // the ledger (3 dark sites, 9 fresh, nothing quarantined)
        assert!(c.current_plan().is_valid());
        let m = c.metrics_snapshot();
        assert_eq!(m.ledger.signal_fresh, 9.0);
        assert_eq!(m.ledger.signal_stale, 3.0);
        assert_eq!(m.ledger.signal_quarantined, 0.0);
    }

    #[test]
    fn no_faults_leave_no_signal_divergence() {
        let c = coordinator();
        for i in 0..40 {
            c.handle(i % 4, 0, 64, 100);
        }
        c.tick_epoch();
        c.tick_epoch();
        let m = c.metrics_snapshot();
        assert_eq!(m.ledger.signal_div, [0.0; 3]);
        assert_eq!(m.ledger.signal_quarantined, 0.0);
        assert_eq!(m.ledger.signal_stale, 0.0);
        assert_eq!(
            m.ledger.signal_fresh,
            (2 * c.cfg.datacenters.len()) as f64
        );
    }

    #[test]
    fn stop_flag() {
        let c = coordinator();
        assert!(!c.stopped());
        c.stop();
        assert!(c.stopped());
    }

    #[test]
    fn cluster_action_takes_effect_at_next_tick() {
        let c = coordinator();
        let full: usize = (0..c.cfg.datacenters.len())
            .map(|l| c.cluster_snapshot().total_nodes(l))
            .sum();
        // darken north-america, tick: plan + capacity now derive from the
        // degraded topology
        c.apply_cluster_action(&ClusterAction::ScaleRegion {
            region: 2,
            frac: 0.0,
        });
        c.tick_epoch();
        let snap = c.cluster_snapshot();
        let after: usize =
            (0..c.cfg.datacenters.len()).map(|l| snap.total_nodes(l)).sum();
        assert!(after < full);
        assert!(c.current_plan().is_valid());
        // dark sites accept nothing, yet requests still get served via the
        // saturation failover to healthy regions
        let mut served = 0;
        for i in 0..80 {
            if c.handle(2, i % 2, 64, 100).is_some() {
                served += 1;
            }
        }
        assert!(served > 0, "no failover to healthy regions");
        for (l, d) in c.cfg.datacenters.iter().enumerate() {
            if d.region == 2 {
                let ls = c.locals[l].lock().expect("local");
                assert_eq!(
                    ls.capacity.used_s.iter().sum::<f64>(),
                    0.0,
                    "dark site {} took load",
                    d.name
                );
            }
        }
        // restore + tick: the fleet is whole again
        c.apply_cluster_action(&ClusterAction::RestoreRegion { region: 2 });
        c.tick_epoch();
        let snap = c.cluster_snapshot();
        let restored: usize =
            (0..c.cfg.datacenters.len()).map(|l| snap.total_nodes(l)).sum();
        assert_eq!(restored, full);
    }
}

#[cfg(test)]
mod batch_tests {
    use super::*;

    fn coordinator() -> Arc<Coordinator> {
        let mut cfg = SystemConfig::small_test();
        cfg.opt.generations = 2;
        cfg.opt.population = 8;
        Coordinator::new(cfg, CoordinatorConfig::default(), None)
    }

    #[test]
    fn batch_path_serves_everything_in_order() {
        let c = coordinator();
        let reqs: Vec<(usize, usize, u32, u32)> = (0..100)
            .map(|i| (i % 4, i % 2, 64, 128))
            .collect();
        let out = c.handle_batch(&reqs);
        assert_eq!(out.len(), 100);
        assert!(out.iter().all(Option::is_some));
        let m = c.metrics_snapshot();
        assert_eq!(m.served, 100);
        assert!(m.batches > 0);
        assert!(m.batch_sizes.mean() >= 1.0);
        assert_eq!(m.ttft.count(), 100);
    }

    #[test]
    fn batch_path_fails_over_from_dark_sites() {
        let c = coordinator();
        c.apply_cluster_action(&ClusterAction::ScaleRegion {
            region: 2,
            frac: 0.0,
        });
        c.tick_epoch();
        // all traffic originates in the darkened region: whatever the
        // re-plan left on dark sites must spill hop-aware to healthy ones
        let reqs: Vec<(usize, usize, u32, u32)> =
            (0..60).map(|i| (2, i % 2, 64, 128)).collect();
        let out = c.handle_batch(&reqs);
        assert_eq!(
            out.iter().flatten().count(),
            60,
            "batch failover left requests unserved with healthy capacity"
        );
        for r in out.iter().flatten() {
            assert_ne!(
                c.cfg.datacenters[r.0].region,
                2,
                "dark site served batch load"
            );
        }
        for (l, d) in c.cfg.datacenters.iter().enumerate() {
            if d.region == 2 {
                let ls = c.locals[l].lock().expect("local");
                assert_eq!(
                    ls.capacity.used_s.iter().sum::<f64>(),
                    0.0,
                    "dark site {} took load",
                    d.name
                );
            }
        }
        let m = c.metrics_snapshot();
        assert_eq!(m.served, 60);
        assert_eq!(m.rejected, 0);
    }

    #[test]
    fn batch_and_single_paths_agree_on_accounting() {
        let c1 = coordinator();
        let c2 = coordinator();
        let reqs: Vec<(usize, usize, u32, u32)> =
            (0..60).map(|i| (i % 4, 0, 64, 128)).collect();
        let _ = c1.handle_batch(&reqs);
        for &(r, m, ti, to) in &reqs {
            c2.handle(r, m, ti, to);
        }
        let m1 = c1.metrics_snapshot();
        let m2 = c2.metrics_snapshot();
        assert_eq!(m1.served, m2.served);
        // both policies route by the same (uniform-initialised) plan; mean
        // TTFTs should be in the same ballpark
        let ratio = m1.ttft.mean() / m2.ttft.mean();
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn batch_age_cap_is_inert_in_handle_batch() {
        // handle_batch builds a fresh batcher per call, so max_wait can
        // never expire on this path — flush_all is what drains the tail.
        // Pin that: an absurd age cap must neither strand nor stall
        // requests.
        let mut cfg = SystemConfig::small_test();
        cfg.opt.generations = 2;
        cfg.opt.population = 8;
        let ccfg = CoordinatorConfig {
            batcher: BatcherConfig {
                max_wait: std::time::Duration::from_secs(3600),
                ..Default::default()
            },
            ..Default::default()
        };
        let c = Coordinator::new(cfg, ccfg, None);
        let reqs: Vec<(usize, usize, u32, u32)> =
            (0..40).map(|i| (i % 4, i % 2, 64, 128)).collect();
        let t0 = std::time::Instant::now();
        let out = c.handle_batch(&reqs);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(60),
            "handle_batch waited on the age cap"
        );
        assert_eq!(out.iter().flatten().count(), 40);
    }

    #[test]
    fn fcfs_ablation_serves_the_same_mass_as_llf() {
        let mk = |policy: DispatchPolicy| {
            let mut cfg = SystemConfig::small_test();
            cfg.opt.generations = 2;
            cfg.opt.population = 8;
            let ccfg = CoordinatorConfig {
                batcher: BatcherConfig {
                    policy,
                    ..Default::default()
                },
                ..Default::default()
            };
            Coordinator::new(cfg, ccfg, None)
        };
        let reqs: Vec<(usize, usize, u32, u32)> =
            (0..120).map(|i| (i % 4, i % 2, 64, 128)).collect();
        let llf = mk(DispatchPolicy::Llf);
        let fcfs = mk(DispatchPolicy::Fcfs);
        let out_llf = llf.handle_batch(&reqs);
        let out_fcfs = fcfs.handle_batch(&reqs);
        // dispatch order changes who pays queue delay, never who is served
        assert_eq!(
            out_llf.iter().flatten().count(),
            out_fcfs.iter().flatten().count()
        );
        let (m1, m2) = (llf.metrics_snapshot(), fcfs.metrics_snapshot());
        assert_eq!(m1.served, m2.served);
        assert_eq!(m1.ttft_hist.count(), m2.ttft_hist.count());
    }
}
