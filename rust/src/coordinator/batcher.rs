//! Dynamic batcher: groups requests per (site, model) before placement,
//! then orders the flushed groups for dispatch.
//!
//! Continuous batching at the node level is modelled inside the node
//! throughput numbers; this batcher captures the *router-side* batching
//! (one placement critical-section per group instead of per request),
//! which is what keeps the coordinator's lock contention flat at high
//! request rates. Flush policy: size cap or age cap, whichever first.
//!
//! Dispatch policy (FREESH-style): by default groups are released in
//! **Least-Laxity-First** order, laxity = TTFT-SLO budget minus queued
//! age minus predicted first-token service (`sched::predicted_first_token_s`).
//! Tight-deadline small-model groups therefore commit site capacity before
//! loose large-model groups and see lower utilisation (lower queue delay)
//! — the head-of-line blocking FCFS suffers in the TTFT tail. Laxity
//! shrinks linearly with age, so a loose-deadline group that has waited
//! long enough always overtakes fresh tight ones: no starvation. Ties
//! break on arrival order (first push sequence), keeping dispatch fully
//! deterministic. FCFS remains available as the ablation baseline.
//!
//! Within one group every request shares (site, model) — identical SLO
//! and predicted service — so LLF inside the group degenerates to
//! oldest-first, which is exactly the arrival order items are stored in.

use std::time::{Duration, Instant};

use crate::config::SystemConfig;
use crate::sched::predicted_first_token_s;
use crate::trace::Request;

/// Order in which flushed groups are released to placement.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// First-come-first-served on the group's first arrival.
    Fcfs,
    /// Least-Laxity-First (FREESH): most urgent group first.
    #[default]
    Llf,
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max requests per batch.
    pub max_batch: usize,
    /// Max time a request may wait in the batcher.
    pub max_wait: Duration,
    /// Group dispatch order.
    pub policy: DispatchPolicy,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(10),
            policy: DispatchPolicy::Llf,
        }
    }
}

/// Precomputed laxity inputs: per-model SLO and per-(site, model)
/// predicted first-token service, so scoring a group at flush time is two
/// lookups and a subtraction.
#[derive(Clone, Debug)]
pub struct LaxityModel {
    /// Predicted first-token service seconds, indexed `dc * models + model`.
    svc_s: Vec<f64>,
    /// TTFT SLO seconds per model.
    slo_s: Vec<f64>,
    models: usize,
}

impl LaxityModel {
    pub fn from_config(cfg: &SystemConfig) -> LaxityModel {
        let models = cfg.models.len();
        let dcs = cfg.datacenters.len();
        let mut svc_s = Vec::with_capacity(dcs * models);
        for dc in 0..dcs {
            for model in 0..models {
                svc_s.push(predicted_first_token_s(cfg, dc, model));
            }
        }
        LaxityModel {
            svc_s,
            slo_s: cfg.models.iter().map(|m| m.ttft_slo_s).collect(),
            models,
        }
    }

    /// Hand-built model for tests / synthetic scheduling studies.
    pub fn from_parts(
        svc_s: Vec<f64>,
        slo_s: Vec<f64>,
        models: usize,
    ) -> LaxityModel {
        assert_eq!(svc_s.len() % models, 0);
        assert_eq!(slo_s.len(), models);
        LaxityModel { svc_s, slo_s, models }
    }

    pub fn dcs(&self) -> usize {
        self.svc_s.len() / self.models
    }

    pub fn models(&self) -> usize {
        self.models
    }

    /// Laxity of a request aged `age_s` bound for (dc, model): how much
    /// deadline slack remains after the predicted service. Negative means
    /// already past budget — maximally urgent.
    pub fn laxity_s(&self, dc: usize, model: usize, age_s: f64) -> f64 {
        self.slo_s[model] - age_s - self.svc_s[dc * self.models + model]
    }
}

/// One request inside a flushed batch, tagged with the caller's index so
/// results map back to submission order no matter how dispatch reorders
/// groups (the old same-key cursor scan this replaces could misattribute
/// TTFTs once groups stopped flushing in arrival order).
#[derive(Clone, Copy, Debug)]
pub struct BatchItem {
    pub req: Request,
    /// Caller-supplied index into its own result array.
    pub tag: usize,
    /// Global arrival sequence (deterministic tie-break).
    pub seq: u64,
}

/// A flushed batch destined for one (site, model) pair. Items are in
/// arrival order (= LLF order within the group; see module docs).
#[derive(Clone, Debug)]
pub struct Batch {
    pub dc: usize,
    pub model: usize,
    pub items: Vec<BatchItem>,
    /// Arrival sequence of the group's oldest item.
    pub first_seq: u64,
    /// Laxity of the group's most urgent (oldest) item at flush time.
    pub min_laxity_s: f64,
}

/// Order flushed groups for dispatch in place: LLF sorts by
/// (min laxity, first arrival), FCFS by first arrival alone. Both orders
/// are total and deterministic for distinct arrival sequences.
pub fn dispatch_order(groups: &mut [Batch], policy: DispatchPolicy) {
    match policy {
        DispatchPolicy::Fcfs => {
            groups.sort_by_key(|g| g.first_seq);
        }
        DispatchPolicy::Llf => {
            groups.sort_by(|a, b| {
                a.min_laxity_s
                    .partial_cmp(&b.min_laxity_s)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.first_seq.cmp(&b.first_seq))
            });
        }
    }
}

/// Accumulates requests per (site, model); `push` returns a batch when the
/// flush condition triggers.
pub struct Batcher {
    cfg: BatcherConfig,
    laxity: LaxityModel,
    /// (items, oldest-arrival) per (dc, model) key
    pending: Vec<(Vec<BatchItem>, Option<Instant>)>,
    models: usize,
    next_seq: u64,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig, laxity: LaxityModel) -> Batcher {
        let slots = laxity.dcs() * laxity.models();
        let models = laxity.models();
        Batcher {
            cfg,
            laxity,
            pending: (0..slots).map(|_| (Vec::new(), None)).collect(),
            models,
            next_seq: 0,
        }
    }

    pub fn policy(&self) -> DispatchPolicy {
        self.cfg.policy
    }

    fn key(&self, dc: usize, model: usize) -> usize {
        dc * self.models + model
    }

    /// Add a routed request carrying the caller's result index; returns a
    /// full batch if the size cap tripped.
    pub fn push(
        &mut self,
        dc: usize,
        req: Request,
        tag: usize,
    ) -> Option<Batch> {
        let model = req.model();
        let k = self.key(dc, model);
        let seq = self.next_seq;
        self.next_seq += 1;
        let slot = &mut self.pending[k];
        if slot.1.is_none() {
            slot.1 = Some(Instant::now());
        }
        slot.0.push(BatchItem { req, tag, seq });
        if slot.0.len() >= self.cfg.max_batch {
            return self.take(dc, model);
        }
        None
    }

    /// Collect every batch whose age exceeded the wait cap.
    pub fn flush_expired(&mut self) -> Vec<Batch> {
        let now = Instant::now();
        let mut out = Vec::new();
        for k in 0..self.pending.len() {
            let expired = matches!(
                self.pending[k].1,
                Some(t0) if now.duration_since(t0) >= self.cfg.max_wait
            );
            if expired && !self.pending[k].0.is_empty() {
                let dc = k / self.models;
                let model = k % self.models;
                if let Some(b) = self.take(dc, model) {
                    out.push(b);
                }
            }
        }
        out
    }

    /// Drain everything (shutdown / end-of-batch path).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        for k in 0..self.pending.len() {
            if !self.pending[k].0.is_empty() {
                let dc = k / self.models;
                let model = k % self.models;
                if let Some(b) = self.take(dc, model) {
                    out.push(b);
                }
            }
        }
        out
    }

    fn take(&mut self, dc: usize, model: usize) -> Option<Batch> {
        let k = self.key(dc, model);
        let age_s = self.pending[k]
            .1
            .map(|t0| t0.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        let slot = &mut self.pending[k];
        if slot.0.is_empty() {
            return None;
        }
        slot.1 = None;
        let items = std::mem::take(&mut slot.0);
        let first_seq = items[0].seq;
        Some(Batch {
            dc,
            model,
            // the oldest item's age is the group age: its laxity is the
            // group minimum (same SLO/service across the group)
            min_laxity_s: self.laxity.laxity_s(dc, model, age_s),
            first_seq,
            items,
        })
    }

    pub fn pending_count(&self) -> usize {
        self.pending.iter().map(|(v, _)| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(class: usize) -> Request {
        Request {
            arrival_s: 0.0,
            class,
            tok_in: 10,
            tok_out: 20,
        }
    }

    /// 2-model laxity model over `dcs` sites: tiny uniform service, SLOs
    /// 1 s (model 0) and 4 s (model 1).
    fn toy_laxity(dcs: usize) -> LaxityModel {
        LaxityModel::from_parts(
            vec![0.05; dcs * 2],
            vec![1.0, 4.0],
            2,
        )
    }

    fn batcher(max_batch: usize, max_wait: Duration, dcs: usize) -> Batcher {
        Batcher::new(
            BatcherConfig {
                max_batch,
                max_wait,
                policy: DispatchPolicy::Llf,
            },
            toy_laxity(dcs),
        )
    }

    #[test]
    fn size_cap_flushes() {
        let mut b = batcher(3, Duration::from_secs(60), 2);
        assert!(b.push(0, req(0), 0).is_none());
        assert!(b.push(0, req(0), 1).is_none());
        let batch = b.push(0, req(0), 2).expect("size cap");
        assert_eq!(batch.items.len(), 3);
        assert_eq!(batch.dc, 0);
        assert_eq!(batch.model, 0);
        assert_eq!(b.pending_count(), 0);
    }

    #[test]
    fn batches_keyed_by_site_and_model() {
        let mut b = batcher(2, Duration::from_secs(60), 2);
        assert!(b.push(0, req(0), 0).is_none()); // model 0
        assert!(b.push(0, req(1), 1).is_none()); // model 1 -> other key
        assert!(b.push(1, req(0), 2).is_none()); // other site
        let batch = b.push(0, req(2), 3).expect("model-0 site-0 cap");
        assert_eq!(batch.items.len(), 2);
        assert_eq!(b.pending_count(), 2);
    }

    #[test]
    fn age_cap_flushes() {
        let mut b = batcher(100, Duration::from_millis(1), 1);
        b.push(0, req(0), 0);
        std::thread::sleep(Duration::from_millis(3));
        let out = b.flush_expired();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].items.len(), 1);
    }

    #[test]
    fn flush_all_drains() {
        let mut b =
            batcher(BatcherConfig::default().max_batch, Duration::from_millis(10), 3);
        b.push(0, req(0), 0);
        b.push(1, req(1), 1);
        b.push(2, req(0), 2);
        let out = b.flush_all();
        assert_eq!(out.len(), 3);
        assert_eq!(b.pending_count(), 0);
    }

    #[test]
    fn flush_expired_skips_young_groups() {
        let mut b = batcher(100, Duration::from_secs(60), 2);
        b.push(0, req(0), 0);
        b.push(1, req(1), 1);
        // nothing is older than the wait cap yet
        assert!(b.flush_expired().is_empty());
        assert_eq!(b.pending_count(), 2);
    }

    #[test]
    fn age_timer_resets_after_a_flush() {
        let mut b = batcher(2, Duration::from_millis(50), 1);
        b.push(0, req(0), 0);
        let batch = b.push(0, req(0), 1).expect("size cap");
        assert_eq!(batch.items.len(), 2);
        // a fresh push after the flush starts a new age window: the old
        // timestamp must not leak into the new group
        b.push(0, req(0), 2);
        assert!(b.flush_expired().is_empty(), "stale age timer leaked");
        assert_eq!(b.pending_count(), 1);
    }

    #[test]
    fn size_cap_of_one_flushes_every_push() {
        let mut b = batcher(1, Duration::from_secs(60), 2);
        for i in 0..6 {
            let batch =
                b.push(i % 2, req(i % 2), i).expect("immediate flush");
            assert_eq!(batch.items.len(), 1);
            assert_eq!(batch.items[0].tag, i);
        }
        assert_eq!(b.pending_count(), 0);
    }

    #[test]
    fn flushed_batches_carry_their_site_and_model_key() {
        let mut b =
            batcher(BatcherConfig::default().max_batch, Duration::from_millis(10), 3);
        b.push(2, req(1), 0); // class 1 -> model 1
        b.push(1, req(2), 1); // class 2 -> model 0
        let mut out = b.flush_all();
        out.sort_by_key(|g| (g.dc, g.model));
        assert_eq!(out.len(), 2);
        assert_eq!((out[0].dc, out[0].model), (1, 0));
        assert_eq!((out[1].dc, out[1].model), (2, 1));
        for g in &out {
            for item in &g.items {
                assert_eq!(
                    item.req.model(),
                    g.model,
                    "request in wrong group"
                );
            }
        }
    }

    #[test]
    fn tags_survive_flush_in_arrival_order() {
        let mut b = batcher(100, Duration::from_secs(60), 1);
        for tag in [7usize, 3, 11, 5] {
            b.push(0, req(0), tag);
        }
        let out = b.flush_all();
        assert_eq!(out.len(), 1);
        let tags: Vec<usize> =
            out[0].items.iter().map(|it| it.tag).collect();
        assert_eq!(tags, vec![7, 3, 11, 5], "arrival order scrambled");
        // seq is strictly increasing in arrival order
        let seqs: Vec<u64> = out[0].items.iter().map(|it| it.seq).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]));
    }

    // ------------------------------------------------------------------
    // LLF ordering invariants
    // ------------------------------------------------------------------

    #[test]
    fn llf_releases_tight_slo_groups_before_loose_ones() {
        // same site, both models, same (fresh) age: the 1 s SLO group must
        // dispatch before the 4 s SLO group; FCFS keeps arrival order
        let mk_groups = |b: &mut Batcher| -> Vec<Batch> {
            b.push(0, req(1), 0); // model 1 (loose) arrives FIRST
            b.push(0, req(0), 1); // model 0 (tight) second
            b.flush_all()
        };
        let mut b = batcher(100, Duration::from_secs(60), 1);
        let mut groups = mk_groups(&mut b);
        dispatch_order(&mut groups, DispatchPolicy::Llf);
        assert_eq!(
            (groups[0].model, groups[1].model),
            (0, 1),
            "LLF must release the tight-SLO group first"
        );
        let mut b = batcher(100, Duration::from_secs(60), 1);
        let mut groups = mk_groups(&mut b);
        dispatch_order(&mut groups, DispatchPolicy::Fcfs);
        assert_eq!(
            (groups[0].model, groups[1].model),
            (1, 0),
            "FCFS must keep arrival order"
        );
    }

    #[test]
    fn laxity_ties_break_deterministically_on_arrival() {
        // two same-model groups on different sites with identical service
        // predictions: laxities tie exactly, arrival sequence decides
        let lax = toy_laxity(2);
        let mk = |dc: usize, first_seq: u64| Batch {
            dc,
            model: 0,
            items: vec![],
            first_seq,
            min_laxity_s: lax.laxity_s(dc, 0, 0.0),
        };
        assert_eq!(
            lax.laxity_s(0, 0, 0.0),
            lax.laxity_s(1, 0, 0.0),
            "test premise: exact laxity tie"
        );
        for _ in 0..3 {
            let mut groups = vec![mk(1, 5), mk(0, 2)];
            dispatch_order(&mut groups, DispatchPolicy::Llf);
            assert_eq!(
                (groups[0].dc, groups[1].dc),
                (0, 1),
                "tie must break on first arrival, deterministically"
            );
        }
    }

    #[test]
    fn aged_loose_groups_overtake_fresh_tight_ones() {
        // no starvation: laxity falls linearly with age, so a loose-SLO
        // group that has queued past (slo_loose - slo_tight) outranks a
        // fresh tight-SLO group
        let lax = toy_laxity(1);
        let fresh_tight = lax.laxity_s(0, 0, 0.0); // 1.0 - 0 - 0.05
        let aged_loose = lax.laxity_s(0, 1, 3.2); // 4.0 - 3.2 - 0.05
        assert!(
            aged_loose < fresh_tight,
            "aged loose group must become the more urgent one \
             ({aged_loose} vs {fresh_tight})"
        );
        let mut groups = vec![
            Batch {
                dc: 0,
                model: 0,
                items: vec![],
                first_seq: 10,
                min_laxity_s: fresh_tight,
            },
            Batch {
                dc: 0,
                model: 1,
                items: vec![],
                first_seq: 0,
                min_laxity_s: aged_loose,
            },
        ];
        dispatch_order(&mut groups, DispatchPolicy::Llf);
        assert_eq!(groups[0].model, 1, "starved loose group not promoted");
    }

    #[test]
    fn laxity_model_from_config_matches_sched_predictions() {
        let cfg = crate::config::SystemConfig::small_test();
        let lax = LaxityModel::from_config(&cfg);
        assert_eq!(lax.dcs(), cfg.datacenters.len());
        assert_eq!(lax.models(), cfg.models.len());
        for dc in 0..lax.dcs() {
            for model in 0..lax.models() {
                let want = cfg.models[model].ttft_slo_s
                    - crate::sched::predicted_first_token_s(&cfg, dc, model);
                assert_eq!(lax.laxity_s(dc, model, 0.0), want);
                // laxity is strictly decreasing in age
                assert!(
                    lax.laxity_s(dc, model, 1.0)
                        < lax.laxity_s(dc, model, 0.0)
                );
            }
        }
    }
}
