//! Dynamic batcher: groups requests per (site, model) before placement.
//!
//! Continuous batching at the node level is modelled inside the node
//! throughput numbers; this batcher captures the *router-side* batching
//! (one placement critical-section per group instead of per request),
//! which is what keeps the coordinator's lock contention flat at high
//! request rates. Flush policy: size cap or age cap, whichever first.

use std::time::{Duration, Instant};

use crate::trace::Request;

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max requests per batch.
    pub max_batch: usize,
    /// Max time a request may wait in the batcher.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(10),
        }
    }
}

/// A flushed batch destined for one (site, model) pair.
#[derive(Clone, Debug)]
pub struct Batch {
    pub dc: usize,
    pub model: usize,
    pub requests: Vec<Request>,
}

/// Accumulates requests per (site, model); `push` returns a batch when the
/// flush condition triggers.
pub struct Batcher {
    cfg: BatcherConfig,
    /// (requests, oldest-arrival) per (dc, model) key
    pending: Vec<(Vec<Request>, Option<Instant>)>,
    models: usize,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig, dcs: usize, models: usize) -> Batcher {
        Batcher {
            cfg,
            pending: (0..dcs * models).map(|_| (Vec::new(), None)).collect(),
            models,
        }
    }

    fn key(&self, dc: usize, model: usize) -> usize {
        dc * self.models + model
    }

    /// Add a routed request; returns a full batch if the size cap tripped.
    pub fn push(&mut self, dc: usize, req: Request) -> Option<Batch> {
        let model = req.model();
        let k = self.key(dc, model);
        let slot = &mut self.pending[k];
        if slot.1.is_none() {
            slot.1 = Some(Instant::now());
        }
        slot.0.push(req);
        if slot.0.len() >= self.cfg.max_batch {
            return self.take(dc, model);
        }
        None
    }

    /// Collect every batch whose age exceeded the wait cap.
    pub fn flush_expired(&mut self) -> Vec<Batch> {
        let now = Instant::now();
        let mut out = Vec::new();
        for k in 0..self.pending.len() {
            let expired = matches!(
                self.pending[k].1,
                Some(t0) if now.duration_since(t0) >= self.cfg.max_wait
            );
            if expired && !self.pending[k].0.is_empty() {
                let dc = k / self.models;
                let model = k % self.models;
                if let Some(b) = self.take(dc, model) {
                    out.push(b);
                }
            }
        }
        out
    }

    /// Drain everything (shutdown path).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        for k in 0..self.pending.len() {
            if !self.pending[k].0.is_empty() {
                let dc = k / self.models;
                let model = k % self.models;
                if let Some(b) = self.take(dc, model) {
                    out.push(b);
                }
            }
        }
        out
    }

    fn take(&mut self, dc: usize, model: usize) -> Option<Batch> {
        let k = self.key(dc, model);
        let slot = &mut self.pending[k];
        if slot.0.is_empty() {
            return None;
        }
        slot.1 = None;
        Some(Batch {
            dc,
            model,
            requests: std::mem::take(&mut slot.0),
        })
    }

    pub fn pending_count(&self) -> usize {
        self.pending.iter().map(|(v, _)| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(class: usize) -> Request {
        Request {
            arrival_s: 0.0,
            class,
            tok_in: 10,
            tok_out: 20,
        }
    }

    #[test]
    fn size_cap_flushes() {
        let mut b = Batcher::new(
            BatcherConfig {
                max_batch: 3,
                max_wait: Duration::from_secs(60),
            },
            2,
            2,
        );
        assert!(b.push(0, req(0)).is_none());
        assert!(b.push(0, req(0)).is_none());
        let batch = b.push(0, req(0)).expect("size cap");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.dc, 0);
        assert_eq!(batch.model, 0);
        assert_eq!(b.pending_count(), 0);
    }

    #[test]
    fn batches_keyed_by_site_and_model() {
        let mut b = Batcher::new(
            BatcherConfig {
                max_batch: 2,
                max_wait: Duration::from_secs(60),
            },
            2,
            2,
        );
        assert!(b.push(0, req(0)).is_none()); // model 0
        assert!(b.push(0, req(1)).is_none()); // model 1 -> other key
        assert!(b.push(1, req(0)).is_none()); // other site
        let batch = b.push(0, req(2)).expect("model-0 site-0 cap");
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.pending_count(), 2);
    }

    #[test]
    fn age_cap_flushes() {
        let mut b = Batcher::new(
            BatcherConfig {
                max_batch: 100,
                max_wait: Duration::from_millis(1),
            },
            1,
            2,
        );
        b.push(0, req(0));
        std::thread::sleep(Duration::from_millis(3));
        let out = b.flush_expired();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].requests.len(), 1);
    }

    #[test]
    fn flush_all_drains() {
        let mut b = Batcher::new(BatcherConfig::default(), 3, 2);
        b.push(0, req(0));
        b.push(1, req(1));
        b.push(2, req(0));
        let out = b.flush_all();
        assert_eq!(out.len(), 3);
        assert_eq!(b.pending_count(), 0);
    }

    #[test]
    fn flush_expired_skips_young_groups() {
        let mut b = Batcher::new(
            BatcherConfig {
                max_batch: 100,
                max_wait: Duration::from_secs(60),
            },
            2,
            2,
        );
        b.push(0, req(0));
        b.push(1, req(1));
        // nothing is older than the wait cap yet
        assert!(b.flush_expired().is_empty());
        assert_eq!(b.pending_count(), 2);
    }

    #[test]
    fn age_timer_resets_after_a_flush() {
        let mut b = Batcher::new(
            BatcherConfig {
                max_batch: 2,
                max_wait: Duration::from_millis(50),
            },
            1,
            1,
        );
        b.push(0, req(0));
        let batch = b.push(0, req(0)).expect("size cap");
        assert_eq!(batch.requests.len(), 2);
        // a fresh push after the flush starts a new age window: the old
        // timestamp must not leak into the new group
        b.push(0, req(0));
        assert!(b.flush_expired().is_empty(), "stale age timer leaked");
        assert_eq!(b.pending_count(), 1);
    }

    #[test]
    fn size_cap_of_one_flushes_every_push() {
        let mut b = Batcher::new(
            BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_secs(60),
            },
            2,
            2,
        );
        for i in 0..6 {
            let batch = b.push(i % 2, req(i % 2)).expect("immediate flush");
            assert_eq!(batch.requests.len(), 1);
        }
        assert_eq!(b.pending_count(), 0);
    }

    #[test]
    fn flushed_batches_carry_their_site_and_model_key() {
        let mut b = Batcher::new(BatcherConfig::default(), 3, 2);
        b.push(2, req(1)); // class 1 -> model 1
        b.push(1, req(2)); // class 2 -> model 0
        let mut out = b.flush_all();
        out.sort_by_key(|g| (g.dc, g.model));
        assert_eq!(out.len(), 2);
        assert_eq!((out[0].dc, out[0].model), (1, 0));
        assert_eq!((out[1].dc, out[1].model), (2, 1));
        for g in &out {
            for r in &g.requests {
                assert_eq!(r.model(), g.model, "request in wrong group");
            }
        }
    }
}
