//! `slit loadgen`: drive a live coordinator's TCP front with synthetic
//! traffic and report achieved throughput + latency percentiles.
//!
//! Two arrival disciplines, both over real sockets:
//!
//! - **Closed loop** (`--mode closed`): `conns` connections, each sending
//!   its next payload only after the previous reply lands. Measures the
//!   server's sustainable round-trip capacity; the offered load adapts to
//!   the server, so it never reveals queueing collapse.
//! - **Open loop** (`--mode open`): each connection pairs a writer thread
//!   pacing payloads on Poisson (exponential-interarrival) schedule at the
//!   requested aggregate rate with a reader thread draining replies.
//!   Offered load is independent of server speed — the honest way to
//!   measure tail latency at a target req/s. Whenever the writer falls
//!   behind its own schedule it sends immediately and counts `behind`
//!   (coordinated-omission signal, reported, never hidden).
//!
//! Request classes cycle deterministically over region x model, so the
//! client knows each in-flight request's class and can build *per-class*
//! TTFT histograms from the replies alone — which is what lets the bench
//! rows compare LLF vs FCFS on slack-normalized (TTFT / SLO) tails.
//!
//! Replies are JSON-lines and strictly ordered per connection, so RTT
//! pairing is a FIFO queue of send timestamps; a reply that never arrives
//! within the read timeout is counted `dropped_replies` (the acceptance
//! bar for the serve path is zero).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::config::{MODELS, REGIONS};
use crate::util::histogram::LatencyHistogram;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Socket write deadline (each worker sets its own read timeout). A
/// wedged server turns into a structured error, never a hung CI job.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);
/// Connect retry schedule: transient refusals (a server still binding,
/// fd pressure) get a few capped-backoff attempts before a structured
/// error.
const CONNECT_ATTEMPTS: u32 = 5;
const CONNECT_BACKOFF: Duration = Duration::from_millis(50);

/// `TcpStream::connect` with capped retry-with-backoff: attempts spaced
/// 50/100/200/400 ms apart, then a structured error naming the target —
/// a loadgen pointed at a dead or still-starting server fails fast with
/// a report instead of hanging whatever drives it.
fn connect_with_retry(host: &str, port: u16) -> anyhow::Result<TcpStream> {
    let mut backoff = CONNECT_BACKOFF;
    let mut last_err = String::new();
    for attempt in 0..CONNECT_ATTEMPTS {
        if attempt > 0 {
            std::thread::sleep(backoff);
            backoff *= 2;
        }
        match TcpStream::connect((host, port)) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                s.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
                return Ok(s);
            }
            Err(e) => last_err = e.to_string(),
        }
    }
    Err(anyhow::anyhow!(
        "loadgen could not connect to {host}:{port} after \
         {CONNECT_ATTEMPTS} attempts: {last_err}"
    ))
}

/// How requests are offered to the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalMode {
    /// Next payload waits for the previous reply (per connection).
    Closed,
    /// Payloads paced by a Poisson clock, independent of replies.
    Open,
}

#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    pub host: String,
    pub port: u16,
    pub mode: ArrivalMode,
    /// Concurrent connections.
    pub conns: usize,
    /// Total requests to send (closed loop).
    pub requests: usize,
    /// Aggregate offered rate, requests/s (open loop).
    pub rate_rps: f64,
    /// Sending window, seconds (open loop).
    pub duration_s: f64,
    /// Requests per line: 1 = plain single-request lines, >1 = `batch`
    /// ops (one reply line per payload either way).
    pub batch: usize,
    pub tok_in: u32,
    pub tok_out: u32,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            host: "127.0.0.1".into(),
            port: 7070,
            mode: ArrivalMode::Closed,
            conns: 8,
            requests: 2_000,
            rate_rps: 2_000.0,
            duration_s: 2.0,
            batch: 1,
            tok_in: 128,
            tok_out: 256,
            seed: 7,
        }
    }
}

/// Everything one run observed. Request accounting is exhaustive:
/// `ok + saturated + errors + dropped_replies == sent`.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    /// Requests written to sockets.
    pub sent: u64,
    /// Requests answered `ok: true`.
    pub ok: u64,
    /// Requests answered "all sites saturated".
    pub saturated: u64,
    /// Connections shed by bounded admission (`overloaded` reply).
    pub overloaded_conns: u64,
    /// Requests answered with any other structured error.
    pub errors: u64,
    /// Requests whose reply never arrived (timeout / early EOF).
    pub dropped_replies: u64,
    /// Open loop: payloads sent late because the writer fell behind its
    /// own Poisson schedule (coordinated-omission signal).
    pub behind: u64,
    /// Wall time from first payload to last reply, seconds.
    pub elapsed_s: f64,
    /// Client-side round-trip time per payload line.
    pub rtt: LatencyHistogram,
    /// Server-reported TTFT per served request.
    pub ttft: LatencyHistogram,
    /// Server-reported TTFT per request class.
    pub class_ttft: Vec<LatencyHistogram>,
}

impl LoadgenReport {
    pub fn achieved_rps(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            (self.ok + self.saturated) as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// Share of sent requests that did not come back `ok`.
    pub fn error_rate(&self) -> f64 {
        if self.sent == 0 {
            return 0.0;
        }
        (self.sent - self.ok) as f64 / self.sent as f64
    }

    fn merge(&mut self, other: &LoadgenReport) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.saturated += other.saturated;
        self.overloaded_conns += other.overloaded_conns;
        self.errors += other.errors;
        self.dropped_replies += other.dropped_replies;
        self.behind += other.behind;
        self.elapsed_s = self.elapsed_s.max(other.elapsed_s);
        self.rtt.merge(&other.rtt);
        self.ttft.merge(&other.ttft);
        if self.class_ttft.len() < other.class_ttft.len() {
            self.class_ttft
                .resize_with(other.class_ttft.len(), LatencyHistogram::new);
        }
        for (a, b) in self.class_ttft.iter_mut().zip(&other.class_ttft) {
            a.merge(b);
        }
    }
}

/// Class of the `i`-th request in the global cycle: the mix covers every
/// (region, model) pair uniformly and deterministically.
fn class_of(i: usize) -> usize {
    i % (REGIONS * MODELS)
}

/// One payload line covering requests `start..start+n` of the global
/// cycle: a plain request line for n == 1, a `batch` op otherwise.
fn payload_line(cfg: &LoadgenConfig, start: usize, n: usize) -> String {
    let one = |i: usize| {
        let k = class_of(i);
        format!(
            r#"{{"region": {}, "model": {}, "tok_in": {}, "tok_out": {}}}"#,
            k / MODELS,
            k % MODELS,
            cfg.tok_in,
            cfg.tok_out
        )
    };
    if n == 1 {
        one(start)
    } else {
        let items: Vec<String> = (start..start + n).map(one).collect();
        format!(
            r#"{{"op": "batch", "requests": [{}]}}"#,
            items.join(", ")
        )
    }
}

/// Fold one reply line into the report. `start..start+n` are the request
/// indices the payload carried (their classes are known by construction).
fn record_reply(
    report: &mut LoadgenReport,
    reply: &Json,
    start: usize,
    n: usize,
) {
    let record_item = |report: &mut LoadgenReport, item: &Json, i: usize| {
        match item.get("ok").and_then(Json::as_bool) {
            Some(true) => {
                report.ok += 1;
                if let Some(ms) = item.get("ttft_ms").and_then(Json::as_f64)
                {
                    let k = class_of(i);
                    report.ttft.record(ms * 1e-3);
                    if k >= report.class_ttft.len() {
                        report
                            .class_ttft
                            .resize_with(k + 1, LatencyHistogram::new);
                    }
                    report.class_ttft[k].record(ms * 1e-3);
                }
            }
            _ => {
                if item.get("error").and_then(Json::as_str)
                    == Some("all sites saturated")
                {
                    report.saturated += 1;
                } else {
                    report.errors += 1;
                }
            }
        }
    };
    if n == 1 {
        record_item(report, reply, start);
        return;
    }
    match reply.get("results").and_then(Json::as_arr) {
        Some(items) if items.len() == n => {
            for (j, item) in items.iter().enumerate() {
                record_item(report, item, start + j);
            }
        }
        // whole-batch structured error (or malformed reply): every
        // request in the payload failed
        _ => report.errors += n as u64,
    }
}

/// Closed loop on one connection: send, await reply, repeat.
fn closed_worker(
    cfg: &LoadgenConfig,
    payloads: usize,
    first_index: usize,
) -> anyhow::Result<LoadgenReport> {
    let mut report = LoadgenReport::default();
    let stream = connect_with_retry(cfg.host.as_str(), cfg.port)?;
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let t0 = Instant::now();
    let mut index = first_index;
    for _ in 0..payloads {
        let line = payload_line(cfg, index, cfg.batch);
        let sent_at = Instant::now();
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        report.sent += cfg.batch as u64;
        let mut reply = String::new();
        match reader.read_line(&mut reply) {
            Ok(n) if n > 0 => {}
            _ => {
                // timeout or EOF: this payload (and everything after on
                // this connection) never got its reply
                report.dropped_replies += cfg.batch as u64;
                break;
            }
        }
        report.rtt.record(sent_at.elapsed().as_secs_f64());
        match Json::parse(reply.trim()) {
            Ok(j) => {
                if j.get("error").and_then(Json::as_str)
                    == Some("overloaded")
                {
                    // admission shed the whole connection, not a request
                    report.sent -= cfg.batch as u64;
                    report.overloaded_conns += 1;
                    break;
                }
                record_reply(&mut report, &j, index, cfg.batch);
            }
            Err(_) => report.errors += cfg.batch as u64,
        }
        index += cfg.batch;
    }
    report.elapsed_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Open loop on one connection: a Poisson-paced writer plus an in-thread
/// reply drain (replies are read opportunistically between sends, then
/// fully drained after the sending window closes — payload order is
/// preserved either way because the protocol is FIFO per connection).
fn open_worker(
    cfg: &LoadgenConfig,
    conn_id: usize,
    first_index: usize,
) -> anyhow::Result<LoadgenReport> {
    let mut report = LoadgenReport::default();
    let stream = connect_with_retry(cfg.host.as_str(), cfg.port)?;
    let mut writer = stream.try_clone()?;
    let reader_stream = stream.try_clone()?;
    reader_stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .ok();

    // payload schedule for this connection's slice of the aggregate rate
    let line_rate =
        (cfg.rate_rps / cfg.conns as f64 / cfg.batch as f64).max(1e-9);
    let mut rng = Rng::new(cfg.seed ^ 0x10AD).fork(conn_id as u64);

    // reader thread: drain replies as they come, pair FIFO with send times
    let batch = cfg.batch;
    let (tx, rx) = std::sync::mpsc::channel::<(usize, Instant)>();
    let reader_thread = std::thread::Builder::new()
        .name(format!("loadgen-read-{conn_id}"))
        .spawn(move || {
            let mut r = LoadgenReport::default();
            let mut reader = BufReader::new(reader_stream);
            // one reply expected per queued send record
            while let Ok((index, sent_at)) = rx.recv() {
                let mut line = String::new();
                match reader.read_line(&mut line) {
                    Ok(n) if n > 0 => {}
                    _ => {
                        r.dropped_replies += batch as u64;
                        // connection is dead: everything still queued is
                        // dropped too
                        while rx.recv().is_ok() {
                            r.dropped_replies += batch as u64;
                        }
                        return r;
                    }
                }
                r.rtt.record(sent_at.elapsed().as_secs_f64());
                match Json::parse(line.trim()) {
                    Ok(j) => {
                        if j.get("error").and_then(Json::as_str)
                            == Some("overloaded")
                        {
                            r.overloaded_conns += 1;
                            r.dropped_replies += batch as u64;
                            while rx.recv().is_ok() {
                                r.dropped_replies += batch as u64;
                            }
                            return r;
                        }
                        record_reply(&mut r, &j, index, batch);
                    }
                    Err(_) => r.errors += batch as u64,
                }
            }
            r
        })?;

    // writer: pace lines on the exponential clock for the window
    let t0 = Instant::now();
    let window = Duration::from_secs_f64(cfg.duration_s);
    let mut next_at = t0;
    let mut index = first_index;
    while t0.elapsed() < window {
        let now = Instant::now();
        if now < next_at {
            std::thread::sleep(next_at - now);
        } else if now.duration_since(next_at) > Duration::from_millis(1) {
            // behind schedule: send immediately, count it
            report.behind += 1;
        }
        let line = payload_line(cfg, index, cfg.batch);
        let sent_at = Instant::now();
        if writer.write_all(line.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
        report.sent += cfg.batch as u64;
        let _ = tx.send((index, sent_at));
        index += cfg.batch;
        next_at += Duration::from_secs_f64(rng.exponential(line_rate));
    }
    drop(tx); // reader drains what's in flight, then returns
    let _ = writer.flush();
    let reader_report = reader_thread
        .join()
        .map_err(|_| anyhow::anyhow!("loadgen reader panicked"))?;
    report.merge(&reader_report);
    report.elapsed_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Run the configured load against a live server and aggregate every
/// connection's observations.
pub fn run_loadgen(cfg: &LoadgenConfig) -> anyhow::Result<LoadgenReport> {
    anyhow::ensure!(cfg.conns > 0, "loadgen needs at least one connection");
    anyhow::ensure!(cfg.batch > 0, "batch must be >= 1");
    let conns = cfg.conns;
    let handles: Vec<_> = (0..conns)
        .map(|t| {
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name(format!("loadgen-{t}"))
                .spawn(move || match cfg.mode {
                    ArrivalMode::Closed => {
                        // distribute payloads across connections; request
                        // indices interleave so every connection carries
                        // the full class mix
                        let total = cfg.requests / cfg.batch.max(1);
                        let payloads =
                            total / conns + usize::from(t < total % conns);
                        closed_worker(&cfg, payloads, t * cfg.batch)
                    }
                    ArrivalMode::Open => open_worker(&cfg, t, t * cfg.batch),
                })
                .expect("spawn loadgen worker")
        })
        .collect();
    let mut report = LoadgenReport::default();
    let mut failures = Vec::new();
    for h in handles {
        match h.join() {
            Ok(Ok(r)) => report.merge(&r),
            Ok(Err(e)) => failures.push(e.to_string()),
            Err(_) => failures.push("worker panicked".into()),
        }
    }
    anyhow::ensure!(
        failures.is_empty(),
        "loadgen connections failed: {}",
        failures.join("; ")
    );
    Ok(report)
}

/// Render the human-readable summary `slit loadgen` prints.
pub fn format_report(cfg: &LoadgenConfig, r: &LoadgenReport) -> String {
    let mut out = String::new();
    let mode = match cfg.mode {
        ArrivalMode::Closed => "closed",
        ArrivalMode::Open => "open",
    };
    out.push_str(&format!(
        "loadgen: mode={mode} conns={} batch={} sent={} elapsed={:.2}s\n",
        cfg.conns, cfg.batch, r.sent, r.elapsed_s
    ));
    out.push_str(&format!(
        "  achieved {:.0} req/s | ok {} | saturated {} | errors {} | \
         dropped {} | shed-conns {} | behind {}\n",
        r.achieved_rps(),
        r.ok,
        r.saturated,
        r.errors,
        r.dropped_replies,
        r.overloaded_conns,
        r.behind
    ));
    out.push_str(&format!(
        "  rtt  p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms\n",
        r.rtt.p50() * 1e3,
        r.rtt.p95() * 1e3,
        r.rtt.p99() * 1e3
    ));
    out.push_str(&format!(
        "  ttft p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms\n",
        r.ttft.p50() * 1e3,
        r.ttft.p95() * 1e3,
        r.ttft.p99() * 1e3
    ));
    for (k, h) in r.class_ttft.iter().enumerate() {
        if h.count() == 0 {
            continue;
        }
        out.push_str(&format!(
            "  class {k} (region {}, model {}): n={} p50 {:.2} ms \
             p99 {:.2} ms\n",
            k / MODELS,
            k % MODELS,
            h.count(),
            h.p50() * 1e3,
            h.p99() * 1e3
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::coordinator::{
        serve_forever, Coordinator, CoordinatorConfig,
    };
    use std::sync::Arc;

    fn boot() -> (Arc<Coordinator>, u16, super::super::ServeHandle) {
        let mut cfg = SystemConfig::small_test();
        cfg.opt.generations = 2;
        cfg.opt.population = 8;
        let ccfg = CoordinatorConfig {
            plan_budget_s: 0.2,
            ..Default::default()
        };
        let c = Coordinator::new(cfg, ccfg, None);
        let handle = serve_forever(Arc::clone(&c), 0).unwrap();
        let port = handle.port;
        (c, port, handle)
    }

    fn shutdown(port: u16, handle: super::super::ServeHandle) {
        let mut cl =
            crate::coordinator::DrillClient::connect("127.0.0.1", port)
                .unwrap();
        let mut msg = Json::obj();
        msg.set("op", Json::Str("shutdown".into()));
        let _ = cl.call(&msg);
        handle.thread.join().unwrap();
    }

    #[test]
    fn class_cycle_covers_the_full_mix() {
        let classes: std::collections::BTreeSet<usize> =
            (0..REGIONS * MODELS).map(class_of).collect();
        assert_eq!(classes.len(), REGIONS * MODELS);
        assert_eq!(class_of(REGIONS * MODELS), class_of(0));
    }

    #[test]
    fn payload_lines_are_valid_protocol() {
        let cfg = LoadgenConfig::default();
        let single = Json::parse(&payload_line(&cfg, 3, 1)).unwrap();
        assert!(single.get("region").is_some());
        assert!(single.get("op").is_none());
        let batch = Json::parse(&payload_line(&cfg, 0, 4)).unwrap();
        assert_eq!(batch.get("op").and_then(Json::as_str), Some("batch"));
        assert_eq!(
            batch
                .get("requests")
                .and_then(Json::as_arr)
                .unwrap()
                .len(),
            4
        );
    }

    #[test]
    fn connect_retry_fails_fast_with_structured_error() {
        // grab an ephemeral port, then close it again: nothing listens
        let port = {
            let l = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
            l.local_addr().unwrap().port()
        };
        let t0 = Instant::now();
        let err = connect_with_retry("127.0.0.1", port).unwrap_err();
        assert!(
            err.to_string().contains("attempts"),
            "error must describe the retry budget: {err}"
        );
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "connect retry must be capped, not a hang"
        );
    }

    #[test]
    fn closed_loop_accounts_every_request() {
        let (_c, port, handle) = boot();
        let cfg = LoadgenConfig {
            port,
            conns: 3,
            requests: 90,
            batch: 3,
            ..Default::default()
        };
        let r = run_loadgen(&cfg).unwrap();
        assert_eq!(r.sent, 90);
        assert_eq!(
            r.ok + r.saturated + r.errors + r.dropped_replies,
            r.sent,
            "request mass not conserved"
        );
        assert_eq!(r.dropped_replies, 0);
        assert_eq!(r.errors, 0);
        assert!(r.ok > 0);
        assert!(r.rtt.count() > 0);
        assert!(r.ttft.p99() >= r.ttft.p50());
        // the class mix reached every (region, model) pair
        assert_eq!(
            r.class_ttft.iter().filter(|h| h.count() > 0).count(),
            REGIONS * MODELS
        );
        shutdown(port, handle);
    }

    #[test]
    fn open_loop_reports_offered_vs_achieved() {
        let (_c, port, handle) = boot();
        let cfg = LoadgenConfig {
            port,
            mode: ArrivalMode::Open,
            conns: 2,
            rate_rps: 400.0,
            duration_s: 0.5,
            batch: 2,
            ..Default::default()
        };
        let r = run_loadgen(&cfg).unwrap();
        assert!(r.sent > 0, "open loop sent nothing");
        assert_eq!(
            r.ok + r.saturated + r.errors + r.dropped_replies,
            r.sent
        );
        assert_eq!(r.dropped_replies, 0);
        assert!(r.elapsed_s >= 0.5);
        assert!(r.achieved_rps() > 0.0);
        shutdown(port, handle);
    }
}
