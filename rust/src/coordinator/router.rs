//! Plan-weighted request router with saturation failover.
//!
//! Thin, lock-light façade over the active plan: given a request class it
//! samples a site from the plan row, and exposes the failover order the
//! coordinator walks when the sampled site is full. Factored out of the
//! coordinator so routing policy is unit-testable in isolation.

use crate::plan::Plan;
use crate::util::rng::Rng;

/// Result of a routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RouteOutcome {
    /// First-choice site.
    pub primary: usize,
    /// Number of sites available for failover (always = dcs).
    pub fanout: usize,
}

/// Stateless router logic (the coordinator owns the plan lock).
pub struct Router;

impl Router {
    /// Sample the primary site for `class` from the plan's row weights.
    pub fn route(plan: &Plan, class: usize, rng: &mut Rng) -> RouteOutcome {
        let row = plan.row(class);
        RouteOutcome {
            primary: rng.weighted(row),
            fanout: plan.dcs,
        }
    }

    /// Failover iteration order: primary, then round-robin over the rest.
    pub fn failover_order(
        outcome: RouteOutcome,
    ) -> impl Iterator<Item = usize> {
        (0..outcome.fanout).map(move |i| (outcome.primary + i) % outcome.fanout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_follows_plan_weights() {
        let mut plan = Plan::uniform(2, 4);
        // concentrate class 0 on site 2
        for l in 0..4 {
            plan.set(0, l, if l == 2 { 1.0 } else { 0.0 });
        }
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let o = Router::route(&plan, 0, &mut rng);
            assert_eq!(o.primary, 2);
        }
        // class 1 stays uniform: all sites appear
        let mut seen = [false; 4];
        for _ in 0..500 {
            seen[Router::route(&plan, 1, &mut rng).primary] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn failover_visits_every_site_once() {
        let o = RouteOutcome {
            primary: 2,
            fanout: 5,
        };
        let order: Vec<usize> = Router::failover_order(o).collect();
        assert_eq!(order, vec![2, 3, 4, 0, 1]);
    }
}
