//! Plan-weighted request router with saturation failover.
//!
//! Thin, lock-light façade over the active plan: given a request class it
//! samples a site from the plan row, and exposes the failover order the
//! coordinator walks when the sampled site is full. Factored out of the
//! coordinator so routing policy is unit-testable in isolation.

use crate::plan::Plan;
use crate::util::rng::Rng;

/// Result of a routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RouteOutcome {
    /// First-choice site.
    pub primary: usize,
    /// Number of sites available for failover (always = dcs).
    pub fanout: usize,
}

/// Stateless router logic (the coordinator owns the plan lock).
pub struct Router;

impl Router {
    /// Sample the primary site for `class` from the plan's row weights.
    pub fn route(plan: &Plan, class: usize, rng: &mut Rng) -> RouteOutcome {
        let row = plan.row(class);
        RouteOutcome {
            primary: rng.weighted(row),
            fanout: plan.dcs,
        }
    }

    /// Failover iteration order: primary, then round-robin over the rest.
    pub fn failover_order(
        outcome: RouteOutcome,
    ) -> impl Iterator<Item = usize> {
        (0..outcome.fanout).map(move |i| (outcome.primary + i) % outcome.fanout)
    }

    /// All sites nearest-first by router hops (ties broken by site index,
    /// so the order is deterministic). This is the single source of the
    /// hop-aware failover rule: the coordinator precomputes one order per
    /// origin region at boot and, per request, walks the plan-sampled
    /// primary first and then this order with the primary filtered out —
    /// a saturated primary spills onto the cheapest Eq. 3 migration path
    /// instead of an arbitrary round-robin neighbour, with no per-request
    /// allocation.
    pub fn hop_order(hops: &[f64]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..hops.len()).collect();
        order.sort_by(|&a, &b| {
            hops[a]
                .partial_cmp(&hops[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_follows_plan_weights() {
        let mut plan = Plan::uniform(2, 4);
        // concentrate class 0 on site 2
        for l in 0..4 {
            plan.set(0, l, if l == 2 { 1.0 } else { 0.0 });
        }
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let o = Router::route(&plan, 0, &mut rng);
            assert_eq!(o.primary, 2);
        }
        // class 1 stays uniform: all sites appear
        let mut seen = [false; 4];
        for _ in 0..500 {
            seen[Router::route(&plan, 1, &mut rng).primary] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn failover_visits_every_site_once() {
        let o = RouteOutcome {
            primary: 2,
            fanout: 5,
        };
        let order: Vec<usize> = Router::failover_order(o).collect();
        assert_eq!(order, vec![2, 3, 4, 0, 1]);
    }

    #[test]
    fn hop_order_walks_nearest_first_with_index_tie_break() {
        let hops = [2.0, 0.0, 1.0, 5.0, 1.0];
        let order = Router::hop_order(&hops);
        assert_eq!(order, vec![1, 2, 4, 0, 3]);
        // every site appears exactly once
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
        // all-equal hops degenerate to site-index order; empty is empty
        assert_eq!(Router::hop_order(&[1.0, 1.0, 1.0]), vec![0, 1, 2]);
        assert_eq!(Router::hop_order(&[]), Vec::<usize>::new());
    }

    #[test]
    fn hop_order_matches_real_config_hops() {
        // with the paper config, a request from region 0 that fails over
        // must try same-region sites before any cross-region site
        let cfg = crate::config::SystemConfig::paper_default();
        let dcs = cfg.datacenters.len();
        let hops: Vec<f64> = (0..dcs).map(|l| cfg.hops(0, l)).collect();
        let order = Router::hop_order(&hops);
        assert_eq!(order.len(), dcs);
        // the hop sequence is non-decreasing along the order
        for w in order.windows(2) {
            assert!(hops[w[0]] <= hops[w[1]], "order not nearest-first");
        }
        // same-region sites (the smallest, intra-region hop count) lead
        let local = cfg.datacenters.iter().filter(|d| d.region == 0).count();
        assert!(order[..local]
            .iter()
            .all(|&l| cfg.datacenters[l].region == 0));
    }
}
