//! Epoch-driven discrete simulator: the validation substrate (§6 "we
//! developed and validated a Python-based simulator" — rebuilt in rust).
//!
//! Per epoch: the framework under test produces a scheduling plan from the
//! *predicted* load (workload predictor, §5.1); requests are then sampled
//! from the *actual* trace, routed to sites per the plan, placed by the
//! local WRR scheduler, and accounted through the Eq. 5-18 physics. The
//! paper's line 22-23 fallback applies: request mass beyond the predicted
//! level is routed by the default (uniform) plan.

use crate::cluster::build_panels;
use crate::config::{PhysicsConfig, SystemConfig, N_OBJ};
use crate::eval::{AnalyticEvaluator, EvalConsts};
use crate::models::EpochLedger;
use crate::plan::Plan;
use crate::power::GridSignals;
use crate::predictor::WorkloadPredictor;
use crate::sched::LocalScheduler;
use crate::trace::{EpochLoad, Trace};
use crate::util::rng::Rng;

/// Context handed to a scheduler each epoch.
pub struct EpochContext<'a> {
    pub cfg: &'a SystemConfig,
    pub epoch: usize,
    /// Predicted load for this epoch (what the plan is optimised against).
    pub predicted: &'a EpochLoad,
    /// Analytic evaluator bound to this epoch + the scheduler's power
    /// policy. SLIT searches against it; baselines may ignore it.
    pub evaluator: &'a AnalyticEvaluator,
}

/// A geo-distributed scheduling framework under test.
pub trait Scheduler {
    fn name(&self) -> String;
    /// Power ratio applied to nodes not serving load (power policy):
    /// `pr_idle` for always-warm designs, `pr_off` for scale-to-zero.
    fn unused_pr(&self, phys: &PhysicsConfig) -> f64 {
        phys.pr_idle
    }
    /// Produce the epoch's scheduling plan.
    fn plan(&mut self, ctx: &EpochContext) -> Plan;
}

/// Per-epoch record for the Fig. 5 time series.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub ledger: EpochLedger,
    pub plan: Plan,
    /// Optimiser wall time spent making this decision, seconds.
    pub decision_s: f64,
}

/// Full simulation result for one framework.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub name: String,
    pub per_epoch: Vec<EpochRecord>,
    pub total: EpochLedger,
}

impl SimResult {
    /// Aggregate objective vector [mean ttft, carbon, water, cost].
    pub fn objectives(&self) -> [f64; N_OBJ] {
        self.total.objectives()
    }
}

/// Run one framework over the trace. Deterministic per seed.
pub fn simulate(
    cfg: &SystemConfig,
    trace: &Trace,
    signals: &GridSignals,
    scheduler: &mut dyn Scheduler,
    seed: u64,
) -> SimResult {
    let epochs = cfg.epochs.min(trace.epochs.len());
    let mut rng = Rng::new(seed ^ 0x53494D); // "SIM"
    let mut predictor = WorkloadPredictor::new(cfg);
    let mut locals: Vec<LocalScheduler> = (0..cfg.datacenters.len())
        .map(|l| LocalScheduler::new(cfg, l))
        .collect();

    let mut per_epoch = Vec::with_capacity(epochs);
    let mut total = EpochLedger::default();
    let unused_pr = scheduler.unused_pr(&cfg.physics);

    for epoch in 0..epochs {
        let actual = &trace.epochs[epoch];
        // before observing this epoch, predict it (15 min lookahead)
        let predicted = if epoch == 0 {
            actual.clone() // bootstrap: first epoch is known at t=0
        } else {
            predictor.predict_next()
        };

        let (cp, dp) = build_panels(cfg, signals, epoch, &predicted, unused_pr);
        let evaluator = AnalyticEvaluator::new(
            cp,
            dp,
            EvalConsts::from_physics(&cfg.physics),
        );
        let ctx = EpochContext {
            cfg,
            epoch,
            predicted: &predicted,
            evaluator: &evaluator,
        };
        let t_decision = std::time::Instant::now();
        let plan = scheduler.plan(&ctx);
        let decision_s = t_decision.elapsed().as_secs_f64();
        assert!(plan.is_valid(), "{} produced invalid plan", scheduler.name());

        // ---- discrete execution against the ACTUAL load ------------------
        let mut ledger = EpochLedger::default();
        for ls in &mut locals {
            ls.new_epoch(cfg);
        }
        let requests = trace.sample_requests(cfg, epoch, &mut rng);
        let default_plan = Plan::uniform(plan.classes, plan.dcs);
        // per-class realised count to detect prediction misses (line 22-23)
        let mut seen = vec![0.0f64; plan.classes];

        for req in &requests {
            let k = req.class;
            seen[k] += 1.0;
            let missed = seen[k] > predicted.classes[k].n_req.ceil().max(1.0);
            let row = if missed {
                default_plan.row(k)
            } else {
                plan.row(k)
            };
            // route by plan weights; fall back to other sites on saturation
            let first = rng.weighted(row);
            let mut placed = false;
            for attempt in 0..cfg.datacenters.len() {
                let l = (first + attempt) % cfg.datacenters.len();
                if row[l] <= 0.0 && attempt == 0 && row[first] <= 0.0 {
                    continue;
                }
                let hops = cfg.hops(req.region(), l);
                // serverless container churn: a cold_frac share of requests
                // land on a cold container and pay the Eq. 2 load latency
                // (consistent with the analytic/AOT evaluator's cold term)
                let is_warm = !rng.chance(cfg.physics.cold_frac);
                if let Some(p) = locals[l].place(cfg, req, hops, is_warm) {
                    ledger.add_request(p.ttft_s);
                    placed = true;
                    break;
                }
            }
            if !placed {
                ledger.dropped += 1.0;
                // a dropped request is re-queued; charge the configured
                // re-queue latency penalty
                ledger.add_request(cfg.physics.drop_penalty_s);
            }
        }

        // ---- energy/water/carbon accounting (Eqs. 5-18) -------------------
        let (ci, wi, tou) = signals.at(epoch);
        for (l, ls) in locals.iter().enumerate() {
            let spec = &cfg.datacenters[l];
            let mut e_it = 0.0;
            for (ti, nt) in cfg.node_types.iter().enumerate() {
                let on = ls.capacity.on_nodes(ti, cfg.physics.epoch_s);
                let nodes = spec.nodes_per_type[ti] as f64;
                e_it += (on * cfg.physics.pr_on
                    + (nodes - on) * unused_pr)
                    * nt.tdp_w
                    * cfg.physics.epoch_s;
            }
            ledger.add_site(
                e_it,
                spec.cop,
                tou[l],
                cfg.physics.h_water,
                cfg.physics.d_ratio,
                wi[l],
                cfg.physics.ei_pot,
                cfg.physics.ei_waste,
                ci[l],
            );
        }

        predictor.observe(actual);
        total.merge(&ledger);
        per_epoch.push(EpochRecord {
            epoch,
            ledger,
            plan,
            decision_s,
        });
    }

    SimResult {
        name: scheduler.name(),
        per_epoch,
        total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    /// Trivial scheduler: always the uniform plan, always-warm.
    pub struct UniformScheduler;

    impl Scheduler for UniformScheduler {
        fn name(&self) -> String {
            "uniform".into()
        }
        fn plan(&mut self, ctx: &EpochContext) -> Plan {
            Plan::uniform(ctx.cfg.num_classes(), ctx.cfg.datacenters.len())
        }
    }

    /// Everything to one site (stress test for saturation handling).
    pub struct OneDcScheduler(pub usize);

    impl Scheduler for OneDcScheduler {
        fn name(&self) -> String {
            format!("one-dc-{}", self.0)
        }
        fn plan(&mut self, ctx: &EpochContext) -> Plan {
            Plan::one_dc(
                ctx.cfg.num_classes(),
                ctx.cfg.datacenters.len(),
                self.0,
            )
        }
    }

    fn run(cfg: &SystemConfig, s: &mut dyn Scheduler, seed: u64) -> SimResult {
        let trace = Trace::generate(cfg, cfg.epochs, seed);
        let signals = GridSignals::generate(cfg, cfg.epochs, seed);
        simulate(cfg, &trace, &signals, s, seed)
    }

    #[test]
    fn uniform_simulation_accounts_everything() {
        let cfg = SystemConfig::small_test();
        let res = run(&cfg, &mut UniformScheduler, 3);
        assert_eq!(res.per_epoch.len(), cfg.epochs);
        assert!(res.total.requests > 0.0);
        assert!(res.total.carbon_kg > 0.0);
        assert!(res.total.water_l > 0.0);
        assert!(res.total.cost_usd > 0.0);
        assert!(res.total.mean_ttft_s() > 0.0);
        // every epoch ledger is internally consistent
        for e in &res.per_epoch {
            assert!(e.ledger.e_tot_j >= e.ledger.e_it_j);
            assert!(e.ledger.requests >= 0.0);
        }
        // totals equal the per-epoch sum
        let sum_carbon: f64 =
            res.per_epoch.iter().map(|e| e.ledger.carbon_kg).sum();
        assert!((sum_carbon - res.total.carbon_kg).abs() < 1e-6);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = SystemConfig::small_test();
        let a = run(&cfg, &mut UniformScheduler, 9);
        let b = run(&cfg, &mut UniformScheduler, 9);
        assert_eq!(a.total.requests, b.total.requests);
        assert_eq!(a.total.carbon_kg, b.total.carbon_kg);
        assert_eq!(a.total.ttft_sum_s, b.total.ttft_sum_s);
    }

    #[test]
    fn concentration_saturates_or_slows() {
        // shrink sites until one DC cannot absorb the load: single-site
        // routing must then hurt TTFT (queueing/drops) vs spreading
        let mut cfg = SystemConfig::small_test();
        for d in &mut cfg.datacenters {
            d.nodes_per_type = vec![2, 2, 2, 2, 2, 2];
        }
        cfg.workload.base_requests_per_epoch = 20_000.0;
        let uni = run(&cfg, &mut UniformScheduler, 5);
        let one = run(&cfg, &mut OneDcScheduler(0), 5);
        assert!(
            one.total.mean_ttft_s() > uni.total.mean_ttft_s()
                || one.total.dropped > uni.total.dropped,
            "one-dc {} vs uniform {}",
            one.total.mean_ttft_s(),
            uni.total.mean_ttft_s()
        );
    }

    #[test]
    fn scale_to_zero_policy_saves_energy() {
        struct OffUniform;
        impl Scheduler for OffUniform {
            fn name(&self) -> String {
                "uniform-off".into()
            }
            fn unused_pr(&self, phys: &PhysicsConfig) -> f64 {
                phys.pr_off
            }
            fn plan(&mut self, ctx: &EpochContext) -> Plan {
                Plan::uniform(
                    ctx.cfg.num_classes(),
                    ctx.cfg.datacenters.len(),
                )
            }
        }
        let cfg = SystemConfig::small_test();
        let warm = run(&cfg, &mut UniformScheduler, 7);
        let off = run(&cfg, &mut OffUniform, 7);
        assert!(off.total.e_tot_j < warm.total.e_tot_j);
        assert!(off.total.carbon_kg < warm.total.carbon_kg);
        assert!(off.total.water_l < warm.total.water_l);
        assert!(off.total.cost_usd < warm.total.cost_usd);
    }

    #[test]
    fn objectives_vector_layout() {
        let cfg = SystemConfig::small_test();
        let res = run(&cfg, &mut UniformScheduler, 1);
        let o = res.objectives();
        assert_eq!(o[0], res.total.mean_ttft_s());
        assert_eq!(o[1], res.total.carbon_kg);
        assert_eq!(o[2], res.total.water_l);
        assert_eq!(o[3], res.total.cost_usd);
    }
}

#[cfg(test)]
mod fallback_tests {
    use super::*;
    use crate::config::SystemConfig;

    /// Algorithm 1 lines 22-23: when the prediction misses, overflow
    /// requests ride the default plan. A scheduler that routes everything
    /// to one site under a zero prediction must still see traffic spread
    /// by the uniform default.
    struct ZeroPredictionOneDc;

    impl Scheduler for ZeroPredictionOneDc {
        fn name(&self) -> String {
            "zero-pred-one-dc".into()
        }
        fn plan(&mut self, ctx: &EpochContext) -> Plan {
            Plan::one_dc(ctx.cfg.num_classes(), ctx.cfg.datacenters.len(), 0)
        }
    }

    #[test]
    fn prediction_miss_falls_back_to_default_plan() {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let trace = Trace::generate(&cfg, cfg.epochs, 13);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 13);
        let res = simulate(&cfg, &trace, &signals, &mut ZeroPredictionOneDc, 13);
        // epoch 0 bootstraps with the true load (all to site 0); epochs
        // 1-2 are planned against near-zero early predictions, so most
        // traffic overflows the per-class predicted count and routes
        // uniformly -> sites other than 0 must have burned ON energy.
        // Detect via the per-epoch ledger: with pr_idle policy and some
        // load everywhere, epoch >0 e_it must exceed the pure site-0 case.
        assert!(res.total.requests > 0.0);
        assert_eq!(res.per_epoch.len(), 3);
        // sanity: nothing dropped in this tiny workload
        assert_eq!(res.total.dropped, 0.0);
    }
}
