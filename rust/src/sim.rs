//! Simulator-facing scheduler interface and the legacy batch entry point.
//!
//! The epoch loop itself lives in [`crate::session::SimSession`] — a
//! streaming API over a mutable cluster (see DESIGN.md §11). This module
//! keeps the stable surface: the [`Scheduler`] trait, the per-epoch
//! context/record types, and a thin [`simulate`] wrapper that drives a
//! session with no events — bit-identical to the pre-session batch
//! simulator (rust/tests/session_equivalence.rs pins the equivalence).
//!
//! Per epoch: the framework under test produces a scheduling plan from the
//! *predicted* load (workload predictor, §5.1); requests are then sampled
//! from the *actual* trace, routed to sites per the plan, placed by the
//! local WRR scheduler, and accounted through the Eq. 5-18 physics. The
//! paper's line 22-23 fallback applies: request mass beyond the predicted
//! level is routed by the default (uniform) plan.

use crate::cluster::ClusterState;
use crate::config::{PhysicsConfig, SystemConfig, N_OBJ};
use crate::eval::AnalyticEvaluator;
use crate::models::EpochLedger;
use crate::plan::Plan;
use crate::power::GridSignals;
use crate::session::SimSession;
use crate::trace::{EpochLoad, Trace};

/// Context handed to a scheduler each epoch.
pub struct EpochContext<'a> {
    pub cfg: &'a SystemConfig,
    pub epoch: usize,
    /// Predicted load for this epoch (what the plan is optimised against).
    pub predicted: &'a EpochLoad,
    /// Analytic evaluator bound to this epoch + the scheduler's power
    /// policy. SLIT searches against it; baselines may ignore it.
    pub evaluator: &'a AnalyticEvaluator,
    /// Live cluster topology this epoch runs against — may differ from
    /// `cfg.datacenters` once scenario events have fired.
    pub cluster: &'a ClusterState,
    /// Previous epoch's *actual* ledger (`None` on the first epoch):
    /// feedback for prediction-error-aware schedulers.
    pub prev: Option<&'a EpochLedger>,
}

/// A geo-distributed scheduling framework under test.
pub trait Scheduler {
    fn name(&self) -> String;
    /// Power ratio applied to nodes not serving load (power policy):
    /// `pr_idle` for always-warm designs, `pr_off` for scale-to-zero.
    fn unused_pr(&self, phys: &PhysicsConfig) -> f64 {
        phys.pr_idle
    }
    /// Produce the epoch's scheduling plan.
    fn plan(&mut self, ctx: &EpochContext) -> Plan;
    /// When deferrable trace mass is served relative to arrival. The
    /// default releases on arrival (no temporal control); wrap a
    /// scheduler in [`crate::opt::shift::ShiftScheduler`] to opt into
    /// forecast-driven shifting.
    fn shift_policy(&self) -> crate::opt::shift::ShiftPolicy {
        crate::opt::shift::ShiftPolicy::Immediate
    }
    /// Which believed grid-signal view the session resolves panels
    /// through. The default trusts the feed verbatim (fault-blind; with
    /// zero injected faults this is exactly the ground truth); wrap a
    /// scheduler in [`crate::signals::RobustScheduler`] to opt into the
    /// health-gated fallback ladder.
    fn signal_policy(&self) -> crate::signals::SignalPolicy {
        crate::signals::SignalPolicy::Trusting
    }
}

/// Per-epoch record for the Fig. 5 time series.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub ledger: EpochLedger,
    pub plan: Plan,
    /// Optimiser wall time spent making this decision, seconds.
    pub decision_s: f64,
    /// Live total node count per site this epoch (shows capacity dips
    /// and recoveries under rolling-outage events).
    pub site_nodes: Vec<usize>,
    /// Per-objective oracle-vs-achieved comparison for this epoch's
    /// plan under this epoch's evaluator (`opt::oracle::gap_reports`).
    pub gaps: [crate::opt::oracle::GapReport; N_OBJ],
}

/// Full simulation result for one framework.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub name: String,
    pub per_epoch: Vec<EpochRecord>,
    pub total: EpochLedger,
}

impl SimResult {
    /// Aggregate objective vector [mean ttft, carbon, water, cost].
    pub fn objectives(&self) -> [f64; N_OBJ] {
        self.total.objectives()
    }

    /// Whole-run optimality gap on `obj` vs the summed per-epoch oracle
    /// lower bounds ([`EpochLedger::oracle_gap_frac`]).
    pub fn oracle_gap(&self, obj: usize) -> f64 {
        self.total.oracle_gap_frac(obj)
    }
}

/// Run one framework over the trace. Deterministic per seed.
///
/// Legacy batch entry point: a [`SimSession`] with no scenario events and
/// no observers, driven to the end of the horizon.
pub fn simulate(
    cfg: &SystemConfig,
    trace: &Trace,
    signals: &GridSignals,
    scheduler: &mut dyn Scheduler,
    seed: u64,
) -> SimResult {
    SimSession::new(cfg, trace, signals, scheduler, seed).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    /// Trivial scheduler: always the uniform plan, always-warm.
    pub struct UniformScheduler;

    impl Scheduler for UniformScheduler {
        fn name(&self) -> String {
            "uniform".into()
        }
        fn plan(&mut self, ctx: &EpochContext) -> Plan {
            Plan::uniform(ctx.cfg.num_classes(), ctx.cfg.datacenters.len())
        }
    }

    /// Everything to one site (stress test for saturation handling).
    pub struct OneDcScheduler(pub usize);

    impl Scheduler for OneDcScheduler {
        fn name(&self) -> String {
            format!("one-dc-{}", self.0)
        }
        fn plan(&mut self, ctx: &EpochContext) -> Plan {
            Plan::one_dc(
                ctx.cfg.num_classes(),
                ctx.cfg.datacenters.len(),
                self.0,
            )
        }
    }

    fn run(cfg: &SystemConfig, s: &mut dyn Scheduler, seed: u64) -> SimResult {
        let trace = Trace::generate(cfg, cfg.epochs, seed);
        let signals = GridSignals::generate(cfg, cfg.epochs, seed);
        simulate(cfg, &trace, &signals, s, seed)
    }

    #[test]
    fn uniform_simulation_accounts_everything() {
        let cfg = SystemConfig::small_test();
        let res = run(&cfg, &mut UniformScheduler, 3);
        assert_eq!(res.per_epoch.len(), cfg.epochs);
        assert!(res.total.requests > 0.0);
        assert!(res.total.carbon_kg > 0.0);
        assert!(res.total.water_l > 0.0);
        assert!(res.total.cost_usd > 0.0);
        assert!(res.total.mean_ttft_s() > 0.0);
        // every epoch ledger is internally consistent
        for e in &res.per_epoch {
            assert!(e.ledger.e_tot_j >= e.ledger.e_it_j);
            assert!(e.ledger.requests >= 0.0);
            // no events: the capacity series is flat at the config counts
            let nodes: usize = e.site_nodes.iter().sum();
            let want: usize = cfg
                .datacenters
                .iter()
                .map(|d| d.total_nodes())
                .sum();
            assert_eq!(nodes, want);
        }
        // totals equal the per-epoch sum
        let sum_carbon: f64 =
            res.per_epoch.iter().map(|e| e.ledger.carbon_kg).sum();
        assert!((sum_carbon - res.total.carbon_kg).abs() < 1e-6);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = SystemConfig::small_test();
        let a = run(&cfg, &mut UniformScheduler, 9);
        let b = run(&cfg, &mut UniformScheduler, 9);
        assert_eq!(a.total.requests, b.total.requests);
        assert_eq!(a.total.carbon_kg, b.total.carbon_kg);
        assert_eq!(a.total.ttft_sum_s, b.total.ttft_sum_s);
    }

    #[test]
    fn concentration_saturates_or_slows() {
        // shrink sites until one DC cannot absorb the load: single-site
        // routing must then hurt TTFT (queueing/drops) vs spreading
        let mut cfg = SystemConfig::small_test();
        for d in &mut cfg.datacenters {
            d.nodes_per_type = vec![2, 2, 2, 2, 2, 2];
        }
        cfg.workload.base_requests_per_epoch = 20_000.0;
        let uni = run(&cfg, &mut UniformScheduler, 5);
        let one = run(&cfg, &mut OneDcScheduler(0), 5);
        assert!(
            one.total.mean_ttft_s() > uni.total.mean_ttft_s()
                || one.total.dropped > uni.total.dropped,
            "one-dc {} vs uniform {}",
            one.total.mean_ttft_s(),
            uni.total.mean_ttft_s()
        );
    }

    #[test]
    fn scale_to_zero_policy_saves_energy() {
        struct OffUniform;
        impl Scheduler for OffUniform {
            fn name(&self) -> String {
                "uniform-off".into()
            }
            fn unused_pr(&self, phys: &PhysicsConfig) -> f64 {
                phys.pr_off
            }
            fn plan(&mut self, ctx: &EpochContext) -> Plan {
                Plan::uniform(
                    ctx.cfg.num_classes(),
                    ctx.cfg.datacenters.len(),
                )
            }
        }
        let cfg = SystemConfig::small_test();
        let warm = run(&cfg, &mut UniformScheduler, 7);
        let off = run(&cfg, &mut OffUniform, 7);
        assert!(off.total.e_tot_j < warm.total.e_tot_j);
        assert!(off.total.carbon_kg < warm.total.carbon_kg);
        assert!(off.total.water_l < warm.total.water_l);
        assert!(off.total.cost_usd < warm.total.cost_usd);
    }

    #[test]
    fn objectives_vector_layout() {
        let cfg = SystemConfig::small_test();
        let res = run(&cfg, &mut UniformScheduler, 1);
        let o = res.objectives();
        assert_eq!(o[0], res.total.mean_ttft_s());
        assert_eq!(o[1], res.total.carbon_kg);
        assert_eq!(o[2], res.total.water_l);
        assert_eq!(o[3], res.total.cost_usd);
    }
}

#[cfg(test)]
mod fallback_tests {
    use super::*;
    use crate::config::SystemConfig;

    /// Algorithm 1 lines 22-23: when the prediction misses, overflow
    /// requests ride the default plan. A scheduler that routes everything
    /// to one site under a zero prediction must still see traffic spread
    /// by the uniform default.
    struct ZeroPredictionOneDc;

    impl Scheduler for ZeroPredictionOneDc {
        fn name(&self) -> String {
            "zero-pred-one-dc".into()
        }
        fn plan(&mut self, ctx: &EpochContext) -> Plan {
            Plan::one_dc(ctx.cfg.num_classes(), ctx.cfg.datacenters.len(), 0)
        }
    }

    #[test]
    fn prediction_miss_falls_back_to_default_plan() {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let trace = Trace::generate(&cfg, cfg.epochs, 13);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 13);
        let res = simulate(&cfg, &trace, &signals, &mut ZeroPredictionOneDc, 13);
        // epoch 0 bootstraps with the true load (all to site 0); epochs
        // 1-2 are planned against near-zero early predictions, so most
        // traffic overflows the per-class predicted count and routes
        // uniformly -> sites other than 0 must have burned ON energy.
        // Detect via the per-epoch ledger: with pr_idle policy and some
        // load everywhere, epoch >0 e_it must exceed the pure site-0 case.
        assert!(res.total.requests > 0.0);
        assert_eq!(res.per_epoch.len(), 3);
        // sanity: nothing dropped in this tiny workload
        assert_eq!(res.total.dropped, 0.0);
    }
}
