//! Pareto machinery for the 4-objective minimisation problem: dominance,
//! a bounded non-dominated archive with crowding-distance pruning (NSGA-II
//! style), hypervolume estimation, and the paper's five showcased solution
//! selectors (SLIT-Carbon/TTFT/Water/Cost best-single-objective plus
//! SLIT-Balance = minimal normalised sum, §6).

use crate::config::{N_OBJ, OBJ_NAMES};
use crate::plan::Plan;
use crate::util::rng::Rng;

/// A plan with its evaluated objective vector.
#[derive(Clone, Debug)]
pub struct Solution {
    pub plan: Plan,
    pub obj: [f64; N_OBJ],
}

/// True iff `a` Pareto-dominates `b` (<= everywhere, < somewhere).
pub fn dominates(a: &[f64; N_OBJ], b: &[f64; N_OBJ]) -> bool {
    let mut strictly = false;
    for i in 0..N_OBJ {
        if a[i] > b[i] {
            return false;
        }
        if a[i] < b[i] {
            strictly = true;
        }
    }
    strictly
}

/// Bounded non-dominated archive (Algorithm 1's `update_population`: only
/// dominant plans are retained).
#[derive(Clone, Debug)]
pub struct ParetoArchive {
    pub solutions: Vec<Solution>,
    cap: usize,
}

impl ParetoArchive {
    pub fn new(cap: usize) -> Self {
        ParetoArchive {
            solutions: Vec::new(),
            cap: cap.max(4),
        }
    }

    pub fn len(&self) -> usize {
        self.solutions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.solutions.is_empty()
    }

    /// True iff an objective vector would enter the archive (not dominated
    /// by and not equal to any member). Lets the optimizer test a
    /// candidate's objectives *before* materialising an owned plan for it:
    /// the hot path only pays the allocation for accepted candidates.
    pub fn would_accept(&self, obj: &[f64; N_OBJ]) -> bool {
        !self
            .solutions
            .iter()
            .any(|s| dominates(&s.obj, obj) || s.obj == *obj)
    }

    /// Try to insert; returns true if the solution enters the archive
    /// (i.e. it is not dominated by any member).
    pub fn insert(&mut self, sol: Solution) -> bool {
        if !self.would_accept(&sol.obj) {
            return false;
        }
        self.solutions.retain(|s| !dominates(&sol.obj, &s.obj));
        self.solutions.push(sol);
        if self.solutions.len() > self.cap {
            self.prune();
        }
        true
    }

    /// Drop the most crowded members until within capacity.
    fn prune(&mut self) {
        while self.solutions.len() > self.cap {
            let crowd = crowding_distances(&self.solutions);
            // never drop an objective-extreme point (infinite crowding)
            let victim = crowd
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            self.solutions.swap_remove(victim);
        }
    }

    /// Verify the non-domination invariant (tests / debug).
    pub fn is_consistent(&self) -> bool {
        for (i, a) in self.solutions.iter().enumerate() {
            for (j, b) in self.solutions.iter().enumerate() {
                if i != j && dominates(&a.obj, &b.obj) {
                    return false;
                }
            }
        }
        true
    }

    /// Best solution for a single objective index.
    pub fn best_for(&self, obj: usize) -> Option<&Solution> {
        self.solutions.iter().min_by(|a, b| {
            a.obj[obj].partial_cmp(&b.obj[obj]).unwrap()
        })
    }

    /// The balanced solution: minimal sum of per-objective min-max
    /// normalised values across the archive (§6 SLIT-Balance).
    pub fn balanced(&self) -> Option<&Solution> {
        if self.solutions.is_empty() {
            return None;
        }
        let (lo, hi) = self.bounds();
        self.solutions.iter().min_by(|a, b| {
            let na = norm_sum(&a.obj, &lo, &hi);
            let nb = norm_sum(&b.obj, &lo, &hi);
            na.partial_cmp(&nb).unwrap()
        })
    }

    /// Per-objective (min, max) over the archive.
    pub fn bounds(&self) -> ([f64; N_OBJ], [f64; N_OBJ]) {
        let mut lo = [f64::INFINITY; N_OBJ];
        let mut hi = [f64::NEG_INFINITY; N_OBJ];
        for s in &self.solutions {
            for i in 0..N_OBJ {
                lo[i] = lo[i].min(s.obj[i]);
                hi[i] = hi[i].max(s.obj[i]);
            }
        }
        (lo, hi)
    }

    /// The paper's five showcased solutions, in OBJ order + balance.
    pub fn showcase(&self) -> Vec<(String, Solution)> {
        let mut out = Vec::new();
        for (i, name) in OBJ_NAMES.iter().enumerate() {
            if let Some(s) = self.best_for(i) {
                out.push((format!("slit-{}", short_name(name)), s.clone()));
            }
        }
        if let Some(s) = self.balanced() {
            out.push(("slit-balance".to_string(), s.clone()));
        }
        out
    }
}

fn short_name(obj_name: &str) -> &str {
    match obj_name {
        "ttft_s" => "ttft",
        "carbon_kg" => "carbon",
        "water_l" => "water",
        "cost_usd" => "cost",
        other => other,
    }
}

fn norm_sum(obj: &[f64; N_OBJ], lo: &[f64; N_OBJ], hi: &[f64; N_OBJ]) -> f64 {
    (0..N_OBJ)
        .map(|i| {
            if hi[i] - lo[i] > 1e-15 {
                (obj[i] - lo[i]) / (hi[i] - lo[i])
            } else {
                0.0
            }
        })
        .sum()
}

/// NSGA-II crowding distance for each solution (extremes get +inf).
pub fn crowding_distances(sols: &[Solution]) -> Vec<f64> {
    let n = sols.len();
    let mut d = vec![0.0f64; n];
    if n <= 2 {
        return vec![f64::INFINITY; n];
    }
    for obj in 0..N_OBJ {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| {
            sols[a].obj[obj].partial_cmp(&sols[b].obj[obj]).unwrap()
        });
        let lo = sols[idx[0]].obj[obj];
        let hi = sols[idx[n - 1]].obj[obj];
        d[idx[0]] = f64::INFINITY;
        d[idx[n - 1]] = f64::INFINITY;
        if hi - lo <= 1e-15 {
            continue;
        }
        for w in 1..n - 1 {
            let prev = sols[idx[w - 1]].obj[obj];
            let next = sols[idx[w + 1]].obj[obj];
            d[idx[w]] += (next - prev) / (hi - lo);
        }
    }
    d
}

/// Deb's fast non-dominated sort (NSGA-II): partition `objs` into
/// successive non-dominated fronts, returning index lists front by front.
/// Every pairwise domination is computed exactly once and cached as
/// domination counts + dominated-sets; peeling a front is then O(edges)
/// instead of re-scanning the whole remaining pool per front the way the
/// old `select_population` loop did (O(n^2) *per front*). Exact duplicates
/// never dominate each other, so they land in the same front. Order within
/// each front is ascending input index (deterministic).
pub fn fast_nondominated_sort(objs: &[[f64; N_OBJ]]) -> Vec<Vec<usize>> {
    let n = objs.len();
    if n == 0 {
        return Vec::new();
    }
    // S_i (who i dominates) and n_i (how many dominate i), computed once
    let mut dominated: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut count = vec![0u32; n];
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&objs[i], &objs[j]) {
                dominated[i].push(j as u32);
                count[j] += 1;
            } else if dominates(&objs[j], &objs[i]) {
                dominated[j].push(i as u32);
                count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut front: Vec<usize> =
        (0..n).filter(|&i| count[i] == 0).collect();
    while !front.is_empty() {
        let mut next: Vec<usize> = Vec::new();
        for &i in &front {
            for &j in &dominated[i] {
                let j = j as usize;
                count[j] -= 1;
                if count[j] == 0 {
                    next.push(j);
                }
            }
        }
        // members are discovered in their dominators' order; keep fronts
        // index-sorted so the output is independent of edge layout
        next.sort_unstable();
        fronts.push(std::mem::take(&mut front));
        front = next;
    }
    fronts
}

/// Monte-Carlo hypervolume: the fraction of the `[0, reference]` box
/// dominated by the front (objectives are non-negative here). Exact HV in
/// 4D is expensive; sampling is plenty for tracking optimizer progress and
/// ablations, and the fixed box keeps values comparable across fronts.
pub fn hypervolume(
    front: &[Solution],
    reference: &[f64; N_OBJ],
    samples: usize,
    seed: u64,
) -> f64 {
    if front.is_empty() {
        return 0.0;
    }
    let mut rng = Rng::new(seed);
    let mut hits = 0usize;
    for _ in 0..samples {
        let mut pt = [0.0; N_OBJ];
        for i in 0..N_OBJ {
            pt[i] = rng.range(0.0, reference[i].max(1e-12));
        }
        if front.iter().any(|s| {
            (0..N_OBJ).all(|i| s.obj[i] <= pt[i])
        }) {
            hits += 1;
        }
    }
    hits as f64 / samples as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propkit;

    fn sol(obj: [f64; N_OBJ]) -> Solution {
        Solution {
            plan: Plan::uniform(2, 3),
            obj,
        }
    }

    #[test]
    fn dominance_basics() {
        let a = [1.0, 1.0, 1.0, 1.0];
        let b = [2.0, 2.0, 2.0, 2.0];
        let c = [0.5, 3.0, 1.0, 1.0];
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&a, &c));
        assert!(!dominates(&c, &a));
        assert!(!dominates(&a, &a));
    }

    #[test]
    fn dominance_is_a_strict_partial_order() {
        propkit::check(
            "dominance-partial-order",
            0xD0,
            300,
            |r| {
                let a: [f64; N_OBJ] =
                    [r.below(5) as f64, r.below(5) as f64, r.below(5) as f64, r.below(5) as f64];
                let b: [f64; N_OBJ] =
                    [r.below(5) as f64, r.below(5) as f64, r.below(5) as f64, r.below(5) as f64];
                let c: [f64; N_OBJ] =
                    [r.below(5) as f64, r.below(5) as f64, r.below(5) as f64, r.below(5) as f64];
                (a, b, c)
            },
            |&(a, b, c)| {
                // irreflexive
                if dominates(&a, &a) {
                    return Err("reflexive".into());
                }
                // antisymmetric
                if dominates(&a, &b) && dominates(&b, &a) {
                    return Err("symmetric".into());
                }
                // transitive
                if dominates(&a, &b) && dominates(&b, &c) && !dominates(&a, &c)
                {
                    return Err("not transitive".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn archive_keeps_only_nondominated() {
        let mut ar = ParetoArchive::new(16);
        assert!(ar.insert(sol([2.0, 2.0, 2.0, 2.0])));
        assert!(ar.insert(sol([1.0, 3.0, 2.0, 2.0]))); // tradeoff
        assert!(!ar.insert(sol([3.0, 3.0, 3.0, 3.0]))); // dominated
        assert!(ar.insert(sol([1.0, 1.0, 1.0, 1.0]))); // dominates all
        assert_eq!(ar.len(), 1);
        assert!(ar.is_consistent());
    }

    #[test]
    fn archive_rejects_duplicates() {
        let mut ar = ParetoArchive::new(8);
        assert!(ar.insert(sol([1.0, 2.0, 3.0, 4.0])));
        assert!(!ar.insert(sol([1.0, 2.0, 3.0, 4.0])));
        assert_eq!(ar.len(), 1);
    }

    #[test]
    fn archive_respects_capacity_and_keeps_extremes() {
        let mut ar = ParetoArchive::new(8);
        // a 2-objective-ish tradeoff curve embedded in 4D
        for i in 0..50 {
            let x = i as f64;
            ar.insert(sol([x, 49.0 - x, 10.0, 10.0]));
        }
        assert!(ar.len() <= 8);
        assert!(ar.is_consistent());
        // extremes survive pruning
        let (lo, _) = ar.bounds();
        assert_eq!(lo[0], 0.0);
        assert_eq!(lo[1], 0.0);
    }

    #[test]
    fn archive_nondomination_invariant_property() {
        propkit::check(
            "archive-invariant",
            0xAC,
            60,
            |r| {
                let mut ar = ParetoArchive::new(12);
                for _ in 0..80 {
                    let o = [
                        r.range(0.0, 10.0),
                        r.range(0.0, 10.0),
                        r.range(0.0, 10.0),
                        r.range(0.0, 10.0),
                    ];
                    ar.insert(sol(o));
                }
                ar
            },
            |ar| {
                if !ar.is_consistent() {
                    return Err("dominated member retained".into());
                }
                if ar.len() > 12 {
                    return Err("capacity exceeded".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn best_for_and_balanced() {
        let mut ar = ParetoArchive::new(16);
        ar.insert(sol([1.0, 9.0, 9.0, 9.0]));
        ar.insert(sol([9.0, 1.0, 9.0, 9.0]));
        ar.insert(sol([9.0, 9.0, 1.0, 9.0]));
        ar.insert(sol([9.0, 9.0, 9.0, 1.0]));
        ar.insert(sol([3.0, 3.0, 3.0, 3.0]));
        assert_eq!(ar.best_for(0).unwrap().obj[0], 1.0);
        assert_eq!(ar.best_for(3).unwrap().obj[3], 1.0);
        let b = ar.balanced().unwrap();
        assert_eq!(b.obj, [3.0, 3.0, 3.0, 3.0]);
        let names: Vec<String> =
            ar.showcase().into_iter().map(|(n, _)| n).collect();
        assert_eq!(
            names,
            vec![
                "slit-ttft",
                "slit-carbon",
                "slit-water",
                "slit-cost",
                "slit-balance"
            ]
        );
    }

    /// Brute-force reference: the unique non-dominated subset of a point
    /// set (first occurrence wins on exact ties).
    fn bruteforce_front(points: &[[f64; N_OBJ]]) -> Vec<[f64; N_OBJ]> {
        let mut front: Vec<[f64; N_OBJ]> = Vec::new();
        for (i, p) in points.iter().enumerate() {
            let dominated_or_dup = points.iter().enumerate().any(|(j, q)| {
                (j != i && dominates(q, p))
                    || (j < i && q == p)
            });
            if !dominated_or_dup {
                front.push(*p);
            }
        }
        front
    }

    fn sorted_objs(mut objs: Vec<[f64; N_OBJ]>) -> Vec<[f64; N_OBJ]> {
        objs.sort_by(|a, b| {
            a.iter()
                .zip(b.iter())
                .find_map(|(x, y)| {
                    let ord = x.partial_cmp(y).unwrap();
                    if ord == std::cmp::Ordering::Equal {
                        None
                    } else {
                        Some(ord)
                    }
                })
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        objs
    }

    #[test]
    fn archive_equals_bruteforce_front_in_any_insertion_order() {
        // integer-ish coordinates force plenty of exact duplicates and
        // dominance ties; the archive must converge to the same unique
        // front as the brute-force reference under every insertion order
        propkit::check(
            "archive-order-invariant",
            0x04D3,
            60,
            |r| {
                let n = 12 + r.below(20);
                let points: Vec<[f64; N_OBJ]> = (0..n)
                    .map(|_| {
                        [
                            r.below(5) as f64,
                            r.below(5) as f64,
                            r.below(5) as f64,
                            r.below(5) as f64,
                        ]
                    })
                    .collect();
                let mut shuffled = points.clone();
                r.shuffle(&mut shuffled);
                (points, shuffled)
            },
            |(points, shuffled)| {
                let want = sorted_objs(bruteforce_front(points));
                for order in [points, shuffled] {
                    let mut ar = ParetoArchive::new(256);
                    for &o in order {
                        ar.insert(sol(o));
                    }
                    if !ar.is_consistent() {
                        return Err("dominated member retained".into());
                    }
                    let got = sorted_objs(
                        ar.solutions.iter().map(|s| s.obj).collect(),
                    );
                    if got != want {
                        return Err(format!(
                            "front mismatch: got {got:?}, want {want:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn crowding_gives_every_objective_boundary_infinite_distance() {
        // each objective's min and max holders must be uncrowdable, on all
        // four axes — not just the first
        let sols = vec![
            sol([0.0, 5.0, 5.0, 5.0]),
            sol([9.0, 0.0, 5.0, 5.0]),
            sol([5.0, 9.0, 0.0, 5.0]),
            sol([5.0, 5.0, 9.0, 0.0]),
            sol([4.0, 4.0, 4.0, 9.0]),
            sol([3.0, 3.0, 3.0, 3.0]),
        ];
        let d = crowding_distances(&sols);
        for obj in 0..N_OBJ {
            let min_i = (0..sols.len())
                .min_by(|&a, &b| {
                    sols[a].obj[obj].partial_cmp(&sols[b].obj[obj]).unwrap()
                })
                .unwrap();
            let max_i = (0..sols.len())
                .max_by(|&a, &b| {
                    sols[a].obj[obj].partial_cmp(&sols[b].obj[obj]).unwrap()
                })
                .unwrap();
            assert!(d[min_i].is_infinite(), "obj {obj} min not boundary");
            assert!(d[max_i].is_infinite(), "obj {obj} max not boundary");
        }
    }

    #[test]
    fn crowding_extremes_infinite() {
        let sols = vec![
            sol([0.0, 4.0, 1.0, 1.0]),
            sol([1.0, 3.0, 1.0, 1.0]),
            sol([2.0, 2.0, 1.0, 1.0]),
            sol([3.0, 1.0, 1.0, 1.0]),
            sol([4.0, 0.0, 1.0, 1.0]),
        ];
        let d = crowding_distances(&sols);
        assert!(d[0].is_infinite());
        assert!(d[4].is_infinite());
        assert!(d[1].is_finite() && d[2].is_finite());
    }

    /// Brute-force front peeling: repeatedly extract the non-dominated
    /// subset of what remains (the old `select_population` strategy).
    fn peel_fronts(objs: &[[f64; N_OBJ]]) -> Vec<Vec<usize>> {
        let mut remaining: Vec<usize> = (0..objs.len()).collect();
        let mut fronts = Vec::new();
        while !remaining.is_empty() {
            let front: Vec<usize> = remaining
                .iter()
                .copied()
                .filter(|&i| {
                    !remaining
                        .iter()
                        .any(|&j| j != i && dominates(&objs[j], &objs[i]))
                })
                .collect();
            remaining.retain(|i| !front.contains(i));
            fronts.push(front);
        }
        fronts
    }

    #[test]
    fn fast_sort_matches_bruteforce_peeling_property() {
        propkit::check(
            "fast-nondominated-sort",
            0xFA57,
            80,
            |r| {
                let n = 5 + r.below(40);
                (0..n)
                    .map(|_| {
                        // integer-ish coords force duplicates + dominance ties
                        [
                            r.below(4) as f64,
                            r.below(4) as f64,
                            r.below(4) as f64,
                            r.below(4) as f64,
                        ]
                    })
                    .collect::<Vec<_>>()
            },
            |objs| {
                let fast = fast_nondominated_sort(objs);
                let brute = peel_fronts(objs);
                if fast != brute {
                    return Err(format!(
                        "fronts diverge: fast {fast:?} vs brute {brute:?}"
                    ));
                }
                let total: usize = fast.iter().map(|f| f.len()).sum();
                if total != objs.len() {
                    return Err("sort dropped or duplicated members".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fast_sort_trivial_cases() {
        assert!(fast_nondominated_sort(&[]).is_empty());
        let one = fast_nondominated_sort(&[[1.0, 2.0, 3.0, 4.0]]);
        assert_eq!(one, vec![vec![0]]);
        // a strict chain: one front per point
        let chain: Vec<[f64; N_OBJ]> = (0..5)
            .map(|i| [i as f64 + 1.0; N_OBJ])
            .collect();
        let fronts = fast_nondominated_sort(&chain);
        assert_eq!(fronts.len(), 5);
        assert_eq!(fronts[0], vec![0]);
        // exact duplicates share a front
        let dup = fast_nondominated_sort(&[
            [1.0, 1.0, 1.0, 1.0],
            [1.0, 1.0, 1.0, 1.0],
        ]);
        assert_eq!(dup, vec![vec![0, 1]]);
    }

    #[test]
    fn would_accept_agrees_with_insert() {
        let mut ar = ParetoArchive::new(16);
        ar.insert(sol([2.0, 2.0, 2.0, 2.0]));
        assert!(!ar.would_accept(&[3.0, 3.0, 3.0, 3.0])); // dominated
        assert!(!ar.would_accept(&[2.0, 2.0, 2.0, 2.0])); // duplicate
        assert!(ar.would_accept(&[1.0, 3.0, 2.0, 2.0])); // tradeoff
        assert!(ar.insert(sol([1.0, 3.0, 2.0, 2.0])));
    }

    #[test]
    fn hypervolume_monotone_in_front_quality() {
        let far = vec![sol([8.0, 8.0, 8.0, 8.0])];
        let near = vec![sol([1.0, 1.0, 1.0, 1.0])];
        let reference = [10.0, 10.0, 10.0, 10.0];
        let hv_far = hypervolume(&far, &reference, 20_000, 1);
        let hv_near = hypervolume(&near, &reference, 20_000, 1);
        assert!(hv_near > hv_far);
        assert!(hypervolume(&[], &reference, 100, 1) == 0.0);
    }
}
