//! # SLIT — sustainable geo-distributed LLM inference scheduling
//!
//! Reproduction of *"Sustainable Carbon-Aware and Water-Efficient LLM
//! Scheduling in Geo-Distributed Cloud Datacenters"* (CS.DC 2025): a
//! multi-objective (TTFT / carbon / water / energy-cost) scheduler for LLM
//! inference across geo-distributed datacenters, with the paper's
//! metaheuristic (ML-guided local search + EA), physical models
//! (Eqs. 1-18), baselines (Helix, Splitwise), discrete simulator, AOT
//! JAX/Pallas plan-evaluation kernel, and PJRT runtime.
//!
//! See DESIGN.md for the module map and EXPERIMENTS.md for reproduced
//! figures. Layer map: `runtime`+`coordinator` (L3 serving), the AOT
//! artifacts under `artifacts/` (L2 JAX graph + L1 Pallas kernel).

pub mod baselines;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod forecast;
pub mod models;
pub mod opt;
pub mod pareto;
pub mod plan;
pub mod power;
pub mod predictor;
pub mod registry;
pub mod runtime;
pub mod scenario;
pub mod sched;
pub mod session;
pub mod signals;
pub mod sim;
pub mod trace;
pub mod util;
