//! The signal plane: believed grid telemetry vs ground truth.
//!
//! Every scheduler used to read carbon intensity (CI), water intensity
//! (WUE), and TOU price straight from the `power.rs` ground truth. Real
//! deployments consume external grid feeds (WattTime / Electricity-Maps
//! style) that go stale, drop out, lag, and spike — and carbon-aware
//! allocation quality is bounded by the quality of those signals. This
//! module interposes a [`SignalFeed`] between ground truth and everything
//! that reads it:
//!
//! * **Fault injection** — deterministic [`SignalFault`]s (freeze,
//!   dropout, spike×k, fixed-lag delivery, region-wide blackout) ride the
//!   existing `ScenarioEvent` path (`ClusterAction::Signal`), so
//!   telemetry faults are scheduled exactly like capacity faults.
//! * **Health monitoring** — a per-site staleness clock plus plausibility
//!   gates (absolute range + max rate-of-change per axis) classify each
//!   site [`FeedState::Fresh`] / [`Stale`](FeedState::Stale) /
//!   [`Quarantined`](FeedState::Quarantined); quarantined feeds recover
//!   after [`RECOVERY_STREAK`] consecutive plausible samples.
//! * **Fallback ladder** — the *robust* believed value blends
//!   last-known-good (confidence decaying [`LKG_DECAY`]^age) toward an
//!   anchor: diurnal persistence (same-phase value from yesterday, via
//!   [`crate::forecast::DiurnalRing`]) → fleet median of currently-fresh
//!   sites → the site's config prior. Robust believed values are always
//!   finite and clamped into the plausibility range (property-tested).
//! * **Two views** — [`SignalPolicy::Trusting`] schedulers consume the
//!   *naive* view (last delivered value verbatim — fault-blind);
//!   [`SignalPolicy::Robust`] schedulers (the `slit-robust` registry row,
//!   a [`RobustScheduler`] wrapper) consume the ladder. `EpochLedger`
//!   accounting always uses ground truth, so the regret of scheduling on
//!   bad signals is directly measurable (`signal_*` ledger fields).
//!
//! With zero faults injected both views are bit-identical copies of the
//! ground truth, so every pre-existing framework is unchanged
//! (rust/tests/signal_faults.rs pins it). See DESIGN.md §17.

use crate::config::SystemConfig;
use crate::forecast::{epochs_per_day, DiurnalRing};
use crate::sim::{EpochContext, Scheduler};

/// Signal axes carried per site: CI, WUE, TOU.
pub const AXES: usize = 3;
pub const AXIS_CI: usize = 0;
pub const AXIS_WUE: usize = 1;
pub const AXIS_TOU: usize = 2;
pub const AXIS_NAMES: [&str; AXES] = ["ci", "wue", "tou"];

/// Absolute plausibility range per axis (kg/kWh, L/kWh, $/kWh). Generous
/// vs the generator floors (0.005 / 0.05 / 0.005) and the paper's site
/// bases, so honest telemetry never trips the gate.
pub const PLAUSIBLE_MIN: [f64; AXES] = [1e-3, 1e-2, 1e-3];
pub const PLAUSIBLE_MAX: [f64; AXES] = [3.0, 60.0, 3.0];

/// Rate-of-change gate vs the last accepted sample: a step is rejected
/// only when it exceeds BOTH the multiplicative ratio and the absolute
/// delta — low-valued signals near the generator floor can legitimately
/// triple between epochs while moving by almost nothing.
pub const MAX_STEP_RATIO: f64 = 3.0;
pub const MAX_STEP_ABS: [f64; AXES] = [0.5, 10.0, 0.5];

/// Consecutive plausible samples a quarantined feed must deliver before
/// it is trusted (and re-classified Fresh) again.
pub const RECOVERY_STREAK: u32 = 2;

/// Per-epoch confidence decay of a last-known-good value: believed =
/// decay^age · lkg + (1 − decay^age) · anchor.
pub const LKG_DECAY: f64 = 0.7;

/// Age at which the decay weight bottoms out (0.7^16 ≈ 3e-3: effectively
/// all anchor).
pub const MAX_DECAY_AGE: usize = 16;

/// One scheduled telemetry fault. Injected via
/// [`crate::cluster::ClusterAction::Signal`] at the start of its epoch;
/// windows are `[epoch, epoch + epochs)`. Site indices out of range are
/// ignored (scenario tables can name sites a small config does not have).
#[derive(Clone, Debug, PartialEq)]
pub enum SignalFault {
    /// The feed keeps reporting its last delivered value (with its
    /// original timestamp) for `epochs` epochs.
    Freeze { site: usize, epochs: usize },
    /// The feed delivers nothing for `epochs` epochs.
    Dropout { site: usize, epochs: usize },
    /// One axis of the feed is multiplied by `factor` (corruption that
    /// *claims* freshness — only the plausibility gates can catch it).
    Spike {
        site: usize,
        axis: usize,
        factor: f64,
        epochs: usize,
    },
    /// The feed delivers the truth from `lag` epochs ago (correctly
    /// timestamped) for `epochs` epochs.
    Lag {
        site: usize,
        lag: usize,
        epochs: usize,
    },
    /// Every feed in a region goes dark for `epochs` epochs.
    RegionBlackout { region: usize, epochs: usize },
}

impl SignalFault {
    /// Short kind tag for scenario listings (`slit scenarios` faults
    /// column).
    pub fn kind(&self) -> &'static str {
        match self {
            SignalFault::Freeze { .. } => "freeze",
            SignalFault::Dropout { .. } => "dropout",
            SignalFault::Spike { .. } => "spike",
            SignalFault::Lag { .. } => "lag",
            SignalFault::RegionBlackout { .. } => "region-blackout",
        }
    }
}

/// Health classification of one site's feed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeedState {
    /// A plausible sample measured this epoch was accepted.
    Fresh,
    /// Last accepted information is from an earlier epoch (no delivery,
    /// or an accepted-but-lagged/frozen sample).
    Stale,
    /// The last delivery failed the plausibility gates; nothing is
    /// trusted until [`RECOVERY_STREAK`] plausible samples arrive.
    Quarantined,
}

impl FeedState {
    pub fn as_str(&self) -> &'static str {
        match self {
            FeedState::Fresh => "fresh",
            FeedState::Stale => "stale",
            FeedState::Quarantined => "quarantined",
        }
    }
}

/// Which rung of the fallback ladder produced a site's robust believed
/// value this epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FallbackSource {
    /// Fresh accepted sample — believed == delivered.
    Live,
    /// Last-known-good still dominates the blend (decay weight ≥ 0.5).
    LastKnownGood,
    /// Diurnal persistence: yesterday's value at the same phase.
    Diurnal,
    /// Per-axis median over currently-fresh sites.
    FleetMedian,
    /// The site's static config prior (ci_base / wi_base / tou_base).
    Prior,
}

impl FallbackSource {
    pub fn as_str(&self) -> &'static str {
        match self {
            FallbackSource::Live => "live",
            FallbackSource::LastKnownGood => "last-known-good",
            FallbackSource::Diurnal => "diurnal",
            FallbackSource::FleetMedian => "fleet-median",
            FallbackSource::Prior => "prior",
        }
    }
}

/// Which believed view a scheduler consumes (mirrors
/// `opt::shift::ShiftPolicy`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SignalPolicy {
    /// Last delivered value verbatim — fault-blind (the default; with
    /// zero faults this is exactly the ground truth).
    #[default]
    Trusting,
    /// The health-gated fallback ladder.
    Robust,
}

impl SignalPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            SignalPolicy::Trusting => "trusting",
            SignalPolicy::Robust => "robust",
        }
    }
}

/// Per-site feed bookkeeping: active fault windows, delivery memory, and
/// health state. All fixed-size — the per-epoch path never allocates.
#[derive(Clone, Debug)]
struct SiteState {
    freeze_until: usize,
    /// Value + measurement epoch the frozen feed keeps replaying.
    frozen: Option<([f64; AXES], usize)>,
    dropout_until: usize,
    lag_until: usize,
    lag: usize,
    spike_until: [usize; AXES],
    spike_factor: [f64; AXES],
    /// Last delivered (possibly corrupt) sample + its measurement epoch.
    last_delivered: Option<([f64; AXES], usize)>,
    /// Last accepted (gate-passing) sample.
    lkg: [f64; AXES],
    has_lkg: bool,
    /// Measurement epoch of the last accepted sample.
    last_measured: Option<usize>,
    /// Epochs between now and the last accepted measurement.
    age: usize,
    /// Consecutive plausible samples while quarantined.
    streak: u32,
    state: FeedState,
    source: FallbackSource,
}

impl SiteState {
    fn new() -> SiteState {
        SiteState {
            freeze_until: 0,
            frozen: None,
            dropout_until: 0,
            lag_until: 0,
            lag: 0,
            spike_until: [0; AXES],
            spike_factor: [1.0; AXES],
            last_delivered: None,
            lkg: [0.0; AXES],
            has_lkg: false,
            last_measured: None,
            age: 0,
            streak: 0,
            state: FeedState::Stale,
            source: FallbackSource::Prior,
        }
    }
}

/// The telemetry layer between ground-truth [`crate::power::GridSignals`]
/// and every consumer. Feed it one epoch of truth via
/// [`SignalFeed::observe`] (faults distort what is *delivered*), then
/// read believed per-site values via [`SignalFeed::view`].
pub struct SignalFeed {
    n: usize,
    regions: Vec<usize>,
    prior: Vec<[f64; AXES]>,
    sites: Vec<SiteState>,
    /// Diurnal persistence rings, `[site * AXES + axis]`, fed only by
    /// fresh accepted samples.
    rings: Vec<DiurnalRing>,
    /// Ground-truth history ring for lag delivery:
    /// `[(epoch % depth) * n * AXES + site * AXES + axis]`.
    truth_ring: Vec<f64>,
    depth: usize,
    naive: [Vec<f64>; AXES],
    robust: [Vec<f64>; AXES],
    /// Per-axis fleet median of fresh sites this epoch (None when no
    /// site is fresh).
    median: [Option<f64>; AXES],
    median_scratch: Vec<f64>,
    faults_injected: usize,
    observed_epochs: usize,
}

impl SignalFeed {
    pub fn new(cfg: &SystemConfig) -> SignalFeed {
        let n = cfg.datacenters.len();
        let epd = epochs_per_day(cfg.physics.epoch_s);
        // lag delivery looks back at most one day (capped so huge epoch
        // counts cannot balloon the ring)
        let depth = epd.clamp(4, 192);
        let prior: Vec<[f64; AXES]> = cfg
            .datacenters
            .iter()
            .map(|d| {
                let mut p = [d.ci_base, d.wi_base, d.tou_base];
                for (a, v) in p.iter_mut().enumerate() {
                    *v = v.clamp(PLAUSIBLE_MIN[a], PLAUSIBLE_MAX[a]);
                }
                p
            })
            .collect();
        let naive_init = |axis: usize| -> Vec<f64> {
            prior.iter().map(|p| p[axis]).collect()
        };
        SignalFeed {
            n,
            regions: cfg.datacenters.iter().map(|d| d.region).collect(),
            sites: (0..n).map(|_| SiteState::new()).collect(),
            rings: (0..n * AXES).map(|_| DiurnalRing::new(epd)).collect(),
            truth_ring: vec![0.0; depth * n * AXES],
            depth,
            naive: [naive_init(0), naive_init(1), naive_init(2)],
            robust: [naive_init(0), naive_init(1), naive_init(2)],
            median: [None; AXES],
            median_scratch: Vec::with_capacity(n),
            faults_injected: 0,
            observed_epochs: 0,
            prior,
        }
    }

    pub fn sites(&self) -> usize {
        self.n
    }

    /// Number of faults injected so far (0 ⇒ both views are bit-identical
    /// to ground truth).
    pub fn faults_injected(&self) -> usize {
        self.faults_injected
    }

    /// Schedule a fault starting at `epoch`. Out-of-range sites/regions
    /// are ignored; spike axes are taken mod [`AXES`].
    pub fn inject(&mut self, epoch: usize, fault: &SignalFault) {
        self.faults_injected += 1;
        match fault {
            SignalFault::Freeze { site, epochs } => {
                if let Some(s) = self.sites.get_mut(*site) {
                    s.freeze_until = s.freeze_until.max(epoch + epochs);
                    if s.frozen.is_none() {
                        s.frozen = s.last_delivered;
                    }
                }
            }
            SignalFault::Dropout { site, epochs } => {
                if let Some(s) = self.sites.get_mut(*site) {
                    s.dropout_until = s.dropout_until.max(epoch + epochs);
                }
            }
            SignalFault::Spike {
                site,
                axis,
                factor,
                epochs,
            } => {
                if let Some(s) = self.sites.get_mut(*site) {
                    let a = axis % AXES;
                    s.spike_until[a] = s.spike_until[a].max(epoch + epochs);
                    s.spike_factor[a] = *factor;
                }
            }
            SignalFault::Lag { site, lag, epochs } => {
                if let Some(s) = self.sites.get_mut(*site) {
                    s.lag_until = s.lag_until.max(epoch + epochs);
                    s.lag = (*lag).min(self.depth - 1);
                }
            }
            SignalFault::RegionBlackout { region, epochs } => {
                for (l, r) in self.regions.iter().enumerate() {
                    if r == region {
                        let s = &mut self.sites[l];
                        s.dropout_until = s.dropout_until.max(epoch + epochs);
                    }
                }
            }
        }
    }

    /// Absorb one epoch of ground truth: faults distort delivery, the
    /// health monitor gates acceptance, and both believed views are
    /// refreshed. Allocation-free once constructed.
    pub fn observe(&mut self, epoch: usize, ci: &[f64], wi: &[f64], tou: &[f64]) {
        // 1. record truth for lag delivery
        let row = (epoch % self.depth) * self.n * AXES;
        for l in 0..self.n {
            self.truth_ring[row + l * AXES + AXIS_CI] = ci[l];
            self.truth_ring[row + l * AXES + AXIS_WUE] = wi[l];
            self.truth_ring[row + l * AXES + AXIS_TOU] = tou[l];
        }

        // 2. per-site delivery + health update
        for l in 0..self.n {
            let truth = [ci[l], wi[l], tou[l]];
            let s = &mut self.sites[l];

            // what does the (possibly faulty) feed deliver this epoch?
            let mut delivered: Option<([f64; AXES], usize)> =
                if epoch < s.dropout_until {
                    None
                } else if epoch < s.freeze_until {
                    if s.frozen.is_none() {
                        // feed froze before its first delivery: it
                        // latches the first truth it measured
                        s.frozen = Some((truth, epoch));
                    }
                    s.frozen
                } else if epoch < s.lag_until {
                    if epoch >= s.lag {
                        let src = epoch - s.lag;
                        let base = (src % self.depth) * self.n * AXES + l * AXES;
                        Some((
                            [
                                self.truth_ring[base + AXIS_CI],
                                self.truth_ring[base + AXIS_WUE],
                                self.truth_ring[base + AXIS_TOU],
                            ],
                            src,
                        ))
                    } else {
                        None // nothing was measured that far back
                    }
                } else {
                    Some((truth, epoch))
                };

            // spikes corrupt whatever is delivered, timestamp untouched
            if let Some((v, _)) = &mut delivered {
                for a in 0..AXES {
                    if epoch < s.spike_until[a] {
                        v[a] *= s.spike_factor[a];
                    }
                }
            }

            match delivered {
                None => {
                    s.age = match s.last_measured {
                        Some(m) => epoch - m,
                        None => epoch + 1,
                    };
                    if s.state != FeedState::Quarantined {
                        s.state = FeedState::Stale;
                    }
                    // a gap breaks any recovery streak
                    s.streak = 0;
                }
                Some((v, measured)) => {
                    s.last_delivered = Some((v, measured));
                    for (a, x) in v.iter().enumerate() {
                        self.naive[a][l] = *x;
                    }
                    let plausible = (0..AXES).all(|a| {
                        let x = v[a];
                        let in_range = x.is_finite()
                            && x >= PLAUSIBLE_MIN[a]
                            && x <= PLAUSIBLE_MAX[a];
                        let step_ok = !s.has_lkg || {
                            let prev = s.lkg[a];
                            (x - prev).abs() <= MAX_STEP_ABS[a]
                                || (x <= prev * MAX_STEP_RATIO
                                    && x * MAX_STEP_RATIO >= prev)
                        };
                        in_range && step_ok
                    });
                    let recovering = s.state == FeedState::Quarantined
                        && s.streak + 1 < RECOVERY_STREAK;
                    if !plausible {
                        s.state = FeedState::Quarantined;
                        s.streak = 0;
                        s.age = match s.last_measured {
                            Some(m) => epoch - m,
                            None => epoch + 1,
                        };
                    } else if recovering {
                        s.streak += 1;
                        s.age = match s.last_measured {
                            Some(m) => epoch - m,
                            None => epoch + 1,
                        };
                    } else {
                        // accept
                        s.streak = 0;
                        s.lkg = v;
                        s.has_lkg = true;
                        s.last_measured = Some(measured);
                        s.age = epoch - measured;
                        s.state = if s.age == 0 {
                            FeedState::Fresh
                        } else {
                            FeedState::Stale
                        };
                        if s.age == 0 {
                            for (a, x) in v.iter().enumerate() {
                                self.rings[l * AXES + a].observe(epoch, *x);
                            }
                        }
                    }
                }
            }
        }

        // 3. per-axis fleet median over fresh sites (anchor rung 2)
        for a in 0..AXES {
            self.median_scratch.clear();
            for s in &self.sites {
                if s.state == FeedState::Fresh {
                    self.median_scratch.push(s.lkg[a]);
                }
            }
            self.median_scratch.sort_unstable_by(|x, y| x.total_cmp(y));
            self.median[a] = if self.median_scratch.is_empty() {
                None
            } else {
                Some(self.median_scratch[(self.median_scratch.len() - 1) / 2])
            };
        }

        // 4. resolve the robust view through the fallback ladder
        for l in 0..self.n {
            let s = &mut self.sites[l];
            let w = if s.has_lkg {
                LKG_DECAY.powi(s.age.min(MAX_DECAY_AGE) as i32)
            } else {
                0.0
            };
            let mut anchor_src = FallbackSource::Prior;
            for a in 0..AXES {
                let (anchor, src) = match self.rings[l * AXES + a]
                    .at_phase(epoch)
                {
                    Some(d) => (d, FallbackSource::Diurnal),
                    None => match self.median[a] {
                        Some(m) => (m, FallbackSource::FleetMedian),
                        None => (self.prior[l][a], FallbackSource::Prior),
                    },
                };
                if a == AXIS_CI {
                    anchor_src = src;
                }
                let mut v = w * s.lkg[a] + (1.0 - w) * anchor;
                if !(v >= PLAUSIBLE_MIN[a]) {
                    v = PLAUSIBLE_MIN[a];
                } else if v > PLAUSIBLE_MAX[a] {
                    v = PLAUSIBLE_MAX[a];
                }
                self.robust[a][l] = v;
            }
            s.source = if s.state == FeedState::Fresh {
                FallbackSource::Live
            } else if w >= 0.5 {
                FallbackSource::LastKnownGood
            } else {
                anchor_src
            };
        }
        self.observed_epochs = self.observed_epochs.max(epoch + 1);
    }

    /// The believed per-site panels for a policy: `(ci, wi, tou)` slices
    /// of length [`SignalFeed::sites`].
    pub fn view(&self, policy: SignalPolicy) -> (&[f64], &[f64], &[f64]) {
        let v = match policy {
            SignalPolicy::Trusting => &self.naive,
            SignalPolicy::Robust => &self.robust,
        };
        (&v[AXIS_CI], &v[AXIS_WUE], &v[AXIS_TOU])
    }

    pub fn site_state(&self, l: usize) -> FeedState {
        self.sites[l].state
    }

    /// Epochs since the site's last accepted measurement.
    pub fn site_age(&self, l: usize) -> usize {
        self.sites[l].age
    }

    /// Ladder rung that produced the site's robust value this epoch.
    pub fn site_source(&self, l: usize) -> FallbackSource {
        self.sites[l].source
    }

    /// `(fresh, stale, quarantined)` site counts this epoch.
    pub fn health_counts(&self) -> (usize, usize, usize) {
        let mut c = (0usize, 0usize, 0usize);
        for s in &self.sites {
            match s.state {
                FeedState::Fresh => c.0 += 1,
                FeedState::Stale => c.1 += 1,
                FeedState::Quarantined => c.2 += 1,
            }
        }
        c
    }

    /// Sum over sites of |believed − truth| per axis for one view.
    pub fn divergence(
        &self,
        policy: SignalPolicy,
        ci: &[f64],
        wi: &[f64],
        tou: &[f64],
    ) -> [f64; AXES] {
        let (bci, bwi, btou) = self.view(policy);
        let mut d = [0.0; AXES];
        for l in 0..self.n {
            d[AXIS_CI] += (bci[l] - ci[l]).abs();
            d[AXIS_WUE] += (bwi[l] - wi[l]).abs();
            d[AXIS_TOU] += (btou[l] - tou[l]).abs();
        }
        d
    }
}

/// Signal-robustness wrapper around any inner spatial scheduler: plans
/// are delegated untouched; the only difference is the
/// [`SignalPolicy::Robust`] believed view the session resolves panels
/// through (the `slit-robust` registry row wraps `slit-carbon`).
pub struct RobustScheduler {
    inner: Box<dyn Scheduler>,
    name: Option<String>,
}

impl RobustScheduler {
    pub fn new(inner: Box<dyn Scheduler>) -> RobustScheduler {
        RobustScheduler { inner, name: None }
    }

    /// Override the derived `robust+<inner>` name (registry rows carry
    /// their spec name).
    pub fn named(mut self, name: &str) -> RobustScheduler {
        self.name = Some(name.into());
        self
    }
}

impl Scheduler for RobustScheduler {
    fn name(&self) -> String {
        self.name
            .clone()
            .unwrap_or_else(|| format!("robust+{}", self.inner.name()))
    }

    fn unused_pr(&self, phys: &crate::config::PhysicsConfig) -> f64 {
        self.inner.unused_pr(phys)
    }

    fn plan(&mut self, ctx: &EpochContext) -> crate::plan::Plan {
        self.inner.plan(ctx)
    }

    fn shift_policy(&self) -> crate::opt::shift::ShiftPolicy {
        self.inner.shift_policy()
    }

    fn signal_policy(&self) -> SignalPolicy {
        SignalPolicy::Robust
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::GridSignals;

    fn world(epochs: usize, seed: u64) -> (SystemConfig, GridSignals) {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = epochs;
        let signals = GridSignals::generate(&cfg, epochs, seed);
        (cfg, signals)
    }

    fn drive(feed: &mut SignalFeed, signals: &GridSignals, epoch: usize) {
        let (ci, wi, tou) = signals.at(epoch);
        feed.observe(epoch, &ci, &wi, &tou);
    }

    #[test]
    fn no_faults_both_views_are_bitwise_truth() {
        let (cfg, signals) = world(16, 3);
        let mut feed = SignalFeed::new(&cfg);
        for t in 0..16 {
            let (ci, wi, tou) = signals.at(t);
            feed.observe(t, &ci, &wi, &tou);
            for policy in [SignalPolicy::Trusting, SignalPolicy::Robust] {
                let (bci, bwi, btou) = feed.view(policy);
                for l in 0..feed.sites() {
                    assert_eq!(bci[l].to_bits(), ci[l].to_bits());
                    assert_eq!(bwi[l].to_bits(), wi[l].to_bits());
                    assert_eq!(btou[l].to_bits(), tou[l].to_bits());
                }
            }
            assert_eq!(feed.health_counts(), (feed.sites(), 0, 0));
            assert_eq!(
                feed.divergence(SignalPolicy::Robust, &ci, &wi, &tou),
                [0.0; AXES]
            );
        }
        assert_eq!(feed.faults_injected(), 0);
    }

    #[test]
    fn freeze_replays_the_pre_freeze_value_and_goes_stale() {
        let (cfg, signals) = world(12, 7);
        let mut feed = SignalFeed::new(&cfg);
        drive(&mut feed, &signals, 0);
        drive(&mut feed, &signals, 1);
        let (ci1, _, _) = signals.at(1);
        feed.inject(2, &SignalFault::Freeze { site: 0, epochs: 6 });
        for t in 2..8 {
            drive(&mut feed, &signals, t);
            let (nci, _, _) = feed.view(SignalPolicy::Trusting);
            assert_eq!(nci[0].to_bits(), ci1[0].to_bits(), "epoch {t}");
            assert_eq!(feed.site_state(0), FeedState::Stale);
            assert_eq!(feed.site_age(0), t - 1, "staleness clock");
        }
        // thaw: the next epoch is fresh again (the small post-freeze step
        // passes the rate gate on these smooth signals)
        drive(&mut feed, &signals, 8);
        assert_eq!(feed.site_state(0), FeedState::Fresh);
        assert_eq!(feed.site_age(0), 0);
    }

    #[test]
    fn dropout_decays_belief_toward_anchor_and_stays_in_bounds() {
        let (cfg, signals) = world(24, 11);
        let mut feed = SignalFeed::new(&cfg);
        drive(&mut feed, &signals, 0);
        feed.inject(1, &SignalFault::Dropout { site: 2, epochs: 20 });
        for t in 1..21 {
            drive(&mut feed, &signals, t);
            let (bci, bwi, btou) = feed.view(SignalPolicy::Robust);
            assert!(bci[2].is_finite() && bwi[2].is_finite());
            assert!(bci[2] >= PLAUSIBLE_MIN[AXIS_CI]);
            assert!(btou[2] <= PLAUSIBLE_MAX[AXIS_TOU]);
            assert_ne!(feed.site_state(2), FeedState::Fresh);
            assert_eq!(feed.site_age(2), t, "staleness clock keeps ticking");
        }
        assert_ne!(
            feed.site_source(2),
            FallbackSource::Live,
            "20 dark epochs cannot be live"
        );
    }

    #[test]
    fn huge_spike_quarantines_then_recovers_after_streak() {
        let (cfg, signals) = world(12, 5);
        let mut feed = SignalFeed::new(&cfg);
        drive(&mut feed, &signals, 0);
        feed.inject(
            1,
            &SignalFault::Spike {
                site: 1,
                axis: AXIS_CI,
                factor: 50.0,
                epochs: 3,
            },
        );
        for t in 1..4 {
            drive(&mut feed, &signals, t);
            assert_eq!(feed.site_state(1), FeedState::Quarantined, "epoch {t}");
            // the robust view never swallows the corrupt value
            let (bci, _, _) = feed.view(SignalPolicy::Robust);
            assert!(bci[1] <= PLAUSIBLE_MAX[AXIS_CI]);
        }
        // spike over: RECOVERY_STREAK plausible epochs restore Fresh
        drive(&mut feed, &signals, 4);
        assert_eq!(feed.site_state(1), FeedState::Quarantined);
        drive(&mut feed, &signals, 5);
        assert_eq!(feed.site_state(1), FeedState::Fresh);
        assert_eq!(feed.site_source(1), FallbackSource::Live);
        // but the naive view swallowed it whole while it lasted
        feed.inject(
            6,
            &SignalFault::Spike {
                site: 1,
                axis: AXIS_CI,
                factor: 50.0,
                epochs: 1,
            },
        );
        let (ci6, wi6, tou6) = signals.at(6);
        feed.observe(6, &ci6, &wi6, &tou6);
        let (nci, _, _) = feed.view(SignalPolicy::Trusting);
        assert_eq!(nci[1].to_bits(), (ci6[1] * 50.0).to_bits());
    }

    #[test]
    fn lag_delivers_old_truth_with_honest_timestamp() {
        let (cfg, signals) = world(12, 9);
        let mut feed = SignalFeed::new(&cfg);
        for t in 0..4 {
            drive(&mut feed, &signals, t);
        }
        feed.inject(
            4,
            &SignalFault::Lag {
                site: 3,
                lag: 2,
                epochs: 4,
            },
        );
        for t in 4..8 {
            drive(&mut feed, &signals, t);
            let (lag_ci, _, _) = signals.at(t - 2);
            let (nci, _, _) = feed.view(SignalPolicy::Trusting);
            assert_eq!(nci[3].to_bits(), lag_ci[3].to_bits(), "epoch {t}");
            assert_eq!(feed.site_state(3), FeedState::Stale);
            assert_eq!(feed.site_age(3), 2);
        }
    }

    #[test]
    fn region_blackout_darkens_every_site_in_the_region() {
        let (cfg, signals) = world(8, 13);
        let mut feed = SignalFeed::new(&cfg);
        drive(&mut feed, &signals, 0);
        feed.inject(1, &SignalFault::RegionBlackout { region: 2, epochs: 4 });
        drive(&mut feed, &signals, 1);
        for (l, d) in cfg.datacenters.iter().enumerate() {
            if d.region == 2 {
                assert_ne!(feed.site_state(l), FeedState::Fresh, "{}", d.name);
            } else {
                assert_eq!(feed.site_state(l), FeedState::Fresh, "{}", d.name);
            }
        }
    }

    #[test]
    fn out_of_range_sites_are_ignored() {
        let (cfg, signals) = world(4, 1);
        let mut feed = SignalFeed::new(&cfg);
        feed.inject(0, &SignalFault::Freeze { site: 999, epochs: 4 });
        feed.inject(0, &SignalFault::RegionBlackout { region: 99, epochs: 4 });
        drive(&mut feed, &signals, 0);
        assert_eq!(feed.health_counts().0, feed.sites());
        assert_eq!(feed.faults_injected(), 2);
    }

    #[test]
    fn robust_wrapper_delegates_and_flips_the_policy() {
        struct Probe;
        impl Scheduler for Probe {
            fn name(&self) -> String {
                "probe".into()
            }
            fn plan(&mut self, ctx: &EpochContext) -> crate::plan::Plan {
                crate::plan::Plan::uniform(
                    ctx.cfg.num_classes(),
                    ctx.cfg.datacenters.len(),
                )
            }
        }
        assert_eq!(Probe.signal_policy(), SignalPolicy::Trusting);
        let s = RobustScheduler::new(Box::new(Probe));
        assert_eq!(s.signal_policy(), SignalPolicy::Robust);
        assert_eq!(s.name(), "robust+probe");
        let named = RobustScheduler::new(Box::new(Probe)).named("slit-robust");
        assert_eq!(named.name(), "slit-robust");
    }
}
