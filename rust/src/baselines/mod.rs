//! Comparison frameworks from §6 plus a naive round-robin comparator,
//! and the min-cost max-flow substrate Helix builds on.

pub mod helix;
pub mod mcmf;
pub mod splitwise;

pub use helix::HelixScheduler;
pub use splitwise::SplitwiseScheduler;

use crate::config::PhysicsConfig;
use crate::plan::Plan;
use crate::sim::{EpochContext, Scheduler};

/// Naive geo-round-robin: even split across all sites, always warm.
/// Not in the paper's comparison set, but a useful sanity floor.
pub struct RoundRobinScheduler;

impl Scheduler for RoundRobinScheduler {
    fn name(&self) -> String {
        "round-robin".into()
    }

    fn unused_pr(&self, phys: &PhysicsConfig) -> f64 {
        phys.pr_idle
    }

    fn plan(&mut self, ctx: &EpochContext) -> Plan {
        Plan::uniform(ctx.cfg.num_classes(), ctx.cfg.datacenters.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::power::GridSignals;
    use crate::sim::simulate;
    use crate::trace::Trace;

    #[test]
    fn round_robin_simulates() {
        let cfg = SystemConfig::small_test();
        let trace = Trace::generate(&cfg, cfg.epochs, 1);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 1);
        let res = simulate(&cfg, &trace, &signals, &mut RoundRobinScheduler, 1);
        assert!(res.total.requests > 0.0);
        assert_eq!(res.name, "round-robin");
    }
}
