//! Splitwise baseline [17]: queue-based scheduling with prefill/decode
//! phase splitting.
//!
//! The published system routes each request to separate prefill and decode
//! machine pools (prefill on the fastest hardware, decode on the
//! power-efficient pool) and keeps both pools warm for latency. At the
//! epoch-plan granularity this becomes: per class, greedily fill sites in
//! latency order (join-shortest-queue against both pools' remaining
//! capacity), with H100 types as the prefill pool and A100 types as the
//! decode pool. It is TTFT-excellent and sustainability-blind
//! (always-warm, Fig. 4/5's shape).

use crate::cluster::can_serve;
use crate::config::{PhysicsConfig, MODELS};
use crate::plan::Plan;
use crate::sim::{EpochContext, Scheduler};

pub struct SplitwiseScheduler;

/// Node-type pool split: A100 types = decode pool, H100 types = prefill.
fn is_prefill_type(name: &str) -> bool {
    name.starts_with("h100")
}

impl Scheduler for SplitwiseScheduler {
    fn name(&self) -> String {
        "splitwise".into()
    }

    // Both pools stay warm — that's the design's latency play.
    fn unused_pr(&self, phys: &PhysicsConfig) -> f64 {
        phys.pr_idle
    }

    fn plan(&mut self, ctx: &EpochContext) -> Plan {
        let cfg = ctx.cfg;
        let ev = ctx.evaluator;
        let k_n = ev.classes();
        let l_n = ev.dcs();
        let cp = &ev.cp;
        let epoch_s = cfg.physics.epoch_s;

        // remaining pool capacity per site, node-seconds — from the LIVE
        // cluster state, so mid-run outages/brownouts shrink the pools
        let mut prefill_cap = vec![0.0f64; l_n];
        let mut decode_cap = vec![0.0f64; l_n];
        for l in 0..l_n {
            let live = ctx.cluster.nodes(l);
            for (ti, nt) in cfg.node_types.iter().enumerate() {
                let budget = live[ti] as f64 * epoch_s;
                if is_prefill_type(&nt.name) {
                    prefill_cap[l] += budget;
                } else {
                    decode_cap[l] += budget;
                }
            }
        }

        // mean pool throughputs per model (tokens/s per node-second is just
        // tokens/s; capacity bookkeeping is node-seconds)
        let mut prefill_thr = [0.0f64; MODELS];
        let mut decode_thr = [0.0f64; MODELS];
        let mut pn = 0.0f64;
        let mut dn = 0.0f64;
        for nt in &cfg.node_types {
            for m in 0..MODELS {
                if is_prefill_type(&nt.name) {
                    prefill_thr[m] += nt.thr_tokens_s[m];
                } else {
                    decode_thr[m] += nt.thr_tokens_s[m];
                }
            }
            if is_prefill_type(&nt.name) {
                pn += 1.0;
            } else {
                dn += 1.0;
            }
        }
        for m in 0..MODELS {
            prefill_thr[m] /= pn.max(1.0);
            decode_thr[m] /= dn.max(1.0);
        }

        // process classes largest-first (queue pressure first)
        let mut order: Vec<usize> = (0..k_n).collect();
        order.sort_by(|&a, &b| {
            cp.n_req[b].partial_cmp(&cp.n_req[a]).unwrap()
        });

        let mut plan = Plan::uniform(k_n, l_n);
        for k in order {
            let m = k % MODELS;
            let model_spec = &cfg.models[m];
            // site order: latency proxy (hops + proc), i.e. the queue-based
            // scheduler's greedy preference
            let mut sites: Vec<usize> = (0..l_n)
                .filter(|&l| {
                    cfg.node_types
                        .iter()
                        .any(|nt| can_serve(nt, model_spec.param_mem_gb))
                        && (prefill_cap[l] > 0.0 || decode_cap[l] > 0.0)
                })
                .collect();
            sites.sort_by(|&a, &b| {
                let la = cp.hops[k * l_n + a] + 50.0 * cp.proc[k * l_n + a];
                let lb = cp.hops[k * l_n + b] + 50.0 * cp.proc[k * l_n + b];
                la.partial_cmp(&lb).unwrap()
            });

            let mut remaining = cp.n_req[k];
            let mut assigned = vec![0.0f64; l_n];
            // per-request pool demand (node-seconds)
            let tok_in = ctx.predicted.classes[k].tok_in.max(1.0);
            let prefill_s = tok_in / prefill_thr[m].max(1e-9);
            let decode_s = cp.tok_out[k] / decode_thr[m].max(1e-9);
            for &l in &sites {
                if remaining <= 0.0 {
                    break;
                }
                // JSQ: how many requests fit in the tighter pool
                let fit_prefill = prefill_cap[l] / prefill_s.max(1e-9);
                let fit_decode = decode_cap[l] / decode_s.max(1e-9);
                let fit = fit_prefill.min(fit_decode).max(0.0);
                let take = remaining.min(fit);
                if take <= 0.0 {
                    continue;
                }
                assigned[l] = take;
                prefill_cap[l] -= take * prefill_s;
                decode_cap[l] -= take * decode_s;
                remaining -= take;
            }
            if remaining > 0.0 && !sites.is_empty() {
                // overloaded: queue the residue on the nearest site
                assigned[sites[0]] += remaining;
            }
            let total: f64 = assigned.iter().sum();
            for l in 0..l_n {
                plan.set(
                    k,
                    l,
                    if total > 0.0 {
                        assigned[l] / total
                    } else {
                        0.0
                    },
                );
            }
        }
        plan.normalize();
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::build_panels;
    use crate::config::SystemConfig;
    use crate::eval::{AnalyticEvaluator, EvalConsts};
    use crate::power::GridSignals;
    use crate::trace::Trace;

    fn plan_for(cfg: &SystemConfig, seed: u64) -> (Plan, AnalyticEvaluator) {
        let trace = Trace::generate(cfg, 4, seed);
        let signals = GridSignals::generate(cfg, 4, seed);
        let (cp, dp) = build_panels(
            cfg,
            &signals,
            1,
            &trace.epochs[1],
            cfg.physics.pr_idle,
        );
        let ev = AnalyticEvaluator::new(
            cp,
            dp,
            EvalConsts::from_physics(&cfg.physics),
        );
        let predicted = trace.epochs[1].clone();
        let cluster = crate::cluster::ClusterState::from_config(cfg);
        let ctx = EpochContext {
            cfg,
            epoch: 1,
            predicted: &predicted,
            evaluator: &ev,
            cluster: &cluster,
            prev: None,
        };
        (SplitwiseScheduler.plan(&ctx), ev)
    }

    #[test]
    fn valid_plan_and_latency_greedy() {
        let cfg = SystemConfig::paper_default();
        let (plan, ev) = plan_for(&cfg, 1);
        assert!(plan.is_valid());
        let l_n = ev.dcs();
        // the dominant site per class is within the origin's low-hop set
        for k in 0..ev.classes() {
            if ev.cp.n_req[k] <= 0.0 {
                continue;
            }
            let best_l = (0..l_n)
                .max_by(|&a, &b| {
                    plan.get(k, a).partial_cmp(&plan.get(k, b)).unwrap()
                })
                .unwrap();
            let min_hops = (0..l_n)
                .map(|l| ev.cp.hops[k * l_n + l])
                .fold(f64::INFINITY, f64::min);
            assert!(ev.cp.hops[k * l_n + best_l] <= min_hops + 4.0);
        }
    }

    #[test]
    fn splits_under_capacity_pressure() {
        let mut cfg = SystemConfig::paper_default();
        for d in &mut cfg.datacenters {
            d.nodes_per_type = vec![2, 2, 2, 2, 2, 2];
        }
        cfg.workload.base_requests_per_epoch = 50_000.0;
        let (plan, ev) = plan_for(&cfg, 2);
        assert!(plan.is_valid());
        let spread = (0..ev.classes()).any(|k| {
            (0..ev.dcs()).filter(|&l| plan.get(k, l) > 0.05).count() > 1
        });
        assert!(spread);
    }

    #[test]
    fn dark_region_receives_no_assignment() {
        use crate::cluster::{ClusterAction, ClusterState};
        let cfg = SystemConfig::paper_default();
        let trace = Trace::generate(&cfg, 4, 5);
        let signals = GridSignals::generate(&cfg, 4, 5);
        let mut cluster = ClusterState::from_config(&cfg);
        cluster.apply(&ClusterAction::ScaleRegion {
            region: 2,
            frac: 0.0,
        });
        let (cp, dp) = crate::cluster::build_panels_dyn(
            &cfg,
            &cluster,
            &signals,
            1,
            &trace.epochs[1],
            cfg.physics.pr_idle,
        );
        let ev = AnalyticEvaluator::new(
            cp,
            dp,
            EvalConsts::from_physics(&cfg.physics),
        );
        let predicted = trace.epochs[1].clone();
        let ctx = EpochContext {
            cfg: &cfg,
            epoch: 1,
            predicted: &predicted,
            evaluator: &ev,
            cluster: &cluster,
            prev: None,
        };
        let plan = SplitwiseScheduler.plan(&ctx);
        assert!(plan.is_valid());
        for k in 0..ev.classes() {
            for (l, d) in cfg.datacenters.iter().enumerate() {
                if d.region == 2 {
                    assert!(
                        plan.get(k, l) < 1e-9,
                        "class {k} routed to dark {}",
                        d.name
                    );
                }
            }
        }
    }

    #[test]
    fn always_warm_power_policy() {
        let cfg = SystemConfig::paper_default();
        let s = SplitwiseScheduler;
        assert_eq!(s.unused_pr(&cfg.physics), cfg.physics.pr_idle);
    }
}
