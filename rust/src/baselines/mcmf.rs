//! Min-cost max-flow substrate (successive shortest augmenting paths with
//! Johnson potentials / Bellman-Ford initialisation).
//!
//! Built for the Helix baseline [16]: Helix formulates LLM serving
//! assignment as max-flow over heterogeneous GPUs; the integral LP it
//! solves is equivalent to MCMF on our aggregated epoch graph (DESIGN.md
//! §3 substitutions). Costs and capacities are i64.

#[derive(Clone, Debug)]
struct Edge {
    to: usize,
    cap: i64,
    cost: i64,
    flow: i64,
}

/// Directed flow network with parallel-edge support.
#[derive(Clone, Debug, Default)]
pub struct FlowNetwork {
    edges: Vec<Edge>,
    /// adjacency: node -> edge indices (even = forward, odd = residual)
    adj: Vec<Vec<usize>>,
}

impl FlowNetwork {
    pub fn new(nodes: usize) -> Self {
        FlowNetwork {
            edges: Vec::new(),
            adj: vec![Vec::new(); nodes],
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.adj.len()
    }

    pub fn add_node(&mut self) -> usize {
        self.adj.push(Vec::new());
        self.adj.len() - 1
    }

    /// Add edge u -> v; returns an id usable with [`FlowNetwork::flow_on`].
    pub fn add_edge(&mut self, u: usize, v: usize, cap: i64, cost: i64) -> usize {
        assert!(u < self.adj.len() && v < self.adj.len());
        assert!(cap >= 0, "negative capacity");
        let id = self.edges.len();
        self.edges.push(Edge {
            to: v,
            cap,
            cost,
            flow: 0,
        });
        self.adj[u].push(id);
        self.edges.push(Edge {
            to: u,
            cap: 0,
            cost: -cost,
            flow: 0,
        });
        self.adj[v].push(id + 1);
        id
    }

    /// Flow currently on a forward edge id.
    pub fn flow_on(&self, id: usize) -> i64 {
        self.edges[id].flow
    }

    /// Run min-cost max-flow from `s` to `t`. Returns (total_flow, total_cost).
    ///
    /// Successive shortest paths with potentials; Bellman-Ford bootstraps
    /// potentials so negative edge costs are allowed (not negative cycles).
    pub fn min_cost_max_flow(&mut self, s: usize, t: usize) -> (i64, i64) {
        let n = self.adj.len();
        let inf = i64::MAX / 4;

        // Bellman-Ford initial potentials
        let mut pot = vec![inf; n];
        pot[s] = 0;
        for _ in 0..n {
            let mut changed = false;
            for u in 0..n {
                if pot[u] == inf {
                    continue;
                }
                for &eid in &self.adj[u] {
                    let e = &self.edges[eid];
                    if e.cap - e.flow > 0 && pot[u] + e.cost < pot[e.to] {
                        pot[e.to] = pot[u] + e.cost;
                        changed = true;
                    }
                }
            }
            if !changed {
                break;
            }
        }

        let mut total_flow = 0i64;
        let mut total_cost = 0i64;
        loop {
            // Dijkstra on reduced costs
            let mut dist = vec![inf; n];
            let mut prev_edge = vec![usize::MAX; n];
            dist[s] = 0;
            let mut heap = std::collections::BinaryHeap::new();
            heap.push(std::cmp::Reverse((0i64, s)));
            while let Some(std::cmp::Reverse((d, u))) = heap.pop() {
                if d > dist[u] {
                    continue;
                }
                for &eid in &self.adj[u] {
                    let e = &self.edges[eid];
                    if e.cap - e.flow <= 0 || pot[u] == inf || pot[e.to] == inf
                    {
                        continue;
                    }
                    let nd = d + e.cost + pot[u] - pot[e.to];
                    if nd < dist[e.to] {
                        dist[e.to] = nd;
                        prev_edge[e.to] = eid;
                        heap.push(std::cmp::Reverse((nd, e.to)));
                    }
                }
            }
            if dist[t] == inf {
                break;
            }
            for u in 0..n {
                if dist[u] < inf {
                    pot[u] = pot[u].saturating_add(dist[u]);
                }
            }
            // bottleneck along the path
            let mut push = inf;
            let mut v = t;
            while v != s {
                let eid = prev_edge[v];
                let e = &self.edges[eid];
                push = push.min(e.cap - e.flow);
                v = self.edges[eid ^ 1].to;
            }
            // apply
            let mut v = t;
            while v != s {
                let eid = prev_edge[v];
                self.edges[eid].flow += push;
                self.edges[eid ^ 1].flow -= push;
                total_cost += push * self.edges[eid].cost;
                v = self.edges[eid ^ 1].to;
            }
            total_flow += push;
        }
        (total_flow, total_cost)
    }

    /// Check flow conservation at every node except s and t (tests).
    pub fn conserves_flow(&self, s: usize, t: usize) -> bool {
        let n = self.adj.len();
        let mut net = vec![0i64; n];
        for (id, e) in self.edges.iter().enumerate() {
            if id % 2 == 0 {
                // forward edge: from edges[id^1].to to e.to
                let from = self.edges[id ^ 1].to;
                net[from] -= e.flow;
                net[e.to] += e.flow;
            }
        }
        (0..n).all(|u| u == s || u == t || net[u] == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propkit;
    use crate::util::rng::Rng;

    #[test]
    fn simple_path() {
        let mut g = FlowNetwork::new(3);
        g.add_edge(0, 1, 5, 1);
        g.add_edge(1, 2, 3, 1);
        let (f, c) = g.min_cost_max_flow(0, 2);
        assert_eq!(f, 3);
        assert_eq!(c, 6);
    }

    #[test]
    fn picks_cheaper_path_first() {
        // two parallel routes: cheap cap 2, expensive cap 10
        let mut g = FlowNetwork::new(4);
        g.add_edge(0, 1, 2, 1);
        g.add_edge(1, 3, 2, 1);
        g.add_edge(0, 2, 10, 5);
        g.add_edge(2, 3, 10, 5);
        let (f, c) = g.min_cost_max_flow(0, 3);
        assert_eq!(f, 12);
        assert_eq!(c, 2 * 2 + 10 * 10);
    }

    #[test]
    fn respects_bottleneck() {
        let mut g = FlowNetwork::new(4);
        g.add_edge(0, 1, 100, 0);
        g.add_edge(1, 2, 7, 0);
        g.add_edge(2, 3, 100, 0);
        let (f, _) = g.min_cost_max_flow(0, 3);
        assert_eq!(f, 7);
    }

    #[test]
    fn handles_negative_costs() {
        let mut g = FlowNetwork::new(3);
        g.add_edge(0, 1, 4, -2);
        g.add_edge(1, 2, 4, 3);
        let (f, c) = g.min_cost_max_flow(0, 2);
        assert_eq!(f, 4);
        assert_eq!(c, 4);
    }

    #[test]
    fn classic_mcmf_instance() {
        // CLRS-style: check against hand-computed optimum
        let mut g = FlowNetwork::new(5);
        g.add_edge(0, 1, 10, 2);
        g.add_edge(0, 2, 8, 4);
        g.add_edge(1, 2, 5, 1);
        g.add_edge(1, 3, 8, 6);
        g.add_edge(2, 4, 10, 3);
        g.add_edge(3, 4, 10, 2);
        let (f, c) = g.min_cost_max_flow(0, 4);
        assert_eq!(f, 18);
        // min cost for max flow 18:
        // 0->1 10 (cost 20); 1->2 5 (5); 1->3 5 (30); 3->4 5 (10);
        // 0->2 8 (32); 2->4 10 (30) => wait 2 receives 13, cap 10 out.
        // solver cost must conserve flow; just sanity-bound it
        assert!(g.conserves_flow(0, 4));
        assert!(c > 0);
    }

    #[test]
    fn conservation_property_random_graphs() {
        propkit::check(
            "mcmf-conservation",
            0xF1,
            40,
            |r: &mut Rng| {
                let n = 6 + r.below(6);
                let mut g = FlowNetwork::new(n);
                let m = 8 + r.below(20);
                for _ in 0..m {
                    let u = r.below(n - 1);
                    let v = 1 + r.below(n - 1);
                    if u != v {
                        g.add_edge(u, v, r.int(0, 20), r.int(0, 9));
                    }
                }
                (g, n)
            },
            |(g, n)| {
                let mut g = g.clone();
                let (f, _) = g.min_cost_max_flow(0, n - 1);
                if f < 0 {
                    return Err("negative flow".into());
                }
                if !g.conserves_flow(0, n - 1) {
                    return Err("conservation violated".into());
                }
                Ok(())
            },
        );
    }

    /// Exhaustive reference: enumerate every integral flow assignment on
    /// a tiny edge list (s = 0, t = n-1), keep the conservation-feasible
    /// ones, and return max flow with min cost among max flows — the
    /// exact objective `min_cost_max_flow` claims to optimise.
    fn brute_force(n: usize, edges: &[(usize, usize, i64, i64)]) -> (i64, i64) {
        let m = edges.len();
        let mut f = vec![0i64; m];
        let mut best = (0i64, 0i64);
        loop {
            let mut net = vec![0i64; n];
            let mut cost = 0i64;
            for (i, &(u, v, _, c)) in edges.iter().enumerate() {
                net[u] -= f[i];
                net[v] += f[i];
                cost += f[i] * c;
            }
            if (0..n).all(|u| u == 0 || u == n - 1 || net[u] == 0) {
                let flow = net[n - 1];
                if flow > best.0 || (flow == best.0 && cost < best.1) {
                    best = (flow, cost);
                }
            }
            // odometer over per-edge flows 0..=cap
            let mut i = 0;
            while i < m {
                f[i] += 1;
                if f[i] <= edges[i].2 {
                    break;
                }
                f[i] = 0;
                i += 1;
            }
            if i == m {
                return best;
            }
        }
    }

    #[test]
    fn parity_with_bruteforce_on_tiny_graphs() {
        // random tiny DAGs (u < v, so negative costs cannot form negative
        // cycles — the solver's stated precondition), caps small enough
        // that full enumeration is the ground truth
        propkit::check(
            "mcmf-bruteforce-parity",
            0xB0F,
            60,
            |r: &mut Rng| {
                let n = 3 + r.below(3);
                let m = 3 + r.below(4);
                let edges: Vec<(usize, usize, i64, i64)> = (0..m)
                    .map(|_| {
                        let u = r.below(n - 1);
                        let v = u + 1 + r.below(n - 1 - u);
                        (u, v, r.int(0, 2), r.int(-3, 3))
                    })
                    .collect();
                (n, edges)
            },
            |(n, edges)| {
                let mut g = FlowNetwork::new(*n);
                for &(u, v, cap, cost) in edges {
                    g.add_edge(u, v, cap, cost);
                }
                let got = g.min_cost_max_flow(0, n - 1);
                let want = brute_force(*n, edges);
                if got != want {
                    return Err(format!(
                        "solver {got:?} vs brute force {want:?} on {edges:?}"
                    ));
                }
                if !g.conserves_flow(0, *n - 1) {
                    return Err("conservation violated".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn parallel_edges_fill_cheapest_first() {
        // two same-endpoint edges must be tracked independently: the
        // cheap one saturates, the dear one carries only the remainder
        let mut g = FlowNetwork::new(3);
        let cheap = g.add_edge(0, 1, 3, 1);
        let dear = g.add_edge(0, 1, 3, 5);
        let out = g.add_edge(1, 2, 4, 0);
        let (f, c) = g.min_cost_max_flow(0, 2);
        assert_eq!(f, 4);
        assert_eq!(c, 3 * 1 + 1 * 5);
        assert_eq!(g.flow_on(cheap), 3);
        assert_eq!(g.flow_on(dear), 1);
        assert_eq!(g.flow_on(out), 4);
    }

    #[test]
    fn potentials_stay_correct_across_negative_cost_augmentations() {
        // three augmenting rounds over a graph whose cheapest paths ride
        // a negative edge: the Dijkstra rounds after the first are only
        // correct if the Johnson potentials absorbed the Bellman-Ford
        // negative-edge initialisation and each round's distance update.
        // Max flow 3 is forced (source cut), and so is its routing:
        // 0→1→3, 0→1→2→3, 0→2→3 => cost (−2+3)+(−2+1+1)+(4+1) = 6.
        let mut g = FlowNetwork::new(4);
        g.add_edge(0, 1, 2, -2);
        g.add_edge(1, 3, 1, 3);
        g.add_edge(1, 2, 1, 1);
        g.add_edge(2, 3, 2, 1);
        g.add_edge(0, 2, 1, 4);
        let (f, c) = g.min_cost_max_flow(0, 3);
        assert_eq!(f, 3);
        assert_eq!(c, 6);
        assert!(g.conserves_flow(0, 3));
    }

    #[test]
    fn flow_on_ids_are_stable_across_add_node_and_solve() {
        // forward ids are even and assigned in insertion order, residual
        // twins at id+1 — interleaving add_node must not disturb either,
        // and a solve must leave ids addressing the same edges
        let mut g = FlowNetwork::new(2);
        let direct = g.add_edge(0, 1, 2, 7);
        let mid = g.add_node();
        let e_in = g.add_edge(0, mid, 5, 1);
        let e_out = g.add_edge(mid, 1, 4, 1);
        assert_eq!((direct, e_in, e_out), (0, 2, 4));
        let (f, c) = g.min_cost_max_flow(0, 1);
        assert_eq!(f, 6);
        assert_eq!(c, 2 * 7 + 4 * 2);
        assert_eq!(g.flow_on(direct), 2);
        assert_eq!(g.flow_on(e_in), 4);
        assert_eq!(g.flow_on(e_out), 4);
        // residual twins carry the negated flow at id+1
        assert_eq!(g.flow_on(direct + 1), -2);
        assert_eq!(g.flow_on(e_in + 1), -4);
    }

    #[test]
    fn max_flow_matches_min_cut_on_bipartite() {
        // bipartite 2x2, unit capacities: max matching = 2
        let mut g = FlowNetwork::new(6);
        g.add_edge(0, 1, 1, 0);
        g.add_edge(0, 2, 1, 0);
        g.add_edge(1, 3, 1, 1);
        g.add_edge(1, 4, 1, 9);
        g.add_edge(2, 4, 1, 1);
        g.add_edge(3, 5, 1, 0);
        g.add_edge(4, 5, 1, 0);
        let (f, c) = g.min_cost_max_flow(0, 5);
        assert_eq!(f, 2);
        assert_eq!(c, 2); // both cheap edges
    }
}
