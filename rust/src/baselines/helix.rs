//! Helix baseline [16]: MILP/max-flow LLM request assignment over
//! heterogeneous GPUs, reproduced as min-cost max-flow on the epoch's
//! aggregated demand graph (DESIGN.md §3 substitutions: the published
//! formulation maximises served throughput over a flow network with
//! latency-weighted edges; it is *not* carbon/water/price aware).
//!
//! Graph: source -> class_k (cap = demand units) -> dc_l (cap = what the
//! site could serve of k alone, cost = latency proxy) -> sink (cap = site
//! node-second budget in units). Flows convert back to plan fractions;
//! unserved residue goes to the lowest-latency site.

use crate::baselines::mcmf::FlowNetwork;
use crate::config::PhysicsConfig;
use crate::plan::Plan;
use crate::sim::{EpochContext, Scheduler};

/// Target number of flow units per epoch (bundles requests to keep the
/// network small regardless of workload scale).
const TARGET_UNITS: f64 = 2000.0;

pub struct HelixScheduler;

impl Scheduler for HelixScheduler {
    fn name(&self) -> String {
        "helix".into()
    }

    // Helix keeps its GPU pool provisioned (no scale-to-zero).
    fn unused_pr(&self, phys: &PhysicsConfig) -> f64 {
        phys.pr_idle
    }

    fn plan(&mut self, ctx: &EpochContext) -> Plan {
        let ev = ctx.evaluator;
        let k_n = ev.classes();
        let l_n = ev.dcs();
        let cp = &ev.cp;
        let dp = &ev.dp;
        let epoch_s = ctx.cfg.physics.epoch_s;

        let total_req: f64 = cp.n_req.iter().sum();
        if total_req <= 0.0 {
            return Plan::uniform(k_n, l_n);
        }
        let bundle = (total_req / TARGET_UNITS).max(1.0);

        // node ids: 0 = source, 1..=k classes, k+1..=k+l sites, last = sink
        let mut g = FlowNetwork::new(2 + k_n + l_n);
        let src = 0usize;
        let sink = 1 + k_n + l_n;
        let class_node = |k: usize| 1 + k;
        let dc_node = |l: usize| 1 + k_n + l;

        // per-class supply
        let mut units = vec![0i64; k_n];
        for k in 0..k_n {
            units[k] = (cp.n_req[k] / bundle).round() as i64;
            if cp.n_req[k] > 0.0 && units[k] == 0 {
                units[k] = 1;
            }
            g.add_edge(src, class_node(k), units[k], 0);
        }

        // mean node-seconds consumed by one bundle at site l (class mix
        // weighted) -> site unit capacity
        for l in 0..l_n {
            let mut svc_num = 0.0;
            let mut svc_den = 0.0;
            for k in 0..k_n {
                let per_req = cp.tok_out[k] / cp.thr[k * l_n + l];
                svc_num += cp.n_req[k] * per_req;
                svc_den += cp.n_req[k];
            }
            let mean_service = if svc_den > 0.0 {
                svc_num / svc_den
            } else {
                1.0
            } * bundle;
            let budget_s = dp.nodes[l] * epoch_s;
            let cap = (budget_s / mean_service.max(1e-9)).floor() as i64;
            g.add_edge(dc_node(l), sink, cap.max(0), 0);
        }

        // class -> site edges. Helix's published formulation maximises
        // served *throughput* over heterogeneous GPUs (a single-cluster
        // max-flow); edge cost is therefore per-token service time on the
        // site's node mix — geo terms (migration hops, cold-start
        // bandwidth) are NOT part of its objective, which is exactly why
        // its TTFT trails the latency-greedy Splitwise in Fig. 4/5.
        let mut edge_ids = vec![vec![usize::MAX; l_n]; k_n];
        for k in 0..k_n {
            if units[k] == 0 {
                continue;
            }
            for l in 0..l_n {
                let i = k * l_n + l;
                let service = cp.tok_out[k] / cp.thr[i];
                let cost = (service * 1e4).round() as i64;
                edge_ids[k][l] = g.add_edge(class_node(k), dc_node(l), units[k], cost);
            }
        }

        let (_flow, _cost) = g.min_cost_max_flow(src, sink);
        debug_assert!(g.conserves_flow(src, sink));

        // flows -> plan fractions; residue to the cheapest edge
        let mut plan = Plan::one_dc(k_n, l_n, 0);
        for k in 0..k_n {
            for l in 0..l_n {
                plan.set(k, l, 0.0);
            }
            if units[k] == 0 {
                // no demand: park on the locally-cheapest site
                let best = (0..l_n)
                    .min_by(|&a, &b| {
                        cp.hops[k * l_n + a]
                            .partial_cmp(&cp.hops[k * l_n + b])
                            .unwrap()
                    })
                    .unwrap_or(0);
                plan.set(k, best, 1.0);
                continue;
            }
            let mut assigned = 0i64;
            for l in 0..l_n {
                if edge_ids[k][l] != usize::MAX {
                    let f = g.flow_on(edge_ids[k][l]);
                    assigned += f;
                    plan.set(k, l, f as f64);
                }
            }
            let residue = units[k] - assigned;
            if residue > 0 {
                // capacity-starved: overflow to the min-latency site
                let best = (0..l_n)
                    .min_by(|&a, &b| {
                        cp.proc[k * l_n + a]
                            .partial_cmp(&cp.proc[k * l_n + b])
                            .unwrap()
                    })
                    .unwrap();
                plan.set(k, best, plan.get(k, best) + residue as f64);
            }
        }
        plan.normalize();
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::build_panels;
    use crate::config::SystemConfig;
    use crate::eval::{AnalyticEvaluator, EvalConsts};
    use crate::power::GridSignals;
    use crate::trace::Trace;

    fn ctx_parts(
        cfg: &SystemConfig,
        seed: u64,
    ) -> (Trace, GridSignals) {
        (
            Trace::generate(cfg, 4, seed),
            GridSignals::generate(cfg, 4, seed),
        )
    }

    fn make_plan(cfg: &SystemConfig, seed: u64) -> (Plan, AnalyticEvaluator) {
        let (trace, signals) = ctx_parts(cfg, seed);
        let (cp, dp) = build_panels(
            cfg,
            &signals,
            1,
            &trace.epochs[1],
            cfg.physics.pr_idle,
        );
        let ev = AnalyticEvaluator::new(
            cp,
            dp,
            EvalConsts::from_physics(&cfg.physics),
        );
        let predicted = trace.epochs[1].clone();
        let cluster = crate::cluster::ClusterState::from_config(cfg);
        let ctx = EpochContext {
            cfg,
            epoch: 1,
            predicted: &predicted,
            evaluator: &ev,
            cluster: &cluster,
            prev: None,
        };
        let mut h = HelixScheduler;
        (h.plan(&ctx), ev)
    }

    #[test]
    fn produces_valid_plan() {
        let cfg = SystemConfig::paper_default();
        let (plan, _) = make_plan(&cfg, 1);
        assert!(plan.is_valid());
    }

    #[test]
    fn prefers_high_throughput_sites() {
        // Helix is throughput-first: with ample capacity each class's
        // heaviest assignment must sit in the fastest service tier (min
        // per-token service time on the site's node mix), regardless of
        // geography.
        let cfg = SystemConfig::paper_default();
        let (plan, ev) = make_plan(&cfg, 2);
        let l_n = ev.dcs();
        let service =
            |k: usize, l: usize| ev.cp.tok_out[k] / ev.cp.thr[k * l_n + l];
        for k in 0..ev.classes() {
            if ev.cp.n_req[k] <= 0.0 {
                continue;
            }
            let best_l = (0..l_n)
                .max_by(|&a, &b| {
                    plan.get(k, a).partial_cmp(&plan.get(k, b)).unwrap()
                })
                .unwrap();
            let min_svc = (0..l_n)
                .map(|l| service(k, l))
                .fold(f64::INFINITY, f64::min);
            assert!(
                service(k, best_l) <= min_svc * 1.25 + 1e-6,
                "class {k}: dominant site service {} vs best {min_svc}",
                service(k, best_l)
            );
        }
    }

    #[test]
    fn spreads_when_capacity_tight() {
        // shrink sites so one DC cannot absorb a class -> flow must split
        let mut cfg = SystemConfig::paper_default();
        for d in &mut cfg.datacenters {
            d.nodes_per_type = vec![3, 3, 3, 3, 3, 3];
        }
        cfg.workload.base_requests_per_epoch = 40_000.0;
        let (plan, ev) = make_plan(&cfg, 3);
        assert!(plan.is_valid());
        // at least one class uses >1 site
        let multi = (0..ev.classes()).any(|k| {
            (0..ev.dcs()).filter(|&l| plan.get(k, l) > 0.01).count() > 1
        });
        assert!(multi, "no class was split despite tight capacity");
    }

    #[test]
    fn zero_demand_epoch_still_valid() {
        let cfg = SystemConfig::paper_default();
        let (trace, signals) = ctx_parts(&cfg, 4);
        let mut zero = trace.epochs[0].clone();
        for c in &mut zero.classes {
            c.n_req = 0.0;
        }
        let (cp, dp) =
            build_panels(&cfg, &signals, 0, &zero, cfg.physics.pr_idle);
        let ev = AnalyticEvaluator::new(
            cp,
            dp,
            EvalConsts::from_physics(&cfg.physics),
        );
        let cluster = crate::cluster::ClusterState::from_config(&cfg);
        let ctx = EpochContext {
            cfg: &cfg,
            epoch: 0,
            predicted: &zero,
            evaluator: &ev,
            cluster: &cluster,
            prev: None,
        };
        let plan = HelixScheduler.plan(&ctx);
        assert!(plan.is_valid());
    }
}
