//! Framework registry: the single source of truth for every scheduling
//! framework the repo can run.
//!
//! One static [`FrameworkSpec`] table replaces the string-matching that
//! used to live in `cli.rs` — the CLI, benches, examples, and the
//! scenario-matrix test all enumerate or resolve frameworks through this
//! module, so adding a framework is one table row, not five call-site
//! edits.

use std::sync::Arc;

use crate::baselines::{HelixScheduler, RoundRobinScheduler, SplitwiseScheduler};
use crate::config::SystemConfig;
use crate::opt::{
    SearchMode, ShiftScheduler, SlitOptions, SlitScheduler, SlitVariant,
};
use crate::runtime::Engine;
use crate::signals::RobustScheduler;
use crate::sim::Scheduler;

/// One registered scheduling framework.
pub struct FrameworkSpec {
    /// Canonical name (`slit simulate --framework <name>`).
    pub name: &'static str,
    /// Accepted alternative spellings.
    pub aliases: &'static [&'static str],
    /// One-line description for `slit frameworks`.
    pub description: &'static str,
    /// Whether the framework belongs to the paper's Fig. 4 comparison set.
    pub in_paper_set: bool,
    /// Instantiate a fresh scheduler for one simulation run.
    pub build: fn(&SystemConfig) -> Box<dyn Scheduler>,
    /// Optional AOT/PJRT-backed construction (SLIT variants search on the
    /// HLO artifact when an engine is supplied).
    pub build_hlo: Option<fn(&SystemConfig, Arc<Engine>) -> Box<dyn Scheduler>>,
}

fn build_helix(_cfg: &SystemConfig) -> Box<dyn Scheduler> {
    Box::new(HelixScheduler)
}

fn build_splitwise(_cfg: &SystemConfig) -> Box<dyn Scheduler> {
    Box::new(SplitwiseScheduler)
}

fn build_round_robin(_cfg: &SystemConfig) -> Box<dyn Scheduler> {
    Box::new(RoundRobinScheduler)
}

macro_rules! slit_builders {
    ($build:ident, $build_hlo:ident, $variant:expr) => {
        fn $build(cfg: &SystemConfig) -> Box<dyn Scheduler> {
            Box::new(SlitScheduler::new(cfg, $variant))
        }
        fn $build_hlo(
            cfg: &SystemConfig,
            engine: Arc<Engine>,
        ) -> Box<dyn Scheduler> {
            Box::new(SlitScheduler::new(cfg, $variant).with_engine(engine))
        }
    };
}

slit_builders!(build_slit_carbon, build_slit_carbon_hlo, SlitVariant::Carbon);
slit_builders!(build_slit_ttft, build_slit_ttft_hlo, SlitVariant::Ttft);
slit_builders!(build_slit_water, build_slit_water_hlo, SlitVariant::Water);
slit_builders!(build_slit_cost, build_slit_cost_hlo, SlitVariant::Cost);
slit_builders!(
    build_slit_balance,
    build_slit_balance_hlo,
    SlitVariant::Balance
);

fn build_slit_adaptive(cfg: &SystemConfig) -> Box<dyn Scheduler> {
    Box::new(SlitScheduler::new(cfg, SlitVariant::Balance).with_feedback())
}

fn build_slit_adaptive_hlo(
    cfg: &SystemConfig,
    engine: Arc<Engine>,
) -> Box<dyn Scheduler> {
    Box::new(
        SlitScheduler::new(cfg, SlitVariant::Balance)
            .with_engine(engine)
            .with_feedback(),
    )
}

fn build_slit_adaptive_level(cfg: &SystemConfig) -> Box<dyn Scheduler> {
    Box::new(
        SlitScheduler::new(cfg, SlitVariant::Balance).with_level_feedback(),
    )
}

fn build_slit_adaptive_level_hlo(
    cfg: &SystemConfig,
    engine: Arc<Engine>,
) -> Box<dyn Scheduler> {
    Box::new(
        SlitScheduler::new(cfg, SlitVariant::Balance)
            .with_engine(engine)
            .with_level_feedback(),
    )
}

fn build_slit_shift(cfg: &SystemConfig) -> Box<dyn Scheduler> {
    Box::new(
        ShiftScheduler::new(Box::new(SlitScheduler::new(
            cfg,
            SlitVariant::Carbon,
        )))
        .named("slit-shift"),
    )
}

fn build_slit_shift_hlo(
    cfg: &SystemConfig,
    engine: Arc<Engine>,
) -> Box<dyn Scheduler> {
    Box::new(
        ShiftScheduler::new(Box::new(
            SlitScheduler::new(cfg, SlitVariant::Carbon).with_engine(engine),
        ))
        .named("slit-shift"),
    )
}

fn build_slit_robust(cfg: &SystemConfig) -> Box<dyn Scheduler> {
    Box::new(
        RobustScheduler::new(Box::new(SlitScheduler::new(
            cfg,
            SlitVariant::Carbon,
        )))
        .named("slit-robust"),
    )
}

fn build_slit_robust_hlo(
    cfg: &SystemConfig,
    engine: Arc<Engine>,
) -> Box<dyn Scheduler> {
    Box::new(
        RobustScheduler::new(Box::new(
            SlitScheduler::new(cfg, SlitVariant::Carbon).with_engine(engine),
        ))
        .named("slit-robust"),
    )
}

fn region_options() -> SlitOptions {
    SlitOptions {
        search_mode: Some(SearchMode::RegionDecomposed),
        ..SlitOptions::default()
    }
}

fn build_slit_region(cfg: &SystemConfig) -> Box<dyn Scheduler> {
    Box::new(
        SlitScheduler::new(cfg, SlitVariant::Balance)
            .with_options(region_options()),
    )
}

fn build_slit_region_hlo(
    cfg: &SystemConfig,
    engine: Arc<Engine>,
) -> Box<dyn Scheduler> {
    Box::new(
        SlitScheduler::new(cfg, SlitVariant::Balance)
            .with_engine(engine)
            .with_options(region_options()),
    )
}

/// The iterable framework table. Order is presentation order (baselines
/// first, SLIT variants after, as in the paper's Fig. 4 rows).
pub static FRAMEWORKS: &[FrameworkSpec] = &[
    FrameworkSpec {
        name: "helix",
        aliases: &[],
        description: "Helix [16]: min-cost max-flow, throughput-first, always-warm",
        in_paper_set: true,
        build: build_helix,
        build_hlo: None,
    },
    FrameworkSpec {
        name: "splitwise",
        aliases: &[],
        description: "Splitwise [17]: prefill/decode pools, latency-greedy, always-warm",
        in_paper_set: true,
        build: build_splitwise,
        build_hlo: None,
    },
    FrameworkSpec {
        name: "round-robin",
        aliases: &["rr"],
        description: "naive even split across sites (sanity floor, not in Fig. 4)",
        in_paper_set: false,
        build: build_round_robin,
        build_hlo: None,
    },
    FrameworkSpec {
        name: "slit-carbon",
        aliases: &[],
        description: "SLIT showcasing the min-carbon Pareto solution",
        in_paper_set: true,
        build: build_slit_carbon,
        build_hlo: Some(build_slit_carbon_hlo),
    },
    FrameworkSpec {
        name: "slit-ttft",
        aliases: &[],
        description: "SLIT showcasing the min-TTFT Pareto solution",
        in_paper_set: true,
        build: build_slit_ttft,
        build_hlo: Some(build_slit_ttft_hlo),
    },
    FrameworkSpec {
        name: "slit-water",
        aliases: &[],
        description: "SLIT showcasing the min-water Pareto solution",
        in_paper_set: true,
        build: build_slit_water,
        build_hlo: Some(build_slit_water_hlo),
    },
    FrameworkSpec {
        name: "slit-cost",
        aliases: &[],
        description: "SLIT showcasing the min-cost Pareto solution",
        in_paper_set: true,
        build: build_slit_cost,
        build_hlo: Some(build_slit_cost_hlo),
    },
    FrameworkSpec {
        name: "slit-balance",
        aliases: &["slit"],
        description: "SLIT showcasing the balanced (knee-point) solution",
        in_paper_set: true,
        build: build_slit_balance,
        build_hlo: Some(build_slit_balance_hlo),
    },
    FrameworkSpec {
        name: "slit-shift",
        aliases: &["shift"],
        description: "min-carbon SLIT wrapped in forecast-driven temporal shifting of deferrable mass (batch-overnight regime)",
        in_paper_set: false,
        build: build_slit_shift,
        build_hlo: Some(build_slit_shift_hlo),
    },
    FrameworkSpec {
        name: "slit-robust",
        aliases: &["robust"],
        description: "min-carbon SLIT planning on the health-gated believed-signal fallback ladder (degraded-telemetry regimes)",
        in_paper_set: false,
        build: build_slit_robust,
        build_hlo: Some(build_slit_robust_hlo),
    },
    FrameworkSpec {
        name: "slit-adaptive",
        aliases: &["slit-feedback"],
        description: "balanced SLIT with per-class prediction-error feedback from the previous epoch's actual ledger",
        in_paper_set: false,
        build: build_slit_adaptive,
        build_hlo: Some(build_slit_adaptive_hlo),
    },
    FrameworkSpec {
        name: "slit-adaptive-level",
        aliases: &["slit-feedback-level"],
        description: "balanced SLIT with the level-only (single-ratio) feedback — ablation baseline for slit-adaptive",
        in_paper_set: false,
        build: build_slit_adaptive_level,
        build_hlo: Some(build_slit_adaptive_level_hlo),
    },
    FrameworkSpec {
        name: "slit-region",
        aliases: &["region"],
        description: "balanced SLIT with the region-decomposed price-coordinated search forced on — ablation row for the ≥256-site auto mode",
        in_paper_set: false,
        build: build_slit_region,
        build_hlo: Some(build_slit_region_hlo),
    },
];

/// Every registered framework.
pub fn all() -> &'static [FrameworkSpec] {
    FRAMEWORKS
}

/// Canonical names, in table order.
pub fn names() -> Vec<&'static str> {
    FRAMEWORKS.iter().map(|f| f.name).collect()
}

/// Resolve a name or alias to its spec.
pub fn find(name: &str) -> Option<&'static FrameworkSpec> {
    FRAMEWORKS
        .iter()
        .find(|f| f.name == name || f.aliases.iter().any(|a| *a == name))
}

/// Instantiate a scheduler by name/alias; the optional engine routes SLIT
/// plan search through the AOT/PJRT artifact. Fleets larger than the
/// artifact's padded `DC_SLOTS` are analytic-only: selecting the AOT
/// backend for one returns the structured `validate_aot` error instead of
/// panicking deep in the panel-padding code.
pub fn build(
    name: &str,
    cfg: &SystemConfig,
    engine: Option<Arc<Engine>>,
) -> anyhow::Result<Box<dyn Scheduler>> {
    let spec = find(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown framework '{name}' (try: {})",
            names().join(", ")
        )
    })?;
    Ok(match (engine, spec.build_hlo) {
        (Some(engine), Some(build_hlo)) => {
            cfg.validate_aot()?;
            build_hlo(cfg, engine)
        }
        _ => (spec.build)(cfg),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_consistent() {
        let mut seen: Vec<&str> = Vec::new();
        for spec in all() {
            assert!(!spec.name.is_empty());
            assert!(!spec.description.is_empty());
            assert!(!seen.contains(&spec.name), "duplicate {}", spec.name);
            seen.push(spec.name);
            for &alias in spec.aliases {
                assert!(!seen.contains(&alias), "alias clash {alias}");
                seen.push(alias);
            }
        }
        // the paper's Fig. 4 set: 2 baselines + 5 SLIT variants
        assert_eq!(all().iter().filter(|f| f.in_paper_set).count(), 7);
    }

    #[test]
    fn every_spec_builds_a_scheduler_with_its_name() {
        let cfg = crate::config::SystemConfig::small_test();
        for spec in all() {
            let s = (spec.build)(&cfg);
            assert_eq!(s.name(), spec.name, "builder/name mismatch");
        }
    }

    #[test]
    fn find_resolves_names_and_aliases() {
        assert_eq!(find("helix").unwrap().name, "helix");
        assert_eq!(find("rr").unwrap().name, "round-robin");
        assert_eq!(find("slit").unwrap().name, "slit-balance");
        assert_eq!(find("slit-feedback").unwrap().name, "slit-adaptive");
        assert_eq!(
            find("slit-feedback-level").unwrap().name,
            "slit-adaptive-level"
        );
        assert_eq!(find("shift").unwrap().name, "slit-shift");
        assert_eq!(find("robust").unwrap().name, "slit-robust");
        assert_eq!(find("region").unwrap().name, "slit-region");
        assert!(find("nope").is_none());
    }

    #[test]
    fn slit_shift_is_the_only_forecast_policy_row() {
        use crate::opt::ShiftPolicy;
        let cfg = crate::config::SystemConfig::small_test();
        for spec in all() {
            let s = (spec.build)(&cfg);
            let want = if spec.name == "slit-shift" {
                ShiftPolicy::Forecast
            } else {
                ShiftPolicy::Immediate
            };
            assert_eq!(s.shift_policy(), want, "{}", spec.name);
        }
    }

    #[test]
    fn slit_robust_is_the_only_robust_signal_row() {
        use crate::signals::SignalPolicy;
        let cfg = crate::config::SystemConfig::small_test();
        for spec in all() {
            let s = (spec.build)(&cfg);
            let want = if spec.name == "slit-robust" {
                SignalPolicy::Robust
            } else {
                SignalPolicy::Trusting
            };
            assert_eq!(s.signal_policy(), want, "{}", spec.name);
        }
    }

    #[test]
    fn build_rejects_unknown_names() {
        let cfg = crate::config::SystemConfig::small_test();
        assert!(build("nope", &cfg, None).is_err());
        assert!(build("splitwise", &cfg, None).is_ok());
    }

    #[test]
    fn analytic_build_accepts_oversized_fleets() {
        // past DC_SLOTS the analytic backend is the only one; every
        // framework must still build (the AOT gate fires only when an
        // engine is actually supplied alongside a build_hlo row)
        let mut cfg = crate::config::SystemConfig::small_test();
        cfg.datacenters = crate::scenario::global_fleet_datacenters(6);
        cfg.validate().unwrap();
        assert!(cfg.validate_aot().is_err());
        for spec in all() {
            let s = build(spec.name, &cfg, None).unwrap();
            assert_eq!(s.name(), spec.name);
        }
    }
}
