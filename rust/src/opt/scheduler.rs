//! SLIT as a `sim::Scheduler`: runs Algorithm 1 each epoch against the
//! epoch-bound evaluator and picks one of the five showcased solutions
//! (§6: SLIT-Carbon / -TTFT / -Water / -Cost / -Balance).
//!
//! SLIT scales unused nodes to zero (`pr_off`) — serverless containers are
//! torn down when the plan parks no load on a site, which is where the
//! large single-objective wins in Fig. 4 come from.

use crate::config::{OptConfig, PhysicsConfig, OBJ_CARBON, OBJ_COST, OBJ_TTFT, OBJ_WATER};
use crate::pareto::ParetoArchive;
use crate::plan::Plan;
use crate::sim::{EpochContext, Scheduler};
use crate::opt::slit::{SlitOptimizer, SlitOptions};

/// Which showcased Pareto solution this scheduler deploys.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlitVariant {
    Carbon,
    Ttft,
    Water,
    Cost,
    Balance,
}

impl SlitVariant {
    pub fn all() -> [SlitVariant; 5] {
        [
            SlitVariant::Carbon,
            SlitVariant::Ttft,
            SlitVariant::Water,
            SlitVariant::Cost,
            SlitVariant::Balance,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            SlitVariant::Carbon => "slit-carbon",
            SlitVariant::Ttft => "slit-ttft",
            SlitVariant::Water => "slit-water",
            SlitVariant::Cost => "slit-cost",
            SlitVariant::Balance => "slit-balance",
        }
    }

    fn pick(&self, archive: &ParetoArchive) -> Option<Plan> {
        let sol = match self {
            SlitVariant::Carbon => archive.best_for(OBJ_CARBON),
            SlitVariant::Ttft => archive.best_for(OBJ_TTFT),
            SlitVariant::Water => archive.best_for(OBJ_WATER),
            SlitVariant::Cost => archive.best_for(OBJ_COST),
            SlitVariant::Balance => archive.balanced(),
        };
        sol.map(|s| s.plan.clone())
    }
}

/// Per-epoch optimizer statistics (for EXPERIMENTS.md and benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct SlitStats {
    pub epochs: usize,
    pub evaluations: usize,
    /// Evaluations answered by the plan-fingerprint memo cache.
    pub cache_hits: usize,
    pub generations: usize,
    pub surrogate_trainings: usize,
    pub wall_s: f64,
}

/// Bounds on the prediction-error correction ratio the feedback variant
/// applies (guards against a single wild epoch whipsawing the forecast).
const FEEDBACK_RATIO_MIN: f64 = 0.5;
const FEEDBACK_RATIO_MAX: f64 = 2.0;

pub struct SlitScheduler {
    pub variant: SlitVariant,
    pub options: SlitOptions,
    opt: OptConfig,
    seed: u64,
    epoch_counter: u64,
    pub stats: SlitStats,
    /// When set, plan search runs on the AOT/PJRT engine: each epoch an
    /// `HloPlanEvaluator` is bound to that epoch's panels.
    engine: Option<std::sync::Arc<crate::runtime::Engine>>,
    /// Prediction-error feedback: scale this epoch's predicted demand by
    /// last epoch's realised/predicted ratio (EpochContext::prev).
    feedback: bool,
    /// Total requests the previous epoch's plan was optimised against.
    last_predicted_req: Option<f64>,
}

impl SlitScheduler {
    pub fn new(cfg: &crate::config::SystemConfig, variant: SlitVariant) -> Self {
        SlitScheduler {
            variant,
            options: SlitOptions::default(),
            opt: cfg.opt.clone(),
            seed: cfg.seed,
            epoch_counter: 0,
            stats: SlitStats::default(),
            engine: None,
            feedback: false,
            last_predicted_req: None,
        }
    }

    pub fn with_options(mut self, options: SlitOptions) -> Self {
        self.options = options;
        self
    }

    /// Route plan search through the AOT/PJRT engine.
    pub fn with_engine(
        mut self,
        engine: std::sync::Arc<crate::runtime::Engine>,
    ) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Enable prediction-error feedback: the SimSession hands each epoch
    /// the previous epoch's *actual* ledger; this variant compares it to
    /// what it planned against and rescales the current forecast by the
    /// (clamped) realised/predicted ratio before searching.
    pub fn with_feedback(mut self) -> Self {
        self.feedback = true;
        self
    }

    /// The correction factor for this epoch, if feedback is on and a
    /// previous epoch exists to learn from.
    fn feedback_ratio(&self, ctx: &EpochContext) -> Option<f64> {
        if !self.feedback {
            return None;
        }
        let predicted = self.last_predicted_req?;
        let prev = ctx.prev?;
        let ratio = (prev.requests / predicted.max(1.0))
            .clamp(FEEDBACK_RATIO_MIN, FEEDBACK_RATIO_MAX);
        // skip the rebuild when the forecast was essentially right
        if (ratio - 1.0).abs() < 0.02 {
            None
        } else {
            Some(ratio)
        }
    }
}

impl Scheduler for SlitScheduler {
    fn name(&self) -> String {
        if self.feedback {
            // the registered `slit-adaptive` framework is the balanced
            // variant; feedback on any other variant keeps its identity
            match self.variant {
                SlitVariant::Balance => "slit-adaptive".into(),
                v => format!("{}-adaptive", v.name()),
            }
        } else {
            self.variant.name().into()
        }
    }

    fn unused_pr(&self, phys: &PhysicsConfig) -> f64 {
        phys.pr_off
    }

    fn plan(&mut self, ctx: &EpochContext) -> Plan {
        self.epoch_counter += 1;
        // prediction-error feedback: rebuild the epoch evaluator against
        // a corrected demand level before searching
        let corrected = self.feedback_ratio(ctx).map(|ratio| {
            let mut cp = ctx.evaluator.cp.clone();
            for n in &mut cp.n_req {
                *n *= ratio;
            }
            crate::eval::AnalyticEvaluator::new(
                cp,
                ctx.evaluator.dp.clone(),
                ctx.evaluator.consts,
            )
        });
        let evaluator = corrected.as_ref().unwrap_or(ctx.evaluator);
        self.last_predicted_req = Some(ctx.predicted.total_requests());

        let mut optimizer = SlitOptimizer::new(
            self.opt.clone(),
            ctx.cfg.num_classes(),
            ctx.cfg.datacenters.len(),
            self.seed ^ self.epoch_counter.wrapping_mul(0x9E37_79B9),
        )
        .with_options(self.options);
        let seeds = evaluator.greedy_seed_plans();
        let outcome = match &self.engine {
            Some(engine) => {
                let hlo = crate::runtime::HloPlanEvaluator::from_analytic(
                    engine.clone(),
                    evaluator,
                );
                optimizer.optimize_with_seeds(&hlo, &seeds)
            }
            None => optimizer.optimize_with_seeds(evaluator, &seeds),
        };
        self.stats.epochs += 1;
        self.stats.evaluations += outcome.evaluations;
        self.stats.cache_hits += outcome.cache_hits;
        self.stats.generations += outcome.generations_run;
        self.stats.surrogate_trainings += outcome.surrogate_trainings;
        self.stats.wall_s += outcome.wall_s;
        self.variant
            .pick(&outcome.archive)
            .unwrap_or_else(|| {
                Plan::uniform(ctx.cfg.num_classes(), ctx.cfg.datacenters.len())
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::power::GridSignals;
    use crate::sim::simulate;
    use crate::trace::Trace;

    fn run_variant(variant: SlitVariant, seed: u64) -> crate::sim::SimResult {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 4;
        let trace = Trace::generate(&cfg, cfg.epochs, seed);
        let signals = GridSignals::generate(&cfg, cfg.epochs, seed);
        let mut s = SlitScheduler::new(&cfg, variant);
        simulate(&cfg, &trace, &signals, &mut s, seed)
    }

    #[test]
    fn slit_simulates_all_variants() {
        for v in SlitVariant::all() {
            let res = run_variant(v, 3);
            assert!(res.total.requests > 0.0, "{}", v.name());
            assert_eq!(res.name, v.name());
        }
    }

    #[test]
    fn carbon_variant_beats_ttft_variant_on_carbon() {
        let carbon = run_variant(SlitVariant::Carbon, 5);
        let ttft = run_variant(SlitVariant::Ttft, 5);
        assert!(
            carbon.total.carbon_kg <= ttft.total.carbon_kg * 1.05,
            "carbon {} vs ttft-variant {}",
            carbon.total.carbon_kg,
            ttft.total.carbon_kg
        );
    }

    #[test]
    fn adaptive_variant_runs_and_reports_its_name() {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let trace = Trace::generate(&cfg, cfg.epochs, 2);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 2);
        let mut s =
            SlitScheduler::new(&cfg, SlitVariant::Balance).with_feedback();
        let res = simulate(&cfg, &trace, &signals, &mut s, 2);
        assert_eq!(res.name, "slit-adaptive");
        assert!(res.total.requests > 0.0);
        assert_eq!(res.per_epoch.len(), 3);
        // feedback on a non-balanced variant keeps the variant identity
        let carbon =
            SlitScheduler::new(&cfg, SlitVariant::Carbon).with_feedback();
        assert_eq!(carbon.name(), "slit-carbon-adaptive");
    }

    #[test]
    fn feedback_is_deterministic_per_seed() {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let trace = Trace::generate(&cfg, cfg.epochs, 4);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 4);
        let run = || {
            let mut s = SlitScheduler::new(&cfg, SlitVariant::Balance)
                .with_feedback();
            simulate(&cfg, &trace, &signals, &mut s, 4)
        };
        let a = run();
        let b = run();
        assert_eq!(a.total.carbon_kg, b.total.carbon_kg);
        assert_eq!(a.total.ttft_sum_s, b.total.ttft_sum_s);
    }

    #[test]
    fn stats_accumulate() {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let trace = Trace::generate(&cfg, cfg.epochs, 1);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 1);
        let mut s = SlitScheduler::new(&cfg, SlitVariant::Balance);
        let _ = simulate(&cfg, &trace, &signals, &mut s, 1);
        assert_eq!(s.stats.epochs, 3);
        assert!(s.stats.evaluations > 0);
        assert!(s.stats.wall_s > 0.0);
    }
}
