//! SLIT as a `sim::Scheduler`: runs Algorithm 1 each epoch against the
//! epoch-bound evaluator and picks one of the five showcased solutions
//! (§6: SLIT-Carbon / -TTFT / -Water / -Cost / -Balance).
//!
//! SLIT scales unused nodes to zero (`pr_off`) — serverless containers are
//! torn down when the plan parks no load on a site, which is where the
//! large single-objective wins in Fig. 4 come from.

use crate::config::{OptConfig, PhysicsConfig, OBJ_CARBON, OBJ_COST, OBJ_TTFT, OBJ_WATER};
use crate::pareto::ParetoArchive;
use crate::plan::Plan;
use crate::sim::{EpochContext, Scheduler};
use crate::opt::slit::{SearchMode, SlitOptimizer, SlitOptions};

/// Which showcased Pareto solution this scheduler deploys.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlitVariant {
    Carbon,
    Ttft,
    Water,
    Cost,
    Balance,
}

impl SlitVariant {
    pub fn all() -> [SlitVariant; 5] {
        [
            SlitVariant::Carbon,
            SlitVariant::Ttft,
            SlitVariant::Water,
            SlitVariant::Cost,
            SlitVariant::Balance,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            SlitVariant::Carbon => "slit-carbon",
            SlitVariant::Ttft => "slit-ttft",
            SlitVariant::Water => "slit-water",
            SlitVariant::Cost => "slit-cost",
            SlitVariant::Balance => "slit-balance",
        }
    }

    fn pick(&self, archive: &ParetoArchive) -> Option<Plan> {
        let sol = match self {
            SlitVariant::Carbon => archive.best_for(OBJ_CARBON),
            SlitVariant::Ttft => archive.best_for(OBJ_TTFT),
            SlitVariant::Water => archive.best_for(OBJ_WATER),
            SlitVariant::Cost => archive.best_for(OBJ_COST),
            SlitVariant::Balance => archive.balanced(),
        };
        sol.map(|s| s.plan.clone())
    }
}

/// Per-epoch optimizer statistics (for EXPERIMENTS.md and benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct SlitStats {
    pub epochs: usize,
    pub evaluations: usize,
    /// Evaluations answered by the plan-fingerprint memo cache.
    pub cache_hits: usize,
    /// Neighbour candidates scored via O(L) delta rescoring (subset of
    /// `evaluations`).
    pub delta_evals: usize,
    pub generations: usize,
    pub surrogate_trainings: usize,
    pub wall_s: f64,
}

/// Bounds on the prediction-error correction ratio the feedback variants
/// apply (guards against a single wild epoch whipsawing the forecast).
/// Each per-class ratio is clamped independently to the same band.
const FEEDBACK_RATIO_MIN: f64 = 0.5;
const FEEDBACK_RATIO_MAX: f64 = 2.0;

/// Relative deadband: corrections closer to 1.0 than this skip the
/// evaluator rebuild entirely (the forecast was essentially right).
const FEEDBACK_DEADBAND: f64 = 0.02;

/// How the scheduler corrects its demand forecast from the previous
/// epoch's realised ledger (`EpochContext::prev`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeedbackMode {
    /// No correction: plan against the predictor's forecast as-is.
    Off,
    /// One global ratio: realised/predicted *total* request mass, clamped.
    Level,
    /// One ratio per request class (region x model), each clamped
    /// independently — a regional burst or outage backlog only rescales
    /// the classes that actually missed.
    PerClass,
}

pub struct SlitScheduler {
    pub variant: SlitVariant,
    pub options: SlitOptions,
    opt: OptConfig,
    seed: u64,
    epoch_counter: u64,
    pub stats: SlitStats,
    /// When set, plan search runs on the AOT/PJRT engine: each epoch an
    /// `HloPlanEvaluator` is bound to that epoch's panels.
    engine: Option<std::sync::Arc<crate::runtime::Engine>>,
    /// Prediction-error feedback policy (EpochContext::prev).
    feedback: FeedbackMode,
    /// Per-class requests the previous epoch's plan was optimised against.
    last_predicted: Option<Vec<f64>>,
}

impl SlitScheduler {
    pub fn new(cfg: &crate::config::SystemConfig, variant: SlitVariant) -> Self {
        SlitScheduler {
            variant,
            options: SlitOptions::default(),
            opt: cfg.opt.clone(),
            seed: cfg.seed,
            epoch_counter: 0,
            stats: SlitStats::default(),
            engine: None,
            feedback: FeedbackMode::Off,
            last_predicted: None,
        }
    }

    pub fn with_options(mut self, options: SlitOptions) -> Self {
        self.options = options;
        self
    }

    /// Route plan search through the AOT/PJRT engine.
    pub fn with_engine(
        mut self,
        engine: std::sync::Arc<crate::runtime::Engine>,
    ) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Enable per-class prediction-error feedback: the SimSession hands
    /// each epoch the previous epoch's *actual* ledger (including realised
    /// per-class demand); this variant compares it class-by-class to what
    /// it planned against and rescales each class of the current forecast
    /// by its own (independently clamped) realised/predicted ratio before
    /// searching. Falls back to the level-only correction when the ledger
    /// carries no per-class counts.
    pub fn with_feedback(mut self) -> Self {
        self.feedback = FeedbackMode::PerClass;
        self
    }

    /// Enable the level-only feedback (the pre-per-class behaviour): one
    /// global realised/predicted ratio over total request mass. Kept as an
    /// ablation baseline for the per-class variant.
    pub fn with_level_feedback(mut self) -> Self {
        self.feedback = FeedbackMode::Level;
        self
    }

    pub fn feedback_mode(&self) -> FeedbackMode {
        self.feedback
    }

    /// Independently clamped realised/predicted ratio per request class.
    /// Classes the realised ledger never saw get ratio = clamp(0), i.e.
    /// the forecast is pulled down toward the floor, not zeroed.
    fn per_class_ratios(predicted: &[f64], realised: &[f64]) -> Vec<f64> {
        predicted
            .iter()
            .enumerate()
            .map(|(k, &p)| {
                let r = realised.get(k).copied().unwrap_or(0.0);
                (r / p.max(1.0)).clamp(FEEDBACK_RATIO_MIN, FEEDBACK_RATIO_MAX)
            })
            .collect()
    }

    /// One clamped realised/predicted ratio over total request mass,
    /// broadcast to every class.
    fn level_ratios(predicted: &[f64], realised_total: f64) -> Vec<f64> {
        let predicted_total: f64 = predicted.iter().sum();
        let ratio = (realised_total / predicted_total.max(1.0))
            .clamp(FEEDBACK_RATIO_MIN, FEEDBACK_RATIO_MAX);
        vec![ratio; predicted.len()]
    }

    /// The per-class correction factors for this epoch, if feedback is on
    /// and a previous epoch exists to learn from. `None` means "plan
    /// against the forecast as-is" — either feedback is off, there is no
    /// history yet, or every ratio sits inside the deadband.
    fn feedback_ratios(&self, ctx: &EpochContext) -> Option<Vec<f64>> {
        if self.feedback == FeedbackMode::Off {
            return None;
        }
        let predicted = self.last_predicted.as_ref()?;
        let prev = ctx.prev?;
        let ratios = match self.feedback {
            FeedbackMode::PerClass if !prev.class_requests.is_empty() => {
                Self::per_class_ratios(predicted, &prev.class_requests)
            }
            // Level mode, or a ledger without per-class counts
            _ => Self::level_ratios(predicted, prev.requests),
        };
        // skip the rebuild when the forecast was essentially right
        if ratios.iter().all(|r| (r - 1.0).abs() < FEEDBACK_DEADBAND) {
            None
        } else {
            Some(ratios)
        }
    }
}

impl Scheduler for SlitScheduler {
    fn name(&self) -> String {
        // a *forced* region-decomposed search is the `slit-region`
        // ablation row; auto-selection (search_mode: None) keeps the
        // variant's identity — past the threshold every slit-* framework
        // decomposes without being renamed
        if self.options.search_mode == Some(SearchMode::RegionDecomposed) {
            return match self.variant {
                SlitVariant::Balance => "slit-region".into(),
                v => format!("{}-region", v.name()),
            };
        }
        // the registered `slit-adaptive` framework is the balanced
        // variant; feedback on any other variant keeps its identity
        match (self.feedback, self.variant) {
            (FeedbackMode::Off, v) => v.name().into(),
            (FeedbackMode::PerClass, SlitVariant::Balance) => {
                "slit-adaptive".into()
            }
            (FeedbackMode::PerClass, v) => format!("{}-adaptive", v.name()),
            (FeedbackMode::Level, SlitVariant::Balance) => {
                "slit-adaptive-level".into()
            }
            (FeedbackMode::Level, v) => {
                format!("{}-adaptive-level", v.name())
            }
        }
    }

    fn unused_pr(&self, phys: &PhysicsConfig) -> f64 {
        phys.pr_off
    }

    fn plan(&mut self, ctx: &EpochContext) -> Plan {
        self.epoch_counter += 1;
        // prediction-error feedback: rebuild the epoch evaluator against
        // the corrected per-class demand before searching
        let corrected = self.feedback_ratios(ctx).map(|ratios| {
            let mut cp = ctx.evaluator.cp.clone();
            for (n, r) in cp.n_req.iter_mut().zip(&ratios) {
                *n *= r;
            }
            crate::eval::AnalyticEvaluator::new(
                cp,
                ctx.evaluator.dp.clone(),
                ctx.evaluator.consts,
            )
        });
        let evaluator = corrected.as_ref().unwrap_or(ctx.evaluator);
        self.last_predicted = Some(
            ctx.predicted.classes.iter().map(|c| c.n_req).collect(),
        );

        let mut optimizer = SlitOptimizer::new(
            self.opt.clone(),
            ctx.cfg.num_classes(),
            ctx.cfg.datacenters.len(),
            self.seed ^ self.epoch_counter.wrapping_mul(0x9E37_79B9),
        )
        .with_options(self.options)
        .with_regions(
            ctx.cfg.datacenters.iter().map(|d| d.region).collect(),
        );
        let seeds = evaluator.greedy_seed_plans();
        // the AOT artifact pads exactly DC_SLOTS columns; fleets past it
        // run analytic-only (registry::build rejects the combination up
        // front — this guard covers hand-built schedulers). The degrade
        // is announced once so backend-comparison runs can't be silently
        // mislabeled.
        let aot_ok = ctx.cfg.validate_aot().is_ok();
        if self.engine.is_some() && !aot_ok && self.epoch_counter == 1 {
            eprintln!(
                "{}: fleet exceeds AOT DC slots — engine ignored, \
                 planning on the analytic backend",
                self.name()
            );
        }
        let outcome = match &self.engine {
            Some(engine) if aot_ok => {
                let hlo = crate::runtime::HloPlanEvaluator::from_analytic(
                    engine.clone(),
                    evaluator,
                );
                optimizer.optimize_with_seeds(&hlo, &seeds)
            }
            _ => optimizer.optimize_with_seeds(evaluator, &seeds),
        };
        self.stats.epochs += 1;
        self.stats.evaluations += outcome.evaluations;
        self.stats.cache_hits += outcome.cache_hits;
        self.stats.delta_evals += outcome.delta_evals;
        self.stats.generations += outcome.generations_run;
        self.stats.surrogate_trainings += outcome.surrogate_trainings;
        self.stats.wall_s += outcome.wall_s;
        self.variant
            .pick(&outcome.archive)
            .unwrap_or_else(|| {
                Plan::uniform(ctx.cfg.num_classes(), ctx.cfg.datacenters.len())
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::power::GridSignals;
    use crate::sim::simulate;
    use crate::trace::Trace;

    fn run_variant(variant: SlitVariant, seed: u64) -> crate::sim::SimResult {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 4;
        let trace = Trace::generate(&cfg, cfg.epochs, seed);
        let signals = GridSignals::generate(&cfg, cfg.epochs, seed);
        let mut s = SlitScheduler::new(&cfg, variant);
        simulate(&cfg, &trace, &signals, &mut s, seed)
    }

    #[test]
    fn slit_simulates_all_variants() {
        for v in SlitVariant::all() {
            let res = run_variant(v, 3);
            assert!(res.total.requests > 0.0, "{}", v.name());
            assert_eq!(res.name, v.name());
        }
    }

    #[test]
    fn carbon_variant_beats_ttft_variant_on_carbon() {
        let carbon = run_variant(SlitVariant::Carbon, 5);
        let ttft = run_variant(SlitVariant::Ttft, 5);
        assert!(
            carbon.total.carbon_kg <= ttft.total.carbon_kg * 1.05,
            "carbon {} vs ttft-variant {}",
            carbon.total.carbon_kg,
            ttft.total.carbon_kg
        );
    }

    #[test]
    fn adaptive_variant_runs_and_reports_its_name() {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let trace = Trace::generate(&cfg, cfg.epochs, 2);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 2);
        let mut s =
            SlitScheduler::new(&cfg, SlitVariant::Balance).with_feedback();
        assert_eq!(s.feedback_mode(), FeedbackMode::PerClass);
        let res = simulate(&cfg, &trace, &signals, &mut s, 2);
        assert_eq!(res.name, "slit-adaptive");
        assert!(res.total.requests > 0.0);
        assert_eq!(res.per_epoch.len(), 3);
        // feedback on a non-balanced variant keeps the variant identity
        let carbon =
            SlitScheduler::new(&cfg, SlitVariant::Carbon).with_feedback();
        assert_eq!(carbon.name(), "slit-carbon-adaptive");
    }

    #[test]
    fn level_feedback_variant_runs_and_reports_its_name() {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let trace = Trace::generate(&cfg, cfg.epochs, 2);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 2);
        let mut s = SlitScheduler::new(&cfg, SlitVariant::Balance)
            .with_level_feedback();
        assert_eq!(s.feedback_mode(), FeedbackMode::Level);
        let res = simulate(&cfg, &trace, &signals, &mut s, 2);
        assert_eq!(res.name, "slit-adaptive-level");
        assert!(res.total.requests > 0.0);
        let water =
            SlitScheduler::new(&cfg, SlitVariant::Water).with_level_feedback();
        assert_eq!(water.name(), "slit-water-adaptive-level");
    }

    #[test]
    fn per_class_ratios_clamp_each_class_independently() {
        // class 0: realised 3x predicted -> clamped to the 2.0 ceiling;
        // class 1: spot on -> 1.0; class 2: vanished -> clamped to 0.5;
        // class 3: absent from the realised ledger -> treated as 0 -> 0.5
        let predicted = [100.0, 50.0, 80.0, 40.0];
        let realised = [300.0, 50.0, 0.0];
        let r = SlitScheduler::per_class_ratios(&predicted, &realised);
        assert_eq!(r, vec![2.0, 1.0, 0.5, 0.5]);
        // tiny predictions are floored at 1 request, not divided by ~0
        let r2 = SlitScheduler::per_class_ratios(&[0.001], &[1.5]);
        assert_eq!(r2, vec![1.5]);
    }

    #[test]
    fn level_ratios_broadcast_one_clamped_ratio() {
        let r = SlitScheduler::level_ratios(&[100.0, 100.0], 260.0);
        assert_eq!(r, vec![1.3, 1.3]);
        let hi = SlitScheduler::level_ratios(&[10.0, 10.0], 1000.0);
        assert_eq!(hi, vec![2.0, 2.0]);
        let lo = SlitScheduler::level_ratios(&[100.0, 100.0], 1.0);
        assert_eq!(lo, vec![0.5, 0.5]);
    }

    #[test]
    fn per_class_feedback_is_deterministic_per_seed() {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let trace = Trace::generate(&cfg, cfg.epochs, 6);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 6);
        let run = || {
            let mut s = SlitScheduler::new(&cfg, SlitVariant::Balance)
                .with_feedback();
            simulate(&cfg, &trace, &signals, &mut s, 6)
        };
        let a = run();
        let b = run();
        assert_eq!(a.total.carbon_kg, b.total.carbon_kg);
        assert_eq!(a.total.ttft_sum_s, b.total.ttft_sum_s);
    }

    #[test]
    fn feedback_is_deterministic_per_seed() {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let trace = Trace::generate(&cfg, cfg.epochs, 4);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 4);
        let run = || {
            let mut s = SlitScheduler::new(&cfg, SlitVariant::Balance)
                .with_feedback();
            simulate(&cfg, &trace, &signals, &mut s, 4)
        };
        let a = run();
        let b = run();
        assert_eq!(a.total.carbon_kg, b.total.carbon_kg);
        assert_eq!(a.total.ttft_sum_s, b.total.ttft_sum_s);
    }

    #[test]
    fn forced_region_mode_renames_and_simulates() {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 2;
        let trace = Trace::generate(&cfg, cfg.epochs, 8);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 8);
        let mut s = SlitScheduler::new(&cfg, SlitVariant::Balance)
            .with_options(SlitOptions {
                search_mode: Some(SearchMode::RegionDecomposed),
                ..SlitOptions::default()
            });
        assert_eq!(s.name(), "slit-region");
        let res = simulate(&cfg, &trace, &signals, &mut s, 8);
        assert_eq!(res.name, "slit-region");
        assert!(res.total.requests > 0.0);
        // non-balanced variants keep their identity under the suffix
        let carbon = SlitScheduler::new(&cfg, SlitVariant::Carbon)
            .with_options(SlitOptions {
                search_mode: Some(SearchMode::RegionDecomposed),
                ..SlitOptions::default()
            });
        assert_eq!(carbon.name(), "slit-carbon-region");
        // auto-selection keeps the plain name
        let auto = SlitScheduler::new(&cfg, SlitVariant::Balance);
        assert_eq!(auto.name(), "slit-balance");
    }

    #[test]
    fn stats_accumulate() {
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let trace = Trace::generate(&cfg, cfg.epochs, 1);
        let signals = GridSignals::generate(&cfg, cfg.epochs, 1);
        let mut s = SlitScheduler::new(&cfg, SlitVariant::Balance);
        let _ = simulate(&cfg, &trace, &signals, &mut s, 1);
        assert_eq!(s.stats.epochs, 3);
        assert!(s.stats.evaluations > 0);
        assert!(s.stats.wall_s > 0.0);
    }
}
