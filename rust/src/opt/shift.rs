//! Temporal shifting of deferrable workload (the "when" control axis).
//!
//! SLIT searches *where* to serve each epoch's load; this layer decides
//! *when* deferrable mass (batch/embedding/eval jobs with deadline
//! epochs, `ClassLoad::defer_req`) is served. The design follows
//! MetaTune (SNIPPETS.md snippet 1): queue delay-tolerant work and
//! release it against a per-DC carbon *forecast*, subject to deadlines.
//!
//! Two pieces:
//!
//! * [`TemporalShifter`] — the deferral queue + release policy, owned by
//!   `SimSession`. Every epoch it absorbs the trace's deferrable offer,
//!   then releases queued lots into the epoch's *effective* load (before
//!   panel build and plan search, so the inner spatial scheduler plans
//!   for the released mass). With [`ShiftPolicy::Immediate`] (the
//!   default for every scheduler without an explicit policy) deferrable
//!   mass is released the epoch it arrives — the pre-shift behaviour.
//! * [`ShiftScheduler`] — a wrapper that composes the
//!   [`ShiftPolicy::Forecast`] policy with any inner spatial scheduler
//!   (the `slit-shift` registry row wraps `slit-carbon`). Plans are
//!   delegated untouched, so with no deferrable mass in the trace the
//!   wrapper is bit-identical to its inner framework
//!   (rust/tests/shift_conservation.rs pins it).
//!
//! The Forecast policy is greedy water-filling over the forecast
//! horizon: each epoch, a lot is released iff the current epoch's
//! realised fleet-green score is no worse than the forecast minimum over
//! the epochs the lot could still wait for (ties release, so a flat
//! forecast degrades gracefully to Immediate), and always at its
//! deadline epoch. Lots are atomic and integral, so served-mass
//! comparisons across release schedules stay exact.

use crate::config::SystemConfig;
use crate::forecast::{epochs_per_day, GridForecaster};
use crate::plan::Plan;
use crate::sim::{EpochContext, Scheduler};
use crate::trace::{EpochLoad, Trace};

/// Weight folding water intensity (L/kWh) into the carbon-primary green
/// score (kg/kWh): small enough that carbon dominates, large enough that
/// water breaks ties between similar-CI windows.
pub const SHIFT_WATER_WEIGHT: f64 = 0.002;

/// Days of synthetic grid history the Forecast policy warm-starts its
/// forecaster with (the stand-in for a real deployment's signal archive).
pub const SHIFT_WARMUP_DAYS: usize = 2;

/// When deferrable mass is served relative to its arrival epoch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShiftPolicy {
    /// Release deferrable mass the epoch it arrives (no temporal control;
    /// behaviour is identical to a world where the mass was interactive).
    #[default]
    Immediate,
    /// Hold deferrable mass and release it into forecast low-carbon /
    /// low-water windows, subject to deadlines.
    Forecast,
}

/// One queued parcel of deferrable mass.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeferredLot {
    pub class: usize,
    pub mass: f64,
    /// Latest epoch this lot may be released into (inclusive).
    pub deadline: usize,
}

/// What the shifter did this epoch (flows in request units; every value
/// also lands in the epoch ledger's `deferred_*` fields).
#[derive(Clone, Debug, Default)]
pub struct ShiftOutcome {
    /// Per-class mass released into this epoch's effective load.
    pub released: Vec<f64>,
    /// Deferrable mass offered (enqueued) this epoch.
    pub offered: f64,
    /// Sum of `released`.
    pub released_mass: f64,
    /// Mass that missed its deadline (policy bug guard — stays 0 for the
    /// shipped policies, which force-release at the deadline).
    pub expired: f64,
    /// Mass still queued after this epoch's releases.
    pub queued: f64,
}

impl ShiftOutcome {
    fn inert(classes: usize) -> ShiftOutcome {
        ShiftOutcome {
            released: vec![0.0; classes],
            ..ShiftOutcome::default()
        }
    }
}

/// Fleet-green score of one epoch: the best (lowest) carbon+water index
/// any site offers. With scale-to-zero serving, marginal released mass is
/// served at the cleanest available site, so the fleet minimum is the
/// right single-scalar proxy for "how green is this window".
pub fn fleet_green_score(ci: &[f64], wi: &[f64]) -> f64 {
    ci.iter()
        .zip(wi)
        .map(|(c, w)| c + SHIFT_WATER_WEIGHT * w)
        .fold(f64::INFINITY, f64::min)
}

/// Deferral queue + release policy. Owned by `SimSession`; inert (zero
/// cost, zero behaviour change) when the trace carries no deferrable mass.
pub struct TemporalShifter {
    policy: ShiftPolicy,
    active: bool,
    queue: Vec<DeferredLot>,
    forecaster: Option<GridForecaster>,
    /// Cumulative flows (request units) for conservation checks.
    offered_total: f64,
    released_total: f64,
    expired_total: f64,
}

impl TemporalShifter {
    /// Build the shifter for one session. Scans the trace once: with no
    /// deferrable mass anywhere the shifter is inert regardless of
    /// policy (this is what keeps `slit-shift` bit-identical to its
    /// inner framework at deferrable fraction 0 — no forecaster is even
    /// constructed).
    pub fn new(
        cfg: &SystemConfig,
        trace: &Trace,
        policy: ShiftPolicy,
    ) -> TemporalShifter {
        let active = trace
            .epochs
            .iter()
            .any(|e| e.classes.iter().any(|c| c.defer_req > 0.0));
        let forecaster = (active && policy == ShiftPolicy::Forecast).then(
            || {
                let horizon = epochs_per_day(cfg.physics.epoch_s);
                GridForecaster::warmed(cfg, SHIFT_WARMUP_DAYS, horizon)
            },
        );
        TemporalShifter {
            policy,
            active,
            queue: Vec::new(),
            forecaster,
            offered_total: 0.0,
            released_total: 0.0,
            expired_total: 0.0,
        }
    }

    /// Advance one epoch: feed the forecaster the epoch's realised
    /// signals, absorb the deferrable offer, and decide releases.
    /// `last_epoch` is the final epoch of the horizon (deadlines clamp to
    /// it so every lot is releasable before the run ends).
    pub fn step(
        &mut self,
        epoch: usize,
        last_epoch: usize,
        actual: &EpochLoad,
        ci: &[f64],
        wi: &[f64],
        _tou: &[f64],
    ) -> ShiftOutcome {
        let classes = actual.classes.len();
        if !self.active {
            return ShiftOutcome::inert(classes);
        }
        if let Some(f) = self.forecaster.as_mut() {
            f.observe(ci, wi, _tou);
        }

        let mut out = ShiftOutcome::inert(classes);
        for (k, c) in actual.classes.iter().enumerate() {
            if c.defer_req > 0.0 {
                out.offered += c.defer_req;
                self.queue.push(DeferredLot {
                    class: k,
                    mass: c.defer_req,
                    deadline: c.defer_deadline.clamp(epoch, last_epoch),
                });
            }
        }
        self.offered_total += out.offered;

        // forecast fleet-green scores for epochs epoch+1 ..= epoch+H
        let fc_scores: Vec<f64> = match &self.forecaster {
            Some(f) => {
                let fc = f.forecast();
                (0..f.horizon())
                    .map(|h| {
                        fc.ci
                            .iter()
                            .zip(&fc.wi)
                            .map(|(c, w)| {
                                c[h] + SHIFT_WATER_WEIGHT * w[h]
                            })
                            .fold(f64::INFINITY, f64::min)
                    })
                    .collect()
            }
            None => Vec::new(),
        };
        let now_score = fleet_green_score(ci, wi);

        let mut kept = Vec::with_capacity(self.queue.len());
        for lot in self.queue.drain(..) {
            if lot.deadline < epoch {
                // a policy failed to release by the deadline: the mass is
                // lost, never served late (the conservation tests pin
                // that this branch is unreachable for shipped policies)
                out.expired += lot.mass;
                continue;
            }
            let release = match self.policy {
                ShiftPolicy::Immediate => true,
                ShiftPolicy::Forecast => {
                    // water-filling step: release iff no strictly greener
                    // epoch is forecast within this lot's remaining slack
                    let look = (lot.deadline - epoch).min(fc_scores.len());
                    let future_min = fc_scores[..look]
                        .iter()
                        .copied()
                        .fold(f64::INFINITY, f64::min);
                    lot.deadline == epoch || now_score <= future_min
                }
            };
            if release {
                out.released[lot.class] += lot.mass;
            } else {
                kept.push(lot);
            }
        }
        self.queue = kept;

        out.released_mass = out.released.iter().sum();
        self.released_total += out.released_mass;
        self.expired_total += out.expired;
        out.queued = self.queue_mass();
        out
    }

    /// Mass currently queued.
    pub fn queue_mass(&self) -> f64 {
        self.queue.iter().map(|l| l.mass).sum()
    }

    /// Cumulative (offered, released, expired) flows.
    pub fn totals(&self) -> (f64, f64, f64) {
        (self.offered_total, self.released_total, self.expired_total)
    }

    /// Whether the trace carries any deferrable mass.
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Forecast backtest of the policy's forecaster, when one exists.
    pub fn backtest(&self) -> Option<crate::forecast::ForecastBacktest> {
        self.forecaster.as_ref().map(|f| f.backtest())
    }
}

/// Temporal-shifting wrapper around any inner spatial scheduler: plans
/// are delegated untouched; the only difference is the
/// [`ShiftPolicy::Forecast`] release policy the session picks up.
pub struct ShiftScheduler {
    inner: Box<dyn Scheduler>,
    name: Option<String>,
}

impl ShiftScheduler {
    pub fn new(inner: Box<dyn Scheduler>) -> ShiftScheduler {
        ShiftScheduler { inner, name: None }
    }

    /// Override the derived `shift+<inner>` name (registry rows carry
    /// their spec name).
    pub fn named(mut self, name: &str) -> ShiftScheduler {
        self.name = Some(name.into());
        self
    }
}

impl Scheduler for ShiftScheduler {
    fn name(&self) -> String {
        self.name
            .clone()
            .unwrap_or_else(|| format!("shift+{}", self.inner.name()))
    }

    fn unused_pr(&self, phys: &crate::config::PhysicsConfig) -> f64 {
        self.inner.unused_pr(phys)
    }

    fn plan(&mut self, ctx: &EpochContext) -> Plan {
        self.inner.plan(ctx)
    }

    fn shift_policy(&self) -> ShiftPolicy {
        ShiftPolicy::Forecast
    }

    // composability: shift(robust(s)) keeps the inner believed-signal view
    fn signal_policy(&self) -> crate::signals::SignalPolicy {
        self.inner.signal_policy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::GridSignals;
    use crate::trace::ClassLoad;
    use crate::util::propkit;
    use crate::util::rng::Rng;

    fn hourly_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::small_test();
        cfg.physics.epoch_s = 3600.0;
        cfg
    }

    /// A trace of hand-built deferrable lots riding a flat interactive
    /// base.
    fn lot_trace(
        cfg: &SystemConfig,
        epochs: usize,
        rng: &mut Rng,
    ) -> Trace {
        let classes = cfg.num_classes();
        let mut out = Vec::with_capacity(epochs);
        for t in 0..epochs {
            let mut cl = vec![ClassLoad::default(); classes];
            for c in cl.iter_mut() {
                c.n_req = 5.0;
                c.tok_in = 100.0;
                c.tok_out = 100.0;
                if rng.chance(0.6) {
                    c.defer_req = rng.below(40) as f64;
                    c.defer_deadline = (t + 1 + rng.below(8)).min(epochs - 1);
                }
            }
            out.push(EpochLoad { classes: cl });
        }
        Trace {
            epochs: out,
            seed: 0,
        }
    }

    fn drive(
        cfg: &SystemConfig,
        trace: &Trace,
        policy: ShiftPolicy,
        seed: u64,
    ) -> (Vec<ShiftOutcome>, TemporalShifter) {
        let epochs = trace.epochs.len();
        let signals = GridSignals::generate(cfg, epochs, seed);
        let mut sh = TemporalShifter::new(cfg, trace, policy);
        let mut outs = Vec::with_capacity(epochs);
        for t in 0..epochs {
            let (ci, wi, tou) = signals.at(t);
            outs.push(sh.step(t, epochs - 1, &trace.epochs[t], &ci, &wi, &tou));
        }
        (outs, sh)
    }

    #[test]
    fn conservation_and_deadlines_hold_under_both_policies() {
        let cfg = hourly_cfg();
        for policy in [ShiftPolicy::Immediate, ShiftPolicy::Forecast] {
            propkit::check(
                &format!("shift_conservation_{policy:?}"),
                0x5348_4946,
                12,
                |rng| {
                    let epochs = 10 + rng.below(20);
                    (lot_trace(&cfg, epochs, rng), rng.next_u64())
                },
                |(trace, seed)| {
                    let (outs, sh) = drive(&cfg, trace, policy, *seed);
                    let offered_cum: f64 =
                        outs.iter().map(|o| o.offered).sum();
                    let released_cum: f64 =
                        outs.iter().map(|o| o.released_mass).sum();
                    let expired_cum: f64 =
                        outs.iter().map(|o| o.expired).sum();
                    // integral masses: conservation is exact
                    propkit::mass_balance(
                        offered_cum,
                        &[released_cum, expired_cum, sh.queue_mass()],
                    )?;
                    if expired_cum != 0.0 {
                        return Err(format!("missed deadlines: {expired_cum}"));
                    }
                    // deadlines clamp to the horizon, so the queue drains
                    if sh.queue_mass() != 0.0 {
                        return Err(format!(
                            "queue not drained: {}",
                            sh.queue_mass()
                        ));
                    }
                    let (o, r, e) = sh.totals();
                    if (o, r, e) != (offered_cum, released_cum, 0.0) {
                        return Err(format!(
                            "totals diverge from per-epoch sums: \
                             ({o}, {r}, {e}) vs ({offered_cum}, \
                             {released_cum}, 0)"
                        ));
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn immediate_policy_releases_on_arrival() {
        let cfg = hourly_cfg();
        let mut rng = Rng::new(3);
        let trace = lot_trace(&cfg, 12, &mut rng);
        let (outs, _) = drive(&cfg, &trace, ShiftPolicy::Immediate, 3);
        for (t, o) in outs.iter().enumerate() {
            assert_eq!(o.released_mass, o.offered, "epoch {t}");
            assert_eq!(o.queued, 0.0);
        }
    }

    #[test]
    fn forecast_policy_moves_mass_but_conserves_it() {
        let cfg = hourly_cfg();
        let mut rng = Rng::new(9);
        let trace = lot_trace(&cfg, 30, &mut rng);
        let (imm, _) = drive(&cfg, &trace, ShiftPolicy::Immediate, 9);
        let (fcp, _) = drive(&cfg, &trace, ShiftPolicy::Forecast, 9);
        let sum =
            |o: &[ShiftOutcome]| o.iter().map(|x| x.released_mass).sum::<f64>();
        assert_eq!(sum(&imm), sum(&fcp), "total released mass differs");
        // the whole point: the release *schedule* differs
        let moved = imm
            .iter()
            .zip(&fcp)
            .any(|(a, b)| a.released_mass != b.released_mass);
        assert!(moved, "forecast policy never shifted anything");
    }

    #[test]
    fn inactive_trace_makes_the_shifter_inert() {
        let cfg = hourly_cfg();
        let trace = Trace::generate(&cfg, 8, 4); // deferrable_frac = 0
        let mut sh =
            TemporalShifter::new(&cfg, &trace, ShiftPolicy::Forecast);
        assert!(!sh.is_active());
        assert!(sh.backtest().is_none(), "no forecaster should exist");
        let signals = GridSignals::generate(&cfg, 8, 4);
        for t in 0..8 {
            let (ci, wi, tou) = signals.at(t);
            let o = sh.step(t, 7, &trace.epochs[t], &ci, &wi, &tou);
            assert_eq!(o.offered, 0.0);
            assert_eq!(o.released_mass, 0.0);
            assert_eq!(o.queued, 0.0);
        }
    }

    #[test]
    fn shift_scheduler_delegates_and_reports_forecast_policy() {
        struct Probe;
        impl Scheduler for Probe {
            fn name(&self) -> String {
                "probe".into()
            }
            fn plan(&mut self, ctx: &EpochContext) -> Plan {
                Plan::uniform(ctx.cfg.num_classes(), ctx.cfg.datacenters.len())
            }
        }
        let s = ShiftScheduler::new(Box::new(Probe));
        assert_eq!(s.name(), "shift+probe");
        assert_eq!(s.shift_policy(), ShiftPolicy::Forecast);
        let named = ShiftScheduler::new(Box::new(Probe)).named("slit-shift");
        assert_eq!(named.name(), "slit-shift");
        // default policy on a bare scheduler is Immediate
        assert_eq!(Probe.shift_policy(), ShiftPolicy::Immediate);
    }
}
