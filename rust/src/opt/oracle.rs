//! Per-epoch lower-bound oracle (DESIGN.md §16): a *certified* lower
//! bound on the single-objective scalarization of one epoch's placement
//! problem, solved exactly as a min-cost flow on the
//! [`FlowNetwork`](crate::baselines::mcmf::FlowNetwork) substrate the
//! Helix baseline already ships.
//!
//! The epoch problem is: route each class's request mass across sites
//! (rows of a [`Plan`] sum to 1) to minimise one of the four objectives
//! the [`AnalyticEvaluator`] scores. Every objective decomposes as a
//! plan-independent constant plus per-site terms of the routed mass:
//!
//! * energy objectives (cost/water/carbon): `konst + Σ_l η_l·min(x_l, cap_l)`
//!   where `x_l` is node-seconds demanded at site l — concave in `x_l`,
//!   so the chord of `min(x, cap)` over the reachable domain `[0, xmax_l]`
//!   is a per-site *linear* underestimator and the relaxation is an
//!   assignment LP = min-cost flow;
//! * TTFT: a per-request base term (linear arc costs) plus the queueing
//!   term `reqs_l·Q(util_l)` — nondecreasing but not convex in the site's
//!   request mass, so it is underestimated by a convex piecewise-linear
//!   staircase hull expanded into parallel site→sink arcs (§16 explains
//!   why plain linearisation is unsound here).
//!
//! Costs and capacities are quantized to i64 fixed point with *floor*
//! rounding (which can only lower a minimum) and the demand left behind
//! by unit-flooring is charged against the bound analytically, so the
//! reported score is a certified lower bound, not an estimate:
//!
//!     oracle.score() = raw − quantization_slack ≤ min over valid plans
//!
//! up to the repo's 1e-9 relative FP discipline, which the explicit
//! `quantization_slack` margin also absorbs. [`gap_reports`] packages the
//! per-objective comparison the [`SimSession`](crate::session::SimSession)
//! threads into the `EpochLedger` and the epoch CSV.

use crate::baselines::mcmf::FlowNetwork;
use crate::config::{N_OBJ, OBJ_COST, OBJ_TTFT, OBJ_WATER};
use crate::eval::AnalyticEvaluator;
use crate::models::{total_energy_factor, J_PER_KWH};
use crate::plan::Plan;

/// Flow units the epoch's total request mass is quantized into. Finer
/// units shrink the floored-residual slack (~K/QUANT_DEMAND relative);
/// 4096 puts it far below the 1e-2 gap resolution the matrix pins while
/// keeping the flow solve in the tens of microseconds.
const QUANT_DEMAND: f64 = 4096.0;

/// Staircase samples per site for the TTFT queue-term hull. The hull is
/// sound for any count >= 1; more segments only tighten it.
const QUEUE_SEGMENTS: usize = 24;

/// Target magnitude for quantized arc costs: |cost| <= 2^40 keeps the
/// worst-case path sum (< 2^53 across 4096 units) exactly representable
/// in i64 *and* in the f64 the bound is reported in.
const COST_SCALE: f64 = (1u64 << 40) as f64;

/// FP-discipline margin folded into `quantization_slack`: the bound and
/// the evaluator compute the same physics in different association
/// orders, so the certified comparison concedes 1e-9 relative.
const FP_REL_MARGIN: f64 = 1e-9;
const FP_ABS_MARGIN: f64 = 1e-12;

/// A certified lower bound: `raw` is the quantized optimum plus the
/// plan-independent constant; `slack` is everything the certification
/// argument concedes (floored demand residue + FP margin). Only
/// `score()` = `raw - slack` is guaranteed `<=` every valid plan's
/// analytic score.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct OracleBound {
    pub raw: f64,
    pub slack: f64,
}

impl OracleBound {
    /// The certified lower bound on the objective.
    pub fn score(&self) -> f64 {
        self.raw - self.slack
    }
}

/// One epoch's oracle-vs-achieved comparison on a single objective —
/// what the session accumulates into the ledger and the epoch CSV.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct GapReport {
    /// Certified lower bound ([`OracleBound::score`]).
    pub oracle_score: f64,
    /// The framework plan's analytic score on this objective.
    pub achieved: f64,
    /// `(achieved - oracle_score) / |achieved|` — 0 means provably
    /// optimal, 1 means the oracle certifies nothing beyond >= 0.
    pub gap_frac: f64,
    /// The slack term of the bound (reported so a reader can see how
    /// much of the gap is certification cost rather than plan quality).
    pub quantization_slack: f64,
}

/// Certified lower bound on objective `obj` for the epoch the evaluator
/// is bound to, over all valid plans (rows nonnegative, summing to 1).
pub fn epoch_lower_bound(ev: &AnalyticEvaluator, obj: usize) -> OracleBound {
    if obj == OBJ_TTFT {
        ttft_bound(ev)
    } else {
        energy_bound(ev, obj)
    }
}

/// Compare one plan against the oracle on one objective.
pub fn gap_for_plan(ev: &AnalyticEvaluator, plan: &Plan, obj: usize) -> GapReport {
    report(epoch_lower_bound(ev, obj), ev.evaluate(plan)[obj])
}

/// All four objectives at once (one evaluation of the plan, four flow
/// solves). Pure and RNG-free: bit-deterministic for a given evaluator.
pub fn gap_reports(ev: &AnalyticEvaluator, plan: &Plan) -> [GapReport; N_OBJ] {
    let achieved = ev.evaluate(plan);
    let mut out = [GapReport::default(); N_OBJ];
    for (obj, slot) in out.iter_mut().enumerate() {
        *slot = report(epoch_lower_bound(ev, obj), achieved[obj]);
    }
    out
}

fn report(bound: OracleBound, achieved: f64) -> GapReport {
    let score = bound.score();
    GapReport {
        oracle_score: score,
        achieved,
        gap_frac: (achieved - score) / achieved.abs().max(1e-12),
        quantization_slack: bound.slack,
    }
}

/// Node-seconds one request of class `k` demands at site `l` — the same
/// `tok_out/thr` ratio the evaluator folds into its contraction weights.
#[inline]
fn tau(ev: &AnalyticEvaluator, k: usize, l: usize) -> f64 {
    ev.cp.tok_out[k] / ev.cp.thr[k * ev.dcs() + l]
}

/// The epoch's request mass floored into integer flow units. `residual`
/// is the per-class mass the flooring leaves unrouted — charged to the
/// slack at that class's most favourable (most negative) arc cost.
struct Demand {
    units: Vec<i64>,
    residual: Vec<f64>,
    /// Requests per flow unit.
    unit: f64,
    total: i64,
}

fn quantize_demand(n_req: &[f64]) -> Demand {
    let raw: f64 = n_req.iter().map(|&r| r.max(0.0)).sum();
    let unit = if raw > 0.0 { raw / QUANT_DEMAND } else { 1.0 };
    let mut units = Vec::with_capacity(n_req.len());
    let mut residual = Vec::with_capacity(n_req.len());
    let mut total = 0i64;
    for &r in n_req {
        let r = r.max(0.0);
        let u = (r / unit).floor() as i64;
        units.push(u);
        residual.push((r - u as f64 * unit).max(0.0));
        total += u;
    }
    Demand {
        units,
        residual,
        unit,
        total,
    }
}

/// One site→sink arc of a convex piecewise-linear site cost: `cap` flow
/// units at `slope` objective-units each. Slopes are nondecreasing per
/// site, so min-cost flow fills segments in order and the arc bundle
/// prices exactly the hull function.
struct Segment {
    cap: i64,
    slope: f64,
}

/// Solve the quantized routing LP: S → class (cap = units) → site
/// (per-request arc cost) → T (free, or the PWL segments). Returns the
/// de-scaled flow optimum and the floored-demand mass slack. Both arc
/// cost flooring and the LP/integral-flow equivalence of the network
/// matrix keep the returned value a lower bound on the *fractional*
/// optimum of the quantized demand.
fn solve_routing(
    d: &Demand,
    cost_per_req: &[f64],
    l_n: usize,
    site_pwl: Option<&[Vec<Segment>]>,
) -> (f64, f64) {
    let k_n = d.units.len();
    debug_assert_eq!(cost_per_req.len(), k_n * l_n);
    let mut mass_slack = 0.0;
    for k in 0..k_n {
        if d.residual[k] > 0.0 {
            let cmin = (0..l_n)
                .map(|l| cost_per_req[k * l_n + l])
                .fold(f64::INFINITY, f64::min);
            mass_slack += d.residual[k] * (-cmin).max(0.0);
        }
    }
    if d.total == 0 {
        return (0.0, mass_slack);
    }

    // fixed-point scale from the largest magnitude on any arc
    let mut max_abs = 0.0f64;
    for k in 0..k_n {
        if d.units[k] == 0 {
            continue;
        }
        for l in 0..l_n {
            max_abs = max_abs.max((cost_per_req[k * l_n + l] * d.unit).abs());
        }
    }
    if let Some(pwl) = site_pwl {
        for segs in pwl {
            for s in segs {
                max_abs = max_abs.max(s.slope.abs());
            }
        }
    }
    let scale = if max_abs > 0.0 { COST_SCALE / max_abs } else { 1.0 };
    let q = |c: f64| (c * scale).floor() as i64;

    let mut g = FlowNetwork::new(k_n + l_n + 2);
    let s = k_n + l_n;
    let t = s + 1;
    for k in 0..k_n {
        if d.units[k] == 0 {
            continue;
        }
        g.add_edge(s, k, d.units[k], 0);
        for l in 0..l_n {
            g.add_edge(k, k_n + l, d.units[k], q(cost_per_req[k * l_n + l] * d.unit));
        }
    }
    for l in 0..l_n {
        match site_pwl {
            Some(pwl) => {
                for seg in &pwl[l] {
                    if seg.cap > 0 {
                        g.add_edge(k_n + l, t, seg.cap, q(seg.slope));
                    }
                }
            }
            None => {
                g.add_edge(k_n + l, t, d.total, 0);
            }
        }
    }
    let (flow, qcost) = g.min_cost_max_flow(s, t);
    assert_eq!(
        flow, d.total,
        "oracle routing graph must absorb all quantized demand"
    );
    (qcost as f64 / scale, mass_slack)
}

fn finish_bound(konst: f64, flow_val: f64, mass_slack: f64) -> OracleBound {
    let raw = konst + flow_val;
    let slack = mass_slack
        + FP_REL_MARGIN * (konst.abs() + flow_val.abs() + mass_slack)
        + FP_ABS_MARGIN;
    OracleBound { raw, slack }
}

/// Cost/water/carbon: `konst + Σ_l η_l·min(x_l, cap_l)` with
/// `x_l = Σ_k m_kl·τ_kl` node-seconds. `min(x, cap)` is concave, so its
/// chord over `[0, xmax_l]` underestimates it when `η_l >= 0`; when
/// `η_l < 0` (unused power above on-power — never in shipped configs,
/// handled anyway) the tangent at 0 (`slope η_l`) underestimates the
/// then-convex term. The relaxation is a pure assignment flow.
fn energy_bound(ev: &AnalyticEvaluator, obj: usize) -> OracleBound {
    let l_n = ev.dcs();
    let k_n = ev.classes();
    let c = &ev.consts;
    let evap = (1.0 / c.h_water) * (1.0 + 1.0 / (1.0 - c.d_ratio));

    let mut xmax = vec![0.0f64; l_n];
    for k in 0..k_n {
        let r = ev.cp.n_req[k].max(0.0);
        if r > 0.0 {
            for (l, x) in xmax.iter_mut().enumerate() {
                *x += r * tau(ev, k, l);
            }
        }
    }

    let mut konst = 0.0;
    let mut rho = vec![0.0f64; l_n];
    for l in 0..l_n {
        let f_kwh = total_energy_factor(ev.dp.cop[l]) / J_PER_KWH;
        // objective units per joule of IT energy at this site
        let per_j = match obj {
            OBJ_COST => f_kwh * ev.dp.tou[l],
            OBJ_WATER => evap + f_kwh * ev.dp.wi[l],
            // OBJ_CARBON: grid kWh + (onsite evaporative + grid-embedded
            // water) priced back through the site's carbon intensity
            _ => ev.dp.ci[l]
                * (f_kwh * (1.0 + ev.dp.wi[l] * c.ei_waste) + evap * c.ei_pot),
        };
        let eta = per_j * (c.pr_on - ev.dp.unused_pr[l]) * ev.dp.tdp[l];
        konst += per_j * ev.dp.nodes[l] * ev.dp.unused_pr[l] * ev.dp.tdp[l] * c.epoch_s;
        let cap_s = ev.dp.nodes[l] * c.epoch_s;
        rho[l] = if eta >= 0.0 && xmax[l] > cap_s {
            // xmax > cap >= 0 implies xmax > 0: the division is safe
            eta * cap_s / xmax[l]
        } else {
            eta
        };
    }

    let d = quantize_demand(&ev.cp.n_req);
    let mut cost = vec![0.0f64; k_n * l_n];
    for k in 0..k_n {
        for l in 0..l_n {
            cost[k * l_n + l] = tau(ev, k, l) * rho[l];
        }
    }
    let (flow_val, mass_slack) = solve_routing(&d, &cost, l_n, None);
    finish_bound(konst, flow_val, mass_slack)
}

/// TTFT: per-request base cost (cold load + migration + proc — exactly
/// the evaluator's `wk_ttft` expression) on the class→site arcs, plus a
/// convex PWL underestimator of each site's queue term on the site→sink
/// arcs, all divided by the evaluator's request denominator.
fn ttft_bound(ev: &AnalyticEvaluator) -> OracleBound {
    let l_n = ev.dcs();
    let k_n = ev.classes();
    let c = &ev.consts;
    let total_req = ev.total_requests();
    let d = quantize_demand(&ev.cp.n_req);

    let mut cost = vec![0.0f64; k_n * l_n];
    for k in 0..k_n {
        for l in 0..l_n {
            let i = k * l_n + l;
            cost[i] = c.cold_frac * ev.cp.mem[k] / ev.dp.bw[l]
                + 2.0 * ev.cp.hops[i] * c.k_media
                + ev.cp.proc[i];
        }
    }

    // per site, the cheapest node-seconds any routable request can cost:
    // x requests at site l demand >= sigma_min_l * x node-seconds, and the
    // queue delay is nondecreasing in demanded node-seconds
    let pwl: Vec<Vec<Segment>> = (0..l_n)
        .map(|l| {
            let sigma_min = (0..k_n)
                .filter(|&k| ev.cp.n_req[k] > 0.0)
                .map(|k| tau(ev, k, l))
                .fold(f64::INFINITY, f64::min);
            let sigma_min = if sigma_min.is_finite() { sigma_min } else { 0.0 };
            queue_hull(d.total, d.unit, sigma_min, ev.dp.nodes[l], c)
        })
        .collect();

    let (flow_val, mass_slack) = solve_routing(&d, &cost, l_n, Some(&pwl));
    finish_bound(0.0, flow_val / total_req, mass_slack / total_req)
}

/// Convex PWL underestimator of the site queue term
/// `g(x) = x·Q(util(sigma_min·x))` over `[0, total]` flow units, built as
/// the lower convex hull of the left-shifted staircase
/// `{(0,0)} ∪ {(b_{j+1}, g(b_j))}`: each hull value sits at or below the
/// infimum of `g` on its segment because `g` is nondecreasing, and the
/// hull is convex by construction, so its segments expand into
/// nondecreasing-slope parallel arcs (DESIGN.md §16).
fn queue_hull(
    total: i64,
    unit: f64,
    sigma_min: f64,
    nodes: f64,
    c: &crate::eval::EvalConsts,
) -> Vec<Segment> {
    if total <= 0 {
        return Vec::new();
    }
    let g_at = |units: i64| -> f64 {
        let m = units as f64 * unit;
        let on = (sigma_min * m / c.epoch_s).min(nodes);
        let util = on / nodes.max(1.0);
        m * (c.q_coef * util / (1.0 - util.min(c.u_max)))
    };
    let segs = QUEUE_SEGMENTS.min(total as usize).max(1);
    let mut pts: Vec<(i64, f64)> = vec![(0, 0.0)];
    let mut prev_b = 0i64;
    for j in 1..=segs {
        let b = ((total as i128 * j as i128) / segs as i128) as i64;
        if b <= prev_b {
            continue;
        }
        pts.push((b, g_at(prev_b)));
        prev_b = b;
    }
    // lower convex hull (monotone chain): drop middle points that sit on
    // or above the line through their neighbours
    let mut hull: Vec<(i64, f64)> = Vec::new();
    for p in pts {
        while hull.len() >= 2 {
            let a = hull[hull.len() - 2];
            let b = hull[hull.len() - 1];
            let lhs = (b.1 - a.1) * (p.0 - b.0) as f64;
            let rhs = (p.1 - b.1) * (b.0 - a.0) as f64;
            if lhs >= rhs {
                hull.pop();
            } else {
                break;
            }
        }
        hull.push(p);
    }
    hull.windows(2)
        .map(|w| Segment {
            cap: w[1].0 - w[0].0,
            slope: (w[1].1 - w[0].1) / ((w[1].0 - w[0].0) as f64),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::build_panels;
    use crate::config::{SystemConfig, OBJ_CARBON};
    use crate::eval::EvalConsts;
    use crate::power::GridSignals;
    use crate::trace::Trace;
    use crate::util::rng::Rng;

    fn make_eval(unused_pr: f64) -> (SystemConfig, AnalyticEvaluator) {
        let cfg = SystemConfig::paper_default();
        let signals = GridSignals::generate(&cfg, 8, 3);
        let trace = Trace::generate(&cfg, 8, 3);
        let (cp, dp) =
            build_panels(&cfg, &signals, 4, &trace.epochs[4], unused_pr);
        let consts = EvalConsts::from_physics(&cfg.physics);
        let ev = AnalyticEvaluator::new(cp, dp, consts);
        (cfg, ev)
    }

    fn scaled_demand(ev: &AnalyticEvaluator, mult: f64) -> AnalyticEvaluator {
        let mut cp = ev.cp.clone();
        for r in &mut cp.n_req {
            *r *= mult;
        }
        AnalyticEvaluator::new(cp, ev.dp.clone(), ev.consts)
    }

    #[test]
    fn oracle_below_random_plans_all_objectives() {
        for &unused in &[0.05, 0.3] {
            let (cfg, ev) = make_eval(unused);
            let mut rng = Rng::new(0x0AC1E);
            let mut plans: Vec<Plan> = (0..16)
                .map(|_| Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng))
                .collect();
            plans.push(Plan::uniform(cfg.num_classes(), ev.dcs()));
            for l in 0..ev.dcs() {
                plans.push(Plan::one_dc(cfg.num_classes(), ev.dcs(), l));
            }
            plans.extend(ev.greedy_seed_plans());
            for obj in 0..N_OBJ {
                let bound = epoch_lower_bound(&ev, obj);
                assert!(bound.score().is_finite());
                for p in &plans {
                    let achieved = ev.evaluate(p)[obj];
                    assert!(
                        bound.score() <= achieved,
                        "obj {obj} unused {unused}: oracle {} > achieved {}",
                        bound.score(),
                        achieved
                    );
                }
            }
        }
    }

    #[test]
    fn gap_report_fields_are_consistent() {
        let (cfg, ev) = make_eval(0.05);
        let plan = Plan::uniform(cfg.num_classes(), ev.dcs());
        let reports = gap_reports(&ev, &plan);
        let achieved = ev.evaluate(&plan);
        for (obj, g) in reports.iter().enumerate() {
            assert_eq!(g.achieved, achieved[obj]);
            assert!(g.gap_frac >= 0.0, "obj {obj}: {g:?}");
            assert!(g.gap_frac.is_finite());
            assert!(g.quantization_slack >= 0.0);
            let single = gap_for_plan(&ev, &plan, obj);
            assert_eq!(&single, g, "single-objective path must agree");
        }
    }

    #[test]
    fn linear_regime_bound_is_nearly_tight() {
        // demand scaled far below every site's capacity: no site saturates,
        // the energy objectives are exactly linear in routed mass, and the
        // optimum routes each class to its cheapest marginal site — the
        // oracle must certify that plan as near-optimal (the only give is
        // the floored demand residue and the FP margin)
        let (cfg, ev) = make_eval(0.05);
        let ev = scaled_demand(&ev, 1e-3);
        let l_n = ev.dcs();
        let c = &ev.consts;
        let evap = (1.0 / c.h_water) * (1.0 + 1.0 / (1.0 - c.d_ratio));
        for obj in [OBJ_CARBON, OBJ_WATER, OBJ_COST] {
            let mut best = Plan::one_dc(cfg.num_classes(), l_n, 0);
            for k in 0..ev.classes() {
                let arg = (0..l_n)
                    .min_by(|&a, &b| {
                        let marg = |l: usize| {
                            let f_kwh =
                                total_energy_factor(ev.dp.cop[l]) / J_PER_KWH;
                            let per_j = match obj {
                                OBJ_COST => f_kwh * ev.dp.tou[l],
                                OBJ_WATER => evap + f_kwh * ev.dp.wi[l],
                                _ => ev.dp.ci[l]
                                    * (f_kwh
                                        * (1.0 + ev.dp.wi[l] * c.ei_waste)
                                        + evap * c.ei_pot),
                            };
                            tau(&ev, k, l)
                                * per_j
                                * (c.pr_on - ev.dp.unused_pr[l])
                                * ev.dp.tdp[l]
                        };
                        marg(a).partial_cmp(&marg(b)).unwrap()
                    })
                    .unwrap();
                for l in 0..l_n {
                    best.set(k, l, if l == arg { 1.0 } else { 0.0 });
                }
            }
            let g = gap_for_plan(&ev, &best, obj);
            assert!(
                g.gap_frac >= 0.0 && g.gap_frac <= 1e-2,
                "obj {obj}: gap {} not tight in linear regime ({g:?})",
                g.gap_frac
            );
        }
    }

    #[test]
    fn ttft_oracle_prices_queueing() {
        // saturate the whole fleet: the PWL queue arcs must lift the bound
        // strictly above the pure base-latency (queue-free) floor
        let (_, ev) = make_eval(0.05);
        let ev = scaled_demand(&ev, 500.0);
        let l_n = ev.dcs();
        let mut base_only = 0.0;
        for k in 0..ev.classes() {
            let best = (0..l_n)
                .map(|l| {
                    let i = k * l_n + l;
                    ev.consts.cold_frac * ev.cp.mem[k] / ev.dp.bw[l]
                        + 2.0 * ev.cp.hops[i] * ev.consts.k_media
                        + ev.cp.proc[i]
                })
                .fold(f64::INFINITY, f64::min);
            base_only += ev.cp.n_req[k] * best;
        }
        base_only /= ev.total_requests();
        let bound = epoch_lower_bound(&ev, OBJ_TTFT);
        assert!(
            bound.score() > base_only * 1.000001,
            "queue term not priced: oracle {} vs base-only {base_only}",
            bound.score()
        );
        // and still sound vs the best spreading plan we know
        let spread = Plan::uniform(ev.classes(), l_n);
        assert!(bound.score() <= ev.evaluate(&spread)[OBJ_TTFT]);
    }

    #[test]
    fn zero_demand_epoch_is_handled() {
        let (cfg, ev) = make_eval(0.3);
        let mut cp = ev.cp.clone();
        for r in &mut cp.n_req {
            *r = 0.0;
        }
        let ev0 = AnalyticEvaluator::new(cp, ev.dp.clone(), ev.consts);
        let plan = Plan::uniform(cfg.num_classes(), ev0.dcs());
        for g in gap_reports(&ev0, &plan) {
            assert!(g.oracle_score.is_finite());
            assert!(g.gap_frac >= 0.0);
            assert!(g.oracle_score <= g.achieved);
        }
    }

    #[test]
    fn bound_is_bit_deterministic() {
        let (_, ev) = make_eval(0.05);
        for obj in 0..N_OBJ {
            let a = epoch_lower_bound(&ev, obj);
            let b = epoch_lower_bound(&ev, obj);
            assert_eq!(a, b, "oracle must be pure (obj {obj})");
        }
    }

    #[test]
    fn slack_is_negligible_on_paper_fleet() {
        // all shipped configs have pr_on > unused_pr, so every arc cost is
        // nonnegative, the mass residue prices to zero, and the slack is
        // just the 1e-9 FP margin
        let (_, ev) = make_eval(0.05);
        for obj in 0..N_OBJ {
            let b = epoch_lower_bound(&ev, obj);
            assert!(
                b.slack <= 1e-6 * (b.raw.abs() + 1.0),
                "obj {obj}: slack {} vs raw {}",
                b.slack,
                b.raw
            );
        }
    }
}
