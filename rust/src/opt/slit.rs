//! The SLIT metaheuristic (Algorithm 1): ML-guided local search + an
//! evolutionary algorithm over scheduling plans, maintaining a Pareto
//! archive across the four objectives.
//!
//! Structure follows the paper's pseudocode:
//!   * init: partially random population including the two extreme plans
//!     (evenly-distributed, single-location);
//!   * ML-guided search (lines 3-11): per plan, candidate neighbours are
//!     ranked by the gradient-boosting surrogate and only the promising
//!     ones pay for a true evaluation; trajectories accumulate in Y_train
//!     and the surrogate retrains every `freq` generations;
//!   * EA (lines 12-20): random-parent crossover + mutation injects the
//!     searched traits into unexplored regions;
//!   * `update_population` keeps only dominant plans (pareto::ParetoArchive)
//!     and the working population is refreshed by rank + crowding.

use std::time::Instant;

use crate::config::{OptConfig, N_OBJ};
use crate::eval::{BatchEvaluator, MemoizedEvaluator};
use crate::opt::gbdt::{Gbdt, GbdtConfig};
use crate::pareto::{crowding_distances, dominates, ParetoArchive, Solution};
use crate::plan::Plan;
use crate::util::rng::Rng;

/// Cap on the surrogate training-set size (most recent trajectories win).
const MAX_TRAIN_SAMPLES: usize = 768;

/// Ablation / instrumentation switches.
#[derive(Clone, Copy, Debug)]
pub struct SlitOptions {
    /// Use the GBDT surrogate to pre-rank neighbours (off = random half).
    pub use_surrogate: bool,
    /// Run the EA phase.
    pub use_ea: bool,
}

impl Default for SlitOptions {
    fn default() -> Self {
        SlitOptions {
            use_surrogate: true,
            use_ea: true,
        }
    }
}

/// Result of one optimizer run (one epoch's planning).
#[derive(Debug)]
pub struct SlitOutcome {
    pub archive: ParetoArchive,
    /// True-evaluator calls spent (memoization cache misses).
    pub evaluations: usize,
    /// Evaluations answered from the plan-fingerprint cache for free.
    pub cache_hits: usize,
    pub generations_run: usize,
    pub surrogate_trainings: usize,
    pub wall_s: f64,
}

/// The metaheuristic runner. Stateless across epochs except the RNG; the
/// surrogate is rebuilt per epoch because the objective landscape moves
/// with the grid signals and predicted load.
pub struct SlitOptimizer {
    pub opt: OptConfig,
    pub options: SlitOptions,
    classes: usize,
    dcs: usize,
    rng: Rng,
}

impl SlitOptimizer {
    pub fn new(opt: OptConfig, classes: usize, dcs: usize, seed: u64) -> Self {
        SlitOptimizer {
            opt,
            options: SlitOptions::default(),
            classes,
            dcs,
            rng: Rng::new(seed ^ 0x534C_4954), // "SLIT"
        }
    }

    pub fn with_options(mut self, options: SlitOptions) -> Self {
        self.options = options;
        self
    }

    /// Run Algorithm 1 against `eval`; respects the per-epoch budget.
    pub fn optimize(&mut self, eval: &dyn BatchEvaluator) -> SlitOutcome {
        self.optimize_with_seeds(eval, &[])
    }

    /// Run Algorithm 1 with extra seed plans injected into the initial
    /// population (e.g. `AnalyticEvaluator::greedy_seed_plans`).
    ///
    /// Every true evaluation goes through a [`MemoizedEvaluator`] wrapped
    /// around `eval`, and the ML-guided search advances all population
    /// slots in lockstep so each step's surviving candidates form **one**
    /// batch — that batch is what fans out over the thread pool
    /// (`util::threadpool::par_map` inside the evaluator), instead of the
    /// per-slot trickle of tiny batches the per-plan loop used to emit.
    pub fn optimize_with_seeds(
        &mut self,
        eval: &dyn BatchEvaluator,
        seeds: &[Plan],
    ) -> SlitOutcome {
        let start = Instant::now();
        let budget = self.opt.budget_s;
        let x = self.opt.population;
        let memo = MemoizedEvaluator::new(eval);
        let mut archive = ParetoArchive::new(self.opt.archive_cap);
        let mut surrogate: Option<Gbdt> = None;
        let mut surrogate_trainings = 0usize;
        // Y_train: (plan features, scalarised score)
        let mut y_train: Vec<(Vec<f64>, f64)> = Vec::new();
        // running objective bounds for scalarisation
        let mut lo = [f64::INFINITY; N_OBJ];
        let mut hi = [f64::NEG_INFINITY; N_OBJ];

        // --- initial population: two extremes + seeds + random
        //     (Algorithm 1 init, memetically strengthened)
        let mut plans: Vec<Plan> = Vec::with_capacity(x);
        plans.push(Plan::uniform(self.classes, self.dcs));
        plans.push(Plan::one_dc(
            self.classes,
            self.dcs,
            self.rng.below(self.dcs),
        ));
        for s in seeds.iter().take(x.saturating_sub(plans.len())) {
            debug_assert_eq!(s.classes, self.classes);
            debug_assert_eq!(s.dcs, self.dcs);
            plans.push(s.clone());
        }
        while plans.len() < x {
            let alpha = self.rng.range(0.1, 1.0);
            plans.push(Plan::random(self.classes, self.dcs, alpha, &mut self.rng));
        }
        let objs = memo.eval_batch(&plans);
        let mut population: Vec<Solution> = plans
            .into_iter()
            .zip(objs)
            .map(|(plan, obj)| Solution { plan, obj })
            .collect();
        for s in &population {
            update_bounds(&mut lo, &mut hi, &s.obj);
            archive.insert(s.clone());
        }

        let mut generations_run = 0usize;
        for gen in 0..self.opt.generations {
            if start.elapsed().as_secs_f64() > budget {
                break;
            }
            generations_run = gen + 1;

            // --- ML-guided local search (lines 3-11) -----------------------
            // diversified scalarisation: each population slot climbs its own
            // objective mix (4 single-objective specialists + balanced),
            // so the archive's extreme points get real search pressure —
            // that's where SLIT-Carbon/-TTFT/-Water/-Cost come from.
            //
            // All slots move in lockstep: per step, neighbour generation and
            // surrogate ranking stay sequential on the main thread (they own
            // the RNG, keeping runs seed-deterministic), while the one merged
            // candidate batch pays for true evaluations in parallel.
            let mut current: Vec<Solution> = population.clone();
            let mut out_of_budget = false;
            for _ in 0..self.opt.search_steps {
                if start.elapsed().as_secs_f64() > budget {
                    break;
                }
                // 1) propose + surrogate-filter candidates for every slot.
                //    The budget is re-checked per slot (the old per-plan
                //    granularity): on overrun the remaining slots are
                //    skipped, the truncated batch still gets evaluated —
                //    ranges and candidates stay aligned — and the search
                //    ends after this step.
                let mut chosen_all: Vec<Plan> = Vec::with_capacity(
                    current.len() * (self.opt.neighbors / 2).max(1),
                );
                let mut ranges: Vec<(usize, usize)> =
                    Vec::with_capacity(current.len());
                for cur in &current {
                    if start.elapsed().as_secs_f64() > budget {
                        out_of_budget = true;
                        break;
                    }
                    let mut cands: Vec<Plan> =
                        Vec::with_capacity(self.opt.neighbors);
                    for c in 0..self.opt.neighbors {
                        let p = match c % 4 {
                            // directed move toward a random DC
                            2 => {
                                let k = self.rng.below(self.classes);
                                let to = self.rng.below(self.dcs);
                                cur.plan.shifted_toward(
                                    k,
                                    to,
                                    self.rng.range(0.2, 0.8),
                                )
                            }
                            // snap-to-vertex: collapse one row onto its
                            // argmax, erasing residual routing mass (the
                            // single-objective optima live on vertices)
                            3 => {
                                let k = self.rng.below(self.classes);
                                let row = cur.plan.row(k);
                                let best = row
                                    .iter()
                                    .enumerate()
                                    .max_by(|a, b| {
                                        a.1.partial_cmp(b.1).unwrap()
                                    })
                                    .map(|(l, _)| l)
                                    .unwrap_or(0);
                                cur.plan.shifted_toward(k, best, 1.0)
                            }
                            _ => cur
                                .plan
                                .perturbed(self.opt.step, &mut self.rng),
                        };
                        cands.push(p);
                    }
                    // surrogate pre-ranking: keep the most promising half
                    let chosen: Vec<Plan> = match (&surrogate,
                        self.options.use_surrogate)
                    {
                        (Some(model), true) => {
                            let mut scored: Vec<(f64, Plan)> = cands
                                .into_iter()
                                .map(|p| {
                                    (model.predict(p.as_slice()), p)
                                })
                                .collect();
                            scored.sort_by(|a, b| {
                                a.0.partial_cmp(&b.0).unwrap()
                            });
                            scored
                                .into_iter()
                                .take((self.opt.neighbors / 2).max(1))
                                .map(|(_, p)| p)
                                .collect()
                        }
                        _ => cands
                            .into_iter()
                            .take((self.opt.neighbors / 2).max(1))
                            .collect(),
                    };
                    let lo_i = chosen_all.len();
                    chosen_all.extend(chosen);
                    ranges.push((lo_i, chosen_all.len()));
                }
                // 2) one true-evaluation batch for the whole population
                //    (parallel inside, memoized across steps/generations)
                let objs = memo.eval_batch(&chosen_all);
                // 3) trajectory capture + archive update + move selection;
                //    ranges are consecutive, so the batch is consumed in
                //    order by value (no per-candidate plan clone)
                let mut candidates = chosen_all.into_iter().zip(objs);
                for (si, &(s_i, e_i)) in ranges.iter().enumerate() {
                    let weights = slot_weights(si);
                    let mut best: Option<Solution> = None;
                    for _ in s_i..e_i {
                        let (plan, obj) = candidates
                            .next()
                            .expect("candidate count matches ranges");
                        update_bounds(&mut lo, &mut hi, &obj);
                        let score = scalarize(&obj, &lo, &hi);
                        y_train.push((plan.as_slice().to_vec(), score));
                        let sol = Solution { plan, obj };
                        archive.insert(sol.clone());
                        let better = match &best {
                            None => true,
                            Some(b) => {
                                scalarize_w(&obj, &weights, &lo, &hi)
                                    < scalarize_w(&b.obj, &weights, &lo, &hi)
                            }
                        };
                        if better {
                            best = Some(sol);
                        }
                    }
                    if let Some(cand) = best {
                        let cur_score = scalarize_w(
                            &current[si].obj,
                            &weights,
                            &lo,
                            &hi,
                        );
                        let cand_score =
                            scalarize_w(&cand.obj, &weights, &lo, &hi);
                        if dominates(&cand.obj, &current[si].obj)
                            || cand_score < cur_score
                        {
                            current[si] = cand;
                        }
                    }
                }
                if out_of_budget {
                    break;
                }
            }
            population = select_population(
                population.into_iter().chain(current).collect(),
                x,
            );

            // --- surrogate retraining (lines 10-11) ------------------------
            if self.options.use_surrogate
                && gen % self.opt.train_freq == self.opt.train_freq - 1
                && y_train.len() >= 32
                && start.elapsed().as_secs_f64() <= budget
            {
                // keep training bounded: most recent trajectories + column
                // subsampling keep one fit well inside the epoch budget
                let take = y_train.len().min(MAX_TRAIN_SAMPLES);
                let tail = &y_train[y_train.len() - take..];
                let xs: Vec<Vec<f64>> =
                    tail.iter().map(|(f, _)| f.clone()).collect();
                let ys: Vec<f64> = tail.iter().map(|(_, s)| *s).collect();
                let d = xs[0].len();
                let cfg = GbdtConfig {
                    trees: self.opt.gbdt_trees,
                    depth: self.opt.gbdt_depth,
                    learning_rate: self.opt.gbdt_lr,
                    min_leaf: self.opt.gbdt_min_leaf,
                    feature_sample: (d / 6).max(8).min(d),
                };
                surrogate = Some(Gbdt::fit(&xs, &ys, &cfg, &mut self.rng));
                surrogate_trainings += 1;
                y_train.clear(); // paper: Y_train = empty after training
            }

            // --- EA phase (lines 12-20) ------------------------------------
            if self.options.use_ea && start.elapsed().as_secs_f64() <= budget {
                let mut children: Vec<Plan> = Vec::with_capacity(x);
                for _ in 0..x {
                    let p1 = self.rng.below(population.len());
                    let p2 = self.rng.below(population.len());
                    let child = population[p1]
                        .plan
                        .crossover(&population[p2].plan, &mut self.rng)
                        .mutated(self.opt.mutation_rate, &mut self.rng);
                    children.push(child);
                }
                let objs = memo.eval_batch(&children);
                let mut child_solutions = Vec::with_capacity(children.len());
                for (plan, obj) in children.into_iter().zip(objs) {
                    update_bounds(&mut lo, &mut hi, &obj);
                    y_train.push((
                        plan.as_slice().to_vec(),
                        scalarize(&obj, &lo, &hi),
                    ));
                    let sol = Solution { plan, obj };
                    archive.insert(sol.clone());
                    child_solutions.push(sol);
                }
                population = select_population(
                    population.into_iter().chain(child_solutions).collect(),
                    x,
                );
            }
        }

        SlitOutcome {
            archive,
            evaluations: memo.misses(),
            cache_hits: memo.hits(),
            generations_run,
            surrogate_trainings,
            wall_s: start.elapsed().as_secs_f64(),
        }
    }
}

fn update_bounds(lo: &mut [f64; N_OBJ], hi: &mut [f64; N_OBJ], obj: &[f64; N_OBJ]) {
    for i in 0..N_OBJ {
        lo[i] = lo[i].min(obj[i]);
        hi[i] = hi[i].max(obj[i]);
    }
}

/// Normalised-sum scalarisation against running bounds (lower is better).
fn scalarize(obj: &[f64; N_OBJ], lo: &[f64; N_OBJ], hi: &[f64; N_OBJ]) -> f64 {
    scalarize_w(obj, &[1.0; N_OBJ], lo, hi)
}

/// Weighted normalised-sum scalarisation.
fn scalarize_w(
    obj: &[f64; N_OBJ],
    weights: &[f64; N_OBJ],
    lo: &[f64; N_OBJ],
    hi: &[f64; N_OBJ],
) -> f64 {
    let mut s = 0.0;
    for i in 0..N_OBJ {
        if hi[i] - lo[i] > 1e-15 {
            s += weights[i] * (obj[i] - lo[i]) / (hi[i] - lo[i]);
        }
    }
    s
}

/// Objective-mix rotation over population slots: slots 0..3 specialise on
/// one objective each (with a small balanced regulariser so they don't
/// wander into absurd corners), the rest climb the balanced sum.
fn slot_weights(slot: usize) -> [f64; N_OBJ] {
    match slot % (N_OBJ + 1) {
        i if i < N_OBJ => {
            let mut w = [0.05; N_OBJ];
            w[i] = 1.0;
            w
        }
        _ => [1.0; N_OBJ],
    }
}

/// Keep `cap` solutions: non-dominated first, then crowding-sorted fill
/// (a light NSGA-II environmental selection).
pub fn select_population(mut pool: Vec<Solution>, cap: usize) -> Vec<Solution> {
    if pool.len() <= cap {
        return pool;
    }
    let mut out: Vec<Solution> = Vec::with_capacity(cap);
    while out.len() < cap && !pool.is_empty() {
        // current non-dominated front of the pool
        let mut front_idx: Vec<usize> = Vec::new();
        for i in 0..pool.len() {
            let dominated = pool
                .iter()
                .enumerate()
                .any(|(j, s)| j != i && dominates(&s.obj, &pool[i].obj));
            if !dominated {
                front_idx.push(i);
            }
        }
        if front_idx.is_empty() {
            // all mutually dominated cycles shouldn't happen; guard anyway
            front_idx = (0..pool.len()).collect();
        }
        let mut front: Vec<Solution> = Vec::with_capacity(front_idx.len());
        for &i in front_idx.iter().rev() {
            front.push(pool.swap_remove(i));
        }
        if out.len() + front.len() <= cap {
            out.extend(front);
        } else {
            let crowd = crowding_distances(&front);
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| {
                crowd[b].partial_cmp(&crowd[a]).unwrap()
            });
            for &i in order.iter().take(cap - out.len()) {
                out.push(front[i].clone());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::build_panels;
    use crate::config::{SystemConfig, OBJ_CARBON, OBJ_TTFT};
    use crate::eval::{AnalyticEvaluator, EvalConsts};
    use crate::power::GridSignals;
    use crate::trace::Trace;

    fn make_eval() -> (SystemConfig, AnalyticEvaluator) {
        let cfg = SystemConfig::paper_default();
        let signals = GridSignals::generate(&cfg, 8, 3);
        let trace = Trace::generate(&cfg, 8, 3);
        let (cp, dp) = build_panels(&cfg, &signals, 4, &trace.epochs[4], 0.05);
        let consts = EvalConsts::from_physics(&cfg.physics);
        (cfg.clone(), AnalyticEvaluator::new(cp, dp, consts))
    }

    fn run_opt(options: SlitOptions, seed: u64) -> (SystemConfig, SlitOutcome) {
        let (cfg, ev) = make_eval();
        let mut opt_cfg = cfg.opt.clone();
        opt_cfg.population = 12;
        opt_cfg.generations = 5;
        opt_cfg.search_steps = 3;
        opt_cfg.neighbors = 6;
        opt_cfg.gbdt_trees = 10;
        opt_cfg.train_freq = 2;
        let mut o = SlitOptimizer::new(
            opt_cfg,
            cfg.num_classes(),
            ev.dcs(),
            seed,
        )
        .with_options(options);
        let out = o.optimize(&ev);
        (cfg, out)
    }

    #[test]
    fn produces_consistent_nonempty_archive() {
        let (_, out) = run_opt(SlitOptions::default(), 1);
        assert!(!out.archive.is_empty());
        assert!(out.archive.is_consistent());
        assert!(out.evaluations > 50);
        assert_eq!(out.generations_run, 5);
        assert!(out.surrogate_trainings >= 1);
    }

    #[test]
    fn showcase_solutions_specialise() {
        let (_, out) = run_opt(SlitOptions::default(), 2);
        let show = out.archive.showcase();
        assert_eq!(show.len(), 5);
        // best-carbon has carbon <= best-ttft's carbon, and vice versa
        let carbon_sol = &show[OBJ_CARBON].1;
        let ttft_sol = &show[OBJ_TTFT].1;
        assert!(carbon_sol.obj[OBJ_CARBON] <= ttft_sol.obj[OBJ_CARBON]);
        assert!(ttft_sol.obj[OBJ_TTFT] <= carbon_sol.obj[OBJ_TTFT]);
    }

    #[test]
    fn optimizer_beats_uniform_plan_on_every_showcased_objective() {
        let (cfg, out) = run_opt(SlitOptions::default(), 3);
        let (_, ev) = make_eval();
        let uniform =
            ev.evaluate(&Plan::uniform(cfg.num_classes(), ev.dcs()));
        for (i, _) in crate::config::OBJ_NAMES.iter().enumerate() {
            let best = out.archive.best_for(i).unwrap();
            assert!(
                best.obj[i] <= uniform[i] * 1.001,
                "objective {i}: best {} vs uniform {}",
                best.obj[i],
                uniform[i]
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (_, a) = run_opt(SlitOptions::default(), 7);
        let (_, b) = run_opt(SlitOptions::default(), 7);
        assert_eq!(a.evaluations, b.evaluations);
        assert_eq!(a.cache_hits, b.cache_hits);
        let oa: Vec<_> = a.archive.solutions.iter().map(|s| s.obj).collect();
        let ob: Vec<_> = b.archive.solutions.iter().map(|s| s.obj).collect();
        assert_eq!(oa, ob);
    }

    #[test]
    fn memoized_evaluation_accounting_is_consistent() {
        // evaluations = cache misses; hits are free repeats — together they
        // cover every eval_batch slot the search requested
        let (_, out) = run_opt(SlitOptions::default(), 12);
        assert!(out.evaluations > 50, "unique evals {}", out.evaluations);
        // repeated runs under the same seed spend the same true-eval budget
        let (_, again) = run_opt(SlitOptions::default(), 12);
        assert_eq!(out.evaluations, again.evaluations);
        assert_eq!(out.cache_hits, again.cache_hits);
    }

    #[test]
    fn ablations_run() {
        let (_, no_sur) = run_opt(
            SlitOptions {
                use_surrogate: false,
                use_ea: true,
            },
            4,
        );
        assert_eq!(no_sur.surrogate_trainings, 0);
        let (_, no_ea) = run_opt(
            SlitOptions {
                use_surrogate: true,
                use_ea: false,
            },
            4,
        );
        assert!(!no_ea.archive.is_empty());
        assert!(no_ea.evaluations < no_sur.evaluations);
    }

    #[test]
    fn select_population_caps_and_keeps_nondominated() {
        let mk = |o: [f64; N_OBJ]| Solution {
            plan: Plan::uniform(2, 3),
            obj: o,
        };
        let pool = vec![
            mk([1.0, 9.0, 9.0, 9.0]),
            mk([9.0, 1.0, 9.0, 9.0]),
            mk([5.0, 5.0, 5.0, 5.0]),
            mk([6.0, 6.0, 6.0, 6.0]), // dominated by the previous
            mk([9.0, 9.0, 1.0, 9.0]),
        ];
        let sel = select_population(pool, 4);
        assert_eq!(sel.len(), 4);
        assert!(!sel.iter().any(|s| s.obj == [6.0, 6.0, 6.0, 6.0]));
    }

    #[test]
    fn budget_is_respected() {
        let (cfg, ev) = make_eval();
        let mut opt_cfg = cfg.opt.clone();
        opt_cfg.generations = 10_000;
        opt_cfg.budget_s = 0.2;
        let mut o =
            SlitOptimizer::new(opt_cfg, cfg.num_classes(), ev.dcs(), 1);
        let t = std::time::Instant::now();
        let out = o.optimize(&ev);
        assert!(t.elapsed().as_secs_f64() < 5.0);
        assert!(out.generations_run < 10_000);
        assert!(!out.archive.is_empty());
    }
}
