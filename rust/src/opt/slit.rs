//! The SLIT metaheuristic (Algorithm 1): ML-guided local search + an
//! evolutionary algorithm over scheduling plans, maintaining a Pareto
//! archive across the four objectives.
//!
//! Structure follows the paper's pseudocode:
//!   * init: partially random population including the two extreme plans
//!     (evenly-distributed, single-location);
//!   * ML-guided search (lines 3-11): per plan, candidate neighbours are
//!     ranked by the gradient-boosting surrogate and only the promising
//!     ones pay for a true evaluation; trajectories accumulate in Y_train
//!     and the surrogate retrains every `freq` generations;
//!   * EA (lines 12-20): random-parent crossover + mutation injects the
//!     searched traits into unexplored regions;
//!   * `update_population` keeps only dominant plans (pareto::ParetoArchive)
//!     and the working population is refreshed by rank + crowding.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use crate::config::{OptConfig, N_OBJ};
use crate::eval::{AnalyticEvaluator, BatchEvaluator, MemoizedEvaluator, PlanAgg};
use crate::opt::gbdt::{Gbdt, GbdtConfig};
use crate::pareto::{
    crowding_distances, dominates, fast_nondominated_sort, ParetoArchive,
    Solution,
};
use crate::plan::{Plan, PlanBatch};
use crate::util::rng::Rng;
use crate::util::threadpool;

/// Cap on the surrogate training-set size (most recent trajectories win).
const MAX_TRAIN_SAMPLES: usize = 768;

/// The per-slot candidate loop checks the wall-clock budget only every
/// this many population slots: `Instant::elapsed` is a clock syscall, and
/// paying one per slot per step dominated the (now O(L)) candidate
/// scoring. Overrun is still detected within 8 slots, and the truncated
/// batch keeps ranges and candidates aligned exactly as before.
const BUDGET_CHECK_STRIDE: usize = 8;

/// Fleets at/above this many sites auto-select the region-decomposed
/// search (when region tags are known and the backend can be sliced).
/// Set past the 48-site global-fleet scenario so every pre-existing
/// regime keeps its bit-identical global walk; the 256/512-site edge
/// fleets land well above it.
pub const REGION_DECOMPOSE_THRESHOLD: usize = 64;

/// Price/dual ascent sweeps per epoch in the decomposed search: each
/// sweep runs every region's subsearch concurrently, merges + rescores
/// the stitched plans, then updates the per-class demand shares against
/// the clearing price.
const PRICE_SWEEPS: usize = 3;

/// Mirror-ascent step for the per-class demand-share update (the dual
/// step on the demand-balance constraint).
const PRICE_ETA: f64 = 0.5;

/// Bounded ring of surrogate training trajectories: (plan features,
/// scalarised score). Replaces the unbounded `Vec<(Vec<f64>, f64)>` that
/// grew one feature-vector clone per candidate between trainings — the
/// ring holds the most recent [`MAX_TRAIN_SAMPLES`] samples (exactly the
/// tail the old code passed to `Gbdt::fit`), and overwritten slots reuse
/// their feature `Vec` allocation instead of reallocating per push.
struct TrainRing {
    feats: Vec<Vec<f64>>,
    scores: Vec<f64>,
    cap: usize,
    /// Next slot to (over)write.
    next: usize,
    /// Live samples (<= cap).
    len: usize,
}

impl TrainRing {
    fn new(cap: usize) -> TrainRing {
        TrainRing {
            feats: Vec::new(),
            scores: Vec::new(),
            cap: cap.max(1),
            next: 0,
            len: 0,
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    /// Record one trajectory, copying `feat` into a reused slot buffer.
    fn push(&mut self, feat: &[f64], score: f64) {
        if self.next == self.feats.len() && self.feats.len() < self.cap {
            self.feats.push(feat.to_vec());
            self.scores.push(score);
        } else {
            let slot = &mut self.feats[self.next];
            slot.clear();
            slot.extend_from_slice(feat);
            self.scores[self.next] = score;
        }
        self.next = (self.next + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    /// Forget all samples (paper: Y_train = empty after training), keeping
    /// the slot allocations for reuse.
    fn clear(&mut self) {
        self.next = 0;
        self.len = 0;
    }

    /// Copy out (features, scores) oldest-first — the order the old
    /// unbounded tail presented to `Gbdt::fit`. One clone per *training
    /// event* (rare) instead of one per candidate.
    fn training_view(&self) -> (Vec<Vec<f64>>, Vec<f64>) {
        let start = if self.len < self.cap { 0 } else { self.next };
        let mut xs = Vec::with_capacity(self.len);
        let mut ys = Vec::with_capacity(self.len);
        for i in 0..self.len {
            let j = (start + i) % self.cap;
            xs.push(self.feats[j].clone());
            ys.push(self.scores[j]);
        }
        (xs, ys)
    }
}

/// Which search strategy `optimize` runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchMode {
    /// The serial global neighbour walk (Algorithm 1 as written).
    Global,
    /// Per-region price-coordinated subsearches run concurrently on the
    /// thread pool, merged and canonically rescored each sweep
    /// (DESIGN.md §18). Requires region tags ([`SlitOptimizer::
    /// with_regions`]) and a sliceable backend; falls back to the global
    /// walk otherwise.
    RegionDecomposed,
}

/// Ablation / instrumentation switches.
#[derive(Clone, Copy, Debug)]
pub struct SlitOptions {
    /// Use the GBDT surrogate to pre-rank neighbours (off = random half).
    pub use_surrogate: bool,
    /// Run the EA phase.
    pub use_ea: bool,
    /// Forced search mode; `None` auto-selects by fleet size
    /// ([`REGION_DECOMPOSE_THRESHOLD`]).
    pub search_mode: Option<SearchMode>,
}

impl Default for SlitOptions {
    fn default() -> Self {
        SlitOptions {
            use_surrogate: true,
            use_ea: true,
            search_mode: None,
        }
    }
}

/// Result of one optimizer run (one epoch's planning).
#[derive(Debug)]
pub struct SlitOutcome {
    pub archive: ParetoArchive,
    /// True evaluations spent: full-contraction batch evals (memoization
    /// cache misses) plus O(L) delta rescorings.
    pub evaluations: usize,
    /// Evaluations answered from the plan-fingerprint cache for free.
    pub cache_hits: usize,
    /// Neighbour candidates scored incrementally (subset of
    /// `evaluations`); 0 when the backend has no delta scorer.
    pub delta_evals: usize,
    pub generations_run: usize,
    pub surrogate_trainings: usize,
    pub wall_s: f64,
}

/// The metaheuristic runner. Stateless across epochs except the RNG; the
/// surrogate is rebuilt per epoch because the objective landscape moves
/// with the grid signals and predicted load.
pub struct SlitOptimizer {
    pub opt: OptConfig,
    pub options: SlitOptions,
    classes: usize,
    dcs: usize,
    rng: Rng,
    /// The raw epoch seed, kept for deriving per-region RNG streams
    /// (`seed ^ region tag`) independent of the main stream's position.
    seed: u64,
    /// Per-site region tags (empty = unknown; the decomposed mode then
    /// falls back to the global walk).
    regions: Vec<usize>,
}

impl SlitOptimizer {
    pub fn new(opt: OptConfig, classes: usize, dcs: usize, seed: u64) -> Self {
        SlitOptimizer {
            opt,
            options: SlitOptions::default(),
            classes,
            dcs,
            rng: Rng::new(seed ^ 0x534C_4954), // "SLIT"
            seed,
            regions: Vec::new(),
        }
    }

    pub fn with_options(mut self, options: SlitOptions) -> Self {
        self.options = options;
        self
    }

    /// Supply per-site region tags (`cfg.datacenters[l].region`), enabling
    /// the region-decomposed search mode.
    pub fn with_regions(mut self, regions: Vec<usize>) -> Self {
        debug_assert!(regions.is_empty() || regions.len() == self.dcs);
        self.regions = regions;
        self
    }

    /// The search mode that will be attempted: the explicit option if set,
    /// else [`SearchMode::RegionDecomposed`] at/above
    /// [`REGION_DECOMPOSE_THRESHOLD`] sites. (The decomposed mode still
    /// needs region tags and a sliceable backend at run time.)
    pub fn resolved_mode(&self) -> SearchMode {
        match self.options.search_mode {
            Some(m) => m,
            None if self.dcs >= REGION_DECOMPOSE_THRESHOLD => {
                SearchMode::RegionDecomposed
            }
            None => SearchMode::Global,
        }
    }

    /// Run Algorithm 1 against `eval`; respects the per-epoch budget.
    pub fn optimize(&mut self, eval: &dyn BatchEvaluator) -> SlitOutcome {
        self.optimize_with_seeds(eval, &[])
    }

    /// Run Algorithm 1 with extra seed plans injected into the initial
    /// population (e.g. `AnalyticEvaluator::greedy_seed_plans`).
    ///
    /// The ML-guided search advances all population slots in lockstep;
    /// each step's candidates are generated **directly into a
    /// [`PlanBatch`] arena** (no per-candidate `Plan` clone), surrogate
    /// ranking reads arena slices, and — when the backend exposes a
    /// [`crate::eval::DeltaScorer`] (the analytic evaluator does) — every
    /// surviving neighbour is rescored incrementally against its slot's
    /// cached epoch aggregates in O(|touched rows| * L) instead of the
    /// O(K*L) full contraction. Backends without delta support (AOT HLO)
    /// fall back to the batched [`MemoizedEvaluator`] path, which the
    /// initial population and EA children always use. Candidate
    /// generation and delta scoring stay sequential on the main thread
    /// (they own the RNG), so runs remain seed- and
    /// thread-count-deterministic.
    pub fn optimize_with_seeds(
        &mut self,
        eval: &dyn BatchEvaluator,
        seeds: &[Plan],
    ) -> SlitOutcome {
        let start = Instant::now();
        let budget = self.opt.budget_s;
        let x = self.opt.population;
        let memo = MemoizedEvaluator::new(eval);
        let delta = eval.delta_scorer();
        let mut delta_evals = 0usize;
        let mut archive = ParetoArchive::new(self.opt.archive_cap);
        let mut surrogate: Option<Gbdt> = None;
        let mut surrogate_trainings = 0usize;
        // Y_train: (plan features, scalarised score), bounded ring
        let mut y_train = TrainRing::new(MAX_TRAIN_SAMPLES);
        // running objective bounds for scalarisation
        let mut lo = [f64::INFINITY; N_OBJ];
        let mut hi = [f64::NEG_INFINITY; N_OBJ];
        // reused per-step buffers (allocation-free once warm); `scratch`
        // is the per-candidate PlanAgg copy target — copy_from reuses its
        // DcVec spill storage, so delta rescoring stays heap-silent even
        // for fleets past the inline tile (L > DC_SLOTS)
        let mut arena = PlanBatch::new(self.classes, self.dcs);
        arena.reserve(x * self.opt.neighbors.max(1));
        let mut scratch = PlanAgg::zeros(self.dcs);
        let mut scores: Vec<f64> = Vec::new();
        let mut order: Vec<usize> = Vec::new();

        // --- initial population: two extremes + seeds + random
        //     (Algorithm 1 init, memetically strengthened)
        let mut plans: Vec<Plan> = Vec::with_capacity(x);
        plans.push(Plan::uniform(self.classes, self.dcs));
        plans.push(Plan::one_dc(
            self.classes,
            self.dcs,
            self.rng.below(self.dcs),
        ));
        for s in seeds.iter().take(x.saturating_sub(plans.len())) {
            debug_assert_eq!(s.classes, self.classes);
            debug_assert_eq!(s.dcs, self.dcs);
            plans.push(s.clone());
        }
        while plans.len() < x {
            let alpha = self.rng.range(0.1, 1.0);
            plans.push(Plan::random(self.classes, self.dcs, alpha, &mut self.rng));
        }
        let objs = memo.eval_batch(&plans);
        let mut population: Vec<Solution> = plans
            .into_iter()
            .zip(objs)
            .map(|(plan, obj)| Solution { plan, obj })
            .collect();
        for s in &population {
            update_bounds(&mut lo, &mut hi, &s.obj);
            archive.insert(s.clone());
        }

        // --- region-decomposed mode: hand the searched phase to the
        //     price-coordinated per-region subsearches. Prerequisites
        //     (region tags, a sliceable backend, >= 2 regions) missing ->
        //     `None`, and the global walk below runs unchanged.
        if self.resolved_mode() == SearchMode::RegionDecomposed {
            if let Some((d_evals, sweeps)) = self.search_region_decomposed(
                eval,
                &memo,
                &mut archive,
                &population,
                &mut lo,
                &mut hi,
                start,
                budget,
            ) {
                return SlitOutcome {
                    archive,
                    evaluations: memo.misses() + d_evals,
                    cache_hits: memo.hits(),
                    delta_evals: d_evals,
                    generations_run: sweeps,
                    surrogate_trainings: 0,
                    wall_s: start.elapsed().as_secs_f64(),
                };
            }
        }

        let mut generations_run = 0usize;
        for gen in 0..self.opt.generations {
            if start.elapsed().as_secs_f64() > budget {
                break;
            }
            generations_run = gen + 1;

            // --- ML-guided local search (lines 3-11) -----------------------
            // diversified scalarisation: each population slot climbs its own
            // objective mix (4 single-objective specialists + balanced),
            // so the archive's extreme points get real search pressure —
            // that's where SLIT-Carbon/-TTFT/-Water/-Cost come from.
            //
            // All slots move in lockstep: per step, the merged candidate
            // batch is generated straight into the SoA arena on the main
            // thread (it owns the RNG, keeping runs seed-deterministic),
            // then scored — incrementally against cached per-slot epoch
            // aggregates when the backend supports delta rescoring, as one
            // memoized parallel batch otherwise.
            let mut current: Vec<Solution> = population.clone();
            let mut aggs: Vec<PlanAgg> = match delta {
                Some(d) => current
                    .iter()
                    .map(|s| d.aggregate(s.plan.as_slice()))
                    .collect(),
                None => Vec::new(),
            };
            let mut out_of_budget = false;
            for _ in 0..self.opt.search_steps {
                if start.elapsed().as_secs_f64() > budget {
                    break;
                }
                // 1) propose candidates for every slot, arena-resident.
                //    The budget is re-checked every BUDGET_CHECK_STRIDE
                //    slots: on overrun the remaining slots are skipped, the
                //    truncated batch still gets scored — ranges and
                //    candidates stay aligned — and the search ends after
                //    this step.
                arena.clear();
                let mut ranges: Vec<(usize, usize)> =
                    Vec::with_capacity(current.len());
                for (si, cur) in current.iter().enumerate() {
                    if si % BUDGET_CHECK_STRIDE == 0
                        && start.elapsed().as_secs_f64() > budget
                    {
                        out_of_budget = true;
                        break;
                    }
                    let lo_i = arena.len();
                    arena.push_neighbors_of(
                        cur.plan.as_slice(),
                        self.opt.neighbors,
                        self.opt.step,
                        &mut self.rng,
                    );
                    ranges.push((lo_i, arena.len()));
                }
                // 2) surrogate pre-ranking over arena slices: keep the most
                //    promising half of each slot's candidates
                let keep = (self.opt.neighbors / 2).max(1);
                let mut chosen: Vec<usize> =
                    Vec::with_capacity(ranges.len() * keep);
                let mut chosen_ranges: Vec<(usize, usize)> =
                    Vec::with_capacity(ranges.len());
                for &(lo_i, hi_i) in &ranges {
                    let c_lo = chosen.len();
                    match (&surrogate, self.options.use_surrogate) {
                        (Some(model), true) => {
                            model.predict_batch_into(
                                arena.range_flat(lo_i, hi_i),
                                arena.stride(),
                                &mut scores,
                            );
                            order.clear();
                            order.extend(0..hi_i - lo_i);
                            order.sort_by(|&a, &b| {
                                scores[a].partial_cmp(&scores[b]).unwrap()
                            });
                            chosen.extend(
                                order.iter().take(keep).map(|&o| lo_i + o),
                            );
                        }
                        _ => chosen.extend(lo_i..(lo_i + keep).min(hi_i)),
                    }
                    chosen_ranges.push((c_lo, chosen.len()));
                }
                // 3) true-evaluate the survivors: O(touched * L) delta
                //    rescoring against the slot aggregates when available,
                //    else one memoized batch (parallel inside)
                let objs: Vec<[f64; N_OBJ]> = match delta {
                    Some(d) => {
                        let mut objs = Vec::with_capacity(chosen.len());
                        for (si, &(c_lo, c_hi)) in
                            chosen_ranges.iter().enumerate()
                        {
                            let base = current[si].plan.as_slice();
                            for &ci in &chosen[c_lo..c_hi] {
                                scratch.copy_from(&aggs[si]);
                                let mask = arena.touched(ci);
                                for k in 0..self.classes {
                                    if (mask >> k) & 1 == 1 {
                                        d.apply_row_delta(
                                            &mut scratch,
                                            k,
                                            &base[k * self.dcs
                                                ..(k + 1) * self.dcs],
                                            arena.row(ci, k),
                                        );
                                    }
                                }
                                objs.push(d.finish(&scratch));
                            }
                        }
                        delta_evals += objs.len();
                        objs
                    }
                    None => {
                        let plans: Vec<Plan> = chosen
                            .iter()
                            .map(|&ci| arena.to_plan(ci))
                            .collect();
                        memo.eval_batch(&plans)
                    }
                };
                // 4) trajectory capture + archive update + move selection;
                //    a Plan is materialised only for archive entrants and
                //    accepted moves
                for (si, &(c_lo, c_hi)) in chosen_ranges.iter().enumerate()
                {
                    let weights = slot_weights(si);
                    let mut best: Option<(usize, [f64; N_OBJ])> = None;
                    for w in c_lo..c_hi {
                        let ci = chosen[w];
                        let obj = objs[w];
                        update_bounds(&mut lo, &mut hi, &obj);
                        let score = scalarize(&obj, &lo, &hi);
                        y_train.push(arena.candidate(ci), score);
                        if archive.would_accept(&obj) {
                            let plan = arena.to_plan(ci);
                            // delta scores carry per-base-aggregate FP
                            // jitter, but archive dedup compares objectives
                            // exactly — rescore entrants canonically
                            // (finish(aggregate(..)) == evaluate bit-for-
                            // bit) so identical plans stay deduplicated;
                            // insert re-checks acceptance on the exact
                            // objective. The gate itself sees the jittered
                            // score, so a candidate within ~1e-9 of the
                            // dominance boundary can be dropped that an
                            // exact gate would admit — accepted tradeoff:
                            // exact gating would cost the O(K*L) rescore
                            // for every candidate, not just entrants.
                            let store = match delta {
                                Some(d) => {
                                    d.finish(&d.aggregate(plan.as_slice()))
                                }
                                None => obj,
                            };
                            archive.insert(Solution { plan, obj: store });
                        }
                        let better = match &best {
                            None => true,
                            Some((_, b_obj)) => {
                                scalarize_w(&obj, &weights, &lo, &hi)
                                    < scalarize_w(b_obj, &weights, &lo, &hi)
                            }
                        };
                        if better {
                            best = Some((ci, obj));
                        }
                    }
                    if let Some((ci, obj)) = best {
                        let cur_score = scalarize_w(
                            &current[si].obj,
                            &weights,
                            &lo,
                            &hi,
                        );
                        let cand_score =
                            scalarize_w(&obj, &weights, &lo, &hi);
                        if dominates(&obj, &current[si].obj)
                            || cand_score < cur_score
                        {
                            current[si] = Solution {
                                plan: arena.to_plan(ci),
                                obj,
                            };
                            if let Some(d) = delta {
                                // re-contract from scratch so FP drift
                                // cannot accumulate across accepted moves,
                                // and pin the slot's objective to the
                                // canonical (full-contraction) score
                                aggs[si] =
                                    d.aggregate(current[si].plan.as_slice());
                                current[si].obj = d.finish(&aggs[si]);
                            }
                        }
                    }
                }
                if out_of_budget {
                    break;
                }
            }
            population = select_population(
                population.into_iter().chain(current).collect(),
                x,
            );

            // --- surrogate retraining (lines 10-11) ------------------------
            if self.options.use_surrogate
                && gen % self.opt.train_freq == self.opt.train_freq - 1
                && y_train.len() >= 32
                && start.elapsed().as_secs_f64() <= budget
            {
                // training is bounded by construction: the ring holds only
                // the most recent MAX_TRAIN_SAMPLES trajectories, and
                // column subsampling keeps one fit inside the epoch budget
                let (xs, ys) = y_train.training_view();
                let d = xs[0].len();
                let cfg = GbdtConfig {
                    trees: self.opt.gbdt_trees,
                    depth: self.opt.gbdt_depth,
                    learning_rate: self.opt.gbdt_lr,
                    min_leaf: self.opt.gbdt_min_leaf,
                    feature_sample: (d / 6).max(8).min(d),
                };
                surrogate = Some(Gbdt::fit(&xs, &ys, &cfg, &mut self.rng));
                surrogate_trainings += 1;
                y_train.clear(); // paper: Y_train = empty after training
            }

            // --- EA phase (lines 12-20) ------------------------------------
            if self.options.use_ea && start.elapsed().as_secs_f64() <= budget {
                let mut children: Vec<Plan> = Vec::with_capacity(x);
                for _ in 0..x {
                    let p1 = self.rng.below(population.len());
                    let p2 = self.rng.below(population.len());
                    let child = population[p1]
                        .plan
                        .crossover(&population[p2].plan, &mut self.rng)
                        .mutated(self.opt.mutation_rate, &mut self.rng);
                    children.push(child);
                }
                let objs = memo.eval_batch(&children);
                let mut child_solutions = Vec::with_capacity(children.len());
                for (plan, obj) in children.into_iter().zip(objs) {
                    update_bounds(&mut lo, &mut hi, &obj);
                    y_train.push(plan.as_slice(), scalarize(&obj, &lo, &hi));
                    let sol = Solution { plan, obj };
                    archive.insert(sol.clone());
                    child_solutions.push(sol);
                }
                population = select_population(
                    population.into_iter().chain(child_solutions).collect(),
                    x,
                );
            }
        }

        SlitOutcome {
            archive,
            evaluations: memo.misses() + delta_evals,
            cache_hits: memo.hits(),
            delta_evals,
            generations_run,
            surrogate_trainings,
            wall_s: start.elapsed().as_secs_f64(),
        }
    }

    /// The region-decomposed searched phase (DESIGN.md §18): partition
    /// sites by region tag, run one price-coordinated subsearch per region
    /// concurrently on the persistent thread pool, and per sweep stitch
    /// the per-region rows into global plans that are canonically rescored
    /// (`finish∘aggregate`, via the memoized evaluator) and inserted into
    /// the global archive on the main thread.
    ///
    /// Determinism: each region's subsearch owns a derived RNG stream
    /// (`seed ^ region tag`), its own arena/scratch, and writes only its
    /// own position-stable `RegionSub` state, so results are bit-identical
    /// regardless of worker count or scheduling order; the only shared
    /// mutable state during a sweep is the budget trip flag, which cannot
    /// change results under a non-binding budget and under a binding one
    /// only truncates (exactly like the global walk's budget checks).
    ///
    /// Returns `None` when prerequisites are missing (no region tags,
    /// fewer than two regions, or a backend that cannot be sliced) — the
    /// caller then falls back to the global walk.
    #[allow(clippy::too_many_arguments)]
    fn search_region_decomposed(
        &self,
        eval: &dyn BatchEvaluator,
        memo: &MemoizedEvaluator,
        archive: &mut ParetoArchive,
        population: &[Solution],
        lo: &mut [f64; N_OBJ],
        hi: &mut [f64; N_OBJ],
        start: Instant,
        budget: f64,
    ) -> Option<(usize, usize)> {
        if self.regions.len() != self.dcs {
            return None;
        }
        let parts =
            crate::scenario::partition_sites_by_region(&self.regions);
        if parts.len() < 2 {
            return None;
        }
        let k_n = self.classes;
        let l_n = self.dcs;
        let slots_n = N_OBJ + 1;

        // Warm starts: for each objective-mix slot, the initial-population
        // member best on that mix (the greedy seeds land here), sliced to
        // each region's sites and renormalised.
        let mut warm: Vec<&Plan> = Vec::with_capacity(slots_n);
        for s in 0..slots_n {
            let weights = slot_weights(s);
            let best = population
                .iter()
                .min_by(|a, b| {
                    scalarize_w(&a.obj, &weights, lo, hi)
                        .partial_cmp(&scalarize_w(&b.obj, &weights, lo, hi))
                        .unwrap()
                })
                .expect("non-empty initial population");
            warm.push(&best.plan);
        }

        let mut regions: Vec<RegionSub> = Vec::with_capacity(parts.len());
        for (tag, sites) in &parts {
            let sub_eval = eval.region_evaluator(sites)?;
            let l_r = sites.len();
            let mut slots = Vec::with_capacity(slots_n);
            for w in warm.iter().take(slots_n) {
                let mut flat = vec![0.0; k_n * l_r];
                for k in 0..k_n {
                    for (j, &g) in sites.iter().enumerate() {
                        flat[k * l_r + j] = w.get(k, g);
                    }
                }
                slots.push(Plan::from_flat(k_n, l_r, flat));
            }
            let mut arena = PlanBatch::new(k_n, l_r);
            arena.reserve(self.opt.neighbors.max(1));
            regions.push(RegionSub {
                sites: sites.clone(),
                eval: sub_eval,
                rng: Rng::new(self.seed ^ region_stream_tag(*tag)),
                slots,
                slot_objs: vec![[0.0; N_OBJ]; slots_n],
                w: vec![l_r as f64 / l_n as f64; k_n],
                aggs: (0..slots_n).map(|_| PlanAgg::zeros(l_r)).collect(),
                scratch: PlanAgg::zeros(l_r),
                arena,
                scaled_flat: vec![0.0; k_n * l_r],
                old_scaled: vec![0.0; l_r],
                new_scaled: vec![0.0; l_r],
                zero_row: vec![0.0; l_r],
                unit_cost: vec![0.0; k_n],
                delta_evals: 0,
            });
        }

        // Satellite budget-cap hardening: ONE shared deadline (start
        // instant + budget + atomic trip flag) across all concurrent
        // subsearches — the first overrun observation trips the flag and
        // every other region stops at its next stride check.
        let tripped = AtomicBool::new(false);
        let steps = self.opt.search_steps.max(1);
        let neighbors = self.opt.neighbors.max(1);
        let move_step = self.opt.step;
        let mut sweeps_run = 0usize;
        for sweep in 0..PRICE_SWEEPS {
            let deadline = SharedDeadline {
                start,
                budget_s: budget,
                tripped: &tripped,
            };
            if deadline.overrun() {
                break;
            }
            sweeps_run = sweep + 1;

            // fan out one task per region; each writes only its own
            // position-stable RegionSub state
            {
                let lo_c = *lo;
                let hi_c = *hi;
                let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::with_capacity(regions.len());
                for r in regions.iter_mut() {
                    let d = deadline;
                    tasks.push(Box::new(move || {
                        r.sweep(steps, neighbors, move_step, &lo_c, &hi_c, &d);
                    }));
                }
                threadpool::run_tasks(tasks);
            }

            // merge: stitch per-region rows, weighted by the per-class
            // demand shares, into one global plan per objective-mix slot
            let mut merged: Vec<Plan> = Vec::with_capacity(slots_n);
            for si in 0..slots_n {
                let mut flat = vec![0.0; k_n * l_n];
                for r in &regions {
                    let l_r = r.sites.len();
                    let sub = r.slots[si].as_slice();
                    for k in 0..k_n {
                        let wk = r.w[k];
                        for (j, &g) in r.sites.iter().enumerate() {
                            flat[k * l_n + g] = wk * sub[k * l_r + j];
                        }
                    }
                }
                merged.push(Plan::from_flat(k_n, l_n, flat));
            }
            // canonical global rescore on the main thread (evaluate ==
            // finish∘aggregate bit-for-bit) + archive insert
            let objs = memo.eval_batch(&merged);
            for (plan, obj) in merged.into_iter().zip(objs) {
                update_bounds(lo, hi, &obj);
                archive.insert(Solution { plan, obj });
            }

            // price/dual ascent on per-class demand balance: the clearing
            // price mu_k is the share-weighted marginal cost; shares move
            // multiplicatively against (unit cost - price) and are exactly
            // renormalised, so sum_r w[k][r] == 1 stays invariant
            if sweep + 1 < PRICE_SWEEPS && !deadline.overrun() {
                for k in 0..k_n {
                    let mu: f64 = regions
                        .iter()
                        .map(|r| r.w[k] * r.unit_cost[k])
                        .sum();
                    let mut sum = 0.0;
                    for r in regions.iter_mut() {
                        let e = (-PRICE_ETA * (r.unit_cost[k] - mu))
                            .clamp(-4.0, 4.0);
                        r.w[k] *= e.exp();
                        sum += r.w[k];
                    }
                    if sum <= 1e-15 {
                        for r in regions.iter_mut() {
                            r.w[k] = r.sites.len() as f64 / l_n as f64;
                        }
                    } else {
                        for r in regions.iter_mut() {
                            r.w[k] /= sum;
                        }
                    }
                }
            }
        }

        let delta: usize = regions.iter().map(|r| r.delta_evals).sum();
        Some((delta, sweeps_run))
    }
}

/// Stable per-region RNG stream tag: spreads the region id across the
/// word so `seed ^ tag` streams are distinct per region and never collide
/// with the main optimizer stream (`seed ^ "SLIT"`).
fn region_stream_tag(region: usize) -> u64 {
    0x5245_4749_4F4E_0000u64 // "REGION"
        ^ (region as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// One hard wall-clock cap shared by every concurrent region subsearch:
/// a single start instant + budget + atomic trip flag (not per-region
/// clocks). After any observer trips the flag, further checks cost one
/// relaxed atomic load instead of a clock syscall.
#[derive(Clone, Copy)]
struct SharedDeadline<'a> {
    start: Instant,
    budget_s: f64,
    tripped: &'a AtomicBool,
}

impl SharedDeadline<'_> {
    fn overrun(&self) -> bool {
        if self.tripped.load(Ordering::Relaxed) {
            return true;
        }
        if self.start.elapsed().as_secs_f64() > self.budget_s {
            self.tripped.store(true, Ordering::Relaxed);
            return true;
        }
        false
    }
}

/// One region's subproblem state: a site-restricted evaluator, per-slot
/// row-stochastic sub-plans over the region's sites, the per-class demand
/// shares `w` (the coupling variables the price sweeps update), and all
/// the arena/scratch buffers a sweep needs — sized once, so a warm
/// subsearch step is allocation-free (pinned in alloc_hotpath.rs).
struct RegionSub {
    /// Global site indices, ascending.
    sites: Vec<usize>,
    eval: AnalyticEvaluator,
    rng: Rng,
    /// Per-objective-mix-slot sub-plans (rows stochastic over the
    /// region's sites; the share `w[k]` scales them at scoring time).
    slots: Vec<Plan>,
    /// Canonical share-scaled objective contribution per slot.
    slot_objs: Vec<[f64; N_OBJ]>,
    /// Per-class demand share routed to this region (sums to 1 across
    /// regions for every class).
    w: Vec<f64>,
    aggs: Vec<PlanAgg>,
    scratch: PlanAgg,
    arena: PlanBatch,
    scaled_flat: Vec<f64>,
    old_scaled: Vec<f64>,
    new_scaled: Vec<f64>,
    zero_row: Vec<f64>,
    /// Marginal (per-unit-share) scalarised cost of each class in this
    /// region, refreshed at the end of every sweep for the price update.
    unit_cost: Vec<f64>,
    delta_evals: usize,
}

impl RegionSub {
    fn fill_scaled_flat(&mut self, si: usize) {
        let l_r = self.sites.len();
        let flat = self.slots[si].as_slice();
        for (k, &wk) in self.w.iter().enumerate() {
            for j in 0..l_r {
                self.scaled_flat[k * l_r + j] = wk * flat[k * l_r + j];
            }
        }
    }

    /// Re-contract every slot's aggregates from scratch under the current
    /// shares (also kills accumulated FP drift between sweeps).
    fn recontract(&mut self) {
        for si in 0..self.slots.len() {
            self.fill_scaled_flat(si);
            self.aggs[si] = self.eval.aggregate(&self.scaled_flat);
            self.slot_objs[si] = self.eval.finish(&self.aggs[si]);
        }
    }

    /// One price sweep's worth of local search: `steps` lockstep passes
    /// over the objective-mix slots, each proposing `neighbors` arena
    /// candidates delta-rescored in O(L_region), then a marginal-cost
    /// refresh for the price update. The shared deadline is re-checked
    /// every [`BUDGET_CHECK_STRIDE`] slot visits.
    fn sweep(
        &mut self,
        steps: usize,
        neighbors: usize,
        move_step: f64,
        lo: &[f64; N_OBJ],
        hi: &[f64; N_OBJ],
        deadline: &SharedDeadline<'_>,
    ) {
        self.recontract();
        let k_n = self.w.len();
        let l_r = self.sites.len();
        let mut tick = 0usize;
        for _ in 0..steps {
            for si in 0..self.slots.len() {
                if tick % BUDGET_CHECK_STRIDE == 0 && deadline.overrun() {
                    return;
                }
                tick += 1;
                self.arena.clear();
                self.arena.push_neighbors_of(
                    self.slots[si].as_slice(),
                    neighbors,
                    move_step,
                    &mut self.rng,
                );
                let weights = slot_weights(si);
                let cur_score =
                    scalarize_w(&self.slot_objs[si], &weights, lo, hi);
                let mut best: Option<(usize, [f64; N_OBJ], f64)> = None;
                for ci in 0..self.arena.len() {
                    self.scratch.copy_from(&self.aggs[si]);
                    let mask = self.arena.touched(ci);
                    for k in 0..k_n {
                        if (mask >> k) & 1 == 1 {
                            let wk = self.w[k];
                            let old = self.slots[si].row(k);
                            let new = self.arena.row(ci, k);
                            for j in 0..l_r {
                                self.old_scaled[j] = wk * old[j];
                                self.new_scaled[j] = wk * new[j];
                            }
                            self.eval.apply_row_delta(
                                &mut self.scratch,
                                k,
                                &self.old_scaled,
                                &self.new_scaled,
                            );
                        }
                    }
                    let obj = self.eval.finish(&self.scratch);
                    self.delta_evals += 1;
                    let score = scalarize_w(&obj, &weights, lo, hi);
                    let better = match &best {
                        None => true,
                        Some((_, _, b)) => score < *b,
                    };
                    if better {
                        best = Some((ci, obj, score));
                    }
                }
                if let Some((ci, obj, score)) = best {
                    if dominates(&obj, &self.slot_objs[si])
                        || score < cur_score
                    {
                        self.slots[si] = self.arena.to_plan(ci);
                        // re-contract canonically so drift cannot
                        // accumulate across accepted moves
                        self.fill_scaled_flat(si);
                        self.aggs[si] =
                            self.eval.aggregate(&self.scaled_flat);
                        self.slot_objs[si] =
                            self.eval.finish(&self.aggs[si]);
                    }
                }
            }
        }
        self.refresh_unit_costs(lo, hi);
    }

    /// Marginal scalarised cost per unit of demand share, per class — the
    /// quantity the price update clears. Computed against the balanced
    /// slot by removing the class's scaled row from the aggregates (one
    /// O(L_region) delta per class).
    fn refresh_unit_costs(&mut self, lo: &[f64; N_OBJ], hi: &[f64; N_OBJ]) {
        let bi = self.slots.len() - 1;
        let full = scalarize(&self.slot_objs[bi], lo, hi);
        let l_r = self.sites.len();
        for k in 0..self.w.len() {
            self.scratch.copy_from(&self.aggs[bi]);
            let wk = self.w[k];
            let row = self.slots[bi].row(k);
            for j in 0..l_r {
                self.old_scaled[j] = wk * row[j];
            }
            self.eval.apply_row_delta(
                &mut self.scratch,
                k,
                &self.old_scaled,
                &self.zero_row,
            );
            let without = self.eval.finish(&self.scratch);
            let attributed = full - scalarize(&without, lo, hi);
            self.unit_cost[k] = attributed / wk.max(1e-9);
        }
    }
}

fn update_bounds(lo: &mut [f64; N_OBJ], hi: &mut [f64; N_OBJ], obj: &[f64; N_OBJ]) {
    for i in 0..N_OBJ {
        lo[i] = lo[i].min(obj[i]);
        hi[i] = hi[i].max(obj[i]);
    }
}

/// Normalised-sum scalarisation against running bounds (lower is better).
fn scalarize(obj: &[f64; N_OBJ], lo: &[f64; N_OBJ], hi: &[f64; N_OBJ]) -> f64 {
    scalarize_w(obj, &[1.0; N_OBJ], lo, hi)
}

/// Weighted normalised-sum scalarisation.
fn scalarize_w(
    obj: &[f64; N_OBJ],
    weights: &[f64; N_OBJ],
    lo: &[f64; N_OBJ],
    hi: &[f64; N_OBJ],
) -> f64 {
    let mut s = 0.0;
    for i in 0..N_OBJ {
        if hi[i] - lo[i] > 1e-15 {
            s += weights[i] * (obj[i] - lo[i]) / (hi[i] - lo[i]);
        }
    }
    s
}

/// Objective-mix rotation over population slots: slots 0..3 specialise on
/// one objective each (with a small balanced regulariser so they don't
/// wander into absurd corners), the rest climb the balanced sum.
fn slot_weights(slot: usize) -> [f64; N_OBJ] {
    match slot % (N_OBJ + 1) {
        i if i < N_OBJ => {
            let mut w = [0.05; N_OBJ];
            w[i] = 1.0;
            w
        }
        _ => [1.0; N_OBJ],
    }
}

/// Keep `cap` solutions: non-dominated first, then crowding-sorted fill
/// (NSGA-II environmental selection). Backed by
/// [`fast_nondominated_sort`], which computes every pairwise domination
/// exactly once — the old loop re-scanned the whole remaining pool per
/// extracted front, an O(n^2)-per-front cost that dominated selection on
/// large merged pools.
pub fn select_population(pool: Vec<Solution>, cap: usize) -> Vec<Solution> {
    if pool.len() <= cap {
        return pool;
    }
    let objs: Vec<[f64; N_OBJ]> = pool.iter().map(|s| s.obj).collect();
    let fronts = fast_nondominated_sort(&objs);
    let mut slots: Vec<Option<Solution>> =
        pool.into_iter().map(Some).collect();
    let mut out: Vec<Solution> = Vec::with_capacity(cap);
    for front in fronts {
        if out.len() == cap {
            break;
        }
        if out.len() + front.len() <= cap {
            out.extend(
                front.iter().map(|&i| slots[i].take().expect("front member")),
            );
        } else {
            // split front: crowding-sorted fill of the remaining slots
            let front_sols: Vec<Solution> = front
                .iter()
                .map(|&i| slots[i].take().expect("front member"))
                .collect();
            let crowd = crowding_distances(&front_sols);
            let mut order: Vec<usize> = (0..front_sols.len()).collect();
            order.sort_by(|&a, &b| crowd[b].partial_cmp(&crowd[a]).unwrap());
            for &i in order.iter().take(cap - out.len()) {
                out.push(front_sols[i].clone());
            }
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::build_panels;
    use crate::config::{SystemConfig, OBJ_CARBON, OBJ_TTFT};
    use crate::eval::{AnalyticEvaluator, EvalConsts};
    use crate::power::GridSignals;
    use crate::trace::Trace;

    fn make_eval() -> (SystemConfig, AnalyticEvaluator) {
        let cfg = SystemConfig::paper_default();
        let signals = GridSignals::generate(&cfg, 8, 3);
        let trace = Trace::generate(&cfg, 8, 3);
        let (cp, dp) = build_panels(&cfg, &signals, 4, &trace.epochs[4], 0.05);
        let consts = EvalConsts::from_physics(&cfg.physics);
        (cfg.clone(), AnalyticEvaluator::new(cp, dp, consts))
    }

    fn run_opt(options: SlitOptions, seed: u64) -> (SystemConfig, SlitOutcome) {
        let (cfg, ev) = make_eval();
        let mut opt_cfg = cfg.opt.clone();
        opt_cfg.population = 12;
        opt_cfg.generations = 5;
        opt_cfg.search_steps = 3;
        opt_cfg.neighbors = 6;
        opt_cfg.gbdt_trees = 10;
        opt_cfg.train_freq = 2;
        let mut o = SlitOptimizer::new(
            opt_cfg,
            cfg.num_classes(),
            ev.dcs(),
            seed,
        )
        .with_options(options);
        let out = o.optimize(&ev);
        (cfg, out)
    }

    #[test]
    fn produces_consistent_nonempty_archive() {
        let (_, out) = run_opt(SlitOptions::default(), 1);
        assert!(!out.archive.is_empty());
        assert!(out.archive.is_consistent());
        assert!(out.evaluations > 50);
        assert_eq!(out.generations_run, 5);
        assert!(out.surrogate_trainings >= 1);
    }

    #[test]
    fn showcase_solutions_specialise() {
        let (_, out) = run_opt(SlitOptions::default(), 2);
        let show = out.archive.showcase();
        assert_eq!(show.len(), 5);
        // best-carbon has carbon <= best-ttft's carbon, and vice versa
        let carbon_sol = &show[OBJ_CARBON].1;
        let ttft_sol = &show[OBJ_TTFT].1;
        assert!(carbon_sol.obj[OBJ_CARBON] <= ttft_sol.obj[OBJ_CARBON]);
        assert!(ttft_sol.obj[OBJ_TTFT] <= carbon_sol.obj[OBJ_TTFT]);
    }

    #[test]
    fn optimizer_beats_uniform_plan_on_every_showcased_objective() {
        let (cfg, out) = run_opt(SlitOptions::default(), 3);
        let (_, ev) = make_eval();
        let uniform =
            ev.evaluate(&Plan::uniform(cfg.num_classes(), ev.dcs()));
        for (i, _) in crate::config::OBJ_NAMES.iter().enumerate() {
            let best = out.archive.best_for(i).unwrap();
            assert!(
                best.obj[i] <= uniform[i] * 1.001,
                "objective {i}: best {} vs uniform {}",
                best.obj[i],
                uniform[i]
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (_, a) = run_opt(SlitOptions::default(), 7);
        let (_, b) = run_opt(SlitOptions::default(), 7);
        assert_eq!(a.evaluations, b.evaluations);
        assert_eq!(a.cache_hits, b.cache_hits);
        let oa: Vec<_> = a.archive.solutions.iter().map(|s| s.obj).collect();
        let ob: Vec<_> = b.archive.solutions.iter().map(|s| s.obj).collect();
        assert_eq!(oa, ob);
    }

    #[test]
    fn memoized_evaluation_accounting_is_consistent() {
        // evaluations = cache misses + delta rescorings; hits are free
        // repeats — together they cover every score the search requested
        let (_, out) = run_opt(SlitOptions::default(), 12);
        assert!(out.evaluations > 50, "unique evals {}", out.evaluations);
        // repeated runs under the same seed spend the same true-eval budget
        let (_, again) = run_opt(SlitOptions::default(), 12);
        assert_eq!(out.evaluations, again.evaluations);
        assert_eq!(out.cache_hits, again.cache_hits);
    }

    #[test]
    fn ablations_run() {
        let (_, no_sur) = run_opt(
            SlitOptions {
                use_surrogate: false,
                use_ea: true,
                search_mode: None,
            },
            4,
        );
        assert_eq!(no_sur.surrogate_trainings, 0);
        let (_, no_ea) = run_opt(
            SlitOptions {
                use_surrogate: true,
                use_ea: false,
                search_mode: None,
            },
            4,
        );
        assert!(!no_ea.archive.is_empty());
        assert!(no_ea.evaluations < no_sur.evaluations);
    }

    #[test]
    fn delta_path_scores_every_neighbor_incrementally() {
        // against the analytic evaluator, all neighbour scoring goes
        // through the O(L) delta core: generations * steps * population *
        // kept-half candidates, with the huge budget never truncating
        let (_, out) = run_opt(SlitOptions::default(), 31);
        assert_eq!(out.delta_evals, 5 * 3 * 12 * 3);
        // the memo still sees the initial population and EA children
        let memo_misses = out.evaluations - out.delta_evals;
        assert!(memo_misses >= 12, "init population pays full evals");
    }

    #[test]
    fn train_ring_keeps_most_recent_tail_and_reuses_slots() {
        let mut ring = TrainRing::new(4);
        assert_eq!(ring.len(), 0);
        for i in 0..10 {
            ring.push(&[i as f64], i as f64);
        }
        assert_eq!(ring.len(), 4, "bounded at capacity");
        let (xs, ys) = ring.training_view();
        assert_eq!(ys, vec![6.0, 7.0, 8.0, 9.0], "oldest-first tail");
        assert_eq!(xs[0], vec![6.0]);
        ring.clear();
        assert_eq!(ring.len(), 0);
        // slots (and their allocations) are reused after clear, including
        // for wider feature vectors
        ring.push(&[1.0, 2.0], 0.5);
        let (xs, ys) = ring.training_view();
        assert_eq!(xs, vec![vec![1.0, 2.0]]);
        assert_eq!(ys, vec![0.5]);
    }

    #[test]
    fn select_population_caps_and_keeps_nondominated() {
        let mk = |o: [f64; N_OBJ]| Solution {
            plan: Plan::uniform(2, 3),
            obj: o,
        };
        let pool = vec![
            mk([1.0, 9.0, 9.0, 9.0]),
            mk([9.0, 1.0, 9.0, 9.0]),
            mk([5.0, 5.0, 5.0, 5.0]),
            mk([6.0, 6.0, 6.0, 6.0]), // dominated by the previous
            mk([9.0, 9.0, 1.0, 9.0]),
        ];
        let sel = select_population(pool, 4);
        assert_eq!(sel.len(), 4);
        assert!(!sel.iter().any(|s| s.obj == [6.0, 6.0, 6.0, 6.0]));
    }

    fn region_mode() -> SlitOptions {
        SlitOptions {
            search_mode: Some(SearchMode::RegionDecomposed),
            ..SlitOptions::default()
        }
    }

    /// Like [`run_opt`] but with the paper fleet's region tags supplied,
    /// so the decomposed mode actually decomposes.
    fn run_opt_region(
        options: SlitOptions,
        seed: u64,
    ) -> (SystemConfig, SlitOutcome) {
        let (cfg, ev) = make_eval();
        let mut opt_cfg = cfg.opt.clone();
        opt_cfg.population = 12;
        opt_cfg.generations = 5;
        opt_cfg.search_steps = 3;
        opt_cfg.neighbors = 6;
        opt_cfg.gbdt_trees = 10;
        opt_cfg.train_freq = 2;
        let regions: Vec<usize> =
            cfg.datacenters.iter().map(|d| d.region).collect();
        let mut o =
            SlitOptimizer::new(opt_cfg, cfg.num_classes(), ev.dcs(), seed)
                .with_options(options)
                .with_regions(regions);
        let out = o.optimize(&ev);
        (cfg, out)
    }

    #[test]
    fn region_decomposed_runs_and_merges_a_consistent_archive() {
        let (_, out) = run_opt_region(region_mode(), 21);
        assert!(!out.archive.is_empty());
        assert!(out.archive.is_consistent());
        // the decomposed phase replaces the global walk entirely: no
        // surrogate, PRICE_SWEEPS "generations", and every candidate goes
        // through the region-local O(L_region) delta core — 4 regions x
        // 3 sweeps x 3 steps x 5 slots x 6 neighbours
        assert_eq!(out.surrogate_trainings, 0);
        assert_eq!(out.generations_run, PRICE_SWEEPS);
        assert_eq!(out.delta_evals, 4 * PRICE_SWEEPS * 3 * (N_OBJ + 1) * 6);
        // merged plans are canonically rescored through the memo
        assert!(out.evaluations > out.delta_evals);
    }

    #[test]
    fn region_decomposed_is_bit_deterministic_across_runs() {
        let (_, a) = run_opt_region(region_mode(), 33);
        let (_, b) = run_opt_region(region_mode(), 33);
        assert_eq!(a.evaluations, b.evaluations);
        assert_eq!(a.delta_evals, b.delta_evals);
        let oa: Vec<_> = a.archive.solutions.iter().map(|s| s.obj).collect();
        let ob: Vec<_> = b.archive.solutions.iter().map(|s| s.obj).collect();
        assert_eq!(oa, ob, "decomposed search must be bit-deterministic");
        // a different seed explores differently
        let (_, c) = run_opt_region(region_mode(), 34);
        let oc: Vec<_> = c.archive.solutions.iter().map(|s| s.obj).collect();
        assert_ne!(oa, oc);
    }

    #[test]
    fn region_mode_without_tags_falls_back_to_the_global_walk() {
        // forced RegionDecomposed but no with_regions: prerequisites are
        // missing, so the run must be bit-identical to the global walk
        let (_, forced) = run_opt(region_mode(), 7);
        let (_, global) = run_opt(SlitOptions::default(), 7);
        assert_eq!(forced.delta_evals, global.delta_evals);
        assert_eq!(forced.evaluations, global.evaluations);
        assert_eq!(forced.surrogate_trainings, global.surrogate_trainings);
        let of: Vec<_> =
            forced.archive.solutions.iter().map(|s| s.obj).collect();
        let og: Vec<_> =
            global.archive.solutions.iter().map(|s| s.obj).collect();
        assert_eq!(of, og);
    }

    #[test]
    fn auto_mode_resolves_by_fleet_size_and_override_wins() {
        let opt_cfg = SystemConfig::paper_default().opt;
        let mk = |dcs: usize, options: SlitOptions| {
            SlitOptimizer::new(opt_cfg.clone(), 8, dcs, 1)
                .with_options(options)
        };
        assert_eq!(
            mk(12, SlitOptions::default()).resolved_mode(),
            SearchMode::Global
        );
        assert_eq!(
            mk(REGION_DECOMPOSE_THRESHOLD, SlitOptions::default())
                .resolved_mode(),
            SearchMode::RegionDecomposed
        );
        assert_eq!(
            mk(256, SlitOptions::default()).resolved_mode(),
            SearchMode::RegionDecomposed
        );
        // explicit choice always wins, in both directions
        assert_eq!(
            mk(
                256,
                SlitOptions {
                    search_mode: Some(SearchMode::Global),
                    ..SlitOptions::default()
                }
            )
            .resolved_mode(),
            SearchMode::Global
        );
        assert_eq!(mk(12, region_mode()).resolved_mode(), SearchMode::RegionDecomposed);
        // the 48-site global fleet stays on the bit-identical global walk
        assert_eq!(
            mk(48, SlitOptions::default()).resolved_mode(),
            SearchMode::Global
        );
    }

    #[test]
    fn shared_deadline_hard_caps_the_decomposed_search_at_l256() {
        // satellite regression: one atomic deadline across all concurrent
        // region subsearches — a tiny budget must bound the whole epoch
        // even at 256 sites, and still leave a usable archive (the initial
        // population and at least the stride-truncated first sweep land)
        let mut cfg = SystemConfig::paper_default();
        cfg.datacenters = crate::scenario::global_fleet_datacenters(32);
        cfg.validate().unwrap();
        let signals = GridSignals::generate(&cfg, 6, 3);
        let trace = Trace::generate(&cfg, 6, 3);
        let (cp, dp) = build_panels(&cfg, &signals, 2, &trace.epochs[2], 0.05);
        let ev = AnalyticEvaluator::new(
            cp,
            dp,
            EvalConsts::from_physics(&cfg.physics),
        );
        let mut opt_cfg = cfg.opt.clone();
        opt_cfg.generations = 10_000;
        opt_cfg.search_steps = 10_000;
        opt_cfg.budget_s = 0.2;
        let regions: Vec<usize> =
            cfg.datacenters.iter().map(|d| d.region).collect();
        let mut o =
            SlitOptimizer::new(opt_cfg, cfg.num_classes(), ev.dcs(), 5)
                .with_regions(regions);
        assert_eq!(o.resolved_mode(), SearchMode::RegionDecomposed);
        let t = std::time::Instant::now();
        let out = o.optimize(&ev);
        assert!(
            t.elapsed().as_secs_f64() < 5.0,
            "decomposed search ignored the shared budget: {:.2}s",
            t.elapsed().as_secs_f64()
        );
        assert!(!out.archive.is_empty());
        assert!(out.archive.is_consistent());
    }

    #[test]
    fn merged_region_plans_are_normalized_and_mass_conserving() {
        use crate::util::propkit;
        // property: stitching share-scaled per-region rows through
        // Plan::from_flat always yields row-stochastic plans, and when the
        // shares sum to 1 per class the pre-normalisation row mass is
        // already 1 (the merge conserves demand mass exactly)
        propkit::check(
            "merged rows normalized + mass conserving",
            0xC0DE,
            64,
            |rng| {
                let k_n = 1 + rng.below(6);
                let n_regions = 2 + rng.below(3);
                // region sizes 1..=4
                let sizes: Vec<usize> =
                    (0..n_regions).map(|_| 1 + rng.below(4)).collect();
                // per-class shares over regions, normalised to sum to 1
                let mut shares = vec![vec![0.0; n_regions]; k_n];
                for row in shares.iter_mut() {
                    let mut sum = 0.0;
                    for s in row.iter_mut() {
                        *s = rng.range(0.01, 1.0);
                        sum += *s;
                    }
                    for s in row.iter_mut() {
                        *s /= sum;
                    }
                }
                // random row-stochastic sub-plans per region
                let subs: Vec<Plan> = sizes
                    .iter()
                    .map(|&l_r| Plan::random(k_n, l_r, 0.7, rng))
                    .collect();
                (sizes, shares, subs)
            },
            |(sizes, shares, subs)| {
                let k_n = shares.len();
                let l_n: usize = sizes.iter().sum();
                let mut flat = vec![0.0; k_n * l_n];
                let mut base = 0usize;
                for (r, sub) in subs.iter().enumerate() {
                    let l_r = sizes[r];
                    for k in 0..k_n {
                        let wk = shares[k][r];
                        for j in 0..l_r {
                            flat[k * l_n + base + j] = wk * sub.get(k, j);
                        }
                    }
                    base += l_r;
                }
                // mass conservation before normalisation: every row's
                // stitched mass is the share-weighted sum of unit rows
                for k in 0..k_n {
                    let mass: f64 =
                        flat[k * l_n..(k + 1) * l_n].iter().sum();
                    propkit::close(mass, 1.0, 1e-9)?;
                }
                let merged = Plan::from_flat(k_n, l_n, flat);
                for k in 0..k_n {
                    let row = merged.row(k);
                    propkit::close(row.iter().sum::<f64>(), 1.0, 1e-9)?;
                    if row.iter().any(|&v| !(0.0..=1.0 + 1e-12).contains(&v))
                    {
                        return Err(format!("row {k} out of range"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn budget_is_respected() {
        let (cfg, ev) = make_eval();
        let mut opt_cfg = cfg.opt.clone();
        opt_cfg.generations = 10_000;
        opt_cfg.budget_s = 0.2;
        let mut o =
            SlitOptimizer::new(opt_cfg, cfg.num_classes(), ev.dcs(), 1);
        let t = std::time::Instant::now();
        let out = o.optimize(&ev);
        assert!(t.elapsed().as_secs_f64() < 5.0);
        assert!(out.generations_run < 10_000);
        assert!(!out.archive.is_empty());
    }
}
