//! The SLIT metaheuristic (§5): gradient-boosting surrogate, ML-guided
//! local search, the evolutionary algorithm (Algorithm 1), and the
//! simulator-facing scheduler adapter.

pub mod gbdt;
pub mod oracle;
pub mod scheduler;
pub mod shift;
pub mod slit;

pub use gbdt::{Gbdt, GbdtConfig};
pub use oracle::{epoch_lower_bound, gap_reports, GapReport, OracleBound};
pub use scheduler::{FeedbackMode, SlitScheduler, SlitStats, SlitVariant};
pub use shift::{ShiftPolicy, ShiftScheduler, TemporalShifter};
pub use slit::{
    select_population, SearchMode, SlitOptimizer, SlitOptions, SlitOutcome,
    REGION_DECOMPOSE_THRESHOLD,
};
