//! Gradient-boosted regression trees (from scratch — §5.2's ML model [29]).
//!
//! SLIT's local search trains this on search trajectories (plan features ->
//! scalarised objective) and uses it to rank candidate neighbours so only
//! promising moves pay for a real evaluation. Least-squares boosting:
//! each tree greedily fits the pseudo-residuals of the ensemble so far.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct GbdtConfig {
    pub trees: usize,
    pub depth: usize,
    pub learning_rate: f64,
    pub min_leaf: usize,
    /// Features sampled per split (column subsampling); 0 = all.
    pub feature_sample: usize,
}

impl Default for GbdtConfig {
    fn default() -> Self {
        GbdtConfig {
            trees: 40,
            depth: 3,
            learning_rate: 0.15,
            min_leaf: 8,
            feature_sample: 0,
        }
    }
}

#[derive(Clone, Debug)]
enum Node {
    Leaf(f64),
    Split {
        feat: usize,
        thresh: f64,
        left: usize,
        right: usize,
    },
}

#[derive(Clone, Debug)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Leaf(v) => return *v,
                Node::Split {
                    feat,
                    thresh,
                    left,
                    right,
                } => {
                    i = if x[*feat] <= *thresh { *left } else { *right };
                }
            }
        }
    }
}

/// A trained gradient-boosting model.
#[derive(Clone, Debug)]
pub struct Gbdt {
    base: f64,
    lr: f64,
    trees: Vec<Tree>,
    pub n_features: usize,
}

impl Gbdt {
    /// Fit on row-major `xs` (n x d) against targets `ys`.
    pub fn fit(
        xs: &[Vec<f64>],
        ys: &[f64],
        cfg: &GbdtConfig,
        rng: &mut Rng,
    ) -> Gbdt {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty(), "gbdt: empty training set");
        let d = xs[0].len();
        let base = ys.iter().sum::<f64>() / ys.len() as f64;
        let mut residuals: Vec<f64> = ys.iter().map(|y| y - base).collect();
        let mut trees = Vec::with_capacity(cfg.trees);
        let idx: Vec<usize> = (0..xs.len()).collect();

        for _ in 0..cfg.trees {
            let mut nodes = Vec::new();
            build_node(
                xs,
                &residuals,
                &idx,
                cfg,
                cfg.depth,
                &mut nodes,
                rng,
                d,
            );
            let tree = Tree { nodes };
            for (i, x) in xs.iter().enumerate() {
                residuals[i] -= cfg.learning_rate * tree.predict(x);
            }
            trees.push(tree);
        }
        Gbdt {
            base,
            lr: cfg.learning_rate,
            trees,
            n_features: d,
        }
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.n_features);
        let sum: f64 = self.trees.iter().map(|t| t.predict(x)).sum();
        self.base + self.lr * sum
    }

    pub fn num_trees(&self) -> usize {
        self.trees.len()
    }
}

/// Recursively grow a tree node; returns its index in `nodes`.
#[allow(clippy::too_many_arguments)]
fn build_node(
    xs: &[Vec<f64>],
    res: &[f64],
    idx: &[usize],
    cfg: &GbdtConfig,
    depth_left: usize,
    nodes: &mut Vec<Node>,
    rng: &mut Rng,
    d: usize,
) -> usize {
    let mean: f64 =
        idx.iter().map(|&i| res[i]).sum::<f64>() / idx.len().max(1) as f64;
    if depth_left == 0 || idx.len() < 2 * cfg.min_leaf {
        nodes.push(Node::Leaf(mean));
        return nodes.len() - 1;
    }

    // choose candidate features
    let feats: Vec<usize> = if cfg.feature_sample > 0 && cfg.feature_sample < d
    {
        let mut all: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut all);
        all.truncate(cfg.feature_sample);
        all
    } else {
        (0..d).collect()
    };

    // best split by SSE reduction
    let total_sum: f64 = idx.iter().map(|&i| res[i]).sum();
    let n = idx.len() as f64;
    let mut best: Option<(usize, f64, f64)> = None; // feat, thresh, gain
    let mut vals: Vec<(f64, f64)> = Vec::with_capacity(idx.len());
    for &feat in &feats {
        vals.clear();
        vals.extend(idx.iter().map(|&i| (xs[i][feat], res[i])));
        vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut left_sum = 0.0;
        let mut left_n = 0.0;
        for w in 0..vals.len() - 1 {
            left_sum += vals[w].1;
            left_n += 1.0;
            if vals[w].0 == vals[w + 1].0 {
                continue; // can't split between equal values
            }
            if (left_n as usize) < cfg.min_leaf
                || (idx.len() - left_n as usize) < cfg.min_leaf
            {
                continue;
            }
            let right_sum = total_sum - left_sum;
            let right_n = n - left_n;
            // gain = sum^2/n improvements (variance reduction x n)
            let gain = left_sum * left_sum / left_n
                + right_sum * right_sum / right_n
                - total_sum * total_sum / n;
            if best.map(|(_, _, g)| gain > g).unwrap_or(gain > 1e-12) {
                let thresh = 0.5 * (vals[w].0 + vals[w + 1].0);
                best = Some((feat, thresh, gain));
            }
        }
    }

    let Some((feat, thresh, _)) = best else {
        nodes.push(Node::Leaf(mean));
        return nodes.len() - 1;
    };

    let (li, ri): (Vec<usize>, Vec<usize>) =
        idx.iter().partition(|&&i| xs[i][feat] <= thresh);
    // placeholder, fix up children after recursion
    nodes.push(Node::Leaf(0.0));
    let me = nodes.len() - 1;
    let left = build_node(xs, res, &li, cfg, depth_left - 1, nodes, rng, d);
    let right = build_node(xs, res, &ri, cfg, depth_left - 1, nodes, rng, d);
    nodes[me] = Node::Split {
        feat,
        thresh,
        left,
        right,
    };
    me
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mse(model: &Gbdt, xs: &[Vec<f64>], ys: &[f64]) -> f64 {
        xs.iter()
            .zip(ys)
            .map(|(x, y)| (model.predict(x) - y).powi(2))
            .sum::<f64>()
            / xs.len() as f64
    }

    #[test]
    fn fits_step_function() {
        let mut rng = Rng::new(1);
        let xs: Vec<Vec<f64>> =
            (0..400).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| if x[0] > 0.5 { 3.0 } else { -1.0 })
            .collect();
        let model = Gbdt::fit(&xs, &ys, &GbdtConfig::default(), &mut rng);
        assert!(mse(&model, &xs, &ys) < 0.05);
        assert!(model.predict(&[0.9, 0.5]) > 2.0);
        assert!(model.predict(&[0.1, 0.5]) < 0.0);
    }

    #[test]
    fn fits_additive_signal_better_with_more_trees() {
        let mut rng = Rng::new(2);
        let xs: Vec<Vec<f64>> = (0..600)
            .map(|_| vec![rng.f64(), rng.f64(), rng.f64()])
            .collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 2.0 * x[0] - 3.0 * x[1] + (x[2] * 6.0).sin())
            .collect();
        let small = Gbdt::fit(
            &xs,
            &ys,
            &GbdtConfig {
                trees: 5,
                ..Default::default()
            },
            &mut rng,
        );
        let big = Gbdt::fit(
            &xs,
            &ys,
            &GbdtConfig {
                trees: 80,
                ..Default::default()
            },
            &mut rng,
        );
        assert!(mse(&big, &xs, &ys) < mse(&small, &xs, &ys));
    }

    #[test]
    fn constant_target_predicts_constant() {
        let mut rng = Rng::new(3);
        let xs: Vec<Vec<f64>> = (0..50).map(|_| vec![rng.f64()]).collect();
        let ys = vec![7.5; 50];
        let model = Gbdt::fit(&xs, &ys, &GbdtConfig::default(), &mut rng);
        for x in &xs {
            assert!((model.predict(x) - 7.5).abs() < 1e-9);
        }
    }

    #[test]
    fn respects_min_leaf() {
        let mut rng = Rng::new(4);
        // 10 points, min_leaf 8 -> no split possible -> pure base model
        let xs: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let model = Gbdt::fit(
            &xs,
            &ys,
            &GbdtConfig {
                trees: 3,
                min_leaf: 8,
                ..Default::default()
            },
            &mut rng,
        );
        let mean = 4.5;
        assert!((model.predict(&[0.0]) - mean).abs() < 1e-9);
        assert!((model.predict(&[9.0]) - mean).abs() < 1e-9);
    }

    #[test]
    fn ranking_quality_on_plan_like_features() {
        // GBDT must rank plans by a synthetic objective well enough that
        // the top-quartile prediction overlaps the true top quartile
        let mut rng = Rng::new(5);
        let d = 24;
        let xs: Vec<Vec<f64>> = (0..500)
            .map(|_| (0..d).map(|_| rng.f64()).collect())
            .collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| x[0] * 5.0 + x[1] * x[2] * 3.0 - x[3])
            .collect();
        let model = Gbdt::fit(&xs, &ys, &GbdtConfig::default(), &mut rng);
        let mut by_pred: Vec<usize> = (0..xs.len()).collect();
        by_pred.sort_by(|&a, &b| {
            model.predict(&xs[a]).partial_cmp(&model.predict(&xs[b])).unwrap()
        });
        let mut by_true: Vec<usize> = (0..xs.len()).collect();
        by_true.sort_by(|&a, &b| ys[a].partial_cmp(&ys[b]).unwrap());
        let top: std::collections::HashSet<usize> =
            by_true[..125].iter().copied().collect();
        let hits = by_pred[..125].iter().filter(|i| top.contains(i)).count();
        assert!(hits > 60, "ranking overlap too weak: {hits}/125");
    }
}
