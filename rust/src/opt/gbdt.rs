//! Gradient-boosted regression trees (from scratch — §5.2's ML model [29]).
//!
//! SLIT's local search trains this on search trajectories (plan features ->
//! scalarised objective) and uses it to rank candidate neighbours so only
//! promising moves pay for a real evaluation. Least-squares boosting:
//! each tree greedily fits the pseudo-residuals of the ensemble so far.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct GbdtConfig {
    pub trees: usize,
    pub depth: usize,
    pub learning_rate: f64,
    pub min_leaf: usize,
    /// Features sampled per split (column subsampling); 0 = all.
    pub feature_sample: usize,
}

impl Default for GbdtConfig {
    fn default() -> Self {
        GbdtConfig {
            trees: 40,
            depth: 3,
            learning_rate: 0.15,
            min_leaf: 8,
            feature_sample: 0,
        }
    }
}

/// Pointer-shaped tree node, used only while *growing* a tree (the greedy
/// splitter recurses naturally over it). Fitted trees are immediately
/// flattened into the contiguous node arrays the predict path walks.
#[derive(Clone, Debug)]
enum Node {
    Leaf(f64),
    Split {
        feat: usize,
        thresh: f64,
        left: usize,
        right: usize,
    },
}

#[derive(Clone, Debug)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Leaf(v) => return *v,
                Node::Split {
                    feat,
                    thresh,
                    left,
                    right,
                } => {
                    i = if x[*feat] <= *thresh { *left } else { *right };
                }
            }
        }
    }
}

/// Leaf marker in the flattened node arrays.
const LEAF: u32 = u32::MAX;

/// A trained gradient-boosting model. All trees live flattened in three
/// contiguous struct-of-arrays buffers (`feat`/`thresh`/`kids`), so a
/// prediction walks cache-dense arrays with no enum matching or pointer
/// chasing — `predict_batch_into` scores a whole candidate arena slice
/// per call, which is how the SLIT surrogate ranks each step's merged
/// neighbour batch.
#[derive(Clone, Debug)]
pub struct Gbdt {
    base: f64,
    lr: f64,
    /// Split feature per node; [`LEAF`] marks a leaf.
    feat: Vec<u32>,
    /// Split threshold per node — or the leaf value for leaves.
    thresh: Vec<f64>,
    /// [left, right] child node indices (absolute; unused for leaves).
    kids: Vec<[u32; 2]>,
    /// Root node index of each tree.
    roots: Vec<u32>,
    pub n_features: usize,
}

impl Gbdt {
    /// Fit on row-major `xs` (n x d) against targets `ys`.
    pub fn fit(
        xs: &[Vec<f64>],
        ys: &[f64],
        cfg: &GbdtConfig,
        rng: &mut Rng,
    ) -> Gbdt {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty(), "gbdt: empty training set");
        let d = xs[0].len();
        let base = ys.iter().sum::<f64>() / ys.len() as f64;
        let mut residuals: Vec<f64> = ys.iter().map(|y| y - base).collect();
        let idx: Vec<usize> = (0..xs.len()).collect();

        let mut model = Gbdt {
            base,
            lr: cfg.learning_rate,
            feat: Vec::new(),
            thresh: Vec::new(),
            kids: Vec::new(),
            roots: Vec::with_capacity(cfg.trees),
            n_features: d,
        };
        for _ in 0..cfg.trees {
            let mut nodes = Vec::new();
            build_node(
                xs,
                &residuals,
                &idx,
                cfg,
                cfg.depth,
                &mut nodes,
                rng,
                d,
            );
            let tree = Tree { nodes };
            for (i, x) in xs.iter().enumerate() {
                residuals[i] -= cfg.learning_rate * tree.predict(x);
            }
            model.flatten_tree(&tree);
        }
        model
    }

    /// Append one grown tree to the flat node arrays (root first:
    /// `build_node` always places the subtree root at local index 0).
    fn flatten_tree(&mut self, tree: &Tree) {
        let offset = self.feat.len() as u32;
        self.roots.push(offset);
        for node in &tree.nodes {
            match node {
                Node::Leaf(v) => {
                    self.feat.push(LEAF);
                    self.thresh.push(*v);
                    self.kids.push([0, 0]);
                }
                Node::Split {
                    feat,
                    thresh,
                    left,
                    right,
                } => {
                    self.feat.push(*feat as u32);
                    self.thresh.push(*thresh);
                    self.kids
                        .push([offset + *left as u32, offset + *right as u32]);
                }
            }
        }
    }

    #[inline]
    fn walk_tree(&self, root: u32, x: &[f64]) -> f64 {
        let mut i = root as usize;
        loop {
            let f = self.feat[i];
            if f == LEAF {
                return self.thresh[i];
            }
            let right = (x[f as usize] > self.thresh[i]) as usize;
            i = self.kids[i][right] as usize;
        }
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.n_features);
        let mut sum = 0.0;
        for &root in &self.roots {
            sum += self.walk_tree(root, x);
        }
        self.base + self.lr * sum
    }

    /// Score every row of a row-major matrix (`stride` features per row —
    /// e.g. a `PlanBatch` arena slice) into `out`, which is cleared first.
    /// Bit-identical to per-row [`Gbdt::predict`]; reusing `out` keeps the
    /// per-step surrogate ranking allocation-free once warm.
    pub fn predict_batch_into(
        &self,
        xs: &[f64],
        stride: usize,
        out: &mut Vec<f64>,
    ) {
        assert_eq!(stride, self.n_features, "feature-width mismatch");
        assert_eq!(xs.len() % stride.max(1), 0, "ragged batch");
        out.clear();
        out.reserve(xs.len() / stride.max(1));
        for row in xs.chunks_exact(stride) {
            let mut sum = 0.0;
            for &root in &self.roots {
                sum += self.walk_tree(root, row);
            }
            out.push(self.base + self.lr * sum);
        }
    }

    /// Allocating convenience wrapper over [`Gbdt::predict_batch_into`].
    pub fn predict_batch(&self, xs: &[f64], stride: usize) -> Vec<f64> {
        let mut out = Vec::new();
        self.predict_batch_into(xs, stride, &mut out);
        out
    }

    pub fn num_trees(&self) -> usize {
        self.roots.len()
    }
}

/// Recursively grow a tree node; returns its index in `nodes`.
#[allow(clippy::too_many_arguments)]
fn build_node(
    xs: &[Vec<f64>],
    res: &[f64],
    idx: &[usize],
    cfg: &GbdtConfig,
    depth_left: usize,
    nodes: &mut Vec<Node>,
    rng: &mut Rng,
    d: usize,
) -> usize {
    let mean: f64 =
        idx.iter().map(|&i| res[i]).sum::<f64>() / idx.len().max(1) as f64;
    if depth_left == 0 || idx.len() < 2 * cfg.min_leaf {
        nodes.push(Node::Leaf(mean));
        return nodes.len() - 1;
    }

    // choose candidate features
    let feats: Vec<usize> = if cfg.feature_sample > 0 && cfg.feature_sample < d
    {
        let mut all: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut all);
        all.truncate(cfg.feature_sample);
        all
    } else {
        (0..d).collect()
    };

    // best split by SSE reduction
    let total_sum: f64 = idx.iter().map(|&i| res[i]).sum();
    let n = idx.len() as f64;
    let mut best: Option<(usize, f64, f64)> = None; // feat, thresh, gain
    let mut vals: Vec<(f64, f64)> = Vec::with_capacity(idx.len());
    for &feat in &feats {
        vals.clear();
        vals.extend(idx.iter().map(|&i| (xs[i][feat], res[i])));
        vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut left_sum = 0.0;
        let mut left_n = 0.0;
        for w in 0..vals.len() - 1 {
            left_sum += vals[w].1;
            left_n += 1.0;
            if vals[w].0 == vals[w + 1].0 {
                continue; // can't split between equal values
            }
            if (left_n as usize) < cfg.min_leaf
                || (idx.len() - left_n as usize) < cfg.min_leaf
            {
                continue;
            }
            let right_sum = total_sum - left_sum;
            let right_n = n - left_n;
            // gain = sum^2/n improvements (variance reduction x n)
            let gain = left_sum * left_sum / left_n
                + right_sum * right_sum / right_n
                - total_sum * total_sum / n;
            if best.map(|(_, _, g)| gain > g).unwrap_or(gain > 1e-12) {
                let thresh = 0.5 * (vals[w].0 + vals[w + 1].0);
                best = Some((feat, thresh, gain));
            }
        }
    }

    let Some((feat, thresh, _)) = best else {
        nodes.push(Node::Leaf(mean));
        return nodes.len() - 1;
    };

    let (li, ri): (Vec<usize>, Vec<usize>) =
        idx.iter().partition(|&&i| xs[i][feat] <= thresh);
    // placeholder, fix up children after recursion
    nodes.push(Node::Leaf(0.0));
    let me = nodes.len() - 1;
    let left = build_node(xs, res, &li, cfg, depth_left - 1, nodes, rng, d);
    let right = build_node(xs, res, &ri, cfg, depth_left - 1, nodes, rng, d);
    nodes[me] = Node::Split {
        feat,
        thresh,
        left,
        right,
    };
    me
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mse(model: &Gbdt, xs: &[Vec<f64>], ys: &[f64]) -> f64 {
        xs.iter()
            .zip(ys)
            .map(|(x, y)| (model.predict(x) - y).powi(2))
            .sum::<f64>()
            / xs.len() as f64
    }

    #[test]
    fn fits_step_function() {
        let mut rng = Rng::new(1);
        let xs: Vec<Vec<f64>> =
            (0..400).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| if x[0] > 0.5 { 3.0 } else { -1.0 })
            .collect();
        let model = Gbdt::fit(&xs, &ys, &GbdtConfig::default(), &mut rng);
        assert!(mse(&model, &xs, &ys) < 0.05);
        assert!(model.predict(&[0.9, 0.5]) > 2.0);
        assert!(model.predict(&[0.1, 0.5]) < 0.0);
    }

    #[test]
    fn fits_additive_signal_better_with_more_trees() {
        let mut rng = Rng::new(2);
        let xs: Vec<Vec<f64>> = (0..600)
            .map(|_| vec![rng.f64(), rng.f64(), rng.f64()])
            .collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 2.0 * x[0] - 3.0 * x[1] + (x[2] * 6.0).sin())
            .collect();
        let small = Gbdt::fit(
            &xs,
            &ys,
            &GbdtConfig {
                trees: 5,
                ..Default::default()
            },
            &mut rng,
        );
        let big = Gbdt::fit(
            &xs,
            &ys,
            &GbdtConfig {
                trees: 80,
                ..Default::default()
            },
            &mut rng,
        );
        assert!(mse(&big, &xs, &ys) < mse(&small, &xs, &ys));
    }

    #[test]
    fn constant_target_predicts_constant() {
        let mut rng = Rng::new(3);
        let xs: Vec<Vec<f64>> = (0..50).map(|_| vec![rng.f64()]).collect();
        let ys = vec![7.5; 50];
        let model = Gbdt::fit(&xs, &ys, &GbdtConfig::default(), &mut rng);
        for x in &xs {
            assert!((model.predict(x) - 7.5).abs() < 1e-9);
        }
    }

    #[test]
    fn respects_min_leaf() {
        let mut rng = Rng::new(4);
        // 10 points, min_leaf 8 -> no split possible -> pure base model
        let xs: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let model = Gbdt::fit(
            &xs,
            &ys,
            &GbdtConfig {
                trees: 3,
                min_leaf: 8,
                ..Default::default()
            },
            &mut rng,
        );
        let mean = 4.5;
        assert!((model.predict(&[0.0]) - mean).abs() < 1e-9);
        assert!((model.predict(&[9.0]) - mean).abs() < 1e-9);
    }

    #[test]
    fn predict_batch_matches_per_row_predict_bitwise() {
        let mut rng = Rng::new(6);
        let d = 12;
        let xs: Vec<Vec<f64>> = (0..200)
            .map(|_| (0..d).map(|_| rng.f64()).collect())
            .collect();
        let ys: Vec<f64> =
            xs.iter().map(|x| x[0] * 2.0 - x[5] + x[7] * x[2]).collect();
        let model = Gbdt::fit(&xs, &ys, &GbdtConfig::default(), &mut rng);
        // row-major flatten, the arena layout
        let flat: Vec<f64> = xs.iter().flatten().copied().collect();
        let batch = model.predict_batch(&flat, d);
        assert_eq!(batch.len(), xs.len());
        for (x, b) in xs.iter().zip(&batch) {
            assert_eq!(model.predict(x), *b, "flat walk diverged");
        }
        // _into reuses the output buffer
        let mut out = vec![0.0; 3];
        model.predict_batch_into(&flat, d, &mut out);
        assert_eq!(out, batch);
        // empty batch is fine
        model.predict_batch_into(&[], d, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn ranking_quality_on_plan_like_features() {
        // GBDT must rank plans by a synthetic objective well enough that
        // the top-quartile prediction overlaps the true top quartile
        let mut rng = Rng::new(5);
        let d = 24;
        let xs: Vec<Vec<f64>> = (0..500)
            .map(|_| (0..d).map(|_| rng.f64()).collect())
            .collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| x[0] * 5.0 + x[1] * x[2] * 3.0 - x[3])
            .collect();
        let model = Gbdt::fit(&xs, &ys, &GbdtConfig::default(), &mut rng);
        let mut by_pred: Vec<usize> = (0..xs.len()).collect();
        by_pred.sort_by(|&a, &b| {
            model.predict(&xs[a]).partial_cmp(&model.predict(&xs[b])).unwrap()
        });
        let mut by_true: Vec<usize> = (0..xs.len()).collect();
        by_true.sort_by(|&a, &b| ys[a].partial_cmp(&ys[b]).unwrap());
        let top: std::collections::HashSet<usize> =
            by_true[..125].iter().copied().collect();
        let hits = by_pred[..125].iter().filter(|i| top.contains(i)).count();
        assert!(hits > 60, "ranking overlap too weak: {hits}/125");
    }
}
