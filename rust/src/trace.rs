//! LLM workload model: a BurstGPT-like synthetic trace (repro substitution
//! for [19], DESIGN.md §3) plus request-level sampling.
//!
//! The generator reproduces the two trends the paper reads off Fig. 1:
//!   1. usage is dominated by smaller/older models (`small_model_frac`), and
//!   2. request intensity changes rapidly epoch-to-epoch (diurnal base x
//!      AR(1) jitter x heavy-tailed burst spikes).
//!
//! Epoch-level aggregates (`EpochLoad`) feed the analytic evaluator and the
//! predictor; request-level samples (`Request`) feed the discrete simulator
//! and the online serving example.

use crate::config::{SystemConfig, CLASSES, MODELS, REGIONS};
use crate::util::csv;
use crate::util::rng::Rng;

/// Aggregate demand of one (origin region, model) class within an epoch.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClassLoad {
    /// Number of interactive requests arriving this epoch (must be served
    /// in their arrival epoch).
    pub n_req: f64,
    /// Mean input tokens per request.
    pub tok_in: f64,
    /// Mean output tokens per request.
    pub tok_out: f64,
    /// Deferrable request mass arriving this epoch (batch/embedding/eval
    /// jobs) on top of `n_req`. The temporal-shifting layer (`opt::shift`)
    /// may hold it and release it into a later epoch's load; schedulers
    /// without a shifting policy serve it in the arrival epoch. Kept
    /// integral by the generator so served-mass comparisons across release
    /// schedules stay exact under `round()` sampling.
    pub defer_req: f64,
    /// Latest epoch (absolute index) by which `defer_req` must be served.
    /// Only meaningful when `defer_req > 0`.
    pub defer_deadline: usize,
}

/// Demand of all classes within one epoch.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EpochLoad {
    pub classes: Vec<ClassLoad>, // len = CLASSES
}

impl EpochLoad {
    pub fn total_requests(&self) -> f64 {
        self.classes.iter().map(|c| c.n_req).sum()
    }

    pub fn total_tokens(&self) -> f64 {
        self.classes
            .iter()
            .map(|c| c.n_req * (c.tok_in + c.tok_out))
            .sum()
    }

    /// Deferrable request mass offered this epoch (sum over classes).
    pub fn total_deferrable(&self) -> f64 {
        self.classes.iter().map(|c| c.defer_req).sum()
    }

    /// Scale request counts (used when realising predictions).
    pub fn scaled(&self, f: f64) -> EpochLoad {
        EpochLoad {
            classes: self
                .classes
                .iter()
                .map(|c| ClassLoad {
                    n_req: c.n_req * f,
                    defer_req: c.defer_req * f,
                    ..*c
                })
                .collect(),
        }
    }
}

/// A single inference request (discrete simulator / serving front).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    /// Arrival offset within the epoch, seconds.
    pub arrival_s: f64,
    /// Class index k = region * MODELS + model.
    pub class: usize,
    pub tok_in: u32,
    pub tok_out: u32,
}

impl Request {
    pub fn region(&self) -> usize {
        self.class / MODELS
    }

    pub fn model(&self) -> usize {
        self.class % MODELS
    }
}

/// A generated multi-epoch workload trace.
#[derive(Clone, Debug)]
pub struct Trace {
    pub epochs: Vec<EpochLoad>,
    pub seed: u64,
}

impl Trace {
    /// Generate `epochs` epochs of synthetic demand per the config knobs.
    pub fn generate(cfg: &SystemConfig, epochs: usize, seed: u64) -> Trace {
        let w = &cfg.workload;
        let mut rng = Rng::new(seed ^ 0x5452_4143_45); // "TRACE"
        let mut out = Vec::with_capacity(epochs);
        // AR(1) intensity jitter — "request intensity changes rapidly"
        let mut jitter = 0.0f64;
        for t in 0..epochs {
            // diurnal base in UTC weighted by the region mix and its local time
            let mut region_intensity = [0.0f64; REGIONS];
            for r in 0..REGIONS {
                // region local-time proxy: use the mean tz of sites there
                let tz = mean_region_tz(cfg, r);
                let hour =
                    (t as f64 * cfg.physics.epoch_s / 3600.0 + tz).rem_euclid(24.0);
                // daytime hump 8..23 local
                let day = (std::f64::consts::PI * ((hour - 7.0) / 16.0))
                    .sin()
                    .max(0.05);
                region_intensity[r] = w.region_mix[r] * day;
            }
            let mix_total: f64 = region_intensity.iter().sum();

            jitter = 0.55 * jitter + 0.45 * rng.gauss();
            let burst = if rng.chance(w.burst_prob) {
                1.0 + rng.gamma(2.0) * (w.burst_mult - 1.0) / 2.0
            } else {
                1.0
            };
            let intensity = (1.0 + 0.35 * jitter).max(0.1) * burst;

            let total_req = w.base_requests_per_epoch
                * w.request_scale
                * intensity
                * mix_total
                / w.delay_scale.max(1e-6); // shorter delays => more arrivals

            let mut classes = vec![ClassLoad::default(); CLASSES];
            for r in 0..REGIONS {
                let region_req = if mix_total > 0.0 {
                    total_req * region_intensity[r] / mix_total
                } else {
                    0.0
                };
                for m in 0..MODELS {
                    let share = if m == 0 {
                        w.small_model_frac
                    } else {
                        1.0 - w.small_model_frac
                    };
                    let spec = &cfg.models[m];
                    let n = rng.poisson(region_req * share) as f64;
                    classes[r * MODELS + m] = ClassLoad {
                        n_req: n,
                        tok_in: (spec.mean_in_tokens
                            * w.token_scale
                            * rng.lognormal(0.0, 0.12))
                        .max(1.0),
                        tok_out: (spec.mean_out_tokens
                            * w.token_scale
                            * rng.lognormal(0.0, 0.12))
                        .max(1.0),
                        ..ClassLoad::default()
                    };
                }
            }
            // Deferrable split: carve an integral share of each class off
            // into the deferrable component. Done *after* all RNG draws so
            // a deferrable trace is an exact partition of the frac=0 trace
            // (same seed => same totals), and so frac=0 stays bit-identical.
            if w.deferrable_frac > 0.0 {
                let deadline = (t + w.defer_slack_epochs).min(epochs - 1);
                for c in classes.iter_mut() {
                    let d = (c.n_req * w.deferrable_frac).round();
                    c.defer_req = d;
                    c.n_req -= d;
                    c.defer_deadline = deadline;
                }
            }
            out.push(EpochLoad { classes });
        }
        Trace { epochs: out, seed }
    }

    /// Sample individual requests for one epoch (Poisson arrivals within
    /// the epoch, log-normal token counts around the class means).
    pub fn sample_requests(
        &self,
        cfg: &SystemConfig,
        epoch: usize,
        rng: &mut Rng,
    ) -> Vec<Request> {
        Trace::sample_load(cfg, &self.epochs[epoch], rng)
    }

    /// Sample requests for an arbitrary epoch load — the session uses this
    /// on the *effective* load (interactive + released deferrable mass)
    /// rather than the raw trace epoch. Deferrable mass still queued is
    /// not sampled; only `n_req` is realised.
    pub fn sample_load(
        cfg: &SystemConfig,
        load: &EpochLoad,
        rng: &mut Rng,
    ) -> Vec<Request> {
        let mut reqs = Vec::new();
        for (k, c) in load.classes.iter().enumerate() {
            let n = c.n_req.round() as usize;
            for _ in 0..n {
                reqs.push(Request {
                    arrival_s: rng.f64() * cfg.physics.epoch_s,
                    class: k,
                    tok_in: (c.tok_in * rng.lognormal(0.0, 0.35)).max(1.0)
                        as u32,
                    tok_out: (c.tok_out * rng.lognormal(0.0, 0.35)).max(1.0)
                        as u32,
                });
            }
        }
        reqs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        reqs
    }

    /// Import a trace previously exported by [`Trace::write_csv`] (or an
    /// external trace converted to the same schema) — lets experiments run
    /// against real request logs instead of the synthetic generator.
    pub fn from_csv(path: &str, cfg: &SystemConfig) -> anyhow::Result<Trace> {
        let (header, rows) = csv::read_file(path)?;
        anyhow::ensure!(
            header.first().map(String::as_str) == Some("epoch"),
            "not a slit trace csv (header {header:?})"
        );
        let class_cols: Vec<usize> = (0..CLASSES)
            .map(|k| {
                header
                    .iter()
                    .position(|h| h == &format!("class{k}_req"))
                    .ok_or_else(|| anyhow::anyhow!("missing class{k}_req"))
            })
            .collect::<anyhow::Result<_>>()?;
        let mut epochs = Vec::with_capacity(rows.len());
        for row in rows {
            let mut classes = vec![ClassLoad::default(); CLASSES];
            for (k, &col) in class_cols.iter().enumerate() {
                let spec = &cfg.models[k % MODELS];
                classes[k] = ClassLoad {
                    n_req: row
                        .get(col)
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(0.0),
                    tok_in: spec.mean_in_tokens * cfg.workload.token_scale,
                    tok_out: spec.mean_out_tokens * cfg.workload.token_scale,
                    ..ClassLoad::default()
                };
            }
            epochs.push(EpochLoad { classes });
        }
        Ok(Trace { epochs, seed: 0 })
    }

    /// Scale one epoch's request counts in place (scenario shaping hook:
    /// diurnal amplification, burst injection, demand shedding).
    pub fn scale_epoch(&mut self, epoch: usize, factor: f64) {
        if let Some(e) = self.epochs.get_mut(epoch) {
            *e = e.scaled(factor);
        }
    }

    /// Tokens requested per epoch — the Fig. 1 series.
    pub fn tokens_per_epoch(&self) -> Vec<f64> {
        self.epochs.iter().map(EpochLoad::total_tokens).collect()
    }

    /// Export the Fig. 1 series + per-class counts to CSV.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut header: Vec<String> =
            vec!["epoch".into(), "total_tokens".into(), "total_requests".into()];
        for k in 0..CLASSES {
            header.push(format!("class{k}_req"));
        }
        let refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut w = csv::CsvWriter::create(path, &refs)?;
        for (t, e) in self.epochs.iter().enumerate() {
            let mut row = vec![
                t as f64,
                e.total_tokens(),
                e.total_requests(),
            ];
            for c in &e.classes {
                row.push(c.n_req);
            }
            w.row_f64(&row)?;
        }
        w.finish()
    }
}

fn mean_region_tz(cfg: &SystemConfig, region: usize) -> f64 {
    let tzs: Vec<f64> = cfg
        .datacenters
        .iter()
        .filter(|d| d.region == region)
        .map(|d| d.tz_offset_h)
        .collect();
    if tzs.is_empty() {
        0.0
    } else {
        tzs.iter().sum::<f64>() / tzs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn small_trace() -> (SystemConfig, Trace) {
        let cfg = SystemConfig::small_test();
        let t = Trace::generate(&cfg, 96, 11);
        (cfg, t)
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = SystemConfig::small_test();
        let a = Trace::generate(&cfg, 32, 5);
        let b = Trace::generate(&cfg, 32, 5);
        let c = Trace::generate(&cfg, 32, 6);
        assert_eq!(a.epochs, b.epochs);
        assert_ne!(a.epochs, c.epochs);
    }

    #[test]
    fn small_model_dominates() {
        let (_, t) = small_trace();
        let mut small = 0.0;
        let mut large = 0.0;
        for e in &t.epochs {
            for (k, c) in e.classes.iter().enumerate() {
                if k % MODELS == 0 {
                    small += c.n_req;
                } else {
                    large += c.n_req;
                }
            }
        }
        assert!(small > 2.5 * large, "small {small} large {large}");
    }

    #[test]
    fn intensity_varies_rapidly() {
        // trend 2: neighbouring epochs should differ noticeably
        let (_, t) = small_trace();
        let toks = t.tokens_per_epoch();
        let mut rel_changes = Vec::new();
        for w in toks.windows(2) {
            if w[0] > 0.0 {
                rel_changes.push(((w[1] - w[0]) / w[0]).abs());
            }
        }
        let mean_change =
            rel_changes.iter().sum::<f64>() / rel_changes.len() as f64;
        assert!(mean_change > 0.05, "trace too smooth: {mean_change}");
    }

    #[test]
    fn request_scale_scales_requests() {
        let mut cfg = SystemConfig::small_test();
        let lo = Trace::generate(&cfg, 48, 3);
        cfg.workload.request_scale = 10.0;
        let hi = Trace::generate(&cfg, 48, 3);
        let sum = |t: &Trace| -> f64 {
            t.epochs.iter().map(EpochLoad::total_requests).sum()
        };
        let ratio = sum(&hi) / sum(&lo).max(1.0);
        assert!((6.0..14.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn token_scale_scales_tokens_per_request() {
        let mut cfg = SystemConfig::small_test();
        cfg.workload.token_scale = 1.0;
        let lo = Trace::generate(&cfg, 48, 3);
        cfg.workload.token_scale = 3.0;
        let hi = Trace::generate(&cfg, 48, 3);
        let mean_tok = |t: &Trace| -> f64 {
            let (mut s, mut n) = (0.0, 0.0);
            for e in &t.epochs {
                for c in &e.classes {
                    s += c.tok_out * c.n_req;
                    n += c.n_req;
                }
            }
            s / n.max(1.0)
        };
        let ratio = mean_tok(&hi) / mean_tok(&lo);
        assert!((2.5..3.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn sampled_requests_match_epoch_counts() {
        let (cfg, t) = small_trace();
        let mut rng = Rng::new(1);
        let reqs = t.sample_requests(&cfg, 10, &mut rng);
        assert_eq!(reqs.len() as f64, t.epochs[10].total_requests());
        // arrivals sorted and within the epoch
        for w in reqs.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
        for r in &reqs {
            assert!(r.arrival_s >= 0.0 && r.arrival_s < cfg.physics.epoch_s);
            assert!(r.class < CLASSES);
            assert!(r.tok_in >= 1 && r.tok_out >= 1);
        }
    }

    #[test]
    fn csv_round_trip() {
        let (_, t) = small_trace();
        let dir = std::env::temp_dir().join("slit_trace_test.csv");
        let path = dir.to_str().unwrap();
        t.write_csv(path).unwrap();
        let (header, rows) = crate::util::csv::read_file(path).unwrap();
        assert_eq!(header[0], "epoch");
        assert_eq!(rows.len(), t.epochs.len());
        let tok0: f64 = rows[0][1].parse().unwrap();
        assert!((tok0 - t.epochs[0].total_tokens()).abs() < 1.0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_import_preserves_request_counts() {
        let (cfg, t) = small_trace();
        let dir = std::env::temp_dir().join("slit_trace_import.csv");
        let path = dir.to_str().unwrap();
        t.write_csv(path).unwrap();
        let t2 = Trace::from_csv(path, &cfg).unwrap();
        assert_eq!(t2.epochs.len(), t.epochs.len());
        for (a, b) in t.epochs.iter().zip(&t2.epochs) {
            for k in 0..CLASSES {
                assert!(
                    (a.classes[k].n_req - b.classes[k].n_req).abs() < 1e-9
                );
            }
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_import_rejects_garbage() {
        let dir = std::env::temp_dir().join("slit_trace_bad.csv");
        std::fs::write(&dir, "foo,bar\n1,2\n").unwrap();
        let cfg = SystemConfig::small_test();
        assert!(Trace::from_csv(dir.to_str().unwrap(), &cfg).is_err());
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn deferrable_split_partitions_the_frac0_trace() {
        // the deferrable carve-out happens after all RNG draws, so a
        // deferrable trace is an exact partition of the frac=0 trace
        let mut cfg = SystemConfig::small_test();
        let plain = Trace::generate(&cfg, 48, 7);
        cfg.workload.deferrable_frac = 0.35;
        cfg.workload.defer_slack_epochs = 12;
        let split = Trace::generate(&cfg, 48, 7);
        for (t, (a, b)) in plain.epochs.iter().zip(&split.epochs).enumerate()
        {
            for (ca, cb) in a.classes.iter().zip(&b.classes) {
                assert_eq!(ca.n_req, cb.n_req + cb.defer_req, "epoch {t}");
                assert_eq!(ca.tok_in, cb.tok_in);
                assert_eq!(ca.tok_out, cb.tok_out);
                // integral deferrable units keep round() sampling exact
                assert_eq!(cb.defer_req, cb.defer_req.round());
                assert!(cb.defer_req >= 0.0);
                if cb.defer_req > 0.0 {
                    assert!(cb.defer_deadline >= t);
                    assert!(cb.defer_deadline <= (t + 12).min(47));
                }
            }
        }
        assert!(
            split.epochs.iter().map(EpochLoad::total_deferrable).sum::<f64>()
                > 0.0,
            "split produced no deferrable mass"
        );
    }

    #[test]
    fn zero_deferrable_frac_is_bit_identical() {
        let mut cfg = SystemConfig::small_test();
        cfg.workload.deferrable_frac = 0.0;
        let a = Trace::generate(&cfg, 32, 5);
        let b = Trace::generate(&SystemConfig::small_test(), 32, 5);
        assert_eq!(a.epochs, b.epochs);
    }

    #[test]
    fn bursts_present_at_paper_scale() {
        let cfg = SystemConfig::paper_default();
        let t = Trace::generate(&cfg, 1344, 9); // two weeks
        let toks = t.tokens_per_epoch();
        let mean = toks.iter().sum::<f64>() / toks.len() as f64;
        let max = toks.iter().cloned().fold(0.0f64, f64::max);
        assert!(max > 2.0 * mean, "no bursts: max {max} mean {mean}");
    }
}
