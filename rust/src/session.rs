//! `SimSession` — the streaming epoch loop the batch `sim::simulate()`
//! wrapper is built on.
//!
//! A session owns a mutable [`ClusterState`] (live per-site node counts,
//! derived from but no longer identical to the `SystemConfig`) and
//! advances one epoch per [`SimSession::step`]. Three hooks open the loop
//! up to the time-varying world the paper re-plans against every 15
//! minutes:
//!
//! * [`ScenarioEvent`]s mutate the cluster mid-run (rolling outages,
//!   brownouts, node additions) — they fire at the *start* of their epoch,
//!   before the framework plans, so schedulers see the degraded world.
//! * [`EpochObserver`] sinks receive every completed [`EpochRecord`]
//!   (CSV/JSON time-series, progress reporting) without buffering the
//!   whole run.
//! * The [`sim::EpochContext`] handed to `Scheduler::plan` carries the
//!   previous epoch's *actual* ledger, so schedulers can correct for
//!   prediction error (the feedback-aware SLIT variant).
//!
//! Event ordering within one `step()` (see DESIGN.md §11, §15):
//!   events -> shift(deferrable) -> predict -> panels(state) -> plan ->
//!   route/place -> account(state) -> observe(predictor) -> observers.
//!
//! With no events and no cluster mutations the session is bit-identical
//! to the legacy batch path (rust/tests/session_equivalence.rs pins it).

use crate::cluster::{build_panels_with, ClusterAction, ClusterState};
use crate::config::SystemConfig;
use crate::eval::{AnalyticEvaluator, EvalConsts};
use crate::models::EpochLedger;
use crate::opt::shift::TemporalShifter;
use crate::plan::Plan;
use crate::power::GridSignals;
use crate::predictor::WorkloadPredictor;
use crate::sched::LocalScheduler;
use crate::signals::{SignalFeed, SignalPolicy};
use crate::sim::{EpochContext, EpochRecord, Scheduler, SimResult};
use crate::trace::{EpochLoad, Trace};
use crate::util::csv::CsvWriter;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// A scheduled mutation of the live cluster topology: `action` fires at
/// the start of `epoch`, before the framework plans that epoch.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioEvent {
    pub epoch: usize,
    pub action: ClusterAction,
}

impl ScenarioEvent {
    pub fn at(epoch: usize, action: ClusterAction) -> ScenarioEvent {
        ScenarioEvent { epoch, action }
    }
}

/// Telemetry sink notified after every completed epoch.
pub trait EpochObserver {
    /// Called once per completed epoch with the realised record and the
    /// cluster state the epoch ran against.
    fn on_epoch(&mut self, record: &EpochRecord, state: &ClusterState);
    /// Called once when the session finishes (after the last epoch).
    fn on_finish(&mut self, _result: &SimResult) {}
}

/// Streaming simulation session: one framework over one world, one epoch
/// per `step()`. Construct with [`SimSession::new`], optionally attach
/// events/observers, then either drive `step()` manually or call
/// [`SimSession::run`].
pub struct SimSession<'a> {
    cfg: &'a SystemConfig,
    trace: &'a Trace,
    signals: &'a GridSignals,
    scheduler: &'a mut dyn Scheduler,
    epochs: usize,
    epoch: usize,
    rng: Rng,
    predictor: WorkloadPredictor,
    locals: Vec<LocalScheduler>,
    state: ClusterState,
    unused_pr: f64,
    /// Temporal-shifting layer for deferrable trace mass; inert (and
    /// forecaster-free) when the trace carries none.
    shifter: TemporalShifter,
    /// Telemetry layer between ground truth and every signal consumer.
    /// With no `Signal` events it is a bit-exact passthrough.
    feed: SignalFeed,
    /// Which believed view the framework consumes (from
    /// `Scheduler::signal_policy`, read once at construction).
    signal_policy: SignalPolicy,
    events: Vec<ScenarioEvent>,
    observers: Vec<Box<dyn EpochObserver + 'a>>,
    per_epoch: Vec<EpochRecord>,
    total: EpochLedger,
    prev_ledger: Option<EpochLedger>,
}

impl<'a> SimSession<'a> {
    pub fn new(
        cfg: &'a SystemConfig,
        trace: &'a Trace,
        signals: &'a GridSignals,
        scheduler: &'a mut dyn Scheduler,
        seed: u64,
    ) -> SimSession<'a> {
        let epochs = cfg.epochs.min(trace.epochs.len());
        let unused_pr = scheduler.unused_pr(&cfg.physics);
        let shifter =
            TemporalShifter::new(cfg, trace, scheduler.shift_policy());
        let feed = SignalFeed::new(cfg);
        let signal_policy = scheduler.signal_policy();
        SimSession {
            feed,
            signal_policy,
            epochs,
            epoch: 0,
            rng: Rng::new(seed ^ 0x53494D), // "SIM" — matches the legacy path
            predictor: WorkloadPredictor::new(cfg),
            locals: (0..cfg.datacenters.len())
                .map(|l| LocalScheduler::new(cfg, l))
                .collect(),
            state: ClusterState::from_config(cfg),
            unused_pr,
            shifter,
            events: Vec::new(),
            observers: Vec::new(),
            per_epoch: Vec::with_capacity(epochs),
            total: EpochLedger::default(),
            prev_ledger: None,
            cfg,
            trace,
            signals,
            scheduler,
        }
    }

    /// Attach a schedule of cluster mutations (builder style).
    pub fn with_events(mut self, events: Vec<ScenarioEvent>) -> Self {
        self.events.extend(events);
        self
    }

    /// Attach a telemetry sink (builder style).
    pub fn with_observer(
        mut self,
        observer: Box<dyn EpochObserver + 'a>,
    ) -> Self {
        self.observers.push(observer);
        self
    }

    pub fn add_observer(&mut self, observer: Box<dyn EpochObserver + 'a>) {
        self.observers.push(observer);
    }

    /// The live cluster topology.
    pub fn cluster(&self) -> &ClusterState {
        &self.state
    }

    /// Mutate the cluster between steps (manual alternative to events).
    pub fn apply(&mut self, action: &ClusterAction) {
        self.state.apply(action);
    }

    /// Next epoch index to be simulated.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    pub fn epochs(&self) -> usize {
        self.epochs
    }

    pub fn is_done(&self) -> bool {
        self.epoch >= self.epochs
    }

    /// Completed epoch records so far.
    pub fn records(&self) -> &[EpochRecord] {
        &self.per_epoch
    }

    /// Advance one epoch; `None` once the horizon is exhausted.
    pub fn step(&mut self) -> Option<&EpochRecord> {
        if self.epoch >= self.epochs {
            return None;
        }
        let epoch = self.epoch;

        // 1. scheduled events for this epoch fire first, so the framework
        //    plans against the changed world: capacity events mutate the
        //    cluster, telemetry faults go to the signal feed
        for ev in &self.events {
            if ev.epoch == epoch {
                if let ClusterAction::Signal(fault) = &ev.action {
                    self.feed.inject(epoch, fault);
                } else {
                    self.state.apply(&ev.action);
                }
            }
        }

        // 1b. the signal plane absorbs this epoch's ground truth; every
        //    *planning* consumer below (shifter, panels) reads the
        //    framework's believed view instead of truth. With no faults
        //    the believed view is bit-identical to truth, so every
        //    pre-existing path is unchanged (rust/tests/signal_faults.rs).
        let (ci, wi, tou) = self.signals.at(epoch);
        self.feed.observe(epoch, &ci, &wi, &tou);
        let (sig_fresh, sig_stale, sig_quar) = self.feed.health_counts();
        let sig_div =
            self.feed.divergence(self.signal_policy, &ci, &wi, &tou);
        let (bci, bwi, btou) = self.feed.view(self.signal_policy);

        // 2. temporal shifting: deferrable mass is queued/released against
        //    the epoch's believed grid signals BEFORE prediction and panel
        //    build, so the spatial scheduler plans for the released mass.
        //    With no deferrable mass in the trace this is a no-op and the
        //    effective load aliases the trace epoch (bit-identity).
        let actual = &self.trace.epochs[epoch];
        let shift = self.shifter.step(
            epoch,
            self.epochs - 1,
            actual,
            bci,
            bwi,
            btou,
        );
        let released_load = (shift.released_mass > 0.0).then(|| {
            let mut eff = actual.clone();
            for (k, c) in eff.classes.iter_mut().enumerate() {
                c.n_req += shift.released[k];
            }
            eff
        });
        let effective: &EpochLoad = released_load.as_ref().unwrap_or(actual);

        // 3. forecast: first epoch is known at t=0 (bootstrap), then the
        //    15-minute-lookahead predictor takes over. Released deferrable
        //    mass is a *known* addition (the shifter just decided it), so
        //    it rides on top of the interactive prediction.
        let predicted = if epoch == 0 {
            effective.clone()
        } else {
            let mut p = self.predictor.predict_next();
            if shift.released_mass > 0.0 {
                for (k, c) in p.classes.iter_mut().enumerate() {
                    c.n_req += shift.released[k];
                }
            }
            p
        };

        // 4. panels + evaluator bound to the live cluster state and the
        //    framework's *believed* grid signals
        let (cp, dp) = build_panels_with(
            self.cfg,
            &self.state,
            bci,
            bwi,
            btou,
            &predicted,
            self.unused_pr,
        );
        let evaluator = AnalyticEvaluator::new(
            cp,
            dp,
            EvalConsts::from_physics(&self.cfg.physics),
        );

        // 5. the framework's decision, with last epoch's realised ledger
        //    exposed for prediction-error feedback
        let ctx = EpochContext {
            cfg: self.cfg,
            epoch,
            predicted: &predicted,
            evaluator: &evaluator,
            cluster: &self.state,
            prev: self.prev_ledger.as_ref(),
        };
        let t_decision = std::time::Instant::now();
        let plan = self.scheduler.plan(&ctx);
        let decision_s = t_decision.elapsed().as_secs_f64();
        assert!(
            plan.is_valid(),
            "{} produced invalid plan",
            self.scheduler.name()
        );

        // 6. discrete execution against the EFFECTIVE load (interactive
        //    actuals + deferrable mass released this epoch) --------------
        let mut ledger = EpochLedger::default();
        for (l, ls) in self.locals.iter_mut().enumerate() {
            ls.new_epoch_with(self.cfg, self.state.nodes(l));
        }
        let requests = Trace::sample_load(self.cfg, effective, &mut self.rng);
        let default_plan = Plan::uniform(plan.classes, plan.dcs);
        // per-class realised count to detect prediction misses (Algorithm
        // 1 lines 22-23: overflow rides the default plan)
        let mut seen = vec![0.0f64; plan.classes];
        let dcs = self.cfg.datacenters.len();

        for req in &requests {
            let k = req.class;
            seen[k] += 1.0;
            let missed = seen[k] > predicted.classes[k].n_req.ceil().max(1.0);
            let row = if missed {
                default_plan.row(k)
            } else {
                plan.row(k)
            };
            // route by plan weights; fall back to other sites on saturation
            let first = self.rng.weighted(row);
            let mut placed = false;
            for attempt in 0..dcs {
                let l = (first + attempt) % dcs;
                if row[l] <= 0.0 && attempt == 0 && row[first] <= 0.0 {
                    continue;
                }
                let hops = self.cfg.hops(req.region(), l);
                // serverless container churn: a cold_frac share of requests
                // land on a cold container and pay the Eq. 2 load latency
                let is_warm = !self.rng.chance(self.cfg.physics.cold_frac);
                if let Some(p) =
                    self.locals[l].place(self.cfg, req, hops, is_warm)
                {
                    ledger.add_request(p.ttft_s);
                    placed = true;
                    break;
                }
            }
            if !placed {
                ledger.dropped += 1.0;
                // a dropped request is re-queued; charge the configured
                // re-queue latency penalty
                ledger.add_request(self.cfg.physics.drop_penalty_s);
            }
        }
        // realised per-class demand (served + dropped): the signal the
        // per-class feedback scheduler corrects its forecast with
        ledger.class_requests = seen;

        // 7. energy/water/carbon accounting (Eqs. 5-18) against the live
        //    node counts — an offline site burns nothing
        for (l, ls) in self.locals.iter().enumerate() {
            let spec = &self.cfg.datacenters[l];
            let live = self.state.nodes(l);
            let mut e_it = 0.0;
            for (ti, nt) in self.cfg.node_types.iter().enumerate() {
                let on = ls.capacity.on_nodes(ti, self.cfg.physics.epoch_s);
                let nodes = live[ti] as f64;
                e_it += (on * self.cfg.physics.pr_on
                    + (nodes - on) * self.unused_pr)
                    * nt.tdp_w
                    * self.cfg.physics.epoch_s;
            }
            ledger.add_site(
                e_it,
                spec.cop,
                tou[l],
                self.cfg.physics.h_water,
                self.cfg.physics.d_ratio,
                wi[l],
                self.cfg.physics.ei_pot,
                self.cfg.physics.ei_waste,
                ci[l],
            );
        }

        // deferral accounting rides the ledger so observers/CSV see it
        ledger.deferred_offered = shift.offered;
        ledger.deferred_released = shift.released_mass;
        ledger.deferred_queued = shift.queued;
        ledger.deferred_expired = shift.expired;

        // signal-plane accounting: feed health plus the believed-vs-truth
        // divergence the framework actually planned on (zero without
        // faults — the measurable regret input)
        ledger.signal_fresh = sig_fresh as f64;
        ledger.signal_stale = sig_stale as f64;
        ledger.signal_quarantined = sig_quar as f64;
        ledger.signal_div = sig_div;

        // optimality-gap oracle: certified per-objective lower bound for
        // this epoch's placement problem vs the plan's analytic score,
        // under the same evaluator the framework planned against. Pure
        // and RNG-free, so the simulation stays bit-identical per seed.
        let gaps = crate::opt::oracle::gap_reports(&evaluator, &plan);
        for (i, g) in gaps.iter().enumerate() {
            ledger.oracle_lb[i] = g.oracle_score;
            ledger.oracle_achieved[i] = g.achieved;
            ledger.oracle_slack[i] = g.quantization_slack;
        }

        // 8. close the loop: predictor, totals, feedback ledger, record.
        //    The predictor tracks the *interactive* series only — released
        //    deferrable mass is known, not forecast.
        self.predictor.observe(actual);
        self.total.merge(&ledger);
        self.prev_ledger = Some(ledger.clone());
        self.per_epoch.push(EpochRecord {
            epoch,
            ledger,
            plan,
            decision_s,
            site_nodes: self.state.site_totals(),
            gaps,
        });
        self.epoch += 1;

        // 9. telemetry sinks see the completed epoch
        let record = self.per_epoch.last().expect("record just pushed");
        for obs in &mut self.observers {
            obs.on_epoch(record, &self.state);
        }
        Some(record)
    }

    /// Drive the session to the end of the horizon and collect the result.
    pub fn run(mut self) -> SimResult {
        while self.step().is_some() {}
        self.finish()
    }

    /// Collect the result of the epochs simulated so far.
    pub fn finish(mut self) -> SimResult {
        let result = SimResult {
            name: self.scheduler.name(),
            per_epoch: self.per_epoch,
            total: self.total,
        };
        for obs in &mut self.observers {
            obs.on_finish(&result);
        }
        result
    }
}

// --------------------------------------------------------------------------
// Built-in observers
// --------------------------------------------------------------------------

/// Streams one CSV row per epoch — the Fig. 5 time series plus the live
/// capacity column that makes rolling outages visible.
pub struct CsvEpochObserver {
    writer: Option<CsvWriter<std::io::BufWriter<std::fs::File>>>,
}

impl CsvEpochObserver {
    pub const HEADER: [&'static str; 26] = [
        "epoch",
        "ttft_s",
        "carbon_kg",
        "water_l",
        "cost_usd",
        "requests",
        "dropped",
        "decision_s",
        "nodes_total",
        "ttft_p50_s",
        "ttft_p95_s",
        "ttft_p99_s",
        "deferred_offered",
        "deferred_released",
        "deferred_queued",
        "deferred_expired",
        "gap_ttft",
        "gap_carbon",
        "gap_water",
        "gap_cost",
        "sig_fresh",
        "sig_stale",
        "sig_quar",
        "sig_div_ci",
        "sig_div_wue",
        "sig_div_tou",
    ];

    pub fn create(path: &str) -> std::io::Result<CsvEpochObserver> {
        Ok(CsvEpochObserver {
            writer: Some(CsvWriter::create(path, &Self::HEADER)?),
        })
    }
}

impl EpochObserver for CsvEpochObserver {
    fn on_epoch(&mut self, record: &EpochRecord, _state: &ClusterState) {
        if let Some(w) = &mut self.writer {
            let nodes: usize = record.site_nodes.iter().sum();
            let _ = w.row_f64(&[
                record.epoch as f64,
                record.ledger.mean_ttft_s(),
                record.ledger.carbon_kg,
                record.ledger.water_l,
                record.ledger.cost_usd,
                record.ledger.requests,
                record.ledger.dropped,
                record.decision_s,
                nodes as f64,
                record.ledger.ttft_hist.p50(),
                record.ledger.ttft_hist.p95(),
                record.ledger.ttft_hist.p99(),
                record.ledger.deferred_offered,
                record.ledger.deferred_released,
                record.ledger.deferred_queued,
                record.ledger.deferred_expired,
                record.gaps[0].gap_frac,
                record.gaps[1].gap_frac,
                record.gaps[2].gap_frac,
                record.gaps[3].gap_frac,
                record.ledger.signal_fresh,
                record.ledger.signal_stale,
                record.ledger.signal_quarantined,
                record.ledger.signal_div[0],
                record.ledger.signal_div[1],
                record.ledger.signal_div[2],
            ]);
        }
    }

    fn on_finish(&mut self, _result: &SimResult) {
        if let Some(w) = self.writer.take() {
            let _ = w.finish();
        }
    }
}

/// Buffers the per-epoch series and writes one JSON document on finish.
pub struct JsonEpochObserver {
    path: String,
    rows: Vec<Json>,
}

impl JsonEpochObserver {
    pub fn new(path: &str) -> JsonEpochObserver {
        JsonEpochObserver {
            path: path.into(),
            rows: Vec::new(),
        }
    }
}

impl EpochObserver for JsonEpochObserver {
    fn on_epoch(&mut self, record: &EpochRecord, _state: &ClusterState) {
        let nodes: usize = record.site_nodes.iter().sum();
        self.rows.push(Json::num_arr(&[
            record.epoch as f64,
            record.ledger.mean_ttft_s(),
            record.ledger.carbon_kg,
            record.ledger.water_l,
            record.ledger.cost_usd,
            record.ledger.requests,
            record.ledger.dropped,
            nodes as f64,
        ]));
    }

    fn on_finish(&mut self, result: &SimResult) {
        let mut root = Json::obj();
        root.set("name", Json::Str(result.name.clone()));
        root.set("objectives", Json::num_arr(&result.objectives()));
        root.set("per_epoch", Json::Arr(std::mem::take(&mut self.rows)));
        let _ = std::fs::write(&self.path, root.to_string_pretty());
    }
}

/// Prints a one-line progress report every `every` epochs.
pub struct ProgressObserver {
    every: usize,
}

impl ProgressObserver {
    pub fn new(every: usize) -> ProgressObserver {
        ProgressObserver {
            every: every.max(1),
        }
    }
}

impl EpochObserver for ProgressObserver {
    fn on_epoch(&mut self, record: &EpochRecord, state: &ClusterState) {
        if record.epoch % self.every == 0 {
            let nodes: usize = state.site_totals().iter().sum();
            eprintln!(
                "  epoch {:>4}: ttft {:.3}s  carbon {:.2}kg  {} nodes live",
                record.epoch,
                record.ledger.mean_ttft_s(),
                record.ledger.carbon_kg,
                nodes
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterAction;
    use crate::config::SystemConfig;
    use crate::sim::simulate;

    /// Trivial scheduler: always the uniform plan, always-warm.
    struct Uniform;
    impl Scheduler for Uniform {
        fn name(&self) -> String {
            "uniform".into()
        }
        fn plan(&mut self, ctx: &EpochContext) -> Plan {
            Plan::uniform(ctx.cfg.num_classes(), ctx.cfg.datacenters.len())
        }
    }

    fn world(cfg: &SystemConfig, seed: u64) -> (Trace, GridSignals) {
        (
            Trace::generate(cfg, cfg.epochs, seed),
            GridSignals::generate(cfg, cfg.epochs, seed),
        )
    }

    #[test]
    fn step_by_step_matches_batch_wrapper() {
        let cfg = SystemConfig::small_test();
        let (trace, signals) = world(&cfg, 5);
        let mut a = Uniform;
        let batch = simulate(&cfg, &trace, &signals, &mut a, 5);

        let mut b = Uniform;
        let mut session = SimSession::new(&cfg, &trace, &signals, &mut b, 5);
        let mut steps = 0;
        while let Some(rec) = session.step() {
            assert_eq!(rec.epoch, steps);
            steps += 1;
        }
        assert!(session.is_done());
        let streamed = session.finish();
        assert_eq!(steps, cfg.epochs);
        assert_eq!(batch.total.requests, streamed.total.requests);
        assert_eq!(batch.total.carbon_kg, streamed.total.carbon_kg);
        assert_eq!(batch.total.ttft_sum_s, streamed.total.ttft_sum_s);
        assert_eq!(batch.total.dropped, streamed.total.dropped);
        for (x, y) in batch.per_epoch.iter().zip(&streamed.per_epoch) {
            assert_eq!(x.plan, y.plan);
            assert_eq!(x.ledger.e_it_j, y.ledger.e_it_j);
        }
    }

    #[test]
    fn events_dip_and_restore_capacity() {
        let cfg = SystemConfig::small_test();
        let (trace, signals) = world(&cfg, 3);
        let mut s = Uniform;
        let events = vec![
            ScenarioEvent::at(
                2,
                ClusterAction::ScaleRegion {
                    region: 2,
                    frac: 0.0,
                },
            ),
            ScenarioEvent::at(4, ClusterAction::RestoreRegion { region: 2 }),
        ];
        let res = SimSession::new(&cfg, &trace, &signals, &mut s, 3)
            .with_events(events)
            .run();
        let full: usize = res.per_epoch[0].site_nodes.iter().sum();
        let dipped: usize = res.per_epoch[2].site_nodes.iter().sum();
        let restored: usize = res.per_epoch[4].site_nodes.iter().sum();
        assert!(dipped < full, "no capacity dip: {dipped} vs {full}");
        assert_eq!(restored, full, "capacity not restored");
        // request mass is conserved across the outage window
        let expected: f64 = trace.epochs[..cfg.epochs]
            .iter()
            .map(|e| {
                e.classes.iter().map(|c| c.n_req.round()).sum::<f64>()
            })
            .sum();
        assert!((res.total.requests - expected).abs() < 1e-6);
    }

    #[test]
    fn prev_ledger_reaches_the_scheduler() {
        struct PrevProbe {
            saw_none: usize,
            saw_some: usize,
        }
        impl Scheduler for PrevProbe {
            fn name(&self) -> String {
                "prev-probe".into()
            }
            fn plan(&mut self, ctx: &EpochContext) -> Plan {
                match ctx.prev {
                    None => self.saw_none += 1,
                    Some(prev) => {
                        assert!(prev.requests >= 0.0);
                        // per-class realised demand rides along for the
                        // per-class feedback scheduler
                        assert_eq!(
                            prev.class_requests.len(),
                            ctx.cfg.num_classes()
                        );
                        assert!(
                            (prev.class_requests.iter().sum::<f64>()
                                - prev.requests)
                                .abs()
                                < 1e-9
                        );
                        self.saw_some += 1;
                    }
                }
                Plan::uniform(
                    ctx.cfg.num_classes(),
                    ctx.cfg.datacenters.len(),
                )
            }
        }
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let (trace, signals) = world(&cfg, 1);
        let mut probe = PrevProbe {
            saw_none: 0,
            saw_some: 0,
        };
        let _ =
            SimSession::new(&cfg, &trace, &signals, &mut probe, 1).run();
        assert_eq!(probe.saw_none, 1, "only epoch 0 lacks a prev ledger");
        assert_eq!(probe.saw_some, 2);
    }

    #[test]
    fn observers_see_every_epoch_and_the_finish() {
        struct Counter {
            epochs: usize,
            finished: bool,
        }
        impl EpochObserver for Counter {
            fn on_epoch(&mut self, rec: &EpochRecord, state: &ClusterState) {
                assert_eq!(rec.site_nodes, state.site_totals());
                self.epochs += 1;
            }
            fn on_finish(&mut self, result: &SimResult) {
                assert_eq!(result.per_epoch.len(), self.epochs);
                self.finished = true;
            }
        }
        // observers are boxed into the session, so count via a shared cell
        use std::cell::RefCell;
        use std::rc::Rc;
        struct Shared(Rc<RefCell<Counter>>);
        impl EpochObserver for Shared {
            fn on_epoch(&mut self, rec: &EpochRecord, state: &ClusterState) {
                self.0.borrow_mut().on_epoch(rec, state);
            }
            fn on_finish(&mut self, result: &SimResult) {
                self.0.borrow_mut().on_finish(result);
            }
        }
        let counter = Rc::new(RefCell::new(Counter {
            epochs: 0,
            finished: false,
        }));
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 4;
        let (trace, signals) = world(&cfg, 2);
        let mut s = Uniform;
        let _ = SimSession::new(&cfg, &trace, &signals, &mut s, 2)
            .with_observer(Box::new(Shared(Rc::clone(&counter))))
            .run();
        assert_eq!(counter.borrow().epochs, 4);
        assert!(counter.borrow().finished);
    }

    #[test]
    fn csv_observer_writes_the_time_series() {
        let tmp = std::env::temp_dir().join("slit_session_epochs.csv");
        let path = tmp.to_str().unwrap().to_string();
        let mut cfg = SystemConfig::small_test();
        cfg.epochs = 3;
        let (trace, signals) = world(&cfg, 7);
        let mut s = Uniform;
        let obs = CsvEpochObserver::create(&path).unwrap();
        let _ = SimSession::new(&cfg, &trace, &signals, &mut s, 7)
            .with_observer(Box::new(obs))
            .run();
        let (header, rows) = crate::util::csv::read_file(&path).unwrap();
        let want: Vec<String> = CsvEpochObserver::HEADER
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(header, want);
        assert_eq!(rows.len(), 3);
        // percentile columns are ordered and populated whenever the epoch
        // served requests
        let col = |name: &str| {
            header.iter().position(|h| h == name).unwrap()
        };
        let (c_req, c_p50, c_p99) =
            (col("requests"), col("ttft_p50_s"), col("ttft_p99_s"));
        for row in &rows {
            let req: f64 = row[c_req].parse().unwrap();
            let p50: f64 = row[c_p50].parse().unwrap();
            let p99: f64 = row[c_p99].parse().unwrap();
            assert!(p99 >= p50, "p99 {p99} < p50 {p50}");
            if req > 0.0 {
                assert!(p50 > 0.0, "served epoch with zero p50");
            }
        }
        std::fs::remove_file(&tmp).ok();
    }
}
