//! Grid signal synthesis: time-varying carbon intensity CI_{l,t}, water
//! intensity WI_{l,t}, and time-of-use price TOU_{l,t} per datacenter.
//!
//! The paper consumes electricitymaps-style feeds; we synthesise them
//! (repro substitution, DESIGN.md §3): each signal is a diurnal curve in
//! the site's local solar time plus a weekly modulation and deterministic
//! seeded noise. Carbon follows the classic duck shape for solar-heavy
//! grids (midday dip, evening peak); TOU peaks in business hours; WI is
//! flatter but follows the generation mix.

use crate::config::SystemConfig;
use crate::util::rng::Rng;

/// Precomputed per-epoch grid signals for every datacenter.
#[derive(Clone, Debug)]
pub struct GridSignals {
    /// Carbon intensity, kg CO2 per kWh: `ci[dc][epoch]`.
    pub ci: Vec<Vec<f64>>,
    /// Water intensity of electricity, L per kWh.
    pub wi: Vec<Vec<f64>>,
    /// Time-of-use price, $ per kWh.
    pub tou: Vec<Vec<f64>>,
    /// Epoch length in seconds (to map epoch -> local hour).
    pub epoch_s: f64,
}

impl GridSignals {
    /// Synthesise `epochs` epochs of signals for every DC in the config.
    pub fn generate(cfg: &SystemConfig, epochs: usize, seed: u64) -> Self {
        let mut root = Rng::new(seed ^ 0x5157_4752_4944); // "QWGRID"
        let mut ci = Vec::with_capacity(cfg.datacenters.len());
        let mut wi = Vec::with_capacity(cfg.datacenters.len());
        let mut tou = Vec::with_capacity(cfg.datacenters.len());

        for (l, dc) in cfg.datacenters.iter().enumerate() {
            let mut r = root.fork(l as u64 + 1);
            let mut ci_l = Vec::with_capacity(epochs);
            let mut wi_l = Vec::with_capacity(epochs);
            let mut tou_l = Vec::with_capacity(epochs);
            // smooth AR(1) noise so adjacent epochs are correlated
            let mut noise_ci = 0.0f64;
            let mut noise_tou = 0.0f64;
            for t in 0..epochs {
                let hour = local_hour(t, cfg.physics.epoch_s, dc.tz_offset_h);
                let day = (t as f64 * cfg.physics.epoch_s / 86_400.0).floor();
                let weekly = 1.0 + 0.05 * (day * 0.9).sin();

                noise_ci = 0.9 * noise_ci + 0.1 * r.gauss();
                noise_tou = 0.9 * noise_tou + 0.1 * r.gauss();

                // duck curve: dip centred at 13:00 local, peak ~19:00
                let solar_dip = (-((hour - 13.0) / 3.5).powi(2)).exp();
                let evening_peak = (-((hour - 19.0) / 2.5).powi(2)).exp();
                let ci_shape = 1.0 - dc.ci_amp * solar_dip
                    + 0.6 * dc.ci_amp * evening_peak;
                let ci_v = (dc.ci_base * ci_shape * weekly
                    * (1.0 + 0.08 * noise_ci))
                    .max(0.005);

                // business-hours TOU: peak 8:00-21:00, shoulder edges
                let peak = smooth_window(hour, 8.0, 21.0);
                let tou_v = (dc.tou_base * (1.0 + dc.tou_amp * peak)
                    * (1.0 + 0.04 * noise_tou))
                    .max(0.005);

                // WI follows the mix: when solar displaces thermal (midday),
                // evaporative-cooled thermal generation recedes slightly.
                let wi_v = (dc.wi_base
                    * (1.0 - 0.5 * dc.wi_amp * solar_dip)
                    * weekly)
                    .max(0.05);

                ci_l.push(ci_v);
                tou_l.push(tou_v);
                wi_l.push(wi_v);
            }
            ci.push(ci_l);
            wi.push(wi_l);
            tou.push(tou_l);
        }
        GridSignals {
            ci,
            wi,
            tou,
            epoch_s: cfg.physics.epoch_s,
        }
    }

    pub fn epochs(&self) -> usize {
        self.ci.first().map(|v| v.len()).unwrap_or(0)
    }

    /// Scale one datacenter's signals over an epoch window (scenario
    /// shaping hook: carbon-intensity spikes, drought-driven WI surges,
    /// price shocks). The range is clamped to the generated horizon.
    pub fn scale_window(
        &mut self,
        dc: usize,
        epochs: std::ops::Range<usize>,
        ci_mult: f64,
        wi_mult: f64,
        tou_mult: f64,
    ) {
        let n = self.epochs();
        let lo = epochs.start.min(n);
        let hi = epochs.end.min(n);
        for t in lo..hi {
            self.ci[dc][t] *= ci_mult;
            self.wi[dc][t] *= wi_mult;
            self.tou[dc][t] *= tou_mult;
        }
    }

    /// Mean of the carbon signal over an epoch window for one DC
    /// (scenario shaping and its tests).
    pub fn mean_ci(&self, dc: usize, epochs: std::ops::Range<usize>) -> f64 {
        let n = self.epochs();
        let lo = epochs.start.min(n);
        let hi = epochs.end.min(n);
        if hi <= lo {
            return 0.0;
        }
        self.ci[dc][lo..hi].iter().sum::<f64>() / (hi - lo) as f64
    }

    /// Signal snapshot for one epoch: (ci, wi, tou) per DC.
    pub fn at(&self, epoch: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let t = epoch.min(self.epochs().saturating_sub(1));
        (
            self.ci.iter().map(|v| v[t]).collect(),
            self.wi.iter().map(|v| v[t]).collect(),
            self.tou.iter().map(|v| v[t]).collect(),
        )
    }
}

/// Local solar hour-of-day for an epoch index.
pub fn local_hour(epoch: usize, epoch_s: f64, tz_offset_h: f64) -> f64 {
    let h = epoch as f64 * epoch_s / 3600.0 + tz_offset_h;
    h.rem_euclid(24.0)
}

/// Smooth 0..1 indicator of `x` in [lo, hi] with soft 1 h edges.
fn smooth_window(x: f64, lo: f64, hi: f64) -> f64 {
    let rise = sigmoid((x - lo) / 0.5);
    let fall = sigmoid((hi - x) / 0.5);
    rise * fall
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn signals() -> (SystemConfig, GridSignals) {
        let cfg = SystemConfig::paper_default();
        let s = GridSignals::generate(&cfg, 192, 7);
        (cfg, s)
    }

    #[test]
    fn shapes_and_positivity() {
        let (cfg, s) = signals();
        assert_eq!(s.ci.len(), cfg.datacenters.len());
        assert_eq!(s.epochs(), 192);
        for l in 0..cfg.datacenters.len() {
            for t in 0..192 {
                assert!(s.ci[l][t] > 0.0);
                assert!(s.wi[l][t] > 0.0);
                assert!(s.tou[l][t] > 0.0);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = SystemConfig::paper_default();
        let a = GridSignals::generate(&cfg, 96, 1);
        let b = GridSignals::generate(&cfg, 96, 1);
        let c = GridSignals::generate(&cfg, 96, 2);
        assert_eq!(a.ci, b.ci);
        assert_ne!(a.ci, c.ci);
    }

    #[test]
    fn ci_reflects_base_ordering() {
        // stockholm (0.03 base) must stay under tokyo (0.48 base) on average
        let (cfg, s) = signals();
        let idx = |name: &str| {
            cfg.datacenters.iter().position(|d| d.name == name).unwrap()
        };
        let avg = |l: usize| -> f64 {
            s.ci[l].iter().sum::<f64>() / s.ci[l].len() as f64
        };
        assert!(avg(idx("stockholm")) < 0.2 * avg(idx("tokyo")));
    }

    #[test]
    fn tou_peaks_during_business_hours() {
        let (cfg, s) = signals();
        // virginia, epochs covering one day
        let l = cfg
            .datacenters
            .iter()
            .position(|d| d.name == "virginia")
            .unwrap();
        let mut peak_sum = 0.0;
        let mut peak_n = 0;
        let mut night_sum = 0.0;
        let mut night_n = 0;
        for t in 0..96 {
            let h = local_hour(t, cfg.physics.epoch_s, cfg.datacenters[l].tz_offset_h);
            if (10.0..18.0).contains(&h) {
                peak_sum += s.tou[l][t];
                peak_n += 1;
            } else if !(7.0..22.0).contains(&h) {
                night_sum += s.tou[l][t];
                night_n += 1;
            }
        }
        assert!(peak_n > 0 && night_n > 0);
        assert!(peak_sum / peak_n as f64 > 1.2 * night_sum / night_n as f64);
    }

    #[test]
    fn duck_dip_for_solar_heavy_site() {
        let (cfg, s) = signals();
        let l = cfg
            .datacenters
            .iter()
            .position(|d| d.name == "melbourne") // ci_amp 0.4
            .unwrap();
        let mut noon = Vec::new();
        let mut evening = Vec::new();
        for t in 0..96 {
            let h = local_hour(t, cfg.physics.epoch_s, cfg.datacenters[l].tz_offset_h);
            if (12.0..14.0).contains(&h) {
                noon.push(s.ci[l][t]);
            }
            if (18.5..20.0).contains(&h) {
                evening.push(s.ci[l][t]);
            }
        }
        let m = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(m(&noon) < m(&evening), "no duck curve dip");
    }

    #[test]
    fn local_hour_wraps() {
        assert!((local_hour(0, 900.0, 9.0) - 9.0).abs() < 1e-9);
        assert!((local_hour(96, 900.0, 9.0) - 9.0).abs() < 1e-9);
        assert!(local_hour(4, 900.0, 23.5) < 24.0);
    }
}
