//! Minimal JSON substrate (parser + writer) — the offline image has no
//! `serde`, so configs, manifests and result files go through this module.
//!
//! Scope: full JSON grammar (RFC 8259) minus exotic escapes beyond \uXXXX;
//! numbers round-trip as f64, which is enough for config/metric payloads.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects keep sorted key order (BTreeMap) so output
/// is deterministic and diffable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn str_arr(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    /// Insert into an object (panics on non-objects — construction misuse).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `get` chained with a numeric conversion, with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    pub fn f64_vec(&self, key: &str) -> Option<Vec<f64>> {
        self.get(key)?
            .as_arr()?
            .iter()
            .map(Json::as_f64)
            .collect()
    }

    // ---- parsing -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- writing -----------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 scalar
                    let start = self.i;
                    let mut end = start + 1;
                    while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap(),
            &Json::Null
        );
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""éA""#).unwrap();
        assert_eq!(j.as_str(), Some("éA"));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"k":"v","n":null},"t":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn escaped_output_parses_back() {
        let j = Json::Str("quote \" slash \\ tab\t".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn helpers() {
        let mut j = Json::obj();
        j.set("x", Json::Num(4.0));
        j.set("v", Json::num_arr(&[1.0, 2.0]));
        assert_eq!(j.f64_or("x", 0.0), 4.0);
        assert_eq!(j.f64_or("y", 9.0), 9.0);
        assert_eq!(j.f64_vec("v").unwrap(), vec![1.0, 2.0]);
    }
}
