//! Property-testing substrate (the offline image has no proptest).
//!
//! `check` runs a property over many random cases; on failure it reports the
//! failing case seed so the exact case can be replayed with `replay`.
//! Generators are just closures over [`crate::util::rng::Rng`], which keeps
//! the whole thing ~100 lines while covering what the test-suite needs:
//! seeded case generation, failure reporting, and replayability.

use crate::util::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` over `cases` random cases derived from `seed`.
///
/// `gen` builds a case from an RNG; `prop` returns `Err(reason)` on failure.
/// Panics with the case seed + debug repr on the first failure.
pub fn check<T, G, P>(name: &str, seed: u64, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = derive_seed(seed, case as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} \
                 (replay seed {case_seed:#x}):\n  reason: {reason}\n  \
                 input: {input:?}"
            );
        }
    }
}

/// Replay one failing case by seed (from the `check` panic message).
pub fn replay<T, G, P>(seed: u64, gen: G, prop: P) -> Result<(), String>
where
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    let input = gen(&mut rng);
    prop(&input)
}

fn derive_seed(seed: u64, case: u64) -> u64 {
    // SplitMix-style mixing keeps per-case streams decorrelated.
    let mut z = seed ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Exact mass-balance check: `total` must equal the sum of `parts` bit-for-bit.
///
/// Meant for integral flows (request counts, deferred lots) where f64
/// addition is exact and any drift is a real accounting bug, not rounding.
pub fn mass_balance(total: f64, parts: &[f64]) -> Result<(), String> {
    let sum: f64 = parts.iter().sum();
    if total == sum {
        Ok(())
    } else {
        Err(format!(
            "mass not conserved: total {total} != sum{parts:?} = {sum} \
             (diff {})",
            total - sum
        ))
    }
}

/// Assert two floats agree to relative tolerance (helper for properties).
pub fn close(a: f64, b: f64, rtol: f64) -> Result<(), String> {
    let scale = a.abs().max(b.abs()).max(1e-12);
    if (a - b).abs() <= rtol * scale {
        Ok(())
    } else {
        Err(format!("{a} != {b} (rtol {rtol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        // count via interior closure state is awkward with Fn; use a cell
        let counter = std::cell::Cell::new(0usize);
        check(
            "sum-commutes",
            42,
            64,
            |r| (r.below(100) as i64, r.below(100) as i64),
            |&(a, b)| {
                counter.set(counter.get() + 1);
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math is broken".into())
                }
            },
        );
        count += counter.get();
        assert_eq!(count, 64);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            7,
            16,
            |r| r.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn replay_reproduces_case() {
        // find a case seed where input > 5, then replay it
        let mut found = None;
        for case in 0..100u64 {
            let s = derive_seed(99, case);
            let v = Rng::new(s).below(10);
            if v > 5 {
                found = Some((s, v));
                break;
            }
        }
        let (seed, val) = found.expect("some case exceeds 5");
        let r = replay(
            seed,
            |r| r.below(10),
            |&v| {
                if v == val {
                    Ok(())
                } else {
                    Err(format!("{v} != {val}"))
                }
            },
        );
        assert!(r.is_ok());
    }

    #[test]
    fn mass_balance_is_exact() {
        assert!(mass_balance(10.0, &[4.0, 6.0]).is_ok());
        assert!(mass_balance(10.0, &[4.0, 6.0 + 1e-9]).is_err());
        assert!(mass_balance(0.0, &[]).is_ok());
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(close(1.0, 1.1, 1e-6).is_err());
        assert!(close(0.0, 0.0, 1e-12).is_ok());
    }
}
