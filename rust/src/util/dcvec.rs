//! `DcVec`: tiled per-datacenter `f64` storage — the abstraction that
//! breaks the 16-site ceiling without giving up the zero-allocation hot
//! path (DESIGN.md §14).
//!
//! Fleets up to [`DC_TILE`] sites (the AOT artifact's padded `DC_SLOTS`)
//! live entirely in an inline `[f64; DC_TILE]` tile: constructing,
//! cloning, and copying a `DcVec` then performs **zero heap operations**
//! (an empty `Vec` clone does not allocate), so `eval::PlanAgg` stays as
//! cheap as the fixed stack buffers it replaces — pinned by
//! rust/tests/alloc_hotpath.rs. Larger fleets transparently spill to a
//! heap-backed buffer sized once from the fleet; steady-state reuse via
//! [`DcVec::copy_from`] keeps the spill path allocation-free too, which
//! is what the SLIT delta-rescoring loop relies on at L = 48.
//!
//! The arithmetic is storage-agnostic: every consumer reads/writes through
//! [`DcVec::as_slice`] / [`DcVec::as_mut_slice`], so objective math is
//! bit-identical between the inline and spill representations (pinned by
//! rust/tests/dcvec_parity.rs against a raw stack-array oracle).

use crate::config::DC_SLOTS;

/// Inline tile width. Equal to the AOT artifact's padded `DC_SLOTS`, so
/// "fits the tile" and "runnable on the AOT backend" are the same bound.
pub const DC_TILE: usize = DC_SLOTS;

/// Per-datacenter `f64` vector with inline storage for small fleets and
/// heap spill for large ones. The length is fixed at construction (sized
/// once from the `SystemConfig`'s fleet).
#[derive(Clone, Debug)]
pub struct DcVec {
    /// Inline tile, authoritative when `len <= DC_TILE`.
    inline: [f64; DC_TILE],
    /// Spill buffer, authoritative when `len > DC_TILE` (empty otherwise,
    /// so deriving `Clone` stays allocation-free on the inline path).
    spill: Vec<f64>,
    len: usize,
}

impl DcVec {
    /// An all-zeros vector of `len` lanes. Allocation-free for
    /// `len <= DC_TILE`; one sized allocation otherwise.
    pub fn zeros(len: usize) -> DcVec {
        DcVec {
            inline: [0.0; DC_TILE],
            spill: if len <= DC_TILE {
                Vec::new()
            } else {
                vec![0.0; len]
            },
            len,
        }
    }

    /// Copy an existing slice into fresh tiled storage.
    pub fn from_slice(v: &[f64]) -> DcVec {
        let mut d = DcVec::zeros(v.len());
        d.as_mut_slice().copy_from_slice(v);
        d
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when the vector fits the inline tile (no heap involvement).
    #[inline]
    pub fn is_inline(&self) -> bool {
        self.len <= DC_TILE
    }

    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        if self.len <= DC_TILE {
            &self.inline[..self.len]
        } else {
            &self.spill
        }
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        if self.len <= DC_TILE {
            &mut self.inline[..self.len]
        } else {
            &mut self.spill
        }
    }

    pub fn fill(&mut self, v: f64) {
        self.as_mut_slice().fill(v);
    }

    /// Overwrite with `other`'s contents, reusing this vector's spill
    /// allocation. Allocation-free whenever the shapes match (inline ->
    /// inline is a tile copy; spill -> spill reuses capacity), which is
    /// what keeps the per-candidate delta rescore heap-silent at any L.
    pub fn copy_from(&mut self, other: &DcVec) {
        if other.len <= DC_TILE {
            self.inline = other.inline;
            self.spill.clear();
        } else {
            self.spill.clear();
            self.spill.extend_from_slice(&other.spill);
        }
        self.len = other.len;
    }
}

impl std::ops::Index<usize> for DcVec {
    type Output = f64;

    #[inline]
    fn index(&self, i: usize) -> &f64 {
        &self.as_slice()[i]
    }
}

impl std::ops::IndexMut<usize> for DcVec {
    #[inline]
    fn index_mut(&mut self, i: usize) -> &mut f64 {
        &mut self.as_mut_slice()[i]
    }
}

/// Equality is value equality over the live lanes; the unused inline tile
/// tail of a spilled vector never participates.
impl PartialEq for DcVec {
    fn eq(&self, other: &DcVec) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inline_and_spill_boundary() {
        for len in [0, 1, DC_TILE - 1, DC_TILE, DC_TILE + 1, 48] {
            let d = DcVec::zeros(len);
            assert_eq!(d.len(), len);
            assert_eq!(d.as_slice().len(), len);
            assert_eq!(d.is_inline(), len <= DC_TILE);
            assert!(d.as_slice().iter().all(|&v| v == 0.0));
        }
        assert!(DcVec::zeros(0).is_empty());
    }

    #[test]
    fn from_slice_round_trips_both_representations() {
        for len in [3, DC_TILE, 48] {
            let src: Vec<f64> = (0..len).map(|i| i as f64 * 1.5 - 2.0).collect();
            let d = DcVec::from_slice(&src);
            assert_eq!(d.as_slice(), &src[..]);
            assert_eq!(d[len - 1], src[len - 1]);
            let mut e = d.clone();
            assert_eq!(d, e);
            e[0] += 1.0;
            assert_ne!(d, e);
        }
    }

    #[test]
    fn copy_from_transfers_across_shapes() {
        let small = DcVec::from_slice(&[1.0, 2.0, 3.0]);
        let big = DcVec::from_slice(&(0..48).map(|i| i as f64).collect::<Vec<_>>());
        let mut d = DcVec::zeros(48);
        d.copy_from(&small);
        assert_eq!(d, small);
        assert!(d.is_inline());
        d.copy_from(&big);
        assert_eq!(d, big);
        assert!(!d.is_inline());
        // same-shape overwrite reuses the spill capacity
        let big2 = DcVec::from_slice(&(0..48).map(|i| -(i as f64)).collect::<Vec<_>>());
        d.copy_from(&big2);
        assert_eq!(d, big2);
    }

    #[test]
    fn index_mut_and_fill() {
        let mut d = DcVec::zeros(48);
        d[47] = 9.0;
        assert_eq!(d.as_slice()[47], 9.0);
        d.fill(2.5);
        assert!(d.as_slice().iter().all(|&v| v == 2.5));
        let mut i = DcVec::zeros(4);
        i[3] = 1.0;
        assert_eq!(i.as_slice(), &[0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn equality_ignores_stale_inline_lanes_of_a_spilled_vector() {
        // a spilled vector can carry stale inline garbage (here: lanes
        // left behind by an earlier inline copy_from); PartialEq must
        // compare only the live spill lanes
        let wide: Vec<f64> = (0..48).map(|i| i as f64).collect();
        let mut a = DcVec::zeros(48);
        a.copy_from(&DcVec::from_slice(&[7.0; 5])); // dirties the inline tile
        a.copy_from(&DcVec::from_slice(&wide)); // back to spilled
        assert!(!a.is_inline());
        assert_eq!(a, DcVec::from_slice(&wide), "stale inline lanes leaked");
        // and differing lengths never compare equal
        assert_ne!(DcVec::from_slice(&[1.0; 5]), DcVec::from_slice(&[1.0; 6]));
    }
}
